"""§Roofline — three-term roofline from the dry-run artifacts, plus
the analytic roofline of the CC hot-loop kernels.

Per (arch x shape) on the single-pod mesh:
    compute    = HLO_FLOPs / peak_FLOPs            (per device)
    memory     = HLO_bytes / HBM_bw                (per device)
    collective = collective_bytes / link_bw        (per device)
plus MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) and the useful-compute
ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy waste).

CC kernels (``cc_kernel_rows``): the fluid-reduce segment reduction
and the fused per-flow block (gen/np-timer + RP + ERP) are pure
bandwidth shapes — a handful of adds per element over many state
vectors — so their roofline is the HBM term: one read of every input
vector + one write of every output per dt.  The rows report the
bytes-per-step each kernel moves at DC scale and the implied ceiling
on steps/sec, alongside the attention kernels' measured cells.

Hardware constants (TPU v5e per assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link (per assignment)

ART = "artifacts/dryrun"


def _model_flops(arch: str, shape: str) -> float:
    """Analytic 6·N_active·D for the cell (D = tokens processed)."""
    from repro.configs import SHAPES, get_config
    cfg = get_config(arch)
    cell = SHAPES[shape]
    n_active = cfg.active_param_count()
    if cell.step == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.step == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * cell.global_batch      # decode: 1 token/seq


def analyze_cell(path: str, n_chips: int = 256) -> dict | None:
    with open(path) as f:
        rec = json.load(f)
    if rec.get("skipped"):
        return None
    arch, shape = rec["arch"], rec["shape"]
    # artifact numbers are per-device (SPMD module)
    flops_dev = rec["flops_total"]
    bytes_dev = rec["bytes_accessed_total"]
    coll_dev = rec["collective_bytes_total"]
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = _model_flops(arch, shape)
    mf_dev = mf / n_chips
    bound = max(terms.values())
    return {
        "arch": arch, "shape": shape, "mesh": rec["mesh"],
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops_dev": mf_dev,
        "useful_ratio": mf_dev / flops_dev if flops_dev > 0 else 0.0,
        "roofline_fraction": (mf_dev / PEAK_FLOPS) / bound
        if bound > 0 else 0.0,
        "temp_gib": rec["memory"].get("temp_size_in_bytes", 0) / 2**30,
        "args_gib": rec["memory"].get("argument_size_in_bytes", 0) / 2**30,
    }


def build_table(mesh_dir: str = "pod16x16") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, mesh_dir, "*.json"))):
        r = analyze_cell(path)
        if r:
            rows.append(r)
    return rows


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | useful | roofline frac | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
                 f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
                 f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
                 f"{r['roofline_fraction']:.3f} | {r['temp_gib']:.2f} |\n")
    return hdr + body


def cc_kernel_rows() -> list[dict]:
    """Analytic roofline cells for the fluid hot-loop kernels.

    Shapes follow the perf harness's scaling curve extrapolated to DC
    scale (10^5..10^6 flows).  Bytes are f32 vectors moved per dt:

      * fluid_reduce — per reduction pass: [N, C] data + int32 segment
        ids in, [S, C] sums out; the fluid step runs 3 passes with
        (3, 3, 2) channels over N = F*K*H rows, S = L+1 links.
      * cc_flow_block — gen/np-timer (9 in / 4 out), RP (9/8) and ERP
        (5/5 incl. params) per-flow kernels: 40 [F] vectors total, the
        "one HBM round trip per state vector" budget.

    The bytes model is :func:`repro.fleet.plan.fluid_step_bytes` — the
    SAME formula the fleet planner balances shards with, imported so
    the two can never drift.
    """
    from repro.fleet.plan import fluid_step_bytes

    rows = []
    for F, K, H, L in [(1 << 17, 1, 6, 1 << 14), (1 << 20, 4, 6, 1 << 16)]:
        n = F * K * H
        flow_bytes = 40 * F * 4
        red_bytes = fluid_step_bytes(F, K, H, L) - flow_bytes
        red_flops = sum(c * n for c in (3, 3, 2))
        flow_flops = 60 * F
        for name, byts, flops in [
                ("fluid_reduce", red_bytes, red_flops),
                ("cc_flow_block", flow_bytes, flow_flops)]:
            t_mem = byts / HBM_BW
            t_comp = flops / PEAK_FLOPS
            rows.append({
                "kernel": name,
                "shape": f"f{F}k{K}l{L}",
                "bytes_per_step": byts,
                "memory_s": t_mem,
                "compute_s": t_comp,
                "dominant": "memory" if t_mem >= t_comp else "compute",
                "steps_per_s_ceiling": 1.0 / max(t_mem, t_comp),
            })
    return rows


def megakernel_rows(blocks: tuple = (1, 10, 100)) -> list[dict]:
    """Analytic roofline cells for the whole-step megakernel
    (``repro.kernels.fluid_step``), per substep-block size.

    One launch runs ``block`` substeps with the fluid state
    VMEM-resident; HBM traffic per launch is one read of state +
    scenario and one write of state + the decimated ``TraceSample``
    row, so bytes *per substep* fall as ``1/block`` while in-kernel
    FLOPs per substep stay constant (the reduction + per-flow update
    math).  The VMEM footprint (state in + out + scenario — the number
    ``mega_footprint`` checks against ``MEGA_VMEM_CAP``) is
    block-independent: blocking buys bandwidth, not residency.

    State model (f32): 2 [F, H] hop tensors (queues + EWMA), ~23 [F]
    flow vectors (counters, rates, CC state dict), 2 [D, F] delay-line
    rings (D = 32 slots); scenario: [F, H] routes + 3 [F*K*H]
    incidence/alt tables + per-link capacity/sink; sample: ~11 [F]
    trace channels.
    """
    D = 32
    rows = []
    for F, K, H, L in [(1 << 17, 1, 6, 1 << 14), (1 << 20, 4, 6, 1 << 16)]:
        state = 4 * (2 * F * H + 23 * F + 2 * D * F)
        scen = 4 * (F * H + 3 * F * K * H + 2 * (L + 2))
        sample = 4 * 11 * F
        n = F * K * H
        flops = sum(c * n for c in (3, 3, 2)) + 60 * F   # per substep
        vmem = 2 * state + scen
        for blk in blocks:
            byts = (2 * state + scen + sample) / blk
            t_mem = byts / HBM_BW
            t_comp = flops / PEAK_FLOPS
            rows.append({
                "kernel": f"fluid_megastep_k{blk}",
                "shape": f"f{F}k{K}l{L}",
                "block": blk,
                "bytes_per_step": byts,
                "flops_per_step": flops,
                "vmem_bytes": vmem,
                "memory_s": t_mem,
                "compute_s": t_comp,
                "dominant": "memory" if t_mem >= t_comp else "compute",
                "steps_per_s_ceiling": 1.0 / max(t_mem, t_comp),
            })
    return rows


def mega_to_markdown(rows: list[dict]) -> str:
    hdr = ("| kernel | shape | block | MB/step | MFLOP/step | VMEM MB | "
           "dominant | steps/s ceiling |\n|---|---|---|---|---|---|---|"
           "---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['kernel']} | {r['shape']} | {r['block']} | "
                 f"{r['bytes_per_step'] / 2**20:.1f} | "
                 f"{r['flops_per_step'] / 1e6:.1f} | "
                 f"{r['vmem_bytes'] / 2**20:.1f} | **{r['dominant']}** | "
                 f"{r['steps_per_s_ceiling']:.3e} |\n")
    return hdr + body


def cc_to_markdown(rows: list[dict]) -> str:
    hdr = ("| kernel | shape | MB/step | memory s | dominant | "
           "steps/s ceiling |\n|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['kernel']} | {r['shape']} | "
                 f"{r['bytes_per_step'] / 2**20:.1f} | "
                 f"{r['memory_s']:.3e} | **{r['dominant']}** | "
                 f"{r['steps_per_s_ceiling']:.3e} |\n")
    return hdr + body


def main() -> list[tuple]:
    rows = build_table()
    cc_rows = cc_kernel_rows()
    mega_rows = megakernel_rows()
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/roofline.md", "w") as f:
        f.write(to_markdown(rows))
        f.write("\n## CC hot-loop kernels (analytic)\n\n")
        f.write(cc_to_markdown(cc_rows))
        f.write("\n## Whole-step megakernel vs substep block (analytic)"
                "\n\n")
        f.write(mega_to_markdown(mega_rows))
    out = []
    for r in rows:
        out.append((f"roofline.{r['arch']}.{r['shape']}",
                    max(r["compute_s"], r["memory_s"],
                        r["collective_s"]) * 1e6,
                    f"dom={r['dominant']} frac={r['roofline_fraction']:.3f}"
                    f" useful={r['useful_ratio']:.2f}"))
    for r in cc_rows:
        out.append((f"roofline.cc.{r['kernel']}.{r['shape']}",
                    r["memory_s"] * 1e6,
                    f"dom={r['dominant']} "
                    f"ceil={r['steps_per_s_ceiling']:.2e}steps/s"))
    for r in mega_rows:
        out.append((f"roofline.cc.{r['kernel']}.{r['shape']}",
                    max(r["memory_s"], r["compute_s"]) * 1e6,
                    f"dom={r['dominant']} "
                    f"vmem={r['vmem_bytes'] / 2**20:.0f}MB "
                    f"ceil={r['steps_per_s_ceiling']:.2e}steps/s"))
    return out


if __name__ == "__main__":
    for row in main():
        print(",".join(str(x) for x in row))
