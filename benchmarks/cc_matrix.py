"""``--cc-matrix`` harness: the full stage-combination sweep.

Enumerates the ``repro.core.cc`` registries — every (marking x
notification x reaction) combination, including variants registered
after this file was written — and runs the whole matrix on the paper's
incast scene as ONE ``Sweep`` launch.  The stage selectors are traced
data, so the matrix shares a single compiled step; the harness asserts
that (``SWEEP_EXEC_CACHE`` must report exactly one executable build) and
appends the per-combination headline rows to ``BENCH_fluid.json``
under the ``cc_matrix`` key (the CI ``cc-matrix`` job uploads the
refreshed file as an artifact).
"""

from __future__ import annotations

import time

N_STEPS = 4000
N_STEPS_QUICK = 1200


def run_matrix(quick: bool = False,
               use_kernels: "bool | str" = False) -> dict:
    """Execute the registry product; returns the BENCH record.

    ``use_kernels="mega"`` runs the same matrix through the whole-step
    megakernel (interpret mode off-TPU): the stage codes are traced
    data *inside* the kernel, so the full combination product must
    still resolve to exactly one executable build.
    """
    from repro.core import CCSpec, ScenarioSpec, Sweep, cc
    from repro.core.experiments import SWEEP_EXEC_CACHE

    from repro.core import DCQCNParams, SimParams

    # give the new variants a regime where they are *distinct*: a real
    # kmin < kmax ramp for slope marking (the defaults' kmin == kmax
    # degenerates it to step marking), and a 0.25 us integrator so the
    # CNP feedback delay spans ~9 steps and FNCC's in-path shortcut is
    # observable (at dt = 1 us the whole RTT rounds to the 2-step floor)
    base = CCSpec(
        dcqcn=DCQCNParams(kmax=4 * 15 * 1024.0, pmax=0.25),
        sim=SimParams(dt=0.25e-6))
    configs = {
        f"{m}+{n}+{r}": base.replace(marking=m, notification=n,
                                     reaction=r)
        for m in cc.MARKING.names()
        for n in cc.NOTIFICATION.names()
        for r in cc.REACTION.names()
    }
    # the paper scene, opened early so even the quick run covers the
    # congestion transient (default generators open at 1 ms)
    scn = ScenarioSpec.paper_incast(roll=0, t_start=0.1e-3,
                                    label="hol")
    n_steps = (N_STEPS_QUICK if quick else N_STEPS) * 4
    misses0 = SWEEP_EXEC_CACHE.stats().misses
    t0 = time.perf_counter()
    res = Sweep.grid(configs=configs, scenarios={"hol": scn}).run(
        n_steps=n_steps, use_kernels=use_kernels,
        interpret=bool(use_kernels))
    wall = time.perf_counter() - t0
    compiles = SWEEP_EXEC_CACHE.stats().misses - misses0
    points = []
    for name, row in res.summary().items():
        points.append({
            "name": name,
            "aggregate_gbps": round(row["aggregate_gbps"], 3),
            "min_flow_gbps": round(row["min_flow_gbps"], 3),
            "peak_queue_kb": round(row["peak_queue_kb"], 1),
            "marks": row["marks"],
            "cnps": row["cnps"],
        })
    try:
        from ._env import bench_env
    except ImportError:              # `python benchmarks/cc_matrix.py`
        from _env import bench_env
    return {
        "unix_time": int(time.time()),
        **bench_env(interpret=bool(use_kernels)),
        "quick": quick,
        "use_kernels": str(use_kernels),
        "n_steps": n_steps,
        "n_points": len(points),
        "compiles": compiles,
        "wall_s": round(wall, 2),
        "marking": list(cc.MARKING.names()),
        "notification": list(cc.NOTIFICATION.names()),
        "reaction": list(cc.REACTION.names()),
        "points": points,
    }


def _perf_fluid():
    """The sibling module owning BENCH_fluid.json (both import modes)."""
    try:
        from . import perf_fluid
    except ImportError:              # `python benchmarks/cc_matrix.py`
        import perf_fluid
    return perf_fluid


def append_matrix_record(record: dict) -> None:
    import json

    pf = _perf_fluid()
    doc = pf.load_bench()
    doc.setdefault("cc_matrix", []).append(record)
    with open(pf.BENCH_PATH, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"appended cc-matrix record -> {pf.BENCH_PATH} "
          f"({len(doc['cc_matrix'])} records)")


def main(quick: bool = False) -> list[tuple]:
    """run.py section hook: run the matrix, append, sanity-gate."""
    record = run_matrix(quick=quick)
    append_matrix_record(record)
    rows = []
    for p in record["points"]:
        rows.append((f"cc_matrix.{p['name']}", 0.0,
                     f"agg={p['aggregate_gbps']:.2f}GB/s "
                     f"min={p['min_flow_gbps']:.2f}GB/s "
                     f"marks={p['marks']} cnps={p['cnps']}"))
    if record["compiles"] != 1:
        rows.append(("cc_matrix.RECOMPILE", 0.0,
                     f"{record['n_points']} stage combinations took "
                     f"{record['compiles']} executable builds; the "
                     f"matrix must ride ONE jit"))
    else:
        rows.append(("cc_matrix.one_launch", record["wall_s"] * 1e6,
                     f"{record['n_points']} combos, 1 compile, "
                     f"{record['wall_s']:.1f}s"))
    # the same matrix through the megakernel: stage dispatch rides the
    # traced codes inside the single pallas_call, so the whole product
    # must again be ONE executable build (always at quick depth — this
    # pass gates the compile counter, not throughput)
    mega = run_matrix(quick=True, use_kernels="mega")
    append_matrix_record(mega)
    if mega["compiles"] != 1:
        rows.append(("cc_matrix.MEGA_RECOMPILE", 0.0,
                     f"{mega['n_points']} stage combinations took "
                     f"{mega['compiles']} megakernel builds; the "
                     f"matrix must ride ONE kernel build"))
    else:
        rows.append(("cc_matrix.mega_one_launch", mega["wall_s"] * 1e6,
                     f"{mega['n_points']} combos through the "
                     f"megakernel, 1 compile, {mega['wall_s']:.1f}s"))
    return rows


if __name__ == "__main__":
    import sys
    rows = main(quick="--quick" in sys.argv)
    for row in rows:
        print(",".join(str(x) for x in row))
    if any("RECOMPILE" in r[0] for r in rows):   # covers MEGA_RECOMPILE
        raise SystemExit(1)
