"""Render artifact tables into EXPERIMENTS.md at the <!-- X --> markers.

  PYTHONPATH=src python -m benchmarks.report
"""

from __future__ import annotations

import glob
import json
import os
import re

from .roofline import build_table, to_markdown


def dryrun_table() -> str:
    rows = []
    for mesh in ("pod16x16", "pod2x16x16"):
        for p in sorted(glob.glob(f"artifacts/dryrun/{mesh}/*.json")):
            r = json.load(open(p))
            if r.get("skipped"):
                continue
            rows.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | "
                f"{r['compile_s']:.0f}s | "
                f"{r['memory'].get('argument_size_in_bytes', 0)/2**30:.2f} | "
                f"{r['memory'].get('temp_size_in_bytes', 0)/2**30:.2f} | "
                f"{r['flops_total']:.3g} | "
                f"{r['collective_bytes_total']:.3g} |")
    n1 = len(glob.glob("artifacts/dryrun/pod16x16/*.json"))
    n2 = len(glob.glob("artifacts/dryrun/pod2x16x16/*.json"))
    head = (f"**{n1} single-pod + {n2} multi-pod cells compiled** "
            "(34 runnable of 40; 6 documented skips).\n\n"
            "| arch | shape | mesh | compile | args GiB/dev | temp GiB/dev "
            "| flops/dev | coll B/dev |\n|---|---|---|---|---|---|---|---|\n")
    return head + "\n".join(rows) + "\n"


def cosim_table() -> str:
    try:
        from .cosim import main as cosim_main
        rows = cosim_main(limit=10)
    except Exception as e:   # noqa: BLE001
        return f"(co-sim unavailable: {e})\n"
    out = "| arch | PFC | DCQCN | DCQCN-Rev |\n|---|---|---|---|\n"
    for name, _, derived in rows:
        if ".section" in name or "skipped" in name:
            continue
        arch = name.split(".", 1)[1]
        d = dict(kv.split("=") for kv in derived.split() if "=" in kv)
        out += (f"| {arch} | {d.get('pfc','-')} | {d.get('dcqcn','-')} | "
                f"{d.get('rev','-')} ({d.get('rev_vs_dcqcn','-')} vs "
                f"DCQCN) |\n")
    return out


def perf_log() -> str:
    paths = sorted(glob.glob("artifacts/perf/*.json"))
    if not paths:
        return "(perf iterations pending)\n"
    out = ""
    for p in paths:
        r = json.load(open(p))
        out += (f"* `{r['arch']} x {r['shape']}` **{r['tag']}** "
                f"({', '.join(r['overrides'])}): "
                f"flops {r['flops_total']:.3g}, "
                f"bytes {r['bytes_accessed_total']:.3g}, "
                f"coll {r['collective_bytes_total']:.3g}, "
                f"temp {r['memory'].get('temp_size_in_bytes',0)/2**30:.1f} "
                f"GiB\n")
    return out


def inject(markdown: str, marker: str, content: str) -> str:
    tag = f"<!-- {marker} -->"
    if tag not in markdown:
        return markdown
    pattern = re.escape(tag) + r".*?(?=\n## |\Z)"
    return re.sub(pattern, tag + "\n\n" + content, markdown,
                  flags=re.DOTALL)


def main():
    path = "EXPERIMENTS.md"
    md = open(path).read()
    md = inject(md, "DRYRUN_TABLE", dryrun_table())
    rows = build_table()
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/roofline.md", "w") as f:
        f.write(to_markdown(rows))
    md = inject(md, "ROOFLINE_TABLE", to_markdown(rows))
    md = inject(md, "COSIM_TABLE", cosim_table())
    md = inject(md, "PERF_LOG", perf_log())
    open(path, "w").write(md)
    print(f"EXPERIMENTS.md updated "
          f"({len(rows)} roofline rows).")


if __name__ == "__main__":
    main()
