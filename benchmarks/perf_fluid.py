"""``--perf`` harness: fluid hot-loop throughput -> ``BENCH_fluid.json``.

Measures the one-pass pipeline against the pre-PR scatter path on an
F/L scaling curve of single-device grid points:

  * steps/sec of the jitted decimating scan, per reduction engine
    (``scat`` = legacy scatter baseline, ``fused`` = sorted-incidence
    one-pass reduction with the dense-CSR tiles when load skew allows)
    plus the ``mega`` whole-step kernel (one launch per trace window,
    interpret mode on CPU)
  * compile seconds per engine (first call minus steady state)
  * incidence shape per point (F, L, K, H, rows = N = F*K*H,
    ``dense_rows`` = max per-link contributors)
  * ``ops_per_step`` — jaxpr equations per substep for the fused
    reference vs the megakernel block, and their ratio
    (``op_reduction``).  On CPU the megakernel runs in interpreter
    mode, so its *wall clock* does not show the launch fusion; the op
    count is the machine-independent form of "one launch instead of a
    few hundred ops per substep", and it is what the mega gate checks
    (``op_reduction`` must hold >= MEGA_OP_REDUCTION_FLOOR and not
    regress > TOLERANCE vs the committed baseline).

Every invocation appends a run record to ``BENCH_fluid.json`` at the
repo root — the perf trajectory the ROADMAP's "fast as the hardware
allows" goal is tracked by.  ``--quick`` shrinks the grid to CI size.

Regression gate (the CI ``perf-smoke`` job): ``check_regression``
compares the *speedup ratio* (fused vs scat measured in the same
process, same machine) of the latest run against the committed
baseline's matching points.  Absolute steps/sec vary wildly across CI
runners, so the machine-normalised ratio is the stable signal; the
job fails when a point's ratio falls below ``(1 - TOLERANCE)`` x its
baseline, with that floor capped at ``FLOOR_CAP`` so cross-runner
scatter/segment-sum lowering differences cannot flake the gate while
a genuine collapse of the fused pipeline still trips it.
"""

from __future__ import annotations

import json
import os
import time

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_fluid.json")

#: fail check_regression when a point's fused/scat speedup falls below
#: (1 - TOLERANCE) x the committed baseline's speedup for that point
TOLERANCE = 0.20

#: CI runners differ from the machine that recorded the baseline (CPU
#: model, XLA version), so a baseline-derived floor is capped here: the
#: gate catches a real collapse of the fused pipeline (back toward the
#: scatter path's throughput) without flaking on runner-to-runner
#: scatter/segment-sum lowering differences.
FLOOR_CAP = 2.0

#: the megakernel must fold at least this many jaxpr equations per
#: substep into its single launch (the acceptance bar is 5x; the
#: measured reduction is ~100x, so this is a collapse detector)
MEGA_OP_REDUCTION_FLOOR = 5.0

N_STEPS = 400
N_STEPS_QUICK = 200


def _grid(quick: bool):
    """(name, ScenarioSpec) F/L scaling curve, smallest first."""
    from repro.core import ScenarioSpec
    from repro.net import FabricSpec
    points = [
        ("clos64_f64",
         ScenarioSpec.permutation(64, seed=0, fabric=FabricSpec.clos3(4))),
        ("ft64_f1024",
         ScenarioSpec.permutation(1024, seed=0,
                                  fabric=FabricSpec.fat_tree(4, taper=1))),
    ]
    if not quick:
        points += [
            ("dfly272_f1024_k4",
             ScenarioSpec.permutation(
                 1024, seed=0, fabric=FabricSpec.dragonfly(4, 4, 4),
                 n_paths=4, route_seed=0)),
            ("dfly272_f4096",
             ScenarioSpec.permutation(
                 4096, seed=0, fabric=FabricSpec.dragonfly(4, 4, 4))),
        ]
    return points


def _bench_point(spec, n_steps: int, engine: str) -> dict:
    import jax
    from repro.core import PAPER_CONFIG
    from repro.core.fluid import init_state, make_step_fn
    from repro.core.simulator import decimating_scan, make_block_fn

    cfg = PAPER_CONFIG
    scn = spec.build(cfg)
    st0 = init_state(scn, cfg)
    k = 10
    if engine == "mega":
        block = make_block_fn(scn, cfg, k, interpret=True)
        fn = jax.jit(lambda st: decimating_scan(
            None, st, n_steps // k, k, cfg.sim.dt, block_fn=block))
    else:
        step = make_step_fn(scn, cfg, reduce=engine)
        fn = jax.jit(lambda st: decimating_scan(step, st, n_steps // k, k,
                                                cfg.sim.dt))
    t0 = time.perf_counter()
    jax.block_until_ready(fn(st0))
    compile_s = time.perf_counter() - t0
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(st0))
        best = min(best, time.perf_counter() - t0)
    return {"steps_per_s": round(n_steps / best, 1),
            "compile_s": round(compile_s - best, 2)}


def _ops_per_step(spec, k: int = 10) -> dict:
    """Jaxpr equations per substep: fused reference vs megakernel block.

    The fused step traces to a few hundred equations, each an XLA op
    (and on TPU, one or more kernel launches); the megakernel block is
    a single ``pallas_call`` equation covering ``k`` substeps.  The
    ratio is the machine-independent measure of the launch fusion —
    wall-clock on the CPU interpret path cannot show it.
    """
    import jax
    from repro.core import PAPER_CONFIG
    from repro.core.fluid import init_state, make_step_fn
    from repro.core.simulator import make_block_fn

    cfg = PAPER_CONFIG
    scn = spec.build(cfg)
    st0 = init_state(scn, cfg)
    step = make_step_fn(scn, cfg)
    ref_eqns = len(jax.make_jaxpr(step)(st0).eqns)
    block = make_block_fn(scn, cfg, k, interpret=True)
    blk_eqns = len(jax.make_jaxpr(block)(st0).eqns)
    return {"ref": ref_eqns, "mega_block": blk_eqns,
            "mega": round(blk_eqns / k, 2),
            "reduction": round(ref_eqns / (blk_eqns / k), 1)}


def run_perf(quick: bool = False) -> dict:
    """Execute the grid; returns the BENCH_fluid run record."""
    import jax
    from repro.core import PAPER_CONFIG
    from repro.core.fluid import dense_reduce_rows

    n_steps = N_STEPS_QUICK if quick else N_STEPS
    points = []
    for name, spec in _grid(quick):
        scn = spec.build(PAPER_CONFIG)
        F, H = scn.routes.shape
        K = 1 if scn.alt_routes is None else scn.alt_routes.shape[1]
        rec = {
            "name": name,
            "F": F, "H": H, "K": K,
            "L": int(scn.capacity.shape[0]),
            "rows": F * K * H,
            "dense_rows": dense_reduce_rows(scn),
            "steps": n_steps,
        }
        for engine in ("scat", "fused", "mega"):
            rec[engine] = _bench_point(spec, n_steps, engine)
        rec["speedup"] = round(
            rec["fused"]["steps_per_s"] / rec["scat"]["steps_per_s"], 2)
        # interpret-mode wall clock, recorded honestly (CPU pays the
        # interpreter; the launch fusion shows in ops_per_step)
        rec["mega_speedup"] = round(
            rec["mega"]["steps_per_s"] / rec["fused"]["steps_per_s"], 2)
        rec["ops_per_step"] = _ops_per_step(spec)
        points.append(rec)
        print(f"perf.{name}: scat={rec['scat']['steps_per_s']:.0f}/s "
              f"fused={rec['fused']['steps_per_s']:.0f}/s "
              f"speedup={rec['speedup']:.2f}x "
              f"mega={rec['mega']['steps_per_s']:.0f}/s "
              f"ops/step {rec['ops_per_step']['ref']}->"
              f"{rec['ops_per_step']['mega']:g} "
              f"({rec['ops_per_step']['reduction']:.0f}x fewer) "
              f"(F={F} L={rec['L']} K={K} dense_rows={rec['dense_rows']})")
    try:
        from ._env import bench_env
    except ImportError:              # `python benchmarks/perf_fluid.py`
        from _env import bench_env
    return {
        "unix_time": int(time.time()),
        # mega cells run the Pallas interpreter off-TPU (noted in their
        # sub-records); the scat/fused cells this record gates on are
        # compiled, so the top-level flag reflects those.
        **bench_env(interpret=False),
        "quick": quick,
        "points": points,
    }


def load_bench(path: str = BENCH_PATH) -> dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {"runs": []}


def append_bench_record(record: dict, path: str = BENCH_PATH) -> None:
    doc = load_bench(path)
    doc.setdefault("runs", []).append(record)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"appended perf record -> {path} ({len(doc['runs'])} runs)")


def check_regression(record: dict, baseline: dict | None = None,
                     tolerance: float = TOLERANCE) -> list[str]:
    """Failures when ``record``'s speedups regress vs the baseline run.

    ``baseline`` defaults to the *first* run in the committed
    BENCH_fluid.json (the frozen reference); points are matched by
    name, unmatched points are skipped (the quick grid is a subset).
    """
    if baseline is None:
        runs = load_bench().get("runs", [])
        if not runs:
            return ["no committed BENCH_fluid.json baseline"]
        baseline = runs[0]
    base = {p["name"]: p for p in baseline["points"]}
    fails = []
    for p in record["points"]:
        b = base.get(p["name"])
        if b is None:
            continue
        floor = min((1.0 - tolerance) * b["speedup"], FLOOR_CAP)
        if p["speedup"] < floor:
            fails.append(
                f"{p['name']}: fused/scat speedup {p['speedup']:.2f}x "
                f"< {floor:.2f}x (baseline {b['speedup']:.2f}x "
                f"- {tolerance:.0%}, capped at {FLOOR_CAP:.1f}x)")
        # megakernel gate: the per-substep op reduction (the launch
        # fusion, machine-independent) must hold the absolute floor
        # and stay within TOLERANCE of the committed baseline's
        ops = p.get("ops_per_step")
        if ops is None:
            continue
        mega_floor = MEGA_OP_REDUCTION_FLOOR
        if b.get("ops_per_step"):
            mega_floor = max(mega_floor, (1.0 - tolerance) *
                             b["ops_per_step"]["reduction"])
        if ops["reduction"] < mega_floor:
            fails.append(
                f"{p['name']}: megakernel op reduction "
                f"{ops['reduction']:.1f}x < {mega_floor:.1f}x "
                f"(ref {ops['ref']} eqns/step vs mega "
                f"{ops['mega']:g}; floor {MEGA_OP_REDUCTION_FLOOR:.0f}x"
                f" abs / baseline - {tolerance:.0%})")
    return fails


def main(quick: bool = False, check: bool = False) -> list[tuple]:
    """run.py section hook: bench, append, optionally gate."""
    record = run_perf(quick=quick)
    fails = check_regression(record) if check else []
    append_bench_record(record)
    rows = []
    for p in record["points"]:
        rows.append((f"perf_fluid.{p['name']}",
                     1e6 / p["fused"]["steps_per_s"],
                     f"fused={p['fused']['steps_per_s']:.0f}/s "
                     f"speedup={p['speedup']:.2f}x "
                     f"mega_ops {p['ops_per_step']['ref']}->"
                     f"{p['ops_per_step']['mega']:g}/step "
                     f"({p['ops_per_step']['reduction']:.0f}x)"))
    for f in fails:
        rows.append(("perf_fluid.REGRESSION", 0.0, f))
    return rows


if __name__ == "__main__":
    import sys
    rows = main(quick="--quick" in sys.argv, check="--check" in sys.argv)
    for row in rows:
        print(",".join(str(x) for x in row))
    if any("REGRESSION" in r[0] for r in rows):
        raise SystemExit(1)
