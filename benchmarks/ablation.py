"""Beyond-paper ablation: which of DCQCN-Rev's mechanisms buys what?

Cross the marking stage {CP, ECP} with the reaction stage {RP, ERP}
(notification follows reaction: NP with RP, ENP with ERP) on the paper's
equal-work scenario (roll=0).  (CP,RP) = DCQCN; (ECP,ERP) = DCQCN-Rev.
The 4 mechanism combinations are one Sweep — the marking/reaction
selectors are traced data, so the grid shares a single compiled step.
"""

from __future__ import annotations

from repro.core import CCConfig, CCScheme, ScenarioSpec, Sweep

COMBOS = [("cp", "rp"), ("ecp", "rp"), ("cp", "erp"), ("ecp", "erp")]


def run_ablation(n_steps: int = 18000) -> list[dict]:
    spec = ScenarioSpec.paper_incast_volume(roll=0)
    sweep = Sweep([
        (f"{m}+{r}",
         CCConfig(scheme=CCScheme.DCQCN, marking=m, reaction=r), spec)
        for m, r in COMBOS])
    results = sweep.run(n_steps=n_steps)
    out = []
    for marking, reaction in COMBOS:
        res = results[f"{marking}+{reaction}"]
        thr = res.mean_throughput_while_active() / 1e9
        out.append({
            "marking": marking.upper(),
            "reaction": reaction.upper(),
            "completion_ms": res.completion_time() * 1e3,
            "victim_gbps": float(thr[4]),
            "victim_marks": int(res.marked[:, 4].sum()),
            "aggregate_gbps": float(thr.sum()),
        })
    return out


def main() -> list[tuple]:
    rows = []
    for r in run_ablation():
        name = f"ablation.{r['marking']}+{r['reaction']}"
        rows.append((name, r["completion_ms"] * 1e3,
                     f"done={r['completion_ms']:.2f}ms "
                     f"victim={r['victim_gbps']:.2f}GB/s "
                     f"vmarks={r['victim_marks']} "
                     f"agg={r['aggregate_gbps']:.2f}GB/s"))
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(str(x) for x in row))
