"""Beyond-paper ablation: which of DCQCN-Rev's mechanisms buys what?

Cross the **registered** marking stages with the registered reaction
stages (notification follows reaction like the legacy schemes: NP with
RP, ENP otherwise) on the paper's equal-work scenario (roll=0).
(cp, rp) = DCQCN; (ecp, erp) = DCQCN-Rev; everything else — including
any stage registered after this file was written — appears in the grid
automatically, because the combos are enumerated from
``repro.core.cc.MARKING`` / ``REACTION`` rather than hardcoded.  All
combinations ride one Sweep — the stage selectors are traced data, so
the grid shares a single compiled step.
"""

from __future__ import annotations

from repro.core import CCSpec, ScenarioSpec, Sweep, cc


def combos() -> list[tuple[str, str]]:
    """(marking, reaction) grid from the registry.

    pfc is the no-CC baseline, not an injection-throttling mechanism —
    excluded.  Mark-free reactions (``consumes_marks=False``, e.g. the
    swift delay-target stage) make the marking axis dead, so they get
    ONE row instead of a redundant cross with every marking."""
    out = []
    for stage in cc.REACTION.stages():
        if stage.name == "pfc":
            continue
        markings = cc.MARKING.names() if stage.consumes_marks \
            else cc.MARKING.names()[:1]
        out += [(m, stage.name) for m in markings]
    return out


def _spec_for(marking: str, reaction: str) -> CCSpec:
    return CCSpec(marking=marking, reaction=reaction,
                  notification="np" if reaction == "rp" else "enp")


def run_ablation(n_steps: int = 18000) -> list[dict]:
    spec = ScenarioSpec.paper_incast_volume(roll=0)
    sweep = Sweep([(f"{m}+{r}", _spec_for(m, r), spec)
                   for m, r in combos()])
    results = sweep.run(n_steps=n_steps)
    out = []
    for marking, reaction in combos():
        res = results[f"{marking}+{reaction}"]
        thr = res.mean_throughput_while_active() / 1e9
        out.append({
            "marking": marking.upper(),
            "reaction": reaction.upper(),
            "completion_ms": res.completion_time() * 1e3,
            "victim_gbps": float(thr[4]),
            "victim_marks": int(res.marked[:, 4].sum()),
            "aggregate_gbps": float(thr.sum()),
        })
    return out


def main() -> list[tuple]:
    rows = []
    for r in run_ablation():
        name = f"ablation.{r['marking']}+{r['reaction']}"
        rows.append((name, r["completion_ms"] * 1e3,
                     f"done={r['completion_ms']:.2f}ms "
                     f"victim={r['victim_gbps']:.2f}GB/s "
                     f"vmarks={r['victim_marks']} "
                     f"agg={r['aggregate_gbps']:.2f}GB/s"))
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(str(x) for x in row))
