"""Beyond-paper ablation: which of DCQCN-Rev's mechanisms buys what?

Cross the marking stage {CP, ECP} with the reaction stage {RP, ERP}
(notification follows reaction: NP with RP, ENP with ERP) on the paper's
equal-work scenario (roll=0).  (CP,RP) = DCQCN; (ECP,ERP) = DCQCN-Rev.
"""

from __future__ import annotations

import numpy as np

from repro.core import CCConfig, CCScheme, paper_incast_volume, run

COMBOS = [("cp", "rp"), ("ecp", "rp"), ("cp", "erp"), ("ecp", "erp")]


def run_ablation() -> list[dict]:
    out = []
    for marking, reaction in COMBOS:
        cfg = CCConfig(scheme=CCScheme.DCQCN, marking=marking,
                       reaction=reaction)
        scn = paper_incast_volume(cfg, roll=0)
        res = run(scn, cfg, n_steps=18000)
        thr = res.mean_throughput_while_active() / 1e9
        out.append({
            "marking": marking.upper(),
            "reaction": reaction.upper(),
            "completion_ms": res.completion_time() * 1e3,
            "victim_gbps": float(thr[4]),
            "victim_marks": int(res.marked[:, 4].sum()),
            "aggregate_gbps": float(thr.sum()),
        })
    return out


def main() -> list[tuple]:
    rows = []
    for r in run_ablation():
        name = f"ablation.{r['marking']}+{r['reaction']}"
        rows.append((name, r["completion_ms"] * 1e3,
                     f"done={r['completion_ms']:.2f}ms "
                     f"victim={r['victim_gbps']:.2f}GB/s "
                     f"vmarks={r['victim_marks']} "
                     f"agg={r['aggregate_gbps']:.2f}GB/s"))
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(str(x) for x in row))
