"""``--serve`` harness: what-if query engine -> ``BENCH_serve.json``.

Replays a fixed mixed-tenant query stream (several CC stacks x several
workloads on one pod, all in one flow bucket) through ``CCQueryEngine``
and records the serving metrics:

  * latency p50 / p99 and mean micro-batch occupancy
  * executable-cache hits / misses / hit rate and the compile vs run
    wall split (the replay must compile exactly ONCE)
  * admission outcomes of a deterministic over-rate burst probe
    (fake clock: the token bucket must throttle, never queue unboundedly)

Every invocation appends a run record to ``BENCH_serve.json`` at the
repo root.  ``--quick`` shrinks the replay to CI size.

Regression gate (the CI ``serve-smoke`` job): ``check_regression``
fails on a *hit-rate collapse* (more executable builds than structural
signatures — the compile-once contract broken, e.g. a shape leaked
into the cache key) and on a p99 latency regression beyond
``(1 + TOLERANCE) x`` the committed baseline's p99, with the threshold
floored at ``ABS_FLOOR_S`` so runner-speed differences cannot flake
the gate while a recompile storm (p99 jumping by whole compile times)
still trips it.
"""

from __future__ import annotations

import json
import os
import time

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serve.json")

#: fail check_regression when p99 exceeds (1 + TOLERANCE) x baseline p99
TOLERANCE = 0.20

#: the p99 threshold never drops below this many seconds: CI runners are
#: slower than the baseline machine, and a warm micro-batch is far under
#: this; only a recompile storm (every batch paying ~seconds of XLA
#: compilation) or a genuine serving collapse can cross it.
ABS_FLOOR_S = 15.0

N_QUERIES = 96
N_QUERIES_QUICK = 48
N_STEPS = 400
N_STEPS_QUICK = 240
DRAIN_EVERY = 24          # queries per drain wave (a service's cadence)


def _mix():
    """(label, cfg, spec) combos: 4 CC stacks x 3 workloads, one flow
    bucket (8) on the default pod."""
    import dataclasses
    from repro.core import CCSpec, ScenarioSpec
    cfgs = {
        "rev": CCSpec(),
        "dcqcn": CCSpec(marking="cp", notification="np", reaction="rp"),
        "swift": CCSpec(reaction="swift"),
        "rev-tuned": CCSpec().replace(rev=dataclasses.replace(
            CCSpec().rev, erp_settle=0.9)),
    }
    specs = {"in4": ScenarioSpec.incast(4), "in6": ScenarioSpec.incast(6),
             "in7": ScenarioSpec.incast(7)}
    return [(f"{cn}/{sn}", cfg, spec)
            for cn, cfg in cfgs.items() for sn, spec in specs.items()]


def run_replay(quick: bool = False) -> dict:
    """The replay: returns the BENCH_serve run record."""
    from repro.serve.whatif import (AdmissionConfig, Admitted,
                                    CCQueryEngine, EngineConfig,
                                    Throttled, WhatIfQuery)

    n_queries = N_QUERIES_QUICK if quick else N_QUERIES
    n_steps = N_STEPS_QUICK if quick else N_STEPS
    mix = _mix()
    eng = CCQueryEngine(EngineConfig(
        max_batch=8, admission=AdmissionConfig(rate=1e9, burst=10_000,
                                               max_queue=256)))
    t0 = time.perf_counter()
    for i in range(n_queries):
        label, cfg, spec = mix[i % len(mix)]
        out = eng.submit(WhatIfQuery(cfg=cfg, scenario=spec,
                                     n_steps=n_steps, label=label,
                                     tenant=f"t{i % 4}"))
        assert isinstance(out, Admitted), out
        if (i + 1) % DRAIN_EVERY == 0:
            eng.drain()
    eng.drain()
    wall = time.perf_counter() - t0
    m = eng.metrics()

    # deterministic over-rate burst probe (fake clock, no jit)
    clk = [0.0]
    probe = CCQueryEngine(EngineConfig(admission=AdmissionConfig(
        rate=10.0, burst=4, max_queue=8)), clock=lambda: clk[0])
    burst = [probe.submit(WhatIfQuery(cfg=mix[0][1], scenario=mix[0][2],
                                      n_steps=n_steps))
             for _ in range(16)]
    throttle = {
        "submitted": len(burst),
        "admitted": sum(isinstance(o, Admitted) for o in burst),
        "throttled": sum(isinstance(o, Throttled) for o in burst),
        "queue_full": probe.metrics()["admission"]["queue_full"],
    }

    print(f"serve: {n_queries} queries in {wall:.1f}s "
          f"(p50={m['latency_s']['p50']:.2f}s "
          f"p99={m['latency_s']['p99']:.2f}s "
          f"occupancy={m['mean_occupancy']:.2f} "
          f"cache {m['exec_cache']['hits']}h/"
          f"{m['exec_cache']['misses']}m "
          f"compile={m['compile_s']:.1f}s run={m['run_s']:.1f}s); "
          f"burst probe: {throttle['throttled']} throttled")
    try:
        from ._env import bench_env
    except ImportError:              # `python benchmarks/serve_bench.py`
        from _env import bench_env
    return {
        "unix_time": int(time.time()),
        **bench_env(interpret=False),
        "quick": quick,
        "n_queries": n_queries,
        "n_steps": n_steps,
        "wall_s": round(wall, 2),
        "metrics": m,
        "throttle_probe": throttle,
    }


def load_bench(path: str = BENCH_PATH) -> dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {"runs": []}


def append_bench_record(record: dict, path: str = BENCH_PATH) -> None:
    doc = load_bench(path)
    doc.setdefault("runs", []).append(record)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"appended serve record -> {path} ({len(doc['runs'])} runs)")


def check_regression(record: dict, baseline: dict | None = None,
                     tolerance: float = TOLERANCE) -> list[str]:
    """Failures when ``record`` breaks the serving contracts.

    ``baseline`` defaults to the *first* run in the committed
    BENCH_serve.json (the frozen reference).
    """
    fails = []
    m = record["metrics"]

    # compile-once / hit-rate collapse: one executable build per
    # structural signature, machine-independent and deterministic
    if m["exec_cache"]["misses"] > m["signatures"]:
        fails.append(
            f"hit-rate collapse: {m['exec_cache']['misses']} executable "
            f"builds for {m['signatures']} structural signature(s) — "
            f"a shape or content leaked into the cache key")
    if m["exec_cache"]["hit_rate"] < 0.5:
        fails.append(f"cache hit rate {m['exec_cache']['hit_rate']:.2f} "
                     f"< 0.50 across the replay")

    # explicit back-pressure: the burst probe must throttle
    probe = record["throttle_probe"]
    if probe["throttled"] == 0:
        fails.append("over-rate burst was never throttled — token "
                     "bucket not enforcing the admission rate")
    if probe["admitted"] + probe["throttled"] + probe["queue_full"] \
            != probe["submitted"]:
        fails.append("burst outcomes don't partition submissions — a "
                     "query was silently dropped or double-counted")

    # p99 latency vs the committed baseline (floored, see ABS_FLOOR_S)
    if baseline is None:
        runs = load_bench().get("runs", [])
        baseline = runs[0] if runs else None
    if baseline is None:
        fails.append("no committed BENCH_serve.json baseline")
        return fails
    base_p99 = baseline["metrics"]["latency_s"]["p99"]
    ceil = max((1.0 + tolerance) * base_p99, ABS_FLOOR_S)
    p99 = m["latency_s"]["p99"]
    if p99 > ceil:
        fails.append(
            f"p99 latency {p99:.2f}s > {ceil:.2f}s (baseline "
            f"{base_p99:.2f}s + {tolerance:.0%}, floored at "
            f"{ABS_FLOOR_S:.0f}s)")
    return fails


def main(quick: bool = False, check: bool = False) -> list[tuple]:
    """run.py section hook: replay, append, optionally gate."""
    record = run_replay(quick=quick)
    fails = check_regression(record) if check else []
    append_bench_record(record)
    m = record["metrics"]
    rows = [
        ("serve.p50_latency", m["latency_s"]["p50"] * 1e6,
         f"{m['latency_s']['p50']:.3f}s"),
        ("serve.p99_latency", m["latency_s"]["p99"] * 1e6,
         f"{m['latency_s']['p99']:.3f}s"),
        ("serve.occupancy", 0.0, f"{m['mean_occupancy']:.2f}"),
        ("serve.cache", 0.0,
         f"{m['exec_cache']['hits']}h/{m['exec_cache']['misses']}m "
         f"hit_rate={m['exec_cache']['hit_rate']:.2f}"),
        ("serve.compile_vs_run", 0.0,
         f"compile={m['compile_s']:.1f}s run={m['run_s']:.1f}s"),
        ("serve.throttled", 0.0,
         str(record["throttle_probe"]["throttled"])),
    ]
    for f in fails:
        rows.append(("serve.REGRESSION", 0.0, f))
    return rows


if __name__ == "__main__":
    import sys
    rows = main(quick="--quick" in sys.argv, check="--check" in sys.argv)
    for row in rows:
        print(",".join(str(x) for x in row))
    if any("REGRESSION" in r[0] for r in rows):
        raise SystemExit(1)
