"""``--tune`` harness: autotuning run -> ``BENCH_tune.json``.

Tunes paper-default DCQCN on one CLOS incast three ways and records
the results:

  * ``grad`` — :class:`repro.tune.GradTuner` (jax.grad through the
    temperature-smoothed dt-scan), the PR's headline path.  Its
    hard-model improvement over the paper defaults is the regression
    gate.
  * ``es`` — a short antithetic-ES run on the exact hard model (the
    no-smoothing cross-check; its populations ride ``Sweep.run``).
  * ``pareto`` — a goodput vs p99-slowdown scalarisation sweep
    (``pareto_autotune``); the non-dominated set is the record's
    trade-off curve entry.

Every invocation appends a run record to ``BENCH_tune.json`` at the
repo root.  ``--quick`` shrinks iteration counts to CI size (the
committed baseline is a quick record, so the CI gate compares
like-for-like).

Regression gate (the CI ``tune-smoke`` job): ``check_regression``
fails when the gradient tuner no longer beats the paper defaults on
the *hard* model, when its improvement margin drops below
``(1 - TOLERANCE) x`` the committed baseline's margin (the demand is
capped at ``MIN_MARGIN`` so cross-runner optimisation variance cannot
flake the gate — a broken tuner lands at ~0, a working one at ~0.1),
or when the Pareto front comes back empty.
"""

from __future__ import annotations

import json
import os
import time

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_tune.json")

#: fail check_regression when the grad tuner's hard-model improvement
#: falls below (1 - TOLERANCE) x the committed baseline's margin
TOLERANCE = 0.20

#: ... but never demand more than this absolute margin — the gate must
#: catch "tuner broken" (margin ~0), not flake on cross-runner
#: optimisation variance (objective is a weighted scalarisation of
#: O(1) terms; 0.01 is far above f32 noise and far below the ~0.1 a
#: working tuner finds on this incast)
MIN_MARGIN = 0.01

N_STEPS = 3000
SCENARIO = "incast8"

GRAD_KW = dict(iters=12, lr=0.25, temperature=0.2)
GRAD_KW_QUICK = dict(iters=8, lr=0.25, temperature=0.2)
ES_KW = dict(iters=4, pop=8, sigma=0.3, lr=0.4)
ES_KW_QUICK = dict(iters=2, pop=4, sigma=0.3, lr=0.4)
PARETO_WEIGHTS = 3
PARETO_WEIGHTS_QUICK = 2


def _problem():
    from repro.core import CCScheme, PAPER_CONFIG, ScenarioSpec
    cfg = PAPER_CONFIG.replace(scheme=CCScheme.DCQCN)
    return cfg, ScenarioSpec.incast(8)


def run_tune(quick: bool = False) -> dict:
    """The tuning runs: returns the BENCH_tune run record."""
    from repro.tune import autotune, pareto_autotune

    cfg, scn = _problem()
    grad_kw = GRAD_KW_QUICK if quick else GRAD_KW
    es_kw = ES_KW_QUICK if quick else ES_KW
    n_weights = PARETO_WEIGHTS_QUICK if quick else PARETO_WEIGHTS

    t0 = time.perf_counter()
    grad = autotune(cfg, scn, method="grad", n_steps=N_STEPS,
                    seed=0, **grad_kw)
    es = autotune(cfg, scn, method="es", n_steps=N_STEPS,
                  seed=0, **es_kw)
    pareto = pareto_autotune(cfg, scn, axes=("goodput", "p99_slowdown"),
                             n_weights=n_weights, method="grad",
                             n_steps=N_STEPS, seed=0,
                             **dict(grad_kw, iters=max(
                                 grad_kw["iters"] // 2, 4)))
    wall = time.perf_counter() - t0

    front = [{k: f[k] for k in ("weights", "params", "axis_values")}
             for f in pareto["front"]]
    print(f"tune: grad {grad.baseline_value:+.4f} -> "
          f"{grad.best_value:+.4f} (margin {grad.improvement:+.4f}), "
          f"es margin {es.improvement:+.4f}, "
          f"pareto front {len(front)} point(s), {wall:.1f}s")
    try:
        from ._env import bench_env
    except ImportError:              # `python benchmarks/tune_bench.py`
        from _env import bench_env
    return {
        "unix_time": int(time.time()),
        **bench_env(interpret=False),
        "quick": quick,
        "scenario": SCENARIO,
        "n_steps": N_STEPS,
        "wall_s": round(wall, 2),
        "grad": grad.to_record(),
        "es": es.to_record(),
        "pareto": {"axes": pareto["axes"], "front": front},
    }


def load_bench(path: str = BENCH_PATH) -> dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {"runs": []}


def append_bench_record(record: dict, path: str = BENCH_PATH) -> None:
    doc = load_bench(path)
    doc.setdefault("runs", []).append(record)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"appended tune record -> {path} ({len(doc['runs'])} runs)")


def check_regression(record: dict, baseline: dict | None = None,
                     tolerance: float = TOLERANCE) -> list[str]:
    """Failures when ``record`` breaks the autotuning contracts.

    ``baseline`` defaults to the *first* run in the committed
    BENCH_tune.json (the frozen reference).
    """
    fails = []
    g = record["grad"]
    if not g["improved"]:
        fails.append(
            f"grad tuner no longer beats paper-default DCQCN on the "
            f"hard model (baseline {g['baseline_value']:+.4f}, best "
            f"{g['best_value']:+.4f})")
    if not record["pareto"]["front"]:
        fails.append("pareto_autotune returned an empty front")

    if baseline is None:
        runs = load_bench().get("runs", [])
        baseline = runs[0] if runs else None
    if baseline is None:
        fails.append("no committed BENCH_tune.json baseline")
        return fails
    floor = min((1.0 - tolerance) * baseline["grad"]["improvement"],
                MIN_MARGIN)
    floor = max(floor, 0.0)
    if g["improvement"] < floor:
        fails.append(
            f"grad improvement {g['improvement']:+.4f} < {floor:+.4f} "
            f"(baseline margin {baseline['grad']['improvement']:+.4f} "
            f"- {tolerance:.0%}, demand capped at {MIN_MARGIN})")
    return fails


def main(quick: bool = False, check: bool = False) -> list[tuple]:
    """run.py section hook: tune, append, optionally gate."""
    record = run_tune(quick=quick)
    fails = check_regression(record) if check else []
    append_bench_record(record)
    rows = [
        ("tune.grad_margin", 0.0,
         f"{record['grad']['improvement']:+.4f}"),
        ("tune.grad_goodput", 0.0,
         f"{record['grad']['baseline_metrics']['goodput']:.3f}->"
         f"{record['grad']['best_metrics']['goodput']:.3f}"),
        ("tune.grad_p99", 0.0,
         f"{record['grad']['baseline_metrics']['p99_slowdown']:.1f}->"
         f"{record['grad']['best_metrics']['p99_slowdown']:.1f}"),
        ("tune.es_margin", 0.0,
         f"{record['es']['improvement']:+.4f}"),
        ("tune.front_size", 0.0,
         str(len(record["pareto"]["front"]))),
    ]
    for f in fails:
        rows.append(("tune.REGRESSION", 0.0, f))
    return rows


if __name__ == "__main__":
    import sys
    rows = main(quick="--quick" in sys.argv, check="--check" in sys.argv)
    for row in rows:
        print(",".join(str(x) for x in row))
    if any("REGRESSION" in r[0] for r in rows):
        raise SystemExit(1)
