"""Benchmark entrypoint — one section per paper table/figure + the
beyond-paper harnesses.  Prints ``name,us_per_call,derived`` CSV.

  fig2.*       paper Fig. 2 (aggregate throughput, completion times)
  fig3.*       paper Fig. 3 (per-flow bandwidth)
  cc_scale.*   DC-scale reaction-point + fluid stepping throughput
  net_scale.*  repro.net fabric-family scaling matrix (also ``--scale``)
  roofline.*   §Roofline terms per (arch x shape) from dry-run artifacts
  cosim.*      collective traffic x CC scheme co-simulation
  train.*      tiny end-to-end training-step wall time (CPU)

``--smoke`` runs one tiny end-to-end Sweep (scheme x scenario grid,
single jitted launch) and exits non-zero on failure — the CI hook.
``--scale`` runs only the fabric matrix and appends a record to
``BENCH_net.json`` (``--quick`` shrinks it to CI size).
``--perf`` runs the fluid hot-loop F/L scaling curve (legacy scatter
path vs fused one-pass reduction vs the whole-step megakernel) and
appends a record to ``BENCH_fluid.json``; with ``--check`` it exits
non-zero when the fused/scat speedup falls below 80% of the committed
baseline's (floor capped at 2.0x for cross-runner noise) or when the
megakernel's per-substep op reduction drops below 5x / regresses >20%
vs baseline (the launch-fusion gate; CPU wall clock runs the
interpreter, so the jaxpr op count is the machine-stable metric) —
the CI perf-smoke gate.
``--serve`` replays the mixed what-if query stream through
``CCQueryEngine`` and appends a record to ``BENCH_serve.json``; with
``--check`` it exits non-zero on a p99 latency regression vs the
committed baseline, a compiled-executable hit-rate collapse, or a
token bucket that fails to throttle an over-rate burst (the CI
serve-smoke gate).
``--tune`` runs the autotuning harness (GradTuner + ESTuner + a
Pareto scalarisation sweep on paper-default DCQCN, one CLOS incast)
and appends a record to ``BENCH_tune.json``; with ``--check`` it exits
non-zero when the tuned config no longer beats the paper defaults on
the hard model, the improvement margin regresses past the committed
baseline's, or the Pareto front is empty (the CI tune-smoke gate).
``--fleet`` runs the same ragged grid as a single ``Sweep.run()``
launch and as a threaded work-stealing fleet (streaming + journal) and
appends a record to ``BENCH_fleet.json``; with ``--check`` it exits
non-zero when the merged fleet result is not bitwise the single
launch, the envelope plan compiled more than once, any shard was
Abandoned, or the scheduling overhead regresses past the committed
baseline (the CI fleet-smoke gate).
``--cc-matrix`` enumerates the ``repro.core.cc`` stage registries
(every marking x notification x reaction combination) as ONE Sweep
launch, appends the rows to ``BENCH_fluid.json`` under ``cc_matrix``
and exits non-zero if the matrix needed more than one compile — then
repeats the matrix through the megakernel (``use_kernels="mega"``),
where the same one-build assertion must hold on the single
pallas_call.
"""

from __future__ import annotations

import argparse
import time


def _section(name: str, fn):
    t0 = time.perf_counter()
    try:
        rows = fn()
    except Exception as e:   # noqa: BLE001 — a bench must not kill the run
        rows = [(f"{name}.ERROR", 0.0, repr(e)[:120])]
    dt = time.perf_counter() - t0
    rows.append((f"{name}.section_wall_s", dt * 1e6, f"{dt:.1f}s"))
    return rows


def bench_train_step() -> list[tuple]:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import transformer
    from repro.models.layers import init_params
    from repro.train.step import (StepConfig, init_train_state,
                                  make_train_step)
    from repro.data import DataConfig, SyntheticLM

    out = []
    for arch in ("qwen2.5-32b", "mixtral-8x22b", "falcon-mamba-7b"):
        cfg = get_smoke_config(arch)
        params = init_params(transformer.param_defs(cfg), 0, jnp.float32)
        sc = StepConfig()
        state = init_train_state(cfg, params, sc)
        step = jax.jit(make_train_step(cfg, sc))
        ds = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                    global_batch=4))
        b = ds.batch_at(0)
        state, m = step(state, b)          # compile
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for i in range(5):
            state, m = step(state, ds.batch_at(i + 1))
        jax.block_until_ready(m["loss"])
        us = (time.perf_counter() - t0) / 5 * 1e6
        out.append((f"train.smoke.{arch}", us,
                    f"loss={float(m['loss']):.3f}"))
    return out


def smoke() -> int:
    """Tiny sweep, end to end: scheme x scenario grid in one launch.

    Checks the load-bearing invariants cheaply (sub-minute on CPU):
    the sweep runs as one jitted call, per-point views slice cleanly,
    and DCQCN-Rev's fair-share behaviour shows up on the small incast.
    """
    from repro.core import CCScheme, PAPER_CONFIG, ScenarioSpec, Sweep

    cfg = PAPER_CONFIG
    t0 = time.perf_counter()
    sweep = Sweep.grid(
        configs={s.name: cfg.replace(scheme=s)
                 for s in (CCScheme.DCQCN, CCScheme.DCQCN_REV)},
        scenarios={"hol": ScenarioSpec.paper_incast(roll=0),
                   "incast2": ScenarioSpec.incast(2, victim=False)})
    res = sweep.run(n_steps=4000)
    wall = time.perf_counter() - t0
    summary = res.summary()
    for name, row in summary.items():
        print(f"smoke.{name}: agg={row['aggregate_gbps']:.2f}GB/s "
              f"peak_q={row['peak_queue_kb']:.0f}KB")
    rev = res["DCQCN_REV/hol"].mean_throughput_while_active()
    dcq = res["DCQCN/hol"].mean_throughput_while_active()
    ok = (len(summary) == 4
          and rev[4] > dcq[4]              # Rev protects the victim
          and rev.sum() > dcq.sum())       # ... and total throughput
    print(f"smoke: 4-point sweep in {wall:.1f}s -> "
          f"{'OK' if ok else 'FAILED'}")
    return 0 if ok else 1


def _print_rows(all_rows) -> None:
    print("name,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.2f},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny end-to-end sweep (CI tier-1 hook)")
    ap.add_argument("--scale", action="store_true",
                    help="fabric-family scaling matrix -> BENCH_net.json")
    ap.add_argument("--perf", action="store_true",
                    help="fluid hot-loop scaling curve -> BENCH_fluid.json")
    ap.add_argument("--check", action="store_true",
                    help="with --perf: fail when fused/scat speedup "
                         "drops below 80%% of the committed "
                         "BENCH_fluid.json baseline (floor capped at "
                         "2.0x for cross-runner noise) or the "
                         "megakernel op reduction below 5x/-20%%")
    ap.add_argument("--serve", action="store_true",
                    help="what-if query engine replay -> BENCH_serve.json "
                         "(--check gates on p99 regression, hit-rate "
                         "collapse and throttling)")
    ap.add_argument("--tune", action="store_true",
                    help="CC autotuning harness -> BENCH_tune.json "
                         "(--check gates on the tuned-beats-default "
                         "margin and a non-empty Pareto front)")
    ap.add_argument("--fleet", action="store_true",
                    help="work-stealing fleet vs single-launch sweep "
                         "-> BENCH_fleet.json (--check gates on "
                         "bitwise fidelity, one compile per signature, "
                         "zero Abandoned shards and the scheduling-"
                         "overhead regression)")
    ap.add_argument("--cc-matrix", action="store_true", dest="cc_matrix",
                    help="stage-registry combination sweep (marking x "
                         "notification x reaction, one jit) -> "
                         "BENCH_fluid.json")
    ap.add_argument("--quick", action="store_true",
                    help="with --scale/--perf/--cc-matrix/--serve/"
                         "--tune/--fleet: CI-sized run")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke())

    if __package__:
        from . import (ablation, cc_matrix, cc_scale, cosim,
                       fig2_throughput, fig3_perflow, fleet_bench,
                       net_scale, perf_fluid, roofline, serve_bench,
                       tune_bench)
    else:                    # `python benchmarks/run.py` (no package ctx)
        import ablation, cc_matrix, cc_scale, cosim        # noqa: E401
        import fig2_throughput, fig3_perflow, fleet_bench  # noqa: E401
        import net_scale, perf_fluid, roofline             # noqa: E401
        import serve_bench, tune_bench                     # noqa: E401

    if args.tune:
        rows = _section("tune",
                        lambda: tune_bench.main(quick=args.quick,
                                                check=args.check))
        _print_rows(rows)
        if any(".ERROR" in r[0] or "REGRESSION" in r[0] for r in rows):
            raise SystemExit(1)
        return

    if args.serve:
        rows = _section("serve",
                        lambda: serve_bench.main(quick=args.quick,
                                                 check=args.check))
        _print_rows(rows)
        if any(".ERROR" in r[0] or "REGRESSION" in r[0] for r in rows):
            raise SystemExit(1)
        return

    if args.fleet:
        rows = _section("fleet",
                        lambda: fleet_bench.main(quick=args.quick,
                                                 check=args.check))
        _print_rows(rows)
        if any(".ERROR" in r[0] or "REGRESSION" in r[0] for r in rows):
            raise SystemExit(1)
        return

    if args.cc_matrix:
        rows = _section("cc_matrix",
                        lambda: cc_matrix.main(quick=args.quick))
        _print_rows(rows)
        if any(".ERROR" in r[0] or "RECOMPILE" in r[0] for r in rows):
            raise SystemExit(1)
        return

    if args.scale:
        rows = _section("net_scale",
                        lambda: net_scale.main(quick=args.quick))
        _print_rows(rows)
        if any(".ERROR" in r[0] for r in rows):
            raise SystemExit(1)
        return

    if args.perf:
        rows = _section("perf_fluid",
                        lambda: perf_fluid.main(quick=args.quick,
                                                check=args.check))
        _print_rows(rows)
        if any(".ERROR" in r[0] or "REGRESSION" in r[0] for r in rows):
            raise SystemExit(1)
        return

    all_rows = []
    all_rows += _section("fig2", fig2_throughput.main)
    all_rows += _section("fig3", fig3_perflow.main)
    all_rows += _section("ablation", ablation.main)
    all_rows += _section("cc_matrix", lambda: cc_matrix.main(quick=True))
    all_rows += _section("cc_scale", cc_scale.main)
    all_rows += _section("net_scale", net_scale.main)
    all_rows += _section("perf_fluid", lambda: perf_fluid.main(quick=True))
    all_rows += _section("roofline", roofline.main)
    all_rows += _section("cosim", cosim.main)
    all_rows += _section("train", bench_train_step)
    _print_rows(all_rows)


if __name__ == "__main__":
    main()
