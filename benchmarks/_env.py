"""Execution-environment honesty for every BENCH_*.json record.

Numbers from a CPU interpreter and numbers from a TPU are different
experiments; a bench record that omits the platform invites comparing
them.  Every bench merges :func:`bench_env` into its record so the
backend, device kind and interpret-mode flag ride with the data.
"""

from __future__ import annotations


def bench_env(interpret: bool = False) -> dict:
    """Backend/platform facts for a bench record (cheap, no device
    work beyond enumerating what jax already initialised)."""
    import jax

    devs = jax.devices()
    return {
        "backend": jax.default_backend(),
        "platform": devs[0].platform if devs else "none",
        "device_kind": devs[0].device_kind if devs else "none",
        "n_devices": len(devs),
        "jax_version": jax.__version__,
        "interpret": bool(interpret),
    }
