"""`--scale`: fabric-family scaling matrix for the repro.net subsystem.

For each (fabric, workload) cell: time the route-table build (+ its
validity check, both cached per fabric afterwards), then run a 3-scheme
Sweep as one jitted launch and report wall time and simulated
steps/second.  Every invocation appends a record to ``BENCH_net.json``
at the repo root so the perf trajectory accumulates across commits.

The routing matrix rides along: the adversarial group-shift dragonfly
cell sweeps {min, valiant, ugal} x all schemes in ONE launch and
records delivered bytes per (scheme, routing) — the record asserts the
paper-level ordering ``ugal >= min`` on that pattern.

So does the PFC-pathology leg: the HoL-victim scenario runs the three
paper schemes and records ``victim_slowdown`` / ``pause_s`` per scheme.
The run fails unless Rev spares the victim better than DCQCN, which
beats PFC-only (the paper's ordering), and — when the committed
``BENCH_net.json`` already carries a ``pfc_pathology`` record — unless
the Rev-vs-DCQCN margin stays within half of that baseline (the CI
``pfc-pathology`` job's gate).

    PYTHONPATH=src python benchmarks/run.py --scale            # full
    PYTHONPATH=src python benchmarks/run.py --scale --quick    # CI-sized
"""

from __future__ import annotations

import json
import os
import time

import numpy as np


BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_net.json")


def _matrix(quick: bool):
    from repro.core.workloads import all_to_all, incast_storm
    from repro.net import FabricSpec

    def storm(n):
        return incast_storm(max(4, n // 4), max(1, n // 16), n,
                            volume=1e6, t_start=0.0)

    def a2a(n):
        return all_to_all(n, 0.5e6, phases=4, nodes=range(min(n, 16)))

    cells = [
        ("clos64", FabricSpec.clos3(4), storm),
        ("ft64_2to1", FabricSpec.fat_tree(4, taper=2), storm),
        ("dfly72", FabricSpec.dragonfly(a=4, p=2, h=2), a2a),
    ]
    if not quick:
        cells += [
            ("clos512", FabricSpec.clos3(8), storm),
            ("xgft4lvl", FabricSpec.xgft((4, 2, 2, 2), (1, 2, 2, 2)),
             a2a),
            ("ft216_3to1", FabricSpec.xgft((6, 6, 6), (1, 2, 6)), storm),
            ("dfly342", FabricSpec.dragonfly(a=6, p=3, h=3), a2a),
        ]
    return cells


def run_matrix(quick: bool = False, n_steps: int = 600) -> list[dict]:
    from repro.core import CCScheme, PAPER_CONFIG, Sweep
    from repro.net import validate_table

    cfg = PAPER_CONFIG
    records = []
    for name, fab, wl_fn in _matrix(quick):
        t0 = time.perf_counter()
        topo = fab.build(cfg.link.line_rate)
        table = fab.route_table()                     # validated in cache
        table_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        validate_table(topo, table)                   # re-check, timed
        validate_s = time.perf_counter() - t0
        spec = wl_fn(topo.n_nodes).spec(fabric=fab)
        scn = spec.build(cfg)          # built once; the timed region
        t0 = time.perf_counter()       # below times only the sweep
        sweep = Sweep.grid(
            configs={s.name: cfg.replace(scheme=s) for s in CCScheme},
            scenarios={name: scn})
        res = sweep.run(n_steps=n_steps)
        sweep_s = time.perf_counter() - t0
        sim_steps = 3 * n_steps                       # 3 schemes batched
        records.append({
            "name": name,
            "fabric": fab.name,
            "n_nodes": int(topo.n_nodes),
            "n_switches": int(topo.n_switches),
            "n_links": int(topo.n_links),
            "h_max": int(table.h_max),
            "n_flows": int(scn.routes.shape[0]),
            "table_s": round(table_s, 4),
            "validate_s": round(validate_s, 4),
            "sweep_s": round(sweep_s, 3),
            "sim_steps_per_s": round(sim_steps / max(sweep_s, 1e-9), 1),
            "delivered_mb": round(float(np.asarray(
                res[f"DCQCN_REV/{name}"].final.delivered).sum()) / 1e6, 3),
        })
    return records


def run_routing_matrix(quick: bool = False, n_steps: int = 1200) -> dict:
    """Routing-mode axis on the adversarial dragonfly: one Sweep of
    {min, valiant, ugal} x 3 schemes on group-shift traffic."""
    from repro.core import CCScheme, PAPER_CONFIG, Sweep
    from repro.core.workloads import group_shift
    from repro.net import FabricSpec

    cfg = PAPER_CONFIG
    if quick:
        fab, n_steps = FabricSpec.dragonfly(a=2, p=2, h=2), 600
    else:
        fab = FabricSpec.dragonfly(a=4, p=2, h=2)
    g = fab.a * fab.h + 1 if fab.groups is None else fab.groups
    hpg = fab.a * fab.p
    spec = group_shift(g, hpg, t_stop=n_steps * cfg.sim.dt).spec(
        fabric=fab, n_paths=4, label="adv")
    t0 = time.perf_counter()
    rset = fab.route_set(4)                       # timed: K-path build
    set_s = time.perf_counter() - t0
    configs = {f"{s.name}/{r}": cfg.replace(scheme=s, routing=r)
               for s in CCScheme for r in ("min", "valiant", "ugal")}
    t0 = time.perf_counter()
    res = Sweep.grid(configs=configs, scenarios={"adv": spec}).run(
        n_steps=n_steps)
    sweep_s = time.perf_counter() - t0
    delivered = {
        name: round(float(np.asarray(r.final.delivered).sum()) / 1e6, 3)
        for name, r in res.items()}
    ugal_ge_min = all(
        delivered[f"{s.name}/ugal/adv"] >= delivered[f"{s.name}/min/adv"]
        for s in CCScheme)
    return {
        "name": "dfly_adv_routing",
        "fabric": fab.name,
        "workload": spec.label,
        "k_paths": int(rset.k_paths),
        "route_set_s": round(set_s, 4),
        "n_points": len(res),
        "sweep_s": round(sweep_s, 3),
        "sim_steps_per_s": round(len(res) * n_steps / max(sweep_s, 1e-9),
                                 1),
        "delivered_mb": delivered,
        "ugal_ge_min": bool(ugal_ge_min),
    }


def run_pathology_matrix(quick: bool = False, n_steps: int = 5000) -> dict:
    """Victim-flow leg: the HoL-victim scenario x the three paper
    schemes as one launch.  Records ``victim_slowdown`` / ``pause_s``
    per scheme plus the ordering verdict the paper stakes its HoL
    claim on (Rev spares the victim, DCQCN collaterally marks it,
    PFC-only head-of-line blocks it)."""
    from repro.core import CCSpec, Sweep
    from repro.core.workloads import hol_victim_incast
    from repro.net import FabricSpec

    specs = {
        "PFC_ONLY": CCSpec(marking="cp", notification="np",
                           reaction="pfc"),
        "DCQCN": CCSpec(marking="cp", notification="np", reaction="rp"),
        "DCQCN_REV": CCSpec(marking="ecp", notification="enp",
                            reaction="erp"),
    }
    spec = hol_victim_incast(4, 64).spec(fabric=FabricSpec.clos3(4))
    t0 = time.perf_counter()
    res = Sweep.grid(configs=specs, scenarios={"hol": spec}).run(
        n_steps=n_steps)
    sweep_s = time.perf_counter() - t0
    vic = {s: round(float(res[f"{s}/hol"].victim_slowdown()), 4)
           for s in specs}
    pause = {s: round(float(res[f"{s}/hol"].pause_duration()), 6)
             for s in specs}
    return {
        "name": "pfc_pathology",
        "fabric": "clos64",
        "workload": spec.label,
        "n_steps": int(n_steps),
        "n_points": len(res),
        "sweep_s": round(sweep_s, 3),
        "victim_slowdown": vic,
        "pause_s": pause,
        "rev_beats_dcqcn": bool(
            vic["DCQCN_REV"] < vic["DCQCN"] < vic["PFC_ONLY"]),
    }


def pathology_baseline(path: str = BENCH_PATH) -> "dict | None":
    """Most recent committed ``pfc_pathology`` record, if any."""
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            doc = json.load(f)
    except (json.JSONDecodeError, OSError):
        return None
    for run_ in reversed(doc.get("runs", [])):
        for r in reversed(run_.get("records", [])):
            if r.get("name") == "pfc_pathology":
                return r
    return None


def append_bench_record(records: list[dict], path: str = BENCH_PATH) -> None:
    doc = {"runs": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                doc = json.load(f)
        except (json.JSONDecodeError, OSError):
            pass
    try:
        from ._env import bench_env
    except ImportError:              # `python benchmarks/net_scale.py`
        from _env import bench_env
    doc.setdefault("runs", []).append({
        "unix_time": int(time.time()),
        **bench_env(interpret=False),
        "records": records,
    })
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)


def main(quick: bool = False) -> list[tuple]:
    records = run_matrix(quick=quick)
    routing = run_routing_matrix(quick=quick)
    records.append(routing)
    baseline = pathology_baseline()        # before this run appends
    pathology = run_pathology_matrix(quick=quick)
    records.append(pathology)
    append_bench_record(records)
    rows = []
    for r in records[:-2]:      # the fabric cells; routing + pathology
        # records carry their own row formats below
        rows.append((
            f"net_scale.{r['name']}", r["sweep_s"] * 1e6,
            f"N={r['n_nodes']} L={r['n_links']} F={r['n_flows']} "
            f"H={r['h_max']} table={r['table_s']:.2f}s "
            f"{r['sim_steps_per_s']:.0f} steps/s"))
    mins = sum(v for k, v in routing["delivered_mb"].items() if "/min/" in k)
    ugal = sum(v for k, v in routing["delivered_mb"].items()
               if "/ugal/" in k)
    rows.append((
        f"net_scale.{routing['name']}", routing["sweep_s"] * 1e6,
        f"{routing['n_points']}pt {routing['fabric']} "
        f"min={mins:.1f}MB ugal={ugal:.1f}MB "
        f"ugal_ge_min={routing['ugal_ge_min']}"))
    if not routing["ugal_ge_min"]:
        raise AssertionError(
            f"UGAL under-delivered vs minimal routing on the adversarial "
            f"pattern: {routing['delivered_mb']}")
    vic = pathology["victim_slowdown"]
    rows.append((
        f"net_scale.{pathology['name']}", pathology["sweep_s"] * 1e6,
        f"{pathology['n_points']}pt {pathology['workload']} "
        f"vic REV={vic['DCQCN_REV']:.3f} DCQCN={vic['DCQCN']:.3f} "
        f"PFC={vic['PFC_ONLY']:.3f} ordered={pathology['rev_beats_dcqcn']}"))
    if not pathology["rev_beats_dcqcn"]:
        raise AssertionError(
            f"victim ordering violated (want REV < DCQCN < PFC_ONLY): "
            f"{vic}")
    if baseline is not None:
        want = (baseline["victim_slowdown"]["DCQCN"]
                - baseline["victim_slowdown"]["DCQCN_REV"])
        got = vic["DCQCN"] - vic["DCQCN_REV"]
        if got < 0.5 * want:
            raise AssertionError(
                f"Rev's victim-protection margin regressed vs the "
                f"committed baseline: {got:.4f} < 0.5 * {want:.4f}")
    rows.append(("net_scale.bench_json", 0.0, BENCH_PATH))
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(str(x) for x in row))
