"""Co-simulation: the dry-run's collective bytes, pushed through the
paper's CC mechanisms on the CLOS fabric model.

This is the integration benchmark that ties the two halves of the repo
together: for a training step of each architecture, take the cross-pod
collective volume from the compiled artifact, model it as concurrent
flows between pod leaf groups (the DCN incast pattern), and measure the
collective completion time under PFC / DCQCN / DCQCN-Rev — with and
without ERP-paced chunking.
"""

from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.core import CCConfig, CCScheme, ScenarioSpec, Sweep

ART = "artifacts/dryrun/pod2x16x16"


def _pod_bytes(rec: dict) -> float:
    """Cross-pod share of the collective traffic (upper-bound model:
    1/pod-fraction of the total collective bytes move on DCN)."""
    return max(rec.get("collective_bytes_total", 0.0) / 2.0, 1e6)


def cosim_cell(rec: dict, n_sources: int = 8,
               budget_ms: float = 2.0) -> dict:
    """Reduce-phase incast: n_sources pod-0 aggregators funnel the
    cell's DCN bytes into the pod-1 ingress node, beside a victim
    tenant flow.  The volume is clipped to what a `budget_ms` window
    can carry so every scheme gets a comparable, bounded run."""
    vol = min(_pod_bytes(rec), budget_ms * 1e-3 * 12.5e9 * 2)
    out = {"arch": rec["arch"], "shape": rec["shape"], "dcn_bytes": vol}
    srcs = [i if i < 3 else i + 1 for i in range(n_sources)]
    pairs = [(s, 16) for s in srcs]
    pairs.append((3, 12))                      # victim tenant (leaf 0)
    per_flow = vol / n_sources
    horizon = max(3e-3, 4 * vol / 12.5e9)
    cfg = CCConfig()
    spec = ScenarioSpec.flows(pairs, t_start=0.0, t_stop=float("inf"),
                              volume=per_flow, nic_buffer=2 * per_flow)
    results = Sweep.grid(           # 3 schemes, one batched launch
        configs={s.name: cfg.replace(scheme=s) for s in CCScheme},
        scenarios={"reduce": spec}).run(
            n_steps=int(horizon / cfg.sim.dt))
    for scheme in CCScheme:
        res = results[f"{scheme.name}/reduce"]
        ct = res.completion_times()
        thr = res.mean_throughput_while_active()
        out[scheme.name + "_ms"] = float(np.nanmax(ct[:-1])) * 1e3
        out[scheme.name + "_victim_gbps"] = float(thr[-1]) / 1e9
    return out


def main(limit: int = 3) -> list[tuple]:
    paths = sorted(glob.glob(os.path.join(ART, "*__train_4k.json")))
    out = []
    done = 0
    for p in paths:
        with open(p) as f:
            rec = json.load(f)
        if rec.get("skipped") or "collective_bytes_total" not in rec:
            continue
        r = cosim_cell(rec)
        speedup = r["DCQCN_ms"] / max(r["DCQCN_REV_ms"], 1e-9)
        out.append((f"cosim.{r['arch']}",
                    r["DCQCN_REV_ms"] * 1e3,
                    f"pfc={r['PFC_ONLY_ms']:.2f}ms "
                    f"dcqcn={r['DCQCN_ms']:.2f}ms "
                    f"rev={r['DCQCN_REV_ms']:.2f}ms "
                    f"rev_vs_dcqcn={speedup:.2f}x "
                    f"victim_rev={r['DCQCN_REV_victim_gbps']:.1f}GB/s "
                    f"victim_dcqcn={r['DCQCN_victim_gbps']:.1f}GB/s"))
        done += 1
        if done >= limit:
            break
    if not out:
        out.append(("cosim.skipped", 0.0,
                    "no dry-run artifacts yet — run repro.launch.dryrun"))
    return out


if __name__ == "__main__":
    for row in main(limit=10):
        print(",".join(str(x) for x in row))
