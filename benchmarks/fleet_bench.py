"""Fleet fabric benchmark -> BENCH_fleet.json.

Runs one ragged scheme x scenario grid twice — the single-launch
``Sweep.run()`` reference and the threaded work-stealing fleet
(streaming + journal) — and records:

  * **scheduling overhead** — fleet wall over single-launch wall minus
    one (the price of shard launches + streaming + journaling; gated
    against the committed baseline with ``--check``),
  * **bitwise fidelity** — the merged fleet result must equal the
    reference over every trace field and the final state (recorded,
    and a hard gate),
  * **fleet health** — per-signature compile count (must be 1 for the
    envelope plan), steal/retry counters, and Abandoned shards (any is
    a hard gate).

Record schema (appended to ``runs`` in BENCH_fleet.json)::

    {unix_time, quick, backend/platform/... (bench_env), n_points,
     n_shards, n_workers, n_steps, single_wall_s, fleet_wall_s,
     overhead_frac, bitwise, compiles, stolen, retries, resumed,
     abandoned}
"""

from __future__ import annotations

import json
import os
import tempfile
import time

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_fleet.json")

#: overhead gate: fail when overhead_frac exceeds the committed
#: baseline's by more than this (plus an absolute slack floor for
#: cross-runner noise — threaded scheduling on a busy CI box jitters).
TOLERANCE = 0.20
ABS_SLACK = 0.50

N_STEPS, N_STEPS_QUICK = 2000, 500


def _env():
    try:
        from . import _env as env_mod
    except ImportError:              # `python benchmarks/fleet_bench.py`
        import _env as env_mod
    return env_mod


def _grid(quick: bool):
    """A deliberately ragged grid: mixed flow counts so the LPT plan
    has something to balance and the stealers something to steal."""
    from repro.core import CCScheme, PAPER_CONFIG, ScenarioSpec, Sweep

    schemes = [CCScheme.DCQCN, CCScheme.DCQCN_REV] if quick \
        else list(CCScheme)
    scns = {"i2": ScenarioSpec.incast(2, victim=False),
            "i6": ScenarioSpec.incast(6, victim=False),
            "hol": ScenarioSpec.paper_incast(roll=0)}
    if not quick:
        scns["i12"] = ScenarioSpec.incast(12, victim=False)
    return Sweep.grid(
        configs={s.name: PAPER_CONFIG.replace(scheme=s)
                 for s in schemes},
        scenarios=scns)


def _bitwise(fleet_res, ref) -> bool:
    import jax
    import numpy as np
    from repro.core.serialize import _SIM_TRACE_FIELDS

    if not np.array_equal(fleet_res.times, ref.times):
        return False
    for f in _SIM_TRACE_FIELDS:
        a = getattr(fleet_res.traces, f)
        b = getattr(ref.traces, f)
        if (a is None) != (b is None):
            return False
        if a is not None and not np.array_equal(np.asarray(a),
                                                np.asarray(b)):
            return False
    la = jax.tree.flatten(fleet_res.final)[0]
    lb = jax.tree.flatten(ref.final)[0]
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def run_fleet_bench(quick: bool = False) -> dict:
    from repro.fleet import FleetConfig, run_fleet

    sweep = _grid(quick)
    n_steps = N_STEPS_QUICK if quick else N_STEPS
    trace_every = n_steps // 10

    # single-launch reference (warms the shared executable cache for
    # neither side: the fleet pads to the same envelope, so both pay
    # exactly one compile of the same program — time them separately)
    t0 = time.perf_counter()
    ref = sweep.run(n_steps=n_steps, trace_every=trace_every)
    single_wall = time.perf_counter() - t0

    cfg = FleetConfig(n_workers=3, max_points=2)
    with tempfile.TemporaryDirectory(prefix="fleet_bench_") as d:
        t0 = time.perf_counter()
        out = run_fleet(sweep, n_steps, trace_every, config=cfg,
                        journal=d)
        fleet_wall = time.perf_counter() - t0

    s = out.stats
    record = {
        "unix_time": int(time.time()),
        "quick": quick,
        **_env().bench_env(interpret=False),
        "n_points": len(sweep.points),
        "n_shards": s.n_shards,
        "n_workers": cfg.n_workers,
        "n_steps": n_steps,
        "single_wall_s": round(single_wall, 3),
        "fleet_wall_s": round(fleet_wall, 3),
        "overhead_frac": round(fleet_wall / single_wall - 1.0, 3),
        "bitwise": _bitwise(out.result, ref),
        "compiles": s.compiles,
        "stolen": s.stolen,
        "retries": s.retries,
        "resumed": s.resumed,
        "abandoned": s.abandoned,
    }
    print(f"fleet: {record['n_points']} pts / {record['n_shards']} "
          f"shards / {cfg.n_workers} workers: single "
          f"{single_wall:.2f}s fleet {fleet_wall:.2f}s "
          f"(overhead {record['overhead_frac']:+.1%}), "
          f"bitwise={record['bitwise']} compiles={s.compiles} "
          f"stolen={s.stolen} abandoned={s.abandoned}")
    return record


def load_bench(path: str = BENCH_PATH) -> dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {"runs": []}


def append_bench_record(record: dict, path: str = BENCH_PATH) -> None:
    doc = load_bench(path)
    doc.setdefault("runs", []).append(record)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"appended fleet record -> {path} ({len(doc['runs'])} runs)")


def check_regression(record: dict,
                     baseline: dict | None = None) -> list[str]:
    """Hard gates (always-on facts) + the overhead gate vs the first
    committed BENCH_fleet.json run."""
    fails = []
    if not record["bitwise"]:
        fails.append("fleet result is NOT bitwise the single-launch "
                     "Sweep.run() reference")
    if record["abandoned"]:
        fails.append(f"{record['abandoned']} shard(s) abandoned")
    if record["compiles"] > 1:
        fails.append(f"envelope plan compiled {record['compiles']}x "
                     f"(must share ONE executable)")
    if baseline is None:
        runs = load_bench().get("runs", [])
        if not runs:
            return fails + ["no committed BENCH_fleet.json baseline"]
        baseline = runs[0]
    ceiling = baseline["overhead_frac"] + TOLERANCE + ABS_SLACK
    if record["overhead_frac"] > ceiling:
        fails.append(
            f"scheduling overhead {record['overhead_frac']:+.1%} > "
            f"{ceiling:+.1%} (baseline "
            f"{baseline['overhead_frac']:+.1%} + {TOLERANCE:.0%} "
            f"+ {ABS_SLACK:.0%} slack)")
    return fails


def main(quick: bool = False, check: bool = False) -> list[tuple]:
    """run.py section hook: bench, append, optionally gate."""
    record = run_fleet_bench(quick=quick)
    fails = check_regression(record) if check else []
    append_bench_record(record)
    rows = [
        ("fleet.single_wall", record["single_wall_s"] * 1e6,
         f"{record['single_wall_s']:.2f}s one launch"),
        ("fleet.fleet_wall", record["fleet_wall_s"] * 1e6,
         f"{record['fleet_wall_s']:.2f}s {record['n_shards']} shards "
         f"x {record['n_workers']} workers "
         f"(overhead {record['overhead_frac']:+.1%})"),
        ("fleet.bitwise", 0.0, str(record["bitwise"])),
        ("fleet.compiles", 0.0, str(record["compiles"])),
        ("fleet.stolen", 0.0, str(record["stolen"])),
        ("fleet.abandoned", 0.0, str(record["abandoned"])),
    ]
    for f in fails:
        rows.append(("fleet.REGRESSION", 0.0, f))
    return rows


if __name__ == "__main__":
    import sys
    rows = main(quick="--quick" in sys.argv, check="--check" in sys.argv)
    for row in rows:
        print(",".join(str(x) for x in row))
    if any("REGRESSION" in r[0] for r in rows):
        raise SystemExit(1)
