"""Paper Fig. 2 — aggregate network throughput + completion times.

One ``Sweep``: 3 CC schemes x 4 scenarios (both wirings x window/equal-
work) = 12 runs in a single jitted vmap-of-scan — no python-level
per-run loop, one compilation.  roll=0 is the shared-wire Fig. 3 HoL
narrative; roll=1 the victim-disjoint Fig. 2 25 GB/s aggregate.  Writes
throughput timelines to artifacts/paper/fig2_<roll>.csv and returns the
headline numbers.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core import CCScheme, PAPER_CONFIG, ScenarioSpec, Sweep

OUT = "artifacts/paper"


def paper_sweep(n_steps: int = 18000):
    """The 3-scheme x 4-scenario sweep behind Figs. 2 and 3."""
    cfg = PAPER_CONFIG
    scenarios = {}
    for roll in (0, 1):
        scenarios[f"w{roll}"] = ScenarioSpec.paper_incast(roll=roll)
        scenarios[f"v{roll}"] = ScenarioSpec.paper_incast_volume(roll=roll)
    sweep = Sweep.grid(
        configs={s.name: cfg.replace(scheme=s) for s in CCScheme},
        scenarios=scenarios)
    return sweep.run(n_steps=n_steps)


def run_fig2(res=None, roll: int = 1) -> dict:
    if res is None:
        res = paper_sweep()
    os.makedirs(OUT, exist_ok=True)
    out = {}
    rows = None
    for scheme in CCScheme:
        rw = res[f"{scheme.name}/w{roll}"]       # window mode: plateaus
        rv = res[f"{scheme.name}/v{roll}"]       # equal work: completion
        agg = rw.aggregate_throughput(
            window=rw.window_samples(100e-6)) / 1e9
        if rows is None:
            rows = [rw.times * 1e3]
        rows.append(agg)
        thr = rw.mean_throughput_while_active() / 1e9
        out[scheme.name] = {
            "aggregate_gbps": float(thr.sum()),
            "victim_gbps": float(thr[4]),
            "completion_ms": rv.completion_time() * 1e3,
            "peak_queue_kb": float(rw.max_q.max() / 1e3),
        }
    header = "time_ms," + ",".join(s.name for s in CCScheme)
    np.savetxt(os.path.join(OUT, f"fig2_roll{roll}.csv"),
               np.stack(rows, 1), delimiter=",", header=header, fmt="%.4f")
    return out


def main() -> list[tuple]:
    res = paper_sweep()                          # ONE device launch
    out = []
    for roll in (0, 1):
        r = run_fig2(res, roll)
        for scheme, v in r.items():
            out.append((f"fig2.roll{roll}.{scheme}",
                        v["completion_ms"] * 1e3,   # us per "call" (= run)
                        f"agg={v['aggregate_gbps']:.2f}GB/s "
                        f"victim={v['victim_gbps']:.2f}GB/s "
                        f"done={v['completion_ms']:.2f}ms"))
    return out


if __name__ == "__main__":
    for row in main():
        print(",".join(str(x) for x in row))
