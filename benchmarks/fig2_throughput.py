"""Paper Fig. 2 — aggregate network throughput + completion times.

Runs the §II.A scenario under PFC / DCQCN / DCQCN-Rev on both wirings
(roll=0: shared-wire, the Fig. 3 HoL narrative; roll=1: victim-disjoint,
the Fig. 2 25 GB/s aggregate).  Writes the throughput timelines to
artifacts/paper/fig2_<roll>.csv and returns the headline numbers.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core import (CCScheme, PAPER_CONFIG, paper_incast,
                        paper_incast_volume, run)

OUT = "artifacts/paper"


def run_fig2(roll: int = 1, n_steps: int = 14000) -> dict:
    cfg = PAPER_CONFIG
    os.makedirs(OUT, exist_ok=True)
    scn_w = paper_incast(cfg, roll=roll)          # window mode: plateaus
    scn_v = paper_incast_volume(cfg, roll=roll)   # equal work: completion
    res = {}
    rows = None
    for scheme in CCScheme:
        rw = run(scn_w, cfg.replace(scheme=scheme), n_steps=n_steps)
        rv = run(scn_v, cfg.replace(scheme=scheme), n_steps=n_steps + 4000)
        agg = rw.aggregate_throughput(window=100) / 1e9
        if rows is None:
            rows = [rw.times * 1e3]
        rows.append(agg)
        thr = rw.mean_throughput_while_active() / 1e9
        res[scheme.name] = {
            "aggregate_gbps": float(thr.sum()),
            "victim_gbps": float(thr[4]),
            "completion_ms": rv.completion_time() * 1e3,
            "peak_queue_kb": float(rw.max_q.max() / 1e3),
        }
    header = "time_ms," + ",".join(s.name for s in CCScheme)
    np.savetxt(os.path.join(OUT, f"fig2_roll{roll}.csv"),
               np.stack(rows, 1), delimiter=",", header=header, fmt="%.4f")
    return res


def main() -> list[tuple]:
    out = []
    for roll in (0, 1):
        r = run_fig2(roll)
        for scheme, v in r.items():
            out.append((f"fig2.roll{roll}.{scheme}",
                        v["completion_ms"] * 1e3,   # us per "call" (= run)
                        f"agg={v['aggregate_gbps']:.2f}GB/s "
                        f"victim={v['victim_gbps']:.2f}GB/s "
                        f"done={v['completion_ms']:.2f}ms"))
    return out


if __name__ == "__main__":
    for row in main():
        print(",".join(str(x) for x in row))
