"""Beyond-paper: DC-scale CC stepping + sweep throughput.

The paper's scenario has 5 flows; a datacenter NIC fleet runs the RP/ERP
machine for 10^5+ flows.  This measures flow-updates/second of the
reaction-point update at increasing F (jnp reference path; the Pallas
cc_step kernel targets TPU and is validated in interpret mode by tests),
the full fluid-model step at permutation-traffic scale, and the batched
Sweep engine's run-throughput (an incast-degree x scheme grid as one
launch vs the legacy one-run-at-a-time loop).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CCConfig, CCScheme, ScenarioSpec, Sweep,
                        random_permutation, run)
from repro.kernels import ref


def bench_rp_updates(F: int, iters: int = 50) -> float:
    r = np.random.RandomState(0)
    p = ref.RPParams(g=1 / 256, rate_decrease=0.5, timer_T=55e-6,
                     byte_B=10e6, rai=5e6, rhai=25e6, fr_stages=5,
                     min_rate=1e6, line_rate=12.5e9, dt=1e-6)
    st = ref.RPState(*[jnp.asarray(r.rand(F), jnp.float32)
                       for _ in range(8)])
    cnp = jnp.asarray(r.rand(F) > 0.7)

    @jax.jit
    def step(s):
        return ref.rp_update_ref(s, cnp, p)

    st = step(st)
    jax.block_until_ready(st)
    t0 = time.perf_counter()
    for _ in range(iters):
        st = step(st)
    jax.block_until_ready(st)
    dt = (time.perf_counter() - t0) / iters
    return F / dt          # flow-updates per second


def bench_fluid_step(n_flows: int, n_steps: int = 2000) -> float:
    cfg = CCConfig(scheme=CCScheme.DCQCN_REV)
    scn = random_permutation(cfg, n_flows=n_flows, arity=4)
    t0 = time.perf_counter()
    run(scn, cfg, n_steps=n_steps)
    dt = time.perf_counter() - t0
    return n_steps / dt    # sim steps / wall second (incl. jit)


def bench_sweep(n_steps: int = 2000) -> tuple[float, float, int]:
    """Scheme x incast-degree grid: one launch vs a python run() loop.

    Returns (sweep_s, loop_s, n_points)."""
    cfg = CCConfig()
    degrees = (2, 4, 8, 16)
    sweep = Sweep.grid(
        configs={s.name: cfg.replace(scheme=s) for s in CCScheme},
        scenarios={f"incast{n}": ScenarioSpec.incast(n) for n in degrees})
    t0 = time.perf_counter()
    sweep.run(n_steps=n_steps)
    sweep_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for p in sweep.points:
        run(p.scenario, p.cfg, n_steps=n_steps)
    loop_s = time.perf_counter() - t0
    return sweep_s, loop_s, len(sweep.points)


def main() -> list[tuple]:
    out = []
    for F in (1_000, 10_000, 100_000):
        ups = bench_rp_updates(F)
        out.append((f"cc_scale.rp_updates.F{F}", 1e6 / (ups / F),
                    f"{ups:.3g} flow-updates/s"))
    for nf in (16, 64):
        sps = bench_fluid_step(nf)
        out.append((f"cc_scale.fluid_step.flows{nf}", 1e6 / sps,
                    f"{sps:.1f} sim-steps/s"))
    sweep_s, loop_s, n = bench_sweep()
    out.append((f"cc_scale.sweep.points{n}", sweep_s / n * 1e6,
                f"one-launch {sweep_s:.2f}s vs run-loop {loop_s:.2f}s "
                f"({loop_s / max(sweep_s, 1e-9):.1f}x)"))
    return out


if __name__ == "__main__":
    for row in main():
        print(",".join(str(x) for x in row))
