"""Paper Fig. 3 — per-flow bandwidth under each CC scheme (roll=0, the
shared-wire wiring where the HoL pathology lives).

Reproduces: PFC parking-lot on F0/F1 vs F4/F8, DCQCN throttling the
victim alongside congesting flows, DCQCN-Rev keeping the victim at its
max-min share while fair-sharing the incast flows.  All three schemes
ride one batched Sweep launch.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core import (CCScheme, PAPER_CONFIG, PAPER_FLOW_NAMES,
                        ScenarioSpec, Sweep)

OUT = "artifacts/paper"


def run_fig3(n_steps: int = 14000) -> dict:
    cfg = PAPER_CONFIG
    os.makedirs(OUT, exist_ok=True)
    sweep = Sweep.grid(
        configs={s.name: cfg.replace(scheme=s) for s in CCScheme},
        scenarios={"hol": ScenarioSpec.paper_incast(roll=0)})
    results = sweep.run(n_steps=n_steps)
    res = {}
    for scheme in CCScheme:
        r = results[f"{scheme.name}/hol"]
        thr = r.flow_throughput(window=r.window_samples(100e-6)) / 1e9
        header = "time_ms," + ",".join(PAPER_FLOW_NAMES)
        np.savetxt(os.path.join(OUT, f"fig3_{scheme.name}.csv"),
                   np.concatenate([r.times[:, None] * 1e3, thr], 1),
                   delimiter=",", header=header, fmt="%.4f")
        means = r.mean_throughput_while_active() / 1e9
        res[scheme.name] = dict(zip(PAPER_FLOW_NAMES, map(float, means)))
    return res


def main() -> list[tuple]:
    r = run_fig3()
    out = []
    for scheme, flows in r.items():
        for name, gbps in flows.items():
            out.append((f"fig3.{scheme}.{name}", 0.0, f"{gbps:.3f}GB/s"))
    return out


if __name__ == "__main__":
    for row in main():
        print(",".join(str(x) for x in row))
