"""Adaptive non-minimal routing: RouteSet properties, Valiant/VLB
structure, UGAL parity with single-path runs, and the routing axis in
one Sweep launch."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # image without hypothesis: deterministic sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import CCScheme, PAPER_CONFIG, ScenarioSpec, Sweep, run
from repro.core.workloads import group_shift
from repro.net import (FabricSpec, dragonfly_route_set, make_dragonfly,
                       validate_route_set)

CFG = PAPER_CONFIG


def _paths_of(rset, s, d):
    """Real link-id path of every candidate slot of pair (s, d)."""
    return [[int(x) for x in rset.paths[s, d, k, : rset.hops[s, d, k]]]
            for k in range(rset.k_paths)]


# ---------------------------------------------------------------------------
# property: dragonfly Valiant structure over (a, p, h) x seeds
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(a=st.integers(min_value=2, max_value=4),
       p=st.integers(min_value=1, max_value=2),
       h=st.integers(min_value=1, max_value=2),
       seed=st.integers(min_value=0, max_value=3))
def test_dragonfly_valiant_paths_valid_and_one_intermediate(a, p, h, seed):
    """Every candidate layer passes the structural checker, and every
    inter-group detour visits exactly one intermediate group."""
    topo, idx = make_dragonfly(a=a, p=p, h=h)
    rset = dragonfly_route_set(idx, k=3, seed=seed)
    validate_route_set(topo, rset)           # link contiguity, endpoints
    n = idx.n_hosts
    pairs = [(s, d) for s in range(0, n, max(1, n // 6))
             for d in range(1, n, max(1, n // 5)) if s != d]
    for s, d in pairs:
        gs, gd = idx.host_group(s), idx.host_group(d)
        minimal = _paths_of(rset, s, d)[0]
        for path in _paths_of(rset, s, d)[1:]:
            groups = idx.groups_visited(path)
            if path == minimal:              # no detour existed: fallback
                continue
            if gs != gd:
                mid = [g for g in groups if g not in (gs, gd)]
                assert len(mid) == 1, (s, d, path, groups)
                assert groups == [gs, mid[0], gd]
                n_global = sum(idx.is_global(lid) for lid in path)
                assert n_global == 2
            else:                            # in-group router detour
                assert groups == [gs]


@settings(max_examples=6, deadline=None)
@given(a=st.integers(min_value=2, max_value=4),
       seed=st.integers(min_value=0, max_value=2))
def test_dragonfly_valiant_flattens_global_load(a, seed):
    """Under random permutations, the Valiant candidate layers spread
    global-channel load strictly flatter (max/mean) than minimal."""
    topo, idx = make_dragonfly(a=a, p=2, h=2)
    rset = dragonfly_route_set(idx, k=4, seed=seed)
    n = idx.n_hosts
    rng = np.random.RandomState(seed + 17)
    perm = rng.permutation(n)
    pairs = [(s, int(perm[s])) for s in range(n) if perm[s] != s]
    gids = idx.global_ids()

    def ratio(load):
        sel = load[gids].astype(np.float64)
        return sel.max() / max(sel.mean(), 1e-12)

    r_min = ratio(rset.link_load(topo.n_links, pairs, k=0))
    # each flow's detour layers together: 2 sampled globals per flow
    alt = sum(rset.link_load(topo.n_links, pairs, k=j)
              for j in range(1, rset.k_paths))
    assert ratio(alt) < r_min, (ratio(alt), r_min)


def test_dragonfly_adversarial_load_provably_flatter():
    """Group-shift traffic: minimal routing puts a whole group's flows
    on ONE global channel; the Valiant layers stay within a constant
    max/mean factor while minimal is off by ~#channels."""
    topo, idx = make_dragonfly(a=4, p=2, h=2)
    rset = dragonfly_route_set(idx, k=4, seed=0)
    wl = group_shift(idx.g, idx.a * idx.p)
    pairs = list(zip(wl.src, wl.dst))
    gids = idx.global_ids()
    load_min = rset.link_load(topo.n_links, pairs, k=0)[gids]
    # minimal: g channels carry a*p flows each, the rest exactly zero
    assert load_min.max() == idx.a * idx.p
    assert (load_min > 0).sum() == idx.g
    mean_min = load_min.mean()
    alt = sum(rset.link_load(topo.n_links, pairs, k=j)
              for j in range(1, rset.k_paths))[gids]
    # VLB: every channel sees some load; max/mean bounded well below
    # minimal's (which concentrates everything on 1/#channels of links)
    assert alt.max() / alt.mean() < 0.5 * (load_min.max() / mean_min)


# ---------------------------------------------------------------------------
# property: XGFT / CLOS Valiant candidates stay valid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fab", [
    FabricSpec.clos3(4),
    FabricSpec.xgft((4, 4, 4), (1, 4, 4)),
    FabricSpec.fat_tree(4, taper=2),
    FabricSpec.xgft((2, 2, 2, 2), (1, 2, 2, 2)),
    FabricSpec.dragonfly(a=4, p=2, h=2),
    FabricSpec.dragonfly(a=2, p=2, h=1, groups=3),
], ids=lambda f: f.name)
def test_route_set_every_layer_valid(fab):
    validate_route_set(fab.build(), fab.route_set(4, seed=1))


def test_route_set_slot0_is_minimal_table():
    fab = FabricSpec.dragonfly(a=4, p=2, h=2)
    rset, table = fab.route_set(4), fab.route_table()
    np.testing.assert_array_equal(rset.hops[:, :, 0], table.hops)
    np.testing.assert_array_equal(
        rset.paths[:, :, 0, :5], table.paths)     # VLB pads H 5 -> 7
    assert (rset.paths[:, :, 0, 5:] == -1).all()


def test_route_set_cached_and_seed_keyed():
    fab = FabricSpec.dragonfly(a=2, p=2, h=1)
    assert fab.route_set(3, seed=0) is fab.route_set(3, seed=0)
    assert fab.route_set(3, seed=0) is not fab.route_set(3, seed=1)


# ---------------------------------------------------------------------------
# parity: UGAL with zero backlog == the single-path RouteTable run
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fab", [
    FabricSpec.clos3(4),
    FabricSpec.fat_tree(4, taper=2),
    FabricSpec.dragonfly(a=4, p=2, h=2),
], ids=lambda f: f.name)
def test_ugal_zero_backlog_bitexact_vs_single_path(fab):
    """Uncongested traffic (no queues at selection epochs, no CNPs):
    UGAL must pin every flow to its minimal path and reproduce the
    legacy single-path run bit for bit — traces AND final state."""
    mk = lambda **kw: ScenarioSpec.permutation(
        12, seed=3, fabric=fab, t_start=0.0,
        gen_rate=0.05 * CFG.link.line_rate, **kw)
    base = run(mk().build(CFG), CFG, n_steps=800)
    assert int(base.cnp.sum()) == 0          # scenario really is idle
    for mode in ("min", "valiant", "ugal"):
        cfg = CFG.replace(routing=mode)
        res = run(mk(n_paths=4).build(cfg), cfg, n_steps=800)
        if mode == "valiant":                # pinned detours DO diverge
            assert int(res.n_nonmin.max()) > 0
            continue
        for field in ("delivered", "rate", "inst_thr", "max_q",
                      "n_paused", "marked", "cnp"):
            np.testing.assert_array_equal(
                getattr(res, field), getattr(base, field),
                err_msg=f"{mode}/{field}")
        for field in ("nicq", "delivered", "rate"):
            np.testing.assert_array_equal(
                np.asarray(getattr(res.final, field)),
                np.asarray(getattr(base.final, field)),
                err_msg=f"{mode}/final.{field}")
        for field in ("qh", "est"):         # [F, H]: VLB pads H 5 -> 7
            a = np.asarray(getattr(res.final, field))
            b = np.asarray(getattr(base.final, field))
            np.testing.assert_array_equal(
                a[:, : b.shape[1]], b, err_msg=f"{mode}/final.{field}")
            assert (a[:, b.shape[1]:] == 0).all()
        assert int(np.asarray(res.final.path_idx).max()) == 0
        assert int(res.n_nonmin.max()) == 0


# ---------------------------------------------------------------------------
# acceptance: routing x scheme in ONE Sweep launch, UGAL wins adversarial
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def routing_sweep():
    fab = FabricSpec.dragonfly(a=4, p=2, h=2)
    wl = group_shift(9, 8, t_stop=1.5e-3)
    spec = wl.spec(fabric=fab, n_paths=4, label="adv")
    configs = {
        f"{s.name}/{r}": CFG.replace(scheme=s, routing=r)
        for s in CCScheme for r in ("min", "valiant", "ugal")}
    return Sweep.grid(configs=configs, scenarios={"adv": spec}).run(
        n_steps=1200)


@pytest.mark.parametrize("scheme", list(CCScheme))
def test_ugal_beats_minimal_on_adversarial_dragonfly(routing_sweep, scheme):
    """{min, valiant, ugal} x all schemes ride one launch; non-minimal
    routing must strictly win delivered throughput on the group-shift
    pattern that hotspots a single global channel per group."""
    res = routing_sweep
    delivered = {r: float(np.asarray(
        res[f"{scheme.name}/{r}/adv"].final.delivered).sum())
        for r in ("min", "valiant", "ugal")}
    assert delivered["ugal"] >= 1.5 * delivered["min"], delivered
    assert delivered["valiant"] >= 1.5 * delivered["min"], delivered
    # and UGAL actually moved flows off their minimal paths
    assert int(res[f"{scheme.name}/ugal/adv"].n_nonmin.max()) > 0
    assert int(res[f"{scheme.name}/min/adv"].n_nonmin.max()) == 0


def test_routing_modes_share_one_scenario_build(routing_sweep):
    """All 9 points carry the same [F, K, H] candidate tensors — the
    routing decision is config data, not scenario structure."""
    res = routing_sweep
    assert len(res) == 9
    shapes = {res[n].scn.alt_routes.shape for n in res.names}
    assert shapes == {(72, 4, 7)}
