"""Sweep/ScenarioSpec API tests: batched == individual, padding
invariance, trace decimation, delay-line sizing, vectorised metrics."""

import numpy as np
import pytest

from repro.core import (CCConfig, CCScheme, PAPER_CONFIG, ScenarioSpec,
                        Sweep, config_grid, delay_depth, init_state,
                        make_step_fn, pad_scenario, paper_incast, run)

CFG = PAPER_CONFIG
N_STEPS = 3000


@pytest.fixture(scope="module")
def sweep_vs_individual():
    spec = ScenarioSpec.paper_incast(roll=0)
    sweep = Sweep.grid(
        configs={s.name: CFG.replace(scheme=s) for s in CCScheme},
        scenarios={"hol": spec})
    batched = sweep.run(n_steps=N_STEPS)
    single = {s: run(spec.build(CFG.replace(scheme=s)),
                     CFG.replace(scheme=s), n_steps=N_STEPS)
              for s in CCScheme}
    return batched, single


# ---------------------------------------------------------------------------
# one-jit sweep == per-point run()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", list(CCScheme))
def test_sweep_matches_individual_runs(sweep_vs_individual, scheme):
    """The batched vmap-of-scan reproduces run() bit-for-bit: traces
    AND final state."""
    batched, single = sweep_vs_individual
    rs = batched[f"{scheme.name}/hol"]
    ri = single[scheme]
    for field in ("delivered", "rate", "inst_thr", "max_q", "marked",
                  "cnp"):
        np.testing.assert_array_equal(
            getattr(rs, field), getattr(ri, field), err_msg=field)
    np.testing.assert_array_equal(np.asarray(rs.final.qh),
                                  np.asarray(ri.final.qh))
    np.testing.assert_array_equal(np.asarray(rs.final.rate),
                                  np.asarray(ri.final.rate))


def test_sweep_point_views(sweep_vs_individual):
    batched, _ = sweep_vs_individual
    assert len(batched) == 3
    assert "DCQCN/hol" in batched
    assert batched.names == [f"{s.name}/hol" for s in CCScheme]
    # index and name access agree
    np.testing.assert_array_equal(batched[0].delivered,
                                  batched["PFC_ONLY/hol"].delivered)


def test_sweep_mixed_scenario_shapes():
    """Scenarios of different F stack via padding and still run."""
    res = Sweep.grid(
        configs=CFG,
        scenarios={"i2": ScenarioSpec.incast(2, victim=False),
                   "i8": ScenarioSpec.incast(8, victim=False)}
    ).run(n_steps=1000)
    assert res["i2"].delivered.shape[1] == 2
    assert res["i8"].delivered.shape[1] == 8


def test_config_grid_paths():
    grid = config_grid(CFG, **{"dcqcn.kmin": [8192.0, 15360.0]})
    assert len(grid) == 2
    assert grid["kmin=8192"].dcqcn.kmin == 8192.0
    assert grid["kmin=8192"].rev == CFG.rev          # untouched subtree


# ---------------------------------------------------------------------------
# padding invariance
# ---------------------------------------------------------------------------

def test_padding_is_inert():
    """Extra PAD flows/hops/links change nothing for the real flows."""
    scn = paper_incast(CFG, roll=0)
    F, H = scn.routes.shape
    L = scn.capacity.shape[0]
    padded = pad_scenario(scn, F + 3, H + 2, L + 5)
    r0 = run(scn, CFG, n_steps=2000)
    r1 = run(padded, CFG, n_steps=2000)
    np.testing.assert_array_equal(r0.delivered, r1.delivered[:, :F])
    np.testing.assert_array_equal(r0.inst_thr, r1.inst_thr[:, :F])
    np.testing.assert_array_equal(r0.max_q, r1.max_q)
    # PAD flows do nothing at all
    assert np.all(r1.delivered[:, F:] == 0)
    assert np.all(np.asarray(r1.final.offered)[F:] == 0)


def test_pad_scenario_rejects_shrinking():
    scn = paper_incast(CFG)
    with pytest.raises(ValueError):
        pad_scenario(scn, 1, 1, 1)


# ---------------------------------------------------------------------------
# trace decimation
# ---------------------------------------------------------------------------

def test_trace_every_matches_strided_full_trace():
    scn = paper_incast(CFG, roll=0)
    k = 10
    full = run(scn, CFG, n_steps=2000, trace_every=1)
    dec = run(scn, CFG, n_steps=2000, trace_every=k)
    # cumulative fields: strided samples of the full trace
    np.testing.assert_array_equal(full.delivered[k - 1:: k], dec.delivered)
    np.testing.assert_array_equal(full.rate[k - 1:: k], dec.rate)
    np.testing.assert_array_equal(full.times[k - 1:: k], dec.times)
    # event fields: window sums — totals are exact, not subsampled
    T = full.marked.shape[0]
    np.testing.assert_array_equal(
        full.marked.reshape(T // k, k, -1).sum(1), dec.marked)
    assert full.marked.sum() == dec.marked.sum()
    np.testing.assert_array_equal(
        full.cnp.reshape(T // k, k, -1).sum(1), dec.cnp)
    # gauges: window maxima
    np.testing.assert_array_equal(
        full.max_q.reshape(T // k, k).max(1), dec.max_q)


def test_trace_memory_shrinks():
    """The default 14 ms run's trace footprint drops >= 5x on device."""
    scn = paper_incast(CFG, roll=0)
    full = run(scn, CFG, n_steps=2000, trace_every=1)
    dec = run(scn, CFG, n_steps=2000)          # cfg default trace_every
    bytes_of = lambda r: sum(
        getattr(r, f).nbytes for f in
        ("delivered", "rate", "inst_thr", "max_q", "n_paused", "marked",
         "cnp"))
    assert CFG.sim.trace_every >= 5
    assert bytes_of(full) >= 5 * bytes_of(dec)


def test_n_steps_rounds_up_to_whole_windows():
    scn = paper_incast(CFG, roll=0)
    res = run(scn, CFG, n_steps=995, trace_every=10)
    assert res.delivered.shape[0] == 100       # ceil(995/10) windows
    assert int(res.final.t) == 1000


# ---------------------------------------------------------------------------
# delay line
# ---------------------------------------------------------------------------

def _long_rtt(scn, steps):
    return scn._replace(rtt_steps=np.full_like(scn.rtt_steps, steps))


def test_delay_depth_follows_rtt():
    scn = paper_incast(CFG)
    assert delay_depth(scn) == int(scn.rtt_steps.max()) + 1
    long = _long_rtt(scn, 100)
    assert delay_depth(long) == 101
    st = init_state(long, CFG)
    assert st.trig_buf.shape[0] == 101


def test_legacy_delay_cap_raises_instead_of_wrapping():
    """rtt >= DELAY_SLOTS used to silently alias to rtt % 32."""
    scn = _long_rtt(paper_incast(CFG), 40)
    with pytest.raises(ValueError, match="overflow"):
        make_step_fn(scn, CFG, delay_slots=32)
    with pytest.raises(ValueError, match="overflow"):
        init_state(scn, CFG, delay_slots=32)
    make_step_fn(scn, CFG, delay_slots=64)      # explicit headroom: fine


def test_long_rtt_delays_feedback():
    """A 40-step RTT must react LATER than a 2-step RTT, not (as the
    wrapped legacy path had it) like an 8-step one."""
    cfg = CFG.replace(scheme=CCScheme.DCQCN_REV)
    scn = paper_incast(cfg, roll=0)
    fast = run(scn, cfg, n_steps=2000, trace_every=1)
    slow = run(_long_rtt(scn, 40), cfg, n_steps=2000, trace_every=1)
    f_cut = np.argmax(fast.cnp[:, 0] > 0)       # first CNP arrival
    s_cut = np.argmax(slow.cnp[:, 0] > 0)
    assert f_cut > 0 and s_cut > 0
    assert s_cut >= f_cut + 30                  # ~38 steps more delay


# ---------------------------------------------------------------------------
# ScenarioSpec <-> legacy builders
# ---------------------------------------------------------------------------

def test_legacy_builders_are_spec_wrappers():
    a = paper_incast(CFG, roll=1)
    b = ScenarioSpec.paper_incast(roll=1).build(CFG)
    for fa, fb in zip(a, b):
        if isinstance(fa, np.ndarray):
            np.testing.assert_array_equal(fa, fb)
        else:
            assert fa == fb


def test_spec_is_hashable_plain_data():
    s1 = ScenarioSpec.incast(4)
    s2 = ScenarioSpec.incast(4)
    assert s1 == s2 and hash(s1) == hash(s2)


# ---------------------------------------------------------------------------
# vectorised SimResult metrics (vs reference implementations)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def vol_result():
    cfg = CFG.replace(scheme=CCScheme.DCQCN_REV)
    scn = ScenarioSpec.paper_incast_volume(roll=0).build(cfg)
    return run(scn, cfg, n_steps=6000)


def test_flow_throughput_matches_convolve(vol_result):
    r = vol_result
    w = 100
    k = np.ones(w) / w
    ref = np.stack([np.convolve(r.inst_thr[:, f], k, mode="same")
                    for f in range(r.inst_thr.shape[1])], axis=1)
    np.testing.assert_allclose(r.flow_throughput(w), ref, rtol=1e-6)


def test_completion_times_match_loop_reference(vol_result):
    r = vol_result
    offered = np.asarray(r.final.offered)
    vol = np.asarray(r.scn.volume, dtype=np.float64)
    total = np.where(np.isfinite(vol), vol, offered)
    ref = np.full(total.shape, np.nan)
    for f in range(total.shape[0]):
        if total[f] <= 0:
            continue
        hit = np.nonzero(r.delivered[:, f] >= 0.999 * total[f])[0]
        if hit.size:
            ref[f] = r.times[hit[0]]
    np.testing.assert_allclose(r.completion_times(), ref, equal_nan=True)
