"""repro.net: topology zoo, route tables, validity, fabric sweeps."""

import numpy as np
import pytest

from repro.core import CCScheme, PAPER_CONFIG, ScenarioSpec, Sweep, run
from repro.core.routing import build_flow_routes
from repro.core.topology import make_paper_clos
from repro.net import (FabricSpec, clos_route_table, dragonfly_route_table,
                       make_dragonfly, make_fat_tree, make_xgft,
                       stage_balance, validate_table, xgft_route_table)

CFG = PAPER_CONFIG


# ---------------------------------------------------------------------------
# topology zoo structure
# ---------------------------------------------------------------------------

def test_xgft_reproduces_paper_clos_counts():
    """XGFT(3; 4,4,4; 1,4,4) is the paper's 64-node CLOS."""
    topo, idx = make_xgft((4, 4, 4), (1, 4, 4))
    ref = make_paper_clos()
    assert (topo.n_nodes, topo.n_switches, topo.n_links) == \
        (ref.n_nodes, ref.n_switches, ref.n_links)
    assert idx.n_hosts == 64 and idx.h == 3


def test_xgft_every_link_has_a_mirror():
    """Each up-link (u -> v) must have a down-link (v -> u)."""
    topo, _ = make_xgft((3, 2), (1, 2))
    fwd = set(zip(topo.link_src.tolist(), topo.link_dst.tolist()))
    assert len(fwd) == topo.n_links          # no duplicate directed links
    assert all((d, s) in fwd for s, d in fwd)


def test_fat_tree_taper_cuts_uplinks():
    """2:1 taper: leaf stage has half the up-links of the full tree."""
    full, fi = make_fat_tree(4, taper=1)
    tapered, ti = make_fat_tree(4, taper=2)
    assert full.n_nodes == tapered.n_nodes == 64
    assert len(fi.up_stage_ids(2)) == 2 * len(ti.up_stage_ids(2))
    # oversubscription shows up as doubled per-link load under all-to-all
    lf = xgft_route_table(fi).link_load(full.n_links)
    lt = xgft_route_table(ti).link_load(tapered.n_links)
    assert stage_balance(lt, ti.up_stage_ids(2))[1] == \
        2 * stage_balance(lf, fi.up_stage_ids(2))[1]


def test_dragonfly_structure():
    topo, idx = make_dragonfly(a=4, p=2, h=2)
    assert idx.g == 9                        # canonical a*h + 1
    assert topo.n_nodes == 9 * 4 * 2
    assert topo.n_switches == 36
    # every router: p host-dn + (a-1) local + h global out-links
    for r in range(topo.n_switches):
        assert int((topo.link_src == r).sum()) == 2 + 3 + 2


def test_dragonfly_global_channels_pair_up():
    topo, idx = make_dragonfly(a=2, p=1, h=2, groups=4)
    for g1 in range(4):
        for g2 in range(4):
            if g1 == g2:
                continue
            lid = idx.gl_port(g1, g2)
            rid = idx.gl_port(g2, g1)
            assert topo.link_dst[lid] == topo.link_src[rid]
            assert topo.link_src[lid] == topo.link_dst[rid]


# ---------------------------------------------------------------------------
# route tables: validity for every family
# ---------------------------------------------------------------------------

FAMILIES = [
    FabricSpec.clos3(4, roll=0),
    FabricSpec.clos3(4, roll=1),
    FabricSpec.clos3(3),
    FabricSpec.xgft((4, 4, 4), (1, 4, 4)),
    FabricSpec.fat_tree(4, taper=2),
    FabricSpec.xgft((2, 2, 2, 2), (1, 2, 2, 2)),   # 4 levels, H_MAX=8
    FabricSpec.xgft((4, 4), (2, 3)),               # multi-rail hosts
    FabricSpec.dragonfly(a=4, p=2, h=2),
    FabricSpec.dragonfly(a=2, p=2, h=1, groups=3),
]


@pytest.mark.parametrize("fab", FAMILIES, ids=lambda f: f.name)
def test_route_table_valid(fab):
    """Every family's full table passes the structural checker."""
    validate_table(fab.build(), fab.route_table())


def test_clos_table_matches_closed_form():
    """The CLOS table builder is the closed form, memoised."""
    topo = make_paper_clos()
    pairs = [(s, d) for s in range(0, 64, 5) for d in range(2, 64, 9)
             if s != d]
    for roll in (0, 1):
        table = clos_route_table(4, roll=roll)
        np.testing.assert_array_equal(
            table.routes_for_pairs(pairs),
            build_flow_routes(topo, pairs, arity=4, roll=roll))


def test_xgft_dmodk_balances_every_up_stage():
    """All-to-all load is EXACTLY equal within each up stage."""
    for fab_m, fab_w in [((4, 4, 4), (1, 4, 4)), ((2, 2, 2), (1, 2, 2))]:
        topo, idx = make_xgft(fab_m, fab_w)
        load = xgft_route_table(idx).link_load(topo.n_links)
        for l in range(2, idx.h + 1):
            mn, mx = stage_balance(load, idx.up_stage_ids(l))
            assert mn == mx, (fab_m, l, mn, mx)


def test_dragonfly_global_load_uniform():
    """One global channel per group pair -> identical all-to-all load."""
    topo, idx = make_dragonfly(a=2, p=2, h=2)
    load = dragonfly_route_table(idx).link_load(topo.n_links)
    mn, mx = stage_balance(load, idx.global_ids())
    assert mn == mx == (idx.a * idx.p) ** 2


def test_dragonfly_paths_at_most_five_links():
    _, idx = make_dragonfly(a=4, p=2, h=2)
    table = dragonfly_route_table(idx)
    assert table.hops.max() == 5
    assert table.h_max == 5


def test_link_load_masks_by_hop_count_at_mixed_depths():
    """Regression: padding slots beyond ``hops[s, d]`` must never be
    counted, even when they alias a real link id.

    With unequal path lengths in one table the padded tail is only
    *conventionally* PAD; a builder (or a multi-path gather) may leave
    any sentinel there.  ``link_load`` used to scan for the -1 sentinel
    instead of masking by hop count, silently inflating whichever link
    the stale slots named."""
    from repro.net.routing import RouteTable
    table = FabricSpec.dragonfly(a=2, p=2, h=1, groups=3).route_table()
    n_links = int(table.paths.max()) + 1
    want = table.link_load(n_links)
    assert table.hops.min(initial=7, where=table.hops > 0) < table.h_max
    # poison every slot past the hop count with a real link id (0)
    poison = table.paths.copy()
    mask = np.arange(table.h_max)[None, None, :] >= table.hops[..., None]
    poison[mask] = 0
    got = RouteTable(paths=poison, hops=table.hops).link_load(n_links)
    np.testing.assert_array_equal(got, want)
    # pairs path goes through the same mask
    pairs = [(0, 5), (0, 1), (3, 11)]
    np.testing.assert_array_equal(
        RouteTable(paths=poison, hops=table.hops).link_load(n_links, pairs),
        table.link_load(n_links, pairs))


def test_routes_for_pairs_bounds_checked():
    table = FabricSpec.dragonfly(a=2, p=1, h=1).route_table()
    with pytest.raises(ValueError):
        table.routes_for_pairs([(0, table.n_nodes)])


def test_fabric_cache_shares_table():
    f = FabricSpec.fat_tree(4, taper=2)
    assert f.route_table() is FabricSpec.fat_tree(4, taper=2).route_table()
    assert hash(f) == hash(FabricSpec.fat_tree(4, taper=2))


# ---------------------------------------------------------------------------
# acceptance: fabrics through the one-jit Sweep, bitwise vs run()
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fabric_sweep():
    dfly = FabricSpec.dragonfly(a=2, p=2, h=1)          # 12 hosts
    ft = FabricSpec.fat_tree(4, taper=2)                # 64 hosts, 2:1
    specs = {
        "dfly": ScenarioSpec.incast(4, dst=0, victim=None, fabric=dfly,
                                    label="dfly"),
        "ft": ScenarioSpec.incast(6, dst=16, fabric=ft, label="ft"),
    }
    sweep = Sweep.grid(
        configs={s.name: CFG.replace(scheme=s) for s in CCScheme},
        scenarios=specs)
    return specs, sweep.run(n_steps=1200)


@pytest.mark.parametrize("scheme", list(CCScheme))
@pytest.mark.parametrize("fab", ["dfly", "ft"])
def test_fabric_sweep_matches_run(fabric_sweep, scheme, fab):
    """Dragonfly + 2:1 fat-tree x all three schemes in ONE launch,
    bit-identical to per-point run()."""
    specs, res = fabric_sweep
    c = CFG.replace(scheme=scheme)
    ri = run(specs[fab].build(c), c, n_steps=1200)
    rs = res[f"{scheme.name}/{fab}"]
    for field in ("delivered", "rate", "inst_thr", "max_q", "marked",
                  "cnp"):
        np.testing.assert_array_equal(
            getattr(rs, field), getattr(ri, field), err_msg=field)


def test_deep_xgft_pads_against_clos():
    """H_MAX=8 XGFT and H_MAX=6 CLOS stack into one sweep."""
    deep = FabricSpec.xgft((2, 2, 2, 2), (1, 2, 2, 2))
    res = Sweep.grid(
        configs=CFG,
        scenarios={"deep": ScenarioSpec.permutation(6, fabric=deep,
                                                    label="deep"),
                   "clos": ScenarioSpec.paper_incast(roll=0)}
    ).run(n_steps=600)
    assert res["deep"].delivered.shape[1] == 6
    assert res["clos"].delivered.shape[1] == 5
    scn = ScenarioSpec.permutation(6, fabric=deep).build(CFG)
    assert scn.routes.shape[1] == 8          # variable-hop route tensors
    assert (scn.hops <= 8).all() and (scn.hops >= 2).all()


def test_fabric_spec_in_scenario_spec_is_hashable():
    s1 = ScenarioSpec.incast(4, fabric=FabricSpec.dragonfly())
    s2 = ScenarioSpec.incast(4, fabric=FabricSpec.dragonfly())
    assert s1 == s2 and hash(s1) == hash(s2)


# ---------------------------------------------------------------------------
# per-link capacity heterogeneity (FabricSpec.with_rates)
# ---------------------------------------------------------------------------

def test_with_rates_scales_only_named_classes():
    ft = FabricSpec.fat_tree(4, taper=1)
    fast = ft.with_rates(up2=4.0, dn2=4.0)
    t0, t1 = ft.build(), fast.build()
    _, idx = make_fat_tree(4, taper=1)
    up2 = idx.up_stage_ids(2)
    np.testing.assert_array_equal(t1.link_capacity[up2],
                                  4.0 * t0.link_capacity[up2])
    others = np.setdiff1d(np.arange(t0.n_links),
                          np.concatenate([up2, np.arange(
                              idx.dn_base(2),
                              idx.dn_base(2) + idx.n_level(2) * idx.m[1])]))
    np.testing.assert_array_equal(t1.link_capacity[others],
                                  t0.link_capacity[others])
    # routing is pure structure: the scaled spec shares the route caches
    assert fast.route_table() is ft.route_table()
    # scales compose multiplicatively across with_rates calls
    assert ft.with_rates(up2=2.0).with_rates(up2=2.0) == \
        ft.with_rates(up2=4.0)
    with pytest.raises(ValueError, match="unknown link class"):
        FabricSpec.dragonfly(2, 2, 2).with_rates(up7=2.0).build()


def test_uniform_fabrics_stay_bitwise_identical():
    """rate_scales=() must not perturb a single bit of an existing
    build or simulation (the satellite's compatibility contract)."""
    ft = FabricSpec.fat_tree(4, taper=2)
    assert ft.with_rates() == ft
    spec = ScenarioSpec.incast(6, dst=16, fabric=ft, label="ft")
    a = run(spec.build(CFG), CFG, n_steps=600)
    b = run(ScenarioSpec.incast(6, dst=16, fabric=ft.with_rates(),
                                label="ft").build(CFG), CFG, n_steps=600)
    for field in ("delivered", "rate", "max_q", "marked", "cnp"):
        np.testing.assert_array_equal(getattr(a, field),
                                      getattr(b, field), err_msg=field)


def test_tapered_uplinks_congest_where_capacity_shrank():
    """The tapered-uplink example: halving leaf uplink rates on the
    full fat tree must strictly slow an uplink-crossing permutation
    (delivered bytes drop) while a same-leaf flow is untouched —
    capacity heterogeneity reaches the fluid loop end to end."""
    ft = FabricSpec.fat_tree(4, taper=1)
    slow = ft.with_rates(up2=0.5)            # leaf uplinks at half rate
    # 8 cross-leaf pairs, all forced through leaf uplinks
    pairs = [(i, 32 + i) for i in range(8)]
    spec = lambda fab: ScenarioSpec.flows(
        pairs, fabric=fab, t_start=0.0, t_stop=1.0e-3, label="x")
    uni = run(spec(ft).build(CFG), CFG, n_steps=1500)
    tap = run(spec(slow).build(CFG), CFG, n_steps=1500)
    d_uni = float(np.asarray(uni.final.delivered).sum())
    d_tap = float(np.asarray(tap.final.delivered).sum())
    assert d_tap < 0.75 * d_uni, (d_tap, d_uni)
    # capacities thread into the scenario tensors themselves
    scn = spec(slow).build(CFG)
    assert set(np.unique(scn.capacity)) == \
        {np.float32(0.5 * CFG.link.line_rate),
         np.float32(CFG.link.line_rate)}
