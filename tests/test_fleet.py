"""repro.fleet: the pod-scale sweep fabric's contracts.

The acceptance bar (ISSUE 10): a fleet-executed sweep — threaded
backend, >= 3 ragged shards, async trace streaming, one induced worker
failure and one checkpoint/resume cycle — must be **bitwise identical**
to the uninterrupted single-host ``Sweep.run()`` over every trace field
and the final state, while the per-signature compile count stays at
one.  The multi-process leg runs the same plan through the
``jax.distributed`` backend in a 2-process subprocess job (pattern of
``tests/test_sharded_sweep.py``).
"""

import os
import socket
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import CCScheme, PAPER_CONFIG, ScenarioSpec, Sweep
from repro.core.experiments import SWEEP_EXEC_CACHE
from repro.core.serialize import _SIM_TRACE_FIELDS
from repro.fleet import (Abandoned, DistributedBackend, Done, FleetConfig,
                         FleetError, FleetJournal, FleetRunner,
                         PreemptedError, Retried, ThreadBackend,
                         WorkerLost, plan_sweep, run_fleet, stream_sweep)

N_STEPS, TRACE_EVERY = 400, 50


def _ragged_sweep():
    """Mixed flow counts: the planner must balance, stealers steal."""
    return Sweep.grid(
        configs={s.name: PAPER_CONFIG.replace(scheme=s)
                 for s in CCScheme},
        scenarios={"i2": ScenarioSpec.incast(2, victim=False),
                   "i6": ScenarioSpec.incast(6, victim=False),
                   "hol": ScenarioSpec.paper_incast(roll=0)})


@pytest.fixture(scope="module")
def sweep():
    return _ragged_sweep()


@pytest.fixture(scope="module")
def ref(sweep):
    return sweep.run(n_steps=N_STEPS, trace_every=TRACE_EVERY)


def assert_bitwise(res, ref):
    """Every trace field, the time base and the full final-state tree."""
    assert [p.name for p in res.points] == [p.name for p in ref.points]
    np.testing.assert_array_equal(res.times, ref.times)
    for f in _SIM_TRACE_FIELDS:
        a, b = getattr(res.traces, f), getattr(ref.traces, f)
        assert (a is None) == (b is None), f
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f)
    la = jax.tree_util.tree_flatten_with_path(res.final)[0]
    lb = jax.tree_util.tree_flatten_with_path(ref.final)[0]
    assert len(la) == len(lb)
    for (pa, ga), (_, gb) in zip(la, lb):
        assert np.array_equal(np.asarray(ga), np.asarray(gb)), \
            "final" + jax.tree_util.keystr(pa)


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------


def test_plan_deterministic_and_content_addressed(sweep):
    p1 = plan_sweep(sweep, N_STEPS, TRACE_EVERY, n_shards=4)
    p2 = plan_sweep(sweep, N_STEPS, TRACE_EVERY, n_shards=4)
    assert p1.digest == p2.digest
    assert [s.digest for s in p1.shards] == [s.digest for s in p2.shards]
    # content addressing: different work -> different digests
    p3 = plan_sweep(sweep, N_STEPS * 2, TRACE_EVERY, n_shards=4)
    assert p3.digest != p1.digest
    assert all(s3.digest != s1.digest
               for s1, s3 in zip(p1.shards, p3.shards))
    # every point covered exactly once
    seen = sorted(i for s in p1.shards for i in s.indices)
    assert seen == list(range(len(sweep.points)))


def test_plan_envelope_is_one_bucket(sweep):
    plan = plan_sweep(sweep, N_STEPS, TRACE_EVERY, n_shards=4)
    assert len(plan.buckets) == 1
    assert len(plan.shards) >= 3
    b = plan.buckets[0]
    # the envelope covers the raggedest point
    assert b.n_flows >= max(p.scenario.routes.shape[0]
                            for p in sweep.points)
    # ragged costs: LPT must not leave one shard with everything
    costs = [s.cost for s in plan.shards]
    assert max(costs) < plan.total_cost


def test_plan_fabric_bucketing(sweep):
    plan = plan_sweep(sweep, N_STEPS, TRACE_EVERY, n_shards=4,
                      bucket_by="fabric")
    assert len(plan.buckets) >= 1
    for s in plan.shards:
        b = plan.buckets[s.bucket]
        for i in s.indices:
            assert sweep.points[i].scenario.routes.shape[0] <= b.n_flows


def test_shard_sweep_and_kwargs_pin_the_envelope(sweep):
    plan = plan_sweep(sweep, N_STEPS, TRACE_EVERY, n_shards=4)
    b = plan.buckets[0]
    for s in plan.shards:
        sub = plan.shard_sweep(s)
        for p in sub.points:
            assert p.scenario.routes.shape == (b.n_flows, b.n_hops)
        kw = plan.run_kwargs(s)
        assert kw["pad_runs_to"] == b.width
        assert kw["min_switches"] == b.n_switches
        assert kw["min_delay_slots"] == b.delay_slots


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------


def test_stream_sweep_bitwise(sweep, ref):
    res = stream_sweep(sweep, n_steps=N_STEPS, trace_every=TRACE_EVERY)
    assert_bitwise(res, ref)


def test_stream_sweep_spill_dir(tmp_path, sweep, ref):
    res = stream_sweep(sweep, n_steps=N_STEPS, trace_every=TRACE_EVERY,
                       spill_dir=str(tmp_path / "spill"),
                       buffer_windows=1)
    assert_bitwise(res, ref)
    assert (tmp_path / "spill" / "delivered.npy").exists()


# ---------------------------------------------------------------------------
# the acceptance run
# ---------------------------------------------------------------------------


def test_fleet_acceptance_bitwise(tmp_path, sweep, ref):
    """Threaded backend + ragged shards + streaming + one induced
    worker failure + one preempt/resume cycle == one launch, bitwise,
    one compile per signature."""
    plan = plan_sweep(sweep, N_STEPS, TRACE_EVERY, n_shards=4)
    assert len(plan.shards) >= 3
    journal = str(tmp_path / "journal")

    killed = []

    def fault(shard, attempt, worker):
        if shard.index == 1 and not killed:
            killed.append(worker)
            raise WorkerLost(f"chaos: worker {worker} dies")

    # phase 1: worker loss + preemption after 2 commits
    with pytest.raises(PreemptedError):
        FleetRunner(plan, FleetConfig(n_workers=3, preempt_after=2),
                    journal=journal, fault_hook=fault).run()
    assert killed, "the chaos hook never fired"
    committed = len(FleetJournal(journal).completed())
    assert committed >= 2

    # phase 2: resume — journaled shards load with zero recompute
    misses0 = SWEEP_EXEC_CACHE.stats().misses
    out = FleetRunner(plan, FleetConfig(n_workers=3),
                      journal=journal).run()
    assert out.stats.resumed == committed
    assert out.stats.abandoned == 0
    # one signature bucket -> at most one compile across BOTH phases'
    # remaining shards (zero here: phase 1 already built it)
    assert SWEEP_EXEC_CACHE.stats().misses - misses0 <= 1
    assert out.stats.compiles <= 1
    assert_bitwise(out.result, ref)
    # resumed shards really came from the journal
    resumed = [o for o in out.outcomes.values()
               if isinstance(o, Done) and o.resumed]
    assert len(resumed) == committed


def test_fleet_unjournaled_run_bitwise(sweep, ref):
    out = run_fleet(sweep, N_STEPS, TRACE_EVERY,
                    config=FleetConfig(n_workers=2, n_shards=3,
                                       stream=False))
    assert_bitwise(out.result, ref)
    assert all(isinstance(o, Done) for o in out.outcomes.values())


def test_fleet_resume_zero_recompute(tmp_path, sweep, ref):
    journal = str(tmp_path / "journal")
    run_fleet(sweep, N_STEPS, TRACE_EVERY,
              config=FleetConfig(n_workers=2, n_shards=3),
              journal=journal)
    misses0 = SWEEP_EXEC_CACHE.stats().misses
    out = run_fleet(sweep, N_STEPS, TRACE_EVERY,
                    config=FleetConfig(n_workers=2, n_shards=3),
                    journal=journal)
    assert out.stats.executed == 0
    assert out.stats.resumed == len(out.plan.shards)
    assert SWEEP_EXEC_CACHE.stats().misses == misses0
    assert_bitwise(out.result, ref)


# ---------------------------------------------------------------------------
# scheduler semantics
# ---------------------------------------------------------------------------


def test_work_stealing_levels_ragged_shards(sweep, ref):
    """2 workers, 4 shards dealt LPT: the finisher steals the tail."""
    out = run_fleet(sweep, N_STEPS, TRACE_EVERY,
                    config=FleetConfig(n_workers=2, n_shards=4))
    assert_bitwise(out.result, ref)
    workers = {o.worker for o in out.outcomes.values()
               if isinstance(o, (Done, Retried))}
    assert len(workers) == 2, "one worker served the whole fleet"


def test_worker_lost_requeues_for_survivors(sweep, ref):
    killed = []

    def fault(shard, attempt, worker):
        if shard.index == 0 and not killed:
            killed.append(worker)
            raise WorkerLost("chaos")

    out = run_fleet(sweep, N_STEPS, TRACE_EVERY,
                    config=FleetConfig(n_workers=2, n_shards=3),
                    fault_hook=fault)
    assert killed
    assert_bitwise(out.result, ref)
    o = out.outcomes[0]
    assert isinstance(o, Retried) and o.worker != killed[0]


def test_retry_then_succeed(sweep, ref):
    attempts = []

    def fault(shard, attempt, worker):
        if shard.index == 0 and attempt == 1:
            attempts.append(attempt)
            raise RuntimeError("transient")

    out = run_fleet(sweep, N_STEPS, TRACE_EVERY,
                    config=FleetConfig(n_workers=2, n_shards=3,
                                       backoff_s=0.0),
                    fault_hook=fault)
    assert attempts
    o = out.outcomes[0]
    assert isinstance(o, Retried) and o.attempts == 2 and o.errors
    assert out.stats.retries == 1
    assert_bitwise(out.result, ref)


def test_abandoned_is_explicit_and_strict_raises(sweep):
    def fault(shard, attempt, worker):
        if shard.index == 0:
            raise RuntimeError("permanent")

    with pytest.raises(FleetError, match="abandoned"):
        run_fleet(sweep, N_STEPS, TRACE_EVERY,
                  config=FleetConfig(n_workers=2, n_shards=3,
                                     max_retries=1, backoff_s=0.0),
                  fault_hook=fault)

    out = run_fleet(sweep, N_STEPS, TRACE_EVERY,
                    config=FleetConfig(n_workers=2, n_shards=3,
                                       max_retries=1, backoff_s=0.0,
                                       strict=False),
                    fault_hook=fault)
    bad = out.abandoned
    assert len(bad) == 1 and bad[0].shard == 0
    assert bad[0].attempts == 2 and bad[0].errors
    # the merged result still covers every OTHER shard's points
    covered = {n for s in out.plan.shards if s.index != 0
               for n in s.names}
    assert {p.name for p in out.result.points} == covered


def test_all_workers_lost_abandons_remainder(sweep):
    def fault(shard, attempt, worker):
        raise WorkerLost("everyone dies")

    out = run_fleet(sweep, N_STEPS, TRACE_EVERY,
                    config=FleetConfig(n_workers=2, n_shards=3,
                                       strict=False),
                    fault_hook=fault)
    assert out.result is None
    assert all(isinstance(o, Abandoned) for o in out.outcomes.values())
    assert len(out.outcomes) == len(out.plan.shards)


def test_journal_rejects_foreign_plan(tmp_path, sweep):
    plan = plan_sweep(sweep, N_STEPS, TRACE_EVERY, n_shards=3)
    other = plan_sweep(sweep, N_STEPS * 2, TRACE_EVERY, n_shards=3)
    jr = FleetJournal(str(tmp_path))
    jr.bind(plan)
    with pytest.raises(ValueError, match="bound to plan"):
        jr.bind(other)


def test_journal_claims_are_exclusive(tmp_path):
    jr = FleetJournal(str(tmp_path))
    assert jr.claim("d1", "a")
    assert not jr.claim("d1", "b")
    assert jr.claim_age("d1") is not None
    jr.steal_claim("d1", "b")       # stale takeover is an overwrite
    jr.release("d1")
    assert jr.claim_age("d1") is None
    assert jr.failures("d1") == 0
    assert jr.record_failure("d1", "boom") == 1
    assert jr.record_failure("d1", "boom again") == 2
    assert jr.failures("d1") == 2


# ---------------------------------------------------------------------------
# multi-process (jax.distributed) leg
# ---------------------------------------------------------------------------

_DIST_CHILD = """
import sys
import jax
import numpy as np

port, pid, journal = sys.argv[1], int(sys.argv[2]), sys.argv[3]
jax.distributed.initialize(coordinator_address=f"127.0.0.1:{port}",
                           num_processes=2, process_id=pid)
assert jax.process_count() == 2

from repro.core import CCScheme, PAPER_CONFIG, ScenarioSpec, Sweep
from repro.core.serialize import _SIM_TRACE_FIELDS
from repro.fleet import (DistributedBackend, FleetConfig, FleetJournal,
                         FleetRunner, plan_sweep)

sweep = Sweep.grid(
    configs={s.name: PAPER_CONFIG.replace(scheme=s) for s in CCScheme},
    scenarios={"i2": ScenarioSpec.incast(2, victim=False),
               "hol": ScenarioSpec.paper_incast(roll=0)})
plan = plan_sweep(sweep, 300, 50, n_shards=3)
jr = FleetJournal(journal)
out = FleetRunner(plan, FleetConfig(claim_timeout_s=60.0,
                                    timeout_s=600.0),
                  backend=DistributedBackend(jr), journal=jr).run()
if pid == 0:
    assert out.stats.abandoned == 0, out.outcomes
    ref = sweep.run(n_steps=300, trace_every=50)
    res = out.result
    assert [p.name for p in res.points] == [p.name for p in ref.points]
    np.testing.assert_array_equal(res.times, ref.times)
    for f in _SIM_TRACE_FIELDS:
        a, b = getattr(res.traces, f), getattr(ref.traces, f)
        assert (a is None) == (b is None), f
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f)
    la = jax.tree_util.tree_flatten_with_path(res.final)[0]
    lb = jax.tree_util.tree_flatten_with_path(ref.final)[0]
    for (pa, ga), (_, gb) in zip(la, lb):
        assert np.array_equal(np.asarray(ga), np.asarray(gb)), \\
            "final" + jax.tree_util.keystr(pa)
    print("DIST_FLEET_BITWISE_OK")
"""


def _child_env() -> dict:
    env = dict(os.environ)
    src = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "src")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH")) if p)
    return env


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def test_distributed_fleet_two_processes_bitwise(tmp_path):
    """2 jax.distributed processes level one journal-claimed queue; the
    coordinator's merged result is bitwise the single-host launch."""
    port = _free_port()
    journal = str(tmp_path / "journal")
    env = _child_env()
    procs = [subprocess.Popen(
        [sys.executable, "-c", _DIST_CHILD, str(port), str(pid), journal],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        text=True) for pid in (0, 1)]
    outs = [p.communicate(timeout=1200) for p in procs]
    for p, (so, se) in zip(procs, outs):
        assert p.returncode == 0, f"proc exited {p.returncode}:\n" \
            f"{se[-3000:]}"
    assert "DIST_FLEET_BITWISE_OK" in outs[0][0]


def test_coordinator_reclaims_dead_workers_claim(tmp_path, sweep, ref):
    """A worker that died mid-shard leaves a dangling claim file (no
    release, no result).  The coordinator must steal the stale claim
    and run the shard itself — points are delayed, never dropped.
    Single-process: ``process_info`` falls back to (0, 1), so the same
    DistributedBackend code runs as the coordinator."""
    plan = plan_sweep(sweep, N_STEPS, TRACE_EVERY, n_shards=3)
    jr = FleetJournal(str(tmp_path / "journal"))
    jr.bind(plan)
    # fake the dead worker: claim shard 0's digest, backdate the claim
    # far past claim_timeout_s
    victim = plan.shards[0]
    assert jr.claim(victim.digest, "dead-proc")
    stale = os.path.join(jr.claims_dir, victim.digest)
    os.utime(stale, (1.0, 1.0))
    out = FleetRunner(plan, FleetConfig(claim_timeout_s=30.0,
                                        timeout_s=300.0, poll_s=0.05),
                      backend=DistributedBackend(jr), journal=jr).run()
    assert out.stats.abandoned == 0
    assert out.stats.stolen >= 1              # the reclaim happened
    assert_bitwise(out.result, ref)
