"""repro.tune test suite: the soft-model contract and the tuners.

The load-bearing guarantees:

  * **tau = 0 is bitwise hard.**  ``Sweep.run(temperature=0)`` must be
    byte-identical to the default run — the soft relaxations live
    behind ``select(tau, soft, hard)`` with the hard branch verbatim.
  * **tau -> 0 converges.**  On the golden 18-point grid the soft
    model's error against the hard model shrinks monotonically as the
    temperature anneals, hitting exactly zero at tau = 0.
  * **jax.grad is a derivative.**  For every registered objective, the
    gradient through the full dt-scan matches central finite
    differences at random parameter points (direction via cosine
    similarity; the soft model is still piecewise-smooth across
    un-softened transfer plumbing, so FD secants and AD tangents agree
    approximately, not to machine precision).
  * **checkpoint resume is bit-exact.**  A killed-and-resumed tuner
    replays the identical trajectory (``repro.ckpt``; host f64 state,
    per-iteration ``default_rng([seed, it])``).
  * **autotune's verdict is hard.**  The improvement it reports is
    measured on the unsmoothed model via a real ``Sweep`` launch.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp                                    # noqa: E402

from repro.core import (CCScheme, PAPER_CONFIG, ScenarioSpec,  # noqa: E402
                        Sweep)
from repro.core.params import DCQCNParams                  # noqa: E402
from repro.tune import objectives                          # noqa: E402
from repro.tune.optimizers import (BOTuner, ESTuner,       # noqa: E402
                                   Evaluator, GradTuner, ParamBox,
                                   TunableParam, TuneProblem, box_for,
                                   dcqcn_box)
from repro.tune.pareto import autotune, pareto_front       # noqa: E402

# Short-timing incast: flows active 0.1 -> 1.1 ms, so sub-1000-step
# rollouts have real deliveries (the default 1 ms start would make
# every objective degenerate at these horizons).
FAST = dict(t_start=1e-4, t_stop=1.1e-3)
N_STEPS = 900
TRACE_EVERY = 45

DCQCN = PAPER_CONFIG.replace(scheme=CCScheme.DCQCN)


def _small_sweep() -> Sweep:
    return Sweep.grid(
        configs={s.name: PAPER_CONFIG.replace(scheme=s)
                 for s in (CCScheme.DCQCN, CCScheme.DCQCN_REV)},
        scenarios={"in4": ScenarioSpec.incast(4, **FAST)})


def _delivered(res) -> np.ndarray:
    return np.asarray([res[name].final.delivered.sum()
                       for name in sorted(res.summary())])


# ---------------------------------------------------------------------------
# the soft-model contract
# ---------------------------------------------------------------------------


def test_temperature_zero_is_bitwise_hard():
    sweep = _small_sweep()
    hard = sweep.run(n_steps=N_STEPS)
    tau0 = sweep.run(n_steps=N_STEPS, temperature=0.0)
    for name in hard.summary():
        a, b = hard[name], tau0[name]
        assert np.array_equal(np.asarray(a.final.delivered),
                              np.asarray(b.final.delivered)), name
        assert np.array_equal(np.asarray(a.final.rate),
                              np.asarray(b.final.rate)), name
        assert np.array_equal(np.asarray(a.ctrl),
                              np.asarray(b.ctrl)), name


def test_temperature_actually_smooths():
    """tau > 0 must change the dynamics — a soft run that equals the
    hard one means the temperature never reached the gates."""
    sweep = _small_sweep()
    hard = _delivered(sweep.run(n_steps=N_STEPS))
    soft = _delivered(sweep.run(n_steps=N_STEPS, temperature=0.3))
    assert not np.allclose(hard, soft, rtol=1e-6)


def test_annealing_converges_on_golden_grid():
    """The golden 18-point grid (3 schemes x 2 fabrics x 3 routings):
    soft-vs-hard delivered-bytes error decreases as tau anneals and is
    exactly zero at tau = 0."""
    from test_golden import _grid
    sweep = _grid()
    ref = _delivered(sweep.run(n_steps=300))
    errs = {}
    for tau in (0.5, 0.2, 0.08, 0.0):
        d = _delivered(sweep.run(n_steps=300, temperature=tau))
        errs[tau] = float(np.mean(np.abs(d - ref) / (np.abs(ref) + 1.0)))
    assert errs[0.0] == 0.0
    assert errs[0.08] < errs[0.5]
    # weak per-stage monotonicity (10% slack for non-uniform sites)
    assert errs[0.2] <= errs[0.5] * 1.10 + 1e-12
    assert errs[0.08] <= errs[0.2] * 1.10 + 1e-12


def test_sweep_rejects_soft_kernels():
    with pytest.raises(ValueError, match="hard dynamics only"):
        _small_sweep().run(n_steps=64, temperature=0.1, use_kernels=True)


# ---------------------------------------------------------------------------
# gradients vs finite differences
# ---------------------------------------------------------------------------


def _soft_values(ev: Evaluator, thetas: np.ndarray,
                 tau: float) -> np.ndarray:
    """[B] soft objective values in ONE vmapped launch (FD probe)."""
    from repro.core.fluid import fluid_step
    from repro.core.simulator import decimating_scan

    def loss(theta):
        par = ev.box.apply(ev.par0, theta)
        par = par._replace(temperature=jnp.asarray(tau, jnp.float32))
        step = lambda s: fluid_step(s, ev.sd, par, dt=ev.dt,
                                    n_switches=ev.n_sw,
                                    reduce="fused", dense_rows=0)
        final, tr = decimating_scan(step, ev.st0, ev.n_samples, ev.k,
                                    ev.dt)
        return ev.obj_fn(final, tr, ev.ctx)

    return np.asarray(jax.jit(jax.vmap(loss))(
        jnp.asarray(thetas, jnp.float32)), np.float64)


@pytest.mark.parametrize("objective", sorted(objectives.OBJECTIVES))
def test_grad_matches_central_fd(objective):
    """AD through the dt-scan vs central differences at 5 random
    thetas.  Gates are directional (cosine) plus a loose magnitude
    band, applied only where BOTH estimators see a real gradient: the
    un-softened transfer plumbing keeps the model piecewise-smooth, so
    at near-flat points FD measures kink secants (O(1e-3)) while AD
    correctly reports ~0 — those points are gated on AD flatness
    instead."""
    tau, h, n_points = 0.25, 0.05, 5
    ev = Evaluator(TuneProblem(
        DCQCN, ScenarioSpec.incast(4), objective=objective,
        n_steps=1500, trace_every=50))
    d = ev.box.d
    rng = np.random.default_rng(7)
    pts = rng.standard_normal((n_points, d))

    # one vmapped launch for every (point, coordinate, +/-) probe
    probes = np.stack([p + s * h * np.eye(d)[i]
                       for p in pts for i in range(d) for s in (+1, -1)])
    vals = _soft_values(ev, probes, tau).reshape(n_points, d, 2)
    fd = (vals[:, :, 0] - vals[:, :, 1]) / (2 * h)

    cosines, flat_ad = [], []
    for p, f in zip(pts, fd):
        _, g = ev.value_and_grad(p, tau)
        assert np.all(np.isfinite(g)), (objective, p, g)
        ng, nf = np.linalg.norm(g), np.linalg.norm(f)
        if min(ng, nf) < 1e-3:
            flat_ad.append(ng)            # kink-noise regime for FD
            continue
        cosines.append(float(np.dot(g, f) / (ng * nf)))
        assert 0.05 < ng / nf < 20.0, (objective, p, ng, nf)
    if cosines:
        assert np.mean(cosines) > 0.85, (objective, cosines)
        assert min(cosines) > 0.6, (objective, cosines)
    else:
        # genuinely flat objective at every probe: AD must agree
        assert max(flat_ad) < 1e-2, (objective, flat_ad)


# ---------------------------------------------------------------------------
# DCQCNParams construction-time validation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad", [
    dict(kmin=20e3, kmax=10e3),
    dict(pmax=0.0), dict(pmax=1.5), dict(pmax=-0.1),
    dict(g=0.0), dict(g=1.5),
    dict(rai=-1.0), dict(rhai=-1.0), dict(timer_T=-1e-6),
    dict(byte_counter_B=-1.0), dict(min_rate=-1.0), dict(cnp_window=-1.0),
    dict(rate_decrease_factor=-0.1), dict(rate_decrease_factor=1.5),
])
def test_dcqcn_params_rejects(bad):
    with pytest.raises(ValueError):
        DCQCNParams(**bad)


def test_dcqcn_params_accepts_edges():
    DCQCNParams(pmax=1.0, g=1.0, rate_decrease_factor=0.0)
    DCQCNParams(kmin=10e3, kmax=10e3)          # step marking


# ---------------------------------------------------------------------------
# ParamBox
# ---------------------------------------------------------------------------


def test_param_box_encode_roundtrip():
    box = dcqcn_box()
    spec = DCQCN.to_spec()
    theta = box.encode(spec)
    vals = box.values(theta, xp=np)
    want = {"V": spec.dcqcn.kmin, "rdf": spec.dcqcn.rate_decrease_factor,
            "g": spec.dcqcn.g, "rai": spec.dcqcn.rai}
    for name, v in zip(box.names, vals):
        np.testing.assert_allclose(v, want[name], rtol=1e-4)


def test_param_box_host_and_trace_values_agree():
    box = dcqcn_box()
    theta = np.asarray([0.7, -1.2, 0.3, 2.0])
    np.testing.assert_allclose(
        box.values(theta.astype(np.float32), xp=np),
        np.asarray(box.values(jnp.asarray(theta, jnp.float32))),
        rtol=1e-6)


def test_param_box_to_spec_multi_path_validation():
    """Regression: the V knob writes (kmin, kmax) together.  Writing
    them one at a time used to trip the kmin <= kmax validator on the
    transient state whenever V moved past the old kmax."""
    box = dcqcn_box()
    spec = DCQCN.to_spec()
    for t in (+6.0, -6.0):                 # push V to both box edges
        theta = box.encode(spec)
        theta[list(box.names).index("V")] = t
        out = box.to_spec(spec, theta)
        assert out.dcqcn.kmin == out.dcqcn.kmax
    hi = box.to_spec(spec, np.full(box.d, 6.0))
    assert hi.dcqcn.kmin > spec.dcqcn.kmax


def test_param_box_consistency_check_fires():
    """A knob whose spec path and StepParams leaf disagree must raise,
    not silently tune a different constant than it reports."""
    box = ParamBox((TunableParam(
        "wrong", ("react.rp_g",), ("dcqcn.rai",), 1e6, 2e8, log=True),))
    with pytest.raises(AssertionError, match="box inconsistency"):
        box.to_spec(DCQCN.to_spec(), np.zeros(1))


def test_box_for_dispatch():
    assert box_for(DCQCN).names == dcqcn_box().names
    assert "thresh" in box_for(PAPER_CONFIG).names
    swift = PAPER_CONFIG.to_spec().replace(reaction="swift")
    with pytest.raises(ValueError, match="no default ParamBox"):
        box_for(swift)


# ---------------------------------------------------------------------------
# checkpointed tuner loops (bit-exact resume)
# ---------------------------------------------------------------------------


def _tiny_problem(objective="default"):
    return TuneProblem(DCQCN, ScenarioSpec.incast(3, **FAST),
                       objective=objective, n_steps=N_STEPS,
                       trace_every=TRACE_EVERY)


def test_grad_tuner_resume_bit_exact(tmp_path):
    ev = Evaluator(_tiny_problem())
    full = GradTuner(iters=4, lr=0.2, temperature=0.3).run(ev, seed=0)
    d = str(tmp_path / "grad")
    GradTuner(iters=2, lr=0.2, temperature=0.3).run(
        ev, seed=0, ckpt_dir=d, ckpt_every=2)
    resumed = GradTuner(iters=4, lr=0.2, temperature=0.3).run(
        ev, seed=0, ckpt_dir=d)
    assert np.array_equal(full.theta, resumed.theta)
    assert np.array_equal(full.value, resumed.value)


def test_es_tuner_resume_bit_exact(tmp_path):
    ev = Evaluator(_tiny_problem())
    tuner = dict(iters=3, pop=4, sigma=0.3, lr=0.4)
    full = ESTuner(**tuner).run(ev, seed=1)
    d = str(tmp_path / "es")
    ESTuner(**dict(tuner, iters=2)).run(ev, seed=1, ckpt_dir=d,
                                        ckpt_every=2)
    resumed = ESTuner(**tuner).run(ev, seed=1, ckpt_dir=d)
    assert np.array_equal(full.theta, resumed.theta)
    assert np.array_equal(full.value, resumed.value)


def test_bo_tuner_smoke():
    ev = Evaluator(_tiny_problem())
    trace = BOTuner(iters=2, init=3, q=1, cand=32).run(ev, seed=0)
    assert trace.theta.shape[1] == ev.box.d
    assert len(trace.value) >= 5                  # 3 init + 2 x >=1
    assert np.all(np.isfinite(trace.value))
    assert trace.best.shape == (ev.box.d,)


# ---------------------------------------------------------------------------
# objectives + metrics plumbing
# ---------------------------------------------------------------------------


def test_resolve_objective_forms():
    fn, sig = objectives.resolve("goodput")
    assert sig == "name:goodput"
    _, sig = objectives.resolve({"goodput": 1, "jain": 0.5})
    assert sig.startswith("weighted:")
    _, sig = objectives.resolve("default")
    assert sig.startswith("weighted:")
    with pytest.raises(KeyError):
        objectives.resolve("nope")
    with pytest.raises(KeyError):
        objectives.weighted({"nope": 1.0})


def test_summary_carries_tuner_metrics():
    res = _small_sweep().run(n_steps=N_STEPS)
    for name, row in res.summary().items():
        assert 0.0 <= row["jain_index"] <= 1.0, name
        assert row["p99_slowdown"] >= 1.0, name
        assert np.isfinite(row["ctrl_per_mb"]), name
        assert row["ctrl_per_mb"] >= 0.0, name


def test_hard_objective_consistent_with_soft_at_tau0():
    """The device (soft-path) objective at tau = 0 and the host
    hard_objective score the SAME rollout: they must agree closely
    (both are f32 pipelines, not bit-identical reductions)."""
    ev = Evaluator(_tiny_problem())
    theta = ev.box.encode(ev.spec)
    v_soft, _ = ev.value_and_grad(theta, 0.0)
    v_hard = float(ev.hard_values(theta[None])[0])
    np.testing.assert_allclose(v_soft, v_hard, rtol=2e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# pareto + autotune
# ---------------------------------------------------------------------------


def test_pareto_front_basic():
    vals = np.asarray([[1.0, 1.0], [2.0, 0.5], [0.5, 2.0],
                       [0.9, 0.9], [2.0, 0.5]])
    keep = pareto_front(vals)
    assert 3 not in keep                       # dominated by [1, 1]
    assert {0, 1, 2} <= set(keep.tolist())
    assert 4 in keep                           # duplicates both survive
    # mixed senses: column 1 is a cost
    keep = pareto_front(np.asarray([[1.0, 5.0], [1.0, 2.0]]),
                        senses=[1, -1])
    assert keep.tolist() == [1]
    with pytest.raises(ValueError):
        pareto_front(np.zeros(3))


def test_autotune_improves_dcqcn_incast():
    """The PR's acceptance check: GradTuner on the CLOS incast finds
    DCQCN constants whose HARD-model objective strictly beats the
    paper defaults (verdict from an unsmoothed Sweep launch)."""
    res = autotune(DCQCN, ScenarioSpec.incast(8), method="grad",
                   n_steps=3000, trace_every=50, iters=12, lr=0.25,
                   temperature=0.2, seed=0)
    assert res.improved, (res.baseline_value, res.best_value)
    assert res.best_value > res.baseline_value
    assert res.best_metrics["goodput"] > res.baseline_metrics["goodput"]
    assert set(res.best_params) == set(dcqcn_box().names)
    # the winner must be a valid, constructible config
    assert res.best_cfg.dcqcn.kmin == res.best_cfg.dcqcn.kmax
    rec = res.to_record()
    assert rec["improved"] and rec["best_value"] == res.best_value
    import json
    json.dumps(rec)                            # JSON-serialisable


def test_autotune_es_smoke():
    res = autotune(DCQCN, ScenarioSpec.incast(3, **FAST), method="es",
                   n_steps=N_STEPS, trace_every=TRACE_EVERY,
                   iters=2, pop=4, seed=0, max_candidates=4)
    assert res.method == "es"
    assert res.best_value >= res.baseline_value   # argmax includes base
    assert len(res.candidate_values) == len(res.candidates)


def test_autotune_unknown_method():
    with pytest.raises(KeyError, match="unknown method"):
        autotune(DCQCN, ScenarioSpec.incast(3), method="nope")
