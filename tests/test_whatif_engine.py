"""What-if query engine gates: compile-once per structural signature,
bitwise parity with standalone Sweep.run, explicit throttling outcomes,
executable-cache semantics, serving metrics."""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import (CCSpec, ExecutableCache, SWEEP_EXEC_CACHE,
                        ScenarioSpec, Sweep)
from repro.serve.whatif import (AdmissionConfig, AdmissionController,
                                Admitted, CCQueryEngine, EngineConfig,
                                LatencyRecorder, QueueFull, Throttled,
                                TokenBucket, WhatIfQuery, flow_bucket)

N_STEPS = 240

# one flow bucket (8): three workloads x three CC stacks x a param
# variant — the fixed-pod replay mix of the acceptance criteria
SPECS = {"in4": ScenarioSpec.incast(4), "in6": ScenarioSpec.incast(6),
         "in7": ScenarioSpec.incast(7)}
CFGS = {"rev": CCSpec(),
        "dcqcn": CCSpec(marking="cp", notification="np", reaction="rp"),
        "swift": CCSpec(reaction="swift"),
        "rev-tuned": CCSpec().replace(
            rev=dataclasses.replace(CCSpec().rev, erp_settle=0.9))}


def _open_engine(**admission):
    adm = AdmissionConfig(**{"rate": 1e9, "burst": 10_000,
                             "max_queue": 256, **admission})
    return CCQueryEngine(EngineConfig(max_batch=8, admission=adm))


# ---------------------------------------------------------------------------
# the 100-query replay (acceptance gate)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def replay():
    """100 mixed queries over a fixed pod, drained in micro-batches."""
    SWEEP_EXEC_CACHE.clear()
    SWEEP_EXEC_CACHE.reset_stats()
    eng = _open_engine()
    mix = [(cn, sn) for cn in CFGS for sn in SPECS]     # 12 combos
    tickets = {}
    for i in range(100):
        cn, sn = mix[i % len(mix)]
        out = eng.submit(WhatIfQuery(cfg=CFGS[cn], scenario=SPECS[sn],
                                     n_steps=N_STEPS, label=f"{cn}/{sn}"))
        assert isinstance(out, Admitted), out
        tickets[out.ticket] = (cn, sn)
        if (i + 1) % 25 == 0:                # drain in four waves, like
            eng.drain()                      # a service would
    eng.drain()
    return eng, tickets


def test_replay_compiles_exactly_once(replay):
    """All 100 queries share one structural signature (three workloads
    in one flow bucket, params traced) => exactly one executable
    build, everything else cache hits."""
    eng, tickets = replay
    m = eng.metrics()
    assert m["queries"] == 100
    assert m["exec_cache"]["misses"] == 1, m["exec_cache"]
    assert m["exec_cache"]["hits"] == m["batches"] - 1
    assert m["signatures"] == 1
    assert m["compile_s"] > 0


def test_replay_bitwise_matches_standalone_sweep(replay):
    """Every micro-batched answer equals a standalone single-point
    Sweep.run() bit for bit — padding to the batch width and the flow
    bucket is inert."""
    eng, tickets = replay
    solo = {}
    for ticket, (cn, sn) in tickets.items():
        if (cn, sn) not in solo:
            solo[(cn, sn)] = Sweep(
                [("p", CFGS[cn], SPECS[sn])]).run(n_steps=N_STEPS)["p"]
        want, got = solo[(cn, sn)], eng.result(ticket).result
        for f in ("delivered", "rate", "inst_thr", "max_q", "n_paused",
                  "marked", "cnp", "n_nonmin", "times"):
            np.testing.assert_array_equal(
                getattr(got, f), getattr(want, f), err_msg=f"{cn}/{sn}:{f}")
        np.testing.assert_array_equal(np.asarray(got.final.qh),
                                      np.asarray(want.final.qh))
        np.testing.assert_array_equal(np.asarray(got.final.delivered),
                                      np.asarray(want.final.delivered))


def test_identical_queries_identical_results(replay):
    """Replayed duplicates of one (cfg, scenario) point return
    identical arrays (warm path is deterministic)."""
    eng, tickets = replay
    per_combo = {}
    for ticket, key in tickets.items():
        per_combo.setdefault(key, []).append(ticket)
    dup = next(ts for ts in per_combo.values() if len(ts) > 1)
    a, b = (eng.result(t).result for t in dup[:2])
    np.testing.assert_array_equal(a.delivered, b.delivered)
    np.testing.assert_array_equal(a.max_q, b.max_q)


def test_replay_metrics_shape(replay):
    eng, _ = replay
    m = eng.metrics()
    assert {"queries", "batches", "mean_occupancy", "run_s",
            "latency_s", "queue_wait_s", "exec_cache", "compile_s",
            "admission", "queue_depth", "signatures",
            "batch_width"} <= set(m)
    assert m["latency_s"]["count"] == 100
    assert m["latency_s"]["p99"] >= m["latency_s"]["p50"] > 0
    assert 0 < m["mean_occupancy"] <= 1
    assert m["queue_depth"] == 0
    assert m["admission"]["admitted"] == 100
    json.dumps(m)                            # wire-ready


def test_query_result_to_dict_json_ready(replay):
    eng, tickets = replay
    qr = eng.result(next(iter(tickets)))
    d = qr.to_dict()
    json.dumps(d)
    assert d["batch_width"] == 8 and d["summary"]["delivered_mb"] >= 0
    full = qr.to_dict(traces=True)
    json.dumps(full)
    assert "result" in full


# ---------------------------------------------------------------------------
# structural signatures
# ---------------------------------------------------------------------------


def test_flow_bucket():
    assert [flow_bucket(n) for n in (1, 4, 5, 8, 9, 16)] == \
        [4, 4, 8, 8, 16, 16]
    assert flow_bucket(3, minimum=2) == 4


def test_signature_sharing_and_separation():
    eng = _open_engine()

    def sig(**kw):
        q = dict(cfg=CCSpec(), scenario=SPECS["in4"], n_steps=N_STEPS)
        q.update(kw)
        return eng._prepare(WhatIfQuery(**q)).sig

    base = sig()
    assert sig(cfg=CFGS["swift"]) == base             # CC stack: traced
    assert sig(scenario=SPECS["in7"]) == base         # same flow bucket
    assert sig(scenario=ScenarioSpec.permutation(16)) != base   # bucket
    assert sig(trace_every=2) != base                 # trace cadence
    k2 = sig(scenario=dataclasses.replace(SPECS["in4"], n_paths=2))
    assert k2 != base and k2.paths == 2               # K candidate paths
    wide = sig(scenario=dataclasses.replace(SPECS["in4"], arity=6))
    assert wide != base                               # fabric structure
    assert wide.links != base.links


def test_rejected_scenario_type():
    eng = _open_engine()
    spec = SPECS["in4"]
    with pytest.raises(TypeError, match="ScenarioSpec"):
        WhatIfQuery(cfg=CCSpec(), scenario=spec.build(CCSpec()))


# ---------------------------------------------------------------------------
# admission: token bucket + bounded queue
# ---------------------------------------------------------------------------


def test_token_bucket_burst_then_refill():
    b = TokenBucket(rate=2.0, burst=3, now=0.0)
    assert [b.take(0.0) for _ in range(4)] == [True, True, True, False]
    assert b.retry_after(0.0) == pytest.approx(0.5)
    assert b.take(0.25) is False            # half a token refilled
    assert b.take(0.5) is True              # one full token at +0.5s
    assert b.retry_after(10.0) == 0.0       # capped at burst, available


def test_token_bucket_rate_zero_never_refills():
    b = TokenBucket(rate=0.0, burst=1, now=0.0)
    assert b.take(0.0) is True
    assert b.take(1e9) is False
    assert b.retry_after(1e9) == float("inf")


def test_admission_queue_full_preserves_token():
    t = [0.0]
    ctl = AdmissionController(AdmissionConfig(rate=0.0, burst=1,
                                              max_queue=1), clock=lambda: t[0])
    out = ctl.admit("a", queue_depth=1)     # queue at capacity
    assert isinstance(out, QueueFull) and out.queue_depth == 1
    assert ctl.admit("a", queue_depth=0) is None    # token still there
    assert isinstance(ctl.admit("a", queue_depth=0), Throttled)
    assert ctl.counters() == {"admitted": 1, "throttled": 1,
                              "queue_full": 1, "tenants": 1}


def test_admission_per_tenant_isolation():
    t = [0.0]
    ctl = AdmissionController(AdmissionConfig(rate=0.0, burst=2,
                                              max_queue=99), clock=lambda: t[0])
    assert ctl.admit("noisy", 0) is None and ctl.admit("noisy", 0) is None
    assert isinstance(ctl.admit("noisy", 0), Throttled)
    assert ctl.admit("quiet", 0) is None    # unaffected bucket


def test_engine_throttles_over_rate_burst():
    """The acceptance gate: an over-rate burst gets explicit Throttled
    with a usable retry_after; queries admit again after refill."""
    t = [0.0]
    eng = CCQueryEngine(
        EngineConfig(admission=AdmissionConfig(rate=10.0, burst=4,
                                               max_queue=64)),
        clock=lambda: t[0])
    outs = [eng.submit(WhatIfQuery(cfg=CCSpec(), scenario=SPECS["in4"],
                                   n_steps=N_STEPS)) for _ in range(6)]
    assert [type(o) for o in outs] == [Admitted] * 4 + [Throttled] * 2
    assert outs[4].retry_after == pytest.approx(0.1)
    t[0] += outs[4].retry_after             # wait exactly as told
    assert isinstance(eng.submit(WhatIfQuery(
        cfg=CCSpec(), scenario=SPECS["in4"], n_steps=N_STEPS)), Admitted)
    assert eng.metrics()["admission"]["throttled"] == 2
    assert eng.metrics()["queue_depth"] == 5


def test_engine_queue_never_unbounded():
    t = [0.0]
    eng = CCQueryEngine(
        EngineConfig(admission=AdmissionConfig(rate=1e9, burst=10_000,
                                               max_queue=8)),
        clock=lambda: t[0])
    outs = [eng.submit(WhatIfQuery(cfg=CCSpec(), scenario=SPECS["in4"],
                                   n_steps=N_STEPS)) for _ in range(20)]
    assert sum(isinstance(o, Admitted) for o in outs) == 8
    assert all(isinstance(o, QueueFull) for o in outs[8:])
    assert eng.metrics()["queue_depth"] == 8


# ---------------------------------------------------------------------------
# executable cache
# ---------------------------------------------------------------------------


def test_executable_cache_counts_and_lru():
    c = ExecutableCache(capacity=2, name="t")
    built = []

    def mk(v):
        return lambda: built.append(v) or v

    assert c.get_or_build("a", mk(1)) == 1
    assert c.get_or_build("a", mk(99)) == 1         # hit, no rebuild
    assert c.get_or_build("b", mk(2)) == 2
    assert c.get_or_build("c", mk(3)) == 3          # evicts LRU "a"
    assert "a" not in c and "b" in c
    assert c.get_or_build("a", mk(4)) == 4          # rebuilt
    s = c.stats()
    assert (s.hits, s.misses, s.evictions) == (1, 4, 2)
    assert built == [1, 2, 3, 4]


def test_executable_cache_resize_and_stats_delta():
    c = ExecutableCache(capacity=4)
    for k in "abcd":
        c.get_or_build(k, lambda: k)
    before = c.stats()
    c.resize(2)                                     # drops LRU half
    assert len(c) == 2 and "d" in c and "c" in c
    c.get_or_build("d", lambda: "x")
    delta = c.stats() - before
    assert (delta.hits, delta.misses) == (1, 0)
    assert delta.evictions == 2
    with pytest.raises(ValueError):
        ExecutableCache(capacity=0)


def test_latency_recorder_percentiles():
    r = LatencyRecorder()
    assert np.isnan(r.percentile(50))
    for v in [0.1, 0.2, 0.3, 0.4, 1.0]:
        r.record(v)
    assert r.percentile(0) == 0.1
    assert r.percentile(50) == 0.3
    assert r.percentile(100) == 1.0
    s = r.summary()
    assert s["count"] == 5 and s["p99"] == 1.0


# ---------------------------------------------------------------------------
# background drain (auto_drain) + fleet delegation
# ---------------------------------------------------------------------------


def test_auto_drain_serves_and_closes_cleanly():
    """Submitters enqueue; the background thread drains; wait() blocks
    until the answer lands; close() joins the thread."""
    import threading

    with CCQueryEngine(EngineConfig(
            max_batch=8,
            admission=AdmissionConfig(rate=1e9, burst=10_000,
                                      max_queue=256)),
            auto_drain=True) as eng:
        tickets = []

        def sub(i):
            out = eng.submit(WhatIfQuery(cfg=CFGS["rev"],
                                         scenario=SPECS["in4"],
                                         n_steps=N_STEPS,
                                         label=f"bg{i}"))
            assert isinstance(out, Admitted), out
            tickets.append(out.ticket)

        threads = [threading.Thread(target=sub, args=(i,))
                   for i in range(5)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        results = [eng.wait(t, timeout=600) for t in tickets]
        assert all(r is not None for r in results)
        assert eng.metrics()["queue_depth"] == 0
    # closed: further submissions are refused loudly
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit(WhatIfQuery(cfg=CFGS["rev"], scenario=SPECS["in4"],
                               n_steps=N_STEPS))


def test_auto_drain_bitwise_matches_sync_path():
    """The background road must not change a single bit vs the
    synchronous submit+drain road."""
    sync = _open_engine()
    r_sync = sync.ask(WhatIfQuery(cfg=CFGS["dcqcn"],
                                  scenario=SPECS["in6"],
                                  n_steps=N_STEPS))
    with CCQueryEngine(EngineConfig(
            max_batch=8,
            admission=AdmissionConfig(rate=1e9, burst=10_000,
                                      max_queue=256)),
            auto_drain=True) as eng:
        r_bg = eng.ask(WhatIfQuery(cfg=CFGS["dcqcn"],
                                   scenario=SPECS["in6"],
                                   n_steps=N_STEPS))
    np.testing.assert_array_equal(r_bg.result.delivered,
                                  r_sync.result.delivered)
    np.testing.assert_array_equal(r_bg.result.max_q, r_sync.result.max_q)
    np.testing.assert_array_equal(np.asarray(r_bg.result.final.rate),
                                  np.asarray(r_sync.result.final.rate))


def test_close_drains_pending_queries():
    eng = CCQueryEngine(EngineConfig(
        max_batch=8, admission=AdmissionConfig(rate=1e9, burst=10_000,
                                               max_queue=256)))
    out = eng.submit(WhatIfQuery(cfg=CFGS["rev"], scenario=SPECS["in4"],
                                 n_steps=N_STEPS))
    assert isinstance(out, Admitted)
    eng.close()                       # sync engine: close() drains
    assert eng.result(out.ticket) is not None


def test_fleet_delegation_bitwise_and_flagged():
    """fleet_threshold=0 forces every batch onto the fleet road; the
    per-query result must be bitwise the inline road's."""
    inline = _open_engine()
    r_in = inline.ask(WhatIfQuery(cfg=CFGS["swift"],
                                  scenario=SPECS["in4"],
                                  n_steps=N_STEPS))
    assert r_in.via_fleet is False

    fleet_eng = CCQueryEngine(EngineConfig(
        max_batch=8, fleet_threshold=0.0,
        admission=AdmissionConfig(rate=1e9, burst=10_000,
                                  max_queue=256)))
    r_fl = fleet_eng.ask(WhatIfQuery(cfg=CFGS["swift"],
                                     scenario=SPECS["in4"],
                                     n_steps=N_STEPS))
    assert r_fl.via_fleet is True
    assert r_fl.to_dict()["via_fleet"] is True
    for f in ("delivered", "rate", "inst_thr", "max_q", "marked", "cnp"):
        np.testing.assert_array_equal(
            np.asarray(getattr(r_fl.result, f)),
            np.asarray(getattr(r_in.result, f)), err_msg=f)
    np.testing.assert_array_equal(np.asarray(r_fl.result.final.qh),
                                  np.asarray(r_in.result.final.qh))


def test_fleet_threshold_none_never_delegates():
    eng = _open_engine()
    r = eng.ask(WhatIfQuery(cfg=CFGS["rev"], scenario=SPECS["in4"],
                            n_steps=N_STEPS))
    assert r.via_fleet is False
