"""Mesh-sharded Sweep: multi-device runs must be bitwise single-device.

``Sweep.run(mesh=...)`` shard_maps the run axis, so a sharded sweep is
the single-device sweep cut into per-device slices with zero
cross-device math.  The pytest process owns a single-CPU jax backend,
so the >= 2-device check runs in a subprocess with
``--xla_force_host_platform_device_count`` (the standard way to fake a
multi-device host); the in-process tests cover the 1-device mesh and
the batch-padding path, which exercise the same shard_map code.
"""

import os
import subprocess
import sys

import numpy as np

from repro.core import CCScheme, PAPER_CONFIG, ScenarioSpec, Sweep
from repro.dist import sweep_mesh

_SWEEP_SRC = """
from repro.core import CCScheme, PAPER_CONFIG, ScenarioSpec, Sweep
spec = ScenarioSpec.paper_incast(roll=0)
sweep = Sweep.grid(
    configs={{s.name: PAPER_CONFIG.replace(scheme=s) for s in CCScheme}},
    scenarios={{"hol": spec}})
res = sweep.run(n_steps=300{mesh})
"""

_CHILD = """
import jax, numpy as np
assert len(jax.devices()) == 2, jax.devices()
from repro.dist import sweep_mesh
{single}
ref = res
{sharded}
for name in ref.names:
    a, b = ref[name], res[name]
    for f in ("delivered", "rate", "inst_thr", "max_q", "n_paused",
              "marked", "cnp", "n_nonmin"):
        ga, gb = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        assert np.array_equal(ga, gb), (name, f)
    la = jax.tree_util.tree_flatten_with_path(a.final)[0]
    lb = jax.tree_util.tree_flatten_with_path(b.final)[0]
    assert len(la) == len(lb)
    for (pa, ga), (_, gb) in zip(la, lb):
        assert np.array_equal(np.asarray(ga), np.asarray(gb)), \\
            (name, "final" + jax.tree_util.keystr(pa))
print("SHARDED_BITWISE_OK")
"""


def _env_with_devices(n: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n}")
    src = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH")) if p)
    return env


def test_sharded_sweep_bitwise_on_two_devices():
    """3 runs on a 2-device mesh (pads to 4) == single device, bitwise."""
    src = _CHILD.format(
        single=_SWEEP_SRC.format(mesh=""),
        sharded=_SWEEP_SRC.format(mesh=", mesh=sweep_mesh()"))
    out = subprocess.run([sys.executable, "-c", src],
                         env=_env_with_devices(2), capture_output=True,
                         text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED_BITWISE_OK" in out.stdout


def test_one_device_mesh_in_process():
    """mesh= with a single device goes through the same shard_map path
    (incl. padding 3 runs -> 3, i.e. no pad) and must be bitwise."""
    spec = ScenarioSpec.paper_incast(roll=0)
    sweep = Sweep.grid(
        configs={s.name: PAPER_CONFIG.replace(scheme=s)
                 for s in CCScheme},
        scenarios={"hol": spec})
    r1 = sweep.run(n_steps=200)
    r2 = sweep.run(n_steps=200, mesh=sweep_mesh(1))
    for name in r1.names:
        a, b = r1[name], r2[name]
        assert np.array_equal(a.delivered, b.delivered)
        assert np.array_equal(np.asarray(a.final.qh),
                              np.asarray(b.final.qh))


def test_sweep_mesh_validation():
    import pytest
    with pytest.raises(ValueError):
        sweep_mesh(0)
    with pytest.raises(ValueError):
        sweep_mesh(10_000)
