"""Bit-exact parity suite for the one-pass hot loop.

The fused segment-reduction rewrite and the Pallas per-flow kernels
must be *indistinguishable* from the legacy paths: the golden suite
freezes summaries, so even one reordered f32 add would show.  This
module pins the strongest form — exact array equality — across the
same 18-point scheme x fabric x routing grid the golden suite runs:

  * ``reduce="fused"``  vs ``reduce="scat"``  (segment sum vs scatter)
  * ``reduce="pallas"`` vs ``reduce="fused"`` (fluid_reduce kernel,
    interpret mode)
  * ``use_kernels=True`` vs jnp per-flow block (gen/np-timer + RP/ERP
    kernels, interpret mode)
  * ``use_kernels="mega"`` vs ``reduce="scat"`` (the whole-step
    megakernel, one launch per trace window, interpret mode)

plus unit-level checks of the incidence precompute and the
content-keyed device-placement cache.
"""

import jax
import numpy as np
import pytest

from repro.core import (CCScheme, CCSpec, PAPER_CONFIG, ScenarioSpec,
                        Sweep)
from repro.core.fluid import (_flow_jitter, init_state, make_step_fn,
                              scenario_device)
from repro.core.routing import PAD, link_incidence
from repro.core.workloads import group_shift
from repro.kernels.fluid_reduce import segment_reduce
from repro.net import FabricSpec

TRACE_FIELDS = ("delivered", "rate", "inst_thr", "max_q", "n_paused",
                "marked", "cnp", "n_nonmin", "ctrl", "pause_time",
                "vc_stall")


def _grid_scenarios() -> dict:
    dfly = FabricSpec.dragonfly(a=2, p=2, h=2)
    ft = FabricSpec.fat_tree(4, taper=2)
    return {
        "dfly_adv": group_shift(5, 4, t_stop=0.5e-3).spec(
            fabric=dfly, n_paths=4, route_seed=0, label="dfly_adv"),
        "ft_perm": ScenarioSpec.permutation(
            16, seed=2, fabric=ft, n_paths=4, route_seed=0,
            t_start=0.0, t_stop=0.5e-3, label="ft_perm"),
    }


def _grid() -> Sweep:
    """The golden suite's 18-point grid (same seeds/shapes)."""
    configs = {f"{s.name}/{r}": PAPER_CONFIG.replace(scheme=s, routing=r)
               for s in CCScheme for r in ("min", "valiant", "ugal")}
    return Sweep.grid(configs=configs, scenarios=_grid_scenarios())


def _assert_final_equal(fa, fb, ctx):
    """Exact leaf-wise equality of two FluidStates (dict-state aware)."""
    la = jax.tree_util.tree_flatten_with_path(fa)[0]
    lb = jax.tree_util.tree_flatten_with_path(fb)[0]
    assert len(la) == len(lb)
    for (pa, ga), (pb, gb) in zip(la, lb):
        assert pa == pb
        assert np.array_equal(np.asarray(ga), np.asarray(gb)), \
            ctx + (jax.tree_util.keystr(pa),)


def _assert_bitwise(res_a, res_b, ctx: str):
    assert res_a.names == res_b.names
    for name in res_a.names:
        a, b = res_a[name], res_b[name]
        for f in TRACE_FIELDS:
            ga, gb = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
            assert np.array_equal(ga, gb), (ctx, name, f)
        _assert_final_equal(a.final, b.final, (ctx, name, "final"))


def test_fused_matches_scat_on_golden_grid():
    """One sweep launch per engine; every decimated trace and the final
    state must agree to the bit across all 18 points."""
    sweep = _grid()
    _assert_bitwise(sweep.run(n_steps=150, reduce="fused"),
                    sweep.run(n_steps=150, reduce="scat"),
                    "fused-vs-scat")


def test_kernel_flow_block_matches_jnp_on_golden_grid():
    """Pallas gen/np-timer + RP/ERP kernels (interpret mode) vs the jnp
    per-flow block: exact f32 equality."""
    sweep = _grid()
    _assert_bitwise(
        sweep.run(n_steps=60),
        sweep.run(n_steps=60, use_kernels=True, interpret=True),
        "kernels-vs-jnp")


def test_megakernel_matches_scat_on_golden_grid():
    """The whole-step megakernel — every phase of the step plus the
    in-kernel trace-window scan inside one pallas_call — vs the scatter
    engine: exact equality of all decimated traces and final states
    (delay-line ring and per-flow CC state included) across the 18-point
    grid."""
    sweep = _grid()
    _assert_bitwise(
        sweep.run(n_steps=60, reduce="scat"),
        sweep.run(n_steps=60, use_kernels="mega", interpret=True),
        "mega-vs-scat")


def test_megakernel_matches_scat_at_two_vcs():
    """The megakernel carries the per-VC queue axis (and its stall
    trace) bit-exactly too."""
    sweep = _grid_v2()
    _assert_bitwise(
        sweep.run(n_steps=60, reduce="scat"),
        sweep.run(n_steps=60, use_kernels="mega", interpret=True),
        "mega-vs-scat-v2")


def test_simulator_run_megakernel_bitexact():
    """``simulator.run(use_kernels="mega")`` — the single-point entry —
    matches the per-step scat path sample for sample."""
    from repro.core import simulator as sim
    cfg = PAPER_CONFIG
    scn = ScenarioSpec.paper_incast(
        roll=0, t_start=0.1e-3, t_stop=1.2e-3).build(cfg)
    ra = sim.run(scn, cfg, n_steps=60, trace_every=10, reduce="scat")
    rb = sim.run(scn, cfg, n_steps=60, trace_every=10,
                 use_kernels="mega", interpret=True)
    for f in TRACE_FIELDS:
        assert np.array_equal(np.asarray(getattr(ra, f)),
                              np.asarray(getattr(rb, f))), f
    _assert_final_equal(ra.final, rb.final, ("sim-mega",))


def test_megakernel_rejects_nested_pallas_reduce():
    """reduce="pallas" cannot run inside the megakernel (no nested
    pallas_call); the combination is refused up front."""
    cfg = PAPER_CONFIG
    scn = ScenarioSpec.paper_incast(roll=0).build(cfg)
    with pytest.raises(ValueError, match="mega"):
        make_step_fn(scn, cfg, reduce="pallas", use_kernels="mega",
                     interpret=True)


def test_kernel_tier_rejects_unknown_string():
    from repro.core.fluid import kernel_tier
    assert kernel_tier(False) == "off"
    assert kernel_tier(True) == "flow"
    assert kernel_tier("mega") == "mega"
    with pytest.raises(ValueError, match="use_kernels"):
        kernel_tier("turbo")


@pytest.mark.parametrize("tier", [True, "mega"])
def test_soft_gates_refused_under_kernels_at_both_entry_points(tier):
    """temperature > 0 + any kernel tier must raise at *both* entry
    points (``make_step_fn`` and ``fluid_step``), not silently run the
    hard dynamics (the kernels implement the hard model only)."""
    from repro.core.fluid import fluid_step, step_params
    cfg = PAPER_CONFIG
    scn = ScenarioSpec.paper_incast(roll=0).build(cfg)
    with pytest.raises(ValueError, match="temperature"):
        make_step_fn(scn, cfg, use_kernels=tier, interpret=True,
                     temperature=0.1)
    st = init_state(scn, cfg)
    sd = scenario_device(scn)
    par = step_params(cfg, temperature=0.1)
    with pytest.raises(ValueError, match="temperature"):
        fluid_step(st, sd, par, dt=float(cfg.sim.dt),
                   n_switches=int(scn.n_switches), use_kernels=tier,
                   interpret=True)
    # temperature=0 through the same entry points is fine
    make_step_fn(scn, cfg, use_kernels=tier, interpret=True,
                 temperature=0.0)


def test_pallas_reduce_matches_fused_single_point():
    """The fluid_reduce kernel inside a real stepping loop."""
    cfg = PAPER_CONFIG.replace(routing="ugal")
    scn = ScenarioSpec.permutation(
        16, seed=2, fabric=FabricSpec.fat_tree(4, taper=2), n_paths=4,
        route_seed=0, t_start=0.0, t_stop=0.5e-3).build(cfg)
    outs = []
    for kw in (dict(reduce="fused"),
               dict(reduce="pallas", interpret=True)):
        step = jax.jit(make_step_fn(scn, cfg, **kw))
        st = init_state(scn, cfg)
        for _ in range(100):
            st, _ = step(st)
        outs.append(st)
    _assert_final_equal(outs[0], outs[1], ("pallas-vs-fused",))


# ---------------------------------------------------------------------------
# legacy-scheme shim parity: CCConfig == hand-written CCSpec, bit for bit
# ---------------------------------------------------------------------------

#: what each legacy scheme must decompose into (the shim's contract)
SCHEME_STAGES = {
    CCScheme.PFC_ONLY: ("cp", "np", "pfc"),
    CCScheme.DCQCN: ("cp", "np", "rp"),
    CCScheme.DCQCN_REV: ("ecp", "enp", "erp"),
}


# ---------------------------------------------------------------------------
# multi-VC parity: the per-VC queue axis through every engine
# ---------------------------------------------------------------------------

def _grid_v2() -> Sweep:
    """The 18-point grid at n_vcs=2 (detour hops land on VC 1, so the
    valiant/ugal points exercise genuinely split lanes)."""
    from repro.core.params import LinkParams
    link = LinkParams(n_vcs=2)
    configs = {}
    for s, (m, n, r) in SCHEME_STAGES.items():
        for routing in ("min", "valiant", "ugal"):
            configs[f"{s.name}/{routing}"] = CCSpec(
                marking=m, notification=n, reaction=r, routing=routing,
                link=link)
    return Sweep.grid(configs=configs, scenarios=_grid_scenarios())


def test_fused_matches_scat_at_two_vcs():
    """The VC-striped incidence reduces identically through segment-sum
    and scatter — traces (incl. per-VC stall) and final state."""
    sweep = _grid_v2()
    _assert_bitwise(sweep.run(n_steps=150, reduce="fused"),
                    sweep.run(n_steps=150, reduce="scat"),
                    "fused-vs-scat-v2")


def test_kernel_flow_block_matches_jnp_at_two_vcs():
    sweep = _grid_v2()
    _assert_bitwise(
        sweep.run(n_steps=60),
        sweep.run(n_steps=60, use_kernels=True, interpret=True),
        "kernels-vs-jnp-v2")


def test_pallas_reduce_matches_fused_at_two_vcs():
    from repro.core.params import LinkParams
    cfg = CCSpec(routing="ugal", link=LinkParams(n_vcs=2))
    scn = ScenarioSpec.permutation(
        16, seed=2, fabric=FabricSpec.fat_tree(4, taper=2), n_paths=4,
        route_seed=0, t_start=0.0, t_stop=0.5e-3).build(cfg)
    outs = []
    for kw in (dict(reduce="fused"),
               dict(reduce="pallas", interpret=True)):
        step = jax.jit(make_step_fn(scn, cfg, **kw))
        st = init_state(scn, cfg)
        for _ in range(100):
            st, _ = step(st)
        outs.append(st)
    _assert_final_equal(outs[0], outs[1], ("pallas-vs-fused-v2",))


def test_single_vc_link_params_is_inert():
    """Spelling ``n_vcs=1`` explicitly is the identity — same bits as
    the default config on a golden-grid point (the V axis collapses to
    the legacy layout, not a parallel code path)."""
    from repro.core.params import LinkParams
    spec = _grid_scenarios()["dfly_adv"]
    base = CCSpec(routing="ugal")
    expl = CCSpec(routing="ugal", link=LinkParams(n_vcs=1))
    _assert_bitwise(
        Sweep.grid(configs={"p": base}, scenarios={"s": spec}).run(
            n_steps=150),
        Sweep.grid(configs={"p": expl}, scenarios={"s": spec}).run(
            n_steps=150),
        "v1-inert")


def test_legacy_shim_bitexact_on_golden_grid():
    """Every legacy CCScheme x routing point must produce the same bits
    through an *explicitly constructed* CCSpec as through the CCConfig
    shim — one sweep launch each, traces AND final state compared."""
    legacy = _grid()
    spec_configs = {}
    for s in CCScheme:
        m, n, r = SCHEME_STAGES[s]
        for routing in ("min", "valiant", "ugal"):
            spec_configs[f"{s.name}/{routing}"] = CCSpec(
                marking=m, notification=n, reaction=r, routing=routing)
    explicit = Sweep.grid(configs=spec_configs,
                          scenarios=_grid_scenarios())
    _assert_bitwise(legacy.run(n_steps=150), explicit.run(n_steps=150),
                    "shim-vs-spec")


def test_legacy_override_shim_bitexact():
    """The marking/reaction ablation overrides map through the registry
    bit-exactly too (including the PFC_ONLY window quirk: notification
    follows the reaction override even when the reaction is pinned)."""
    spec_scn = ScenarioSpec.paper_incast(roll=0, t_start=0.1e-3)
    cases = {
        "ecp_rp": (PAPER_CONFIG.replace(scheme=CCScheme.DCQCN,
                                        marking="ecp"),
                   CCSpec(marking="ecp", notification="np",
                          reaction="rp")),
        "cp_erp": (PAPER_CONFIG.replace(scheme=CCScheme.DCQCN,
                                        reaction="erp"),
                   CCSpec(marking="cp", notification="enp",
                          reaction="erp")),
        "pfc_erp": (PAPER_CONFIG.replace(scheme=CCScheme.PFC_ONLY,
                                         reaction="erp"),
                    CCSpec(marking="cp", notification="enp",
                           reaction="pfc")),
    }
    legacy = Sweep([(k, cfg, spec_scn) for k, (cfg, _) in cases.items()])
    explicit = Sweep([(k, sp, spec_scn) for k, (_, sp) in cases.items()])
    _assert_bitwise(legacy.run(n_steps=1500),
                    explicit.run(n_steps=1500), "override-shim")


# ---------------------------------------------------------------------------
# segment_reduce kernel unit tests
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,c,s", [(1, 1, 1), (100, 3, 17), (513, 2, 5),
                                   (1536, 8, 300), (4096, 5, 1000)])
def test_segment_reduce_exact(n, c, s):
    rng = np.random.RandomState(n + c + s)
    seg = np.sort(rng.randint(0, s, size=n)).astype(np.int32)
    data = rng.randn(n, c).astype(np.float32)
    got = segment_reduce(jax.numpy.asarray(data), jax.numpy.asarray(seg),
                         s, interpret=True)
    want = jax.ops.segment_sum(jax.numpy.asarray(data),
                               jax.numpy.asarray(seg), num_segments=s,
                               indices_are_sorted=True)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_segment_reduce_empty_input():
    """Zero rows must yield exact zeros (the grid never runs, so the
    wrapper must not hand back uninitialised output)."""
    out = segment_reduce(jax.numpy.zeros((0, 3), jax.numpy.float32),
                         jax.numpy.zeros((0,), jax.numpy.int32), 7,
                         interpret=True)
    assert np.array_equal(np.asarray(out), np.zeros((7, 3), np.float32))


def test_segment_reduce_rejects_oversized_accumulator():
    """Shapes whose [S, C] accumulator cannot sit in VMEM are refused
    with a pointer at the segment-sum engine, not silently compiled."""
    with pytest.raises(ValueError, match="VMEM"):
        segment_reduce(jax.numpy.zeros((512, 128), jax.numpy.float32),
                       jax.numpy.zeros((512,), jax.numpy.int32),
                       1 << 16, interpret=True)


def test_segment_reduce_empty_segments():
    """Links no flow crosses must come back exactly 0."""
    seg = np.asarray([3, 3, 7], np.int32)
    data = np.ones((3, 2), np.float32)
    out = np.asarray(segment_reduce(jax.numpy.asarray(data),
                                    jax.numpy.asarray(seg), 10,
                                    interpret=True))
    want = np.zeros((10, 2), np.float32)
    want[3] = 2.0
    want[7] = 1.0
    assert np.array_equal(out, want)


# ---------------------------------------------------------------------------
# incidence precompute + device-placement cache
# ---------------------------------------------------------------------------

def test_link_incidence_structure():
    rng = np.random.RandomState(0)
    F, K, H, L = 13, 3, 5, 40
    alt = rng.randint(-1, L, size=(F, K, H)).astype(np.int32)
    perm, seg, off = link_incidence(alt, L)
    assert sorted(perm.tolist()) == list(range(F * K * H))
    assert (np.diff(seg) >= 0).all()                  # sorted
    flat = alt.reshape(-1)
    np.testing.assert_array_equal(
        seg, np.where(flat[perm] == PAD, L, flat[perm]))
    # CSR offsets: segment l spans [off[l], off[l+1])
    assert off[0] == 0 and off[-1] == F * K * H
    for l in (0, L // 2, L):                          # spot-check rows
        rows = perm[off[l]:off[l + 1]]
        vals = np.where(flat == PAD, L, flat)[rows]
        assert (vals == l).all()
    # stability: equal-id entries keep flattened order
    for l in range(L + 1):
        assert (np.diff(perm[off[l]:off[l + 1]]) > 0).all()


def test_clamp_dense_rows_guards_batch_max():
    """The dense-CSR size guard applies to batch-wide row counts too:
    a skewed maximum that would dwarf the incidence disables the dense
    engine instead of inflating every run's table."""
    from repro.core.fluid import DENSE_ROWS_CAP, clamp_dense_rows
    assert clamp_dense_rows(4, 384, 30) == 4
    assert clamp_dense_rows(0, 384, 30) == 0
    assert clamp_dense_rows(DENSE_ROWS_CAP + 1, 10, 10 ** 9) == 0
    # L * ml far beyond 16x the incidence entries -> disabled
    assert clamp_dense_rows(1000, 100_000, 6_000) == 0


def test_fabric_incidence_mirrors_scenario_device():
    """RouteTable/RouteSet.incidence are the host-side view of the
    exact ``red_*`` layout ``scenario_device`` ships: same permutation,
    segments and CSR offsets for the same pairs."""
    fab = FabricSpec.fat_tree(4, taper=2)
    pairs = [(0, 9), (3, 17), (22, 41), (5, 60), (13, 2)]
    for spec, inc in [
            (ScenarioSpec.flows(pairs, fabric=fab),
             lambda L: fab.route_table().incidence(L, pairs)),
            (ScenarioSpec.flows(pairs, fabric=fab, n_paths=4,
                                route_seed=0),
             lambda L: fab.route_set(4, seed=0).incidence(L, pairs))]:
        scn = spec.build(PAPER_CONFIG)
        sd = scenario_device(scn)
        perm, seg, off = inc(scn.capacity.shape[0])
        np.testing.assert_array_equal(np.asarray(sd.red_perm), perm)
        np.testing.assert_array_equal(np.asarray(sd.red_seg), seg)
        np.testing.assert_array_equal(np.asarray(sd.red_off), off)


def test_scenario_device_upload_cache_and_jitter():
    """Two grid points sharing a fabric must share the device buffers
    of its route/capacity tensors (content-keyed placement cache), and
    the ERP jitter must be hoisted into the scenario."""
    cfg = PAPER_CONFIG
    spec = ScenarioSpec.paper_incast(roll=0)
    sd1 = scenario_device(spec.build(cfg))
    sd2 = scenario_device(spec.build(cfg.replace(scheme=CCScheme.DCQCN)))
    for f in ("cap_ext", "sink_ext", "alt_routes", "alt_hops",
              "red_perm", "red_seg", "red_off", "pool_perm", "pool_seg",
              "jitter"):
        assert getattr(sd1, f) is getattr(sd2, f), f
    F = sd1.gen_rate.shape[0]
    np.testing.assert_array_equal(np.asarray(sd1.jitter), _flow_jitter(F))
