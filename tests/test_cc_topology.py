"""Topology + routing invariants for the CC core."""

import numpy as np
import pytest

from repro.core import (ClosIndex, build_flow_routes, clos_route,
                        make_clos3, make_paper_clos)
from repro.core.routing import route_hops, stage_load, validate_routes


def test_paper_clos_counts():
    topo = make_paper_clos()
    assert topo.n_nodes == 64
    assert topo.n_switches == 48
    assert topo.n_links == 6 * 64


def test_clos_radix_bound():
    """No switch may use more than 8 ports (4 in + 4 out per side)."""
    topo = make_paper_clos()
    # per-switch degree: count directed links touching each switch, / 2
    for s in range(topo.n_switches):
        out_deg = int((topo.link_src == s).sum())
        in_deg = int((topo.link_dst == s).sum())
        assert out_deg <= 8 and in_deg <= 8


def test_switch16_is_agg00():
    idx = ClosIndex(4)
    assert idx.switch_of_agg(0, 0) == 16  # the paper's HoL switch


@pytest.mark.parametrize("roll", [0, 1])
def test_routes_connected(roll):
    topo = make_paper_clos()
    pairs = [(s, d) for s in range(0, 64, 7) for d in range(3, 64, 11)
             if s != d]
    routes = build_flow_routes(topo, pairs, roll=roll)
    validate_routes(topo, routes)  # raises on any broken hop


def test_routes_start_and_end_at_hosts():
    topo = make_paper_clos()
    pairs = [(0, 63), (5, 6), (17, 42)]
    routes = build_flow_routes(topo, pairs)
    hops = route_hops(routes)
    for f, (s, d) in enumerate(pairs):
        first, last = routes[f, 0], routes[f, hops[f] - 1]
        assert topo.link_src[first] == -(s + 1)
        assert topo.link_dst[last] == -(d + 1)


def test_dmodk_balances_uplinks():
    """All-to-all routes must spread ~evenly over each stage's links."""
    topo = make_paper_clos()
    pairs = [(s, d) for s in range(64) for d in range(64) if s != d]
    routes = build_flow_routes(topo, pairs)
    load = stage_load(routes, topo.n_links)
    leaf_up = load[64:128]          # leaf->agg stage
    assert leaf_up.max() <= 2 * max(1, leaf_up.min())


def test_paper_shared_wire():
    """roll=0: F0,F1 (->N16) and F3 (->N12) share leaf-0 uplink 0."""
    idx = ClosIndex(4)
    p0 = clos_route(idx, 0, 16, roll=0)
    p1 = clos_route(idx, 1, 16, roll=0)
    pv = clos_route(idx, 3, 12, roll=0)
    shared = idx.leaf_up(0, 0)
    assert shared in p0 and shared in p1 and shared in pv


def test_paper_disjoint_wire():
    """roll=1: the victim's path is wire-disjoint from the incast flows."""
    idx = ClosIndex(4)
    incast = set()
    for s in (0, 1, 4, 8):
        incast |= set(clos_route(idx, s, 16, roll=1))
    victim = set(clos_route(idx, 3, 12, roll=1))
    assert not (incast & victim)


def test_generic_arity_scales():
    topo = make_clos3(arity=8)
    assert topo.n_nodes == 512
    assert topo.n_switches == 3 * 64
    pairs = [(0, 511), (100, 200)]
    routes = build_flow_routes(topo, pairs, arity=8)
    validate_routes(topo, routes)


# ---------------------------------------------------------------------------
# route-validity property: the D-mod-K invariants, all arities x rolls
# (pins the digit-selector semantics so rewrites of the once-confusing
#  `digit1` expression can't silently change a wiring)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arity", [2, 3, 4])
@pytest.mark.parametrize("roll", [0, 1])
def test_dmodk_route_properties(arity, roll):
    """All-to-all: consecutive links share a switch, first/last hops
    are the endpoint hosts, and every up stage is EXACTLY balanced."""
    topo = make_clos3(arity=arity)
    n = topo.n_nodes
    pairs = [(s, d) for s in range(n) for d in range(n) if s != d]
    routes = build_flow_routes(topo, pairs, arity=arity, roll=roll)
    validate_routes(topo, routes)            # consecutive-hop invariant
    hops = route_hops(routes)
    first = routes[np.arange(len(pairs)), 0]
    last = routes[np.arange(len(pairs)), hops - 1]
    srcs = np.asarray([p[0] for p in pairs])
    dsts = np.asarray([p[1] for p in pairs])
    assert (topo.link_src[first] == -(srcs + 1)).all()
    assert (topo.link_dst[last] == -(dsts + 1)).all()    # sinks at dst
    # per-stage uplink balance.  roll=0 spreads all-to-all EXACTLY at
    # both stages; roll=1's leaf stage is near-balanced (same-leaf
    # destinations deplete the slot matching the leaf's own digit) and
    # its agg stage is exact again.
    load = stage_load(routes, topo.n_links)
    a3 = arity ** 3
    leaf_up = load[a3: 2 * a3]
    agg_up = load[2 * a3: 3 * a3]
    assert agg_up.min() == agg_up.max() == arity ** 2 * (arity - 1)
    if roll == 0:
        assert leaf_up.min() == leaf_up.max() == arity * (arity ** 2 - 1)
    else:
        assert leaf_up.max() <= 2 * leaf_up.min()
        assert leaf_up.sum() == leaf_up.size * arity * (arity ** 2 - 1)


def test_clos_route_rejects_unknown_roll():
    with pytest.raises(ValueError, match="roll"):
        clos_route(ClosIndex(4), 0, 16, roll=2)


def test_digit_roll_swaps_stage_selectors():
    """roll=1 swaps the digit selectors: (d//a)%a at the leaf and
    d%a at the agg — the exact wiring the paper's Fig. 2 needs."""
    idx = ClosIndex(4)
    # dst=17: digits (d%4, (d//4)%4) = (1, 0)
    p0 = clos_route(idx, 32, 17, roll=0)
    p1 = clos_route(idx, 32, 17, roll=1)
    assert p0[1] == idx.leaf_up(8, 1)        # roll=0 leaf digit: d%a
    assert p1[1] == idx.leaf_up(8, 0)        # roll=1 leaf digit: (d//a)%a
    assert p0[2] == idx.agg_up(2, 1, 0)      # roll=0 agg digit: (d//a)%a
    assert p1[2] == idx.agg_up(2, 0, 1)      # roll=1 agg digit: d%a
