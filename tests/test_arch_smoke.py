"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs (assignment deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config, supports
from repro.models import encdec, transformer, vlm
from repro.models.layers import init_params
from repro.train.step import StepConfig, make_train_step, init_train_state
from repro.optim import AdamWConfig

B, T = 2, 16


def _batch(cfg):
    rng = np.random.RandomState(0)
    if cfg.encdec is not None:
        return {
            "frames": jnp.asarray(
                rng.randn(B, cfg.encdec.enc_seq, cfg.d_model), jnp.float32)
            * 0.02,
            "tokens": jnp.asarray(rng.randint(0, cfg.vocab, (B, T)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.randint(0, cfg.vocab, (B, T)),
                                  jnp.int32),
        }
    if cfg.vlm is not None:
        p = cfg.vlm.n_patches
        return {
            "patches": jnp.asarray(
                rng.randn(B, p, cfg.vlm.vit_dim), jnp.float32) * 0.02,
            "tokens": jnp.asarray(rng.randint(0, cfg.vocab, (B, T)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.randint(0, cfg.vocab, (B, T + p)),
                                  jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab, (B, T)), jnp.int32),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab, (B, T)), jnp.int32),
    }


def _defs(cfg):
    if cfg.encdec is not None:
        return encdec.param_defs(cfg)
    if cfg.vlm is not None:
        return vlm.param_defs(cfg)
    return transformer.param_defs(cfg)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    params = init_params(_defs(cfg), 0, jnp.float32)
    batch = _batch(cfg)
    if cfg.encdec is not None:
        logits, _ = encdec.forward(params, cfg, batch["frames"],
                                   batch["tokens"])
        assert logits.shape == (B, T, cfg.vocab)
    elif cfg.vlm is not None:
        logits, _ = vlm.forward(params, cfg, batch["patches"],
                                batch["tokens"])
        assert logits.shape == (B, T + cfg.vlm.n_patches, cfg.vocab)
    else:
        logits, _ = transformer.forward(params, cfg, batch["tokens"])
        assert logits.shape == (B, T, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(_defs(cfg), 0, jnp.float32)
    sc = StepConfig(opt=AdamWConfig(lr=1e-3, use_master=False))
    state = init_train_state(cfg, params, sc)
    step = jax.jit(make_train_step(cfg, sc))
    state2, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, l: a + float(jnp.abs(l).sum()),
        jax.tree.map(lambda a, b: a - b, state.params, state2.params), 0.0)
    assert moved > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).encdec is None
                                  and get_config(a).vlm is None])
def test_decode_matches_forward(arch):
    """Serving path consistency on the reduced config."""
    cfg = get_smoke_config(arch)
    params = init_params(transformer.param_defs(cfg), 0, jnp.float32)
    rng = np.random.RandomState(1)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (2, 12)), jnp.int32)
    full, _ = transformer.forward(params, cfg, toks)
    logits_p, caches = transformer.prefill(params, cfg, toks[:, :9],
                                           max_len=12)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(full[:, 8]), atol=2e-4, rtol=2e-3)
    for i in range(9, 12):
        logits_d, caches = transformer.decode_step(
            params, cfg, toks[:, i:i + 1], caches, jnp.asarray(i, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(full[:, i]), atol=2e-4,
                                   rtol=2e-3)


def test_cell_matrix_counts():
    """40 cells total; skips match DESIGN.md §6 exactly."""
    from repro.configs import all_cells
    cells = all_cells()
    assert len(cells) == 40
    skipped = {(a, s) for a, s, ok, _ in cells if not ok}
    assert skipped == {
        ("qwen2.5-32b", "long_500k"),
        ("starcoder2-3b", "long_500k"),
        ("phi3-medium-14b", "long_500k"),
        ("whisper-base", "long_500k"),
        ("deepseek-moe-16b", "long_500k"),
        ("internvl2-26b", "long_500k"),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expect = {
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expect, f"{arch}: {got} != {expect}"
