"""Golden-trace regression suite: frozen SweepResult summaries.

A fixed grid — 3 schemes x 2 fabrics x {min, valiant, ugal} under
pinned seeds — runs as one Sweep launch; headline numbers (throughput,
completion, delivered bytes, ECN-mark / CNP counts, peak non-minimal
flow count) are compared against ``tests/golden/routing_sweep.json``.
Kernel or fluid-model refactors that change numerics now fail loudly
instead of silently drifting the paper's tables.

A second frozen grid covers the PFC-pathology scenarios (HoL-victim
incast, pause-storm cascade, dragonfly credit loop) x the three paper
schemes, pinning the victim-flow metrics (``victim_slowdown``,
``pause_s``) in ``tests/golden/pfc_pathology.json``.

Regenerate (after an *intentional* numerics change, with a line in the
commit message saying why).  The two files regenerate independently —
a change that only touches the pathology scenarios must NOT rewrite
``routing_sweep.json``, and vice versa:

    PYTHONPATH=src python tests/test_golden.py --regen            # routing
    PYTHONPATH=src python tests/test_golden.py --regen-pathology  # pathology

Tolerances: floats rtol=2e-3 (covers accumulation-order jitter across
BLAS/jax versions), counters within 2% or +-2 events.
"""

import json
import os

import numpy as np
import pytest

from repro.core import CCScheme, CCSpec, PAPER_CONFIG, ScenarioSpec, Sweep
from repro.core.workloads import (credit_loop, group_shift,
                                  hol_victim_incast, pause_storm)
from repro.net import FabricSpec

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "routing_sweep.json")
PATHOLOGY_PATH = os.path.join(os.path.dirname(__file__), "golden",
                              "pfc_pathology.json")
N_STEPS = 600
N_STEPS_PATHOLOGY = 5000
ROUTINGS = ("min", "valiant", "ugal")

FLOAT_KEYS = ("aggregate_gbps", "completion_ms", "delivered_mb",
              "peak_queue_kb")
COUNT_KEYS = ("marks", "cnps", "peak_nonmin_flows")

#: completion_ms is deliberately absent — the pathology windows close
#: right at the horizon, so its NaN-ness is not a stable signature
PATHOLOGY_FLOAT_KEYS = ("aggregate_gbps", "delivered_mb", "peak_queue_kb",
                        "victim_slowdown", "pause_s")
PATHOLOGY_COUNT_KEYS = ("marks", "cnps")


def _grid() -> Sweep:
    """The frozen grid; every seed and shape pinned."""
    dfly = FabricSpec.dragonfly(a=2, p=2, h=2)          # 20 hosts, 5 groups
    ft = FabricSpec.fat_tree(4, taper=2)                # 64 hosts, 2:1
    scenarios = {
        "dfly_adv": group_shift(5, 4, t_stop=0.5e-3).spec(
            fabric=dfly, n_paths=4, route_seed=0, label="dfly_adv"),
        "ft_perm": ScenarioSpec.permutation(
            16, seed=2, fabric=ft, n_paths=4, route_seed=0,
            t_start=0.0, t_stop=0.5e-3, label="ft_perm"),
    }
    configs = {f"{s.name}/{r}": PAPER_CONFIG.replace(scheme=s, routing=r)
               for s in CCScheme for r in ROUTINGS}
    return Sweep.grid(configs=configs, scenarios=scenarios)


def current_summaries() -> dict:
    res = _grid().run(n_steps=N_STEPS)
    out = {}
    for name, row in res.summary().items():
        out[name] = {k: row[k] for k in FLOAT_KEYS + COUNT_KEYS}
    return out


@pytest.fixture(scope="module")
def summaries():
    return current_summaries()


def _golden() -> dict:
    if not os.path.exists(GOLDEN_PATH):
        pytest.fail(f"golden file missing: {GOLDEN_PATH}; regenerate with "
                    f"PYTHONPATH=src python tests/test_golden.py --regen")
    with open(GOLDEN_PATH) as f:
        return json.load(f)["summaries"]


def test_golden_grid_covers_full_routing_axis(summaries):
    assert len(summaries) == 3 * 2 * 3
    golden = _golden()
    assert set(golden) == set(summaries)


@pytest.mark.parametrize("routing", ROUTINGS)
def test_golden_summaries_match(summaries, routing):
    golden = _golden()
    for name, got in summaries.items():
        if f"/{routing}/" not in name:
            continue
        want = golden[name]
        for k in FLOAT_KEYS:
            g, w = got[k], want[k]
            if np.isnan(w):
                assert np.isnan(g), (name, k, g)
                continue
            np.testing.assert_allclose(
                g, w, rtol=2e-3, atol=1e-9,
                err_msg=f"{name}.{k} drifted (golden {w}, got {g}); "
                        f"if intentional: tests/test_golden.py --regen")
        for k in COUNT_KEYS:
            g, w = got[k], want[k]
            assert abs(g - w) <= max(2, 0.02 * w), \
                f"{name}.{k} drifted (golden {w}, got {g})"


# ---------------------------------------------------------------------------
# PFC-pathology goldens
# ---------------------------------------------------------------------------

SCHEME_SPECS = {
    "PFC_ONLY": CCSpec(marking="cp", notification="np", reaction="pfc"),
    "DCQCN": CCSpec(marking="cp", notification="np", reaction="rp"),
    "DCQCN_REV": CCSpec(marking="ecp", notification="enp", reaction="erp"),
}


def _pathology_grid() -> Sweep:
    clos = FabricSpec.clos3(4)                          # 64 hosts
    dfly = FabricSpec.dragonfly(a=2, p=2, h=2)          # 20 hosts, 5 groups
    scenarios = {
        "holvictim": hol_victim_incast(4, 64).spec(fabric=clos),
        "pausestorm": pause_storm(3, 4, 64).spec(fabric=clos),
        "creditloop": credit_loop(5, 4).spec(fabric=dfly),
    }
    return Sweep.grid(configs=SCHEME_SPECS, scenarios=scenarios)


def pathology_summaries() -> dict:
    res = _pathology_grid().run(n_steps=N_STEPS_PATHOLOGY)
    return {name: {k: row[k] for k in
                   PATHOLOGY_FLOAT_KEYS + PATHOLOGY_COUNT_KEYS}
            for name, row in res.summary().items()}


@pytest.fixture(scope="module")
def pathology():
    return pathology_summaries()


def _golden_pathology() -> dict:
    if not os.path.exists(PATHOLOGY_PATH):
        pytest.fail(f"golden file missing: {PATHOLOGY_PATH}; regenerate "
                    f"with PYTHONPATH=src python tests/test_golden.py "
                    f"--regen-pathology")
    with open(PATHOLOGY_PATH) as f:
        return json.load(f)["summaries"]


def test_pathology_summaries_match(pathology):
    golden = _golden_pathology()
    assert set(golden) == set(pathology)
    for name, got in pathology.items():
        want = golden[name]
        for k in PATHOLOGY_FLOAT_KEYS:
            g, w = got[k], want[k]
            if np.isnan(w):
                assert np.isnan(g), (name, k, g)
                continue
            np.testing.assert_allclose(
                g, w, rtol=2e-3, atol=1e-9,
                err_msg=f"{name}.{k} drifted (golden {w}, got {g}); if "
                        f"intentional: tests/test_golden.py "
                        f"--regen-pathology")
        for k in PATHOLOGY_COUNT_KEYS:
            g, w = got[k], want[k]
            assert abs(g - w) <= max(2, 0.02 * w), \
                f"{name}.{k} drifted (golden {w}, got {g})"


def test_pathology_golden_encodes_victim_ordering():
    """The frozen numbers themselves witness the paper's HoL claim:
    Rev spares the victim, DCQCN collaterally marks it, PFC-only
    head-of-line blocks it — and only PFC-only propagates pauses."""
    golden = _golden_pathology()
    vic = {s: golden[f"{s}/holvictim"]["victim_slowdown"]
           for s in SCHEME_SPECS}
    assert vic["DCQCN_REV"] < vic["DCQCN"] < vic["PFC_ONLY"], vic
    storm = {s: golden[f"{s}/pausestorm"]["pause_s"]
             for s in SCHEME_SPECS}
    assert storm["PFC_ONLY"] > 10 * max(storm["DCQCN"],
                                        storm["DCQCN_REV"], 1e-9), storm


def test_legacy_grid_maps_through_stage_registry():
    """Every golden-grid config decomposes into the expected
    ``repro.core.cc`` stages with matching traced codes — the shim
    contract whose *bitwise* form test_fluid_fused holds on this same
    grid.  A change to the mapping (or a renumbering of the built-in
    stages) fails here before it silently drifts the goldens."""
    from repro.core import cc
    from repro.core.fluid import step_params
    expected = {CCScheme.PFC_ONLY: ("cp", "np", "pfc"),
                CCScheme.DCQCN: ("cp", "np", "rp"),
                CCScheme.DCQCN_REV: ("ecp", "enp", "erp")}
    for s, (m, n, r) in expected.items():
        for routing in ROUTINGS:
            spec = PAPER_CONFIG.replace(scheme=s, routing=routing) \
                .to_spec()
            assert (spec.marking, spec.notification, spec.reaction) \
                == (m, n, r), s
            par = step_params(spec)
            assert int(par.mark_code) == cc.MARKING.code(m)
            assert int(par.notif_code) == cc.NOTIFICATION.code(n)
            assert int(par.react_code) == cc.REACTION.code(r)


def test_golden_encodes_the_acceptance_ordering():
    """The frozen numbers themselves must witness the adaptive-routing
    claim: UGAL >= minimal delivered bytes on the adversarial pattern."""
    golden = _golden()
    for s in CCScheme:
        u = golden[f"{s.name}/ugal/dfly_adv"]["delivered_mb"]
        m = golden[f"{s.name}/min/dfly_adv"]["delivered_mb"]
        assert u >= m, (s.name, u, m)


def _regen(path: str, n_steps: int, summaries: dict, flag: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    doc = {
        "comment": f"frozen by tests/test_golden.py {flag}; see module "
                   "docstring for when regeneration is legitimate",
        "n_steps": n_steps,
        "summaries": summaries,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {path} ({len(doc['summaries'])} points)")


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        _regen(GOLDEN_PATH, N_STEPS, current_summaries(), "--regen")
    elif "--regen-pathology" in sys.argv:
        _regen(PATHOLOGY_PATH, N_STEPS_PATHOLOGY, pathology_summaries(),
               "--regen-pathology")
    else:
        print(__doc__)
