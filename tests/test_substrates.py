"""Substrate tests: optimizer, compression, data pipeline, checkpointing,
pacer, pipeline parallelism, sharding rules."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # image without hypothesis: deterministic sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.ckpt import (CheckpointManager, latest_step, load_checkpoint,
                        save_checkpoint)
from repro.data import DataConfig, SyntheticLM
from repro.dist.pacer import chunk_bytes_of, erp_chunk_schedule
from repro.dist.sharding import DEFAULT_RULES, pspec
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, compress_int8,
                         cosine_schedule, decompress_int8,
                         ef_compress_update, ef_init)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_descends_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, use_master=True)
    opt = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw w^2
        params, opt, _ = adamw_update(grads, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.asarray(s), peak_lr=1.0,
                                 warmup_steps=10, total_steps=100))
           for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) <= 1.0
    assert lrs[2] == 1.0                         # end of warmup
    assert lrs[-1] == pytest.approx(0.1, rel=1e-3)   # min_ratio floor


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-6, 1e6))
def test_int8_roundtrip_bounded_error(seed, scale):
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(257) * scale, jnp.float32)
    q, s = compress_int8(x)
    err = jnp.abs(decompress_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-12  # half-ULP of the quantiser


def test_error_feedback_is_unbiased_over_time():
    """Sum of EF-compressed grads converges to sum of true grads."""
    r = np.random.RandomState(0)
    g_true = [{"w": jnp.asarray(r.randn(64), jnp.float32)}
              for _ in range(50)]
    ef = ef_init(g_true[0])
    tot_c = jnp.zeros(64)
    tot_t = jnp.zeros(64)
    for g in g_true:
        gc, ef = ef_compress_update(g, ef)
        tot_c += gc["w"]
        tot_t += g["w"]
    resid = float(jnp.abs(ef.residual["w"]).max())
    drift = float(jnp.abs(tot_c - tot_t).max())
    assert drift <= resid + 1e-4   # EF: error never accumulates beyond 1 q

# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab=97, seq_len=16, global_batch=4, kind="zipf")
    ds = SyntheticLM(cfg)
    a = ds.batch_at(12)
    b = SyntheticLM(cfg).batch_at(12)     # fresh instance, same step
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_data_host_sharding_disjoint():
    full = DataConfig(vocab=97, seq_len=8, global_batch=8, kind="uniform")
    h0 = DataConfig(vocab=97, seq_len=8, global_batch=8, kind="uniform",
                    n_hosts=2, host_id=0)
    h1 = DataConfig(vocab=97, seq_len=8, global_batch=8, kind="uniform",
                    n_hosts=2, host_id=1)
    b0 = SyntheticLM(h0).batch_at(3)
    b1 = SyntheticLM(h1).batch_at(3)
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_markov_is_learnable_structure():
    ds = SyntheticLM(DataConfig(vocab=64, seq_len=128, global_batch=2,
                                kind="markov"))
    b = ds.batch_at(0)
    pred = (b["tokens"].astype(np.int64) * 31 + 17) % 64
    # labels within the 0..6 noise band of the deterministic map
    diff = (b["labels"] - pred) % 64
    assert diff.max() <= 6


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_ckpt_roundtrip_and_atomicity():
    tree = {"a": jnp.arange(5.0), "b": [jnp.ones((2, 2)),
                                        {"c": jnp.zeros(3)}]}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, tree, extra={"data_step": 7})
        # a torn write must be invisible
        os.makedirs(os.path.join(d, "step_000000009.tmp"))
        assert latest_step(d) == 7
        got, extra = load_checkpoint(d)
        np.testing.assert_array_equal(got["a"], np.arange(5.0))
        np.testing.assert_array_equal(got["b"][0], np.ones((2, 2)))
        assert extra["data_step"] == 7


def test_ckpt_manager_async_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save_async(s, {"x": jnp.full((4,), float(s))})
        mgr.wait()
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                       if n.startswith("step_") and not n.endswith(".done"))
        assert steps == [3, 4]
        got, _ = load_checkpoint(d)
        assert float(got["x"][0]) == 4.0


def test_ckpt_elastic_resharding():
    """Restore onto explicit (different) shardings."""
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec
    tree = {"w": jnp.arange(8.0)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, tree)
        sh = {"w": NamedSharding(mesh, PartitionSpec("data"))}
        got, _ = load_checkpoint(d, shardings=sh)
        assert got["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(8.0))


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_pspec_divisibility_guard():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # 40 heads % 1 == 0 trivially here; force the guard with a fake shape
    spec = pspec(("vocab",), (92553,), DEFAULT_RULES, mesh)
    assert spec == jax.sharding.PartitionSpec(None,) or spec is not None


def test_pspec_joint_axes():
    # AbstractMesh: the production shape without needing 4 real devices
    mesh = jax.sharding.AbstractMesh((2, 2, 1), ("pod", "data", "model"))
    spec = pspec(("batch", None), (8, 4), DEFAULT_RULES, mesh)
    assert spec[0] == ("pod", "data")
    # non-divisible batch degrades to replication
    spec = pspec(("batch", None), (3, 4), DEFAULT_RULES, mesh)
    assert spec[0] is None


# ---------------------------------------------------------------------------
# pacer + pipeline
# ---------------------------------------------------------------------------

def test_chunk_bytes_partition():
    tree = {"a": jnp.zeros((1000,)), "b": jnp.zeros((24,))}
    chunks = chunk_bytes_of(tree, 8)
    assert sum(chunks) == 1024 * 4
    assert len(chunks) == 8


def test_erp_schedule_orders_chunks():
    sched = erp_chunk_schedule([1e6] * 4, n_pods=2)
    assert sched["completion_ms"] > 0
    assert len(sched["chunks"]) == 4


def test_pipeline_matches_sequential():
    """2-stage pipeline == running both stages back to back."""
    from repro.dist.pipeline import pipeline_apply
    mesh = jax.make_mesh((1,), ("pod",))   # 1 device: S=1 degenerate ring
    w = jnp.asarray([[2.0]])
    params = jnp.stack([w])                # [S=1, 1, 1]
    xs = jnp.arange(6.0).reshape(3, 2, 1)  # M=3 microbatches of [2, 1]

    def stage(p, x):
        return x @ p + 1.0

    out = pipeline_apply(stage, params, xs, mesh, n_stages=1, axis="pod")
    want = jnp.stack([stage(w, xs[i]) for i in range(3)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want))
