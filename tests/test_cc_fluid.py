"""Fluid-model conservation + closed-loop behaviour tests (paper claims).

Configs are canonical ``CCSpec`` stage triples; the legacy ``CCConfig``
shim's mapping onto them is asserted once (``test_legacy_shim_maps_to_
canonical_specs``) rather than re-exercised per test — its bitwise form
lives in test_fluid_fused.
"""

import numpy as np
import pytest

from repro.core import (CCScheme, CCSpec, PAPER_CONFIG, incast,
                        paper_incast, paper_incast_volume, run)

CFG = CCSpec()

#: the paper's three schemes as explicit stage triples
SPECS = {
    "PFC_ONLY": CCSpec(marking="cp", notification="np", reaction="pfc"),
    "DCQCN": CCSpec(marking="cp", notification="np", reaction="rp"),
    "DCQCN_REV": CCSpec(marking="ecp", notification="enp", reaction="erp"),
}


def test_legacy_shim_maps_to_canonical_specs():
    """The one place the CCConfig shim is exercised here: each legacy
    scheme must decompose into exactly the stage triple this module
    runs, so every claim below also covers the shim path."""
    for s in CCScheme:
        assert PAPER_CONFIG.replace(scheme=s).to_spec() == SPECS[s.name], s


@pytest.fixture(scope="module")
def results_roll0():
    scn = paper_incast_volume(CFG, roll=0)
    return {name: run(scn, spec, n_steps=16000)
            for name, spec in SPECS.items()}


# ---------------------------------------------------------------------------
# conservation / sanity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", sorted(SPECS))
def test_byte_conservation(scheme):
    scn = paper_incast(CFG, roll=0)
    res = run(scn, SPECS[scheme], n_steps=6000)
    f = res.final
    offered = np.asarray(f.offered)
    acct = (np.asarray(f.delivered) + np.asarray(f.nicq)
            + np.asarray(f.qh).sum(1))
    np.testing.assert_allclose(acct, offered, rtol=1e-4, atol=1e3)


@pytest.mark.parametrize("scheme", sorted(SPECS))
def test_no_negative_state(scheme):
    scn = paper_incast(CFG, roll=0)
    res = run(scn, SPECS[scheme], n_steps=4000)
    f = res.final
    assert np.asarray(f.qh).min() >= -1e-3
    assert np.asarray(f.nicq).min() >= -1e-3
    assert np.asarray(f.rate).min() > 0
    assert np.all(np.isfinite(np.asarray(f.rate)))


def test_link_capacity_respected():
    """No flow can beat line rate; no wire can carry above capacity."""
    scn = paper_incast(CFG, roll=1)
    res = run(scn, SPECS["DCQCN_REV"], n_steps=6000)
    assert res.inst_thr.max() <= CFG.link.line_rate * 1.01
    agg_into_dst = res.inst_thr[:, :4].sum(1)  # four flows, one dst port
    assert agg_into_dst.max() <= CFG.link.line_rate * 1.01


# ---------------------------------------------------------------------------
# the paper's claims (§II.B)
# ---------------------------------------------------------------------------

def test_completion_ordering(results_roll0):
    """Fig 2: DCQCN-Rev < PFC < DCQCN completion."""
    ct = {k: r.completion_time() for k, r in results_roll0.items()}
    assert ct["DCQCN_REV"] < ct["PFC_ONLY"] < ct["DCQCN"]


def test_rev_fair_share(results_roll0):
    """Incast flows converge to ~12.5/4 = 3.125 GB/s under DCQCN-Rev."""
    thr = results_roll0["DCQCN_REV"].mean_throughput_while_active()
    fair = CFG.link.line_rate / 4
    np.testing.assert_allclose(thr[:4], fair, rtol=0.08)


def test_rev_protects_victim(results_roll0):
    """Victim does strictly better under Rev than under PFC or DCQCN."""
    v = {k: r.mean_throughput_while_active()[4]
         for k, r in results_roll0.items()}
    assert v["DCQCN_REV"] > 1.5 * v["PFC_ONLY"]
    assert v["DCQCN_REV"] > 2.5 * v["DCQCN"]


def test_dcqcn_marks_victim_ecp_does_not(results_roll0):
    """ECP essentially never marks the victim; CP marks it persistently."""
    m_dcqcn = results_roll0["DCQCN"].marked[:, 4].sum()
    m_rev = results_roll0["DCQCN_REV"].marked[:, 4].sum()
    assert m_rev < 0.2 * m_dcqcn
    assert m_dcqcn > 100


def test_rev_keeps_queues_short():
    """CC drains the congestion tree: standing queues shrink vs PFC."""
    scn = paper_incast(CFG, roll=1)
    q = {}
    for name in ("PFC_ONLY", "DCQCN_REV"):
        res = run(scn, SPECS[name], n_steps=10000)
        # steady-state window: 1.5 - 2.5 ms
        w = (res.times > 1.5e-3) & (res.times < 2.5e-3)
        q[name] = res.max_q[w].mean()
    assert q["DCQCN_REV"] < 0.5 * q["PFC_ONLY"]


def test_fig2_aggregate_disjoint():
    """roll=1 window mode: Rev sustains ~25 GB/s; PFC-only incast HoL
    keeps parking-lot shares; DCQCN underutilises."""
    scn = paper_incast(CFG, roll=1)
    agg = {}
    for name, spec in SPECS.items():
        res = run(scn, spec, n_steps=14000)
        agg[name] = res.mean_throughput_while_active().sum()
    assert agg["DCQCN_REV"] > 24e9        # paper: 25 GB/s
    assert agg["DCQCN"] < 0.8 * agg["DCQCN_REV"]


def test_fig3_pfc_parking_lot():
    """roll=0 PFC: F0/F1 (two hops of contention) do worse than F4/F8."""
    scn = paper_incast(CFG, roll=0)
    res = run(scn, SPECS["PFC_ONLY"], n_steps=14000)
    thr = res.mean_throughput_while_active()
    assert thr[0] < 0.7 * thr[2]
    assert thr[1] < 0.7 * thr[3]
    # and the victim is HoL-degraded far below line rate
    assert thr[4] < 0.35 * CFG.link.line_rate


def test_victim_full_rate_when_disjoint():
    """roll=1: victim reaches ~line rate under Rev (Fig 2's 12.5 GB/s)."""
    scn = paper_incast(CFG, roll=1)
    res = run(scn, SPECS["DCQCN_REV"], n_steps=14000)
    thr = res.mean_throughput_while_active()
    assert thr[4] > 0.97 * CFG.link.line_rate


# ---------------------------------------------------------------------------
# robustness across incast degree
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 8, 16])
def test_rev_fair_share_scales(n):
    scn = incast(CFG, n_senders=n, victim=False)
    res = run(scn, SPECS["DCQCN_REV"], n_steps=10000)
    thr = res.mean_throughput_while_active()
    fair = CFG.link.line_rate / n
    # all senders within 2x of fair share, none starved
    assert thr.min() > 0.3 * fair
    assert thr.max() < min(2.5 * fair, CFG.link.line_rate * 1.01)
