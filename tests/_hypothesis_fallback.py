"""Minimal stand-in for ``hypothesis`` when it isn't installed.

The container image pins its package set, so property tests degrade to a
small deterministic sample sweep instead of failing at collection.  The
API surface covers exactly what this test suite uses: ``given`` with
keyword strategies, ``settings(max_examples=..., deadline=...)``, and
``st.integers`` / ``st.floats`` bounds.
"""

from __future__ import annotations

import functools
import itertools

import numpy as np

HAVE_HYPOTHESIS = False


class _Strategy:
    def __init__(self, samples):
        self.samples = list(samples)


def integers(min_value: int, max_value: int) -> _Strategy:
    lo, hi = int(min_value), int(max_value)
    mid = (lo + hi) // 2
    return _Strategy(dict.fromkeys([lo, mid, hi, lo + 1 if hi > lo else lo]))


def floats(min_value: float, max_value: float) -> _Strategy:
    lo, hi = float(min_value), float(max_value)
    return _Strategy(
        dict.fromkeys([lo, hi, float(np.sqrt(lo * hi)) if lo > 0 else 0.0]))


def sampled_from(values) -> _Strategy:
    return _Strategy(values)


def none() -> _Strategy:
    return _Strategy([None])


def one_of(*strats: _Strategy) -> _Strategy:
    out = []
    for s in strats:
        out.extend(s.samples)
    return _Strategy(dict.fromkeys(out))


class strategies:  # mirrors `from hypothesis import strategies as st`
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    sampled_from = staticmethod(sampled_from)
    none = staticmethod(none)
    one_of = staticmethod(one_of)


def settings(max_examples: int = 10, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(**strats):
    names = sorted(strats)

    def deco(fn):
        # NOTE: no functools.wraps — pytest would introspect the wrapped
        # signature and treat the strategy kwargs as fixtures.
        def wrapper():
            cap = getattr(fn, "_max_examples", 10)
            combos = itertools.product(*(strats[n].samples for n in names))
            for combo in itertools.islice(combos, cap):
                fn(**dict(zip(names, combo)))
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
