"""Integration: fault-tolerant loop (resume/preemption/straggler),
serving engine, optimization-flag equivalence, sharded-context forward."""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, SyntheticLM
from repro.models import ModelConfig
from repro.models import transformer as T
from repro.models.layers import init_params
from repro.optim import AdamWConfig
from repro.serve import ServeConfig, ServingEngine
from repro.train.loop import TrainLoopConfig, train_loop
from repro.train.step import StepConfig, init_train_state, make_train_step

CFG = ModelConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, head_dim=16, d_ff=128, vocab=256)
DATA = DataConfig(vocab=256, seq_len=32, global_batch=4, kind="markov")


def _setup(compress=False, microbatches=1):
    params = init_params(T.param_defs(CFG), 0, jnp.float32)
    sc = StepConfig(opt=AdamWConfig(lr=3e-3), microbatches=microbatches,
                    compress_grads=compress, warmup_steps=5,
                    total_steps=200)
    state = init_train_state(CFG, params, sc)
    return jax.jit(make_train_step(CFG, sc)), state


def test_loop_checkpoint_resume_exact():
    step, state = _setup()
    with tempfile.TemporaryDirectory() as d:
        lc = TrainLoopConfig(total_steps=20, ckpt_dir=d, ckpt_every=10)
        out1 = train_loop(step, state, DATA, lc)
        # uninterrupted run to 30
        lc30 = TrainLoopConfig(total_steps=30, ckpt_dir=None)
        ref = train_loop(step, state, DATA,
                         dataclasses.replace(lc30))
        # resumed run 20 -> 30 must match the uninterrupted trajectory
        out2 = train_loop(step, state, DATA,
                          TrainLoopConfig(total_steps=30, ckpt_dir=d,
                                          ckpt_every=100))
        np.testing.assert_allclose(out2["losses"],
                                   ref["losses"][20:30], rtol=1e-5)


def test_loop_preemption_saves():
    step, state = _setup()
    calls = {"n": 0}

    def stop_flag():
        calls["n"] += 1
        return calls["n"] >= 7

    with tempfile.TemporaryDirectory() as d:
        out = train_loop(step, state, DATA,
                         TrainLoopConfig(total_steps=100, ckpt_dir=d,
                                         ckpt_every=1000),
                         stop_flag=stop_flag)
        from repro.ckpt import latest_step
        assert out["final_step"] < 100
        assert latest_step(d) == out["final_step"]   # graceful save


def test_loop_detects_stragglers(monkeypatch):
    step, state = _setup()
    slow = {"at": 12}
    orig = step

    def wrapped(s, b):
        import time
        out = orig(s, b)
        jax.block_until_ready(out[1]["loss"])
        if slow["at"] == 0:
            time.sleep(0.5)
            slow["at"] = -1
        slow["at"] -= 1
        return out

    out = train_loop(wrapped, state, DATA,
                     TrainLoopConfig(total_steps=20, straggler_factor=3.0))
    assert out["stragglers"] >= 1


def test_compressed_training_matches_uncompressed_trend():
    step_c, state_c = _setup(compress=True)
    step_u, state_u = _setup(compress=False)
    ds = SyntheticLM(DATA)
    for i in range(30):
        state_c, mc = step_c(state_c, ds.batch_at(i))
        state_u, mu = step_u(state_u, ds.batch_at(i))
    assert abs(float(mc["loss"]) - float(mu["loss"])) < 0.3


def test_serving_engine_continuous_batching():
    params = init_params(T.param_defs(CFG), 0, jnp.float32)
    eng = ServingEngine(CFG, params, ServeConfig(batch_slots=2,
                                                 max_len=64))
    prompts = [[3, 4, 5], [7, 8, 9], [11, 12, 13]]   # > slots: 2 waves
    outs = eng.generate(prompts, max_new_tokens=6)
    assert len(outs) == 3
    assert all(1 <= len(o) <= 6 for o in outs)
    # greedy determinism: same prompt -> same continuation
    outs2 = eng.generate([prompts[0]], max_new_tokens=6)
    assert outs2[0] == outs[0]


def test_serving_engine_matches_wave_oracle():
    """With EOS disabled the refill scheduler degenerates to waves:
    outputs must equal the pre-refill wave implementation exactly."""
    params = init_params(T.param_defs(CFG), 0, jnp.float32)
    eng = ServingEngine(CFG, params, ServeConfig(batch_slots=3,
                                                 max_len=64,
                                                 eos_token=-1))
    rng = np.random.RandomState(0)
    prompts = [[int(x) for x in rng.randint(2, 255, 2 + i % 4)]
               for i in range(7)]
    got = eng.generate(prompts, max_new_tokens=6)
    assert eng.stats["refills"] == 0          # EOS never fires
    want = eng._generate_waves(prompts, max_new_tokens=6)
    assert got == want


def test_serving_engine_refills_on_eos():
    """A finished slot is refilled mid-flight, and the refilled
    request's output equals serving it alone with the same left
    padding (rows are independent under the causal position mask)."""
    params = init_params(T.param_defs(CFG), 0, jnp.float32)
    probe = ServingEngine(CFG, params, ServeConfig(batch_slots=2,
                                                   max_len=64,
                                                   eos_token=-1))
    p0, p1, p2 = [3, 4, 5], [7, 8, 9], [11, 12, 13]
    free = probe.generate([p0, p1], max_new_tokens=8)
    eos = free[0][2]                    # row 0's 3rd token becomes EOS
    # precondition: slot 0 must free first, else p2 rides slot 1
    assert eos not in free[1][:free[0].index(eos) + 1]

    eng = ServingEngine(CFG, params, ServeConfig(batch_slots=2,
                                                 max_len=64,
                                                 eos_token=eos))
    outs = eng.generate([p0, p1, p2], max_new_tokens=8)
    assert eng.stats["refills"] >= 1
    assert eng.stats["prefills"] == 1   # p2 rode slot 0, no new wave
    assert outs[0][-1] == eos           # request 0 stopped at EOS
    # p2 entered at the position where slot 0 freed; standalone serve
    # of the same left-padded prompt must reproduce its output
    pos = len(p0) + outs[0].index(eos)
    padded = [0] * (pos - len(p2)) + p2
    solo = eng.generate([padded], max_new_tokens=8)
    assert outs[2] == solo[0][:len(outs[2])]


def test_optimization_flags_preserve_semantics():
    cfg = dataclasses.replace(CFG, block_pattern=("local", "attn"),
                              window=16, softcap_attn=50.0)
    params = init_params(T.param_defs(cfg), 0, jnp.float32)
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, 256, (2, 48)), jnp.int32)
    labs = jnp.asarray(rng.randint(0, 256, (2, 48)), jnp.int32)
    base, _ = T.loss_fn(params, cfg, toks, labs)
    opt_cfg = dataclasses.replace(cfg, attn_impl="blockwise",
                                  attn_block_k=16, loss_chunk=16)
    opt, _ = T.loss_fn(params, opt_cfg, toks, labs)
    np.testing.assert_allclose(float(base), float(opt), rtol=1e-5)


def test_forward_under_mesh_context():
    """shard() constraints must be no-ops-but-valid under a real mesh."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = init_params(T.param_defs(CFG), 0, jnp.float32)
    toks = jnp.zeros((2, 16), jnp.int32)
    with jax.set_mesh(mesh):
        logits, _ = jax.jit(
            lambda p, t: T.forward(p, CFG, t))(params, toks)
    assert np.all(np.isfinite(np.asarray(logits)))
