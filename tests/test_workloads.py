"""Collective-workload generator: structure + compilation to Scenario."""

import numpy as np
import pytest

from repro.core import PAPER_CONFIG, ScenarioSpec, run
from repro.core.workloads import (Workload, all_to_all, bursty, concat,
                                  hotspot, incast_storm, ring_allreduce,
                                  recursive_doubling_allreduce)
from repro.net import FabricSpec

CFG = PAPER_CONFIG


def test_all_to_all_covers_every_ordered_pair_once():
    n = 6
    w = all_to_all(n, 1e6)
    assert w.n_flows == n * (n - 1)
    assert sorted(zip(w.src, w.dst)) == sorted(
        (i, j) for i in range(n) for j in range(n) if i != j)


def test_all_to_all_phases_stagger_starts():
    w = all_to_all(6, 1e6, phases=5, phase_gap=1e-4)
    starts = sorted(set(w.t_start))
    assert len(starts) == 5
    np.testing.assert_allclose(np.diff(starts), 1e-4)
    # fewer phases coalesce shifts but keep every pair
    w2 = all_to_all(6, 1e6, phases=2)
    assert len(set(w2.t_start)) == 2 and w2.n_flows == 30


def test_ring_allreduce_volume_conservation():
    """Unphased ring: n neighbour flows of 2(n-1)/n * S bytes each."""
    n, S = 8, 4e6
    w = ring_allreduce(n, S)
    assert w.n_flows == n
    assert all(d == (s + 1) % n for s, d in zip(w.src, w.dst))
    np.testing.assert_allclose(w.volume, 2 * (n - 1) / n * S)
    # phased variant: 2(n-1) steps x n flows of S/n
    wp = ring_allreduce(n, S, phased=True)
    assert wp.n_flows == 2 * (n - 1) * n
    np.testing.assert_allclose(sum(wp.volume), 2 * (n - 1) * S)


def test_recursive_doubling_partners_xor():
    n = 8
    w = recursive_doubling_allreduce(n, 1e6)
    assert w.n_flows == n * 3                    # log2(8) rounds
    rounds = np.asarray(w.t_start)
    for r, t in enumerate(sorted(set(rounds))):
        sel = rounds == t
        for s, d in zip(np.asarray(w.src)[sel], np.asarray(w.dst)[sel]):
            assert s ^ d == 1 << r
    with pytest.raises(ValueError):
        recursive_doubling_allreduce(6, 1e6)     # not a power of two


def test_incast_storm_fan_in():
    w = incast_storm(12, 3, 64, volume=1e6, seed=3)
    assert w.n_flows == 12
    dsts, counts = np.unique(w.dst, return_counts=True)
    assert len(dsts) == 3 and (counts == 4).all()
    assert not set(w.src) & set(w.dst)           # sinks don't send
    assert all(v == 1e6 for v in w.volume)
    assert all(t == float("inf") for t in w.t_stop)   # equal-work mode


def test_hotspot_mix_tracks_config_line_rate():
    """Hot flows ride the inf sentinel, background the -frac sentinel —
    both must resolve against whatever line rate the config carries."""
    w = hotspot(20, 64, hot_frac=0.6, hot_node=7, bg_rate_frac=0.25,
                seed=1)
    hot = [i for i in range(w.n_flows) if w.dst[i] == 7]
    assert len(hot) == 12
    rates = np.asarray(w.rate)
    assert np.isinf(rates[hot]).all()
    bg = [i for i in range(w.n_flows) if i not in hot]
    assert (rates[bg] == -0.25).all()
    assert all(w.src[i] != w.dst[i] for i in range(w.n_flows))
    import dataclasses
    cfg2 = CFG.replace(link=dataclasses.replace(CFG.link, line_rate=25e9))
    scn = w.spec(fabric=FabricSpec.clos3(4)).build(cfg2)
    assert (scn.gen_rate[hot] == 25e9).all()
    assert (scn.gen_rate[bg] == 0.25 * 25e9).all()


def test_bursty_on_off_windows():
    w = bursty(5, 16, on=0.2e-3, off=0.8e-3, n_bursts=4, seed=2)
    assert w.n_flows == 20
    t0, t1 = np.asarray(w.t_start), np.asarray(w.t_stop)
    np.testing.assert_allclose(t1 - t0, 0.2e-3)
    # bursts of one pair are disjoint and 1 period apart
    for f in range(5):
        s = slice(4 * f, 4 * f + 4)
        np.testing.assert_allclose(np.diff(t0[s]), 1e-3)
        assert len(set(zip(w.src[s.start:s.stop],
                           w.dst[s.start:s.stop]))) == 1


def test_concat_mixes_and_validates():
    a = incast_storm(4, 1, 16, volume=1e6)
    b = hotspot(4, 16)
    m = concat(a, b)
    assert m.n_flows == 8
    assert m.rate is not None and np.isinf(m.rate[0])   # line-rate sentinel
    with pytest.raises(ValueError):
        Workload(src=(0,), dst=(1, 2), t_start=(0.0,), t_stop=(1.0,),
                 volume=(1.0,))


# ---------------------------------------------------------------------------
# compilation to Scenario tensors
# ---------------------------------------------------------------------------

def test_workload_spec_builds_per_flow_tensors():
    fab = FabricSpec.dragonfly(a=2, p=2, h=1)           # 12 hosts
    w = concat(incast_storm(4, 1, 12, volume=3e6),
               bursty(3, 12, n_bursts=2))
    scn = w.spec(fabric=fab).build(CFG)
    F = w.n_flows
    assert scn.routes.shape == (F, 5)
    np.testing.assert_allclose(scn.t_start, w.t_start)
    np.testing.assert_allclose(scn.t_stop, w.t_stop)
    np.testing.assert_allclose(scn.volume, w.volume)
    # inf rate sentinel resolved to the config's line rate
    assert (scn.gen_rate == CFG.link.line_rate).all()
    # per-flow NIC buffers: 2x volume for work-mode flows, the scalar
    # default for window-mode ones
    assert scn.nic_buffer.shape == (F,)
    np.testing.assert_allclose(scn.nic_buffer[:4], 6e6)
    np.testing.assert_allclose(scn.nic_buffer[4:], 4e6)


def test_workload_runs_and_delivers():
    """An incast storm on the tapered fat tree delivers its volume."""
    fab = FabricSpec.fat_tree(4, taper=2)
    w = incast_storm(6, 2, 64, volume=0.5e6, t_start=0.0, seed=5)
    res = run(w.spec(fabric=fab).build(CFG), CFG, n_steps=3000)
    np.testing.assert_allclose(
        np.asarray(res.final.delivered), 0.5e6, rtol=1e-3)


def test_flowspec_length_mismatch_raises():
    spec = ScenarioSpec(kind="flowspec", flow_src=(0, 1), flow_dst=(2,))
    with pytest.raises(ValueError):
        spec.build(CFG)
    spec2 = ScenarioSpec(kind="flowspec", flow_src=(0, 1),
                         flow_dst=(2, 3), flow_t_start=(0.0,))
    with pytest.raises(ValueError):
        spec2.build(CFG)
