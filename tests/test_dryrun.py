"""Dry-run machinery tests on a 1-device mesh with shrunken shape cells.

(The full 512-device sweep runs via `python -m repro.launch.dryrun`;
here we prove the cell builders produce lowerable/compilable programs
for every step kind and that the collective parser works.)
"""

import dataclasses

import jax
import pytest

from repro.configs import get_smoke_config
from repro.configs import common as cfg_common

# NOTE: importing dryrun late (jax already initialised with 1 CPU device;
# its XLA_FLAGS write is inert here by design).
from repro.launch import dryrun

TINY = {
    "train_4k": cfg_common.ShapeCell("train_4k", 64, 4, "train"),
    "prefill_32k": cfg_common.ShapeCell("prefill_32k", 64, 2, "prefill"),
    "decode_32k": cfg_common.ShapeCell("decode_32k", 64, 2, "decode"),
    "long_500k": cfg_common.ShapeCell("long_500k", 128, 1, "decode"),
}


@pytest.fixture(autouse=True)
def tiny_shapes(monkeypatch):
    for k, v in TINY.items():
        monkeypatch.setitem(cfg_common.SHAPES, k, v)
    yield


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch,shape", [
    ("qwen2.5-32b", "train_4k"),
    ("gemma2-27b", "prefill_32k"),
    ("mixtral-8x22b", "decode_32k"),
    ("falcon-mamba-7b", "long_500k"),
    ("whisper-base", "decode_32k"),
    ("internvl2-26b", "train_4k"),
    ("recurrentgemma-9b", "decode_32k"),
])
def test_build_and_compile_cell(arch, shape):
    cfg = get_smoke_config(arch)
    mesh = _mesh()
    with jax.set_mesh(mesh):
        fn, args, donate = dryrun.build_cell(cfg, shape, mesh)
        compiled = jax.jit(fn, donate_argnums=donate).lower(*args).compile()
        mem = compiled.memory_analysis()
        assert mem is not None
        cost = compiled.cost_analysis()
        assert cost.get("flops", 0) > 0


def test_collective_parser():
    hlo = """
  %all-reduce.1 = bf16[8,128] all-reduce(bf16[8,128] %x)
  %ag = f32[64] all-gather(f32[32] %y)
  %rs.2 = f32[16,4]{1,0} reduce-scatter(f32[64,4] %z)
  %notacollective = f32[2] add(f32[2] %a, f32[2] %b)
  %cp-start = u32[4] collective-permute-start(u32[4] %w)
"""
    stats = dryrun.collective_stats(hlo)
    assert stats["all-reduce"]["bytes"] == 8 * 128 * 2
    assert stats["all-gather"]["bytes"] == 64 * 4
    assert stats["reduce-scatter"]["bytes"] == 16 * 4 * 4
    assert stats["collective-permute"]["count"] == 1
    assert stats["total_bytes"] == (8 * 128 * 2 + 256 + 256 + 16)


def test_with_groups_probe_configs():
    cfg = get_smoke_config("gemma2-27b")          # pattern period 2
    probe = dryrun._with_groups(cfg, 2)
    assert probe.scan_layers is False
    assert probe.n_layers == 4                    # 2 groups x period 2
    cfg = get_smoke_config("recurrentgemma-9b")   # period 3, tail 2
    probe = dryrun._with_groups(cfg, 2)
    from repro.models.transformer import layer_plan
    head, pat, n_groups, tail = layer_plan(cfg)
    assert probe.n_layers == len(head) + 2 * len(pat) + len(tail)
