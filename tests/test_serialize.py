"""SimResult / SweepResult wire format: JSON round-trips must be
bit-exact (numpy-free scalars, tagged arrays, dtype-preserving),
decimation/trace-dropping explicit and loud."""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import (CCSpec, PAPER_CONFIG, ScenarioSpec, SimResult,
                        Sweep, run)
from repro.core.serialize import (config_from_dict, config_to_dict,
                                  decode_array, encode_array,
                                  scenario_from_dict, scenario_to_dict)
from repro.core.experiments import SweepResult

N_STEPS = 300


@pytest.fixture(scope="module")
def sim_result():
    spec = ScenarioSpec.incast(3)
    cfg = PAPER_CONFIG
    return run(spec.build(cfg), cfg, n_steps=N_STEPS)


@pytest.fixture(scope="module")
def sweep_result():
    return Sweep([("a", CCSpec(), ScenarioSpec.incast(3)),
                  ("b", CCSpec(reaction="rp"),
                   ScenarioSpec.incast(4))]).run(n_steps=N_STEPS)


def _assert_simresults_equal(a, b):
    for f in ("times", "delivered", "rate", "inst_thr", "max_q",
              "n_paused", "marked", "cnp", "n_nonmin", "ctrl"):
        x, y = getattr(a, f), getattr(b, f)
        assert x.dtype == y.dtype, f
        np.testing.assert_array_equal(x, y, err_msg=f)
    for f in ("pause_time", "vc_stall"):    # optional: None survives
        x, y = getattr(a, f), getattr(b, f)
        if x is None:
            assert y is None, f
        else:
            np.testing.assert_array_equal(x, y, err_msg=f)
    assert a.trace_every == b.trace_every
    fa = {k: np.asarray(v) for k, v in zip(a.final._fields, a.final)
          if not isinstance(v, dict)}
    fb = {k: np.asarray(v) for k, v in zip(b.final._fields, b.final)
          if not isinstance(v, dict)}
    for k in fa:
        assert fa[k].dtype == fb[k].dtype, k
        np.testing.assert_array_equal(fa[k], fb[k], err_msg=k)
    for k in a.final.cc:
        np.testing.assert_array_equal(np.asarray(a.final.cc[k]),
                                      np.asarray(b.final.cc[k]),
                                      err_msg=f"cc.{k}")


def test_array_codec_preserves_dtype():
    for a in (np.arange(6, dtype=np.int32).reshape(2, 3),
              np.float32([[1.5, -0.0]]), np.int32(7).reshape(()),
              np.float64([np.inf])):
        d = json.loads(json.dumps(encode_array(a)))
        b = decode_array(d)
        assert b.dtype == a.dtype and b.shape == a.shape
        np.testing.assert_array_equal(a, b)


def test_simresult_json_roundtrip_bitexact(sim_result):
    wire = json.loads(json.dumps(sim_result.to_dict()))
    back = SimResult.from_dict(wire)
    _assert_simresults_equal(sim_result, back)
    # the final state's step counter keeps its int32 dtype
    assert np.asarray(back.final.t).dtype == np.int32
    # config and scenario survive too: a re-run reproduces the result
    np.testing.assert_array_equal(back.scn.routes, sim_result.scn.routes)
    rerun = run(back.scn, back.cfg, n_steps=N_STEPS)
    np.testing.assert_array_equal(rerun.delivered, sim_result.delivered)


def test_simresult_traceless_and_decimated(sim_result):
    lean = sim_result.to_dict(traces=False)
    assert "delivered" not in lean and "times" not in lean
    with pytest.raises(ValueError, match="trace"):
        SimResult.from_dict(json.loads(json.dumps(lean)))
    # decimation thins every trace array consistently (window-end
    # samples: every k-th, starting at the k-th) and is marked lossy
    dec = json.loads(json.dumps(sim_result.to_dict(decimate=4)))
    np.testing.assert_array_equal(decode_array(dec["times"]),
                                  sim_result.times[3::4])
    np.testing.assert_array_equal(decode_array(dec["delivered"]),
                                  sim_result.delivered[3::4])
    assert dec["trace_every"] == sim_result.trace_every * 4
    with pytest.raises(ValueError, match="trace"):
        SimResult.from_dict(dec)


def test_simresult_victim_metrics_survive_roundtrip(sim_result):
    """The PFC-pathology numbers are wire-format first-class: the
    decoded result reports the same victim/pause metrics, and a blob
    predating the counters degrades to the documented NaN/None."""
    assert sim_result.scn.victim is not None        # incast designates one
    wire = json.loads(json.dumps(sim_result.to_dict()))
    back = SimResult.from_dict(wire)
    np.testing.assert_equal(back.victim_slowdown(),
                            sim_result.victim_slowdown())
    np.testing.assert_equal(back.pause_duration(),
                            sim_result.pause_duration())
    np.testing.assert_array_equal(back.vc_stall_time(),
                                  sim_result.vc_stall_time())
    # pre-counter blob: optional trace fields absent, not zero-filled
    old = dict(wire)
    del old["pause_time"], old["vc_stall"]
    legacy = SimResult.from_dict(old)
    assert legacy.pause_time is None and legacy.vc_stall is None
    assert np.isnan(legacy.pause_duration())
    assert legacy.vc_stall_time() is None
    assert legacy.summary()["vc_stall_s"] is None


def test_scenario_roundtrip_vc_and_victim():
    """A multi-VC scenario with designated victims keeps its ``vc`` and
    ``victim`` tensors (dtype and all) through the wire format."""
    from repro.core.params import LinkParams
    from repro.core.workloads import hol_victim_incast
    from repro.net import FabricSpec
    cfg = CCSpec(link=LinkParams(n_vcs=2))
    wl = hol_victim_incast(4, 64)
    wl = dataclasses.replace(wl, vc=(0,) * 4 + (1,))
    scn = wl.spec(fabric=FabricSpec.clos3(4)).build(cfg)
    assert scn.vc is not None and scn.victim is not None
    back = scenario_from_dict(
        json.loads(json.dumps(scenario_to_dict(scn))))
    for f, v in zip(scn._fields, scn):
        w = getattr(back, f)
        if v is None:
            assert w is None, f
        elif isinstance(v, (int, float)):
            assert w == v, f
        else:
            assert np.asarray(w).dtype == np.asarray(v).dtype, f
            np.testing.assert_array_equal(np.asarray(w),
                                          np.asarray(v), err_msg=f)


def test_sweepresult_json_roundtrip_bitexact(sweep_result):
    wire = json.loads(json.dumps(sweep_result.to_dict()))
    back = SweepResult.from_dict(wire)
    assert [p.name for p in back.points] == \
        [p.name for p in sweep_result.points]
    for name, res in sweep_result.items():
        _assert_simresults_equal(res, back[name])
    for name, row in sweep_result.summary().items():
        got = back.summary()[name]
        for k, v in row.items():
            np.testing.assert_equal(got[k], v,            # nan == nan
                                    err_msg=f"{name}.{k}")


def test_config_roundtrip_spec_and_legacy():
    spec = CCSpec(reaction="swift").replace(
        rev=dataclasses.replace(CCSpec().rev, erp_settle=0.93))
    back = config_from_dict(json.loads(json.dumps(config_to_dict(spec))))
    assert back == spec
    legacy = PAPER_CONFIG
    back2 = config_from_dict(
        json.loads(json.dumps(config_to_dict(legacy))))
    assert back2 == legacy


def test_scenario_roundtrip_multipath():
    scn = ScenarioSpec.incast(3, n_paths=2).build(CCSpec())
    back = scenario_from_dict(
        json.loads(json.dumps(scenario_to_dict(scn))))
    for f, v in zip(scn._fields, scn):
        w = getattr(back, f)
        if v is None:
            assert w is None, f
        elif isinstance(v, (int, float)):
            assert w == v, f
        else:
            assert np.asarray(w).dtype == np.asarray(v).dtype, f
            np.testing.assert_array_equal(np.asarray(w),
                                          np.asarray(v), err_msg=f)
