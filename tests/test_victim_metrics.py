"""Victim-flow metrics, by hand and end-to-end.

The closed-form half builds a synthetic ``SimResult`` whose traces are
chosen so every PFC-pathology metric has an exact pencil-and-paper
value (victim slowdown 4.0, pause wire-seconds 4 µs, per-VC stall
split) — the metric code is arithmetic over traces, so it is tested as
arithmetic.  The end-to-end half runs the HoL-victim scenario and
asserts the paper's headline ordering: DCQCN-Rev spares the victim,
DCQCN collaterally marks it, PFC-only head-of-line blocks it.
"""

import dataclasses
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import CCSpec, Sweep
from repro.core.params import LinkParams
from repro.core.simulator import SimResult
from repro.core.workloads import hol_victim_incast
from repro.net import FabricSpec

LINE = 12.5e9
T, F = 4, 3


def _mini_result(*, victim, pause_time=None, vc_stall=None) -> SimResult:
    """Synthetic 3-flow window-mode result: flows 0/1 run at line rate,
    flow 2 at line/4 — slowdowns exactly [1, 1, 4]."""
    cfg = CCSpec(link=LinkParams(line_rate=LINE))
    times = (np.arange(T) + 1) * cfg.sim.dt
    scn = SimpleNamespace(
        gen_rate=np.full(F, LINE),   # f64: keep ideal/thr exact
        t_start=np.zeros(F),
        t_stop=np.full(F, (T + 1) * cfg.sim.dt),
        volume=np.full(F, np.inf),
        victim=None if victim is None else np.asarray(victim, bool),
    )
    inst_thr = np.tile([LINE, LINE, LINE / 4], (T, 1))
    delivered = np.cumsum(inst_thr * cfg.sim.dt, axis=0)
    zeros = np.zeros((T, F))
    return SimResult(
        cfg=cfg, scn=scn, times=times, delivered=delivered,
        rate=np.tile([LINE, LINE, LINE / 4], (T, 1)),
        inst_thr=inst_thr, max_q=np.zeros(T), n_paused=np.zeros(T),
        marked=zeros, cnp=zeros, n_nonmin=np.zeros(T),
        final=SimpleNamespace(offered=np.full(F, 1.0),
                              delivered=delivered[-1]),
        ctrl=zeros, trace_every=1,
        pause_time=pause_time, vc_stall=vc_stall)


def test_victim_slowdown_closed_form():
    res = _mini_result(victim=[False, False, True])
    np.testing.assert_allclose(res.flow_slowdowns(), [1.0, 1.0, 4.0])
    assert res.victim_slowdown() == 4.0
    assert res.summary()["victim_slowdown"] == 4.0


def test_victim_slowdown_degrades_to_nan():
    assert np.isnan(_mini_result(victim=None).victim_slowdown())
    assert np.isnan(
        _mini_result(victim=[False, False, False]).victim_slowdown())
    # padding rows (gen_rate 0) never count as victims
    res = _mini_result(victim=[False, False, True])
    res.scn.gen_rate = np.asarray([LINE, LINE, 0.0], np.float32)
    assert np.isnan(res.victim_slowdown())


def test_pause_duration_closed_form():
    pt = np.asarray([0.0, 1.5e-6, 2.5e-6, 0.0])
    res = _mini_result(victim=None, pause_time=pt)
    assert res.pause_duration() == pytest.approx(4e-6, rel=1e-12)
    assert res.summary()["pause_s"] == pytest.approx(4e-6, rel=1e-12)
    # traces predating the counter degrade, not crash
    assert np.isnan(_mini_result(victim=None).pause_duration())


def test_vc_stall_closed_form():
    vs = np.asarray([[0.0, 0.0], [1e-6, 0.0], [0.0, 2e-6], [1e-6, 3e-6]])
    res = _mini_result(victim=None, vc_stall=vs)
    np.testing.assert_allclose(res.vc_stall_time(), [2e-6, 5e-6])
    assert res.summary()["vc_stall_s"] == pytest.approx([2e-6, 5e-6])
    legacy = _mini_result(victim=None)
    assert legacy.vc_stall_time() is None
    assert legacy.summary()["vc_stall_s"] is None


# ---------------------------------------------------------------------------
# end-to-end: the HoL-victim scenario separates the three schemes
# ---------------------------------------------------------------------------

SCHEME_SPECS = {
    "PFC_ONLY": CCSpec(marking="cp", notification="np", reaction="pfc"),
    "DCQCN": CCSpec(marking="cp", notification="np", reaction="rp"),
    "DCQCN_REV": CCSpec(marking="ecp", notification="enp", reaction="erp"),
}


@pytest.fixture(scope="module")
def hol_results():
    spec = hol_victim_incast(4, 64).spec(fabric=FabricSpec.clos3(4))
    res = Sweep.grid(configs=SCHEME_SPECS, scenarios={"hol": spec}).run(
        n_steps=5000)
    return {s: res[f"{s}/hol"] for s in SCHEME_SPECS}


def test_hol_victim_ordering(hol_results):
    """The ISSUE's acceptance ordering: the victim is spared by Rev's
    fair-grant marking, collaterally marked by DCQCN's step marking,
    and head-of-line blocked hardest by PFC alone."""
    vic = {s: r.victim_slowdown() for s, r in hol_results.items()}
    assert vic["DCQCN_REV"] < vic["DCQCN"] < vic["PFC_ONLY"], vic
    # Rev keeps the victim essentially unharmed; PFC-only at least
    # doubles its finish time — margins, not just ordering
    assert vic["DCQCN_REV"] < 1.1
    assert vic["PFC_ONLY"] > 1.5


def test_hol_victim_pause_accounting(hol_results):
    """PFC-only resolves the incast by pausing wires; the CC schemes
    barely pause at all.  vc_stall is the per-VC split of pause_s."""
    pause = {s: r.pause_duration() for s, r in hol_results.items()}
    assert pause["PFC_ONLY"] > pause["DCQCN"]
    assert pause["PFC_ONLY"] > pause["DCQCN_REV"]
    for s, r in hol_results.items():
        stall = r.vc_stall_time()
        assert stall.shape == (1,)
        np.testing.assert_allclose(stall.sum(), pause[s], rtol=1e-5)


def test_vc_escape_frees_the_hol_victim():
    """Pinning the victim to its own virtual channel defeats the
    head-of-line block: per-VC PFC pauses the incast lane, not the
    victim's — the tentpole's whole point, measured."""
    wl = hol_victim_incast(4, 64)
    wl_vc = dataclasses.replace(wl, vc=(0,) * 4 + (1,))
    cfg1 = SCHEME_SPECS["PFC_ONLY"]
    cfg2 = cfg1.replace(link=LinkParams(n_vcs=2))
    fab = FabricSpec.clos3(4)
    r1 = Sweep.grid(configs={"v1": cfg1},
                    scenarios={"hol": wl.spec(fabric=fab)}).run(n_steps=5000)
    r2 = Sweep.grid(configs={"v2": cfg2},
                    scenarios={"hol": wl_vc.spec(fabric=fab)}).run(
        n_steps=5000)
    v1 = r1["v1/hol"].victim_slowdown()
    v2 = r2["v2/hol"].victim_slowdown()
    assert v2 < v1 - 0.1, (v1, v2)
    # and the stall moved onto the incast's channel, not the victim's
    stall = r2["v2/hol"].vc_stall_time()
    assert stall.shape == (2,)
    assert stall[0] >= stall[1]
