"""Preemption hardening for repro.ckpt: a writer killed mid-checkpoint
must never corrupt the restore path.

The commit protocol is temp-dir + fsync + atomic rename + a fsync'd
``.done`` marker, so every possible kill point leaves either (a) no
trace, (b) an ignorable ``.tmp`` orphan, or (c) a fully committed
checkpoint.  ``load_checkpoint`` additionally *verifies* on read: a
checkpoint that is committed but unreadable (disk corruption) is
skipped with its reason collected, never fatal while an older good
step exists.
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (CheckpointManager, committed_steps,
                        latest_step, load_checkpoint, save_checkpoint)

TREE = {"a": jnp.arange(6.0), "b": {"c": jnp.ones((2, 3))}}


def _corrupt(path: str, data: bytes = b"torn") -> None:
    with open(path, "wb") as f:
        f.write(data)


def test_committed_steps_ignores_unmarked_dirs(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, TREE)
    save_checkpoint(d, 2, TREE)
    # a step dir without its .done marker = a kill between rename and
    # commit; it must be invisible
    os.remove(os.path.join(d, "step_000000002.done"))
    assert committed_steps(d) == [1]
    assert latest_step(d) == 1


def test_load_skips_torn_newest_checkpoint(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, TREE, extra={"v": 1})
    save_checkpoint(d, 2, TREE, extra={"v": 2})
    # newest committed but its arrays are garbage (disk corruption)
    _corrupt(os.path.join(d, "step_000000002", "arrays.npz"))
    got, extra = load_checkpoint(d)
    assert extra["v"] == 1
    np.testing.assert_array_equal(got["a"], np.arange(6.0))


def test_load_skips_torn_manifest(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, TREE, extra={"v": 1})
    save_checkpoint(d, 2, TREE, extra={"v": 2})
    _corrupt(os.path.join(d, "step_000000002", "manifest.json"),
             b'{"truncated')
    got, extra = load_checkpoint(d)
    assert extra["v"] == 1


def test_explicit_uncommitted_step_is_an_error(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 3, TREE)
    os.remove(os.path.join(d, "step_000000003.done"))
    with pytest.raises(FileNotFoundError, match="torn write"):
        load_checkpoint(d, step=3)


def test_all_torn_reports_every_reason(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, TREE)
    _corrupt(os.path.join(d, "step_000000001", "arrays.npz"))
    with pytest.raises(FileNotFoundError, match="step 1"):
        load_checkpoint(d)


def test_save_overwrites_stale_tmp_orphan(tmp_path):
    """A previous writer died mid-write leaving step_N.tmp: a retry of
    the same step must succeed and commit cleanly."""
    d = str(tmp_path)
    tmp = os.path.join(d, "step_000000005.tmp")
    os.makedirs(tmp)
    with open(os.path.join(tmp, "garbage"), "w") as f:
        f.write("partial")
    save_checkpoint(d, 5, TREE, extra={"ok": True})
    got, extra = load_checkpoint(d, step=5)
    assert extra["ok"] is True
    np.testing.assert_array_equal(got["b"]["c"], np.ones((2, 3)))


def test_manifest_lists_arrays_and_extra_survives(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, TREE, extra={"digest": "abc"})
    with open(os.path.join(d, "step_000000001",
                           "manifest.json")) as f:
        mf = json.load(f)
    assert mf["extra"]["digest"] == "abc"


def test_manager_save_async_commits_atomically(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=2)
    for s in (1, 2, 3):
        mgr.save_async(s, {"x": jnp.full((4,), float(s))},
                       extra={"s": s})
    mgr.wait()
    assert committed_steps(d) == [2, 3]
    got, extra = load_checkpoint(d)
    assert extra["s"] == 3
    assert float(got["x"][0]) == 3.0
