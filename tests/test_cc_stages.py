"""Composable CC-stage API: registry mechanics, construction-time
validation, the three new stage variants (slope / fncc / swift), and
the acceptance property — a mixed stage matrix riding ONE jit."""

import dataclasses

import numpy as np
import pytest

from repro.core import (CCScheme, CCSpec, DCQCNParams, LinkParams,
                        PAPER_CONFIG, ScenarioSpec, SimParams, Sweep, cc,
                        run)

SCENE = ScenarioSpec.paper_incast(roll=0, t_start=0.1e-3, t_stop=1.2e-3)


# ---------------------------------------------------------------------------
# construction-time validation
# ---------------------------------------------------------------------------

def test_pfc_thresholds_validated():
    with pytest.raises(ValueError, match="XOFF.*XON|pfc_xoff"):
        LinkParams(pfc_xoff_frac=0.4, pfc_xon_frac=0.5)
    with pytest.raises(ValueError, match="pfc_xoff"):
        LinkParams(pfc_xoff_frac=0.5, pfc_xon_frac=0.5)
    LinkParams(pfc_xoff_frac=0.51, pfc_xon_frac=0.5)     # ok


def test_marking_ramp_validated():
    with pytest.raises(ValueError, match="kmin.*kmax"):
        DCQCNParams(kmin=16 * 1024.0, kmax=15 * 1024.0)
    DCQCNParams(kmin=15 * 1024.0, kmax=15 * 1024.0)      # step: ok


def test_unknown_stage_names_raise():
    with pytest.raises(ValueError, match="unknown marking stage"):
        CCSpec(marking="nope")
    with pytest.raises(ValueError, match="unknown notification stage"):
        CCSpec(notification="nope")
    with pytest.raises(ValueError, match="unknown reaction stage"):
        CCSpec(reaction="nope")
    with pytest.raises(ValueError, match="unknown routing"):
        CCSpec(routing="nope")


def test_adaptive_routing_needs_multipath_scenario():
    """routing != 'min' on a single-path scenario must raise instead of
    silently degenerating to minimal routing — in run() AND in Sweep."""
    cfg = PAPER_CONFIG.replace(routing="ugal")
    scn = SCENE.build(cfg)                     # n_paths = 1
    with pytest.raises(ValueError, match="multi-path"):
        run(scn, cfg, n_steps=10)
    with pytest.raises(ValueError, match="multi-path"):
        Sweep([("p", cfg, scn)])
    # multi-path scenario: fine
    multi = ScenarioSpec.permutation(
        8, seed=0, n_paths=4, t_stop=0.3e-3).build(cfg)
    Sweep([("p", cfg, multi)])


# ---------------------------------------------------------------------------
# registry mechanics
# ---------------------------------------------------------------------------

def test_builtin_codes_are_frozen():
    assert [s.name for s in cc.MARKING.stages()] == ["cp", "ecp", "slope"]
    assert [s.name for s in cc.NOTIFICATION.stages()] == \
        ["np", "enp", "fncc"]
    assert [s.name for s in cc.REACTION.stages()] == \
        ["pfc", "rp", "erp", "swift"]
    assert cc.MARKING.code("cp") == 0 and cc.REACTION.code("swift") == 3


def test_register_rejects_duplicates_and_param_conflicts():
    reg = cc.StageRegistry("test")
    reg.register("a", step=lambda p, c, s: ((), {}),
                 params={"shared": lambda spec: 1.0})
    with pytest.raises(ValueError, match="already registered"):
        reg.register("a", step=lambda p, c, s: ((), {}))
    reg.register("b", step=lambda p, c, s: ((), {}),
                 params={"shared": lambda spec: 2.0})
    with pytest.raises(ValueError, match="conflicting"):
        reg.device_params(PAPER_CONFIG.to_spec())


def test_registered_state_rides_fluid_state():
    """Every stage's init_state contributes to FluidState.cc with [F]
    leaves, for every config (the pytree must be sweep-stable)."""
    from repro.core.fluid import init_state
    scn = SCENE.build(PAPER_CONFIG)
    st = init_state(scn, PAPER_CONFIG)
    assert set(st.cc) == {"slope_acc", "swift_cool"}
    for v in st.cc.values():
        assert v.shape == (scn.routes.shape[0],)


# ---------------------------------------------------------------------------
# slope marking (kmin < kmax ramp, pmax)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def slope_vs_cp():
    ramp = DCQCNParams(kmin=15 * 1024.0, kmax=90 * 1024.0, pmax=0.3)
    base = CCSpec(notification="enp", reaction="erp", dcqcn=ramp)
    res = Sweep.grid(
        configs={"cp": base.replace(marking="cp"),
                 "slope": base.replace(marking="slope")},
        scenarios={"hol": SCENE}).run(n_steps=2500)
    return res


def test_slope_marks_probabilistically(slope_vs_cp):
    """With a real kmin<kmax ramp and pmax<1, slope marking thins the
    mark stream relative to step marking at the same kmin — but still
    marks (the loop stays closed) and still controls the queue."""
    cp, slope = slope_vs_cp["cp/hol"], slope_vs_cp["slope/hol"]
    m_cp, m_slope = int(cp.marked.sum()), int(slope.marked.sum())
    assert 0 < m_slope < 0.8 * m_cp, (m_slope, m_cp)
    # queue stays bounded well below the PFC pause point
    assert float(slope.max_q.max()) < 0.9 * 512 * 1024


def test_slope_with_step_params_degenerates_to_cp():
    """kmin == kmax (the paper's V) makes the ramp a step of p=1 — the
    error-diffusion accumulator fires every step, so slope == cp
    bit-exactly (the shim's safety net for default params)."""
    base = CCSpec(notification="enp", reaction="erp")
    res = Sweep.grid(
        configs={"cp": base.replace(marking="cp"),
                 "slope": base.replace(marking="slope")},
        scenarios={"hol": SCENE}).run(n_steps=1200)
    a, b = res["cp/hol"], res["slope/hol"]
    for f in ("delivered", "rate", "marked", "cnp", "max_q"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), f)


# ---------------------------------------------------------------------------
# FNCC in-path notification
# ---------------------------------------------------------------------------

def _first_event_ms(res, field) -> float:
    ev = np.asarray(getattr(res, field)).sum(axis=1) > 0
    return float(res.times[np.argmax(ev)]) if ev.any() else np.inf


@pytest.fixture(scope="module")
def fncc_vs_enp():
    # 0.1 us integrator: the CNP feedback delay spans ~23 steps, so the
    # in-path shortcut is resolvable (at dt = 1 us the whole RTT rounds
    # down to the 2-step floor and fncc == enp by construction)
    sim = SimParams(dt=1e-7, trace_every=1)
    base = CCSpec(marking="ecp", reaction="erp", sim=sim)
    scene = ScenarioSpec.paper_incast(roll=0, t_start=0.02e-3,
                                      t_stop=0.5e-3)
    return Sweep.grid(
        configs={"enp": base.replace(notification="enp"),
                 "fncc": base.replace(notification="fncc")},
        scenarios={"hol": scene}).run(n_steps=2500)


def test_fncc_feedback_arrives_earlier(fncc_vs_enp):
    """Same marking stream, but the first CNP lands strictly earlier
    through the in-path return than through the end-to-end echo."""
    enp, fncc = fncc_vs_enp["enp/hol"], fncc_vs_enp["fncc/hol"]
    t_mark_enp = _first_event_ms(enp, "marked")
    t_mark_fncc = _first_event_ms(fncc, "marked")
    assert t_mark_enp == t_mark_fncc          # detection unchanged
    t_enp, t_fncc = _first_event_ms(enp, "cnp"), \
        _first_event_ms(fncc, "cnp")
    assert np.isfinite(t_enp) and np.isfinite(t_fncc)
    assert t_fncc < t_enp, (t_fncc, t_enp)


def test_fncc_never_slower_than_rtt(fncc_vs_enp):
    """The shortened delay is clipped to [2 steps, rtt] — peak queue
    under faster feedback must not blow past the end-to-end variant's
    by more than noise (the loop is strictly tighter)."""
    enp, fncc = fncc_vs_enp["enp/hol"], fncc_vs_enp["fncc/hol"]
    assert float(fncc.max_q.max()) <= 1.1 * float(enp.max_q.max())


# ---------------------------------------------------------------------------
# swift delay-target reaction
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def swift_res():
    base = CCSpec(marking="ecp", notification="enp")
    return Sweep.grid(
        configs={"swift": base.replace(reaction="swift"),
                 "pfc": base.replace(reaction="pfc"),
                 "swift_np": base.replace(reaction="swift",
                                          notification="np")},
        scenarios={"hol": SCENE}).run(n_steps=2500)


def test_swift_throttles_on_delay_not_marks(swift_res):
    """The delay-target reaction must actually throttle (rates fall
    below line) and keep queues far below the uncontrolled PFC-only
    run — despite never consuming a CNP."""
    swift, pfc = swift_res["swift/hol"], swift_res["pfc/hol"]
    line = PAPER_CONFIG.link.line_rate
    assert float(np.asarray(swift.final.rate)[:4].max()) < 0.6 * line
    assert float(np.asarray(pfc.final.rate).min()) >= line * 0.99
    assert float(swift.max_q.max()) < 0.75 * float(pfc.max_q.max())
    assert float(np.asarray(swift.final.delivered).sum()) > 0


def test_swift_is_notification_independent(swift_res):
    """Swapping the notification stage under swift changes which CNPs
    fly, but not a single delivered byte or rate sample — reaction
    composability is real, not nominal."""
    a, b = swift_res["swift/hol"], swift_res["swift_np/hol"]
    for f in ("delivered", "rate", "inst_thr", "max_q"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), f)


def test_swift_kernel_matches_jnp():
    """use_kernels routes swift through its Pallas kernel (interpret
    mode on CPU) — exact f32 equality against the jnp stage."""
    cfg = CCSpec(reaction="swift")
    scn = SCENE.build(cfg)
    a = run(scn, cfg, n_steps=600)
    b = run(scn, cfg, n_steps=600, use_kernels=True, interpret=True)
    for f in ("delivered", "rate", "max_q"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), f)
    np.testing.assert_array_equal(
        np.asarray(a.final.cc["swift_cool"]),
        np.asarray(b.final.cc["swift_cool"]))


# ---------------------------------------------------------------------------
# acceptance: a mixed stage matrix rides ONE jit
# ---------------------------------------------------------------------------

def test_mixed_stage_matrix_one_jit_no_recompile():
    """>= 3 marking x 2 notification x 3 reaction variants — including
    slope, fncc and swift — in a single Sweep launch with exactly one
    executable build, and the stage axes must be live (outputs differ
    across combinations)."""
    from repro.core.experiments import SWEEP_EXEC_CACHE
    ramp = DCQCNParams(kmin=15 * 1024.0, kmax=90 * 1024.0, pmax=0.3)
    combos = [(m, n, r)
              for m in ("cp", "ecp", "slope")
              for n in ("enp", "fncc")
              for r in ("rp", "erp", "swift")]
    configs = {f"{m}+{n}+{r}": CCSpec(marking=m, notification=n,
                                      reaction=r, dcqcn=ramp)
               for m, n, r in combos}
    sweep = Sweep.grid(configs=configs, scenarios={"hol": SCENE})
    before = SWEEP_EXEC_CACHE.stats()
    res = sweep.run(n_steps=1200)
    assert (SWEEP_EXEC_CACHE.stats() - before).misses <= 1, \
        "mixed stage matrix must share one compiled executable"
    assert len(res) == 18
    delivered = {name: round(float(np.asarray(r.final.delivered).sum()))
                 for name, r in res.items()}
    # marking axis live (under erp), notification axis live via mark
    # counts, reaction axis live
    assert delivered["cp+enp+erp/hol"] != delivered["ecp+enp+erp/hol"]
    assert delivered["ecp+enp+erp/hol"] != delivered["ecp+enp+swift/hol"]
    marks = {name: int(r.marked.sum()) for name, r in res.items()}
    assert marks["slope+enp+erp/hol"] != marks["cp+enp+erp/hol"]


def test_mixed_stage_matrix_one_jit_on_kernel_tiers():
    """The kernel tiers keep the one-jit property across the same
    3 x 2 x 3 mixed matrix: the flow tier's prepacked SMEM param rows
    are built from *traced* params (hoisted out of the scan, once per
    trace), and the megakernel dispatches stages by traced codes inside
    one pallas_call — so each tier resolves to exactly one executable
    build, and the megakernel's 18 combos match the jnp engine bit for
    bit."""
    import jax
    from repro.core.experiments import SWEEP_EXEC_CACHE
    ramp = DCQCNParams(kmin=15 * 1024.0, kmax=90 * 1024.0, pmax=0.3)
    configs = {f"{m}+{n}+{r}": CCSpec(marking=m, notification=n,
                                      reaction=r, dcqcn=ramp)
               for m in ("cp", "ecp", "slope")
               for n in ("enp", "fncc")
               for r in ("rp", "erp", "swift")}
    sweep = Sweep.grid(configs=configs, scenarios={"hol": SCENE})
    base = sweep.run(n_steps=600)
    for tier in (True, "mega"):
        before = SWEEP_EXEC_CACHE.stats()
        res = sweep.run(n_steps=600, use_kernels=tier, interpret=True)
        assert (SWEEP_EXEC_CACHE.stats() - before).misses <= 1, \
            f"use_kernels={tier!r} must build one executable for the " \
            f"whole mixed matrix"
        if tier == "mega":
            for a, b in zip(jax.tree.leaves((base.traces, base.final)),
                            jax.tree.leaves((res.traces, res.final))):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b))


def test_shim_and_spec_share_the_one_jit():
    """Legacy CCConfig points and CCSpec points can ride the same
    launch — the shim is a mapping, not a second code path."""
    cfg = PAPER_CONFIG.replace(scheme=CCScheme.DCQCN)
    spec = CCSpec(marking="cp", notification="np", reaction="rp")
    res = Sweep([("legacy", cfg, SCENE), ("spec", spec, SCENE)]).run(
        n_steps=1200)
    np.testing.assert_array_equal(res["legacy"].delivered,
                                  res["spec"].delivered)


def test_config_grid_sweeps_stage_params():
    """Dotted-path grids reach the new stage param groups too."""
    from repro.core import config_grid
    grid = config_grid(CCSpec(reaction="swift"),
                       **{"swift.target_delay": [2e-6, 8e-6]})
    res = Sweep.grid(configs=grid, scenarios={"hol": SCENE}).run(
        n_steps=1500)
    qs = [float(r.max_q.max()) for _, r in res.items()]
    assert qs[0] < qs[1]        # tighter delay target -> smaller queues


def test_ccspec_is_frozen_and_replaceable():
    s = CCSpec()
    assert s.name == "ecp+enp+erp"
    s2 = s.replace(marking="slope",
                   dcqcn=DCQCNParams(kmax=60 * 1024.0))
    assert s2.marking == "slope" and s.marking == "ecp"
    with pytest.raises(dataclasses.FrozenInstanceError):
        s.marking = "cp"
