"""Mechanism-ablation invariants (beyond-paper §Ablation)."""

import pytest

from repro.core import CCConfig, CCScheme, paper_incast_volume, run


@pytest.fixture(scope="module")
def results():
    out = {}
    for marking, reaction in [("cp", "rp"), ("ecp", "rp"),
                              ("cp", "erp"), ("ecp", "erp")]:
        cfg = CCConfig(scheme=CCScheme.DCQCN, marking=marking,
                       reaction=reaction)
        res = run(paper_incast_volume(cfg, roll=0), cfg, n_steps=16000)
        out[(marking, reaction)] = res
    return out


def test_every_mechanism_improves_on_dcqcn(results):
    base = results[("cp", "rp")].completion_time()
    for combo in [("ecp", "rp"), ("cp", "erp"), ("ecp", "erp")]:
        assert results[combo].completion_time() < base


def test_ecp_is_load_bearing(results):
    """Accurate marking alone must recover most of Rev's gain."""
    dcqcn = results[("cp", "rp")].completion_time()
    ecp_only = results[("ecp", "rp")].completion_time()
    rev = results[("ecp", "erp")].completion_time()
    gain_full = dcqcn - rev
    gain_ecp = dcqcn - ecp_only
    assert gain_ecp > 0.8 * gain_full


def test_erp_cannot_fix_bad_marking(results):
    """ERP on mis-marked victims settles them at the wrong fair share."""
    v_cp_erp = results[("cp", "erp")].mean_throughput_while_active()[4]
    v_rev = results[("ecp", "erp")].mean_throughput_while_active()[4]
    assert v_cp_erp < 0.6 * v_rev
    # and the victim keeps getting marked without ECP
    assert results[("cp", "erp")].marked[:, 4].sum() > \
        5 * results[("ecp", "erp")].marked[:, 4].sum()


def test_scheme_equivalence():
    """(cp, rp) override == plain DCQCN; (ecp, erp) == plain Rev."""
    import numpy as np
    cfg_a = CCConfig(scheme=CCScheme.DCQCN)
    cfg_b = CCConfig(scheme=CCScheme.DCQCN_REV, marking="cp",
                     reaction="rp")
    ra = run(paper_incast_volume(cfg_a, roll=0), cfg_a, n_steps=4000)
    rb = run(paper_incast_volume(cfg_b, roll=0), cfg_b, n_steps=4000)
    np.testing.assert_allclose(ra.delivered[-1], rb.delivered[-1],
                               rtol=1e-5)
