"""Per-kernel correctness: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel body on CPU) + hypothesis properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # image without hypothesis: deterministic sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.cc_step import erp_step, gen_np_step, rp_step
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention

RNG = np.random.RandomState(0)


def _qkv(b, t, s, h, kv, d, dtype):
    q = jnp.asarray(RNG.randn(b, t, h, d), dtype) * 0.3
    k = jnp.asarray(RNG.randn(b, s, kv, d), dtype) * 0.3
    v = jnp.asarray(RNG.randn(b, s, kv, d), dtype) * 0.3
    return q, k, v


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # b, t, h, kv, d, causal, window, softcap, bq, bk
    (1, 128, 4, 2, 64, True, None, 0.0, 64, 64),
    (2, 256, 8, 8, 64, True, None, 50.0, 64, 64),
    (1, 200, 4, 1, 64, True, 64, 0.0, 64, 64),       # ragged + window
    (2, 128, 6, 2, 128, False, None, 0.0, 64, 64),   # encoder
    (1, 512, 4, 2, 64, True, 128, 30.0, 128, 128),
    (1, 96, 2, 2, 32, True, 32, 0.0, 32, 64),
    (1, 80, 4, 4, 64, True, None, 0.0, 64, 64),      # ragged tail block
]


@pytest.mark.parametrize(
    "b,t,h,kv,d,causal,window,cap,bq,bk", FLASH_CASES)
def test_flash_matches_ref_f32(b, t, h, kv, d, causal, window, cap, bq, bk):
    q, k, v = _qkv(b, t, t, h, kv, d, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=cap, block_q=bq, block_k=bk,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.bfloat16, 2e-2),
                                       (jnp.float32, 3e-5)])
def test_flash_dtypes(dtype, tol):
    q, k, v = _qkv(1, 128, 128, 4, 2, 64, dtype)
    out = flash_attention(q, k, v, interpret=True, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol)


def test_flash_block_shape_invariance():
    """Result must not depend on the chosen BlockSpec tiling."""
    q, k, v = _qkv(1, 256, 256, 4, 2, 64, jnp.float32)
    outs = [flash_attention(q, k, v, window=96, block_q=bq, block_k=bk,
                            interpret=True)
            for bq, bk in [(32, 32), (64, 128), (128, 64), (256, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,kv,d,cap,bk", [
    (2, 256, 8, 2, 64, 0.0, 128),
    (1, 1000, 4, 1, 64, 50.0, 256),     # ragged
    (3, 128, 16, 8, 128, 0.0, 64),
    (1, 64, 4, 4, 32, 0.0, 64),
])
def test_decode_matches_ref(b, s, h, kv, d, cap, bk):
    q = jnp.asarray(RNG.randn(b, h, d), jnp.float32) * 0.3
    k = jnp.asarray(RNG.randn(b, s, kv, d), jnp.float32) * 0.3
    v = jnp.asarray(RNG.randn(b, s, kv, d), jnp.float32) * 0.3
    valid = jnp.asarray(RNG.rand(b, s) > 0.3)
    out = decode_attention(q, k, v, valid, softcap=cap, block_k=bk,
                           interpret=True)
    want = ref.decode_attention_ref(q, k, v, valid, softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def test_decode_ring_mask_single_survivor():
    """Degenerate mask: only one valid slot -> output == its value row."""
    b, s, h, kv, d = 1, 64, 4, 2, 32
    q = jnp.asarray(RNG.randn(b, h, d), jnp.float32)
    k = jnp.asarray(RNG.randn(b, s, kv, d), jnp.float32)
    v = jnp.asarray(RNG.randn(b, s, kv, d), jnp.float32)
    valid = jnp.zeros((b, s), bool).at[0, 17].set(True)
    out = decode_attention(q, k, v, valid, interpret=True, block_k=32)
    want = jnp.repeat(v[0, 17], h // kv, 0).reshape(1, h, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# cc_step (the paper's RP/ERP at scale)
# ---------------------------------------------------------------------------

def _rp_params(dt=1e-6):
    return ref.RPParams(g=1 / 256, rate_decrease=0.5, timer_T=55e-6,
                        byte_B=10e6, rai=5e6, rhai=25e6, fr_stages=5,
                        min_rate=1e6, line_rate=12.5e9, dt=dt)


# F values straddle every _pad_to_grid boundary: sub-lane (1, 5, 127),
# one-over-lane (129, 130), exactly one grid block (8192), one-over-block
# (8193), and multi-block ragged (100_001).
@pytest.mark.parametrize("F", [1, 5, 127, 129, 130, 8192, 8193, 100_001])
def test_rp_kernel_matches_ref(F):
    r = np.random.RandomState(F)
    st = ref.RPState(
        rate=jnp.asarray(r.rand(F) * 12.5e9, jnp.float32),
        target=jnp.asarray(r.rand(F) * 12.5e9, jnp.float32),
        alpha=jnp.asarray(r.rand(F), jnp.float32),
        byte_cnt=jnp.asarray(r.rand(F) * 10e6, jnp.float32),
        tmr=jnp.asarray(r.rand(F) * 55e-6, jnp.float32),
        alpha_tmr=jnp.asarray(r.rand(F) * 55e-6, jnp.float32),
        bc_stage=jnp.asarray(r.randint(0, 8, F), jnp.float32),
        t_stage=jnp.asarray(r.randint(0, 8, F), jnp.float32))
    cnp = jnp.asarray(r.rand(F) > 0.6)
    out = rp_step(st, cnp, _rp_params(), interpret=True)
    want = ref.rp_update_ref(st, cnp, _rp_params())
    for a, b, name in zip(out, want, ref.RPState._fields):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                                   err_msg=f"F={F} {name}")


@pytest.mark.parametrize("F", [1, 127, 129, 8193, 50_000])
def test_erp_kernel_matches_ref(F):
    r = np.random.RandomState(7)
    p = ref.ERPParams(settle=0.98, hold=50e-6, min_rate=1e6,
                      line_rate=12.5e9, dt=1e-6)
    args = (jnp.asarray(r.rand(F) * 12.5e9, jnp.float32),
            jnp.asarray(r.rand(F) * 1e-4, jnp.float32),
            jnp.asarray(r.rand(F) > 0.5),
            jnp.asarray(r.rand(F) * 12.5e9, jnp.float32),
            jnp.full((F,), 5e12, jnp.float32))
    r1, h1 = erp_step(*args, p, interpret=True)
    r2, h2 = ref.erp_update_ref(*args, p)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-6)


@pytest.mark.parametrize("F", [1, 127, 129, 8193, 50_000])
def test_swift_kernel_matches_ref(F):
    """Delay-target reaction kernel vs its jnp oracle (exact f32 —
    the fluid step's swift stage routes through this behind
    use_kernels, so drift here is drift in the sweep)."""
    from repro.kernels.cc_step import swift_step
    r = np.random.RandomState(11)
    p = ref.SwiftKParams(target=3e-6, beta=0.8, ai=1e12, guard=25e-6,
                         min_rate=1e6, line_rate=12.5e9, dt=1e-6)
    rate = jnp.asarray(r.rand(F) * 12.5e9, jnp.float32)
    cool = jnp.asarray(np.where(r.rand(F) > 0.5, r.rand(F) * 5e-5, 0.0),
                       jnp.float32)
    qd = jnp.asarray(np.where(r.rand(F) > 0.3, r.rand(F) * 2e-5, 0.0),
                     jnp.float32)
    r1, c1 = swift_step(rate, cool, qd, p, interpret=True)
    r2, c2 = ref.swift_update_ref(
        rate, cool, qd, target=p.target, beta=p.beta, ai=p.ai,
        guard=p.guard, min_rate=p.min_rate, line_rate=p.line_rate,
        dt=p.dt)
    assert np.array_equal(np.asarray(r1), np.asarray(r2)), F
    assert np.array_equal(np.asarray(c1), np.asarray(c2)), F


@pytest.mark.parametrize("F", [1, 127, 129, 8193])
def test_gen_np_kernel_matches_jnp(F):
    """Fused generation + notification-timer kernel vs the fluid step's
    phase-1/5a arithmetic (exact, incl. inf volumes / buffers)."""
    r = np.random.RandomState(F)
    nicq = jnp.asarray(r.rand(F) * 1e6, jnp.float32)
    offered = jnp.asarray(r.rand(F) * 1e7, jnp.float32)
    dropped = jnp.asarray(r.rand(F) * 1e5, jnp.float32)
    np_tmr = jnp.asarray(r.rand(F) * 1e-4, jnp.float32)
    gen_rate = jnp.asarray(r.rand(F) * 12.5e9, jnp.float32)
    t_start = jnp.asarray(r.rand(F) * 2e-3, jnp.float32)
    t_stop = jnp.asarray(
        np.where(r.rand(F) > 0.5, r.rand(F) * 3e-3, np.inf), jnp.float32)
    volume = jnp.asarray(
        np.where(r.rand(F) > 0.5, r.rand(F) * 2e7, np.inf), jnp.float32)
    nic_buffer = jnp.asarray(
        np.where(r.rand(F) > 0.3, 4e6, np.inf), jnp.float32)
    t_sec, dt = jnp.float32(1.2e-3), jnp.float32(1e-6)
    got = gen_np_step(nicq, offered, dropped, np_tmr, gen_rate, t_start,
                      t_stop, volume, nic_buffer, t_sec=t_sec, dt=dt,
                      interpret=True)
    active = (t_sec >= t_start) & (t_sec < t_stop)
    gen = jnp.where(active, gen_rate, 0.0) * dt
    gen = jnp.minimum(gen, jnp.maximum(volume - offered, 0.0))
    q = nicq + gen
    over = jnp.maximum(q - nic_buffer, 0.0)
    want = (q - over, offered + gen - over, dropped + over, np_tmr + dt)
    for g, w, name in zip(got, want,
                          ("nicq", "offered", "dropped", "np_tmr")):
        assert np.array_equal(np.asarray(g), np.asarray(w)), (F, name)


def test_cc_kernels_accept_traced_params():
    """CC constants are SMEM data, not compile-time floats: jitting over
    traced params must work and vary the result without recompiling."""
    F = 300
    r = np.random.RandomState(3)
    rate = jnp.asarray(r.rand(F) * 12.5e9, jnp.float32)
    hold = jnp.zeros((F,), jnp.float32)
    cnp = jnp.asarray(r.rand(F) > 0.5)
    tgt = jnp.asarray(r.rand(F) * 12.5e9, jnp.float32)
    slope = jnp.full((F,), 5e12, jnp.float32)

    calls = []

    @jax.jit
    def f(settle):
        calls.append(None)       # traces once per shape, not per value
        p = ref.ERPParams(settle=settle, hold=jnp.float32(50e-6),
                          min_rate=jnp.float32(1e6),
                          line_rate=jnp.float32(12.5e9),
                          dt=jnp.float32(1e-6))
        return erp_step(rate, hold, cnp, tgt, slope, p, interpret=True)

    r1, _ = f(jnp.float32(0.98))
    r2, _ = f(jnp.float32(0.50))
    assert len(calls) == 1
    assert not np.array_equal(np.asarray(r1), np.asarray(r2))
    want, _ = ref.erp_update_ref(
        rate, hold, cnp, tgt, slope,
        ref.ERPParams(0.5, 50e-6, 1e6, 12.5e9, 1e-6))
    np.testing.assert_allclose(np.asarray(r2), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------------------
# fluid_step megakernel: whole-step parity off the tile grid + under vmap
# ---------------------------------------------------------------------------

def _mega_scn(F):
    """F same-shaped flows on the legacy CLOS — F straddles the lane /
    block boundaries the per-flow kernels pad to (1, 127, 129, 8193),
    so the megakernel's lifted (1, F) layouts see ragged shapes."""
    from repro.core import PAPER_CONFIG, ScenarioSpec
    pairs = [(i % 16, 16 + (i * 5) % 16) for i in range(F)]
    spec = ScenarioSpec.flows(pairs, t_start=0.0, t_stop=0.5e-3,
                              label=f"mega{F}")
    return spec.build(PAPER_CONFIG), PAPER_CONFIG


def _assert_states_equal(fa, fb, ctx):
    la = jax.tree_util.tree_flatten_with_path(fa)[0]
    lb = jax.tree_util.tree_flatten_with_path(fb)[0]
    assert len(la) == len(lb)
    for (pa, ga), (pb, gb) in zip(la, lb):
        assert pa == pb
        assert np.array_equal(np.asarray(ga), np.asarray(gb)), \
            (ctx, jax.tree_util.keystr(pa))


@pytest.mark.parametrize("F", [1, 127, 129, 8193])
def test_megakernel_matches_scat_off_tile_grid(F):
    """Whole-step megakernel vs the scatter engine at non-tile-aligned
    flow counts: exact equality of state and step trace after a short
    jitted run (mirrors the rp/erp ragged-shape sweeps above, but for
    the fused whole-step kernel)."""
    from repro.core.fluid import init_state, make_step_fn
    scn, cfg = _mega_scn(F)
    n = 5 if F > 1000 else 20
    finals, traces = [], []
    for kw in (dict(reduce="scat"),
               dict(use_kernels="mega", interpret=True)):
        step = jax.jit(make_step_fn(scn, cfg, **kw))
        st = init_state(scn, cfg)
        for _ in range(n):
            st, tr = step(st)
        finals.append(st)
        traces.append(tr)
    _assert_states_equal(finals[0], finals[1], f"mega-F{F}-final")
    _assert_states_equal(traces[0], traces[1], f"mega-F{F}-trace")


def test_megakernel_under_vmap_on_sweep_run_axis():
    """vmap over the Sweep run axis must batch straight through the
    megakernel's pallas_call: a 3-point sweep (mixed schemes) through
    ``use_kernels="mega"`` equals the scatter engine bit for bit."""
    from repro.core import CCScheme, PAPER_CONFIG, ScenarioSpec, Sweep
    spec = ScenarioSpec.paper_incast(roll=0, t_start=0.1e-3,
                                     t_stop=1.2e-3)
    sweep = Sweep.grid(
        {s.name: PAPER_CONFIG.replace(scheme=s) for s in CCScheme},
        {"inc": spec})
    ra = sweep.run(n_steps=60, trace_every=10, reduce="scat")
    rb = sweep.run(n_steps=60, trace_every=10, use_kernels="mega",
                   interpret=True)
    _assert_states_equal(ra.traces, rb.traces, "mega-vmap-traces")
    _assert_states_equal(ra.final, rb.final, "mega-vmap-final")


def test_megakernel_vmem_guard_refuses_oversized_state():
    """Off interpret mode the launcher enforces the VMEM budget: a
    state+scenario footprint beyond ~14 MiB must be refused with the
    block-size pointer, not handed to the compiler."""
    from repro.kernels.fluid_step import (MEGA_VMEM_CAP, mega_footprint,
                                          megastep)
    from repro.core.fluid import scenario_device, step_body_fn, \
        init_state, step_params
    scn, cfg = _mega_scn(127)
    st = init_state(scn, cfg)
    sd = scenario_device(scn)
    assert 0 < mega_footprint(st, sd) < MEGA_VMEM_CAP
    big = st._replace(
        qh=jnp.zeros((MEGA_VMEM_CAP // 8 + 1, 2), jnp.float32))
    body = step_body_fn(dt=float(cfg.sim.dt),
                        n_switches=int(scn.n_switches))
    with pytest.raises(ValueError, match="VMEM"):
        megastep(big, sd, step_params(cfg), body=body, interpret=False)


# ---------------------------------------------------------------------------
# hypothesis property tests (system invariants)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(t=st.integers(8, 96), h=st.sampled_from([2, 4]),
       kv=st.sampled_from([1, 2]), window=st.one_of(
           st.none(), st.integers(4, 64)))
def test_flash_rows_are_convex_combinations(t, h, kv, window):
    """softmax(QK)V rows lie inside the convex hull of V rows: the output
    max must never exceed V's max (and min symmetric)."""
    if h % kv:
        h = kv
    q, k, v = _qkv(1, t, t, h, kv, 32, jnp.float32)
    # fresh randomness per example is fine; convexity is shape-independent
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=32, block_k=32, interpret=True)
    assert float(out.max()) <= float(v.max()) + 1e-4
    assert float(out.min()) >= float(v.min()) - 1e-4


@settings(max_examples=20, deadline=None)
@given(f=st.integers(1, 300), frac=st.floats(0, 1))
def test_rp_rates_stay_in_bounds(f, frac):
    """RP invariant: rates remain within [min_rate, line_rate] under any
    CNP pattern (no runaway, no starvation)."""
    r = np.random.RandomState(f)
    p = _rp_params()
    st_ = ref.RPState(
        rate=jnp.asarray(r.rand(f) * 12.5e9 + 1e6, jnp.float32),
        target=jnp.asarray(r.rand(f) * 12.5e9 + 1e6, jnp.float32),
        alpha=jnp.asarray(r.rand(f), jnp.float32),
        byte_cnt=jnp.zeros((f,), jnp.float32),
        tmr=jnp.zeros((f,), jnp.float32),
        alpha_tmr=jnp.zeros((f,), jnp.float32),
        bc_stage=jnp.zeros((f,), jnp.float32),
        t_stage=jnp.zeros((f,), jnp.float32))
    for i in range(5):
        cnp = jnp.asarray(r.rand(f) < frac)
        st_ = ref.rp_update_ref(st_, cnp, p)
    assert float(st_.rate.min()) >= p.min_rate - 1
    assert float(st_.rate.max()) <= p.line_rate + 1
    assert np.all(np.isfinite(np.asarray(st_.rate)))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100))
def test_erp_cnp_sets_rate_to_fair_share(seed):
    """ERP invariant: a CNP pins the rate to settle*target immediately."""
    r = np.random.RandomState(seed)
    F = 64
    p = ref.ERPParams(settle=0.98, hold=50e-6, min_rate=1e6,
                      line_rate=12.5e9, dt=1e-6)
    rate = jnp.asarray(r.rand(F) * 12.5e9, jnp.float32)
    tgt = jnp.asarray(r.rand(F) * 12.5e9 + 2e6, jnp.float32)
    cnp = jnp.ones((F,), bool)
    new_rate, _ = ref.erp_update_ref(
        rate, jnp.zeros((F,)), cnp, tgt, jnp.full((F,), 5e12), p)
    np.testing.assert_allclose(
        np.asarray(new_rate),
        np.clip(0.98 * np.asarray(tgt), 1e6, 12.5e9), rtol=1e-6)
