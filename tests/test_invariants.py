"""Invariant / property harness over the CC-stage registry product.

Every (marking x notification x reaction) combo registered in
``repro.core.cc`` — 36 with the built-ins — must satisfy the fluid
model's physical invariants on randomized fabrics and workloads, at
one VC and at several:

  * byte conservation — every offered byte is delivered, waiting in a
    NIC backlog, or queued in the fabric (f32 accumulation tolerance);
  * queue sanity — no negative queues, and the hottest port stays
    within the per-port buffer (PFC's whole job);
  * PFC hysteresis legality — a queue's pause rises only at XOFF and
    re-enables only below XON (checked step-by-step against a host
    mirror of the per-(wire, VC) backlog reduction);
  * reaction rate clamps — flow rates stay in (0, line_rate].

Each sampled point runs the full 36-combo product as ONE Sweep launch
(the stage registry is traced data), so the harness scales by
scenarios, not by configs.  Runs under hypothesis when available, else
the deterministic fallback sweep (tests/_hypothesis_fallback.py).
"""

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # image without hypothesis: deterministic sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import CCSpec, Sweep, cc
from repro.core.fluid import init_state, make_step_fn
from repro.core.params import LinkParams
from repro.core.workloads import (group_shift, hol_victim_incast, hotspot,
                                  incast_storm)
from repro.net import FabricSpec

N_STEPS = 300


def _stage_product() -> list:
    return [(m, n, r) for m in cc.MARKING.names()
            for n in cc.NOTIFICATION.names()
            for r in cc.REACTION.names()]


def test_stage_product_covers_the_advertised_grid():
    """The built-in registries multiply out to (at least) the 36 combos
    this harness claims to cover; shrinkage means a stage went missing."""
    assert len(_stage_product()) >= 36


# ---------------------------------------------------------------------------
# property sweep: invariants across the full stage product
# ---------------------------------------------------------------------------

def _fabric(kind: str) -> FabricSpec:
    return (FabricSpec.dragonfly(a=2, p=2, h=2) if kind == "dfly"
            else FabricSpec.fat_tree(4, taper=2))


def _workload(kind: str, seed: int, n_nodes: int):
    t0, t1 = 0.05e-3, 2e-3
    if kind == "gshift":
        return group_shift(n_nodes // 4, 4, t_start=t0, t_stop=t1)
    if kind == "storm":
        return incast_storm(min(8, n_nodes - 2), 2, n_nodes, seed=seed,
                            t_start=t0, t_stop=t1)
    return hotspot(8, n_nodes, seed=seed, t_start=t0, t_stop=t1)


#: (fabric, workload, seed, n_vcs) — the fallback runs all of these;
#: hypothesis additionally shuffles which it visits per run.
SAMPLES = [
    ("dfly", "gshift", 0, 1),
    ("ft", "storm", 1, 1),
    ("ft", "hot", 2, 2),
    ("dfly", "storm", 0, 2),
    ("ft", "storm", 3, 2),
    ("dfly", "hot", 1, 1),
]


def _check_point(name: str, res, cfg) -> None:
    f = res.final
    offered = np.asarray(f.offered)
    acct = (np.asarray(f.delivered) + np.asarray(f.nicq)
            + np.asarray(f.qh).sum(1))
    np.testing.assert_allclose(acct, offered, rtol=1e-4, atol=1e3,
                               err_msg=f"{name}: bytes not conserved")
    assert np.asarray(f.qh).min() >= -1e-3, name
    assert np.asarray(f.nicq).min() >= -1e-3, name
    # PFC keeps the hottest port inside its buffer (xoff sits at 75%
    # with headroom for one step of in-flight arrivals)
    assert res.max_q.max() <= cfg.link.port_buffer, \
        (name, float(res.max_q.max()))
    # reaction rate clamps: positive, never above line rate
    rate = np.asarray(res.rate)
    assert rate.min() > 0.0, name
    assert rate.max() <= cfg.link.line_rate * (1 + 1e-5), \
        (name, float(rate.max()))
    assert np.isfinite(np.asarray(f.rate)).all(), name


@settings(max_examples=6, deadline=None)
@given(sample=st.sampled_from(SAMPLES))
def test_invariants_hold_across_stage_product(sample):
    fab_kind, wl_kind, seed, n_vcs = sample
    fab = _fabric(fab_kind)
    spec = _workload(wl_kind, seed, fab.n_nodes).spec(
        fabric=fab, label=f"{fab_kind}/{wl_kind}/{seed}")
    link = LinkParams(n_vcs=n_vcs)
    configs = {f"{m}+{n}+{r}": CCSpec(marking=m, notification=n,
                                      reaction=r, link=link)
               for m, n, r in _stage_product()}
    res = Sweep.grid(configs=configs, scenarios={"wl": spec}).run(
        n_steps=N_STEPS)
    assert len(res.names) == len(configs)
    for name in res.names:
        _check_point(f"{sample}/{name}", res[name],
                     configs[name.rsplit("/", 1)[0]])


# ---------------------------------------------------------------------------
# PFC hysteresis legality: step-level check against a host-side mirror
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_vcs", [1, 2])
def test_pfc_hysteresis_legality(n_vcs):
    """Pause transitions obey the hysteresis band, per (wire, VC) queue.

    Replays the scan host-side: after every step, the per-queue backlog
    B is recomputed from ``qh`` exactly as phase 3 does (sum over
    non-final hops into ``route * n_vcs + vc``), and each pause
    transition is checked — a rise demands B at/above the queue's XOFF
    threshold, a fall demands B at/below XON (small f32 reduction-order
    epsilon).  The shared-pool escape hatch is excluded by construction:
    the scenario's total queued bytes stay far under ``pool_xoff``.
    """
    cfg = CCSpec(marking="cp", notification="np", reaction="pfc",
                 link=LinkParams(n_vcs=n_vcs))
    wl = hol_victim_incast(4, 64, t_start=0.1e-3, victim_delay=0.2e-3,
                           burst_delay=0.3e-3, t_stop=1.5e-3)
    scn = wl.spec(fabric=FabricSpec.clos3(4)).build(cfg)
    V = n_vcs
    L = scn.capacity.shape[0]
    routes = np.asarray(scn.routes)                       # [F, H]
    hops = np.asarray(scn.hops)
    vc = (np.zeros_like(routes) if scn.vc is None
          else np.asarray(scn.vc)[:, 0, :])
    F, H = routes.shape
    holds = (np.arange(H)[None, :] < (hops[:, None] - 1)) & (routes >= 0)
    qidx = np.where(holds, routes * V + vc, L * V)        # scratch at S

    xoff = cfg.link.port_buffer * cfg.link.pfc_xoff_frac / V
    xon = cfg.link.port_buffer * cfg.link.pfc_xon_frac / V
    eps = 16.0                                            # f32 sum reorder

    step = jax.jit(make_step_fn(scn, cfg))
    st = init_state(scn, cfg)
    prev_paused = np.asarray(st.paused)
    saw_rise = saw_fall = False
    for t in range(2000):   # past t_stop: drain forces pause-fall edges
        st, _ = step(st)
        paused = np.asarray(st.paused)
        assert ((paused == 0.0) | (paused == 1.0)).all(), t
        B = np.zeros(L * V + 1)
        np.add.at(B, qidx.ravel(),
                  np.where(holds, np.asarray(st.qh), 0.0).ravel())
        assert B.sum() < cfg.link.shared_buffer * cfg.link.pfc_xoff_frac
        rise = (paused > prev_paused)
        fall = (paused < prev_paused)
        assert (B[:L * V][rise] >= xoff - eps).all(), \
            (t, B[:L * V][rise].min())
        assert (B[:L * V][fall] <= xon + eps).all(), \
            (t, B[:L * V][fall].max())
        saw_rise |= bool(rise.any())
        saw_fall |= bool(fall.any())
        prev_paused = paused
    # vacuous-truth guard: the scenario must actually exercise both edges
    assert saw_rise and saw_fall


def test_pfc_hysteresis_band_is_inert():
    """A queue parked between XON and XOFF holds its pause state — the
    hysteresis, not the instantaneous level, decides (unit-level check
    of the phase-3 update rule on crafted backlogs)."""
    import jax.numpy as jnp
    xoff, xon = 384.0, 256.0
    B = jnp.asarray([300.0, 300.0, 400.0, 100.0])
    prev = jnp.asarray([1.0, 0.0, 0.0, 1.0])
    nxt = jnp.where(B > xoff, 1.0, jnp.where(B < xon, 0.0, prev))
    np.testing.assert_array_equal(np.asarray(nxt), [1.0, 0.0, 1.0, 0.0])
