"""Multi-process bootstrap for the jax.distributed fleet backend.

Thin, idempotent wrappers over ``jax.distributed`` so fleet code can
ask "who am I / how many of us are there" without caring whether the
run is single-process (the answer is then (0, 1)) or a real
multi-controller job.
"""

from __future__ import annotations

import jax

_initialized = [False]


def init_processes(coordinator_address: str | None = None,
                   num_processes: int | None = None,
                   process_id: int | None = None) -> tuple[int, int]:
    """Join (or start) the distributed runtime; returns (pid, nproc).

    Idempotent — a second call is a no-op.  With all-None arguments
    jax reads the cluster env vars (as on TPU pods); explicit arguments
    drive the test harness's ``127.0.0.1`` two-process jobs.
    """
    if not _initialized[0]:
        jax.distributed.initialize(
            coordinator_address=coordinator_address,
            num_processes=num_processes, process_id=process_id)
        _initialized[0] = True
    return process_info()


def process_info() -> tuple[int, int]:
    """(process_id, process_count); (0, 1) when uninitialized."""
    try:
        return jax.process_index(), jax.process_count()
    except RuntimeError:          # backend not initialized yet
        return 0, 1
