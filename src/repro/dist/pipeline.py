"""Pipeline parallelism: microbatched stage execution.

``pipeline_apply`` threads M microbatches through S stacked stages.  The
schedule is the standard synchronous pipeline: each microbatch traverses
the stages in order (a ``lax.scan`` over the stage axis), microbatches
are mapped on the outer axis.  On a 1-device mesh this degenerates to
sequential execution; the cross-stage ``collective_permute`` ring (stages
sharded over ``axis``) is layered on once sweeps shard over real meshes.
"""

from __future__ import annotations

import jax


def pipeline_apply(stage, params, xs, mesh, *, n_stages: int,
                   axis: str = "pod"):
    """Apply ``n_stages`` stacked stages to M microbatches.

    Args:
      stage:    ``stage(stage_params, x) -> y`` with y shaped like x.
      params:   stage-stacked pytree; every leaf's leading dim is S.
      xs:       [M, ...] microbatches.
      mesh:     mesh owning ``axis`` (stage placement; unused for S=1).
      n_stages: S; must match the params stacking.
      axis:     mesh axis the stages live on.

    Returns [M, ...] outputs, equal to running the stages back-to-back
    on each microbatch.
    """
    leading = {x.shape[0] for x in jax.tree.leaves(params)}
    if leading != {n_stages}:
        raise ValueError(
            f"params leading dims {leading} != n_stages {n_stages}")
    if axis not in getattr(mesh, "axis_names", (axis,)):
        raise ValueError(f"mesh has no axis {axis!r}")

    def through_stages(x):
        def body(y, p):
            return stage(p, y), None
        y, _ = jax.lax.scan(body, x, params)
        return y

    return jax.lax.map(through_stages, xs)
