"""Logical-axis sharding rules.

Model code names array dimensions with *logical* axes ("batch", "mlp",
"fsdp", ...); this module maps them onto the physical mesh axes of
``repro.launch.mesh`` (pod / data / model).  The mapping degrades
gracefully: a rule whose mesh axes are absent, already taken, or do not
divide the dimension falls back to replication, so the same model code
runs on 1 CPU device and on the 512-way production mesh.

  * ``pspec(dims, shape, rules, mesh)``  -> PartitionSpec
  * ``logical_sharding(dims, shape, mesh)`` -> NamedSharding
  * ``shard(x, *dims)``  -> with_sharding_constraint under the ambient
    mesh (no-op outside any mesh, e.g. single-device tests)
"""

from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec


# logical axis -> ordered mesh axes it may shard over.  Batch-like axes
# span pod x data (DP across the DCN and inside the pod); weight fan-in
# shards over data (FSDP); heads/ffn/vocab/experts shard over model (TP).
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "cache_batch": ("pod", "data"),
    # the Sweep engine's run axis (independent (config, scenario)
    # points): batch-like, spans DP axes
    "run": ("pod", "data"),
    "fsdp": ("data",),
    "vocab": ("model",),
    "embed": (),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "act_heads": ("model",),
    "act_embed": (),
    "experts": ("model",),
    "seq": (),
    "kv_seq": (),
    "state": (),
    "conv": (),
}


def _mesh_sizes(mesh) -> dict[str, int]:
    return dict(mesh.shape)


def pspec(dims, shape, rules, mesh) -> PartitionSpec:
    """PartitionSpec for logical ``dims`` of an array of ``shape``.

    Each entry of ``dims`` is a logical axis name or None.  A logical
    axis shards over the subset of its rule's mesh axes that exist in
    ``mesh`` and are not already used by an earlier dim — but only when
    their combined size divides the dimension; otherwise the dim is
    replicated (None).
    """
    sizes = _mesh_sizes(mesh)
    used: set[str] = set()
    out = []
    for name, dim in zip(dims, shape):
        axes = tuple(a for a in rules.get(name or "", ())
                     if a in sizes and a not in used)
        total = math.prod(sizes[a] for a in axes) if axes else 1
        if not axes or total == 1 or dim % total != 0:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes if len(axes) > 1 else axes[0])
    return PartitionSpec(*out)


def logical_sharding(dims, shape, mesh) -> NamedSharding:
    """NamedSharding for ``dims`` under DEFAULT_RULES."""
    return NamedSharding(mesh, pspec(dims, shape, DEFAULT_RULES, mesh))


def _ambient_mesh():
    try:
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # noqa: BLE001 — any jax-internal drift => no mesh
        return None


def sweep_mesh(n_devices: int | None = None, axis: str = "run"):
    """1-axis device mesh for ``Sweep.run(mesh=...)``.

    Takes the first ``n_devices`` local devices (all by default) on one
    axis named ``axis``; the Sweep engine shards its run batch over
    every axis of whatever mesh it is given, so any custom mesh works —
    this is just the common single-axis spelling.
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(f"n_devices={n} outside 1..{len(devs)}")
    import numpy as np
    return jax.sharding.Mesh(np.array(devs[:n]), (axis,))


def shard(x, *dims):
    """Constrain ``x``'s sharding by logical dims under the ambient mesh.

    Inside a ``with jax.set_mesh(mesh):`` scope this lowers to
    with_sharding_constraint; with no mesh (unit tests, single device)
    it is the identity, so model code can call it unconditionally.
    """
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, logical_sharding(dims, x.shape, mesh))
