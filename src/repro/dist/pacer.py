"""ERP-paced collective scheduling on the modelled fabric.

Training traffic is the framework's own congestion workload: a cross-pod
gradient reduction is an incast of chunked flows into each pod's DCN
ports.  ``erp_chunk_schedule`` runs that incast (plus a victim tenant)
through the CC fluid model and returns the chunk completion schedule a
NIC rate-limiter would be programmed with — the paper's mechanism applied
to the collectives the serving/training stack emits.

Built on ``repro.core.experiments``: every scheme evaluation is one
point of a Sweep, so repeated calls with the same chunk count share a
single compiled executable (the scheme and chunk sizes are data).
"""

from __future__ import annotations

import math

import jax
import numpy as np

from repro.core.experiments import ScenarioSpec, Sweep
from repro.core.params import CCConfig, CCScheme


def chunk_bytes_of(tree, n_chunks: int) -> list[int]:
    """Partition a pytree's total byte size into ``n_chunks`` quanta.

    The quanta are the injection units a NIC pacer schedules; they cover
    the tree exactly (sum == total bytes) and differ by at most one byte.
    """
    if n_chunks <= 0:
        raise ValueError("n_chunks must be positive")
    leaves = jax.tree.leaves(tree)
    total = sum(int(np.prod(x.shape, dtype=np.int64)) * x.dtype.itemsize
                for x in leaves)
    base, rem = divmod(total, n_chunks)
    return [base + (1 if i < rem else 0) for i in range(n_chunks)]


def _schedule_scenario(chunks, n_pods: int, cfg: CCConfig) -> ScenarioSpec:
    """One flow per (pod-pair, chunk) into the reducing pod's port, plus
    the victim tenant of the paper's scene."""
    n_senders = max(2, 4 * max(1, n_pods - 1))
    dst = 16
    senders = [n for n in range(64) if n != dst][:n_senders]
    pairs = [(senders[i % n_senders], dst) for i in range(len(chunks))]
    pairs.append((3, 12))                       # victim tenant
    vols = list(chunks) + [float("inf")]
    spec = ScenarioSpec.flows(pairs, t_start=0.0, t_stop=float("inf"),
                              label="reduce")
    scn = spec.build(cfg)
    # per-flow volumes: chunks are unequal in general
    volume = np.asarray(vols, np.float32)
    t_stop = np.where(np.isfinite(volume), np.inf, 2e-3).astype(np.float32)
    return scn._replace(volume=volume,
                        t_stop=t_stop,
                        nic_buffer=float(2 * max(max(chunks), 1)))


def erp_chunk_schedule(chunks, n_pods: int = 2,
                       scheme_name: str = "DCQCN_REV",
                       cfg: CCConfig | None = None) -> dict:
    """Schedule a chunked cross-pod reduction under one CC scheme.

    Returns the collective's completion time, the per-chunk completion
    schedule (what the pacer programs), and the victim tenant's
    bandwidth while the reduction is in flight.
    """
    if cfg is None:
        cfg = CCConfig(scheme=CCScheme[scheme_name])
    else:
        cfg = cfg.replace(scheme=CCScheme[scheme_name])
    chunks = [max(int(c), 1) for c in chunks]
    scn = _schedule_scenario(chunks, n_pods, cfg)
    # Horizon: all concurrent chunk flows share the reducing port, so the
    # fair drain is line_rate / n_concurrent; x3 slack covers DCQCN's slow
    # staged recovery (the scheme under test may be far off fair).
    n_concurrent = min(len(chunks), max(2, 4 * max(1, n_pods - 1)))
    horizon = 3.0 * max(chunks) * n_concurrent / cfg.link.line_rate + 2e-3
    n_steps = int(math.ceil(horizon / cfg.sim.dt / 1000.0)) * 1000
    res = Sweep([("reduce", cfg, scn)]).run(n_steps=n_steps)["reduce"]
    ct = res.completion_times()
    chunk_ct = ct[: len(chunks)]
    victim = res.mean_throughput_while_active()[-1]
    done = float(np.nanmax(chunk_ct)) if np.isfinite(chunk_ct).any() \
        else float("nan")
    return {
        "scheme": scheme_name,
        "completion_ms": done * 1e3,
        "chunks": [float(c) * 1e3 for c in np.nan_to_num(chunk_ct)],
        "victim_gbps": float(victim) / 1e9,
        "bytes": int(sum(chunks)),
    }
