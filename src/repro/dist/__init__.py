"""repro.dist — distribution substrate: sharding rules, the ERP-paced
collective scheduler, and pipeline parallelism.

Public surface:
  * sharding: shard / logical_sharding / pspec / DEFAULT_RULES /
    sweep_mesh (run-axis mesh for sharded Sweeps)
  * pacer:    chunk_bytes_of / erp_chunk_schedule
  * pipeline: pipeline_apply
"""

from . import _compat  # noqa: F401  (installs jax API shims; must be first)
from .sharding import (DEFAULT_RULES, logical_sharding, pspec, shard,
                       sweep_mesh)

__all__ = ["DEFAULT_RULES", "logical_sharding", "pspec", "shard",
           "sweep_mesh"]
