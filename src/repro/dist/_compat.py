"""Shims for jax APIs this repo targets that older jax builds lack.

The launch/dryrun code and the tests are written against the modern mesh
API (``jax.set_mesh`` as a context, ``AbstractMesh(sizes, names)``).  On
jax <= 0.4.x those spell differently; installing the aliases here keeps
every caller on one spelling.  Both shims are no-ops on new jax.
"""

from __future__ import annotations

import jax
import jax.sharding as _jshard

if not hasattr(jax, "set_mesh"):
    # Mesh is itself a context manager on old jax, so returning it gives
    # ``with jax.set_mesh(mesh):`` the intended scoping semantics.
    def _set_mesh(mesh):
        return mesh

    jax.set_mesh = _set_mesh


def _install_cost_analysis_dict() -> None:
    """New jax returns one flat dict from Compiled.cost_analysis();
    0.4.x returned a single-element list of dicts.  Normalise to the
    modern shape so callers can ``cost.get("flops")`` everywhere.
    The list check happens per call — no probe compile at import, and
    on new jax the wrapper is a passthrough."""
    import jax.stages

    orig = jax.stages.Compiled.cost_analysis
    if getattr(orig, "_repro_normalised", False):
        return

    def cost_analysis(self):
        out = orig(self)
        if isinstance(out, list):
            return out[0] if out else {}
        return out

    cost_analysis._repro_normalised = True
    jax.stages.Compiled.cost_analysis = cost_analysis


_install_cost_analysis_dict()


def _abstract_mesh_wants_pairs() -> bool:
    try:
        _jshard.AbstractMesh((1,), ("_probe",))
        return False
    except TypeError:
        return True


if _abstract_mesh_wants_pairs():
    _OrigAbstractMesh = _jshard.AbstractMesh

    def _abstract_mesh(axis_sizes, axis_names=None, **kw):
        if axis_names is not None:
            return _OrigAbstractMesh(tuple(zip(axis_names, axis_sizes)), **kw)
        return _OrigAbstractMesh(axis_sizes, **kw)

    _jshard.AbstractMesh = _abstract_mesh
