"""RG-LRU recurrent block (RecurrentGemma).

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_r x_t)                    (recurrence gate)
    i_t = sigmoid(W_i x_t)                    (input gate)
    a_t = a^(c * r_t),  a = sigmoid(Lambda)   (per-channel decay)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The block wraps it with a temporal conv1d and a linear in/out projection,
per the Griffin/RecurrentGemma recipe.  Train/prefill uses an associative
scan; decode carries (conv_state [b, cw-1, w], h [b, w]) — O(1)/token,
so the hybrid runs the 500k decode shape.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..dist.sharding import shard
from .config import ModelConfig
from .layers import ParamDef

C_SCALE = 8.0   # the Griffin `c` constant


class RGLRUState(NamedTuple):
    conv: jax.Array    # [b, conv_width-1, width]
    h: jax.Array       # [b, width] f32


def rglru_defs(cfg: ModelConfig) -> dict:
    w = cfg.lru_width
    d = cfg.d_model
    cw = cfg.rglru.conv_width
    return {
        "in_x": ParamDef((d, w), ("fsdp", "mlp"), "scaled"),
        "in_gate": ParamDef((d, w), ("fsdp", "mlp"), "scaled"),
        "conv_w": ParamDef((cw, w), ("conv", "mlp"), "scaled"),
        "conv_b": ParamDef((w,), ("mlp",), "zeros"),
        "w_r": ParamDef((w, w), ("mlp", None), "scaled"),
        "w_i": ParamDef((w, w), ("mlp", None), "scaled"),
        "lam": ParamDef((w,), ("mlp",), "ones"),
        "out": ParamDef((w, d), ("mlp", "fsdp"), "scaled"),
    }


def init_rglru_state(cfg: ModelConfig, batch: int, dtype) -> RGLRUState:
    w, cw = cfg.lru_width, cfg.rglru.conv_width
    return RGLRUState(conv=jnp.zeros((batch, cw - 1, w), dtype),
                      h=jnp.zeros((batch, w), jnp.float32))


def rglru_state_spec(cfg: ModelConfig) -> RGLRUState:
    return RGLRUState(conv=("cache_batch", None, "mlp"),
                      h=("cache_batch", "mlp"))


def apply_rglru(p: dict, cfg: ModelConfig, x: jax.Array,
                state: RGLRUState | None = None):
    """x: [b, t, d] -> (y, new_state)."""
    cw = cfg.rglru.conv_width
    b, t, _ = x.shape

    gate = jax.nn.gelu(jnp.einsum(
        "btd,dw->btw", x, p["in_gate"].astype(x.dtype)))
    xi = jnp.einsum("btd,dw->btw", x, p["in_x"].astype(x.dtype))
    xi = shard(xi, "batch", "seq", "mlp")

    # temporal conv
    if state is not None:
        xpad = jnp.concatenate([state.conv.astype(xi.dtype), xi], axis=1)
    else:
        xpad = jnp.pad(xi, ((0, 0), (cw - 1, 0), (0, 0)))
    conv_w = p["conv_w"].astype(xi.dtype)
    xc = sum(xpad[:, i:i + t, :] * conv_w[i][None, None, :]
             for i in range(cw))
    xc = xc + p["conv_b"].astype(xc.dtype)
    new_conv = xpad[:, -(cw - 1):, :] if cw > 1 else xpad[:, :0]

    r = jax.nn.sigmoid(jnp.einsum(
        "btw,wv->btv", xc, p["w_r"].astype(xc.dtype)).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum(
        "btw,wv->btv", xc, p["w_i"].astype(xc.dtype)).astype(jnp.float32))
    log_a0 = jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))  # log a
    a = jnp.exp(C_SCALE * r * log_a0[None, None])              # a^(c r)
    gated = i * xc.astype(jnp.float32)
    u = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated

    h0 = (state.h if state is not None
          else jnp.zeros((b, xc.shape[-1]), jnp.float32))
    if t == 1:
        h = a[:, 0] * h0 + u[:, 0]
        hs = h[:, None]
    else:
        def combine(lhs, rhs):
            a1, u1 = lhs
            a2, u2 = rhs
            return a1 * a2, a2 * u1 + u2
        u = u.at[:, 0].add(a[:, 0] * h0)
        _, hs = jax.lax.associative_scan(combine, (a, u), axis=1)
        h = hs[:, -1]

    y = hs.astype(x.dtype) * gate
    out = jnp.einsum("btw,wd->btd", y, p["out"].astype(x.dtype))
    return out, RGLRUState(conv=new_conv.astype(x.dtype), h=h)
