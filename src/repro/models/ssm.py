"""Mamba-1 selective SSM block (falcon-mamba-7b).

Train/prefill uses an associative scan over the sequence (TPU-friendly:
log-depth, no per-step HBM round-trips); decode is a single recurrence
step on the carried ``(conv_state, ssm_state)`` — O(1) per token, which
is why the SSM arch runs the 500k-token decode shape.

State per layer: conv_state [b, d_conv-1, d_inner],
                 ssm_state  [b, d_inner, d_state].
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..dist.sharding import shard
from .config import ModelConfig
from .layers import ParamDef


class SSMState(NamedTuple):
    conv: jax.Array    # [b, d_conv-1, d_inner]
    ssm: jax.Array     # [b, d_inner, d_state]


def ssm_defs(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d, di, dr, ds = cfg.d_model, cfg.d_inner, cfg.dt_rank, s.d_state
    return {
        "in_proj": ParamDef((d, 2 * di), ("fsdp", "mlp"), "scaled"),
        "conv_w": ParamDef((s.d_conv, di), ("conv", "mlp"), "scaled"),
        "conv_b": ParamDef((di,), ("mlp",), "zeros"),
        "x_proj": ParamDef((di, dr + 2 * ds), ("mlp", None), "scaled"),
        "dt_proj_w": ParamDef((dr, di), (None, "mlp"), "scaled"),
        "dt_proj_b": ParamDef((di,), ("mlp",), "ones"),
        # A stored as log so A = -exp(log_a) < 0 (stability)
        "log_a": ParamDef((di, ds), ("mlp", "state"), "zeros"),
        "d_skip": ParamDef((di,), ("mlp",), "ones"),
        "out_proj": ParamDef((di, d), ("mlp", "fsdp"), "scaled"),
    }


def init_ssm_state(cfg: ModelConfig, batch: int, dtype) -> SSMState:
    s = cfg.ssm
    return SSMState(
        conv=jnp.zeros((batch, s.d_conv - 1, cfg.d_inner), dtype),
        ssm=jnp.zeros((batch, cfg.d_inner, s.d_state), jnp.float32))


def ssm_state_spec(cfg: ModelConfig) -> SSMState:
    return SSMState(conv=("cache_batch", None, "mlp"),
                    ssm=("cache_batch", "mlp", "state"))


def _ssm_params(p: dict, cfg: ModelConfig, xc: jax.Array):
    """Input-dependent (dt, B, C) from the conv output xc [..., di]."""
    s = cfg.ssm
    dr = cfg.dt_rank
    proj = jnp.einsum("...i,ir->...r", xc, p["x_proj"].astype(xc.dtype))
    dt_low, Bm, Cm = (proj[..., :dr], proj[..., dr:dr + s.d_state],
                      proj[..., dr + s.d_state:])
    dt = jnp.einsum("...r,ri->...i", dt_low,
                    p["dt_proj_w"].astype(xc.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_proj_b"].astype(jnp.float32))
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def apply_ssm(p: dict, cfg: ModelConfig, x: jax.Array,
              state: SSMState | None = None):
    """x: [b, t, d].  Returns (y, new_state)."""
    s = cfg.ssm
    b, t, d = x.shape
    di = cfg.d_inner

    xz = jnp.einsum("btd,de->bte", x, p["in_proj"].astype(x.dtype))
    xi, z = xz[..., :di], xz[..., di:]
    if cfg.ssm_shard == "channel":
        # recurrence is elementwise in d_inner: shard channels so the
        # associative scan over t needs no cross-shard communication
        xi = shard(xi, "batch", None, "mlp")
    else:
        xi = shard(xi, "batch", "seq", "mlp")

    # depthwise causal conv1d (width d_conv)
    if state is not None:
        hist = state.conv.astype(xi.dtype)          # [b, dc-1, di]
        xpad = jnp.concatenate([hist, xi], axis=1)
    else:
        xpad = jnp.pad(xi, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
    conv_w = p["conv_w"].astype(xi.dtype)            # [dc, di]
    xc = sum(xpad[:, i:i + t, :] * conv_w[i][None, None, :]
             for i in range(s.d_conv))
    xc = jax.nn.silu(xc + p["conv_b"].astype(xc.dtype))
    new_conv = xpad[:, -(s.d_conv - 1):, :] if s.d_conv > 1 else xpad[:, :0]

    dt, Bm, Cm = _ssm_params(p, cfg, xc)             # [b,t,di],[b,t,ds]x2
    A = -jnp.exp(p["log_a"].astype(jnp.float32))     # [di, ds]
    da = jnp.exp(dt[..., None] * A[None, None])      # [b,t,di,ds] decay
    db = dt[..., None] * Bm[:, :, None, :]           # [b,t,di,ds]
    u = db * xc.astype(jnp.float32)[..., None]       # input injection
    scan_dt = jnp.dtype(cfg.ssm_scan_dtype)
    da, u = da.astype(scan_dt), u.astype(scan_dt)

    h0 = (state.ssm if state is not None
          else jnp.zeros((b, di, s.d_state), jnp.float32))

    def combine(lhs, rhs):
        # associative pair op: (a2, u2) o (a1, u1) = (a1*a2, a2*u1 + u2)
        a1, u1 = lhs
        a2, u2 = rhs
        return a1 * a2, a2 * u1 + u2

    if t == 1:
        h = (da[:, 0].astype(jnp.float32) * h0
             + u[:, 0].astype(jnp.float32))          # single decode step
        hs = h[:, None]
        y = jnp.einsum("btis,bts->bti", hs, Cm)
    elif cfg.ssm_chunk and t > cfg.ssm_chunk and t % cfg.ssm_chunk == 0:
        # §Perf: chunked selective scan — lax.scan over chunks carrying
        # the state, assoc-scan within; temporaries drop from O(t) to
        # O(chunk) in the [.., d_inner, d_state] axis.
        ck = cfg.ssm_chunk
        nc = t // ck
        mlp_ax = "mlp" if cfg.ssm_shard == "channel" else None
        da_c = da.reshape(b, nc, ck, di, -1).transpose(1, 0, 2, 3, 4)
        u_c = u.reshape(b, nc, ck, di, -1).transpose(1, 0, 2, 3, 4)
        da_c = shard(da_c, None, "batch", None, mlp_ax, None)
        u_c = shard(u_c, None, "batch", None, mlp_ax, None)
        cm_c = Cm.reshape(b, nc, ck, -1).transpose(1, 0, 2, 3)

        def chunk_body(hc, xs):
            da_i, u_i, cm_i = xs
            da_i = shard(da_i, "batch", None, mlp_ax, None)
            u_i = shard(u_i, "batch", None, mlp_ax, None)
            u_i = u_i.at[:, 0].add((da_i[:, 0].astype(jnp.float32)
                                    * hc).astype(u_i.dtype))
            _, hs_i = jax.lax.associative_scan(combine, (da_i, u_i),
                                               axis=1)
            y_i = jnp.einsum("btis,bts->bti", hs_i, cm_i)
            return (hs_i[:, -1].astype(jnp.float32),
                    shard(y_i, "batch", None, mlp_ax))

        h, y = jax.lax.scan(chunk_body, h0, (da_c, u_c, cm_c))
        y = y.transpose(1, 0, 2, 3).reshape(b, t, di)
    else:
        u = u.at[:, 0].add((da[:, 0].astype(jnp.float32)
                            * h0).astype(u.dtype))   # fold carried state
        _, hs = jax.lax.associative_scan(combine, (da, u), axis=1)
        h = hs[:, -1].astype(jnp.float32)
        y = jnp.einsum("btis,bts->bti", hs, Cm)      # C read-out
    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bti,id->btd", y, p["out_proj"].astype(x.dtype))
    return out, SSMState(conv=new_conv.astype(x.dtype), ssm=h)
