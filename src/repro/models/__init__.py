"""repro.models — every assigned architecture, from scratch in JAX."""

from .config import (EncDecConfig, ModelConfig, MoEConfig, RGLRUConfig,
                     SSMConfig, VLMConfig)
from . import attention, encdec, layers, moe, rglru, ssm, transformer, vlm
from .layers import abstract_params, init_params, param_specs, param_shapes

__all__ = [
    "EncDecConfig", "ModelConfig", "MoEConfig", "RGLRUConfig", "SSMConfig",
    "VLMConfig", "attention", "encdec", "layers", "moe", "rglru", "ssm",
    "transformer", "vlm", "abstract_params", "init_params", "param_specs",
    "param_shapes",
]
