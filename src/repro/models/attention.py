"""GQA attention: causal / sliding-window / encoder / cross variants.

KV cache layout: ``k,v: [batch, cache_len, n_kv, head_dim]`` plus an
int32 ``pos`` scalar (tokens seen so far).  For sliding-window layers the
cache is a ring buffer of length ``window`` — decode cost and memory are
O(window), which is what makes 500k-token decoding feasible for the
SWA/hybrid architectures (DESIGN.md §6).

Sharding: query/output activations are sequence-sharded over ``model``
(SP) in train/prefill; decode shards the KV cache length over ``model``
with a numerically exact two-pass softmax (psum of max then of num/den)
expressed via sharding constraints — XLA inserts the collectives.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..dist.sharding import shard
from .config import ModelConfig
from .layers import ParamDef, rope, softcap


class KVCache(NamedTuple):
    k: jax.Array          # [b, cache_len, n_kv, head_dim]
    v: jax.Array
    pos: jax.Array        # [] int32 — absolute tokens already cached


def attn_defs(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs = {
        "wq": ParamDef((d, h, hd), ("fsdp", "heads", None), "scaled"),
        "wk": ParamDef((d, kv, hd), ("fsdp", "kv_heads", None), "scaled"),
        "wv": ParamDef((d, kv, hd), ("fsdp", "kv_heads", None), "scaled"),
        "wo": ParamDef((h, hd, d), ("heads", None, "fsdp"), "scaled"),
    }
    if cfg.qkv_bias:
        defs |= {
            "bq": ParamDef((h, hd), ("heads", None), "zeros"),
            "bk": ParamDef((kv, hd), ("kv_heads", None), "zeros"),
            "bv": ParamDef((kv, hd), ("kv_heads", None), "zeros"),
        }
    return defs


def init_cache(cfg: ModelConfig, batch: int, max_len: int, kind: str,
               dtype) -> KVCache:
    """kind: 'attn' full cache; 'local' ring buffer bounded by window."""
    length = min(max_len, cfg.window) if kind in ("local", "moe_local") else max_len
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   pos=jnp.zeros((), jnp.int32))


def cache_spec(cfg: ModelConfig, batch: int, max_len: int, kind: str):
    """Logical dims for the cache (SP over length when heads indivisible)."""
    return KVCache(k=("cache_batch", "kv_seq", None, None),
                   v=("cache_batch", "kv_seq", None, None),
                   pos=())


def _project_qkv(p: dict, cfg: ModelConfig, x: jax.Array):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


def _scale(cfg: ModelConfig) -> float:
    return cfg.query_scale or 1.0 / math.sqrt(cfg.head_dim)


def _mha(q, k, v, cfg: ModelConfig, mask) -> jax.Array:
    """q: [b,t,h,hd]; k,v: [b,s,kv,hd]; mask: [b,t,s] bool or None."""
    b, t, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    qg = q.reshape(b, t, kv, h // kv, hd)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg, k).astype(jnp.float32)
    logits = logits * _scale(cfg)
    logits = softcap(logits, cfg.softcap_attn)
    if mask is not None:
        logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", w, v)
    return out.reshape(b, t, h, hd)


def _blockwise_attn(q, k, v, cfg: ModelConfig, *, causal: bool,
                    window: int | None, q_offset: int = 0):
    """Online-softmax attention via lax.scan over KV blocks — the
    XLA-compilable twin of kernels/flash_attention (same math).  Peak
    memory is O(t x block_k) instead of O(t x s): this is the §Perf fix
    for the 32k-prefill score-materialisation blowup."""
    b, t, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    bk = min(cfg.attn_block_k, s)
    nb = -(-s // bk)
    pad = nb * bk - s
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = kp.reshape(b, nb, bk, kv, hd).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(b, nb, bk, kv, hd).transpose(1, 0, 2, 3, 4)

    qg = (q.reshape(b, t, kv, g, hd) * _scale(cfg)).astype(jnp.float32)
    qpos = q_offset + jnp.arange(t)

    def body(carry, xs):
        m, l, acc = carry
        i, kblk, vblk = xs
        logits = jnp.einsum("btkgd,bskd->bkgts", qg,
                            kblk.astype(jnp.float32))
        logits = softcap(logits, cfg.softcap_attn)
        kpos = i * bk + jnp.arange(bk)
        mask = kpos[None, :] < s
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        corr = jnp.where(m == -jnp.inf, 1.0, jnp.exp(m - m_new))
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        l_new = l * corr + p.sum(-1)
        acc_new = (acc * corr[..., None]
                   + jnp.einsum("bkgts,bskd->bkgtd", p,
                                vblk.astype(jnp.float32)))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, g, t), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kv, g, t), jnp.float32)
    a0 = jnp.zeros((b, kv, g, t, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(nb), kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return (out.transpose(0, 3, 1, 2, 4)
            .reshape(b, t, h, hd).astype(q.dtype))


def _causal_mask(t: int, s: int, q_offset, window: int | None):
    qpos = jnp.arange(t)[:, None] + q_offset       # absolute query pos
    kpos = jnp.arange(s)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m                                        # [t, s]


def attention(p: dict, cfg: ModelConfig, kind: str, x: jax.Array,
              positions: jax.Array,
              cache: Optional[KVCache] = None,
              use_rope: bool = True):
    """Self-attention for train / prefill / decode.

    Train/prefill: cache is None or empty -> returns (out, new_cache-ish)
    Decode:        x is [b, 1, d], cache holds history.
    """
    window = cfg.window if kind in ("local", "moe_local") else None
    q, k, v = _project_qkv(p, cfg, x)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "act_heads", None)

    def _self_attn(qq, kk, vv):
        if cfg.use_pallas:
            from ..kernels import ops
            return ops.attention(qq, kk, vv, causal=True, window=window,
                                 softcap=cfg.softcap_attn,
                                 scale=_scale(cfg))
        if cfg.attn_impl == "blockwise":
            return _blockwise_attn(qq, kk, vv, cfg, causal=True,
                                   window=window)
        mask = _causal_mask(qq.shape[1], kk.shape[1], 0, window)[None]
        return _mha(qq, kk, vv, cfg, mask)

    if cache is None:
        # training / full prefill without cache return
        out = _self_attn(q, k, v)
    elif x.shape[1] > 1:
        # prefill: write into cache, attend within the prefix
        out = _self_attn(q, k, v)
        cache = _cache_write_prefill(cache, k, v, kind, cfg)
    else:
        # single-token decode against ring/full cache
        cache = _cache_write_step(cache, k, v, kind, cfg)
        ck = shard(cache.k, "cache_batch", "kv_seq", None, None)
        cv = shard(cache.v, "cache_batch", "kv_seq", None, None)
        valid = _decode_mask(cache, kind, cfg)       # [1, clen]
        if cfg.use_pallas:
            from ..kernels import ops
            b = x.shape[0]
            out = ops.decode_attn(
                q[:, 0], ck, cv,
                jnp.broadcast_to(valid, (b, valid.shape[-1])),
                softcap=cfg.softcap_attn, scale=_scale(cfg))[:, None]
        else:
            mask = jnp.broadcast_to(valid[:, None, :],
                                    (x.shape[0], 1, valid.shape[-1]))
            out = _mha(q, ck, cv, cfg, mask)
    out = shard(out, "batch", "seq", "act_heads", None)
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))
    return y, cache


# Ring-buffer invariant: the K/V of the token at absolute position ``a``
# lives at slot ``a % clen``.  Prefill and decode both honour it, so a
# prefill of any length can be continued by single-token decode steps.

def _cache_write_prefill(cache: KVCache, k, v, kind: str,
                         cfg: ModelConfig) -> KVCache:
    t = k.shape[1]
    clen = cache.k.shape[1]
    if kind in ("local", "moe_local") and t > clen:
        k, v = k[:, -clen:], v[:, -clen:]            # last `window` tokens
        slots = (t - clen + jnp.arange(clen)) % clen
        nk = cache.k.at[:, slots].set(k)
        nv = cache.v.at[:, slots].set(v)
    else:
        nk = jax.lax.dynamic_update_slice_in_dim(cache.k, k, 0, axis=1)
        nv = jax.lax.dynamic_update_slice_in_dim(cache.v, v, 0, axis=1)
    return KVCache(k=nk, v=nv, pos=cache.pos + t)


def _cache_write_step(cache: KVCache, k, v, kind: str,
                      cfg: ModelConfig) -> KVCache:
    clen = cache.k.shape[1]
    if kind in ("local", "moe_local"):
        slot = cache.pos % clen
    else:
        slot = jnp.minimum(cache.pos, clen - 1)
    nk = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
    nv = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
    return KVCache(k=nk, v=nv, pos=cache.pos + 1)


def _decode_mask(cache: KVCache, kind: str, cfg: ModelConfig):
    """Valid-slot mask [1, clen]; cache.pos counts tokens incl. current."""
    clen = cache.k.shape[1]
    idx = jnp.arange(clen)
    if kind in ("local", "moe_local"):
        newest = (cache.pos - 1) % clen
        age = (newest - idx) % clen                  # 0 = newest
        valid = age < jnp.minimum(cache.pos, clen)
    else:
        valid = idx < cache.pos
    return valid[None, :]


def cross_attention(p: dict, cfg: ModelConfig, x: jax.Array,
                    enc_kv: tuple[jax.Array, jax.Array]) -> jax.Array:
    """Decoder cross-attn over precomputed encoder K/V (whisper)."""
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    k, v = enc_kv
    out = _mha(q, k, v, cfg, None)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))


def encode_kv(p: dict, cfg: ModelConfig, enc_out: jax.Array):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    if "bk" in p:
        k = k + p["bk"].astype(enc_out.dtype)
        v = v + p["bv"].astype(enc_out.dtype)
    return k, v
