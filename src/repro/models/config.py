"""Unified model configuration covering all ten assigned architectures.

One dataclass, optional sections: dense / MoE / SSM / RG-LRU hybrid /
encoder-decoder / VLM.  Per-layer heterogeneity (gemma2 local-global,
recurrentgemma 2:1 rec:attn) is expressed with ``block_pattern`` applied
cyclically over the layer stack.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0              # deepseek shared experts
    d_ff_shared: int = 0
    first_k_dense: int = 0         # first k layers use a dense MLP
    d_ff_dense: int = 0            # ... of this width
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:                   # Mamba-1 (falcon-mamba)
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0               # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:                 # RecurrentGemma
    lru_width: int = 0             # 0 -> d_model
    conv_width: int = 4
    block_width: int = 0           # recurrent block expansion (0 -> 3/2 ff)


@dataclasses.dataclass(frozen=True)
class EncDecConfig:                # Whisper
    n_enc_layers: int = 6
    enc_seq: int = 1500            # encoder frames after conv stub
    cross_attn: bool = True


@dataclasses.dataclass(frozen=True)
class VLMConfig:                   # InternVL: ViT-stub -> projector -> LM
    n_patches: int = 1024          # patch embeddings per image (stub input)
    vit_dim: int = 3200            # InternViT-6B hidden (stub output dim)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # block pattern, cycled over layers:
    #   "attn" full causal | "local" sliding window | "rec" RG-LRU |
    #   "ssm" mamba | "moe_attn" attention feeding an MoE MLP
    block_pattern: tuple = ("attn",)
    window: int = 4096              # sliding window for "local" blocks
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    softcap_attn: float = 0.0       # gemma2: 50.0
    softcap_final: float = 0.0      # gemma2: 30.0
    query_scale: float = 0.0        # 0 -> 1/sqrt(head_dim)
    # mlp / norm
    mlp_kind: str = "swiglu"        # swiglu | geglu | gelu
    norm_kind: str = "rms"          # rms | ln
    norm_eps: float = 1e-6
    post_block_norm: bool = False   # gemma2 post-norms
    tie_embeddings: bool = False
    embed_scale: bool = False       # gemma: scale embeds by sqrt(d_model)
    # optional sections
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    # runtime
    dtype: str = "bfloat16"
    remat: str = "none"             # none | full | dots (activation ckpt)
    use_pallas: bool = False        # route attention through Pallas kernels
    attn_impl: str = "dense"        # dense | blockwise (online-softmax scan,
    #   the XLA-compilable twin of the Pallas flash kernel; §Perf)
    attn_block_k: int = 2048        # kv block for blockwise attention
    loss_chunk: int = 0             # >0: seq-chunked CE head (§Perf)
    ssm_chunk: int = 0              # >0: chunked selective-scan (§Perf —
    #   bounds the [b, t, d_inner, d_state] scan temporaries to t=chunk)
    moe_impl: str = "onehot"        # onehot | sort (§Perf: gather/scatter
    #   dispatch — no [b,t,e,c] one-hot matmuls, flops -> 6·N_active·D)
    moe_tokens: str = "sharded"     # sharded | gathered (§Perf: gather the
    #   seq axis at MoE entry / reduce-scatter at exit — one AG+RS of
    #   [b,t,d] replaces the per-layer [b,e,c,d] dispatch all-reduces)
    ssm_shard: str = "seq"          # seq | channel (§Perf: the recurrence
    #   is elementwise in channels, so sharding d_inner instead of time
    #   keeps the associative scan collective-free)
    ssm_scan_dtype: str = "float32"  # float32 | bfloat16 scan pairs (§Perf:
    #   halves the dominant [b,t,d_inner,d_state] HBM traffic; the carried
    #   inter-chunk state stays f32)
    scan_layers: bool = True
    # which shapes this arch supports (see DESIGN.md §6 for skips)
    supports_decode: bool = True
    subquadratic: bool = False      # may run long_500k

    # -- derived ------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.pattern_period

    @property
    def n_tail_layers(self) -> int:
        return self.n_layers - self.n_groups * self.pattern_period

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % self.pattern_period]

    @property
    def dt_rank(self) -> int:
        assert self.ssm is not None
        return self.ssm.dt_rank or math.ceil(self.d_model / 16)

    @property
    def d_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def lru_width(self) -> int:
        assert self.rglru is not None
        return self.rglru.lru_width or self.d_model

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        from . import transformer  # lazy, avoids cycle
        defs = transformer.param_defs(self)
        import jax
        leaves = jax.tree.leaves(
            defs, is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "dims"))
        return sum(math.prod(l.shape) for l in leaves)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k experts)."""
        total = self.param_count()
        if self.moe is None:
            return total
        m = self.moe
        per_expert = 3 * self.d_model * m.d_ff_expert
        n_moe_layers = self.n_layers - m.first_k_dense
        inactive = per_expert * (m.n_experts - m.top_k) * n_moe_layers
        return total - inactive
