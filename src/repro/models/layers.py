"""Primitive layers + the ParamDef descriptor system.

Params are described by trees of ``ParamDef(shape, dims, init)`` where
``dims`` are *logical* sharding axes (see repro.dist.sharding).  The same
tree materialises three ways:

  * ``init_params``      — real arrays (seeded, for training/smoke tests)
  * ``abstract_params``  — ShapeDtypeStructs (dry-run: zero allocation)
  * ``param_specs``      — logical-dims tree (for in_shardings)
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import shard


class ParamDef(NamedTuple):
    shape: tuple
    dims: tuple                   # logical axis per dim (str | None)
    init: str = "normal"          # normal | zeros | ones | scaled
    scale: float = 0.02


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_one(d: ParamDef, key, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "normal":
        return (d.scale * jax.random.normal(key, d.shape)).astype(dtype)
    if d.init == "scaled":  # fan-in scaled
        fan_in = d.shape[0] if len(d.shape) > 1 else max(d.shape[0], 1)
        s = 1.0 / math.sqrt(fan_in)
        return (s * jax.random.normal(key, d.shape)).astype(dtype)
    raise ValueError(d.init)


def init_params(defs, seed: int, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    vals = [_init_one(d, k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(defs, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=is_def)


def param_specs(defs):
    return jax.tree.map(lambda d: tuple(d.dims), defs, is_leaf=is_def)


def param_shapes(defs):
    return jax.tree.map(lambda d: tuple(d.shape), defs, is_leaf=is_def)


# ---------------------------------------------------------------------------
# normalisation
# ---------------------------------------------------------------------------

def rmsnorm_defs(dim: int) -> dict:
    return {"scale": ParamDef((dim,), (None,), "ones")}


def layernorm_defs(dim: int) -> dict:
    return {"scale": ParamDef((dim,), (None,), "ones"),
            "bias": ParamDef((dim,), (None,), "zeros")}


def norm_defs(kind: str, dim: int) -> dict:
    return rmsnorm_defs(dim) if kind == "rms" else layernorm_defs(dim)


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rms":
        nrm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (nrm * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    nrm = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (nrm * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def embed_defs(vocab: int, d_model: int) -> dict:
    return {"table": ParamDef((vocab, d_model), ("vocab", "embed"),
                              "normal", 0.01)}


def embed_lookup(p: dict, ids: jax.Array, scale: bool, d: int) -> jax.Array:
    x = jnp.take(p["table"], ids, axis=0)
    if scale:
        x = x * jnp.asarray(math.sqrt(d), x.dtype)
    return shard(x, "batch", "seq", "act_embed")


def logits_defs(vocab: int, d_model: int, tied: bool) -> dict:
    if tied:
        return {}
    return {"out": ParamDef((d_model, vocab), ("embed", "vocab"), "scaled")}


def apply_logits(p: dict, embed_p: dict, x: jax.Array, tied: bool,
                 softcap: float) -> jax.Array:
    w = embed_p["table"].T if tied else p["out"]
    logits = jnp.einsum("btd,dv->btv", x, w.astype(x.dtype))
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits.astype(jnp.float32)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_defs(d_model: int, d_ff: int, kind: str) -> dict:
    if kind in ("swiglu", "geglu"):
        return {
            "wi": ParamDef((d_model, d_ff), ("fsdp", "mlp"), "scaled"),
            "wg": ParamDef((d_model, d_ff), ("fsdp", "mlp"), "scaled"),
            "wo": ParamDef((d_ff, d_model), ("mlp", "fsdp"), "scaled"),
        }
    return {  # plain gelu MLP (starcoder2, whisper)
        "wi": ParamDef((d_model, d_ff), ("fsdp", "mlp"), "scaled"),
        "bi": ParamDef((d_ff,), ("mlp",), "zeros"),
        "wo": ParamDef((d_ff, d_model), ("mlp", "fsdp"), "scaled"),
        "bo": ParamDef((d_model,), (None,), "zeros"),
    }


def apply_mlp(p: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind in ("swiglu", "geglu"):
        h = jnp.einsum("btd,df->btf", x, p["wi"].astype(x.dtype))
        g = jnp.einsum("btd,df->btf", x, p["wg"].astype(x.dtype))
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        h = shard(h * act, "batch", "seq", "mlp")
        return jnp.einsum("btf,fd->btd", h, p["wo"].astype(x.dtype))
    h = jnp.einsum("btd,df->btf", x, p["wi"].astype(x.dtype))
    h = jax.nn.gelu(h + p["bi"].astype(x.dtype))
    h = shard(h, "batch", "seq", "mlp")
    return (jnp.einsum("btf,fd->btd", h, p["wo"].astype(x.dtype))
            + p["bo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [b, t, heads, head_dim]; positions: [b, t] int32."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # [b,t,half]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap > 0 else x
