"""Mixture-of-Experts block: top-k routing with capacity-based dispatch.

Grouped Mesh-TensorFlow-style dispatch: tokens route *within their own
sequence* (group = batch row), so dispatch/combine tensors are
``[b, t, experts, capacity]`` einsum operands that XLA fuses into dots.
Under the ``experts -> model`` sharding the expert compute lowers to the
canonical all-to-all + expert-parallel matmuls — exactly the incast-ish
fabric traffic the paper's CC mechanism targets (benchmarks/cosim.py
feeds these bytes into the CLOS fluid model).

Supports mixtral (8e top-2) and deepseek-moe (64e top-6 + 2 shared,
fine-grained d_ff, first layer dense).  The sort-based (dropless) dispatch
in §Perf replaces this one-hot path for the MoE hillclimb cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dist.sharding import shard
from .config import ModelConfig
from .layers import ParamDef, apply_mlp, mlp_defs


def moe_defs(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    defs = {
        "router": ParamDef((d, m.n_experts), ("embed", None), "scaled"),
        "wi": ParamDef((m.n_experts, d, m.d_ff_expert),
                       ("experts", "fsdp", "mlp"), "scaled"),
        "wg": ParamDef((m.n_experts, d, m.d_ff_expert),
                       ("experts", "fsdp", "mlp"), "scaled"),
        "wo": ParamDef((m.n_experts, m.d_ff_expert, d),
                       ("experts", "mlp", "fsdp"), "scaled"),
    }
    if m.n_shared:
        defs["shared"] = mlp_defs(d, m.d_ff_shared, "swiglu")
    return defs


def capacity_of(cfg: ModelConfig, t: int) -> int:
    m = cfg.moe
    return max(1, int(m.capacity_factor * t * m.top_k / m.n_experts))


def apply_moe(p: dict, cfg: ModelConfig, x: jax.Array):
    """x: [b, t, d] -> (y, aux_loss)."""
    m = cfg.moe
    b, t, d = x.shape
    e, k = m.n_experts, m.top_k
    c = capacity_of(cfg, t)

    if cfg.moe_tokens == "gathered":
        # §Perf: all-gather the seq axis once at entry; the dispatch
        # einsums then contract an unsharded t (no [b,e,c,d] psums) and
        # the exit constraint reduce-scatters y back to seq shards.
        x = shard(x, "batch", None, "act_embed")

    gate_logits = jnp.einsum(
        "btd,de->bte", x, p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(gate_logits, axis=-1)            # [b,t,e]
    gate_w, gate_idx = jax.lax.top_k(probs, k)              # [b,t,k]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    if cfg.moe_impl == "sort":
        y = _dispatch_sort(p, cfg, x, gate_w, gate_idx, c)
        if m.n_shared:
            y = y + apply_mlp(p["shared"], x, "swiglu")
        me = probs.mean((0, 1))
        ce = jax.nn.one_hot(gate_idx[..., 0], e).mean((0, 1))
        aux = m.router_aux_weight * e * jnp.sum(me * ce)
        return y, aux

    # slot position of each (token, k) inside its expert's capacity buffer
    oh = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)       # [b,t,k,e]
    flat = oh.reshape(b, t * k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(b, t, k, e)
    pos = (pos * oh).sum(-1)                                # [b,t,k]
    keep = (pos < c).astype(x.dtype)

    # accumulate dispatch/combine over the small k axis to bound temps
    disp = jnp.zeros((b, t, e, c), x.dtype)
    comb = jnp.zeros((b, t, e, c), x.dtype)
    for kk in range(k):
        sel = (jax.nn.one_hot(gate_idx[:, :, kk], e, dtype=x.dtype)
               [:, :, :, None]
               * jax.nn.one_hot(pos[:, :, kk], c, dtype=x.dtype)
               [:, :, None, :]
               * keep[:, :, kk, None, None])
        disp = disp + sel
        comb = comb + sel * gate_w[:, :, kk, None, None].astype(x.dtype)

    # dispatch/combine accumulate in the activation dtype: every (e, c)
    # slot receives at most ONE nonzero term (one-hot selection), so the
    # low-precision psum is exact — and the cross-shard partial-sum
    # all-reduces halve vs XLA's default f32 accumulation (§Perf).
    xe = jnp.einsum("btec,btd->becd", disp, x,
                    preferred_element_type=x.dtype)         # a2a dispatch
    xe = shard(xe, "batch", "experts", None, "act_embed")
    ye = _expert_ffn(p, cfg, xe)
    y = jnp.einsum("btec,becd->btd", comb, ye,
                   preferred_element_type=x.dtype)          # a2a combine

    if m.n_shared:
        y = y + apply_mlp(p["shared"], x, "swiglu")
    if cfg.moe_tokens == "gathered":
        y = shard(y, "batch", "seq", "act_embed")           # RS back

    # Switch-style load-balancing aux loss
    me = probs.mean((0, 1))                                 # [e]
    ce = jax.nn.one_hot(gate_idx[..., 0], e).mean((0, 1))
    aux = m.router_aux_weight * e * jnp.sum(me * ce)
    return y, aux


def _expert_ffn(p: dict, cfg: ModelConfig, xe: jax.Array) -> jax.Array:
    """SwiGLU per expert: xe [b, e, c, d] -> [b, e, c, d]."""
    hi = jnp.einsum("becd,edf->becf", xe, p["wi"].astype(xe.dtype))
    hg = jnp.einsum("becd,edf->becf", xe, p["wg"].astype(xe.dtype))
    he = shard(jax.nn.silu(hg) * hi, "batch", "experts",
               None, "mlp")
    ye = jnp.einsum("becf,efd->becd", he, p["wo"].astype(xe.dtype))
    return shard(ye, "batch", "experts", None, "act_embed")


def _dispatch_sort(p: dict, cfg: ModelConfig, x, gate_w, gate_idx,
                   c: int) -> jax.Array:
    """§Perf sort-based dispatch: gather/scatter instead of one-hot
    einsums.  Same position-priority capacity semantics as the one-hot
    path (bitwise-matching drops), but the [b, t, e, c] dispatch tensors
    and their O(b·t·e·c·d) matmul flops disappear — compiled flops drop
    to ~6·N_active·D and the temp footprint to the gathered [b,e,c,d]."""
    m = cfg.moe
    b, t, d = x.shape
    e, k = m.n_experts, m.top_k
    tk = t * k

    def per_row(xr, widx, wval):
        # xr [t, d]; widx/wval [t, k]
        flat_e = widx.reshape(tk)                    # expert of each pair
        flat_w = wval.reshape(tk)
        flat_tok = jnp.repeat(jnp.arange(t), k)
        order = jnp.argsort(flat_e, stable=True)     # token-order stable
        se, stok, sw = flat_e[order], flat_tok[order], flat_w[order]
        # rank within expert segment = running index - segment start
        pos = jnp.arange(tk)
        seg_start = jnp.searchsorted(se, jnp.arange(e), side="left")
        rank = pos - seg_start[se]
        keep = rank < c
        slot = jnp.where(keep, se * c + rank, e * c)  # e*c = trash slot
        # gather tokens into [e*c, d] slots
        xe = jnp.zeros((e * c + 1, d), x.dtype).at[slot].set(
            jnp.where(keep[:, None], xr[stok], 0.0))[:e * c]
        return xe.reshape(e, c, d), slot, stok, sw, keep

    xe, slot, stok, sw, keep = jax.vmap(per_row)(x, gate_idx, gate_w)
    xe = shard(xe, "batch", "experts", None, "act_embed")
    ye = _expert_ffn(p, cfg, xe)                     # [b, e, c, d]

    def per_row_combine(ye_r, slot_r, stok_r, sw_r, keep_r):
        flat = ye_r.reshape(e * c, d)
        vals = jnp.where(keep_r[:, None],
                         flat[jnp.minimum(slot_r, e * c - 1)], 0.0)
        return jnp.zeros((t, d), x.dtype).at[stok_r].add(
            vals * sw_r[:, None].astype(x.dtype))

    return jax.vmap(per_row_combine)(ye, slot, stok, sw, keep)
