"""Whisper-style encoder-decoder. Conv audio frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed frame embeddings
[b, enc_seq, d_model] (what the two conv layers would emit).

Decoder = causal self-attn + cross-attn + MLP per layer, LayerNorm,
learned positions.  Serving decodes with self-attn KV caches plus
precomputed per-layer cross-attn K/V.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..dist.sharding import shard
from . import attention as attn_mod
from .config import ModelConfig
from .layers import (ParamDef, apply_mlp, apply_norm, embed_defs,
                     embed_lookup, logits_defs, apply_logits, mlp_defs,
                     norm_defs)
from .transformer import _stack_defs


def _maybe_scan(cfg: ModelConfig, body, init, xs):
    """lax.scan when cfg.scan_layers else an unrolled python loop
    (slicing the same stacked params) — used by the dry-run cost probes."""
    if cfg.scan_layers:
        return jax.lax.scan(body, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    return carry, jax.tree.map(lambda *a: jnp.stack(a), *ys)


def param_defs(cfg: ModelConfig) -> dict:
    e = cfg.encdec
    d, nk = cfg.d_model, cfg.norm_kind
    enc_block = {
        "norm1": norm_defs(nk, d),
        "attn": attn_mod.attn_defs(cfg),
        "norm2": norm_defs(nk, d),
        "mlp": mlp_defs(d, cfg.d_ff, cfg.mlp_kind),
    }
    dec_block = {
        "norm1": norm_defs(nk, d),
        "attn": attn_mod.attn_defs(cfg),
        "norm_x": norm_defs(nk, d),
        "xattn": attn_mod.attn_defs(cfg),
        "norm2": norm_defs(nk, d),
        "mlp": mlp_defs(d, cfg.d_ff, cfg.mlp_kind),
    }
    return {
        "enc_pos": ParamDef((e.enc_seq, d), (None, "embed"), "normal", 0.01),
        "enc": _stack_defs(enc_block, e.n_enc_layers),
        "enc_norm": norm_defs(nk, d),
        "embed": embed_defs(cfg.vocab, d),
        "dec_pos": ParamDef((4096, d), (None, "embed"), "normal", 0.01),
        "dec": _stack_defs(dec_block, cfg.n_layers),
        "final_norm": norm_defs(nk, d),
        "logits": logits_defs(cfg.vocab, d, cfg.tie_embeddings),
    }


def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: [b, s, d] stub conv output -> encoder states."""
    nk, eps = cfg.norm_kind, cfg.norm_eps
    s = frames.shape[1]
    x = frames + params["enc_pos"][:s][None].astype(frames.dtype)
    x = shard(x, "batch", "seq", "act_embed")
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(xc, bp):
        h = apply_norm(bp["norm1"], xc, nk, eps)
        q = jnp.einsum("btd,dhk->bthk", h, bp["attn"]["wq"].astype(h.dtype))
        k, v = attn_mod.encode_kv(bp["attn"], cfg, h)
        if "bq" in bp["attn"]:
            q = q + bp["attn"]["bq"].astype(h.dtype)
        out = attn_mod._mha(q, k, v, cfg, None)      # bidirectional
        h = jnp.einsum("bthk,hkd->btd", out,
                       bp["attn"]["wo"].astype(h.dtype))
        xc = xc + h
        h2 = apply_mlp(bp["mlp"], apply_norm(bp["norm2"], xc, nk, eps),
                       cfg.mlp_kind)
        return xc + h2, None

    x, _ = _maybe_scan(cfg, body, x, params["enc"])
    return apply_norm(params["enc_norm"], x, nk, eps)


def _dec_block(bp, cfg, x, positions, cache, enc_kv):
    nk, eps = cfg.norm_kind, cfg.norm_eps
    h = apply_norm(bp["norm1"], x, nk, eps)
    h, cache = attn_mod.attention(bp["attn"], cfg, "attn", h, positions,
                                  cache, use_rope=False)
    x = x + h
    h = apply_norm(bp["norm_x"], x, nk, eps)
    x = x + attn_mod.cross_attention(bp["xattn"], cfg, h, enc_kv)
    h = apply_mlp(bp["mlp"], apply_norm(bp["norm2"], x, nk, eps),
                  cfg.mlp_kind)
    return x + h, cache


def decode(params, cfg: ModelConfig, tokens, enc_out,
           caches=None, pos0: Optional[jax.Array] = None):
    """Teacher-forced decoding (caches=None) or cached decode step."""
    nk, eps = cfg.norm_kind, cfg.norm_eps
    b, t = tokens.shape
    x = embed_lookup(params["embed"], tokens, cfg.embed_scale, cfg.d_model)
    start = jnp.zeros((), jnp.int32) if pos0 is None else pos0
    posids = start + jnp.arange(t, dtype=jnp.int32)
    x = x + jnp.take(params["dec_pos"], posids, 0)[None].astype(x.dtype)
    positions = jnp.broadcast_to(posids, (b, t))

    def body(carry, xs):
        xc = carry
        bp, cache = xs
        enc_kv = attn_mod.encode_kv(bp["xattn"], cfg, enc_out)
        xc, cache = _dec_block(bp, cfg, xc, positions, cache, enc_kv)
        return xc, cache

    x, caches = _maybe_scan(cfg, body, x, (params["dec"], caches))
    x = apply_norm(params["final_norm"], x, nk, eps)
    logits = apply_logits(params["logits"], params["embed"], x,
                          cfg.tie_embeddings, cfg.softcap_final)
    return logits, caches


def init_dec_caches(cfg: ModelConfig, batch: int, max_len: int, dtype):
    c = attn_mod.init_cache(cfg, batch, max_len, "attn", dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), c)


def dec_cache_specs(cfg: ModelConfig):
    c = attn_mod.cache_spec(cfg, 0, 0, "attn")
    return jax.tree.map(lambda dims: ("layers",) + tuple(dims), c,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def forward(params, cfg: ModelConfig, frames, tokens):
    """Full enc-dec training forward -> (logits, aux=0)."""
    enc_out = encode(params, cfg, frames)
    logits, _ = decode(params, cfg, tokens, enc_out)
    return logits, jnp.zeros((), jnp.float32)


def prefill(params, cfg: ModelConfig, frames, tokens, max_len: int):
    """Encode audio + fill decoder self-attn caches over the prompt."""
    enc_out = encode(params, cfg, frames)
    caches = init_dec_caches(cfg, tokens.shape[0], max_len,
                             enc_out.dtype)
    logits, caches = decode(params, cfg, tokens, enc_out, caches)
    return logits[:, -1:], caches, enc_out


def decode_step(params, cfg: ModelConfig, token, enc_out, caches, pos):
    """One-token serve step with cached self-attn (cross-attn re-reads
    enc_out, which is resident)."""
    logits, caches = decode(params, cfg, token, enc_out, caches, pos0=pos)
    return logits, caches


def loss_fn(params, cfg: ModelConfig, frames, tokens, labels):
    logits, aux = forward(params, cfg, frames, tokens)
    mask = labels >= 0
    lab = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, -1)
    nll = -jnp.take_along_axis(logp, lab[..., None], -1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss + aux, (loss, aux)
