"""Decoder-only LM trunk shared by 8 of the 10 architectures.

Layer stack = [head (unrolled)] + [groups (lax.scan)] + [tail (unrolled)],
where a *group* is one period of ``cfg.block_pattern`` (e.g. gemma2's
(local, attn) pair, recurrentgemma's (rec, rec, attn) triple) and params
for scanned groups are stacked on a leading "layers" axis.  Scanning keeps
the HLO O(1) in depth — essential for compiling 64-layer full-size models
in the dry-run.

Entry points:
  * param_defs(cfg)                      — ParamDef tree
  * forward(params, cfg, tokens)        — train/eval logits
  * prefill(params, cfg, tokens)        — logits + caches
  * decode_step(params, cfg, token, caches) — one-token serve step
  * init_caches / cache_specs           — serving state + sharding specs
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..dist.sharding import shard
from . import attention as attn_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import (ParamDef, apply_mlp, apply_norm, embed_defs,
                     embed_lookup, is_def, logits_defs, apply_logits,
                     mlp_defs, norm_defs)

# ---------------------------------------------------------------------------
# structure helpers
# ---------------------------------------------------------------------------


def layer_plan(cfg: ModelConfig):
    """(head_kinds, pattern, n_groups, tail_kinds)."""
    head = []
    if cfg.moe is not None and cfg.moe.first_k_dense:
        head = ["dense_attn"] * cfg.moe.first_k_dense
    remaining = cfg.n_layers - len(head)
    pat = tuple(cfg.block_pattern)
    if not cfg.scan_layers:
        return head + [pat[i % len(pat)] for i in range(remaining)], pat, 0, []
    n_groups = remaining // len(pat)
    tail_n = remaining - n_groups * len(pat)
    tail = [pat[i % len(pat)] for i in range(n_groups * len(pat),
                                             n_groups * len(pat) + tail_n)]
    return head, pat, n_groups, tail


def _block_defs(cfg: ModelConfig, kind: str) -> dict:
    nk, d = cfg.norm_kind, cfg.d_model
    if kind == "ssm":
        return {"norm": norm_defs(nk, d), "ssm": ssm_mod.ssm_defs(cfg)}
    defs: dict[str, Any] = {"norm1": norm_defs(nk, d)}
    if kind in ("attn", "local", "moe_attn", "moe_local",
                "dense_attn"):
        defs["attn"] = attn_mod.attn_defs(cfg)
    elif kind == "rec":
        defs["rglru"] = rglru_mod.rglru_defs(cfg)
    defs["norm2"] = norm_defs(nk, d)
    if kind in ("moe_attn", "moe_local"):
        defs["moe"] = moe_mod.moe_defs(cfg)
    elif kind == "dense_attn":
        defs["mlp"] = mlp_defs(d, cfg.moe.d_ff_dense, cfg.mlp_kind)
    elif kind != "ssm":
        defs["mlp"] = mlp_defs(d, cfg.d_ff, cfg.mlp_kind)
    if cfg.post_block_norm:
        defs["post1"] = norm_defs(nk, d)
        if kind != "ssm":
            defs["post2"] = norm_defs(nk, d)
    return defs


def _stack_defs(defs: dict, n: int) -> dict:
    return jax.tree.map(
        lambda p: ParamDef((n,) + p.shape, ("layers",) + p.dims,
                           p.init, p.scale),
        defs, is_leaf=is_def)


def param_defs(cfg: ModelConfig) -> dict:
    head, pat, n_groups, tail = layer_plan(cfg)
    defs: dict[str, Any] = {"embed": embed_defs(cfg.vocab, cfg.d_model)}
    defs["head_blocks"] = [_block_defs(cfg, k) for k in head]
    if n_groups:
        defs["groups"] = {
            f"p{j}": _stack_defs(_block_defs(cfg, k), n_groups)
            for j, k in enumerate(pat)}
    defs["tail_blocks"] = [_block_defs(cfg, k) for k in tail]
    defs["final_norm"] = norm_defs(cfg.norm_kind, cfg.d_model)
    defs["logits"] = logits_defs(cfg.vocab, cfg.d_model, cfg.tie_embeddings)
    return defs


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def _blk_cache(cfg, kind, batch, max_len, dtype, mode):
    """mode: 'init' arrays | 'spec' logical dims."""
    if kind in ("attn", "local", "moe_attn", "moe_local",
                "dense_attn"):
        if mode == "init":
            return attn_mod.init_cache(cfg, batch, max_len, kind, dtype)
        return attn_mod.cache_spec(cfg, batch, max_len, kind)
    if kind == "ssm":
        return (ssm_mod.init_ssm_state(cfg, batch, dtype) if mode == "init"
                else ssm_mod.ssm_state_spec(cfg))
    if kind == "rec":
        return (rglru_mod.init_rglru_state(cfg, batch, dtype)
                if mode == "init" else rglru_mod.rglru_state_spec(cfg))
    raise ValueError(kind)


def _stack_cache(c, n):
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), c)


def _stack_cache_spec(c, n):
    return jax.tree.map(lambda dims: ("layers",) + tuple(dims), c,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype):
    head, pat, n_groups, tail = layer_plan(cfg)
    caches: dict[str, Any] = {
        "head": [_blk_cache(cfg, k, batch, max_len, dtype, "init")
                 for k in head]}
    if n_groups:
        caches["groups"] = {
            f"p{j}": _stack_cache(
                _blk_cache(cfg, k, batch, max_len, dtype, "init"), n_groups)
            for j, k in enumerate(pat)}
    caches["tail"] = [_blk_cache(cfg, k, batch, max_len, dtype, "init")
                      for k in tail]
    return caches


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    head, pat, n_groups, tail = layer_plan(cfg)
    specs: dict[str, Any] = {
        "head": [_blk_cache(cfg, k, batch, max_len, None, "spec")
                 for k in head]}
    if n_groups:
        specs["groups"] = {
            f"p{j}": _stack_cache_spec(
                _blk_cache(cfg, k, batch, max_len, None, "spec"), n_groups)
            for j, k in enumerate(pat)}
    specs["tail"] = [_blk_cache(cfg, k, batch, max_len, None, "spec")
                     for k in tail]
    return specs


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _apply_block(bp: dict, cfg: ModelConfig, kind: str, x, positions,
                 cache, aux):
    nk, eps = cfg.norm_kind, cfg.norm_eps
    if kind == "ssm":
        h, cache = ssm_mod.apply_ssm(
            bp["ssm"], cfg, apply_norm(bp["norm"], x, nk, eps), cache)
        if cfg.post_block_norm:
            h = apply_norm(bp["post1"], h, nk, eps)
        return x + h, cache, aux

    h = apply_norm(bp["norm1"], x, nk, eps)
    if kind == "rec":
        h, cache = rglru_mod.apply_rglru(bp["rglru"], cfg, h, cache)
    else:
        h, cache = attn_mod.attention(bp["attn"], cfg, kind, h, positions,
                                      cache)
    if cfg.post_block_norm:
        h = apply_norm(bp["post1"], h, nk, eps)
    x = x + h

    h = apply_norm(bp["norm2"], x, nk, eps)
    if kind in ("moe_attn", "moe_local"):
        h, a = moe_mod.apply_moe(bp["moe"], cfg, h)
        aux = aux + a
    else:
        h = apply_mlp(bp["mlp"], h, cfg.mlp_kind)
    if cfg.post_block_norm:
        h = apply_norm(bp["post2"], h, nk, eps)
    return x + h, cache, aux


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


# ---------------------------------------------------------------------------
# trunk
# ---------------------------------------------------------------------------


def _trunk(params, cfg: ModelConfig, x, positions, caches):
    """Shared by forward/prefill/decode. caches=None for pure training."""
    head, pat, n_groups, tail = layer_plan(cfg)
    aux = jnp.zeros((), jnp.float32)
    new_caches: dict[str, Any] = {"head": [], "tail": []}

    def get(cs, part, i):
        return None if cs is None else cs[part][i]

    for i, kind in enumerate(head):
        x, c, aux = _apply_block(params["head_blocks"][i], cfg, kind, x,
                                 positions, get(caches, "head", i), aux)
        new_caches["head"].append(c)

    if n_groups:
        gparams = params["groups"]
        gcaches = None if caches is None else caches["groups"]

        def body(carry, xs):
            xc, auxc = carry
            gp, gc = xs
            for j, kind in enumerate(pat):
                cj = None if gc is None else gc[f"p{j}"]
                xc, cj, auxc = _apply_block(gp[f"p{j}"], cfg, kind, xc,
                                            positions, cj, auxc)
                if gc is not None:
                    gc[f"p{j}"] = cj
            return (xc, auxc), gc

        body = _remat(body, cfg)
        (x, aux), gcaches_new = jax.lax.scan(
            body, (x, aux), (gparams, gcaches))
        new_caches["groups"] = gcaches_new

    for i, kind in enumerate(tail):
        x, c, aux = _apply_block(params["tail_blocks"][i], cfg, kind, x,
                                 positions, get(caches, "tail", i), aux)
        new_caches["tail"].append(c)

    x = apply_norm(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
    return x, (None if caches is None else new_caches), aux


def _embed_in(params, cfg, tokens):
    x = embed_lookup(params["embed"], tokens, cfg.embed_scale, cfg.d_model)
    return shard(x, "batch", "seq", "act_embed")


def forward(params, cfg: ModelConfig, tokens: jax.Array,
            embeds: Optional[jax.Array] = None):
    """Training/eval forward: tokens [b, t] -> (logits f32, aux)."""
    x = _embed_in(params, cfg, tokens) if embeds is None else embeds
    b, t = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    x, _, aux = _trunk(params, cfg, x, positions, None)
    logits = apply_logits(params["logits"], params["embed"], x,
                          cfg.tie_embeddings, cfg.softcap_final)
    return logits, aux


def prefill(params, cfg: ModelConfig, tokens: jax.Array, max_len: int,
            embeds: Optional[jax.Array] = None):
    """Prefill: fills caches, returns last-position logits + caches."""
    x = _embed_in(params, cfg, tokens) if embeds is None else embeds
    b, t = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    caches = init_caches(cfg, b, max_len, x.dtype)
    x, caches, aux = _trunk(params, cfg, x, positions, caches)
    logits = apply_logits(params["logits"], params["embed"], x[:, -1:],
                          cfg.tie_embeddings, cfg.softcap_final)
    return logits, caches


def decode_step(params, cfg: ModelConfig, token: jax.Array, caches,
                pos: jax.Array):
    """One serve step: token [b, 1], pos [] int32 -> (logits, caches)."""
    x = _embed_in(params, cfg, token)
    b = x.shape[0]
    positions = jnp.broadcast_to(pos.astype(jnp.int32), (b, 1))
    x, caches, _ = _trunk(params, cfg, x, positions, caches)
    logits = apply_logits(params["logits"], params["embed"], x,
                          cfg.tie_embeddings, cfg.softcap_final)
    return logits, caches


def forward_hidden(params, cfg: ModelConfig, tokens: jax.Array,
                   embeds: Optional[jax.Array] = None):
    """Trunk output before the LM head (for chunked-loss heads)."""
    x = _embed_in(params, cfg, tokens) if embeds is None else embeds
    b, t = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    x, _, aux = _trunk(params, cfg, x, positions, None)
    return x, aux


def _ce(logits, labels):
    mask = labels >= 0
    lab = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, lab[..., None], -1)[..., 0]
    return jnp.sum(nll * mask), jnp.sum(mask)


def loss_fn(params, cfg: ModelConfig, tokens, labels,
            embeds: Optional[jax.Array] = None):
    """Next-token cross-entropy (labels = -1 ignored) + MoE aux.

    cfg.loss_chunk > 0 streams the LM head over sequence chunks so the
    [b, t, vocab] logits tensor is never materialised (§Perf: at 256k
    vocab the f32 logits + softmax grads dominate train memory)."""
    if cfg.loss_chunk <= 0:
        logits, aux = forward(params, cfg, tokens, embeds)
        tot, cnt = _ce(logits, labels)
        loss = tot / jnp.maximum(cnt, 1)
        return loss + aux, (loss, aux)

    x, aux = forward_hidden(params, cfg, tokens, embeds)
    b, t, d = x.shape
    c = min(cfg.loss_chunk, t)
    nc = t // c
    xc = x[:, :nc * c].reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    lc = labels[:, :nc * c].reshape(b, nc, c).transpose(1, 0, 2)

    def body(carry, xs):
        xi, li = xs
        logits = apply_logits(params["logits"], params["embed"], xi,
                              cfg.tie_embeddings, cfg.softcap_final)
        tot, cnt = _ce(logits, li)
        return (carry[0] + tot, carry[1] + cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(())), (xc, lc))
    if nc * c < t:   # ragged tail
        logits = apply_logits(params["logits"], params["embed"],
                              x[:, nc * c:], cfg.tie_embeddings,
                              cfg.softcap_final)
        t2, c2 = _ce(logits, labels[:, nc * c:])
        tot, cnt = tot + t2, cnt + c2
    loss = tot / jnp.maximum(cnt, 1)
    return loss + aux, (loss, aux)
