"""InternVL2-style VLM: ViT-stub -> MLP projector -> InternLM2 trunk.

The InternViT frontend is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings [b, n_patches, vit_dim].  This
module owns the projector (vit_dim -> d_model) and splices the projected
patches in front of the token embeddings before running the standard
decoder trunk from ``transformer.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dist.sharding import shard
from .config import ModelConfig
from .layers import ParamDef, apply_norm, embed_lookup, norm_defs
from . import transformer


def param_defs(cfg: ModelConfig) -> dict:
    v = cfg.vlm
    defs = transformer.param_defs(cfg)
    defs["projector"] = {
        "norm": norm_defs("ln", v.vit_dim),
        "w1": ParamDef((v.vit_dim, cfg.d_model), ("fsdp", "tensor"),
                       "scaled"),
        "b1": ParamDef((cfg.d_model,), (None,), "zeros"),
        "w2": ParamDef((cfg.d_model, cfg.d_model), ("fsdp", "tensor"),
                       "scaled"),
        "b2": ParamDef((cfg.d_model,), (None,), "zeros"),
    }
    return defs


def project_patches(params, cfg: ModelConfig, patches: jax.Array):
    """[b, p, vit_dim] -> [b, p, d_model]."""
    pp = params["projector"]
    x = apply_norm(pp["norm"], patches, "ln", cfg.norm_eps)
    x = jnp.einsum("bpv,vd->bpd", x, pp["w1"].astype(x.dtype))
    x = jax.nn.gelu(x + pp["b1"].astype(x.dtype))
    x = jnp.einsum("bpd,de->bpe", x, pp["w2"].astype(x.dtype))
    return x + pp["b2"].astype(x.dtype)


def fuse_inputs(params, cfg: ModelConfig, patches, tokens):
    """Patch embeds ++ token embeds -> [b, p + t, d_model]."""
    img = project_patches(params, cfg, patches)
    txt = embed_lookup(params["embed"], tokens, cfg.embed_scale,
                       cfg.d_model)
    x = jnp.concatenate([img.astype(txt.dtype), txt], axis=1)
    return shard(x, "batch", "seq", "act_embed")


def forward(params, cfg: ModelConfig, patches, tokens):
    embeds = fuse_inputs(params, cfg, patches, tokens)
    return transformer.forward(params, cfg, tokens=None, embeds=embeds)


def loss_fn(params, cfg: ModelConfig, patches, tokens, labels):
    """labels align with the fused sequence; patch positions use -1."""
    logits, aux = forward(params, cfg, patches, tokens)
    mask = labels >= 0
    lab = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, -1)
    nll = -jnp.take_along_axis(logp, lab[..., None], -1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss + aux, (loss, aux)


def prefill(params, cfg: ModelConfig, patches, tokens, max_len: int):
    embeds = fuse_inputs(params, cfg, patches, tokens)
    return transformer.prefill(params, cfg, tokens=None, max_len=max_len,
                               embeds=embeds)
