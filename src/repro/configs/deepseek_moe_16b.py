"""deepseek-moe-16b [moe] — 28L d2048 16H (MHA kv=16) vocab 102400.
Fine-grained MoE: 64 routed experts (d_ff 1408) top-6 + 2 shared experts,
first layer dense (d_ff 10944). [arXiv:2401.06066; hf]"""

from ..models.config import ModelConfig, MoEConfig
from .common import reduced

ARCH = "deepseek-moe-16b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        head_dim=128, d_ff=1408, vocab=102400,
        block_pattern=("moe_attn",),
        moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                      n_shared=2, d_ff_shared=2816, first_k_dense=1,
                      d_ff_dense=10944, capacity_factor=1.25),
        rope_theta=1e4, mlp_kind="swiglu", norm_kind="rms",
        subquadratic=False,
        # §Perf default: MHA kv=16 scores dominate collectives
        attn_impl="blockwise")


def smoke_config() -> ModelConfig:
    return reduced(config(), n_layers=3, d_model=64, n_heads=4,
                   n_kv_heads=4, head_dim=16, d_ff=32, vocab=512,
                   moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                                 n_shared=2, d_ff_shared=64,
                                 first_k_dense=1, d_ff_dense=128,
                                 capacity_factor=8.0))
