"""whisper-base [audio] — enc-dec 6+6L d512 8H d_ff 2048 vocab 51865.
Conv audio frontend is a STUB: input_specs feeds precomputed frame
embeddings [b, 1500, 512].  LayerNorm, GELU, biases, learned positions.
[arXiv:2212.04356; unverified]"""

from ..models.config import EncDecConfig, ModelConfig
from .common import reduced

ARCH = "whisper-base"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
        head_dim=64, d_ff=2048, vocab=51865, qkv_bias=True,
        mlp_kind="gelu", norm_kind="ln", norm_eps=1e-5,
        encdec=EncDecConfig(n_enc_layers=6, enc_seq=1500),
        subquadratic=False)


def smoke_config() -> ModelConfig:
    return reduced(config(), n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=4, head_dim=16, d_ff=128, vocab=512,
                   encdec=EncDecConfig(n_enc_layers=2, enc_seq=16))
