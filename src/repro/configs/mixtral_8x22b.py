"""mixtral-8x22b [moe] — 56L d6144 48H (GQA kv=8) expert d_ff 16384
vocab 32768, 8 experts top-2, SWA 4096 (per assignment).
[arXiv:2401.04088; hf]"""

from ..models.config import ModelConfig, MoEConfig
from .common import reduced

ARCH = "mixtral-8x22b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        head_dim=128, d_ff=16384, vocab=32768,
        block_pattern=("moe_local",), window=4096,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384,
                      capacity_factor=1.25),
        rope_theta=1e6, mlp_kind="swiglu", norm_kind="rms",
        subquadratic=True,   # SWA bounds the KV cache
        # §Perf defaults: local sort dispatch over gathered tokens
        moe_impl="sort", moe_tokens="gathered")


def smoke_config() -> ModelConfig:
    return reduced(config(), n_layers=3, d_model=64, n_heads=4,
                   n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
                   window=16,
                   moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                                 capacity_factor=8.0))
