"""Shared shape table + input-spec builders for all assigned archs.

Every (arch x shape) cell is defined here once:
  * train_4k     seq 4,096   global_batch 256   -> train_step
  * prefill_32k  seq 32,768  global_batch 32    -> prefill
  * decode_32k   cache 32,768 global_batch 128  -> serve_step (1 token)
  * long_500k    cache 524,288 global_batch 1   -> serve_step (1 token)

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins only — no
allocation ever happens for full-size configs (dry-run contract).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    step: str                  # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

I32 = jnp.int32
BF16 = jnp.bfloat16


def supports(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runnable?, reason-if-skipped) per DESIGN.md §6."""
    cell = SHAPES[shape_name]
    if cell.step == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 500k decode state is "
                       "O(seq) full KV with quadratic prefill — skipped "
                       "per assignment")
    return True, ""


def input_specs(cfg: ModelConfig, shape_name: str) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cell = SHAPES[shape_name]
    b, t = cell.global_batch, cell.seq_len
    S = jax.ShapeDtypeStruct

    if cell.step == "train":
        if cfg.encdec is not None:
            return {
                "frames": S((b, cfg.encdec.enc_seq, cfg.d_model), BF16),
                "tokens": S((b, t), I32),
                "labels": S((b, t), I32),
            }
        if cfg.vlm is not None:
            p = cfg.vlm.n_patches
            return {
                "patches": S((b, p, cfg.vlm.vit_dim), BF16),
                "tokens": S((b, t - p), I32),
                "labels": S((b, t), I32),
            }
        return {"tokens": S((b, t), I32), "labels": S((b, t), I32)}

    if cell.step == "prefill":
        if cfg.encdec is not None:
            return {
                "frames": S((b, cfg.encdec.enc_seq, cfg.d_model), BF16),
                "tokens": S((b, t), I32),
            }
        if cfg.vlm is not None:
            p = cfg.vlm.n_patches
            return {
                "patches": S((b, p, cfg.vlm.vit_dim), BF16),
                "tokens": S((b, t - p), I32),
            }
        return {"tokens": S((b, t), I32)}

    # decode: one new token against a cache of length t
    return {"token": S((b, 1), I32)}


def decode_cache_len(shape_name: str) -> int:
    return SHAPES[shape_name].seq_len


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return dataclasses.replace(cfg, **overrides)
