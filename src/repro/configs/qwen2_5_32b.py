"""qwen2.5-32b [dense] — 64L d5120 40H (GQA kv=8) d_ff 27648 vocab 152064.
GQA + QKV bias, RoPE theta 1e6, SwiGLU, RMSNorm. [hf:Qwen/Qwen2.5; hf]"""

from ..models.config import ModelConfig
from .common import reduced

ARCH = "qwen2.5-32b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
        head_dim=128, d_ff=27648, vocab=152064, qkv_bias=True,
        rope_theta=1e6, mlp_kind="swiglu", norm_kind="rms",
        subquadratic=False)


def smoke_config() -> ModelConfig:
    return reduced(config(), n_layers=4, d_model=64, n_heads=8,
                   n_kv_heads=2, head_dim=8, d_ff=128, vocab=512)
