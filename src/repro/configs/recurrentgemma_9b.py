"""recurrentgemma-9b [hybrid] — 38L d4096 16H (MQA kv=1) d_ff 12288
vocab 256000.  RG-LRU + local attention 1:2 (pattern rec,rec,local),
window 2048, GeGLU, tied + scaled embeddings. [arXiv:2402.19427]"""

from ..models.config import ModelConfig, RGLRUConfig
from .common import reduced

ARCH = "recurrentgemma-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
        head_dim=256, d_ff=12288, vocab=256000,
        block_pattern=("rec", "rec", "local"), window=2048,
        rglru=RGLRUConfig(lru_width=4096, conv_width=4),
        mlp_kind="geglu", norm_kind="rms", tie_embeddings=True,
        embed_scale=True, subquadratic=True)


def smoke_config() -> ModelConfig:
    return reduced(config(), n_layers=5, d_model=64, n_heads=4,
                   n_kv_heads=1, head_dim=16, d_ff=128, vocab=512,
                   window=16, rglru=RGLRUConfig(lru_width=64, conv_width=4))
