"""gemma2-27b [dense] — 46L d4608 32H (GQA kv=16) d_ff 36864 vocab 256000.
Local(4096)+global alternating, logit softcaps (attn 50, final 30),
post-block norms, GeGLU, tied embeddings, query scale 1/sqrt(144).
[arXiv:2408.00118; hf]"""

import math

from ..models.config import ModelConfig
from .common import reduced

ARCH = "gemma2-27b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
        head_dim=128, d_ff=36864, vocab=256000,
        block_pattern=("local", "attn"), window=4096,
        softcap_attn=50.0, softcap_final=30.0,
        query_scale=1.0 / math.sqrt(144.0),       # query_pre_attn_scalar
        mlp_kind="geglu", norm_kind="rms", post_block_norm=True,
        tie_embeddings=True, embed_scale=True,
        subquadratic=True)   # local layers ring-bounded; global = O(seq)/tok


def smoke_config() -> ModelConfig:
    return reduced(config(), n_layers=4, d_model=64, n_heads=4,
                   n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
                   window=16, query_scale=1.0 / math.sqrt(16.0))
