"""falcon-mamba-7b [ssm] — 64L d4096 attn-free, ssm_state 16, vocab 65024.
Mamba-1 blocks: in_proj -> conv1d(4) -> selective SSM -> gate -> out_proj,
d_inner 8192 (expand 2), dt_rank 256. [arXiv:2410.05355; unverified]"""

from ..models.config import ModelConfig, SSMConfig
from .common import reduced

ARCH = "falcon-mamba-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
        head_dim=64, d_ff=0, vocab=65024, block_pattern=("ssm",),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, dt_rank=256),
        norm_kind="rms", subquadratic=True,
        # §Perf defaults (EXPERIMENTS.md): channel-sharded chunked scan
        ssm_shard="channel", ssm_chunk=512)


def smoke_config() -> ModelConfig:
    return reduced(config(), n_layers=4, d_model=64, vocab=512,
                   ssm=SSMConfig(d_state=4, d_conv=4, expand=2, dt_rank=8))
