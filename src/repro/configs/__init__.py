"""repro.configs — registry of the ten assigned architectures.

``get_config(arch)`` / ``get_smoke_config(arch)`` / ``input_specs`` /
``SHAPES`` are the public surface; the launcher and dry-run select with
``--arch <id>``.
"""

from __future__ import annotations

from ..models.config import ModelConfig
from . import (deepseek_moe_16b, falcon_mamba_7b, gemma2_27b,
               internvl2_26b, mixtral_8x22b, phi3_medium_14b, qwen2_5_32b,
               recurrentgemma_9b, starcoder2_3b, whisper_base)
from .common import (SHAPES, ShapeCell, decode_cache_len, input_specs,
                     supports)

_MODULES = {
    m.ARCH: m for m in (
        qwen2_5_32b, starcoder2_3b, gemma2_27b, phi3_medium_14b,
        recurrentgemma_9b, whisper_base, falcon_mamba_7b, mixtral_8x22b,
        deepseek_moe_16b, internvl2_26b)
}

ARCHS = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].smoke_config()


def all_cells():
    """Every (arch, shape) pair with its runnability verdict."""
    out = []
    for a in ARCHS:
        cfg = get_config(a)
        for s in SHAPES:
            ok, why = supports(cfg, s)
            out.append((a, s, ok, why))
    return out


__all__ = ["ARCHS", "SHAPES", "ShapeCell", "all_cells", "decode_cache_len",
           "get_config", "get_smoke_config", "input_specs", "supports"]
