"""internvl2-26b [vlm] — InternViT (STUB) + InternLM2-20B backbone:
48L d6144 48H (GQA kv=8) d_ff 16384 vocab 92553.  input_specs feeds
precomputed patch embeddings [b, 1024, 3200]. [arXiv:2404.16821; hf]"""

from ..models.config import ModelConfig, VLMConfig
from .common import reduced

ARCH = "internvl2-26b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        head_dim=128, d_ff=16384, vocab=92553, rope_theta=1e6,
        mlp_kind="swiglu", norm_kind="rms",
        vlm=VLMConfig(n_patches=1024, vit_dim=3200),
        subquadratic=False)


def smoke_config() -> ModelConfig:
    return reduced(config(), n_layers=3, d_model=64, n_heads=4,
                   n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
                   vlm=VLMConfig(n_patches=8, vit_dim=48))
