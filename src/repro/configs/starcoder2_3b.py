"""starcoder2-3b [dense] — 30L d3072 24H (GQA kv=2) d_ff 12288 vocab 49152.
GQA, RoPE ~1e6, LayerNorm + GELU MLP, attention/MLP bias.
[arXiv:2402.19173; hf]"""

from ..models.config import ModelConfig
from .common import reduced

ARCH = "starcoder2-3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
        head_dim=128, d_ff=12288, vocab=49152, qkv_bias=True,
        rope_theta=999999.44, mlp_kind="gelu", norm_kind="ln",
        norm_eps=1e-5, subquadratic=False)


def smoke_config() -> ModelConfig:
    return reduced(config(), n_layers=3, d_model=48, n_heads=6,
                   n_kv_heads=2, head_dim=8, d_ff=96, vocab=512)
