"""phi3-medium-14b [dense] — 40L d5120 40H (GQA kv=10) d_ff 17920
vocab 100352.  RoPE, SwiGLU, RMSNorm. [arXiv:2404.14219; unverified]"""

from ..models.config import ModelConfig
from .common import reduced

ARCH = "phi3-medium-14b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
        head_dim=128, d_ff=17920, vocab=100352, rope_theta=1e4,
        mlp_kind="swiglu", norm_kind="rms", subquadratic=False)


def smoke_config() -> ModelConfig:
    return reduced(config(), n_layers=4, d_model=80, n_heads=8,
                   n_kv_heads=2, head_dim=10, d_ff=160, vocab=512)
