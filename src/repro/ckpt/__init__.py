"""repro.ckpt — atomic sharded checkpoints with elastic restore."""

from .checkpoint import (CheckpointManager, committed_steps,
                         load_checkpoint, save_checkpoint, latest_step)

__all__ = ["CheckpointManager", "committed_steps", "load_checkpoint",
           "save_checkpoint", "latest_step"]
