"""Fault-tolerant checkpointing.

Layout:  <dir>/step_000123/
            manifest.json       — step, data step, config hash, tree spec
            arrays.npz          — flat {path: array} (host 0's view)
         <dir>/step_000123.done — commit marker (atomic rename)

Properties required at 1000-node scale and tested in tests/test_ckpt.py:
  * **atomic**: partially-written checkpoints are never visible (write to
    tmp dir, fsync, rename; .done marker commits),
  * **async**: `CheckpointManager.save_async` runs serialisation off the
    step loop (straggler-free saves),
  * **elastic**: arrays are saved densely and re-sharded on load onto any
    mesh (restore is `jax.device_put(value, sharding)` per leaf),
  * **exact resume**: the data-pipeline step and RNG state live in the
    manifest, so training resumes bit-identically.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, tuple) and hasattr(tree, "_fields"):
        for f in tree._fields:          # namedtuple: field-name paths,
            out.update(_flatten(getattr(tree, f), f"{prefix}{f}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif tree is None:
        pass
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _tree_template(tree):
    """JSON-able structure descriptor used to rebuild on load."""
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _tree_template(v) for k, v in tree.items()}}
    if isinstance(tree, tuple) and hasattr(tree, "_fields"):
        return {"__kind__": "namedtuple", "cls": type(tree).__name__,
                "items": {f: _tree_template(getattr(tree, f))
                          for f in tree._fields}}
    if isinstance(tree, (list, tuple)):
        return {"__kind__": "list" if isinstance(tree, list) else "tuple",
                "items": [_tree_template(v) for v in tree]}
    if tree is None:
        return {"__kind__": "none"}
    return {"__kind__": "leaf"}


def _rebuild(template, flat, prefix="", nt_registry=None):
    k = template["__kind__"]
    if k == "none":
        return None
    if k == "leaf":
        return flat[prefix.rstrip("/")]
    if k == "dict":
        return {key: _rebuild(v, flat, f"{prefix}{key}/", nt_registry)
                for key, v in template["items"].items()}
    if k == "namedtuple":
        vals = {key: _rebuild(v, flat, f"{prefix}{key}/", nt_registry)
                for key, v in template["items"].items()}
        cls = (nt_registry or {}).get(template["cls"])
        return cls(**vals) if cls else vals
    seq = [_rebuild(v, flat, f"{prefix}{i}/", nt_registry)
           for i, v in enumerate(template["items"])]
    return seq if k == "list" else tuple(seq)


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Atomic synchronous save. Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "template": _tree_template(tree),
                "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(final + ".done", "w") as f:   # commit marker
        f.write("ok")
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith((".tmp", ".done")):
            if os.path.exists(os.path.join(directory, name) + ".done"):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: Optional[int] = None,
                    shardings: Any = None, nt_registry=None):
    """Load (tree, extra). `shardings`: optional matching tree of
    NamedShardings — arrays are device_put onto them (elastic restore)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    tree = _rebuild(manifest["template"], flat, nt_registry=nt_registry)
    if shardings is not None:
        tree = jax.tree.map(
            lambda v, s: jax.device_put(v, s), tree, shardings)
    return tree, manifest["extra"]


class CheckpointManager:
    """Async saver with bounded retention + straggler-free commits."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any, extra=None) -> None:
        self.wait()
        # materialise on host *before* returning control to the step loop
        host_tree = jax.tree.map(lambda v: np.asarray(jax.device_get(v)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith((".tmp", ".done"))
            and os.path.exists(os.path.join(self.directory, n) + ".done"))
        for s in steps[:-self.keep] if self.keep else []:
            p = os.path.join(self.directory, f"step_{s:09d}")
            shutil.rmtree(p, ignore_errors=True)
            try:
                os.remove(p + ".done")
            except OSError:
                pass
