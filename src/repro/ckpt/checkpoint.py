"""Fault-tolerant checkpointing.

Layout:  <dir>/step_000123/
            manifest.json       — step, data step, config hash, tree spec
            arrays.npz          — flat {path: array} (host 0's view)
         <dir>/step_000123.done — commit marker (atomic rename)

Properties required at 1000-node scale and tested in tests/test_ckpt.py:
  * **atomic**: partially-written checkpoints are never visible (write to
    tmp dir, fsync, rename; .done marker commits),
  * **async**: `CheckpointManager.save_async` runs serialisation off the
    step loop (straggler-free saves),
  * **elastic**: arrays are saved densely and re-sharded on load onto any
    mesh (restore is `jax.device_put(value, sharding)` per leaf),
  * **exact resume**: the data-pipeline step and RNG state live in the
    manifest, so training resumes bit-identically.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zipfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, tuple) and hasattr(tree, "_fields"):
        for f in tree._fields:          # namedtuple: field-name paths,
            out.update(_flatten(getattr(tree, f), f"{prefix}{f}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif tree is None:
        pass
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _tree_template(tree):
    """JSON-able structure descriptor used to rebuild on load."""
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _tree_template(v) for k, v in tree.items()}}
    if isinstance(tree, tuple) and hasattr(tree, "_fields"):
        return {"__kind__": "namedtuple", "cls": type(tree).__name__,
                "items": {f: _tree_template(getattr(tree, f))
                          for f in tree._fields}}
    if isinstance(tree, (list, tuple)):
        return {"__kind__": "list" if isinstance(tree, list) else "tuple",
                "items": [_tree_template(v) for v in tree]}
    if tree is None:
        return {"__kind__": "none"}
    return {"__kind__": "leaf"}


def _rebuild(template, flat, prefix="", nt_registry=None):
    k = template["__kind__"]
    if k == "none":
        return None
    if k == "leaf":
        return flat[prefix.rstrip("/")]
    if k == "dict":
        return {key: _rebuild(v, flat, f"{prefix}{key}/", nt_registry)
                for key, v in template["items"].items()}
    if k == "namedtuple":
        vals = {key: _rebuild(v, flat, f"{prefix}{key}/", nt_registry)
                for key, v in template["items"].items()}
        cls = (nt_registry or {}).get(template["cls"])
        return cls(**vals) if cls else vals
    seq = [_rebuild(v, flat, f"{prefix}{i}/", nt_registry)
           for i, v in enumerate(template["items"])]
    return seq if k == "list" else tuple(seq)


def _fsync_dir(path: str) -> None:
    """Durably record a directory's entries (renames included)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:                    # platform without dir-open: best
        return                         # effort, the data fsyncs stand
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Atomic synchronous save. Returns the committed path.

    Preemption-safe: everything is written and fsynced inside a
    ``.tmp`` dir, renamed into place, and only then committed by the
    ``.done`` marker (itself written via temp + atomic rename, so a
    marker can never exist half-written).  A kill at ANY point leaves
    either no visible checkpoint for this step or a fully committed
    one — ``load_checkpoint`` / ``latest_step`` ignore everything else.
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    manifest = {"step": step, "template": _tree_template(tree),
                "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    done_tmp = final + ".done.tmp"
    with open(done_tmp, "w") as f:   # commit marker: temp + rename so
        f.write("ok")                # it is atomic like everything else
        f.flush()
        os.fsync(f.fileno())
    os.rename(done_tmp, final + ".done")
    _fsync_dir(directory)
    return final


def latest_step(directory: str) -> Optional[int]:
    steps = committed_steps(directory)
    return steps[-1] if steps else None


def committed_steps(directory: str) -> list[int]:
    """All committed (``.done``-marked) steps, ascending."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith((".tmp", ".done")):
            if os.path.exists(os.path.join(directory, name) + ".done"):
                steps.append(int(name.split("_")[1]))
    return sorted(steps)


def _load_step(directory: str, step: int, shardings, nt_registry):
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    tree = _rebuild(manifest["template"], flat, nt_registry=nt_registry)
    if shardings is not None:
        tree = jax.tree.map(
            lambda v, s: jax.device_put(v, s), tree, shardings)
    return tree, manifest["extra"]


def load_checkpoint(directory: str, step: Optional[int] = None,
                    shardings: Any = None, nt_registry=None):
    """Load (tree, extra). `shardings`: optional matching tree of
    NamedShardings — arrays are device_put onto them (elastic restore).

    With ``step=None``, walks the committed steps newest-first and
    skips torn/partial checkpoints (unreadable manifest or arrays —
    e.g. a ``.done`` marker surviving a corrupted write) instead of
    crashing, so a fleet resuming after preemption always lands on the
    newest checkpoint that actually loads.  An explicit ``step`` must
    be committed (``.done`` marker present) and intact.
    """
    if step is not None:
        path = os.path.join(directory, f"step_{step:09d}")
        if not os.path.exists(path + ".done"):
            raise FileNotFoundError(
                f"checkpoint step {step} in {directory} is missing or "
                f"uncommitted (no .done marker — torn write?)")
        return _load_step(directory, step, shardings, nt_registry)
    errors = []
    for s in reversed(committed_steps(directory)):
        try:
            return _load_step(directory, s, shardings, nt_registry)
        except (OSError, ValueError, KeyError, EOFError,
                json.JSONDecodeError, zipfile.BadZipFile) as e:
            errors.append(f"step {s}: {e!r}")   # torn/corrupt: try older
    detail = f" (skipped torn: {'; '.join(errors)})" if errors else ""
    raise FileNotFoundError(
        f"no loadable committed checkpoint in {directory}{detail}")


class CheckpointManager:
    """Async saver with bounded retention + straggler-free commits."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any, extra=None) -> None:
        self.wait()
        # materialise on host *before* returning control to the step loop
        host_tree = jax.tree.map(lambda v: np.asarray(jax.device_get(v)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith((".tmp", ".done"))
            and os.path.exists(os.path.join(self.directory, n) + ".done"))
        for s in steps[:-self.keep] if self.keep else []:
            p = os.path.join(self.directory, f"step_{s:09d}")
            shutil.rmtree(p, ignore_errors=True)
            try:
                os.remove(p + ".done")
            except OSError:
                pass
