"""FabricSpec — declarative, hashable fabric descriptions.

A ``FabricSpec`` names a topology family + its parameters as plain
data, so it can sit inside a (frozen, hashable) ``ScenarioSpec`` and
key jit/result caches.  ``build`` / ``route_table`` materialise the
``Topology`` and its validated ``RouteTable`` once per (spec,
line_rate) — sweeping 3 CC schemes over one fabric builds its table a
single time.  ``route_set(k, seed)`` is the multi-path analogue
(minimal + Valiant detour candidates, cached per (spec, k, seed)) that
adaptive routing modes select from at run time.

Families:
  * ``clos3``      — the paper's 3-stage CLOS (closed-form D-mod-K,
                     materialised as a table; ``roll`` picks the wiring)
  * ``xgft``       — XGFT(h; m; w) with arbitrary arities / tapering
  * ``fat_tree``   — sugar: k-ary 3-level XGFT with a leaf taper
  * ``dragonfly``  — dragonfly(a, p, h[, groups]), minimal routing
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.topology import Topology, make_clos3

from .routing import (RouteSet, RouteTable, clos_route_set,
                      clos_route_table, dragonfly_route_set,
                      dragonfly_route_table, validate_route_set,
                      validate_table, xgft_route_set, xgft_route_table)
from .topologies import (DragonflyIndex, XGFTIndex, fat_tree_mw,
                         make_dragonfly, make_xgft)


@dataclasses.dataclass(frozen=True)
class FabricSpec:
    """One fabric as plain data; ``build``/``route_table`` are cached."""

    kind: str = "clos3"           # clos3 | xgft | dragonfly
    arity: int = 4                # clos3
    roll: int = 0                 # D-mod-K digit roll (clos3 / xgft)
    m: tuple[int, ...] = ()       # xgft down-arities
    w: tuple[int, ...] = ()       # xgft parent multiplicities
    a: int = 4                    # dragonfly routers / group
    p: int = 2                    # dragonfly hosts / router
    h: int = 2                    # dragonfly global ports / router
    groups: int | None = None     # dragonfly groups (None = a*h + 1)
    # per-link capacity heterogeneity: (link class -> rate multiplier)
    # pairs, applied to ``Topology.link_capacity`` at build.  Classes:
    # XGFT/CLOS ``up1..uph`` / ``dn1..dnh`` (level 1 = host edge);
    # dragonfly ``hostup`` / ``hostdn`` / ``local`` / ``global``.
    # Empty = uniform (bitwise identical to the pre-heterogeneity
    # builds).  Use ``with_rates(up2=4.0)`` to construct.
    rate_scales: tuple[tuple[str, float], ...] = ()

    # -- constructors -------------------------------------------------------

    @classmethod
    def clos3(cls, arity: int = 4, roll: int = 0) -> "FabricSpec":
        return cls(kind="clos3", arity=arity, roll=roll)

    @classmethod
    def xgft(cls, m, w, roll: int = 0) -> "FabricSpec":
        return cls(kind="xgft", m=tuple(int(v) for v in m),
                   w=tuple(int(v) for v in w), roll=roll)

    @classmethod
    def fat_tree(cls, arity: int = 4, taper: int = 1, levels: int = 3,
                 roll: int = 0) -> "FabricSpec":
        """k-ary fat tree; ``taper=2`` gives 2:1 leaf oversubscription."""
        return cls.xgft(*fat_tree_mw(arity, taper, levels), roll=roll)

    @classmethod
    def dragonfly(cls, a: int = 4, p: int = 2, h: int = 2,
                  groups: int | None = None) -> "FabricSpec":
        return cls(kind="dragonfly", a=a, p=p, h=h, groups=groups)

    def with_rates(self, **scales: float) -> "FabricSpec":
        """Per-link-class capacity multipliers (heterogeneous fabrics).

        ``FabricSpec.fat_tree(4).with_rates(up2=4.0, dn2=4.0)`` models
        fast uplinks (hosts at 1x, leaf->spine wires at 4x);
        ``with_rates(global_=0.5)`` (note the trailing underscore for
        the python keyword) halves dragonfly global channels.  Scales
        compose with earlier ones; the class names are validated at
        build time against the fabric family.
        """
        merged = dict(self.rate_scales)
        for k, v in scales.items():
            key = k.rstrip("_")
            merged[key] = merged.get(key, 1.0) * float(v)
        return dataclasses.replace(
            self, rate_scales=tuple(sorted(merged.items())))

    # -- materialisation ----------------------------------------------------

    @property
    def name(self) -> str:
        if self.kind == "clos3":
            base = f"clos{self.arity ** 3}" + \
                (f"_r{self.roll}" if self.roll else "")
        elif self.kind == "xgft":
            base = ("xgft" + "x".join(map(str, self.m)) + "_w"
                    + "x".join(map(str, self.w)))
        else:
            g = self.a * self.h + 1 if self.groups is None else self.groups
            base = f"dfly_a{self.a}p{self.p}h{self.h}g{g}"
        for cls, scale in self.rate_scales:
            base += f"+{cls}x{scale:g}"
        return base

    @property
    def n_nodes(self) -> int:
        if self.kind == "clos3":
            return self.arity ** 3
        if self.kind == "xgft":
            n = 1
            for v in self.m:
                n *= v
            return n
        g = self.a * self.h + 1 if self.groups is None else self.groups
        return g * self.a * self.p

    def build(self, line_rate: float = 12.5e9) -> Topology:
        return _build_topo(self, float(line_rate))

    @property
    def _structural(self) -> "FabricSpec":
        """This fabric with capacity scales stripped — routing is pure
        structure, so scaled variants share the unscaled spec's route
        caches instead of rebuilding O(N^2 * H) tables."""
        if not self.rate_scales:
            return self
        return dataclasses.replace(self, rate_scales=())

    def route_table(self) -> RouteTable:
        """The fabric's validated route table.

        Tables are pure structure — link *ids*, not capacities — so the
        cache is keyed on the structural spec alone; sweeping line
        rates or per-class capacity scales never rebuilds the table.
        """
        return _build_table(self._structural)

    def route_set(self, k_paths: int = 4, seed: int = 0) -> RouteSet:
        """K-candidate multi-path routes (slot 0 minimal, 1..K-1
        Valiant detours); validated + cached per (spec, k, seed)."""
        return _build_route_set(self._structural, int(k_paths), int(seed))

    def flow_routes(self, pairs) -> "np.ndarray":
        """[F, H_MAX] minimal routes for (src, dst) pairs, cached per
        (spec hash, pairs) — a sweep's grid points share one extraction
        (and, downstream, one device upload + one incidence sort).
        Treat as read-only: the array is shared across callers.
        """
        return _flow_routes(self._structural,
                            tuple(tuple(p) for p in pairs))

    def flow_route_set(self, pairs, k_paths: int = 4, seed: int = 0):
        """([F, K, H_MAX] candidate routes, [F, K] hops) for pairs,
        cached per (spec hash, pairs, k, seed); read-only like
        ``flow_routes``."""
        return _flow_route_set(self._structural,
                               tuple(tuple(p) for p in pairs),
                               int(k_paths), int(seed))


def _link_class_ids(spec: FabricSpec) -> "dict[str, np.ndarray]":
    """Link ids per named class, for the per-class capacity scales.

    XGFT/CLOS expose one class per stage and direction (``up1`` = host
    edge up, ``up2`` = leaf uplinks, ..., ``dnl`` the mirror);
    dragonfly exposes ``hostup`` / ``hostdn`` / ``local`` / ``global``.
    """
    if spec.kind == "clos3":
        a3 = spec.arity ** 3
        seg = lambda i: np.arange(i * a3, (i + 1) * a3)
        return {"up1": seg(0), "up2": seg(1), "up3": seg(2),
                "dn3": seg(3), "dn2": seg(4), "dn1": seg(5)}
    if spec.kind == "xgft":
        idx = XGFTIndex(spec.m, spec.w)      # pure digit arithmetic —
        out = {}                             # no topology materialised
        for l in range(1, idx.h + 1):
            out[f"up{l}"] = idx.up_stage_ids(l)
            n_dn = idx.n_level(l) * idx.m[l - 1]
            out[f"dn{l}"] = np.arange(idx.dn_base(l),
                                      idx.dn_base(l) + n_dn)
        return out
    if spec.kind == "dragonfly":
        g = spec.a * spec.h + 1 if spec.groups is None else spec.groups
        idx = DragonflyIndex(a=spec.a, p=spec.p, h=spec.h, g=g)
        n = idx.n_hosts
        return {"hostup": np.arange(0, n),
                "hostdn": np.arange(n, 2 * n),
                "local": idx.local_ids(),
                "global": idx.global_ids()}
    raise ValueError(f"unknown fabric kind: {spec.kind!r}")


def _apply_rate_scales(spec: FabricSpec, topo: Topology) -> Topology:
    if not spec.rate_scales:
        return topo                  # uniform fabrics: untouched arrays
    classes = _link_class_ids(spec)
    cap = topo.link_capacity.copy()
    for cls, scale in spec.rate_scales:
        if cls not in classes:
            raise ValueError(
                f"unknown link class {cls!r} for {spec.kind} fabric; "
                f"available: {sorted(classes)}")
        cap[classes[cls]] *= scale
    return dataclasses.replace(topo, link_capacity=cap)


@functools.lru_cache(maxsize=64)
def _build_topo(spec: FabricSpec, line_rate: float) -> Topology:
    """Materialise one fabric's Topology; cached per (spec, line_rate).

    ``spec.rate_scales`` multiplies whole link classes (tapered or
    accelerated uplinks, slow global channels); the scaled capacities
    thread through ``Scenario.capacity`` into ``ScenarioDev.cap_ext``
    untouched, so heterogeneity costs the fluid loop nothing.  The
    returned arrays are shared across callers — treat as read-only.
    """
    if spec.kind == "clos3":
        topo = make_clos3(arity=spec.arity, line_rate=line_rate,
                          name=spec.name)
    elif spec.kind == "xgft":
        topo = make_xgft(spec.m, spec.w, line_rate=line_rate,
                         name=spec.name)[0]
    elif spec.kind == "dragonfly":
        topo = make_dragonfly(spec.a, spec.p, spec.h, groups=spec.groups,
                              line_rate=line_rate, name=spec.name)[0]
    else:
        raise ValueError(f"unknown fabric kind: {spec.kind!r}")
    return _apply_rate_scales(spec, topo)


@functools.lru_cache(maxsize=64)
def _build_table(spec: FabricSpec) -> RouteTable:
    """Build + validate one fabric's route table; cached per spec."""
    if spec.kind == "clos3":
        table = clos_route_table(spec.arity, roll=spec.roll)
    elif spec.kind == "xgft":
        _, idx = make_xgft(spec.m, spec.w)
        table = xgft_route_table(idx, roll=spec.roll)
    elif spec.kind == "dragonfly":
        _, idx = make_dragonfly(spec.a, spec.p, spec.h,
                                groups=spec.groups)
        table = dragonfly_route_table(idx)
    else:
        raise ValueError(f"unknown fabric kind: {spec.kind!r}")
    validate_table(_build_topo(spec, 12.5e9), table)
    return table


def _frozen(a: np.ndarray) -> np.ndarray:
    """Cached arrays are shared across callers; make 'read-only' real —
    an in-place edit raises instead of corrupting every later build."""
    a.setflags(write=False)
    return a


@functools.lru_cache(maxsize=256)
def _flow_routes(spec: FabricSpec, pairs: tuple):
    return _frozen(_build_table(spec).routes_for_pairs(pairs))


@functools.lru_cache(maxsize=256)
def _flow_route_set(spec: FabricSpec, pairs: tuple, k: int, seed: int):
    rset = _build_route_set(spec, k, seed)
    return (_frozen(rset.routes_for_pairs(pairs)),
            _frozen(rset.hops_for_pairs(pairs)))


@functools.lru_cache(maxsize=64)
def _build_route_set(spec: FabricSpec, k: int, seed: int) -> RouteSet:
    """Build + validate one fabric's multi-path RouteSet; cached."""
    if spec.kind == "clos3":
        rset = clos_route_set(spec.arity, k=k, seed=seed, roll=spec.roll)
    elif spec.kind == "xgft":
        _, idx = make_xgft(spec.m, spec.w)
        rset = xgft_route_set(idx, k=k, seed=seed, roll=spec.roll)
    elif spec.kind == "dragonfly":
        _, idx = make_dragonfly(spec.a, spec.p, spec.h, groups=spec.groups)
        rset = dragonfly_route_set(idx, k=k, seed=seed)
    else:
        raise ValueError(f"unknown fabric kind: {spec.kind!r}")
    validate_route_set(_build_topo(spec, 12.5e9), rset)
    return rset
