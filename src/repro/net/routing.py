"""Table-driven routing: precomputed per-(src,dst) padded link-id paths.

The general routing path of the repo: every fabric family provides a
*table builder* that emits a ``RouteTable`` — a dense
``[N, N, H_MAX]`` int32 array of directed-link ids (PAD = -1, trailing)
with per-pair hop counts — and every scenario then routes by table
lookup (``routes_for_pairs``).  ``H_MAX`` varies by fabric (2h for an
h-level XGFT, 5 for a dragonfly), replacing the CLOS-only hardwired
``H_MAX = 6``; the fluid model is shape-polymorphic in hops, and mixed
fabrics pad to a common H when stacked into one Sweep.

The closed-form CLOS D-mod-K of ``repro.core.routing`` survives as one
table builder among several (``clos_route_table``) — same link ids,
same wirings (``roll``), just materialised once per fabric instead of
recomputed per flow.

``validate_table`` is the vectorised validity checker every builder is
held to: paths start at the source host, end at the destination host,
consecutive links share a switch, and padding is trailing-only.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.routing import PAD, clos_route
from repro.core.topology import ClosIndex, Topology

from .topologies import DragonflyIndex, XGFTIndex


@dataclasses.dataclass(frozen=True)
class RouteTable:
    """Dense per-(src,dst) padded link-id paths for one fabric.

    ``paths[s, d, :hops[s, d]]`` are real link ids; the rest is PAD.
    ``paths[s, s]`` is all-PAD (no self-traffic).
    """

    paths: np.ndarray             # [N, N, H_MAX] int32, PAD-padded
    hops: np.ndarray              # [N, N] int32

    @property
    def n_nodes(self) -> int:
        return self.paths.shape[0]

    @property
    def h_max(self) -> int:
        return self.paths.shape[2]

    def routes_for_pairs(self, pairs) -> np.ndarray:
        """[F, H_MAX] int32 route matrix for (src, dst) pairs."""
        if not len(pairs):
            return np.empty((0, self.h_max), np.int32)
        idx = np.asarray(pairs, np.int64)
        if idx.ndim != 2 or idx.shape[1] != 2:
            raise ValueError(f"pairs must be [F, 2], got {idx.shape}")
        if (idx < 0).any() or (idx >= self.n_nodes).any():
            raise ValueError(
                f"pair endpoints must be host ids in [0, {self.n_nodes})")
        return self.paths[idx[:, 0], idx[:, 1]].copy()

    def link_load(self, n_links: int,
                  pairs=None) -> np.ndarray:
        """Flow-routes crossing each link (all-to-all, or given pairs)."""
        routes = (self.paths.reshape(-1, self.h_max) if pairs is None
                  else self.routes_for_pairs(pairs))
        ids = routes[routes != PAD]
        return np.bincount(ids, minlength=n_links).astype(np.int64)


def _from_path_fn(n: int, h_max: int, path_fn) -> RouteTable:
    """Materialise ``path_fn(s, d) -> list[int]`` into a RouteTable."""
    paths = np.full((n, n, h_max), PAD, np.int32)
    hops = np.zeros((n, n), np.int32)
    for s in range(n):
        for d in range(n):
            if s == d:
                continue
            p = path_fn(s, d)
            if len(p) > h_max:
                raise ValueError(
                    f"path {s}->{d} has {len(p)} hops > H_MAX={h_max}")
            paths[s, d, : len(p)] = p
            hops[s, d] = len(p)
    return RouteTable(paths=paths, hops=hops)


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------


def clos_route_table(arity: int = 4, roll: int = 0) -> RouteTable:
    """The 3-stage CLOS closed form, materialised as a table (H_MAX=6)."""
    idx = ClosIndex(arity)
    n = arity ** 3
    return _from_path_fn(n, 6, lambda s, d: clos_route(idx, s, d, roll=roll))


def xgft_path(idx: XGFTIndex, s: int, d: int, roll: int = 0) -> list[int]:
    """Deterministic D-mod-K up-down path in XGFT(h; m; w).

    Ascends to the lowest common ancestor level L (highest host digit
    where s and d differ); the up-link slot at each level j is a
    destination digit — ``(d // W[(j-1+roll) % h]) % w_j`` with
    ``W[k] = prod(w[:k])`` — so all-to-all traffic spreads evenly over
    every up stage; the down path is forced by d's digits.
    """
    if s == d:
        return []
    h, m, w = idx.h, idx.m, idx.w
    sx, dx = idx.host_digits(s), idx.host_digits(d)
    L = max(j for j in range(1, h + 1) if sx[j - 1] != dx[j - 1])
    W = [1]
    for j in range(1, h):
        W.append(W[-1] * w[j - 1])
    path = []
    y = [0] * h
    cur = s                                     # level-0 index = host id
    for j in range(1, L + 1):                   # ascend, choosing y_j
        y[j - 1] = (d // W[(j - 1 + roll) % h]) % w[j - 1]
        path.append(idx.up(j, cur, y[j - 1]))
        cur = idx.node_index(j, sx, y)
    for j in range(L, 0, -1):                   # descend along d's digits
        path.append(idx.dn(j, cur, dx[j - 1]))
        # the level-(j-1) child has d's x-digits at every position >= j
        # (above L they equal s's) and the ascent's y-digits below j
        cur = idx.node_index(j - 1, dx, y)
    return path


def xgft_route_table(idx: XGFTIndex, roll: int = 0) -> RouteTable:
    """D-mod-K table for an XGFT; H_MAX = 2 * levels."""
    return _from_path_fn(idx.n_hosts, 2 * idx.h,
                         lambda s, d: xgft_path(idx, s, d, roll=roll))


def dragonfly_path(idx: DragonflyIndex, s: int, d: int) -> list[int]:
    """Minimal dragonfly route: local -> global -> local (<= 5 links)."""
    if s == d:
        return []
    a, p = idx.a, idx.p
    rs, rd = (s // p) % a, (d // p) % a
    gs, gd = s // (a * p), d // (a * p)
    up, dn = s, idx.n_hosts + d
    if gs == gd:
        if rs == rd:
            return [up, dn]
        return [up, idx.local(gs, rs, rd), dn]
    path = [up]
    gw = idx.gl_owner(gs, gd)                   # gateway router in gs
    if rs != gw:
        path.append(idx.local(gs, rs, gw))
    path.append(idx.gl_port(gs, gd))
    rin = idx.gl_owner(gd, gs)                  # arrival router in gd
    if rin != rd:
        path.append(idx.local(gd, rin, rd))
    path.append(dn)
    return path


def dragonfly_route_table(idx: DragonflyIndex) -> RouteTable:
    """Minimal-route table for a dragonfly; H_MAX = 5."""
    return _from_path_fn(idx.n_hosts, 5,
                         lambda s, d: dragonfly_path(idx, s, d))


# ---------------------------------------------------------------------------
# validity checking
# ---------------------------------------------------------------------------


def validate_table(topo: Topology, table: RouteTable) -> None:
    """Structural validity of a full route table (vectorised).

    Raises AssertionError unless, for every (s, d) pair with s != d:
    the first link leaves host s, the last link delivers to host d,
    consecutive links share a switch (sink(h) == source(h+1)), all
    link ids are in range, and padding is trailing-only.
    """
    n, h = table.n_nodes, table.h_max
    paths, hops = table.paths, table.hops
    if topo.n_nodes != n:
        raise AssertionError(
            f"table is for {n} hosts, topology has {topo.n_nodes}")
    valid = paths != PAD
    # trailing-only padding, and hops consistent with the mask
    want = np.arange(h)[None, None, :] < hops[..., None]
    if not (valid == want).all():
        raise AssertionError("non-trailing PAD or hops/path mismatch")
    off = ~np.eye(n, dtype=bool)
    if not (hops[off] >= 2).all() or (hops.diagonal() != 0).any():
        raise AssertionError("every s != d path needs >= 2 links "
                             "(host up + host down); s == s must be empty")
    ids = paths[valid]
    if ids.size and (ids.min() < 0 or ids.max() >= topo.n_links):
        raise AssertionError("link id out of range")
    # endpoint checks
    s_idx, d_idx = np.nonzero(off)
    first = paths[s_idx, d_idx, 0]
    last = paths[s_idx, d_idx, hops[s_idx, d_idx] - 1]
    if not (topo.link_src[first] == -(s_idx + 1)).all():
        bad = int(np.argmax(topo.link_src[first] != -(s_idx + 1)))
        raise AssertionError(
            f"path {s_idx[bad]}->{d_idx[bad]} does not start at its "
            f"source host")
    if not (topo.link_dst[last] == -(d_idx + 1)).all():
        bad = int(np.argmax(topo.link_dst[last] != -(d_idx + 1)))
        raise AssertionError(
            f"path {s_idx[bad]}->{d_idx[bad]} does not sink at its "
            f"destination host")
    # consecutive links share a switch
    a, b = paths[..., :-1], paths[..., 1:]
    both = (a != PAD) & (b != PAD)
    sink = topo.link_dst[np.where(both, a, 0)]
    srcn = topo.link_src[np.where(both, b, 0)]
    ok = ~both | ((sink == srcn) & (sink >= 0))
    if not ok.all():
        s, d, j = (int(x[0]) for x in np.nonzero(~ok))
        raise AssertionError(
            f"path {s}->{d}: hop {j} sinks at {topo.link_dst[paths[s,d,j]]}"
            f" but hop {j+1} departs {topo.link_src[paths[s,d,j+1]]}")


def stage_balance(load: np.ndarray, ids: np.ndarray) -> tuple[int, int]:
    """(min, max) flow load over one stage's link ids."""
    sel = load[ids]
    return int(sel.min()), int(sel.max())
