"""Table-driven routing: precomputed per-(src,dst) padded link-id paths.

The general routing path of the repo: every fabric family provides a
*table builder* that emits a ``RouteTable`` — a dense
``[N, N, H_MAX]`` int32 array of directed-link ids (PAD = -1, trailing)
with per-pair hop counts — and every scenario then routes by table
lookup (``routes_for_pairs``).  ``H_MAX`` varies by fabric (2h for an
h-level XGFT, 5 for a dragonfly), replacing the CLOS-only hardwired
``H_MAX = 6``; the fluid model is shape-polymorphic in hops, and mixed
fabrics pad to a common H when stacked into one Sweep.

The closed-form CLOS D-mod-K of ``repro.core.routing`` survives as one
table builder among several (``clos_route_table``) — same link ids,
same wirings (``roll``), just materialised once per fabric instead of
recomputed per flow.

``validate_table`` is the vectorised validity checker every builder is
held to: paths start at the source host, end at the destination host,
consecutive links share a switch, and padding is trailing-only.

Multi-path routing generalises the table to a ``RouteSet`` — K
candidate paths per pair ([N, N, K, H_MAX]): slot 0 is the minimal
path, slots 1..K-1 are Valiant/VLB detours (random spine for CLOS,
random root for XGFT, random intermediate group for dragonfly).  The
fluid loop selects among candidates at run time (``min`` pins slot 0,
``valiant`` pins a sampled detour, ``ugal`` compares queue-weighted
hop costs — see ``repro.core.fluid``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.routing import PAD, assign_vc, clos_route, link_incidence
from repro.core.topology import ClosIndex, Topology

from .topologies import DragonflyIndex, XGFTIndex


def _pair_index(pairs, n_nodes: int) -> np.ndarray:
    """Validate (src, dst) pairs into an [F, 2] host-id index."""
    idx = np.asarray(pairs, np.int64)
    if idx.ndim != 2 or idx.shape[1] != 2:
        raise ValueError(f"pairs must be [F, 2], got {idx.shape}")
    if (idx < 0).any() or (idx >= n_nodes).any():
        raise ValueError(
            f"pair endpoints must be host ids in [0, {n_nodes})")
    return idx


@dataclasses.dataclass(frozen=True)
class RouteTable:
    """Dense per-(src,dst) padded link-id paths for one fabric.

    ``paths[s, d, :hops[s, d]]`` are real link ids; the rest is PAD.
    ``paths[s, s]`` is all-PAD (no self-traffic).
    """

    paths: np.ndarray             # [N, N, H_MAX] int32, PAD-padded
    hops: np.ndarray              # [N, N] int32

    @property
    def n_nodes(self) -> int:
        return self.paths.shape[0]

    @property
    def h_max(self) -> int:
        return self.paths.shape[2]

    def routes_for_pairs(self, pairs) -> np.ndarray:
        """[F, H_MAX] int32 route matrix for (src, dst) pairs."""
        if not len(pairs):
            return np.empty((0, self.h_max), np.int32)
        idx = _pair_index(pairs, self.n_nodes)
        return self.paths[idx[:, 0], idx[:, 1]].copy()

    def hops_for_pairs(self, pairs) -> np.ndarray:
        """[F] int32 hop counts for (src, dst) pairs."""
        if not len(pairs):
            return np.empty((0,), np.int32)
        idx = _pair_index(pairs, self.n_nodes)
        return self.hops[idx[:, 0], idx[:, 1]].copy()

    def link_load(self, n_links: int,
                  pairs=None) -> np.ndarray:
        """Flow-routes crossing each link (all-to-all, or given pairs).

        Real hops are selected by each path's hop *count*, not by
        scanning for the PAD sentinel: tables whose paths have unequal
        lengths may legally carry anything (stale ids, scratch slots)
        beyond ``hops[s, d]``, and counting those slots silently
        inflated the load of whichever link id the padding aliased.
        """
        if pairs is None:
            routes = self.paths.reshape(-1, self.h_max)
            hops = self.hops.reshape(-1)
        else:
            routes = self.routes_for_pairs(pairs)
            hops = self.hops_for_pairs(pairs)
        mask = np.arange(self.h_max)[None, :] < hops[:, None]
        ids = routes[mask]
        return np.bincount(ids, minlength=n_links).astype(np.int64)

    def incidence(self, n_links: int, pairs=None):
        """Link-sorted (flow, hop) incidence of this table's routes.

        ``(perm, seg, offsets)`` per ``repro.core.routing
        .link_incidence``.  For a single-path scenario built from the
        same ``pairs`` this is exactly the ``ScenarioDev.red_perm`` /
        ``red_seg`` / ``red_off`` layout the fluid loop's fused
        reductions tile by (cross-checked in tests/test_fluid_fused) —
        the host-side view for inspecting load skew (``offsets`` row
        lengths size the dense-CSR engine) without building a scenario.
        """
        routes = (self.paths.reshape(-1, self.h_max) if pairs is None
                  else self.routes_for_pairs(pairs))
        return link_incidence(routes[:, None, :], n_links)


def _from_path_fn(n: int, h_max: int, path_fn) -> RouteTable:
    """Materialise ``path_fn(s, d) -> list[int]`` into a RouteTable."""
    paths = np.full((n, n, h_max), PAD, np.int32)
    hops = np.zeros((n, n), np.int32)
    for s in range(n):
        for d in range(n):
            if s == d:
                continue
            p = path_fn(s, d)
            if len(p) > h_max:
                raise ValueError(
                    f"path {s}->{d} has {len(p)} hops > H_MAX={h_max}")
            paths[s, d, : len(p)] = p
            hops[s, d] = len(p)
    return RouteTable(paths=paths, hops=hops)


@dataclasses.dataclass(frozen=True)
class RouteSet:
    """Multi-path routes: K candidate paths per (src, dst) pair.

    ``paths[s, d, k, :hops[s, d, k]]`` are real link ids; slot ``k = 0``
    is always the fabric's minimal (deterministic) path, slots
    ``1..K-1`` are the Valiant/VLB detour candidates.  Every slot of an
    ``s != d`` pair holds a *valid* path — builders that cannot detour
    a pair (e.g. same-leaf XGFT) fall back to the minimal path for that
    slot, so selection logic never has to special-case missing
    candidates.  A ``RouteTable`` is the ``K = 1`` degenerate case
    (``minimal`` recovers it; ``slot(k)`` views any candidate layer).
    """

    paths: np.ndarray             # [N, N, K, H_MAX] int32, PAD-padded
    hops: np.ndarray              # [N, N, K] int32

    @property
    def n_nodes(self) -> int:
        return self.paths.shape[0]

    @property
    def k_paths(self) -> int:
        return self.paths.shape[2]

    @property
    def h_max(self) -> int:
        return self.paths.shape[3]

    def slot(self, k: int) -> RouteTable:
        """Candidate layer ``k`` as a single-path RouteTable view."""
        return RouteTable(paths=self.paths[:, :, k], hops=self.hops[:, :, k])

    @property
    def minimal(self) -> RouteTable:
        return self.slot(0)

    def routes_for_pairs(self, pairs) -> np.ndarray:
        """[F, K, H_MAX] int32 candidate routes for (src, dst) pairs."""
        if not len(pairs):
            return np.empty((0, self.k_paths, self.h_max), np.int32)
        idx = _pair_index(pairs, self.n_nodes)
        return self.paths[idx[:, 0], idx[:, 1]].copy()

    def hops_for_pairs(self, pairs) -> np.ndarray:
        """[F, K] int32 per-candidate hop counts."""
        if not len(pairs):
            return np.empty((0, self.k_paths), np.int32)
        idx = _pair_index(pairs, self.n_nodes)
        return self.hops[idx[:, 0], idx[:, 1]].copy()

    def vc_for_pairs(self, pairs, n_vcs: int,
                     mode: str = "slot") -> np.ndarray:
        """[F, K, H_MAX] int32 static VC per candidate hop.

        The per-VC fluid model (``LinkParams.n_vcs > 1``) splits every
        wire's input buffer into independent queues; this is where the
        route set decides which queue each candidate path rides.
        ``mode="slot"`` (default) keeps minimal traffic on VC 0 and
        puts Valiant/UGAL detours on VC 1 — detoured flows stop
        sharing hop queues (and pause state) with minimal flows;
        ``mode="hop"`` escalates the VC along the path (dateline-style
        credit-loop avoidance for dragonfly cycles).  See
        ``repro.core.routing.assign_vc`` for the exact rule.
        """
        return assign_vc(self.routes_for_pairs(pairs), n_vcs, mode=mode)

    def link_load(self, n_links: int, pairs=None,
                  k: int | None = None) -> np.ndarray:
        """Flow-routes crossing each link; ``k`` selects one candidate
        layer (None sums all K layers, hop-count-masked)."""
        if k is not None:
            return self.slot(k).link_load(n_links, pairs=pairs)
        return sum(self.slot(j).link_load(n_links, pairs=pairs)
                   for j in range(self.k_paths))

    def incidence(self, n_links: int, pairs=None):
        """Link-sorted (flow, slot, hop) incidence over ALL K candidate
        layers — for a ``pairs`` scenario with ``n_paths == K`` this is
        exactly the [F*K*H] ``ScenarioDev.red_*`` layout the fluid loop
        reduces at run time (unselected slots contribute exact zeros).
        See ``RouteTable.incidence``.
        """
        routes = (self.paths.reshape(-1, self.k_paths, self.h_max)
                  if pairs is None else self.routes_for_pairs(pairs))
        return link_incidence(routes, n_links)


def _rng_for(seed: int, s: int, d: int, k: int) -> np.random.RandomState:
    """Independent, order-free stream per (seed, src, dst, slot)."""
    return np.random.RandomState(
        np.array([seed & 0x7FFFFFFF, s, d, k], np.uint32))


def _route_set_from_fns(n: int, h_max: int, k: int, seed: int,
                        min_fn, alt_fn) -> RouteSet:
    """Assemble a RouteSet: slot 0 = ``min_fn(s, d)``; slots 1..k-1 =
    ``alt_fn(s, d, rng)`` with a deterministic per-(s, d, slot) rng."""
    if k < 1:
        raise ValueError(f"need k >= 1 candidate paths, got {k}")
    paths = np.full((n, n, k, h_max), PAD, np.int32)
    hops = np.zeros((n, n, k), np.int32)
    for s in range(n):
        for d in range(n):
            if s == d:
                continue
            for j in range(k):
                p = min_fn(s, d) if j == 0 else \
                    alt_fn(s, d, _rng_for(seed, s, d, j))
                if len(p) > h_max:
                    raise ValueError(
                        f"path {s}->{d} slot {j} has {len(p)} hops "
                        f"> H_MAX={h_max}")
                paths[s, d, j, : len(p)] = p
                hops[s, d, j] = len(p)
    return RouteSet(paths=paths, hops=hops)


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------


def clos_route_table(arity: int = 4, roll: int = 0) -> RouteTable:
    """The 3-stage CLOS closed form, materialised as a table (H_MAX=6)."""
    idx = ClosIndex(arity)
    n = arity ** 3
    return _from_path_fn(n, 6, lambda s, d: clos_route(idx, s, d, roll=roll))


def xgft_path(idx: XGFTIndex, s: int, d: int, roll: int = 0) -> list[int]:
    """Deterministic D-mod-K up-down path in XGFT(h; m; w).

    Ascends to the lowest common ancestor level L (highest host digit
    where s and d differ); the up-link slot at each level j is a
    destination digit — ``(d // W[(j-1+roll) % h]) % w_j`` with
    ``W[k] = prod(w[:k])`` — so all-to-all traffic spreads evenly over
    every up stage; the down path is forced by d's digits.
    """
    if s == d:
        return []
    h, m, w = idx.h, idx.m, idx.w
    sx, dx = idx.host_digits(s), idx.host_digits(d)
    L = max(j for j in range(1, h + 1) if sx[j - 1] != dx[j - 1])
    W = [1]
    for j in range(1, h):
        W.append(W[-1] * w[j - 1])
    path = []
    y = [0] * h
    cur = s                                     # level-0 index = host id
    for j in range(1, L + 1):                   # ascend, choosing y_j
        y[j - 1] = (d // W[(j - 1 + roll) % h]) % w[j - 1]
        path.append(idx.up(j, cur, y[j - 1]))
        cur = idx.node_index(j, sx, y)
    for j in range(L, 0, -1):                   # descend along d's digits
        path.append(idx.dn(j, cur, dx[j - 1]))
        # the level-(j-1) child has d's x-digits at every position >= j
        # (above L they equal s's) and the ascent's y-digits below j
        cur = idx.node_index(j - 1, dx, y)
    return path


def xgft_route_table(idx: XGFTIndex, roll: int = 0) -> RouteTable:
    """D-mod-K table for an XGFT; H_MAX = 2 * levels."""
    return _from_path_fn(idx.n_hosts, 2 * idx.h,
                         lambda s, d: xgft_path(idx, s, d, roll=roll))


def dragonfly_path(idx: DragonflyIndex, s: int, d: int) -> list[int]:
    """Minimal dragonfly route: local -> global -> local (<= 5 links)."""
    if s == d:
        return []
    a, p = idx.a, idx.p
    rs, rd = (s // p) % a, (d // p) % a
    gs, gd = s // (a * p), d // (a * p)
    up, dn = s, idx.n_hosts + d
    if gs == gd:
        if rs == rd:
            return [up, dn]
        return [up, idx.local(gs, rs, rd), dn]
    path = [up]
    gw = idx.gl_owner(gs, gd)                   # gateway router in gs
    if rs != gw:
        path.append(idx.local(gs, rs, gw))
    path.append(idx.gl_port(gs, gd))
    rin = idx.gl_owner(gd, gs)                  # arrival router in gd
    if rin != rd:
        path.append(idx.local(gd, rin, rd))
    path.append(dn)
    return path


def dragonfly_route_table(idx: DragonflyIndex) -> RouteTable:
    """Minimal-route table for a dragonfly; H_MAX = 5."""
    return _from_path_fn(idx.n_hosts, 5,
                         lambda s, d: dragonfly_path(idx, s, d))


# ---------------------------------------------------------------------------
# Valiant (VLB) detour candidates + multi-path route sets
# ---------------------------------------------------------------------------


def clos_valiant_path(idx: ClosIndex, s: int, d: int,
                      rng: np.random.RandomState) -> list[int]:
    """Randomised up-route through the 3-stage CLOS.

    The CLOS is single-length up-down, so "Valiant" degenerates to a
    random spine (random digit selectors u0, u1 instead of D-mod-K):
    same hop count, different — congestion-decorrelated — middle links.
    Same-leaf pairs have a forced path and fall back to it.
    """
    a = idx.arity
    if s == d:
        return []
    s_leaf, d_leaf = s // a, d // a
    s_grp, d_grp = s_leaf // a, d_leaf // a
    path = [idx.nic_up(s)]
    if d_leaf == s_leaf:                        # forced: no detour exists
        path.append(idx.leaf_dn(d))
        return path
    u0 = int(rng.randint(a))
    path.append(idx.leaf_up(s_leaf, u0))
    if d_grp == s_grp:
        path.append(idx.agg_dn(s_grp, u0, d_leaf % a))
        path.append(idx.leaf_dn(d))
        return path
    u1 = int(rng.randint(a))
    path.append(idx.agg_up(s_grp, u0, u1))
    path.append(idx.spine_dn(u0 * a + u1, d_grp))
    path.append(idx.agg_dn(d_grp, u0, d_leaf % a))
    path.append(idx.leaf_dn(d))
    return path


def xgft_valiant_path(idx: XGFTIndex, s: int, d: int,
                      rng: np.random.RandomState) -> list[int]:
    """VLB detour in XGFT(h; m; w): ascend all the way to a *random*
    root (uniform parent slot at every level), then descend along d's
    digits — the fat-tree form of "route to a random intermediate",
    since the root choice fixes the intermediate subtree.  Always 2h
    links (non-minimal whenever the true LCA is below the roots)."""
    if s == d:
        return []
    h = idx.h
    sx, dx = idx.host_digits(s), idx.host_digits(d)
    path = []
    y = [0] * h
    cur = s
    for j in range(1, h + 1):                   # ascend with random slots
        y[j - 1] = int(rng.randint(idx.w[j - 1]))
        path.append(idx.up(j, cur, y[j - 1]))
        cur = idx.node_index(j, sx, y)
    for j in range(h, 0, -1):                   # descend along d's digits
        path.append(idx.dn(j, cur, dx[j - 1]))
        cur = idx.node_index(j - 1, dx, y)
    return path


def dragonfly_valiant_path(idx: DragonflyIndex, s: int, d: int,
                           rng: np.random.RandomState) -> list[int]:
    """VLB detour in a dragonfly: route minimally to a random
    *intermediate group* (neither source nor destination group), then
    minimally on to the destination — two global hops, <= 7 links.
    Intra-group pairs detour via a random intermediate router instead;
    pairs with no possible detour fall back to the minimal path.
    """
    if s == d:
        return []
    a, p = idx.a, idx.p
    rs, rd = (s // p) % a, (d // p) % a
    gs, gd = s // (a * p), d // (a * p)
    up, dn = s, idx.n_hosts + d
    if gs == gd:                                # in-group router detour
        cand = [r for r in range(a) if r not in (rs, rd)]
        if not cand:
            return dragonfly_path(idx, s, d)
        ri = cand[int(rng.randint(len(cand)))]
        return [up, idx.local(gs, rs, ri), idx.local(gs, ri, rd), dn]
    cand = [g for g in range(idx.g) if g not in (gs, gd)]
    if not cand:
        return dragonfly_path(idx, s, d)
    gi = cand[int(rng.randint(len(cand)))]
    path = [up]
    gw = idx.gl_owner(gs, gi)                   # leg 1: gs -> gi
    if rs != gw:
        path.append(idx.local(gs, rs, gw))
    path.append(idx.gl_port(gs, gi))
    rin = idx.gl_owner(gi, gs)
    gw2 = idx.gl_owner(gi, gd)                  # leg 2: gi -> gd
    if rin != gw2:
        path.append(idx.local(gi, rin, gw2))
    path.append(idx.gl_port(gi, gd))
    rin2 = idx.gl_owner(gd, gi)
    if rin2 != rd:
        path.append(idx.local(gd, rin2, rd))
    path.append(dn)
    return path


DFLY_VLB_H_MAX = 7        # up + local + global + local + global + local + dn


def clos_route_set(arity: int = 4, k: int = 4, seed: int = 0,
                   roll: int = 0) -> RouteSet:
    """Minimal D-mod-K + k-1 random-spine candidates; H_MAX = 6."""
    idx = ClosIndex(arity)
    return _route_set_from_fns(
        arity ** 3, 6, k, seed,
        lambda s, d: clos_route(idx, s, d, roll=roll),
        lambda s, d, rng: clos_valiant_path(idx, s, d, rng))


def xgft_route_set(idx: XGFTIndex, k: int = 4, seed: int = 0,
                   roll: int = 0) -> RouteSet:
    """Minimal D-mod-K + k-1 random-root VLB candidates; H_MAX = 2h."""
    return _route_set_from_fns(
        idx.n_hosts, 2 * idx.h, k, seed,
        lambda s, d: xgft_path(idx, s, d, roll=roll),
        lambda s, d, rng: xgft_valiant_path(idx, s, d, rng))


def dragonfly_route_set(idx: DragonflyIndex, k: int = 4,
                        seed: int = 0) -> RouteSet:
    """Minimal + k-1 intermediate-group VLB candidates; H_MAX = 7
    (the VLB worst case) once any detour slot exists, else 5."""
    h_max = DFLY_VLB_H_MAX if k > 1 else 5
    return _route_set_from_fns(
        idx.n_hosts, h_max, k, seed,
        lambda s, d: dragonfly_path(idx, s, d),
        lambda s, d, rng: dragonfly_valiant_path(idx, s, d, rng))


# ---------------------------------------------------------------------------
# validity checking
# ---------------------------------------------------------------------------


def validate_table(topo: Topology, table: RouteTable) -> None:
    """Structural validity of a full route table (vectorised).

    Raises AssertionError unless, for every (s, d) pair with s != d:
    the first link leaves host s, the last link delivers to host d,
    consecutive links share a switch (sink(h) == source(h+1)), all
    link ids are in range, and padding is trailing-only.
    """
    n, h = table.n_nodes, table.h_max
    paths, hops = table.paths, table.hops
    if topo.n_nodes != n:
        raise AssertionError(
            f"table is for {n} hosts, topology has {topo.n_nodes}")
    valid = paths != PAD
    # trailing-only padding, and hops consistent with the mask
    want = np.arange(h)[None, None, :] < hops[..., None]
    if not (valid == want).all():
        raise AssertionError("non-trailing PAD or hops/path mismatch")
    off = ~np.eye(n, dtype=bool)
    if not (hops[off] >= 2).all() or (hops.diagonal() != 0).any():
        raise AssertionError("every s != d path needs >= 2 links "
                             "(host up + host down); s == s must be empty")
    ids = paths[valid]
    if ids.size and (ids.min() < 0 or ids.max() >= topo.n_links):
        raise AssertionError("link id out of range")
    # endpoint checks
    s_idx, d_idx = np.nonzero(off)
    first = paths[s_idx, d_idx, 0]
    last = paths[s_idx, d_idx, hops[s_idx, d_idx] - 1]
    if not (topo.link_src[first] == -(s_idx + 1)).all():
        bad = int(np.argmax(topo.link_src[first] != -(s_idx + 1)))
        raise AssertionError(
            f"path {s_idx[bad]}->{d_idx[bad]} does not start at its "
            f"source host")
    if not (topo.link_dst[last] == -(d_idx + 1)).all():
        bad = int(np.argmax(topo.link_dst[last] != -(d_idx + 1)))
        raise AssertionError(
            f"path {s_idx[bad]}->{d_idx[bad]} does not sink at its "
            f"destination host")
    # consecutive links share a switch
    a, b = paths[..., :-1], paths[..., 1:]
    both = (a != PAD) & (b != PAD)
    sink = topo.link_dst[np.where(both, a, 0)]
    srcn = topo.link_src[np.where(both, b, 0)]
    ok = ~both | ((sink == srcn) & (sink >= 0))
    if not ok.all():
        s, d, j = (int(x[0]) for x in np.nonzero(~ok))
        raise AssertionError(
            f"path {s}->{d}: hop {j} sinks at {topo.link_dst[paths[s,d,j]]}"
            f" but hop {j+1} departs {topo.link_src[paths[s,d,j+1]]}")


def validate_route_set(topo: Topology, rset: RouteSet) -> None:
    """Every candidate layer of a RouteSet passes ``validate_table``.

    Builders guarantee each slot of an ``s != d`` pair holds a complete
    valid path (detour or minimal fallback), so the single-table checker
    applies verbatim per layer.
    """
    for k in range(rset.k_paths):
        try:
            validate_table(topo, rset.slot(k))
        except AssertionError as e:
            raise AssertionError(f"candidate layer {k}: {e}") from e


def stage_balance(load: np.ndarray, ids: np.ndarray) -> tuple[int, int]:
    """(min, max) flow load over one stage's link ids."""
    sel = load[ids]
    return int(sel.min()), int(sel.max())
