"""Topology zoo: parametric XGFT / fat-tree and dragonfly fabrics.

Every builder emits the generic directed-link ``Topology`` of
``repro.core.topology`` (one queue per directed link at its sink end),
so any fabric drops straight into the fluid model.  Alongside the
``Topology`` each builder returns an *index* object that knows the
fabric's link-id layout — the routing-table builders in
``repro.net.routing`` consume it, and tests use it to reason about
stages (e.g. per-stage load balance).

XGFT(h; m_1..m_h; w_1..w_h)  (Ohring et al.'s extended generalised fat
tree): level 0 holds the ``prod(m)`` hosts, level ``l`` holds
``prod(m[l:]) * prod(w[:l])`` switches.  A level-(l-1) node has ``w_l``
parents and a level-l node ``m_l`` children, so oversubscription
(tapering) is expressed structurally: ``w_{l+1} < m_l`` gives an
``m_l : w_{l+1}`` taper at level l.  The paper's 64-node CLOS is
XGFT(3; 4,4,4; 1,4,4).

Dragonfly(a, p, h): ``g`` groups of ``a`` routers; each router has
``p`` hosts and ``h`` global ports; routers within a group are fully
connected; each ordered group pair is joined by exactly one global
channel (canonical ``g = a*h + 1`` sizing, smaller ``g`` allowed).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.topology import Topology


def _node_enc(n: int) -> int:
    return -(n + 1)


# ---------------------------------------------------------------------------
# XGFT / fat-tree
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class XGFTIndex:
    """Link-id layout + digit arithmetic for XGFT(h; m; w).

    Link ids: up-links level 1..h first (host->leaf is up level 1),
    then down-links level h..1 (leaf->host is down level 1).  Within a
    level, ``up(l, c, y) = up_base[l] + c * w[l-1] + y`` for level-(l-1)
    node index ``c`` and parent slot ``y`` (and symmetrically for down).
    """

    m: tuple[int, ...]            # down-arities, level 1..h
    w: tuple[int, ...]            # parent multiplicities, level 1..h

    @property
    def h(self) -> int:
        return len(self.m)

    def n_level(self, l: int) -> int:
        """Nodes at level l (0 = hosts)."""
        return math.prod(self.m[l:]) * math.prod(self.w[:l])

    @property
    def n_hosts(self) -> int:
        return self.n_level(0)

    @property
    def n_switches(self) -> int:
        return sum(self.n_level(l) for l in range(1, self.h + 1))

    def switch_id(self, l: int, idx: int) -> int:
        """Global switch id of level-l node ``idx`` (levels stack 1..h)."""
        return sum(self.n_level(j) for j in range(1, l)) + idx

    def up_base(self, l: int) -> int:
        return sum(self.n_level(j - 1) * self.w[j - 1] for j in range(1, l))

    @property
    def dn_base0(self) -> int:
        return self.up_base(self.h + 1)

    def dn_base(self, l: int) -> int:
        """Down-links are laid out level h..1 after all up-links."""
        return self.dn_base0 + sum(
            self.n_level(j) * self.m[j - 1] for j in range(l + 1, self.h + 1))

    def up(self, l: int, child_idx: int, slot: int) -> int:
        return self.up_base(l) + child_idx * self.w[l - 1] + slot

    def dn(self, l: int, parent_idx: int, slot: int) -> int:
        return self.dn_base(l) + parent_idx * self.m[l - 1] + slot

    @property
    def n_links(self) -> int:
        return self.dn_base(1) + self.n_level(1) * self.m[0]

    def up_stage_ids(self, l: int) -> np.ndarray:
        """All up-link ids of level l (for balance diagnostics)."""
        return np.arange(self.up_base(l),
                         self.up_base(l) + self.n_level(l - 1) * self.w[l - 1])

    # -- digit arithmetic ---------------------------------------------------

    def host_digits(self, n: int) -> list[int]:
        """Host id -> [x_1 .. x_h] (x_1 least significant)."""
        out = []
        for ml in self.m:
            out.append(n % ml)
            n //= ml
        return out

    def node_index(self, l: int, x: list[int], y: list[int]) -> int:
        """Level-l node index from digits x_{l+1}..x_h and y_1..y_l.

        ``x`` is the full host digit list (entries <= l ignored); ``y``
        holds the chosen parent slots y_1..y_l (y[j-1] = y_j).
        """
        v = 0
        for j in range(self.h, l, -1):          # x_h .. x_{l+1}
            v = v * self.m[j - 1] + x[j - 1]
        for j in range(l, 0, -1):               # y_l .. y_1
            v = v * self.w[j - 1] + y[j - 1]
        return v


def make_xgft(m: tuple[int, ...], w: tuple[int, ...],
              line_rate: float = 12.5e9,
              name: str | None = None) -> tuple[Topology, XGFTIndex]:
    """XGFT(h; m; w) as a generic directed-link Topology (+ its index).

    ``m[l-1]`` children / ``w[l-1]`` parents per node at each level;
    ``len(m) == len(w)`` levels of switches above the hosts.
    """
    m, w = tuple(int(v) for v in m), tuple(int(v) for v in w)
    if len(m) != len(w) or not m:
        raise ValueError(f"m and w must be equal non-zero length, got "
                         f"{m} / {w}")
    if any(v < 1 for v in m + w):
        raise ValueError(f"arities must be >= 1: m={m} w={w}")
    idx = XGFTIndex(m, w)
    h = idx.h
    L = idx.n_links
    src = np.empty((L,), np.int32)
    dst = np.empty((L,), np.int32)

    def node_ref(l: int, i: int) -> int:
        return _node_enc(i) if l == 0 else idx.switch_id(l, i)

    # enumerate each level's nodes by digits once; connect up and down.
    for l in range(1, h + 1):
        # children at level l-1: digits x_{l}..x_h + y_1..y_{l-1}
        for c in range(idx.n_level(l - 1)):
            # decode child index -> digits (mixed radix, MSB first:
            # x_h..x_l then y_{l-1}..y_1)
            rem = c
            y = [0] * h
            x = [0] * h
            for j in range(1, l):               # y_1 .. y_{l-1} (LSB first)
                y[j - 1] = rem % w[j - 1]
                rem //= w[j - 1]
            for j in range(l, h + 1):           # x_l .. x_h
                x[j - 1] = rem % m[j - 1]
                rem //= m[j - 1]
            for slot in range(w[l - 1]):        # parent slot y_l
                y[l - 1] = slot
                p = idx.node_index(l, x, y)
                lid = idx.up(l, c, slot)
                src[lid] = node_ref(l - 1, c)
                dst[lid] = idx.switch_id(l, p)
                did = idx.dn(l, p, x[l - 1])    # the mirror down-link
                src[did] = idx.switch_id(l, p)
                dst[did] = node_ref(l - 1, c)
    cap = np.full((L,), float(line_rate), np.float64)
    topo = Topology(
        n_nodes=idx.n_hosts, n_switches=idx.n_switches, n_links=L,
        link_src=src, link_dst=dst, link_capacity=cap,
        name=name or f"xgft{m}x{w}")
    return topo, idx


def fat_tree_mw(arity: int, taper: int = 1, levels: int = 3
                ) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """(m, w) of the k-ary fat tree with a leaf-stage taper — the one
    definition shared by ``make_fat_tree`` and ``FabricSpec.fat_tree``."""
    if arity % taper:
        raise ValueError(f"taper {taper} must divide arity {arity}")
    m = (arity,) * levels
    w = ((1, arity // taper) + (arity,) * (levels - 2))[:levels]
    return m, w


def make_fat_tree(arity: int = 4, taper: int = 1, levels: int = 3,
                  line_rate: float = 12.5e9) -> tuple[Topology, XGFTIndex]:
    """k-ary fat tree with an optional leaf-stage taper.

    ``taper=1`` is the full-bisection XGFT(levels; a..a; 1,a..a);
    ``taper=2`` halves the leaf uplinks (2:1 oversubscription), etc.
    """
    m, w = fat_tree_mw(arity, taper, levels)
    return make_xgft(m, w, line_rate=line_rate,
                     name=f"ft{arity}^{levels}"
                          + (f"_{taper}to1" if taper > 1 else ""))


# ---------------------------------------------------------------------------
# dragonfly
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DragonflyIndex:
    """Link-id layout for dragonfly(a, p, h) with ``g`` groups.

    Layout: host-up [0, N), host-dn [N, 2N), then per-group local links
    (a*(a-1) ordered router pairs each), then per-group global ports
    (only ports whose peer group exists are materialised; ``gl_port``
    maps (group, peer group) -> link id).
    """

    a: int                        # routers per group
    p: int                        # hosts per router
    h: int                        # global ports per router
    g: int                        # groups

    @property
    def n_hosts(self) -> int:
        return self.g * self.a * self.p

    @property
    def n_switches(self) -> int:
        return self.g * self.a

    def router(self, grp: int, r: int) -> int:
        return grp * self.a + r

    @property
    def local_base(self) -> int:
        return 2 * self.n_hosts

    def local(self, grp: int, r1: int, r2: int) -> int:
        """Directed local link router r1 -> r2 inside ``grp``."""
        slot = r2 - 1 if r2 > r1 else r2
        return (self.local_base + grp * self.a * (self.a - 1)
                + r1 * (self.a - 1) + slot)

    @property
    def global_base(self) -> int:
        return self.local_base + self.g * self.a * (self.a - 1)

    def peer_group(self, grp: int, port: int) -> int:
        """Group reached by global port ``port`` of ``grp`` (may be >= g
        for truncated fabrics — such ports are not materialised)."""
        return port if port < grp else port + 1

    def port_to(self, grp: int, dst_grp: int) -> int:
        """The global port of ``grp`` that reaches ``dst_grp``."""
        return dst_grp if dst_grp < grp else dst_grp - 1

    def gl_owner(self, grp: int, dst_grp: int) -> int:
        """Router of ``grp`` owning the global channel to ``dst_grp``."""
        return self.port_to(grp, dst_grp) // self.h

    def gl_port(self, grp: int, dst_grp: int) -> int:
        """Link id of the global channel ``grp`` -> ``dst_grp``.

        Ports are materialised in (group, port) order, skipping ports
        whose peer group does not exist; with canonical ``g = a*h + 1``
        every port exists and the layout is dense.
        """
        ports_per_group = min(self.g - 1, self.a * self.h)
        return (self.global_base + grp * ports_per_group
                + self.port_to(grp, dst_grp))

    @property
    def n_links(self) -> int:
        ports_per_group = min(self.g - 1, self.a * self.h)
        return self.global_base + self.g * ports_per_group

    def global_ids(self) -> np.ndarray:
        return np.arange(self.global_base, self.n_links)

    def local_ids(self) -> np.ndarray:
        return np.arange(self.local_base, self.global_base)

    # -- reverse lookups (tests + adaptive-routing diagnostics) -------------

    def host_group(self, n: int) -> int:
        return n // (self.a * self.p)

    def host_router(self, n: int) -> int:
        return self.router(self.host_group(n), (n // self.p) % self.a)

    def is_global(self, lid: int) -> bool:
        return self.global_base <= lid < self.n_links

    def global_endpoints(self, lid: int) -> tuple[int, int]:
        """(src group, dst group) of a global channel's link id."""
        if not self.is_global(lid):
            raise ValueError(f"link {lid} is not a global channel")
        ports_per_group = min(self.g - 1, self.a * self.h)
        off = lid - self.global_base
        grp, port = off // ports_per_group, off % ports_per_group
        return grp, self.peer_group(grp, port)

    def groups_visited(self, path: list[int]) -> list[int]:
        """Ordered group sequence a link-id path passes through
        (consecutive duplicates collapsed)."""
        out: list[int] = []
        for lid in path:
            if lid < self.local_base:            # host up/down link
                n = lid if lid < self.n_hosts else lid - self.n_hosts
                grps = [self.host_group(n)]
            elif lid < self.global_base:         # local link
                grps = [(lid - self.local_base) // (self.a * (self.a - 1))]
            else:                                # global channel
                grps = list(self.global_endpoints(lid))
            for grp in grps:
                if not out or out[-1] != grp:
                    out.append(grp)
        return out


def make_dragonfly(a: int = 4, p: int = 2, h: int = 2,
                   groups: int | None = None,
                   line_rate: float = 12.5e9,
                   name: str | None = None
                   ) -> tuple[Topology, DragonflyIndex]:
    """Dragonfly(a, p, h): ``groups`` defaults to the canonical a*h+1."""
    g = a * h + 1 if groups is None else int(groups)
    if not 2 <= g <= a * h + 1:
        raise ValueError(f"groups must be in [2, a*h+1={a*h+1}], got {g}")
    idx = DragonflyIndex(a=a, p=p, h=h, g=g)
    N, L = idx.n_hosts, idx.n_links
    src = np.empty((L,), np.int32)
    dst = np.empty((L,), np.int32)
    for n in range(N):                           # host up / down
        r = idx.router(n // (a * p), (n // p) % a)
        src[n], dst[n] = _node_enc(n), r
        src[N + n], dst[N + n] = r, _node_enc(n)
    for grp in range(g):                         # local full mesh
        for r1 in range(a):
            for r2 in range(a):
                if r1 == r2:
                    continue
                lid = idx.local(grp, r1, r2)
                src[lid] = idx.router(grp, r1)
                dst[lid] = idx.router(grp, r2)
    for grp in range(g):                         # global channels
        for dg in range(g):
            if dg == grp:
                continue
            lid = idx.gl_port(grp, dg)
            src[lid] = idx.router(grp, idx.gl_owner(grp, dg))
            dst[lid] = idx.router(dg, idx.gl_owner(dg, grp))
    cap = np.full((L,), float(line_rate), np.float64)
    topo = Topology(
        n_nodes=N, n_switches=idx.n_switches, n_links=L,
        link_src=src, link_dst=dst, link_capacity=cap,
        name=name or f"dfly_a{a}p{p}h{h}g{g}")
    return topo, idx
