"""repro.net — topology zoo + table-driven routing for the CC model.

The scenario-generation subsystem: parametric fabrics (XGFT/fat-tree
with tapering, dragonfly) emitting the generic directed-link
``Topology``, and per-(src,dst) precomputed route tables with a
validity checker.  Combine with ``repro.core.workloads`` and feed the
result to ``repro.core.experiments.Sweep`` for one-jit batched
(fabric x workload x scheme) evaluation.

    from repro.net import FabricSpec
    from repro.core import ScenarioSpec, Sweep

    fab = FabricSpec.dragonfly(a=4, p=2, h=2)     # 72 hosts, 9 groups
    spec = ScenarioSpec.incast(8, dst=0, fabric=fab)
    Sweep.grid(configs={...}, scenarios={"dfly": spec}).run()
"""

from .fabric import FabricSpec
from .routing import (RouteSet, RouteTable, clos_route_set,
                      clos_route_table, clos_valiant_path,
                      dragonfly_path, dragonfly_route_set,
                      dragonfly_route_table, dragonfly_valiant_path,
                      stage_balance, validate_route_set, validate_table,
                      xgft_path, xgft_route_set, xgft_route_table,
                      xgft_valiant_path)
from .topologies import (DragonflyIndex, XGFTIndex, make_dragonfly,
                         make_fat_tree, make_xgft)

__all__ = [
    "FabricSpec", "RouteSet", "RouteTable", "clos_route_set",
    "clos_route_table", "clos_valiant_path", "dragonfly_path",
    "dragonfly_route_set", "dragonfly_route_table",
    "dragonfly_valiant_path", "stage_balance", "validate_route_set",
    "validate_table", "xgft_path", "xgft_route_set", "xgft_route_table",
    "xgft_valiant_path", "DragonflyIndex", "XGFTIndex",
    "make_dragonfly", "make_fat_tree", "make_xgft",
]
