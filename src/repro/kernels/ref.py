"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function is the straight-line mathematical definition with no tiling;
tests assert_allclose(kernel(interpret=True), ref) over shape/dtype sweeps.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# flash attention (train/prefill): causal / sliding-window / softcap
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  softcap: float = 0.0, scale: float | None = None):
    """q: [b, t, h, d]; k, v: [b, s, kv, d] (GQA) -> [b, t, h, d]."""
    b, t, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, t, kv, h // kv, d)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg, k).astype(jnp.float32)
    logits *= scale
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    qpos = jnp.arange(t)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", w, v)
    return out.reshape(b, t, h, d)


# ---------------------------------------------------------------------------
# decode attention: single query vs (possibly masked) KV cache
# ---------------------------------------------------------------------------

def decode_attention_ref(q, k, v, valid, *, softcap: float = 0.0,
                         scale: float | None = None):
    """q: [b, h, d]; k, v: [b, s, kv, d]; valid: [b, s] bool -> [b, h, d]."""
    b, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, kv, h // kv, d)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k).astype(jnp.float32)
    logits *= scale
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v)
    return out.reshape(b, h, d)


# ---------------------------------------------------------------------------
# cc_step: the paper's reaction-point update at DC scale
# ---------------------------------------------------------------------------

class RPState(NamedTuple):
    rate: jax.Array        # [F] f32 B/s
    target: jax.Array      # [F]
    alpha: jax.Array       # [F]
    byte_cnt: jax.Array    # [F]
    tmr: jax.Array         # [F]
    alpha_tmr: jax.Array   # [F]
    bc_stage: jax.Array    # [F] f32 (integral-valued; f32 for VPU tiling)
    t_stage: jax.Array     # [F]


class RPParams(NamedTuple):
    g: float
    rate_decrease: float
    timer_T: float
    byte_B: float
    rai: float
    rhai: float
    fr_stages: float
    min_rate: float
    line_rate: float
    dt: float


def rp_update_ref(st: RPState, cnp: jax.Array, p: RPParams) -> RPState:
    """One dt of the DCQCN RP state machine, vectorised over flows.

    Mirrors the DCQCN branch of repro.core.fluid (same semantics, f32
    stages instead of int32 so the whole state is one dtype for tiling).
    """
    g = p.g
    alpha_tmr = st.alpha_tmr + p.dt
    a_tick = alpha_tmr >= p.timer_T
    alpha = jnp.where(a_tick, (1 - g) * st.alpha, st.alpha)
    alpha_tmr = jnp.where(a_tick, 0.0, alpha_tmr)

    target = jnp.where(cnp, st.rate, st.target)
    rate = jnp.where(cnp, st.rate * (1 - alpha * p.rate_decrease), st.rate)
    alpha = jnp.where(cnp, (1 - g) * alpha + g, alpha)
    byte_cnt = jnp.where(cnp, 0.0, st.byte_cnt + st.rate * p.dt)
    tmr = jnp.where(cnp, 0.0, st.tmr + p.dt)
    alpha_tmr = jnp.where(cnp, 0.0, alpha_tmr)
    bc_stage = jnp.where(cnp, 0.0, st.bc_stage)
    t_stage = jnp.where(cnp, 0.0, st.t_stage)

    b_ev = byte_cnt >= p.byte_B
    t_ev = tmr >= p.timer_T
    byte_cnt = jnp.where(b_ev, 0.0, byte_cnt)
    tmr = jnp.where(t_ev, 0.0, tmr)
    bc_stage = bc_stage + b_ev
    t_stage = t_stage + t_ev
    ev = b_ev | t_ev
    imax = jnp.maximum(bc_stage, t_stage)
    imin = jnp.minimum(bc_stage, t_stage)
    in_fr = imax <= p.fr_stages
    in_hyper = imin > p.fr_stages
    target = jnp.where(ev & ~in_fr & ~in_hyper, target + p.rai, target)
    target = jnp.where(ev & in_hyper,
                       target + p.rhai * (imin - p.fr_stages), target)
    rate = jnp.where(ev, 0.5 * (rate + target), rate)
    rate = jnp.clip(rate, p.min_rate, p.line_rate)
    target = jnp.clip(target, p.min_rate, p.line_rate)
    return RPState(rate, target, alpha, byte_cnt, tmr, alpha_tmr,
                   bc_stage, t_stage)


class ERPParams(NamedTuple):
    settle: float
    hold: float
    min_rate: float
    line_rate: float
    dt: float


def erp_update_ref(rate, hold, cnp, tgt_rx, slope, p: ERPParams):
    """One dt of the paper's ERP: jump to signalled fair share, hold,
    desynchronised additive recovery.  All [F] f32."""
    rate = jnp.where(cnp, jnp.maximum(p.settle * tgt_rx, p.min_rate), rate)
    hold = jnp.where(cnp, p.hold, jnp.maximum(hold - p.dt, 0.0))
    rate = jnp.where(~cnp & (hold <= 0), rate + slope * p.dt, rate)
    rate = jnp.clip(rate, p.min_rate, p.line_rate)
    return rate, hold


class SwiftKParams(NamedTuple):
    target: float              # s, queuing-delay target
    beta: float                # max multiplicative decrease
    ai: float                  # B/s^2 additive recovery slope
    guard: float               # s between decreases
    min_rate: float
    line_rate: float
    dt: float


def swift_update_ref(rate, cool, qdelay, *, target, beta, ai, guard,
                     min_rate, line_rate, dt):
    """One dt of the delay-target reaction (Swift-like), [F] f32.

    Multiplicative decrease proportional to the excess of the path
    queuing-delay estimate over ``target`` — bounded by ``beta`` and
    paced by the ``guard`` cool-down — additive recovery below target.
    This is the single definition the jnp stage AND the Pallas kernel
    reproduce (exact f32 parity is a tier-1 test).
    """
    cool = jnp.maximum(cool - dt, 0.0)
    over = qdelay > target
    can = cool <= 0.0
    factor = 1.0 - beta * (qdelay - target) / jnp.maximum(qdelay, 1e-12)
    dec = jnp.maximum(rate * jnp.maximum(factor, 1.0 - beta), min_rate)
    rate = jnp.where(over & can, dec,
                     jnp.where(over, rate, rate + ai * dt))
    cool = jnp.where(over & can, guard, cool)
    rate = jnp.clip(rate, min_rate, line_rate)
    return rate, cool
