"""Whole-step fluid megakernel: VMEM-resident state across the dt-scan.

PR 4 kernelised the *pieces* of the fluid hot loop — the link
reductions (``fluid_reduce``) and the per-flow CC updates (``cc_step``)
— but every substep still round-trips ``FluidState`` through HBM
between four Pallas launches and a few hundred XLA ops.  This module
fuses the **whole step** into one ``pallas_call``:

  * ``megastep``       — one launch = one ``dt`` update.  The kernel
    body reconstructs the ``(FluidState, ScenarioDev, StepParams)``
    pytrees from its refs and runs the exact step math of
    ``repro.core.fluid`` (phase 1 generation + NP timers, transfers,
    PFC, and the marking / notification / reaction stage dispatches),
    selecting stages branchlessly by the traced ``mark_code`` /
    ``notif_code`` / ``react_code`` scalars riding in the packed SMEM
    param rows — so the whole 36-combo ``CCSpec`` matrix rides ONE
    kernel build, exactly like the jnp path's ``jnp.where`` dispatch.
  * ``megastep_block``  — the dt-scan pulled *inside* the kernel: a
    ``fori_loop`` over ``n_substeps`` keeps the state (rates, queues,
    the delay-line ring, per-flow CC state) resident across the whole
    decimated trace window, spilling only the window's ``TraceSample``
    accumulators to HBM.  One launch per trace window instead of
    one-plus per substep.
  * ``dense_reduce_tiled`` — the in-kernel form of the dense-CSR link
    reduction: the ``[S, dense_rows]`` position table is walked in
    ``[S, block]`` tiles with a sequential position loop per tile, so
    the per-link half of the step stays on-chip too.  Contributors
    accumulate in the same left-to-right position order as the untiled
    engine (trailing pad rows are exact ``+0.0``), so the result is
    bit-identical.

Bit-exactness: the kernel body runs the *same* jnp step function
(``repro.core.fluid.step_body_fn``) on values loaded from refs — same
primitives, same order — so the megakernel is held to exact f32
equality against the ``reduce="scat"`` / ``use_kernels=False``
reference by the parity suites (``tests/test_fluid_fused.py``,
``tests/test_kernels.py``), including delay-line ring contents and the
per-flow CC state dict.

Deployment note: CI runs every kernel with ``interpret=True`` (CPU).
On real TPU hardware the mega tier additionally requires the
scatter-free engines — ``reduce="fused"`` with ``dense_rows > 0`` (the
tiled dense-CSR walk above) — and a state footprint under the ~16 MB
VMEM budget; ``mega_footprint`` reports the resident bytes and
``_mega_call`` refuses a non-interpret launch past ``MEGA_VMEM_CAP``
(the roofline rows in ``benchmarks/roofline.py`` chart footprint vs
substep block size).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: VMEM budget for a non-interpret megakernel launch: state + scenario
#: operands must fit on-chip with ~2 MB headroom under the 16 MB/core.
MEGA_VMEM_CAP = 14 << 20

#: position-tile width of the in-kernel dense-CSR walk ([S, block, C]
#: resident per tile); 8 keeps the tile within a sublane group at the
#: common channel counts (C <= 3)
DENSE_TILE_BLOCK = 8


def mega_footprint(st, sd) -> int:
    """VMEM-resident bytes of one megakernel launch (state + scenario).

    State leaves count twice (input + output residency); scenario
    tensors once.  The packed param rows are dozens of bytes and are
    ignored.  This is the number the DESIGN.md §7 budget math and the
    roofline's footprint-vs-block-size rows are computed from.
    """
    n = 0
    for leaf in jax.tree.leaves(st):
        n += 2 * leaf.size * leaf.dtype.itemsize
    for leaf in jax.tree.leaves(sd):
        n += leaf.size * leaf.dtype.itemsize
    return int(n)


def dense_reduce_tiled(data_ext: jax.Array, dense_idx: jax.Array,
                       n_queues: int, dense_rows: int,
                       block: int = DENSE_TILE_BLOCK) -> jax.Array:
    """Tiled dense-CSR reduction: ``[S + 1, C]`` per-queue sums.

    ``data_ext`` is the queue-sorted ``[N + 1, C]`` contributor table
    (sentinel zero row last) and ``dense_idx`` the flattened
    ``[S * dense_rows]`` position table from the CSR offsets.  Where
    the untiled engine ``dynamic_slice``s one position at a time over
    the whole ``[S, dense_rows, C]`` table, this walks ``[S, block, C]``
    tiles — the VMEM-resident unit on TPU — with a sequential position
    loop per tile.  Real contributors keep their left-to-right order
    and the pad positions (to a whole number of tiles) gather the
    sentinel zero row, an exact ``+0.0`` after each queue's real
    entries: bit-identical to the untiled accumulation.
    """
    C = data_ext.shape[-1]
    n_blk = -(-dense_rows // block)
    idx = jnp.pad(dense_idx.reshape(n_queues, dense_rows),
                  ((0, 0), (0, n_blk * block - dense_rows)),
                  constant_values=data_ext.shape[0] - 1)
    dense = jnp.take(data_ext, idx.reshape(-1),
                     axis=0).reshape(n_queues, n_blk, block, C)

    def tile_body(b, acc):
        tile = jax.lax.dynamic_slice_in_dim(dense, b, 1, 1)[:, 0]

        def pos_body(p, a):
            return a + jax.lax.dynamic_slice_in_dim(tile, p, 1, 1)[:, 0]

        return jax.lax.fori_loop(0, block, pos_body, acc)

    acc = jax.lax.fori_loop(0, n_blk, tile_body,
                            jnp.zeros((n_queues, C), jnp.float32))
    return jnp.concatenate([acc, jnp.zeros((1, C), jnp.float32)])


# ---------------------------------------------------------------------------
# pytree <-> kernel-operand plumbing
# ---------------------------------------------------------------------------


def _lift(x: jax.Array) -> jax.Array:
    """Kernel-operand shape for one leaf (scalars/vectors become 2-d,
    the layout TPU refs want; >= 2-d leaves pass through)."""
    if x.ndim == 0:
        return x.reshape(1, 1)
    if x.ndim == 1:
        return x.reshape(1, -1)
    return x


def _split_params(par):
    """Pack a scalar-leaf pytree into (1, NF) f32 + (1, NI) int32 rows.

    ``StepParams`` is ~40 traced scalars (stage codes + every family's
    param union); packing them into two SMEM-sized rows keeps the
    kernel's operand list flat and — packed once per *launch*, outside
    any substep loop — hoists the per-step row rebuild the per-flow
    kernels used to pay.  Returns the rows plus a rebuild closure that
    reinflates the pytree from the loaded rows inside the kernel.
    """
    leaves, treedef = jax.tree.flatten(par)
    f_idx = [i for i, x in enumerate(leaves) if x.dtype == jnp.float32]
    i_idx = [i for i, x in enumerate(leaves) if x.dtype != jnp.float32]
    frow = (jnp.stack([leaves[i].reshape(()) for i in f_idx]).reshape(1, -1)
            if f_idx else jnp.zeros((1, 1), jnp.float32))
    irow = (jnp.stack([leaves[i].astype(jnp.int32).reshape(())
                       for i in i_idx]).reshape(1, -1)
            if i_idx else jnp.zeros((1, 1), jnp.int32))
    dtypes = [leaves[i].dtype for i in i_idx]

    def rebuild(fr, ir):
        out: list = [None] * len(leaves)
        for j, i in enumerate(f_idx):
            out[i] = fr[0, j]
        for j, i in enumerate(i_idx):
            out[i] = ir[0, j].astype(dtypes[j])
        return jax.tree.unflatten(treedef, out)

    return frow, irow, rebuild


def _mega_call(st, sd, par, inner, *, interpret: bool):
    """Launch ``inner(st, sd, par) -> (state', out_pytree)`` as ONE
    ``pallas_call``.

    Every ``FluidState`` / ``ScenarioDev`` leaf becomes a kernel ref;
    ``StepParams`` rides as two packed scalar rows.  Output leaves are
    sized by ``jax.eval_shape`` of ``inner`` — bool leaves (the trace's
    ``marked`` / ``cnp``) travel as int32 through the kernel and are
    cast back outside, value-exact.
    """
    st_leaves, st_def = jax.tree.flatten(st)
    sd_leaves, sd_def = jax.tree.flatten(sd)
    frow, irow, rebuild = _split_params(par)
    if not interpret and mega_footprint(st, sd) > MEGA_VMEM_CAP:
        raise ValueError(
            f"megakernel state footprint {mega_footprint(st, sd)} B "
            f"exceeds MEGA_VMEM_CAP ({MEGA_VMEM_CAP} B); shrink the "
            f"scenario (F/H/D) or run the flow-kernel tier "
            f"(use_kernels=True)")

    out_struct = jax.eval_shape(inner, st, sd, par)
    out_leaves, out_def = jax.tree.flatten(out_struct)

    def _oshape(s):
        shp = s.shape
        if len(shp) == 0:
            shp = (1, 1)
        elif len(shp) == 1:
            shp = (1,) + shp
        dt = jnp.int32 if s.dtype == jnp.bool_ else s.dtype
        return jax.ShapeDtypeStruct(shp, dt)

    n_st, n_sd = len(st_leaves), len(sd_leaves)

    def kernel(*refs):
        fr = refs[0][...]
        ir = refs[1][...]
        st_k = jax.tree.unflatten(
            st_def, [r[...].reshape(l.shape)
                     for r, l in zip(refs[2:2 + n_st], st_leaves)])
        sd_k = jax.tree.unflatten(
            sd_def, [r[...].reshape(l.shape)
                     for r, l in zip(refs[2 + n_st:2 + n_st + n_sd],
                                     sd_leaves)])
        res = inner(st_k, sd_k, rebuild(fr, ir))
        for ref, val, s in zip(refs[2 + n_st + n_sd:],
                               jax.tree.leaves(res), out_leaves):
            if s.dtype == jnp.bool_:
                val = val.astype(jnp.int32)
            ref[...] = val.reshape(ref.shape)

    outs = pl.pallas_call(
        kernel,
        out_shape=[_oshape(s) for s in out_leaves],
        interpret=interpret,
    )(frow, irow, *[_lift(x) for x in st_leaves],
      *[_lift(x) for x in sd_leaves])
    outs = [o.reshape(s.shape).astype(s.dtype)
            for o, s in zip(outs, out_leaves)]
    return jax.tree.unflatten(out_def, outs)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def megastep(st, sd, par, *, body, interpret: bool = False):
    """One fused whole-step launch: ``(state', StepTrace)``.

    ``body`` is the step closure from ``repro.core.fluid.step_body_fn``
    (statics baked, ``dense_tiled`` reduction, stage ``kernel_body``
    dispatch) — the single definition both the jnp path and this kernel
    execute, which is what makes the tiers bit-identical.
    """
    return _mega_call(st, sd, par, body, interpret=interpret)


def megastep_block(st, sd, par, *, body, n_substeps: int, acc_init,
                   acc_update, make_sample, n_vcs: int, dt: float,
                   interpret: bool = False):
    """One decimated trace window as ONE launch: the in-kernel dt-scan.

    Runs ``n_substeps`` iterations of ``body`` in a ``fori_loop`` whose
    carry — the full ``FluidState`` plus the window's trace
    accumulators — never leaves the kernel, then spills a single
    ``TraceSample`` row.  ``acc_init`` / ``acc_update`` /
    ``make_sample`` are the *same* accumulation functions
    ``repro.core.simulator.decimating_scan`` uses (window maxima,
    event counts, sums, window-mean ``inst_thr``), so the decimated
    trace is bit-identical to the per-step scan's.
    """

    def inner(st_k, sd_k, par_k):
        d0 = st_k.delivered

        def sub(_, carry):
            s, acc = carry
            s2, tr = body(s, sd_k, par_k)
            return s2, acc_update(acc, tr)

        st_out, acc = jax.lax.fori_loop(
            0, n_substeps, sub, (st_k, acc_init(st_k, n_vcs)))
        return st_out, make_sample(st_out, d0, acc, n_substeps, dt)

    return _mega_call(st, sd, par, inner, interpret=interpret)
