"""repro.kernels — Pallas TPU kernels + jnp oracles.

  flash_attention  — blockwise causal/SWA/softcap attention (train/prefill)
  decode_attention — single-token GQA decode over long KV caches
  cc_step          — DCQCN RP / paper-ERP rate updates at DC flow counts
  fluid_step       — the whole-step megakernel: one launch per dt (or
                     per decimated trace window), state VMEM-resident
  ops              — jit'd dispatchers (pallas | interpret | ref)
  ref              — pure-jnp ground truth for all of the above
"""

from . import ops, ref
from .flash_attention import flash_attention
from .decode_attention import decode_attention
from .cc_step import erp_step, rp_step
from .fluid_step import megastep, megastep_block

__all__ = ["ops", "ref", "flash_attention", "decode_attention",
           "erp_step", "rp_step", "megastep", "megastep_block"]
