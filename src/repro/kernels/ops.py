"""jit'd public wrappers for the Pallas kernels with jnp fallbacks.

Dispatch policy: on TPU backends the Pallas path compiles natively; on
CPU (this container) the default is the pure-jnp reference path, with
``interpret=True`` available everywhere for kernel-correctness tests.
Models call these wrappers (cfg.use_pallas) so swapping the backend is a
config flip, not a code change.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .cc_step import erp_step, rp_step
from .decode_attention import decode_attention
from .flash_attention import flash_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "backend"))
def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              softcap: float = 0.0, scale: float | None = None,
              backend: str = "auto"):
    """Fused attention: backend in {auto, pallas, interpret, ref}."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "ref":
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 softcap=softcap, scale=scale)
    return flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, scale=scale,
                           interpret=(backend == "interpret"))


@functools.partial(jax.jit, static_argnames=("softcap", "scale", "backend"))
def decode_attn(q, k, v, valid, *, softcap: float = 0.0,
                scale: float | None = None, backend: str = "auto"):
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "ref":
        return ref.decode_attention_ref(q, k, v, valid, softcap=softcap,
                                        scale=scale)
    return decode_attention(q, k, v, valid, softcap=softcap, scale=scale,
                            interpret=(backend == "interpret"))


def cc_rp_update(st, cnp, p, backend: str = "auto"):
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "ref":
        return ref.rp_update_ref(st, cnp, p)
    return rp_step(st, cnp, p, interpret=(backend == "interpret"))


def cc_erp_update(rate, hold, cnp, tgt_rx, slope, p, backend: str = "auto"):
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "ref":
        return ref.erp_update_ref(rate, hold, cnp, tgt_rx, slope, p)
    return erp_step(rate, hold, cnp, tgt_rx, slope, p,
                    interpret=(backend == "interpret"))
