"""Fused per-link segment reduction — Pallas TPU kernel for the fluid
hot loop.

The fluid step's per-link sums (FIFO num/den, transfer weights, PFC
sink queues, marking activity/surplus) all reduce the same [F*K*H]
link-sorted incidence (``ScenarioDev.red_perm``/``red_seg``, see
``repro.core.routing.link_incidence``).  This kernel performs one
multi-channel sorted segment sum: a single sweep over the [N, C] data
tile stream produces every per-link channel at once, with the output
accumulator and all C channels resident in VMEM for the whole pass —
the jnp path instead issues one XLA scatter per channel group and
bounces each through HBM.  Data streams at its true [N, C] width (C is
small — 1..3 channels per fluid pass), so HBM traffic is the payload
bytes, not a lane-padded copy.

Bit-exactness is a hard requirement (the golden suite freezes sweep
summaries), which pins the accumulation *order*: each segment's
contributions must be added in incidence order, exactly like the
sequential scatter-add they replace.  The kernel therefore walks the
rows of each tile in order (grid steps are sequential on a TPU core,
so cross-tile segments accumulate correctly) instead of using the
faster order-losing tricks (one-hot matmul scatter, cumsum
differencing).  Segment ids ride in SMEM via scalar prefetch.

The [S, C] accumulator must fit in VMEM alongside one data tile; with
the fluid step's C <= 3 that is ~2^20 segments before the guard below
trips — callers past it (or with pathological channel counts) should
use the ``reduce="fused"`` segment-sum engine instead.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_ROWS = 512          # rows per grid step
#: VMEM budget for the [S_pad, C] accumulator block (per-core VMEM is
#: ~16 MB and the data tile + ids need room too)
ACC_VMEM_CAP = 12 << 20


def _reduce_kernel(seg_ref, data_ref, out_ref):
    """Accumulate one row tile into the [S_pad, C] output block.

    ``out_ref`` maps to the same block on every grid step; step 0
    zeroes it, later steps keep accumulating (TPU grid steps run
    sequentially on a core, preserving the global row order).
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    base = i * TILE_ROWS

    def body(r, carry):
        s = seg_ref[base + r]
        out_ref[pl.ds(s, 1), :] += data_ref[pl.ds(r, 1), :]
        return carry

    jax.lax.fori_loop(0, TILE_ROWS, body, 0)


def segment_reduce(data: jax.Array, seg: jax.Array, num_segments: int,
                   *, interpret: bool = False) -> jax.Array:
    """Multi-channel sorted segment sum: [N, C] + [N] ids -> [S, C].

    ``seg`` must be ascending (sorted incidence); equal-id rows are
    accumulated in row order, bit-identical to a sequential
    ``zeros.at[seg].add(data)``.  ``num_segments`` is static.
    """
    N, C = data.shape
    if N == 0:
        # grid would be empty and the zeroing step would never run
        return jnp.zeros((num_segments, C), jnp.float32)
    n_pad = (-N) % TILE_ROWS
    s_pad = (-(num_segments + 1)) % 8
    s_rows = num_segments + 1 + s_pad
    if s_rows * C * 4 > ACC_VMEM_CAP:
        raise ValueError(
            f"segment_reduce accumulator [{s_rows}, {C}] f32 exceeds the "
            f"{ACC_VMEM_CAP >> 20} MB VMEM budget; use the segment-sum "
            f"engine (reduce='fused') for this shape")
    # padded rows land in a scratch segment past every real one
    scratch = num_segments
    data_p = jnp.pad(data, ((0, n_pad), (0, 0)))
    seg_p = jnp.pad(seg.astype(jnp.int32), (0, n_pad),
                    constant_values=scratch)
    rows = N + n_pad
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(rows // TILE_ROWS,),
        in_specs=[
            pl.BlockSpec((TILE_ROWS, C), lambda i, seg_ref: (i, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((s_rows, C), lambda i, seg_ref: (0, 0),
                               memory_space=pltpu.VMEM),
    )
    out = pl.pallas_call(
        _reduce_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s_rows, C), jnp.float32),
        interpret=interpret,
    )(seg_p, data_p)
    return out[:num_segments]
