"""Blockwise fused attention (forward) — Pallas TPU kernel.

Grid = (batch * kv_heads, q_blocks, kv_blocks); the kv axis is the
innermost (sequential / "arbitrary") dimension, carrying the online-
softmax accumulators in VMEM scratch.  Q/K/V tiles are MXU-aligned
(block_q x d and block_k x d with d = head_dim, multiples of 128 for
bf16-friendly layouts); GQA is handled by folding the q-per-kv group into
the q-block rows, so each grid cell is a dense [bq*g, d] x [d, bk] matmul.

Causal + sliding-window masking skips fully-masked kv blocks via
``pl.when`` (no wasted MXU issue slots); logit softcap (gemma2) is fused.

VMEM footprint per cell (defaults bq=bk=128, d=128, g<=8, f32 scratch):
  q (bq*g x d) + k,v (bk x d) + acc (bq*g x d) + m,l (bq*g)
  ~= (2*128*8 + 2*128) * 128 * 4B ~= 1.2 MB  << 16 MB v5e VMEM.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, causal: bool,
                  window: int | None, softcap: float, scale: float,
                  seq_q: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # can this kv block contribute at all?
    relevant = jnp.bool_(True)
    if causal:
        relevant = k_start <= q_start + block_q - 1       # not fully future
    if window is not None:
        relevant = jnp.logical_and(
            relevant, k_start + block_k - 1 > q_start - window)

    @pl.when(relevant)
    def _body():
        q = q_ref[0].astype(jnp.float32)                  # [bq*g, d]
        k = k_ref[0].astype(jnp.float32)                  # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        # zero padded KV rows: 0-weighted garbage (inf/nan) would still
        # poison the pexp @ v dot (0 * inf = nan)
        kvalid = (k_start + jax.lax.broadcasted_iota(
            jnp.int32, (v.shape[0], 1), 0) < seq_k)
        v = jnp.where(kvalid, v, 0.0)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq*g, bk]
        if softcap > 0:
            logits = jnp.tanh(logits / softcap) * softcap

        # rows are laid out q-position-major: row = pos * g + group
        g = q.shape[0] // block_q
        rows = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
        qpos = q_start + rows // max(g, 1)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[...]                               # [bq*g]
        m_new = jnp.maximum(m_prev, logits.max(axis=1))
        # all-masked rows keep m == NEG_INF; freeze them so exp() of a
        # (NEG_INF - NEG_INF) difference can't mint phantom mass
        corr = jnp.where(m_prev == NEG_INF, 1.0, jnp.exp(m_prev - m_new))
        pexp = jnp.exp(logits - m_new[:, None]) * mask
        l_ref[...] = l_ref[...] * corr + pexp.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            pexp, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, softcap: float = 0.0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: [b, t, h, d]; k, v: [b, s, kv, d] -> [b, t, h, d].

    GQA: h = kv * g; q rows are interleaved (position-major) so each
    (batch, kv-head) pair runs as one grid row.
    """
    b, t, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    block_q = min(block_q, t)
    block_k = min(block_k, s)
    nq = pl.cdiv(t, block_q)
    nk = pl.cdiv(s, block_k)

    # [b, t, kv, g, d] -> [b*kv, t*g, d] with rows position-major
    qr = (q.reshape(b, t, kv, g, d).transpose(0, 2, 1, 3, 4)
          .reshape(b * kv, t * g, d))
    kr = k.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kv, s, d)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal,
        window=window, softcap=softcap, scale=scale, seq_q=t, seq_k=s)

    out = pl.pallas_call(
        kernel,
        grid=(b * kv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q * g, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q * g, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, t * g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * g,), jnp.float32),      # running max m
            pltpu.VMEM((block_q * g,), jnp.float32),      # running sum l
            pltpu.VMEM((block_q * g, d), jnp.float32),    # accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)

    return (out.reshape(b, kv, t, g, d).transpose(0, 2, 1, 3, 4)
            .reshape(b, t, h, d))
