"""Single-token GQA decode attention — Pallas TPU kernel.

The decode_32k / long_500k hot spot: one query token against a long KV
cache.  Memory-bound by the KV stream (arithmetic intensity ~ g, the
GQA group size), so the kernel's job is a clean pipeline: KV tiles
stream HBM -> VMEM along the innermost sequential grid axis while the
online-softmax state (m, l, acc) lives in VMEM scratch.

Grid = (batch * kv_heads, kv_blocks).  q rows are the g group heads
(padded to >= 8 rows for TPU sublane alignment by the wrapper).  The
valid-mask handles ring-buffer caches (arbitrary valid-slot patterns).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   block_k: int, softcap: float, scale: float, seq_k: int):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # [g, d]
    k = k_ref[0].astype(jnp.float32)                     # [bk, d]
    v = v_ref[0].astype(jnp.float32)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (k.shape[0], 1), 0)
    ok = (valid_ref[0] > 0) & (kpos[:, 0] < seq_k)       # [bk]
    v = jnp.where(ok[:, None], v, 0.0)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # [g, bk]
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(ok[None, :], logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    corr = jnp.where(m_prev == NEG_INF, 1.0, jnp.exp(m_prev - m_new))
    pexp = jnp.exp(logits - m_new[:, None]) * ok[None, :]
    l_ref[...] = l_ref[...] * corr + pexp.sum(axis=1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot_general(
                        pexp, v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention(q, k, v, valid, *, softcap: float = 0.0,
                     scale: float | None = None, block_k: int = 512,
                     interpret: bool = False):
    """q: [b, h, d]; k, v: [b, s, kv, d]; valid: [b, s] -> [b, h, d]."""
    b, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    block_k = min(block_k, s)
    nk = pl.cdiv(s, block_k)

    qr = q.reshape(b, kv, g, d).reshape(b * kv, g, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    validr = jnp.repeat(valid.astype(jnp.int32), kv, axis=0)  # [b*kv, s]

    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               softcap=softcap, scale=scale, seq_k=s)
    out = pl.pallas_call(
        kernel,
        grid=(b * kv, nk),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k), lambda bh, ki: (bh, ki)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr, validr)
    return out.reshape(b, kv, g, d).reshape(b, h, d)
