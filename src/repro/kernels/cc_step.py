"""DC-scale reaction-point update — Pallas TPU kernel (the paper's hot
loop).

A datacenter NIC fleet runs the RP/ERP state machine for every active
flow (10^5..10^6 QPs).  The update is elementwise over flows — pure VPU
work — so the kernel's value is bandwidth shape: all 8 state vectors for
a flow tile are resident in VMEM simultaneously, giving one HBM round
trip per state per dt instead of the ~20 the unfused jnp version issues
(one per intermediate).  Tiles are (8, 128)-aligned rows of a [F8, 128]
layout.

Both reaction points are provided:
  * rp_step   — DCQCN RP (alpha EWMA + staged FR/AI/HI recovery)
  * erp_step  — the paper's ERP (jump-to-fair, hold, jittered recovery)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ERPParams, RPParams, RPState

LANE = 128
BLOCK_ROWS = 64          # (64, 128) f32 tiles = 32 KB per state vector


def _pad_to_grid(x: jax.Array) -> tuple[jax.Array, int]:
    f = x.shape[0]
    rows = pl.cdiv(f, LANE)
    rows_pad = pl.cdiv(rows, BLOCK_ROWS) * BLOCK_ROWS
    pad = rows_pad * LANE - f
    return jnp.pad(x, (0, pad)).reshape(rows_pad, LANE), f


def _unpad(x2d: jax.Array, f: int) -> jax.Array:
    return x2d.reshape(-1)[:f]


# ---------------------------------------------------------------------------
# DCQCN RP
# ---------------------------------------------------------------------------

def _rp_kernel(rate_ref, tgt_ref, alpha_ref, bc_ref, tmr_ref, atmr_ref,
               bst_ref, tst_ref, cnp_ref,
               o_rate, o_tgt, o_alpha, o_bc, o_tmr, o_atmr, o_bst, o_tst,
               *, p: RPParams):
    rate = rate_ref[...]
    target = tgt_ref[...]
    alpha = alpha_ref[...]
    byte_cnt = bc_ref[...]
    tmr = tmr_ref[...]
    alpha_tmr = atmr_ref[...] + p.dt
    bc_stage = bst_ref[...]
    t_stage = tst_ref[...]
    cnp = cnp_ref[...] > 0

    a_tick = alpha_tmr >= p.timer_T
    alpha = jnp.where(a_tick, (1 - p.g) * alpha, alpha)
    alpha_tmr = jnp.where(a_tick, 0.0, alpha_tmr)

    target = jnp.where(cnp, rate, target)
    new_rate = jnp.where(cnp, rate * (1 - alpha * p.rate_decrease), rate)
    alpha = jnp.where(cnp, (1 - p.g) * alpha + p.g, alpha)
    byte_cnt = jnp.where(cnp, 0.0, byte_cnt + rate * p.dt)
    tmr = jnp.where(cnp, 0.0, tmr + p.dt)
    alpha_tmr = jnp.where(cnp, 0.0, alpha_tmr)
    bc_stage = jnp.where(cnp, 0.0, bc_stage)
    t_stage = jnp.where(cnp, 0.0, t_stage)
    rate = new_rate

    b_ev = byte_cnt >= p.byte_B
    t_ev = tmr >= p.timer_T
    byte_cnt = jnp.where(b_ev, 0.0, byte_cnt)
    tmr = jnp.where(t_ev, 0.0, tmr)
    bc_stage = bc_stage + b_ev
    t_stage = t_stage + t_ev
    ev = b_ev | t_ev
    imax = jnp.maximum(bc_stage, t_stage)
    imin = jnp.minimum(bc_stage, t_stage)
    in_fr = imax <= p.fr_stages
    in_hyper = imin > p.fr_stages
    target = jnp.where(ev & ~in_fr & ~in_hyper, target + p.rai, target)
    target = jnp.where(ev & in_hyper,
                       target + p.rhai * (imin - p.fr_stages), target)
    rate = jnp.where(ev, 0.5 * (rate + target), rate)

    o_rate[...] = jnp.clip(rate, p.min_rate, p.line_rate)
    o_tgt[...] = jnp.clip(target, p.min_rate, p.line_rate)
    o_alpha[...] = alpha
    o_bc[...] = byte_cnt
    o_tmr[...] = tmr
    o_atmr[...] = alpha_tmr
    o_bst[...] = bc_stage
    o_tst[...] = t_stage


def rp_step(st: RPState, cnp: jax.Array, p: RPParams,
            interpret: bool = False) -> RPState:
    """Vectorised DCQCN RP update for F flows (any F)."""
    flat = [st.rate, st.target, st.alpha, st.byte_cnt, st.tmr,
            st.alpha_tmr, st.bc_stage, st.t_stage,
            cnp.astype(jnp.float32)]
    padded = [_pad_to_grid(x)[0] for x in flat]
    f = st.rate.shape[0]
    rows = padded[0].shape[0]
    grid = (rows // BLOCK_ROWS,)
    spec = pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0))
    outs = pl.pallas_call(
        functools.partial(_rp_kernel, p=p),
        grid=grid,
        in_specs=[spec] * 9,
        out_specs=[spec] * 8,
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.float32)] * 8,
        interpret=interpret,
    )(*padded)
    return RPState(*[_unpad(o, f) for o in outs])


# ---------------------------------------------------------------------------
# the paper's ERP
# ---------------------------------------------------------------------------

def _erp_kernel(rate_ref, hold_ref, cnp_ref, tgt_ref, slope_ref,
                o_rate, o_hold, *, p: ERPParams):
    rate = rate_ref[...]
    hold = hold_ref[...]
    cnp = cnp_ref[...] > 0
    tgt = tgt_ref[...]
    slope = slope_ref[...]
    rate = jnp.where(cnp, jnp.maximum(p.settle * tgt, p.min_rate), rate)
    hold = jnp.where(cnp, p.hold, jnp.maximum(hold - p.dt, 0.0))
    rate = jnp.where(~cnp & (hold <= 0), rate + slope * p.dt, rate)
    o_rate[...] = jnp.clip(rate, p.min_rate, p.line_rate)
    o_hold[...] = hold


def erp_step(rate, hold, cnp, tgt_rx, slope, p: ERPParams,
             interpret: bool = False):
    flat = [rate, hold, cnp.astype(jnp.float32), tgt_rx, slope]
    padded = [_pad_to_grid(x)[0] for x in flat]
    f = rate.shape[0]
    rows = padded[0].shape[0]
    spec = pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0))
    outs = pl.pallas_call(
        functools.partial(_erp_kernel, p=p),
        grid=(rows // BLOCK_ROWS,),
        in_specs=[spec] * 5,
        out_specs=[spec] * 2,
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.float32)] * 2,
        interpret=interpret,
    )(*padded)
    return _unpad(outs[0], f), _unpad(outs[1], f)
