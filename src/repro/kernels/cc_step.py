"""DC-scale per-flow CC updates — Pallas TPU kernels (the paper's hot
loop).

A datacenter NIC fleet runs the RP/ERP state machine for every active
flow (10^5..10^6 QPs).  The updates are elementwise over flows — pure
VPU work — so the kernels' value is bandwidth shape: all state vectors
for a flow tile are resident in VMEM simultaneously, giving one HBM
round trip per state per dt instead of the ~20 the unfused jnp version
issues (one per intermediate).  Tiles are (8, 128)-aligned rows of a
[F8, 128] layout.

The kernels are keyed per *stage*, not per scheme: each reaction
component registered in ``repro.core.cc`` may carry its own
``kernel_step``, and ``fluid_step(use_kernels=True)`` dispatches
through the registry.  Current set:
  * gen_np_step — fused generation + notification-timer tick (phase 1
                  + the per-flow half of phase 5)
  * rp_step     — DCQCN RP (alpha EWMA + staged FR/AI/HI recovery)
  * erp_step    — the paper's ERP (jump-to-fair, hold, jittered
                  recovery)
  * swift_step  — the delay-target reaction (queuing-delay signal,
                  guard-paced multiplicative decrease)

CC constants enter as a tiny (1, NP) SMEM vector rather than baked-in
python floats, so the *same compiled kernel* serves traced parameter
grids (the Sweep engine stacks ``StepParams`` and vmaps) — the
RPParams/ERPParams fields may be python floats or traced f32 scalars
interchangeably.

Soft-path note (``repro.tune``): the kernels implement the HARD
dynamics only — the incoming notification level is thresholded
(``cnp > 0``), so at ``StepParams.temperature == 0`` they are bitwise
equal to the jnp stages (the tier-1 parity suites), while a soft
(``temperature > 0``) tuner rollout must run ``use_kernels=False``;
``repro.tune.optimizers`` pins that.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import ERPParams, RPParams, RPState, SwiftKParams

LANE = 128
BLOCK_ROWS = 64          # (64, 128) f32 tiles = 32 KB per state vector


def _pad_to_grid(x: jax.Array) -> tuple[jax.Array, int]:
    f = x.shape[0]
    rows = pl.cdiv(f, LANE)
    rows_pad = pl.cdiv(rows, BLOCK_ROWS) * BLOCK_ROWS
    pad = rows_pad * LANE - f
    return jnp.pad(x, (0, pad)).reshape(rows_pad, LANE), f


def _unpad(x2d: jax.Array, f: int) -> jax.Array:
    return x2d.reshape(-1)[:f]


def _param_vec(*vals) -> jax.Array:
    """(1, NP) f32 row for the SMEM params block (floats or tracers)."""
    return jnp.stack([jnp.asarray(v, jnp.float32).reshape(())
                      for v in vals]).reshape(1, -1)


# Row layouts of each kernel's SMEM param vector.  These are the single
# definition of the packing order (the kernels unpack by index), and the
# hoisting entry point: a scanned step packs the rows ONCE per launch
# via ``repro.core.cc.pack_react_rows`` and passes them back through the
# ``packed=`` kwarg of the *_step wrappers, instead of re-tracing the
# stack-and-reshape every substep.

def pack_rp_params(p: RPParams) -> jax.Array:
    return _param_vec(p.g, p.rate_decrease, p.timer_T, p.byte_B, p.rai,
                      p.rhai, p.fr_stages, p.min_rate, p.line_rate, p.dt)


def pack_erp_params(p: ERPParams) -> jax.Array:
    return _param_vec(p.settle, p.hold, p.min_rate, p.line_rate, p.dt)


def pack_swift_params(p: SwiftKParams) -> jax.Array:
    return _param_vec(p.target, p.beta, p.ai, p.guard, p.min_rate,
                      p.line_rate, p.dt)


def _flow_call(kernel, inputs, params, n_out, *, interpret: bool):
    """Launch an elementwise per-flow kernel over (8,128)-tiled rows.

    ``inputs`` are [F] f32 vectors; ``params`` the (1, NP) SMEM row.
    Returns ``n_out`` [F] vectors.
    """
    padded = [_pad_to_grid(x)[0] for x in inputs]
    f = inputs[0].shape[0]
    rows = padded[0].shape[0]
    spec = pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0),
                        memory_space=pltpu.VMEM)
    pspec = pl.BlockSpec((1, params.shape[1]), lambda i: (0, 0),
                         memory_space=pltpu.SMEM)
    outs = pl.pallas_call(
        kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[pspec] + [spec] * len(padded),
        out_specs=[spec] * n_out,
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.float32)] * n_out,
        interpret=interpret,
    )(params, *padded)
    return [_unpad(o, f) for o in outs]


# ---------------------------------------------------------------------------
# fused generation + notification timer (fluid phases 1 and 5a)
# ---------------------------------------------------------------------------

def _gen_np_kernel(par_ref, nicq_ref, off_ref, drop_ref, tmr_ref,
                   rate_ref, ts_ref, te_ref, vol_ref, buf_ref,
                   o_nicq, o_off, o_drop, o_tmr):
    t_sec = par_ref[0, 0]
    dt = par_ref[0, 1]
    active = (t_sec >= ts_ref[...]) & (t_sec < te_ref[...])
    gen = jnp.where(active, rate_ref[...], 0.0) * dt
    gen = jnp.minimum(gen, jnp.maximum(vol_ref[...] - off_ref[...], 0.0))
    nicq = nicq_ref[...] + gen
    over = jnp.maximum(nicq - buf_ref[...], 0.0)
    o_nicq[...] = nicq - over
    o_off[...] = off_ref[...] + gen - over
    o_drop[...] = drop_ref[...] + over
    o_tmr[...] = tmr_ref[...] + dt


def gen_np_step(nicq, offered, dropped, np_tmr, gen_rate, t_start, t_stop,
                volume, nic_buffer, *, t_sec, dt,
                interpret: bool = False):
    """Fused window generator + NP suppression-timer tick for F flows.

    Returns ``(nicq', offered', dropped', np_tmr + dt)`` — the exact
    phase-1/5a arithmetic of the jnp fluid step, one VMEM residency.
    """
    return _flow_call(
        _gen_np_kernel,
        [nicq, offered, dropped, np_tmr, gen_rate, t_start, t_stop,
         volume, nic_buffer],
        _param_vec(t_sec, dt), 4, interpret=interpret)


# ---------------------------------------------------------------------------
# DCQCN RP
# ---------------------------------------------------------------------------

def _rp_kernel(par_ref, rate_ref, tgt_ref, alpha_ref, bc_ref, tmr_ref,
               atmr_ref, bst_ref, tst_ref, cnp_ref,
               o_rate, o_tgt, o_alpha, o_bc, o_tmr, o_atmr, o_bst, o_tst):
    (g, rate_decrease, timer_T, byte_B, rai, rhai, fr_stages, min_rate,
     line_rate, dt) = (par_ref[0, i] for i in range(10))
    rate = rate_ref[...]
    target = tgt_ref[...]
    alpha = alpha_ref[...]
    byte_cnt = bc_ref[...]
    tmr = tmr_ref[...]
    alpha_tmr = atmr_ref[...] + dt
    bc_stage = bst_ref[...]
    t_stage = tst_ref[...]
    cnp = cnp_ref[...] > 0

    a_tick = alpha_tmr >= timer_T
    alpha = jnp.where(a_tick, (1 - g) * alpha, alpha)
    alpha_tmr = jnp.where(a_tick, 0.0, alpha_tmr)

    target = jnp.where(cnp, rate, target)
    new_rate = jnp.where(cnp, rate * (1 - alpha * rate_decrease), rate)
    alpha = jnp.where(cnp, (1 - g) * alpha + g, alpha)
    byte_cnt = jnp.where(cnp, 0.0, byte_cnt + rate * dt)
    tmr = jnp.where(cnp, 0.0, tmr + dt)
    alpha_tmr = jnp.where(cnp, 0.0, alpha_tmr)
    bc_stage = jnp.where(cnp, 0.0, bc_stage)
    t_stage = jnp.where(cnp, 0.0, t_stage)
    rate = new_rate

    b_ev = byte_cnt >= byte_B
    t_ev = tmr >= timer_T
    byte_cnt = jnp.where(b_ev, 0.0, byte_cnt)
    tmr = jnp.where(t_ev, 0.0, tmr)
    bc_stage = bc_stage + b_ev
    t_stage = t_stage + t_ev
    ev = b_ev | t_ev
    imax = jnp.maximum(bc_stage, t_stage)
    imin = jnp.minimum(bc_stage, t_stage)
    in_fr = imax <= fr_stages
    in_hyper = imin > fr_stages
    target = jnp.where(ev & ~in_fr & ~in_hyper, target + rai, target)
    target = jnp.where(ev & in_hyper,
                       target + rhai * (imin - fr_stages), target)
    rate = jnp.where(ev, 0.5 * (rate + target), rate)

    o_rate[...] = jnp.clip(rate, min_rate, line_rate)
    o_tgt[...] = jnp.clip(target, min_rate, line_rate)
    o_alpha[...] = alpha
    o_bc[...] = byte_cnt
    o_tmr[...] = tmr
    o_atmr[...] = alpha_tmr
    o_bst[...] = bc_stage
    o_tst[...] = t_stage


def rp_step(st: RPState, cnp: jax.Array, p: RPParams,
            interpret: bool = False,
            packed: jax.Array | None = None) -> RPState:
    """Vectorised DCQCN RP update for F flows (any F)."""
    outs = _flow_call(
        _rp_kernel,
        [st.rate, st.target, st.alpha, st.byte_cnt, st.tmr, st.alpha_tmr,
         st.bc_stage, st.t_stage, cnp.astype(jnp.float32)],
        pack_rp_params(p) if packed is None else packed,
        8, interpret=interpret)
    return RPState(*outs)


# ---------------------------------------------------------------------------
# the paper's ERP
# ---------------------------------------------------------------------------

def _erp_kernel(par_ref, rate_ref, hold_ref, cnp_ref, tgt_ref, slope_ref,
                o_rate, o_hold):
    settle, hold_T, min_rate, line_rate, dt = (
        par_ref[0, i] for i in range(5))
    rate = rate_ref[...]
    hold = hold_ref[...]
    cnp = cnp_ref[...] > 0
    tgt = tgt_ref[...]
    slope = slope_ref[...]
    rate = jnp.where(cnp, jnp.maximum(settle * tgt, min_rate), rate)
    hold = jnp.where(cnp, hold_T, jnp.maximum(hold - dt, 0.0))
    rate = jnp.where(~cnp & (hold <= 0), rate + slope * dt, rate)
    o_rate[...] = jnp.clip(rate, min_rate, line_rate)
    o_hold[...] = hold


def erp_step(rate, hold, cnp, tgt_rx, slope, p: ERPParams,
             interpret: bool = False,
             packed: jax.Array | None = None):
    outs = _flow_call(
        _erp_kernel,
        [rate, hold, cnp.astype(jnp.float32), tgt_rx, slope],
        pack_erp_params(p) if packed is None else packed,
        2, interpret=interpret)
    return outs[0], outs[1]


# ---------------------------------------------------------------------------
# delay-target reaction (Swift-like) — the mark-free stage variant
# ---------------------------------------------------------------------------

def _swift_kernel(par_ref, rate_ref, cool_ref, qd_ref, o_rate, o_cool):
    target, beta, ai, guard, min_rate, line_rate, dt = (
        par_ref[0, i] for i in range(7))
    rate = rate_ref[...]
    cool = jnp.maximum(cool_ref[...] - dt, 0.0)
    qd = qd_ref[...]
    over = qd > target
    can = cool <= 0.0
    factor = 1.0 - beta * (qd - target) / jnp.maximum(qd, 1e-12)
    dec = jnp.maximum(rate * jnp.maximum(factor, 1.0 - beta), min_rate)
    rate = jnp.where(over & can, dec,
                     jnp.where(over, rate, rate + ai * dt))
    o_cool[...] = jnp.where(over & can, guard, cool)
    o_rate[...] = jnp.clip(rate, min_rate, line_rate)


def swift_step(rate, cool, qdelay, p: SwiftKParams,
               interpret: bool = False,
               packed: jax.Array | None = None):
    """Vectorised delay-target update for F flows (any F).

    Exact f32 mirror of ``ref.swift_update_ref`` — the delay signal
    replaces the CNP input, so the kernel reads (rate, guard cool-down,
    queuing-delay estimate) and writes (rate', cool-down').
    """
    outs = _flow_call(
        _swift_kernel,
        [rate, cool, qdelay],
        pack_swift_params(p) if packed is None else packed,
        2, interpret=interpret)
    return outs[0], outs[1]
