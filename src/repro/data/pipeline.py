"""Deterministic synthetic LM data pipeline.

Production shape: host-sharded, stateful (exact-resume via the checkpoint
manifest), backpressure-free.  The generator is a counter-based PRNG
(threefry on (seed, step, shard)) so any host can materialise its shard of
any step independently — the property that makes elastic restart and
straggler skip-ahead trivial: state == an integer.

Also provides a Zipf-mixture "naturalish" token distribution so loss
curves have realistic structure (tests assert learnability).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "zipf"            # zipf | markov | uniform
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticLM:
    """Stateless-per-step generator; state is just the step counter."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed Zipf ranks + a deterministic bigram shift for structure
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        self._zipf_p = (1.0 / ranks ** 1.2)
        self._zipf_p /= self._zipf_p.sum()

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        key = ((cfg.seed & 0xFFFFFFFF) << 96) | ((step & 0xFFFFFFFF) << 64) \
            | ((cfg.host_id & 0xFFFFFFFF) << 32) | 0xC0FFEE
        rng = np.random.Generator(np.random.Philox(key=key))
        b, t = cfg.host_batch, cfg.seq_len
        if cfg.kind == "uniform":
            toks = rng.integers(0, cfg.vocab, size=(b, t + 1))
        elif cfg.kind == "markov":
            # learnable structure: x_{i+1} = (a*x_i + noise) mod vocab
            toks = np.zeros((b, t + 1), np.int64)
            toks[:, 0] = rng.integers(0, cfg.vocab, size=b)
            noise = rng.integers(0, 7, size=(b, t))
            for i in range(t):
                toks[:, i + 1] = (toks[:, i] * 31 + 17 + noise[:, i]) \
                    % cfg.vocab
        else:  # zipf
            toks = rng.choice(cfg.vocab, size=(b, t + 1), p=self._zipf_p)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batches(cfg: DataConfig, start_step: int = 0):
    """Iterator of (step, batch) resuming exactly at `start_step`."""
    ds = SyntheticLM(cfg)
    step = start_step
    while True:
        yield step, ds.batch_at(step)
        step += 1
