"""Batched serving: continuous-batching engine over prefill/decode steps.

``make_serve_step`` builds the jitted single-token step the dry-run
lowers for decode_* / long_* shapes.  ``ServingEngine`` is the host-side
request manager: slot-based continuous batching (a finished sequence's
slot is refilled by the next queued request without stopping the batch),
greedy or temperature sampling.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer
from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 2048
    temperature: float = 0.0      # 0 = greedy
    eos_token: int = 1


def make_serve_step(cfg: ModelConfig):
    """(params, token [b,1], caches, pos []) -> (logits, caches)."""
    def serve_step(params, token, caches, pos):
        return transformer.decode_step(params, cfg, token, caches, pos)
    return serve_step


def make_prefill(cfg: ModelConfig, max_len: int):
    def prefill(params, tokens):
        return transformer.prefill(params, cfg, tokens, max_len)
    return prefill


class ServingEngine:
    """Host-side continuous batching over a fixed slot grid.

    All slots share one decode position counter (padded prefixes), which
    keeps the jitted step shape-stable; per-slot alive masks handle
    ragged completion.
    """

    def __init__(self, cfg: ModelConfig, params, sv: ServeConfig):
        self.cfg, self.params, self.sv = cfg, params, sv
        self._step = jax.jit(make_serve_step(cfg))
        self._prefill = jax.jit(make_prefill(cfg, sv.max_len))
        self.rng = np.random.RandomState(0)

    def generate(self, prompts: list[list[int]],
                 max_new_tokens: int = 32) -> list[list[int]]:
        """Serve a queue of prompts through the slot grid."""
        sv = self.sv
        queue = list(enumerate(prompts))
        outputs: dict[int, list[int]] = {i: [] for i in range(len(prompts))}
        B = sv.batch_slots

        while queue:
            wave, queue = queue[:B], queue[B:]
            ids = [w[0] for w in wave]
            toks = [w[1] for w in wave]
            plen = max(len(t) for t in toks)
            grid = np.zeros((B, plen), np.int32)
            for i, t in enumerate(toks):
                grid[i, plen - len(t):] = t       # left-pad
            logits, caches = self._prefill(self.params, jnp.asarray(grid))
            last = self._sample(np.asarray(logits)[:, -1])
            alive = np.zeros((B,), bool)
            alive[:len(wave)] = True
            for i in range(len(wave)):
                outputs[ids[i]].append(int(last[i]))

            pos = plen
            cur = last
            for _ in range(max_new_tokens - 1):
                if not alive.any() or pos >= sv.max_len - 1:
                    break
                logits, caches = self._step(
                    self.params, jnp.asarray(cur[:, None], jnp.int32),
                    caches, jnp.asarray(pos, jnp.int32))
                nxt = self._sample(np.asarray(logits)[:, 0])
                for i in range(len(wave)):
                    if alive[i]:
                        outputs[ids[i]].append(int(nxt[i]))
                        if nxt[i] == sv.eos_token:
                            alive[i] = False
                cur = nxt
                pos += 1
        return [outputs[i] for i in range(len(prompts))]

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.sv.temperature <= 0:
            return logits.argmax(-1).astype(np.int32)
        z = logits / self.sv.temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.asarray([self.rng.choice(p.shape[-1], p=p[i])
                           for i in range(p.shape[0])], np.int32)
