"""Batched serving: continuous-batching engine over prefill/decode steps.

``make_serve_step`` builds the jitted single-token step the dry-run
lowers for decode_* / long_* shapes.  ``ServingEngine`` is the host-side
request manager: slot-based continuous batching (a finished sequence's
slot is refilled by the next queued request without stopping the batch),
greedy or temperature sampling.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer
from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 2048
    temperature: float = 0.0      # 0 = greedy
    eos_token: int = 1


def make_serve_step(cfg: ModelConfig):
    """(params, token [b,1], caches, pos []) -> (logits, caches)."""
    def serve_step(params, token, caches, pos):
        return transformer.decode_step(params, cfg, token, caches, pos)
    return serve_step


def make_prefill(cfg: ModelConfig, max_len: int):
    def prefill(params, tokens):
        return transformer.prefill(params, cfg, tokens, max_len)
    return prefill


class ServingEngine:
    """Host-side continuous batching over a fixed slot grid.

    All slots share one decode position counter (padded prefixes), which
    keeps the jitted step shape-stable; per-slot alive masks handle
    ragged completion.  When a slot's sequence ends (EOS or budget) the
    next queued request is *refilled* into that slot mid-flight — its
    prompt is prefilled left-padded to the batch's current position and
    the fresh KV rows are scattered into the live caches — so the batch
    never stalls on its slowest member.  Rows are independent under the
    causal position mask, so a refilled slot's output is identical to
    serving it alone with the same left padding.
    """

    def __init__(self, cfg: ModelConfig, params, sv: ServeConfig):
        self.cfg, self.params, self.sv = cfg, params, sv
        self._step = jax.jit(make_serve_step(cfg))
        self._prefill = jax.jit(make_prefill(cfg, sv.max_len))
        self.rng = np.random.RandomState(0)
        self.stats = {"prefills": 0, "refills": 0, "decode_steps": 0}

    def generate(self, prompts: list[list[int]],
                 max_new_tokens: int = 32) -> list[list[int]]:
        """Serve a queue of prompts through the slot grid.

        Continuous batching: a finished slot is refilled from the queue
        head while the rest of the batch keeps decoding (strict FIFO; a
        head prompt longer than the current position waits for the next
        joint prefill).  Unlike the wave scheduler, a refilled request's
        first (prefill-sampled) token is also EOS-checked.
        """
        sv = self.sv
        queue = list(enumerate(prompts))
        outputs: dict[int, list[int]] = {i: [] for i in range(len(prompts))}
        B = sv.batch_slots
        self.stats = {"prefills": 0, "refills": 0, "decode_steps": 0}
        slot_id = np.full((B,), -1, np.int64)    # request id, -1 = free
        remaining = np.zeros((B,), np.int64)     # decode budget per slot
        caches = None
        cur = np.zeros((B,), np.int32)           # token for position `pos`
        pos = 0

        while queue or (slot_id >= 0).any():
            if not (slot_id >= 0).any():
                # joint prefill: restart the grid with the next B requests
                wave, queue = queue[:B], queue[B:]
                plen = max(len(t) for _, t in wave)
                grid = np.zeros((B, plen), np.int32)
                for i, (_, t) in enumerate(wave):
                    grid[i, plen - len(t):] = t           # left-pad
                logits, caches = self._prefill(self.params,
                                               jnp.asarray(grid))
                last = self._sample(np.asarray(logits)[:, -1])
                pos, cur = plen, last
                self.stats["prefills"] += 1
                for i, (rid, _) in enumerate(wave):
                    slot_id[i] = rid
                    remaining[i] = max_new_tokens - 1
                    outputs[rid].append(int(last[i]))
                    if last[i] == sv.eos_token or remaining[i] <= 0:
                        slot_id[i] = -1
                continue

            # refill free slots from the queue head (prompts that fit
            # in the current position; longer ones wait for a restart)
            free = [i for i in range(B) if slot_id[i] < 0]
            fill = []
            while queue and free and len(queue[0][1]) <= pos:
                fill.append((free.pop(0), queue.pop(0)))
            if fill:
                grid = np.zeros((B, pos), np.int32)
                for slot, (_, t) in fill:
                    grid[slot, pos - len(t):] = t
                logits, fresh = self._prefill(self.params,
                                              jnp.asarray(grid))
                last = self._sample(np.asarray(logits)[:, -1])
                caches = self._scatter_rows(
                    caches, fresh, [s for s, _ in fill])
                self.stats["refills"] += len(fill)
                for slot, (rid, _) in fill:
                    slot_id[slot] = rid
                    remaining[slot] = max_new_tokens - 1
                    cur[slot] = last[slot]
                    outputs[rid].append(int(last[slot]))
                    if last[slot] == sv.eos_token or remaining[slot] <= 0:
                        slot_id[slot] = -1
                if not (slot_id >= 0).any():
                    continue

            if pos >= sv.max_len - 1:            # out of cache room:
                slot_id[:] = -1                  # retire the whole grid
                continue
            logits, caches = self._step(
                self.params, jnp.asarray(cur[:, None], jnp.int32),
                caches, jnp.asarray(pos, jnp.int32))
            nxt = self._sample(np.asarray(logits)[:, 0])
            pos += 1
            self.stats["decode_steps"] += 1
            for i in range(B):
                if slot_id[i] >= 0:
                    outputs[slot_id[i]].append(int(nxt[i]))
                    remaining[i] -= 1
                    if nxt[i] == sv.eos_token or remaining[i] <= 0:
                        slot_id[i] = -1
            cur = nxt
        return [outputs[i] for i in range(len(prompts))]

    def _scatter_rows(self, live, fresh, slots: list[int]):
        """Copy ``slots``' rows of every per-sequence cache leaf from
        ``fresh`` into ``live``.

        The batch axis is found per leaf via ``cache_specs`` — grouped
        layers are stacked behind a leading ``layers`` axis, so it is
        NOT always axis 0.  Leaves without a ``cache_batch`` dim (the
        shared position counter) stay live.
        """
        specs = transformer.cache_specs(self.cfg, self.sv.batch_slots,
                                        self.sv.max_len)
        rows = jnp.asarray(slots, jnp.int32)

        def scatter(leaf_live, leaf_new, spec):
            if "cache_batch" not in spec:
                return leaf_live
            idx = (slice(None),) * spec.index("cache_batch") + (rows,)
            return leaf_live.at[idx].set(leaf_new[idx])

        return jax.tree.map(scatter, live, fresh, specs)

    def _generate_waves(self, prompts: list[list[int]],
                        max_new_tokens: int = 32) -> list[list[int]]:
        """Wave scheduler (the pre-refill baseline, kept as the
        regression oracle): each wave of B prompts runs to completion
        before the next starts; a finished slot idles till wave end."""
        sv = self.sv
        queue = list(enumerate(prompts))
        outputs: dict[int, list[int]] = {i: [] for i in range(len(prompts))}
        B = sv.batch_slots

        while queue:
            wave, queue = queue[:B], queue[B:]
            ids = [w[0] for w in wave]
            toks = [w[1] for w in wave]
            plen = max(len(t) for t in toks)
            grid = np.zeros((B, plen), np.int32)
            for i, t in enumerate(toks):
                grid[i, plen - len(t):] = t       # left-pad
            logits, caches = self._prefill(self.params, jnp.asarray(grid))
            last = self._sample(np.asarray(logits)[:, -1])
            alive = np.zeros((B,), bool)
            alive[:len(wave)] = True
            for i in range(len(wave)):
                outputs[ids[i]].append(int(last[i]))

            pos = plen
            cur = last
            for _ in range(max_new_tokens - 1):
                if not alive.any() or pos >= sv.max_len - 1:
                    break
                logits, caches = self._step(
                    self.params, jnp.asarray(cur[:, None], jnp.int32),
                    caches, jnp.asarray(pos, jnp.int32))
                nxt = self._sample(np.asarray(logits)[:, 0])
                for i in range(len(wave)):
                    if alive[i]:
                        outputs[ids[i]].append(int(nxt[i]))
                        if nxt[i] == sv.eos_token:
                            alive[i] = False
                cur = nxt
                pos += 1
        return [outputs[i] for i in range(len(prompts))]

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.sv.temperature <= 0:
            return logits.argmax(-1).astype(np.int32)
        z = logits / self.sv.temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.asarray([self.rng.choice(p.shape[-1], p=p[i])
                           for i in range(p.shape[0])], np.int32)
