"""repro.serve.whatif — the simulator as a throttled, cache-warm
what-if query service (DESIGN.md §8).

  * engine:    CCQueryEngine / WhatIfQuery / QueryResult — micro-
               batched queries over the one-jit Sweep, keyed to the
               shared compiled-executable cache
  * admission: token-bucket + bounded-queue front door with explicit
               Admitted / Throttled / QueueFull outcomes
  * metrics:   latency percentiles, batch occupancy, cache hit rate,
               compile/run split (-> BENCH_serve.json)
"""

from .admission import (AdmissionConfig, AdmissionController, Admitted,
                        QueueFull, Throttled, TokenBucket)
from .engine import (CCQueryEngine, EngineConfig, QueryResult,
                     StructuralSignature, WhatIfQuery, flow_bucket)
from .metrics import EngineMetrics, LatencyRecorder

__all__ = [
    "AdmissionConfig", "AdmissionController", "Admitted", "QueueFull",
    "Throttled", "TokenBucket",
    "CCQueryEngine", "EngineConfig", "QueryResult",
    "StructuralSignature", "WhatIfQuery", "flow_bucket",
    "EngineMetrics", "LatencyRecorder",
]
