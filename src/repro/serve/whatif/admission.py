"""Token-bucket admission + bounded queue for the what-if front door.

The paper's subject is injection throttling inside the fabric; this
module applies the same discipline to the simulator-as-a-service front
door (the SNIPPETS.md throttling pattern, dogfooded): a per-tenant
token bucket meters the *rate* (with a burst allowance), a bounded
queue meters the *backlog*, and both reject explicitly — callers get a
:class:`Throttled` (with ``retry_after``) or :class:`QueueFull` outcome
instead of blocking forever or growing an unbounded queue.  Decisions
never silently drop work: every submitted query resolves to exactly one
of ``Admitted`` / ``Throttled`` / ``QueueFull``.

The clock is injected (``clock=time.monotonic`` by default) so tests
and replays drive admission deterministically.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Front-door policy: rate x burst per tenant, bounded backlog.

    ``rate`` tokens/second refill each tenant's bucket up to ``burst``;
    a query costs one token.  ``max_queue`` bounds the waiting queries
    across all tenants; ``max_inflight`` caps how many admitted queries
    may execute concurrently (the micro-batcher never builds a wider
    batch, whatever ``EngineConfig.max_batch`` says).
    """

    rate: float = 100.0
    burst: int = 32
    max_queue: int = 64
    max_inflight: int = 16

    def __post_init__(self):
        if self.rate < 0 or self.burst < 1:
            raise ValueError(
                f"rate must be >= 0 and burst >= 1, got rate={self.rate} "
                f"burst={self.burst}")
        if self.max_queue < 1 or self.max_inflight < 1:
            raise ValueError(
                f"max_queue and max_inflight must be >= 1, got "
                f"max_queue={self.max_queue} "
                f"max_inflight={self.max_inflight}")


# -- outcomes ---------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Admitted:
    """Query accepted; ``ticket`` keys the eventual result."""

    ticket: int
    tenant: str = "default"
    queue_depth: int = 0


@dataclasses.dataclass(frozen=True)
class Throttled:
    """Over-rate: the tenant's token bucket is empty.  Retry after
    ``retry_after`` seconds (when the next token lands)."""

    tenant: str
    retry_after: float


@dataclasses.dataclass(frozen=True)
class QueueFull:
    """Back-pressure: the bounded queue is at capacity.  The token was
    *not* consumed; retry after the service drains."""

    tenant: str
    queue_depth: int


# -- token bucket -----------------------------------------------------------


class TokenBucket:
    """Continuous-refill token bucket (rate/s up to ``burst``)."""

    def __init__(self, rate: float, burst: int, now: float):
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)          # start full: bursts admit
        self.stamp = now

    def _refill(self, now: float) -> None:
        if now > self.stamp:
            self.tokens = min(self.burst,
                              self.tokens + (now - self.stamp) * self.rate)
        self.stamp = max(self.stamp, now)

    def peek(self, now: float) -> bool:
        self._refill(now)
        return self.tokens >= 1.0

    def take(self, now: float) -> bool:
        self._refill(now)
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False

    def retry_after(self, now: float) -> float:
        """Seconds until a full token is available (inf at rate 0)."""
        self._refill(now)
        if self.tokens >= 1.0:
            return 0.0
        if self.rate <= 0.0:
            return float("inf")
        return (1.0 - self.tokens) / self.rate


class AdmissionController:
    """Per-tenant token buckets + counters; the engine owns the queue.

    ``admit(tenant)`` charges the tenant's bucket (created on first
    sight, starting full) and returns ``None`` on success or a
    :class:`Throttled` outcome.  Queue capacity is checked *before*
    the token is spent — a rejected query never burns budget.
    """

    def __init__(self, cfg: AdmissionConfig,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self._buckets: dict[str, TokenBucket] = {}
        self.admitted = 0
        self.throttled = 0
        self.queue_full = 0

    def _bucket(self, tenant: str) -> TokenBucket:
        b = self._buckets.get(tenant)
        if b is None:
            b = self._buckets[tenant] = TokenBucket(
                self.cfg.rate, self.cfg.burst, self.clock())
        return b

    def admit(self, tenant: str, queue_depth: int):
        """None = admitted (token charged); else Throttled/QueueFull."""
        now = self.clock()
        bucket = self._bucket(tenant)
        if not bucket.peek(now):
            self.throttled += 1
            return Throttled(tenant=tenant,
                             retry_after=bucket.retry_after(now))
        if queue_depth >= self.cfg.max_queue:
            self.queue_full += 1
            return QueueFull(tenant=tenant, queue_depth=queue_depth)
        bucket.take(now)
        self.admitted += 1
        return None

    def counters(self) -> dict:
        return {"admitted": self.admitted, "throttled": self.throttled,
                "queue_full": self.queue_full,
                "tenants": len(self._buckets)}
