"""Observability for the what-if engine: latency, occupancy, cache.

Everything here is plain-python accumulation — no numpy in the hot
path, dicts of scalars out — because the metrics are part of the wire
surface (``benchmarks/serve_bench.py`` dumps them into
``BENCH_serve.json`` and the CI ``serve-smoke`` job gates on them).
"""

from __future__ import annotations

import dataclasses


class LatencyRecorder:
    """Per-query latency samples with percentile summaries.

    Keeps every sample (queries are seconds apart and kilobyte-sized;
    a replay of 10^5 queries is still only megabytes) so p50/p99 are
    exact, not sketched.
    """

    def __init__(self, name: str = "latency"):
        self.name = name
        self._samples: list[float] = []

    def record(self, seconds: float) -> None:
        self._samples.append(float(seconds))

    def __len__(self) -> int:
        return len(self._samples)

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile, q in [0, 100]; nan when empty."""
        if not self._samples:
            return float("nan")
        s = sorted(self._samples)
        rank = max(0, min(len(s) - 1,
                          int(round(q / 100.0 * (len(s) - 1)))))
        return s[rank]

    def summary(self) -> dict:
        if not self._samples:
            return {"count": 0}
        return {"count": len(self._samples),
                "mean": sum(self._samples) / len(self._samples),
                "p50": self.percentile(50.0),
                "p99": self.percentile(99.0),
                "max": max(self._samples)}


@dataclasses.dataclass
class EngineMetrics:
    """Counters + recorders the engine updates as it serves.

    ``compile_s`` vs ``run_s`` is the compile-time / run-time split:
    compile seconds come from the executable cache's builder clock (a
    miss pays AOT lowering + compilation exactly once), run seconds are
    the device-launch wall time of each micro-batch.
    """

    queries: int = 0              # completed queries
    batches: int = 0              # micro-batches launched
    occupancy_sum: float = 0.0    # sum over batches of real/width
    run_s: float = 0.0            # device launch + host pack/slice time
    latency: LatencyRecorder = dataclasses.field(
        default_factory=LatencyRecorder)
    queue_wait: LatencyRecorder = dataclasses.field(
        default_factory=lambda: LatencyRecorder("queue_wait"))

    def record_batch(self, n_real: int, width: int,
                     exec_s: float) -> None:
        self.batches += 1
        self.queries += n_real
        self.occupancy_sum += n_real / max(1, width)
        self.run_s += exec_s

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / self.batches if self.batches else 0.0

    def to_dict(self, cache_stats=None, admission=None) -> dict:
        """The metrics dict of the serving layer (wire-ready scalars).

        ``cache_stats``: a ``CacheStats`` *window delta* for the
        executable cache; ``admission``: the controller's counters.
        """
        out = {
            "queries": self.queries,
            "batches": self.batches,
            "mean_occupancy": round(self.mean_occupancy, 4),
            "run_s": round(self.run_s, 4),
            "latency_s": {k: (round(v, 6) if isinstance(v, float) else v)
                          for k, v in self.latency.summary().items()},
            "queue_wait_s": {k: (round(v, 6) if isinstance(v, float)
                                 else v)
                             for k, v in self.queue_wait.summary().items()},
        }
        if cache_stats is not None:
            out["exec_cache"] = cache_stats.to_dict()
            out["compile_s"] = round(cache_stats.build_s, 3)
        if admission is not None:
            out["admission"] = dict(admission)
        return out
