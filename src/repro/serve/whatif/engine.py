"""CCQueryEngine — the one-jit Sweep as a cache-warm what-if service.

"What if kmin=X on this pod under this incast storm?" as a low-latency
query instead of an offline batch job.  Four layers (DESIGN.md §8):

  1. **Compiled-executable cache** — every query resolves to the shared
     ``repro.core.SWEEP_EXEC_CACHE`` via its *structural signature*
     (fabric topology / H_MAX / K-paths, bucketed grid shape, trace
     settings): the first query on a pod shape pays compilation, every
     later one swaps traced data into the warm executable.
  2. **Micro-batcher** — queued queries that share a signature coalesce
     onto the vmap run axis, padded to a fixed batch width
     (``Sweep.run(pad_runs_to=...)``) and a bucketed flow count
     (``pad_scenario``), so batch composition never changes the
     compiled program.  Per-query slices are *bitwise* what a
     standalone single-point ``Sweep.run()`` returns (padding is inert
     by construction; gated in tests/test_whatif_engine.py).
  3. **Admission control** — a per-tenant token bucket + bounded queue
     (``repro.serve.whatif.admission``): over-rate submissions get an
     explicit :class:`Throttled`, a full queue gets :class:`QueueFull`;
     nothing blocks forever, nothing queues unboundedly.
  4. **Observability** — per-query latency (p50/p99), batch occupancy,
     cache hit rate and the compile/run time split, as a metrics dict
     (``benchmarks/serve_bench.py`` -> ``BENCH_serve.json``).

Quickstart::

    from repro.core import CCSpec, ScenarioSpec
    from repro.serve.whatif import CCQueryEngine, WhatIfQuery

    eng = CCQueryEngine()
    r = eng.ask(WhatIfQuery(cfg=CCSpec(reaction="erp"),
                            scenario=ScenarioSpec.incast(4),
                            n_steps=4000))
    print(r.result.summary(), eng.metrics())

The synchronous surface is unchanged: ``submit`` admits + enqueues,
``drain`` executes everything queued in micro-batches, ``ask`` is
submit-then-drain for one query — that path is bitwise untouched.  Two
opt-in extensions ride on top:

  * ``CCQueryEngine(auto_drain=True)`` runs ``drain`` on a background
    thread woken by ``submit``, so callers enqueue and ``wait(ticket)``
    instead of owning the serve loop.  ``close()`` (or the context
    manager) shuts the thread down cleanly after finishing in-flight
    work; all public methods are thread-safe either way.
  * ``EngineConfig.fleet_threshold`` delegates oversized micro-batches
    (roofline estimate >= the threshold, in seconds) to ``repro.fleet``
    — the batch streams device→host in bounded memory instead of
    holding the whole trace device-resident.  Padding inertness keeps
    the per-query slices bitwise identical to the inline path
    (``QueryResult.via_fleet`` flags which road a query took).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from typing import Callable

import numpy as np

from repro.core import SWEEP_EXEC_CACHE, Sweep, pad_scenario, trim_final
from repro.core.experiments import ScenarioSpec
from repro.core.params import CCConfig, CCSpec
from repro.core.simulator import SimResult, _resolve_steps

from .admission import (AdmissionConfig, AdmissionController, Admitted,
                        QueueFull, Throttled)
from .metrics import EngineMetrics

__all__ = ["CCQueryEngine", "EngineConfig", "QueryResult",
           "StructuralSignature", "WhatIfQuery", "flow_bucket"]


# ---------------------------------------------------------------------------
# queries and results
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WhatIfQuery:
    """One what-if question: a CC config on a workload, for N steps.

    ``scenario`` must be a declarative ``ScenarioSpec`` (the engine
    builds + pads it; raw ``Scenario`` tensors have no stable identity
    to key the executable cache by).  ``tenant`` keys the front-door
    token bucket — the noisy neighbour throttles alone.
    """

    cfg: "CCConfig | CCSpec"
    scenario: ScenarioSpec
    n_steps: int | None = None
    trace_every: int | None = None
    tenant: str = "default"
    label: str = ""

    def __post_init__(self):
        if not isinstance(self.scenario, ScenarioSpec):
            raise TypeError(
                f"WhatIfQuery.scenario must be a ScenarioSpec, got "
                f"{type(self.scenario).__name__}; wrap raw tensors in a "
                f"spec (e.g. ScenarioSpec.flows(pairs, fabric=...))")


@dataclasses.dataclass(frozen=True)
class StructuralSignature:
    """What must match for two queries to share one executable.

    Fabric structure (link/switch/hop-slot counts, K candidate paths),
    the *bucketed* flow count, resolved trace settings and the engine's
    static execution knobs.  Everything else — CC params, routes,
    rates, timing — is traced data and swaps freely at run time.
    """

    fabric: str                   # FabricSpec.name (display; also keys
    #   H_MAX/L so distinct families never alias)
    links: int
    hops: int                     # H_MAX of the route table
    paths: int                    # K candidate paths
    switches: int
    flows: int                    # bucketed flow count
    n_samples: int
    trace_every: int
    dt: float
    sim_trace_every: int          # cfg.sim value (Sweep rejects mixes)
    link_key: tuple               # (line_rate, propagation_delay, mtu)
    width: int                    # padded run-axis width
    reduce: str
    dense_rows: int
    use_kernels: bool
    interpret: bool


def flow_bucket(n_flows: int, minimum: int = 4) -> int:
    """Next power-of-two bucket >= n_flows (floor ``minimum``) — the
    pad-to-bucket that keeps the flow axis off the compile key."""
    b = max(int(minimum), 1)
    while b < n_flows:
        b *= 2
    return b


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine knobs (all part of the structural signature).

    ``dense_rows`` pins the dense-CSR row count so the executable key
    cannot depend on batch *content* (the auto heuristic reads link
    skew); 0 — the default — is the segment-sum path, bit-identical to
    dense (PR-4 parity suites).  Operators who know their pod's skew
    can set it explicitly for the dense-tile speedup.
    """

    max_batch: int = 8
    admission: AdmissionConfig = dataclasses.field(
        default_factory=AdmissionConfig)
    reduce: str = "fused"
    use_kernels: bool = False
    interpret: bool = False
    dense_rows: int = 0
    min_flow_bucket: int = 4
    max_results: int = 1024       # completed results retained for poll
    #: roofline seconds above which a micro-batch is delegated to the
    #: fleet (streamed, bounded host memory); None = always inline.
    fleet_threshold: float | None = None
    fleet_workers: int = 2        # threads for delegated batches

    @property
    def width(self) -> int:
        """Micro-batch width: the vmap run-axis pad target (bounded by
        the admission layer's in-flight cap)."""
        return min(self.max_batch, self.admission.max_inflight)


@dataclasses.dataclass
class QueryResult:
    """One answered what-if query plus its serving telemetry."""

    ticket: int
    label: str
    tenant: str
    result: SimResult             # trimmed to the query's true flows
    latency_s: float              # submit -> answer
    queue_wait_s: float           # submit -> batch launch
    exec_s: float                 # the micro-batch's launch wall time
    batch_size: int               # real queries in the batch
    batch_width: int              # padded run-axis width
    compiled: bool                # this batch paid an executable build
    via_fleet: bool = False       # delegated to repro.fleet (streamed)

    def to_dict(self, *, traces: bool = False) -> dict:
        """Wire-ready dict: telemetry + headline summary; pass
        ``traces=True`` to inline the full ``SimResult`` payload."""
        out = {"ticket": self.ticket, "label": self.label,
               "tenant": self.tenant,
               "latency_s": round(self.latency_s, 6),
               "queue_wait_s": round(self.queue_wait_s, 6),
               "exec_s": round(self.exec_s, 6),
               "batch_size": self.batch_size,
               "batch_width": self.batch_width,
               "compiled": self.compiled,
               "via_fleet": self.via_fleet,
               "summary": self.result.summary()}
        if traces:
            out["result"] = self.result.to_dict()
        return out


@dataclasses.dataclass
class _Pending:
    ticket: int
    query: WhatIfQuery
    scenario: object              # built (true-F) Scenario
    padded: object                # bucket-padded Scenario
    true_flows: int
    sig: StructuralSignature
    min_delay_slots: int
    t_submit: float


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class CCQueryEngine:
    """Persistent what-if evaluation service over the Sweep machinery.

    See the module docstring for the layer map.  The executable cache
    is the process-wide ``repro.core.SWEEP_EXEC_CACHE`` (shared with
    plain ``Sweep.run`` callers — a sweep warmed offline serves
    queries warm); the engine snapshots its stats at construction so
    ``metrics()`` reports this engine's window only.
    """

    def __init__(self, config: EngineConfig | None = None, *,
                 auto_drain: bool = False,
                 clock: Callable[[], float] = time.monotonic):
        self.config = config or EngineConfig()
        self._clock = clock
        self._admission = AdmissionController(self.config.admission,
                                              clock=clock)
        self._queue: deque[_Pending] = deque()
        self._results: "OrderedDict[int, QueryResult]" = OrderedDict()
        self._metrics = EngineMetrics()
        self._cache_base = SWEEP_EXEC_CACHE.stats()
        self._next_ticket = 0
        self._signatures: set[StructuralSignature] = set()
        # engine state lock (queue/results/metrics) + a condition that
        # signals both "work arrived" (drain loop) and "result landed"
        # (wait); a separate lock serialises drains so a user-called
        # drain() and the background loop never interleave batches.
        self._lock = threading.RLock()
        self._wake = threading.Condition(self._lock)
        self._drain_lock = threading.Lock()
        self._closed = False
        self._drainer: threading.Thread | None = None
        self.auto_drain = bool(auto_drain)
        if self.auto_drain:
            self._drainer = threading.Thread(
                target=self._drain_loop, name="whatif-drain", daemon=True)
            self._drainer.start()

    # -- signature ----------------------------------------------------------

    def _prepare(self, query: WhatIfQuery) -> _Pending:
        """Build + bucket-pad the scenario and derive its signature."""
        cfg = query.cfg
        scn = query.scenario.build(cfg)
        F, H = scn.routes.shape
        L = int(scn.capacity.shape[0])
        K = 1 if scn.alt_routes is None else int(scn.alt_routes.shape[1])
        Fb = flow_bucket(F, self.config.min_flow_bucket)
        padded = pad_scenario(scn, Fb, H, L) if Fb > F else scn
        n_samples, k = _resolve_steps(cfg, query.n_steps,
                                      query.trace_every)
        link = cfg.link
        sig = StructuralSignature(
            fabric=query.scenario._fabric().name, links=L, hops=H,
            paths=K, switches=int(scn.n_switches), flows=Fb,
            n_samples=n_samples, trace_every=k, dt=float(cfg.sim.dt),
            sim_trace_every=int(cfg.sim.trace_every),
            link_key=(float(link.line_rate),
                      float(link.propagation_delay), float(link.mtu)),
            width=self.config.width, reduce=self.config.reduce,
            dense_rows=self.config.dense_rows,
            use_kernels=self.config.use_kernels,
            interpret=self.config.interpret)
        # delay-line floor from the signature's worst case (a flow
        # using every hop slot), so batch mix can't move the compiled
        # ring depth: matches ScenarioSpec.build's rtt quantisation
        per_hop = link.propagation_delay + link.mtu / link.line_rate
        rtt = 2 * H * per_hop + 1e-6
        d_min = int(max(2, np.round(rtt / cfg.sim.dt))) + 1
        return _Pending(ticket=-1, query=query, scenario=scn,
                        padded=padded, true_flows=F, sig=sig,
                        min_delay_slots=d_min, t_submit=0.0)

    # -- front door ---------------------------------------------------------

    def submit(self, query: WhatIfQuery):
        """Admit + enqueue one query.

        Returns :class:`Admitted` (with the result ticket), or the
        explicit back-pressure outcomes :class:`Throttled` /
        :class:`QueueFull` — the caller decides whether to retry.
        """
        pending = self._prepare(query)      # validates before charging
        with self._lock:
            if self._closed:
                raise RuntimeError("CCQueryEngine is closed")
            outcome = self._admission.admit(query.tenant,
                                            len(self._queue))
            if outcome is not None:
                return outcome
            ticket = self._next_ticket
            self._next_ticket += 1
            pending.ticket = ticket
            pending.t_submit = self._clock()
            self._queue.append(pending)
            self._signatures.add(pending.sig)
            self._wake.notify_all()
            return Admitted(ticket=ticket, tenant=query.tenant,
                            queue_depth=len(self._queue))

    def drain(self) -> list[QueryResult]:
        """Serve the whole queue as signature-grouped micro-batches
        (FIFO: each batch groups the head's signature).  Device
        execution runs outside the state lock, so submitters are never
        blocked behind a batch."""
        done: list[QueryResult] = []
        with self._drain_lock:
            while True:
                with self._lock:
                    if not self._queue:
                        break
                    head_sig = self._queue[0].sig
                    width = self.config.width
                    group: list[_Pending] = []
                    rest: deque[_Pending] = deque()
                    for p in self._queue:
                        if p.sig == head_sig and len(group) < width:
                            group.append(p)
                        else:
                            rest.append(p)
                    self._queue = rest
                batch = self._execute(group, width)
                with self._lock:
                    for qr in batch:
                        self._results[qr.ticket] = qr
                        while len(self._results) > \
                                self.config.max_results:
                            self._results.popitem(last=False)
                    self._wake.notify_all()
                done.extend(batch)
        return done

    def ask(self, query: WhatIfQuery):
        """submit + drain for one query: a ``QueryResult`` if admitted,
        else the ``Throttled`` / ``QueueFull`` outcome.  NOTE: drains
        previously queued queries too (they're answered, retrievable
        via :meth:`result`).  With ``auto_drain`` the background thread
        owns the loop and this waits for the answer instead."""
        outcome = self.submit(query)
        if not isinstance(outcome, Admitted):
            return outcome
        if self.auto_drain:
            return self.wait(outcome.ticket)
        self.drain()
        return self.result(outcome.ticket)

    def result(self, ticket: int) -> QueryResult | None:
        """A completed query's result (None while still queued)."""
        with self._lock:
            return self._results.get(ticket)

    def wait(self, ticket: int,
             timeout: float | None = None) -> QueryResult | None:
        """Block until ``ticket``'s result lands (None on timeout, or
        if the engine closes before serving it)."""
        deadline = None if timeout is None else \
            time.monotonic() + timeout
        with self._wake:
            while ticket not in self._results:
                if self._closed and self._drainer is None:
                    return self._results.get(ticket)
                left = None if deadline is None else \
                    deadline - time.monotonic()
                if left is not None and left <= 0:
                    return None
                self._wake.wait(0.1 if left is None else min(left, 0.1))
            return self._results[ticket]

    # -- background drain / lifecycle ---------------------------------------

    def _drain_loop(self) -> None:
        while True:
            with self._wake:
                while not self._queue and not self._closed:
                    self._wake.wait(0.1)
                if self._closed and not self._queue:
                    return
            self.drain()

    def close(self, *, drain: bool = True) -> None:
        """Shut down cleanly: stop admitting, optionally serve what is
        already queued, and join the background drain thread."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            if not drain:
                self._queue.clear()
            self._wake.notify_all()
        th = self._drainer
        if th is not None:
            th.join()
            self._drainer = None
        elif drain:
            self.drain()
        with self._wake:
            self._wake.notify_all()

    def __enter__(self) -> "CCQueryEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- execution ----------------------------------------------------------

    def _execute(self, group: list[_Pending],
                 width: int) -> list[QueryResult]:
        head = group[0]
        q0 = head.query
        t0 = self._clock()
        before = SWEEP_EXEC_CACHE.stats()
        sweep = Sweep([(f"q{p.ticket}", p.query.cfg, p.padded)
                       for p in group])
        kw = dict(n_steps=q0.n_steps, trace_every=q0.trace_every,
                  reduce=self.config.reduce,
                  use_kernels=self.config.use_kernels,
                  interpret=self.config.interpret,
                  min_delay_slots=max(p.min_delay_slots for p in group),
                  dense_rows=self.config.dense_rows)
        via_fleet = self._oversized(group)
        if via_fleet:
            # fleet road: streamed device->host in bounded memory; the
            # per-query slices are bitwise the inline path's (padding
            # is inert; gated in tests/test_whatif_engine.py)
            from repro.fleet import FleetConfig, run_fleet
            out = run_fleet(
                sweep,
                config=FleetConfig(n_workers=self.config.fleet_workers,
                                   max_points=max(1, width // 2)),
                **kw)
            res = out.result
        else:
            res = sweep.run(pad_runs_to=width, **kw)
        t1 = self._clock()
        delta = SWEEP_EXEC_CACHE.stats() - before
        exec_s = t1 - t0
        out = []
        with self._lock:
            self._metrics.record_batch(len(group), width, exec_s)
            for p in group:
                sim = self._trim(res[f"q{p.ticket}"], p)
                latency = t1 - p.t_submit
                wait = t0 - p.t_submit
                self._metrics.latency.record(latency)
                self._metrics.queue_wait.record(wait)
                out.append(QueryResult(
                    ticket=p.ticket, label=p.query.label or q0.label,
                    tenant=p.query.tenant, result=sim,
                    latency_s=latency, queue_wait_s=wait, exec_s=exec_s,
                    batch_size=len(group), batch_width=width,
                    compiled=delta.misses > 0, via_fleet=via_fleet))
        return out

    def _oversized(self, group: list[_Pending]) -> bool:
        """Roofline estimate of the batch vs ``fleet_threshold``."""
        thr = self.config.fleet_threshold
        if thr is None:
            return False
        from repro.fleet.plan import estimate_point_cost
        sig = group[0].sig
        steps = sig.n_samples * sig.trace_every
        est = sum(estimate_point_cost(p.padded, steps) for p in group)
        return est >= thr

    @staticmethod
    def _trim(sim: SimResult, p: _Pending) -> SimResult:
        """Bucket-padded point view -> the query's true flow count."""
        F = p.true_flows
        if sim.delivered.shape[1] == F:
            return dataclasses.replace(sim, scn=p.scenario)
        return dataclasses.replace(
            sim, scn=p.scenario,
            delivered=sim.delivered[:, :F], rate=sim.rate[:, :F],
            inst_thr=sim.inst_thr[:, :F], marked=sim.marked[:, :F],
            cnp=sim.cnp[:, :F], ctrl=sim.ctrl[:, :F],
            final=trim_final(sim.final, F))

    # -- observability ------------------------------------------------------

    def metrics(self) -> dict:
        """The serving metrics dict: query/batch counters, latency
        percentiles, batch occupancy, executable-cache hit rate and the
        compile/run split — everything ``BENCH_serve.json`` records."""
        with self._lock:
            out = self._metrics.to_dict(
                cache_stats=SWEEP_EXEC_CACHE.stats() - self._cache_base,
                admission=self._admission.counters())
            out["queue_depth"] = len(self._queue)
            out["signatures"] = len(self._signatures)
        out["batch_width"] = self.config.width
        return out
