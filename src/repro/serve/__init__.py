"""repro.serve — serving layers.

  * engine: batched KV-cache token serving (continuous batching)
  * whatif: the CC simulator as a throttled, cache-warm query service
"""

from .engine import ServeConfig, ServingEngine, make_serve_step
from . import whatif

__all__ = ["ServeConfig", "ServingEngine", "make_serve_step", "whatif"]
