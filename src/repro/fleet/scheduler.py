"""Queue-based load-leveling coordinator for fleet shards.

One :class:`Backend` protocol, two implementations:

  * :class:`ThreadBackend` — single-host worker threads over per-worker
    deques.  Shards are dealt by longest-processing-time on the plan's
    roofline costs; an idle worker STEALS from the busiest remaining
    deque's tail, so ragged grids level out at runtime instead of
    waiting on the slowest static assignment.  (Python threads are a
    real execution axis here: shard wall time is device compute, which
    releases the GIL inside XLA.)
  * :class:`DistributedBackend` — ``jax.distributed`` processes sharing
    a :class:`~repro.fleet.resume.FleetJournal`.  Ownership is an
    O_EXCL claim file per shard digest (claim-race = cross-process work
    stealing), completion is the journal's atomic ckpt commit, and the
    coordinator (process 0) reclaims stale claims from dead workers.

Failure model — a lost worker never silently drops grid points:

  * every shard ends in an explicit terminal outcome: :class:`Done`
    (first try), :class:`Retried` (succeeded after >= 1 failure, the
    errors attached) or :class:`Abandoned` (failed ``max_retries`` + 1
    times, the errors attached);
  * worker loss (:class:`WorkerLost` — raised by a fault hook in tests,
    or by a backend detecting a dead peer) requeues the in-flight shard
    for the survivors and retires the worker; if every worker dies the
    coordinator abandons the remainder EXPLICITLY;
  * retries back off linearly (``backoff_s`` x attempt) and are bounded
    (``max_retries``); ``strict`` (default) raises :class:`FleetError`
    if anything was abandoned, after merging what completed.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Optional, Protocol, Sequence

from repro.core.experiments import SWEEP_EXEC_CACHE, Sweep, SweepResult
from repro.core.serialize import merge_sweepresults

from .plan import FleetPlan, ShardSpec, plan_sweep
from .resume import FleetJournal
from .stream import stream_sweep


class WorkerLost(RuntimeError):
    """The executing worker died (injected by fault hooks in tests):
    the shard is requeued for the survivors; the worker leaves the
    pool."""


class PreemptedError(RuntimeError):
    """The run was preempted (``FleetConfig.preempt_after`` chaos knob):
    completed shards are journaled; resume with the same plan+journal."""


class FleetError(RuntimeError):
    """Strict-mode failure: one or more shards were abandoned."""


# -- terminal outcomes ------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Done:
    """Shard completed on the first attempt (or straight from the
    journal: ``resumed=True``, zero recompute)."""

    shard: int
    digest: str
    attempts: int
    worker: int                    # -1: journal resume / remote process
    wall_s: float
    resumed: bool = False


@dataclasses.dataclass(frozen=True)
class Retried:
    """Shard completed after >= 1 failed attempt (errors attached)."""

    shard: int
    digest: str
    attempts: int
    worker: int
    wall_s: float
    errors: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Abandoned:
    """Shard failed every allowed attempt — its grid points are NOT in
    the merged result, and strict mode raises on it."""

    shard: int
    digest: str
    attempts: int
    errors: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Knobs for one fleet run (planning + scheduling + streaming)."""

    n_workers: int = 2
    n_shards: int | None = None      # default: ~4 points per shard
    max_points: int | None = None    # alternative sizing: points/shard
    bucket_by: str = "envelope"
    stream: bool = True              # per-window device->host streaming
    buffer_windows: int = 2
    max_retries: int = 2
    backoff_s: float = 0.02
    strict: bool = True              # raise FleetError on any Abandoned
    preempt_after: int | None = None   # kill the run after N commits
    claim_timeout_s: float = 300.0   # distributed: stale-claim reclaim
    poll_s: float = 0.2              # distributed: coordinator poll
    timeout_s: float = 900.0         # distributed: coordinator wait cap


@dataclasses.dataclass
class FleetStats:
    n_shards: int = 0
    executed: int = 0               # shards actually run here
    resumed: int = 0                # shards loaded from the journal
    stolen: int = 0                 # work-steal events (threads)
    retries: int = 0                # failed attempts that were retried
    abandoned: int = 0
    compiles: int = 0               # SWEEP_EXEC_CACHE misses this run
    wall_s: float = 0.0
    exec_s: float = 0.0             # sum of per-shard execution walls

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class FleetResult:
    """The merged grid result + per-shard accounting."""

    result: SweepResult | None      # None: non-coordinator process, or
    outcomes: dict[int, object]     # nothing completed
    stats: FleetStats
    plan: FleetPlan

    @property
    def abandoned(self) -> list[Abandoned]:
        return [o for o in self.outcomes.values()
                if isinstance(o, Abandoned)]


#: run_fn(shard) -> SweepResult; on_result(shard, result, outcome) ->
#: False to stop scheduling (preemption), anything else to continue.
RunFn = Callable[[ShardSpec], SweepResult]
OnResult = Callable[[ShardSpec, SweepResult, object], Optional[bool]]
FaultHook = Callable[[ShardSpec, int, int], None]


class Backend(Protocol):
    """A shard-execution substrate: runs every shard to a terminal
    outcome (or stops early when ``on_result`` returns False)."""

    name: str

    def execute(self, shards: Sequence[ShardSpec], run_fn: RunFn,
                on_result: OnResult, config: FleetConfig,
                fault_hook: FaultHook | None = None,
                ) -> tuple[dict[int, object], dict]:
        ...


# -- single-host threads ----------------------------------------------------


class ThreadBackend:
    """Worker threads + per-worker deques + tail stealing."""

    name = "threads"

    def __init__(self, n_workers: int = 2):
        self.n_workers = max(1, int(n_workers))

    def execute(self, shards, run_fn, on_result, config,
                fault_hook=None):
        W = self.n_workers
        cv = threading.Condition()
        deques = [collections.deque() for _ in range(W)]
        loads = [0.0] * W
        # LPT deal: heaviest shard to the lightest deque
        for s in sorted(shards, key=lambda s: (-s.cost, s.index)):
            w = min(range(W), key=lambda j: (loads[j], j))
            deques[w].append(s)
            loads[w] += s.cost
        outcomes: dict[int, object] = {}
        attempts = {s.index: 0 for s in shards}
        errors = {s.index: [] for s in shards}
        remaining = [len(shards)]
        stop = [False]
        stolen = [0]
        retries = [0]
        exec_s = [0.0]

        def worker(w: int) -> None:
            while True:
                with cv:
                    task = None
                    while task is None:
                        if remaining[0] <= 0 or stop[0]:
                            return
                        if deques[w]:
                            task = deques[w].popleft()
                        else:
                            busy = [j for j in range(W)
                                    if j != w and deques[j]]
                            if busy:     # steal the busiest tail
                                j = max(busy, key=lambda j: (
                                    sum(s.cost for s in deques[j]), -j))
                                task = deques[j].pop()
                                stolen[0] += 1
                            else:        # others may still requeue
                                cv.wait(0.02)
                    attempts[task.index] += 1
                    a = attempts[task.index]
                t0 = time.perf_counter()
                try:
                    if fault_hook is not None:
                        fault_hook(task, a, w)
                    res = run_fn(task)
                except WorkerLost as e:
                    with cv:
                        errors[task.index].append(repr(e))
                        retries[0] += 1
                        deques[w].appendleft(task)   # survivors steal it
                        cv.notify_all()
                    return               # this worker is gone
                except Exception as e:   # noqa: BLE001 — bounded retry
                    with cv:
                        errors[task.index].append(repr(e))
                        gone = a > config.max_retries
                        if gone:
                            outcomes[task.index] = Abandoned(
                                task.index, task.digest, a,
                                tuple(errors[task.index]))
                            remaining[0] -= 1
                        else:
                            retries[0] += 1
                        cv.notify_all()
                    if not gone:
                        time.sleep(config.backoff_s * a)
                        with cv:
                            deques[w].append(task)
                            cv.notify_all()
                else:
                    wall = time.perf_counter() - t0
                    with cv:
                        errs = tuple(errors[task.index])
                        out = (Retried(task.index, task.digest, a, w,
                                       wall, errs) if errs else
                               Done(task.index, task.digest, a, w, wall))
                        outcomes[task.index] = out
                        remaining[0] -= 1
                        exec_s[0] += wall
                        cv.notify_all()
                    if on_result(task, res, out) is False:
                        with cv:
                            stop[0] = True
                            cv.notify_all()

        threads = [threading.Thread(target=worker, args=(w,),
                                    name=f"fleet-worker-{w}", daemon=True)
                   for w in range(W)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        # every worker died with shards left: abandon them EXPLICITLY
        if not stop[0]:
            with cv:
                for dq in deques:
                    while dq:
                        task = dq.popleft()
                        outcomes[task.index] = Abandoned(
                            task.index, task.digest,
                            attempts[task.index],
                            tuple(errors[task.index])
                            or ("all workers lost",))
                        remaining[0] -= 1
        return outcomes, {"stolen": stolen[0], "retries": retries[0],
                          "exec_s": exec_s[0],
                          "preempted": stop[0]}


# -- multi-process (jax.distributed) ----------------------------------------


class DistributedBackend:
    """``jax.distributed`` processes levelling one queue via the journal.

    Every process walks the shard list (own LPT stride first, then
    everyone else's — the claim race IS the work stealing) and runs
    what it can claim; completion is the journal's atomic commit.  The
    coordinator (process 0) then waits for full coverage, reclaiming
    claims older than ``claim_timeout_s`` from dead workers and running
    them locally, so a lost process delays but never drops points.
    Requires a journal (the shared substrate); see ``repro.dist.procs``
    for process bootstrap.
    """

    name = "distributed"

    def __init__(self, journal: FleetJournal):
        self.journal = journal

    def execute(self, shards, run_fn, on_result, config,
                fault_hook=None):
        from repro.dist.procs import process_info
        pid, nproc = process_info()
        me = f"proc{pid}"
        jr = self.journal
        outcomes: dict[int, object] = {}
        stats = {"stolen": 0, "retries": 0, "exec_s": 0.0,
                 "preempted": False}
        order = sorted(shards, key=lambda s: (-s.cost, s.index))
        mine = order[pid::nproc]
        theirs = [s for s in order if s not in mine]

        def attempt(task: ShardSpec, stolen_claim: bool = False) -> bool:
            """Claimed: run to an outcome.  True = stop requested."""
            fails = jr.failures(task.digest)
            a = fails + 1
            if a > config.max_retries + 1:
                outcomes[task.index] = Abandoned(
                    task.index, task.digest, fails,
                    (f"{fails} failures on record",))
                jr.release(task.digest)
                return False
            t0 = time.perf_counter()
            try:
                if fault_hook is not None:
                    fault_hook(task, a, pid)
                res = run_fn(task)
            except Exception as e:   # noqa: BLE001 — bounded retry
                jr.record_failure(task.digest, repr(e))
                jr.release(task.digest)
                stats["retries"] += 1
                time.sleep(config.backoff_s * a)
                return False
            wall = time.perf_counter() - t0
            stats["exec_s"] += wall
            out = (Done(task.index, task.digest, a, pid, wall)
                   if fails == 0 else
                   Retried(task.index, task.digest, a, pid, wall,
                           (f"{fails} prior failures on record",)))
            outcomes[task.index] = out
            stop = on_result(task, res, out) is False
            jr.release(task.digest)
            if stolen_claim:
                stats["stolen"] += 1
            return stop

        stopped = False
        for rounds in range(config.max_retries + 1):
            progressed = False
            for task in mine + theirs:
                if stopped or jr.is_complete(task.digest):
                    continue
                if jr.claim(task.digest, me):
                    stopped = attempt(task, stolen_claim=task in theirs)
                    progressed = True
            if stopped or not progressed:
                break
        stats["preempted"] = stopped

        if pid == 0 and not stopped:
            # coordinator: wait out the stragglers, reclaim the dead
            deadline = time.monotonic() + config.timeout_s
            while time.monotonic() < deadline:
                done = jr.completed()
                left = [s for s in shards if s.digest not in done]
                if not left:
                    break
                for task in left:
                    age = jr.claim_age(task.digest)
                    fails = jr.failures(task.digest)
                    if fails > config.max_retries:
                        continue          # abandoned below
                    if age is None:
                        if jr.claim(task.digest, me):
                            stopped = attempt(task)
                    elif age > config.claim_timeout_s:
                        jr.steal_claim(task.digest, me)
                        stats["stolen"] += 1
                        stopped = attempt(task, stolen_claim=True)
                    if stopped:
                        break
                if stopped:
                    break
                if all(jr.failures(s.digest) > config.max_retries
                       for s in left):
                    break
                time.sleep(config.poll_s)
            done = jr.completed()
            for task in shards:
                if task.index in outcomes or task.digest in done:
                    continue
                fails = jr.failures(task.digest)
                outcomes[task.index] = Abandoned(
                    task.index, task.digest, fails,
                    (f"not completed by any process "
                     f"({fails} failures on record)",))
        return outcomes, stats


# -- coordinator ------------------------------------------------------------


class FleetRunner:
    """Plan in, merged ``SweepResult`` out — resilient in between.

    Resume-skips journaled shards (zero recompute), drives the backend
    over the rest, journals every completion, and merges the per-shard
    results in plan-point order so the output is bitwise the
    uninterrupted one-launch ``Sweep.run()``.
    """

    def __init__(self, plan: FleetPlan,
                 config: FleetConfig | None = None, *,
                 backend: Backend | None = None,
                 journal: "FleetJournal | str | None" = None,
                 fault_hook: FaultHook | None = None):
        self.plan = plan
        self.config = config or FleetConfig()
        if isinstance(journal, str):
            journal = FleetJournal(journal)
        self.journal = journal
        if journal is not None:
            journal.bind(plan)
        if backend is None:
            backend = ThreadBackend(self.config.n_workers)
        if isinstance(backend, DistributedBackend) and journal is None:
            raise ValueError("DistributedBackend needs a journal: it is "
                             "the shared claim/completion substrate")
        self.backend = backend
        self.fault_hook = fault_hook

    def _execute_shard(self, shard: ShardSpec) -> SweepResult:
        sub = self.plan.shard_sweep(shard)
        kw = self.plan.run_kwargs(shard)
        if not self.config.stream:
            return sub.run(**kw)
        spill = (self.journal.spill_dir(shard.digest)
                 if self.journal is not None else None)
        return stream_sweep(
            sub, spill_dir=spill,
            buffer_windows=self.config.buffer_windows, **kw)

    def run(self) -> FleetResult:
        cfg = self.config
        t0 = time.perf_counter()
        misses0 = SWEEP_EXEC_CACHE.stats().misses
        results: dict[int, SweepResult] = {}
        outcomes: dict[int, object] = {}
        stats = FleetStats(n_shards=len(self.plan.shards))

        todo = []
        for s in self.plan.shards:
            if self.journal is not None and \
                    self.journal.is_complete(s.digest):
                results[s.index] = self.journal.load_shard(self.plan, s)
                outcomes[s.index] = Done(s.index, s.digest, 0, -1, 0.0,
                                         resumed=True)
                stats.resumed += 1
            else:
                todo.append(s)

        lock = threading.Lock()
        committed = [stats.resumed]
        preempted = [False]

        def on_result(shard, res, out) -> bool:
            with lock:
                results[shard.index] = res
                if self.journal is not None:
                    spill = (self.journal.spill_dir(shard.digest)
                             if cfg.stream else None)
                    self.journal.save_shard(shard, res, spill=spill)
                committed[0] += 1
                if cfg.preempt_after is not None and \
                        committed[0] >= cfg.preempt_after:
                    preempted[0] = True
                    return False
            return True

        bstats = {}
        if todo:
            got, bstats = self.backend.execute(
                todo, self._execute_shard, on_result, cfg,
                self.fault_hook)
            outcomes.update(got)

        # distributed: shards other processes completed live in the
        # journal only — load them so the coordinator can merge
        if self.journal is not None:
            done = self.journal.completed()
            for s in self.plan.shards:
                if s.index not in results and s.digest in done:
                    results[s.index] = self.journal.load_shard(
                        self.plan, s)
                    if not isinstance(outcomes.get(s.index), Abandoned):
                        outcomes.setdefault(
                            s.index, Done(s.index, s.digest, 1, -1, 0.0))

        stats.executed = sum(
            1 for o in outcomes.values()
            if isinstance(o, (Done, Retried))
            and not getattr(o, "resumed", False) and o.worker >= 0)
        stats.stolen = int(bstats.get("stolen", 0))
        stats.retries = int(bstats.get("retries", 0))
        stats.exec_s = float(bstats.get("exec_s", 0.0))
        stats.abandoned = sum(1 for o in outcomes.values()
                              if isinstance(o, Abandoned))
        stats.compiles = SWEEP_EXEC_CACHE.stats().misses - misses0
        stats.wall_s = time.perf_counter() - t0

        if preempted[0]:
            raise PreemptedError(
                f"fleet preempted after {committed[0]} committed "
                f"shard(s); resume from the journal "
                f"({getattr(self.journal, 'directory', None)})")

        merged = None
        if results:
            have = [s for s in self.plan.shards if s.index in results]
            names = {n for s in have for n in s.names}
            pts = [p for p in self.plan.sweep.points if p.name in names]
            merged = merge_sweepresults(
                [results[s.index] for s in have], points=pts)
        out = FleetResult(result=merged, outcomes=outcomes,
                          stats=stats, plan=self.plan)
        if cfg.strict and stats.abandoned:
            bad = [f"shard {o.shard} {list(o.errors)[-1:]}"
                   for o in out.abandoned]
            raise FleetError(
                f"{stats.abandoned} shard(s) abandoned after bounded "
                f"retries: {'; '.join(bad)}")
        return out


def run_fleet(sweep: Sweep, n_steps: int | None = None,
              trace_every: int | None = None, *,
              config: FleetConfig | None = None,
              backend: Backend | None = None,
              journal: "FleetJournal | str | None" = None,
              fault_hook: FaultHook | None = None,
              plan: FleetPlan | None = None,
              **plan_kw) -> FleetResult:
    """Front door: plan (or take a plan) + schedule + merge.

    ``plan_kw`` forwards to :func:`~repro.fleet.plan.plan_sweep`
    (``reduce``, ``use_kernels``, ``min_delay_slots``, …).
    """
    config = config or FleetConfig()
    if plan is None:
        plan = plan_sweep(sweep, n_steps, trace_every,
                          n_shards=config.n_shards,
                          max_points=config.max_points,
                          bucket_by=config.bucket_by, **plan_kw)
    return FleetRunner(plan, config, backend=backend, journal=journal,
                       fault_hook=fault_hook).run()
