"""Preemptible checkpoint/resume for fleet runs.

The coordinator journals every completed shard through ``repro.ckpt``'s
atomic checkpoint layout (one committed step per shard, step id =
shard index, the shard's content digest + spill path in the manifest
extra), so a killed fleet resumes with ZERO recompute of finished
shards: on restart the runner loads each committed shard's result
bit-for-bit from the journal and only schedules the remainder.  The
journal is also the multi-process coordination substrate of the
``jax.distributed`` backend — shard ownership is an O_EXCL claim file,
failure counts are append-only markers, and completion is the ckpt
``.done`` commit, all of which survive any worker dying mid-write
(that is exactly the torn-checkpoint hardening in
``repro.ckpt.checkpoint``).

Layout::

    <dir>/plan.json                  — plan digest + shard digests
    <dir>/shards/step_<i>/…(.done)   — shard i's result (repro.ckpt)
    <dir>/claims/<digest>            — live ownership (O_EXCL create)
    <dir>/failures/<digest>.<n>      — one marker per failed attempt
    <dir>/spill/<digest>/            — raw streaming window spill
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.ckpt import committed_steps, load_checkpoint, save_checkpoint
from repro.core.fluid import FluidState
from repro.core.experiments import SweepResult
from repro.core.serialize import _SIM_TRACE_FIELDS
from repro.core.simulator import TraceSample

from .plan import FleetPlan, ShardSpec


class FleetJournal:
    """Durable record of one plan's progress, addressed by content."""

    def __init__(self, directory: str):
        self.directory = str(directory)
        self.shards_dir = os.path.join(self.directory, "shards")
        self.claims_dir = os.path.join(self.directory, "claims")
        self.failures_dir = os.path.join(self.directory, "failures")
        for d in (self.directory, self.shards_dir, self.claims_dir,
                  self.failures_dir):
            os.makedirs(d, exist_ok=True)
        self._plan_digest: str | None = None

    # -- plan binding -------------------------------------------------------

    def bind(self, plan: FleetPlan) -> None:
        """Pin the journal to one plan; a digest mismatch means the
        journal belongs to different work and must not be reused."""
        path = os.path.join(self.directory, "plan.json")
        doc = {"digest": plan.digest,
               "shards": [s.digest for s in plan.shards]}
        if os.path.exists(path):
            with open(path) as f:
                have = json.load(f)
            if have["digest"] != plan.digest:
                raise ValueError(
                    f"journal {self.directory} is bound to plan "
                    f"{have['digest'][:16]}…, not {plan.digest[:16]}… — "
                    f"refusing to mix results of different plans")
        else:
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(doc, f, indent=1)
            os.replace(tmp, path)
        self._plan_digest = plan.digest

    # -- completion ---------------------------------------------------------

    def completed(self) -> dict[str, int]:
        """{shard digest: journal step} over committed shard results."""
        out = {}
        for s in committed_steps(self.shards_dir):
            mf = os.path.join(self.shards_dir, f"step_{s:09d}",
                              "manifest.json")
            try:
                with open(mf) as f:
                    extra = json.load(f).get("extra", {})
            except (OSError, ValueError):
                continue                   # torn manifest: not complete
            d = extra.get("digest")
            if d:
                out[d] = s
        return out

    def is_complete(self, digest: str) -> bool:
        return digest in self.completed()

    def spill_dir(self, digest: str) -> str:
        return os.path.join(self.directory, "spill", digest[:32])

    def save_shard(self, shard: ShardSpec, res: SweepResult,
                   spill: str | None = None) -> str:
        """Commit one shard's result (atomic; step id = shard index)."""
        tree = {
            "times": np.asarray(res.times),
            "traces": {f: np.asarray(getattr(res.traces, f))
                       for f in _SIM_TRACE_FIELDS
                       if getattr(res.traces, f, None) is not None},
            "final": res.final,
        }
        extra = {"digest": shard.digest, "names": list(shard.names),
                 "trace_every": int(res.trace_every),
                 "spill": spill, "plan": self._plan_digest}
        return save_checkpoint(self.shards_dir, shard.index, tree, extra)

    def load_shard(self, plan: FleetPlan, shard: ShardSpec) -> SweepResult:
        """Rebuild one shard's SweepResult bit-for-bit from the journal."""
        tree, extra = load_checkpoint(
            self.shards_dir, step=shard.index,
            nt_registry={"FluidState": FluidState})
        if extra.get("digest") != shard.digest:
            raise ValueError(
                f"journal step {shard.index} holds digest "
                f"{str(extra.get('digest'))[:16]}…, expected "
                f"{shard.digest[:16]}… — stale journal for this plan")
        traces = TraceSample(**{f: tree["traces"].get(f)
                                for f in TraceSample._fields})
        return SweepResult(points=plan.shard_sweep(shard).points,
                           times=np.asarray(tree["times"]),
                           traces=traces, final=tree["final"],
                           trace_every=int(extra["trace_every"]))

    # -- multi-process coordination (claims + failure counts) ---------------

    def claim(self, digest: str, owner: str) -> bool:
        """Take exclusive ownership of a shard; False if already owned."""
        path = os.path.join(self.claims_dir, digest)
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            return False
        with os.fdopen(fd, "w") as f:
            json.dump({"owner": owner, "time": time.time()}, f)
        return True

    def release(self, digest: str) -> None:
        try:
            os.remove(os.path.join(self.claims_dir, digest))
        except OSError:
            pass

    def claim_age(self, digest: str) -> float | None:
        """Seconds since the claim was (re)written; None if unclaimed."""
        try:
            return time.time() - os.path.getmtime(
                os.path.join(self.claims_dir, digest))
        except OSError:
            return None

    def steal_claim(self, digest: str, owner: str) -> bool:
        """Replace a stale claim (atomic overwrite).  In the worst race
        two stealers both run the shard — harmless: results are content
        addressed and the ckpt commit is atomic, so the bytes agree."""
        path = os.path.join(self.claims_dir, digest)
        tmp = f"{path}.steal.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"owner": owner, "time": time.time(),
                       "stolen": True}, f)
        os.replace(tmp, path)
        return True

    def record_failure(self, digest: str, error: str) -> int:
        """Append a failure marker; returns the new failure count."""
        n = self.failures(digest) + 1
        path = os.path.join(self.failures_dir, f"{digest}.{n}")
        with open(path, "w") as f:
            f.write(error[:2000])
        return n

    def failures(self, digest: str) -> int:
        n = 0
        while os.path.exists(
                os.path.join(self.failures_dir, f"{digest}.{n + 1}")):
            n += 1
        return n
