"""Deterministic decomposition of a Sweep grid into content-addressed
shards.

A :class:`FleetPlan` cuts an N-point :class:`~repro.core.experiments.
Sweep` into :class:`ShardSpec`\\ s a scheduler can execute in any order,
on any worker, any number of times, and still reassemble the exact
one-launch result:

  * **content-addressed** — every shard carries a sha256 digest over
    its points' configs + scenario tensors + the plan's static launch
    parameters, so a resume journal can recognise "this exact work is
    already done" across processes and restarts (python's randomised
    ``hash()`` never enters the digest);
  * **grouped by executable signature** — shards are bucketed by the
    structural key of ``core.exec_cache.structural_signature``: the
    plan pins the padded shape envelope (flows/hops/links/paths), the
    static switch count, delay-line depth, dense-CSR rows and the run-
    axis width per bucket, so every shard in a bucket resolves to ONE
    cached executable and each worker compiles once per bucket;
  * **cost-balanced** — ragged grids (mixed flow counts / fabrics) are
    rebalanced by the analytic HBM roofline of the fluid step (the
    same bytes-per-step model as ``benchmarks/roofline.cc_kernel_rows``
    — that harness imports :func:`fluid_step_bytes` from here), via
    greedy longest-processing-time assignment; residual raggedness is
    the scheduler's work-stealing problem.

Bitwise discipline: a shard pinned to the plan's envelope runs the
exact program the full batch would — PAD flows/links, extra delay
slots, extra switch rows and replicated pad runs are all inert by
construction — so the merged fleet result is bitwise the uninterrupted
``Sweep.run()`` (asserted in ``tests/test_fleet.py``).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import Sequence

import numpy as np

from repro.core.experiments import (Sweep, SweepPoint, batch_dense_rows,
                                    pad_scenario)
from repro.core.fluid import delay_depth
from repro.core.serialize import config_to_dict
from repro.core.simulator import _resolve_steps

#: HBM bandwidth the cost model normalises against (TPU v5e per the
#: roofline assignment).  Costs are *relative* weights for balancing —
#: only ratios matter to the planner.
HBM_BW = 819e9


def fluid_step_bytes(n_flows: int, n_paths: int, n_hops: int,
                     n_links: int, n_vcs: int = 1) -> float:
    """Analytic HBM bytes one fluid substep moves (f32 vectors).

    The fluid-reduce segment reduction runs 3 passes with (3, 3, 2)
    channels over N = F*K*H incidence rows into L*n_vcs (+1 PAD) link
    sums, and the fused per-flow CC block budgets one HBM round trip
    for its ~40 [F] state vectors.  This is the bandwidth term of the
    hot loop's roofline — the single cost model shared by the fleet
    planner and ``benchmarks/roofline.py``.
    """
    n = n_flows * n_paths * n_hops
    red = sum(c * n * 4 + n * 4 + c * (n_links * n_vcs + 1) * 4
              for c in (3, 3, 2))
    flow = 40 * n_flows * 4
    return float(red + flow)


def estimate_point_cost(scn, n_steps: int, n_vcs: int = 1) -> float:
    """Roofline seconds to advance one (padded) scenario n_steps."""
    F, H = scn.routes.shape
    K = 1 if scn.alt_routes is None else scn.alt_routes.shape[1]
    L = scn.capacity.shape[0]
    return n_steps * fluid_step_bytes(F, K, H, L, n_vcs) / HBM_BW


# ---------------------------------------------------------------------------
# content digests
# ---------------------------------------------------------------------------


def _array_digest(h, name: str, a) -> None:
    if a is None:
        h.update(f"{name}:None".encode())
        return
    a = np.asarray(a)
    h.update(f"{name}:{a.dtype.name}:{a.shape}".encode())
    h.update(np.ascontiguousarray(a).tobytes())


def point_digest(p: SweepPoint) -> str:
    """sha256 of a sweep point's full content (config + scenario)."""
    h = hashlib.sha256()
    h.update(p.name.encode())
    h.update(json.dumps(config_to_dict(p.cfg), sort_keys=True,
                        default=str).encode())
    for name, v in p.scenario._asdict().items():
        if np.ndim(v) == 0 and not isinstance(v, np.ndarray):
            h.update(f"{name}:{v!r}".encode())
        else:
            _array_digest(h, name, v)
    return h.hexdigest()


# ---------------------------------------------------------------------------
# plan dataclasses
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardBucket:
    """One structural bucket: everything that pins the executable.

    All shards of a bucket pad their scenarios to (``n_flows``,
    ``n_hops``, ``n_links``, ``n_paths``), floor the static switch
    count / delay depth / dense rows to the bucket's, and pad the run
    axis to ``width`` — so they share one entry in ``SWEEP_EXEC_CACHE``.
    """

    n_flows: int
    n_hops: int
    n_links: int
    n_paths: int
    n_switches: int
    delay_slots: int
    dense_rows: int
    width: int

    def key(self) -> tuple:
        return dataclasses.astuple(self)


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """A content-addressed unit of fleet work: a few grid points that
    execute as one (padded) sub-sweep launch."""

    index: int                      # position in FleetPlan.shards
    indices: tuple[int, ...]        # rows of the source sweep
    names: tuple[str, ...]
    bucket: int                     # row of FleetPlan.buckets
    cost: float                     # roofline seconds (relative weight)
    digest: str                     # content address (work identity)

    def __len__(self) -> int:
        return len(self.indices)


@dataclasses.dataclass
class FleetPlan:
    """The deterministic execution plan for one fleet run."""

    sweep: Sweep
    n_steps: int | None
    trace_every: int | None
    n_samples: int
    k: int                          # resolved trace_every (steps/window)
    reduce: str
    use_kernels: "bool | str"
    interpret: bool
    temperature: float
    buckets: list[ShardBucket]
    shards: list[ShardSpec]
    digest: str                     # whole-plan content address

    @property
    def total_cost(self) -> float:
        return sum(s.cost for s in self.shards)

    def shard_sweep(self, shard: ShardSpec) -> Sweep:
        """The shard's points as a Sweep, pre-padded to its bucket's
        envelope (so stacking inside ``run`` is a no-op pad)."""
        b = self.buckets[shard.bucket]
        pts = [self.sweep.points[i] for i in shard.indices]
        return Sweep([(p.name, p.cfg,
                       pad_scenario(p.scenario, b.n_flows, b.n_hops,
                                    b.n_links, n_paths=b.n_paths))
                      for p in pts])

    def run_kwargs(self, shard: ShardSpec) -> dict:
        """The exact ``Sweep.run`` kwargs that make this shard execute
        the full batch's program (one signature per bucket)."""
        b = self.buckets[shard.bucket]
        return dict(n_steps=self.n_steps, trace_every=self.trace_every,
                    reduce=self.reduce, use_kernels=self.use_kernels,
                    interpret=self.interpret,
                    temperature=self.temperature,
                    pad_runs_to=b.width,
                    min_delay_slots=b.delay_slots,
                    min_switches=b.n_switches,
                    dense_rows=b.dense_rows)

    def summary(self) -> dict:
        return {
            "digest": self.digest,
            "n_points": len(self.sweep.points),
            "n_shards": len(self.shards),
            "n_buckets": len(self.buckets),
            "total_cost_s": round(self.total_cost, 6),
            "shards": [{"index": s.index, "points": list(s.names),
                        "bucket": s.bucket,
                        "cost_s": round(s.cost, 6),
                        "digest": s.digest[:16]}
                       for s in self.shards],
        }


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------


def _lpt_split(indices: list[int], costs: list[float],
               n_shards: int) -> list[list[int]]:
    """Greedy longest-processing-time balance into n_shards bins.

    Deterministic: stable sort by (cost desc, index asc), ties on bin
    load break toward the lowest bin id.
    """
    order = sorted(range(len(indices)),
                   key=lambda i: (-costs[i], indices[i]))
    bins: list[list[int]] = [[] for _ in range(n_shards)]
    loads = [0.0] * n_shards
    for i in order:
        b = min(range(n_shards), key=lambda j: (loads[j], j))
        bins[b].append(indices[i])
        loads[b] += costs[i]
    # keep source order inside a shard (merge order never depends on it,
    # but determinism is easier to eyeball) and drop empty bins
    return [sorted(b) for b in bins if b]


def plan_sweep(sweep: Sweep, n_steps: int | None = None,
               trace_every: int | None = None, *,
               n_shards: int | None = None,
               max_points: int | None = None,
               bucket_by: str = "envelope",
               reduce: str = "fused", use_kernels: "bool | str" = False,
               interpret: bool = False, temperature: float = 0.0,
               min_delay_slots: int | None = None,
               dense_rows: int | None = None) -> FleetPlan:
    """Cut a sweep into a deterministic, content-addressed FleetPlan.

    ``n_shards`` / ``max_points`` size the decomposition (default: one
    shard per ~4 points); ``bucket_by`` picks the structural grouping:

      * ``"envelope"`` (default) — ONE bucket padded to the global
        shape envelope: every shard shares one executable signature
        and the merged result is bitwise the single ``Sweep.run()``
        launch of the whole grid (the acceptance contract);
      * ``"fabric"`` — bucket by (hops, links, paths, switches): each
        fabric family compiles its own (smaller) program — cheaper per
        step for very ragged grids, still bitwise per point, but the
        executable count is the bucket count.

    ``min_delay_slots`` / ``dense_rows`` floor the corresponding
    static knobs across every bucket (the what-if engine pins these so
    fleet-delegated queries share the serving path's signature).
    """
    pts = sweep.points
    cfg0 = pts[0].cfg
    n_samples, k = _resolve_steps(cfg0, n_steps, trace_every)
    total_steps = n_samples * k
    if bucket_by == "envelope":
        groups = {(): list(range(len(pts)))}
    elif bucket_by == "fabric":
        groups = {}
        for i, p in enumerate(pts):
            s = p.scenario
            K = 1 if s.alt_routes is None else s.alt_routes.shape[1]
            key = (s.routes.shape[1], s.capacity.shape[0], K,
                   s.n_switches)
            groups.setdefault(key, []).append(i)
    else:
        raise ValueError(f"bucket_by must be 'envelope' or 'fabric', "
                         f"got {bucket_by!r}")
    if n_shards is None:
        per = 4 if max_points is None else max(1, int(max_points))
        n_shards = max(1, math.ceil(len(pts) / per))
    n_shards = min(int(n_shards), len(pts))

    # per-group envelope + per-point costs (at the padded shape: cost
    # models the program the shard actually runs, not the ragged input)
    env = {}
    group_cost = {}
    for key, idxs in groups.items():
        scns = [pts[i].scenario for i in idxs]
        F = max(s.routes.shape[0] for s in scns)
        H = max(s.routes.shape[1] for s in scns)
        L = max(s.capacity.shape[0] for s in scns)
        K = max(1 if s.alt_routes is None else s.alt_routes.shape[1]
                for s in scns)
        n_sw = max(s.n_switches for s in scns)
        padded = [pad_scenario(s, F, H, L, n_paths=K) for s in scns]
        D = max(delay_depth(s) for s in padded)
        if min_delay_slots is not None:
            D = max(D, int(min_delay_slots))
        dr = batch_dense_rows(padded, sweep.n_vcs, reduce, dense_rows)
        c = estimate_point_cost(padded[0], total_steps, sweep.n_vcs)
        env[key] = (F, H, L, K, n_sw, D, dr)
        group_cost[key] = c * len(idxs)

    # allocate shard counts proportional to group cost (>= 1 each),
    # then LPT-balance each group's points into its shards
    total = sum(group_cost.values()) or 1.0
    buckets: list[ShardBucket] = []
    shards: list[ShardSpec] = []
    plan_h = hashlib.sha256()
    plan_static = {
        "n_samples": n_samples, "k": k, "dt": float(cfg0.sim.dt),
        "n_vcs": sweep.n_vcs, "reduce": reduce,
        "use_kernels": str(use_kernels), "interpret": bool(interpret),
        "temperature": float(temperature), "bucket_by": bucket_by,
    }
    plan_h.update(json.dumps(plan_static, sort_keys=True).encode())
    digests = [point_digest(p) for p in pts]
    remaining = n_shards
    keys = sorted(groups, key=lambda key: (-group_cost[key], key))
    for gi, key in enumerate(keys):
        idxs = groups[key]
        left = len(keys) - gi - 1
        want = max(1, round(n_shards * group_cost[key] / total))
        g_shards = min(len(idxs), max(1, min(want, remaining - left)))
        remaining -= g_shards
        F, H, L, K, n_sw, D, dr = env[key]
        c1 = group_cost[key] / len(idxs)
        parts = _lpt_split(idxs, [c1] * len(idxs), g_shards)
        width = max(len(p) for p in parts)
        b = ShardBucket(n_flows=F, n_hops=H, n_links=L, n_paths=K,
                        n_switches=n_sw, delay_slots=D, dense_rows=dr,
                        width=width)
        buckets.append(b)
        for part in parts:
            h = hashlib.sha256()
            h.update(json.dumps(plan_static, sort_keys=True).encode())
            h.update(repr(b.key()).encode())
            for i in part:
                h.update(digests[i].encode())
            shards.append(ShardSpec(
                index=len(shards), indices=tuple(part),
                names=tuple(pts[i].name for i in part),
                bucket=len(buckets) - 1, cost=c1 * len(part),
                digest=h.hexdigest()))
    for s in shards:
        plan_h.update(s.digest.encode())
    return FleetPlan(sweep=sweep, n_steps=n_steps,
                     trace_every=trace_every, n_samples=n_samples, k=k,
                     reduce=reduce, use_kernels=use_kernels,
                     interpret=interpret, temperature=temperature,
                     buckets=buckets, shards=shards,
                     digest=plan_h.hexdigest())
