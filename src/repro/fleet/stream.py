"""Double-buffered async device→host trace streaming.

``Sweep.run`` keeps the whole decimated trace device-resident until the
scan returns — at pod scale that is the memory ceiling, and a preempted
run loses everything.  ``stream_sweep`` runs the SAME staged batch one
trace window at a time (the executable is the sweep scan with the outer
scan depth pinned to 1 — every other static knob, and therefore the
whole numeric body, is identical), handing each window's device arrays
to a background spiller thread that ``jax.device_get``\\ s them into
per-field ``.npy`` spill files while the device advances the next
window.  The bounded hand-off queue (``buffer_windows`` deep, default
2) is the double buffer: at most that many windows are ever in flight,
so host memory stays O(window), not O(trace).

Reassembly transposes the spill ([T, R, ...]) into the [R, T, ...]
layout of ``SweepResult`` exactly like ``Sweep.run`` does — the result
is **bitwise identical** to the in-memory launch (asserted over every
trace field and the final state in ``tests/test_fleet.py``).
"""

from __future__ import annotations

import os
import queue
import shutil
import tempfile
import threading

import jax
import numpy as np

from repro.core.experiments import (Sweep, SweepResult, _sweep_executable)
from repro.core.simulator import TraceSample


class _Spill:
    """Per-field [T, ...] spill files under one directory."""

    def __init__(self, directory: str, n_samples: int):
        self.directory = directory
        self.n_samples = n_samples
        self._mm: dict[str, np.memmap] = {}
        os.makedirs(directory, exist_ok=True)

    def write(self, t: int, window: dict) -> None:
        for f, v in window.items():
            if v is None:
                continue
            mm = self._mm.get(f)
            if mm is None:
                mm = np.lib.format.open_memmap(
                    os.path.join(self.directory, f"{f}.npy"), mode="w+",
                    dtype=v.dtype, shape=(self.n_samples,) + v.shape[1:])
                self._mm[f] = mm
            mm[t] = v[0]              # the window's single sample row

    def arrays(self) -> dict[str, np.ndarray]:
        for mm in self._mm.values():
            mm.flush()
        return {f: np.asarray(mm) for f, mm in self._mm.items()}


def stream_sweep(sweep: Sweep, n_steps: int | None = None,
                 trace_every: int | None = None, *,
                 spill_dir: str | None = None,
                 buffer_windows: int = 2,
                 reduce: str = "fused", use_kernels: "bool | str" = False,
                 interpret: bool = False,
                 pad_runs_to: int | None = None,
                 min_delay_slots: int | None = None,
                 dense_rows: int | None = None,
                 temperature: float = 0.0,
                 min_switches: int | None = None) -> SweepResult:
    """``Sweep.run`` with per-window device→host trace streaming.

    Accepts ``Sweep.run``'s knobs (minus ``mesh`` — stream one host's
    shard; the fleet scheduler is the multi-host axis).  ``spill_dir``
    keeps the raw window spill on disk (the fleet journal points
    there); ``None`` spills to a temp dir deleted after reassembly.
    ``buffer_windows`` bounds the windows in flight (the double
    buffer); the producer blocks when the spiller falls behind, so
    streaming can throttle but never drop or reorder a window.
    """
    if buffer_windows < 1:
        raise ValueError(f"buffer_windows must be >= 1: {buffer_windows}")
    static, args, n_samples, k = sweep._prepare(
        n_steps, trace_every, mesh=None, reduce=reduce,
        use_kernels=use_kernels, interpret=interpret,
        pad_runs_to=pad_runs_to, min_delay_slots=min_delay_slots,
        dense_rows=dense_rows, temperature=temperature,
        min_switches=min_switches)
    st, sd_b, par_b = args
    # the window program: the same scan, outer depth 1.  Everything
    # numeric (inner substep scan, reduction engine, kernel tier) is
    # bit-identical to the full-depth program; only the trace stacking
    # depth changes, so T windows chain to the full run exactly.
    exec_fn = _sweep_executable((1,) + static[1:], args)

    tmp = tempfile.mkdtemp(prefix="sweep_spill_") if spill_dir is None \
        else spill_dir
    spill = _Spill(tmp, n_samples)
    q: "queue.Queue" = queue.Queue(maxsize=buffer_windows)
    err: list[BaseException] = []

    def spiller():
        while True:
            item = q.get()
            if item is None:
                return
            t, window = item
            try:
                host = jax.device_get(window)
                spill.write(t, dict(zip(TraceSample._fields, host)))
            except BaseException as e:     # surfaced after the loop
                err.append(e)
                return

    def put(item) -> bool:
        """Bounded put that bails out if the spiller died (a dead
        consumer must never deadlock the producer on a full queue)."""
        while not err:
            try:
                q.put(item, timeout=0.1)   # blocks at the buffer bound
                return True
            except queue.Full:
                continue
        return False

    th = threading.Thread(target=spiller, name="trace-spiller",
                          daemon=True)
    th.start()
    try:
        for t in range(n_samples):
            st, tr = exec_fn(st, sd_b, par_b)
            if not put((t, tuple(tr))):
                break                      # spiller died: stop producing
    finally:
        put(None)
        th.join()
    if err:
        if spill_dir is None:
            shutil.rmtree(tmp, ignore_errors=True)
        raise err[0]

    R = len(sweep.points)
    arrays = spill.arrays()
    traces = TraceSample(**{
        f: (np.moveaxis(arrays[f], 0, 1)[:R] if f in arrays else None)
        for f in TraceSample._fields})
    final = jax.tree.map(lambda x: np.asarray(x)[:R], jax.device_get(st))
    dt = sweep.points[0].cfg.sim.dt
    times = (np.arange(n_samples) + 1) * k * dt
    if spill_dir is None:
        shutil.rmtree(tmp, ignore_errors=True)
    return SweepResult(points=sweep.points, times=times, traces=traces,
                       final=final, trace_every=k)
