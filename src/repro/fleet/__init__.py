"""repro.fleet — pod-scale sweep fabric.

Decomposes a parameter ``Sweep`` into content-addressed, compile-
signature-bucketed shards (:mod:`~repro.fleet.plan`), schedules them
over a work-stealing backend — single-host threads or
``jax.distributed`` processes (:mod:`~repro.fleet.scheduler`) — streams
each shard's traces device→host through a double buffer
(:mod:`~repro.fleet.stream`), and journals completions through
``repro.ckpt`` so a preempted fleet resumes with zero recompute
(:mod:`~repro.fleet.resume`).  The merged result is bitwise identical
to the uninterrupted single-host ``Sweep.run()``.

Quickstart::

    from repro.fleet import FleetConfig, run_fleet
    out = run_fleet(sweep, n_steps=2000, trace_every=100,
                    config=FleetConfig(n_workers=4),
                    journal="/tmp/fleet_journal")
    res = out.result            # a plain SweepResult
"""

from .plan import (FleetPlan, ShardBucket, ShardSpec, estimate_point_cost,
                   fluid_step_bytes, plan_sweep, point_digest)
from .resume import FleetJournal
from .scheduler import (Abandoned, Backend, DistributedBackend, Done,
                        FleetConfig, FleetError, FleetResult, FleetRunner,
                        FleetStats, PreemptedError, Retried, ThreadBackend,
                        WorkerLost, run_fleet)
from .stream import stream_sweep

__all__ = [
    "Abandoned", "Backend", "DistributedBackend", "Done", "FleetConfig",
    "FleetError", "FleetJournal", "FleetPlan", "FleetResult",
    "FleetRunner", "FleetStats", "PreemptedError", "Retried",
    "ShardBucket", "ShardSpec", "ThreadBackend", "WorkerLost",
    "estimate_point_cost", "fluid_step_bytes", "plan_sweep",
    "point_digest", "run_fleet", "stream_sweep",
]
