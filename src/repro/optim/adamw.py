"""AdamW from scratch, sharding-aware.

Optimizer moments inherit each param's logical sharding (ZeRO-style: the
FSDP axis shards them 16-way, tensor axis another 16-way), and the fp32
master copy is optional (bf16 training keeps masters; fp32 training
reuses params).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    use_master: bool = True       # fp32 master weights for bf16 params


class OptState(NamedTuple):
    step: jax.Array               # [] int32
    mu: Any                       # first moment  (fp32)
    nu: Any                       # second moment (fp32)
    master: Any                   # fp32 master params or None


def adamw_init(params, cfg: AdamWConfig) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if cfg.use_master else None)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros), master=master)


def opt_state_specs(param_spec_tree, cfg: AdamWConfig):
    """Logical-dims tree for the optimizer state (mirrors params)."""
    leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    copy = jax.tree.map(lambda d: tuple(d), param_spec_tree, is_leaf=leaf)
    return OptState(step=(), mu=copy,
                    nu=jax.tree.map(lambda d: tuple(d), param_spec_tree,
                                    is_leaf=leaf),
                    master=copy if cfg.use_master else None)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def adamw_update(grads, opt: OptState, params, cfg: AdamWConfig,
                 lr: Optional[jax.Array] = None):
    """Returns (new_params, new_opt, metrics)."""
    step = opt.step + 1
    lr_t = cfg.lr if lr is None else lr
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p, pm):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / bc1
        vhat = v / bc2
        base = (pm if pm is not None else p.astype(jnp.float32))
        new = base - lr_t * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                             + cfg.weight_decay * base)
        return new.astype(p.dtype), m, v, new

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(opt.mu)
    flat_v = jax.tree.leaves(opt.nu)
    flat_p = jax.tree.leaves(params)
    flat_pm = (jax.tree.leaves(opt.master) if opt.master is not None
               else [None] * len(flat_p))
    outs = [upd(g, m, v, p, pm) for g, m, v, p, pm in
            zip(flat_g, flat_m, flat_v, flat_p, flat_pm)]
    new_params = tdef.unflatten([o[0] for o in outs])
    new_mu = tdef.unflatten([o[1] for o in outs])
    new_nu = tdef.unflatten([o[2] for o in outs])
    new_master = (tdef.unflatten([o[3] for o in outs])
                  if opt.master is not None else None)
    new_opt = OptState(step=step, mu=new_mu, nu=new_nu, master=new_master)
    return new_params, new_opt, {"grad_norm": gnorm,
                                 "lr": jnp.asarray(lr_t, jnp.float32)}
