"""repro.optim — AdamW, schedules, gradient clipping & compression."""

from .adamw import (AdamWConfig, OptState, adamw_init, adamw_update,
                    opt_state_specs, global_norm, clip_by_global_norm)
from .schedule import cosine_schedule
from .compress import (compress_int8, decompress_int8, ef_compress_update,
                       EFState, ef_init)

__all__ = [
    "AdamWConfig", "OptState", "adamw_init", "adamw_update",
    "opt_state_specs", "global_norm", "clip_by_global_norm",
    "cosine_schedule", "compress_int8", "decompress_int8",
    "ef_compress_update", "EFState", "ef_init",
]
