"""Int8 gradient compression with error feedback.

Used on the cross-pod (DCN) axis: gradients are quantised to int8 with a
per-tensor scale before the pod all-reduce, and the quantisation residual
is carried into the next step (error feedback keeps convergence —
tests/test_optim.py verifies the EF accumulator bounds the bias).

4x byte reduction on exactly the axis the paper's CC pacer manages; the
co-sim benchmark quantifies both together.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any                 # same tree as grads, fp32


def ef_init(grads_like) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compress_int8(x: jax.Array):
    """-> (int8 values, f32 scale). Symmetric per-tensor quantisation."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_update(grads, ef: EFState):
    """Quantise (grads + residual); return (dequantised grads, new EF).

    The dequantised value is what enters the cross-pod reduction; the
    residual keeps what quantisation lost.
    """
    def one(g, r):
        tot = g.astype(jnp.float32) + r
        q, s = compress_int8(tot)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), tot - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in outs]),
            EFState(residual=tdef.unflatten([o[1] for o in outs])))
