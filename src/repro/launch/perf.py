import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ first lines, same contract as dryrun.py (512 placeholder devices).

"""§Perf hillclimb driver: re-lower a dry-run cell with optimization
overrides and report the roofline-term deltas vs the recorded baseline.

  python -m repro.launch.perf --arch falcon-mamba-7b --shape train_4k \
      --tag chunk256 --set ssm_chunk=256 --set loss_chunk=512

Writes artifacts/perf/<arch>__<shape>__<tag>.json and prints a
before/after table (baseline read from artifacts/dryrun/pod16x16)."""

import argparse
import dataclasses
import json
import time

import jax

from ..configs import ARCHS, SHAPES, get_config
from .dryrun import _compile_cell, _with_groups, collective_stats
from .mesh import make_production_mesh

PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9


def _apply_overrides(cfg, sets: list[str]):
    for s in sets:
        key, val = s.split("=", 1)
        for cast in (int, float):
            try:
                val = cast(val)
                break
            except ValueError:
                continue
        if "." in key:
            sec, leaf = key.split(".", 1)
            cfg = dataclasses.replace(
                cfg, **{sec: dataclasses.replace(
                    getattr(cfg, sec), **{leaf: val})})
        else:
            cfg = dataclasses.replace(cfg, **{key: val})
    return cfg


def _terms(rec):
    return {
        "compute_s": rec["flops_total"] / PEAK_FLOPS,
        "memory_s": rec["bytes_accessed_total"] / HBM_BW,
        "collective_s": rec["collective_bytes_total"] / ICI_BW,
        "temp_gib": rec["memory"].get("temp_size_in_bytes", 0) / 2**30,
    }


def measure(arch: str, shape: str, sets: list[str], tag: str) -> dict:
    from ..models.transformer import layer_plan
    cfg = _apply_overrides(get_config(arch), sets)
    mesh = make_production_mesh(multi_pod=False)
    t0 = time.time()
    with jax.set_mesh(mesh):
        full = _compile_cell(cfg, shape, mesh)
        n_groups = (cfg.n_layers if cfg.encdec is not None
                    else layer_plan(cfg)[2])
        if n_groups > 4:
            c2 = _compile_cell(_with_groups(cfg, 2), shape, mesh)
            c4 = _compile_cell(_with_groups(cfg, 4), shape, mesh)

            def scale(f2, f4):
                per = max(0.0, (f4 - f2) / 2.0)
                return max(0.0, f2 - 2 * per) + per * n_groups

            full["flops_total"] = scale(c2["flops"], c4["flops"])
            full["bytes_accessed_total"] = scale(
                c2["bytes_accessed"], c4["bytes_accessed"])
            full["collective_bytes_total"] = scale(
                c2["collectives"]["total_bytes"],
                c4["collectives"]["total_bytes"])
        else:
            full["flops_total"] = full["flops"]
            full["bytes_accessed_total"] = full["bytes_accessed"]
            full["collective_bytes_total"] = \
                full["collectives"]["total_bytes"]
    rec = dict(full)
    rec.update({"arch": arch, "shape": shape, "tag": tag,
                "overrides": sets,
                "compile_s": round(time.time() - t0, 1)})
    os.makedirs("artifacts/perf", exist_ok=True)
    path = f"artifacts/perf/{arch}__{shape}__{tag}.json"
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", default=[])
    args = ap.parse_args()

    base_path = f"artifacts/dryrun/pod16x16/{args.arch}__{args.shape}.json"
    base = json.load(open(base_path)) if os.path.exists(base_path) else None

    rec = measure(args.arch, args.shape, args.set, args.tag)
    new = _terms(rec)
    print(f"\n{args.arch} x {args.shape}  [{args.tag}]  "
          f"overrides={args.set}")
    if base:
        old = _terms(base)
        for k in new:
            delta = (new[k] / old[k] - 1) * 100 if old[k] else float("nan")
            print(f"  {k:14s} {old[k]:12.4g} -> {new[k]:12.4g}  "
                  f"({delta:+.1f}%)")
    else:
        for k, v in new.items():
            print(f"  {k:14s} {v:12.4g}")


if __name__ == "__main__":
    main()
