"""Production train launcher.

    PYTHONPATH=src python -m repro.launch.train --arch <id> \
        [--smoke] [--steps N] [--ckpt-dir DIR] [--compress] [--microbatches M]

On this CPU container, use --smoke (reduced config).  On a real fleet the
same entrypoint builds the production mesh, shards TrainState with the
logical rules, and runs the fault-tolerant loop; the cross-pod gradient
axis is ERP-paced + int8-EF compressed when --compress is set.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_config, get_smoke_config
from ..data import DataConfig
from ..models import encdec, transformer, vlm
from ..models.layers import init_params
from ..optim import AdamWConfig
from ..train.loop import TrainLoopConfig, train_loop
from ..train.step import StepConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    if cfg.encdec is not None or cfg.vlm is not None:
        raise SystemExit(
            "train.py drives decoder-only LMs; use examples/ for "
            "whisper/internvl training (their loss_fns are wired in "
            "repro.train.step.model_loss).")
    print(f"training {cfg.name}{' (smoke)' if args.smoke else ''}: "
          f"{cfg.n_layers}L d{cfg.d_model} "
          f"~{cfg.param_count()/1e6:.1f}M params on "
          f"{len(jax.devices())} device(s)")

    params = init_params(transformer.param_defs(cfg), 0, jnp.float32)
    sc = StepConfig(opt=AdamWConfig(lr=args.lr),
                    microbatches=args.microbatches,
                    compress_grads=args.compress,
                    warmup_steps=max(args.steps // 20, 5),
                    total_steps=args.steps)
    state = init_train_state(cfg, params, sc)
    step = jax.jit(make_train_step(cfg, sc))
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.global_batch, kind="markov")
    loop = TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                           log_every=args.log_every)

    out = train_loop(step, state, data, loop,
                     on_metrics=lambda s, m: print(
                         f"step {s:5d} loss {float(m['loss']):.4f} "
                         f"({m['step_time']*1e3:.0f} ms)"))
    print(f"final loss {out['losses'][-1]:.4f} after "
          f"{out['final_step']} steps; "
          f"mean step {out['mean_step_time']*1e3:.0f} ms; "
          f"stragglers {out['stragglers']}")


if __name__ == "__main__":
    main()
