"""Production meshes.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — required because
only dryrun.py forces 512 host devices while tests/benches see one CPU.

Axes:
  * pod   — 2  (cross-pod DCN axis; ERP-paced collectives live here)
  * data  — 16 (in-pod DP/FSDP)
  * model — 16 (TP/SP/EP)
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for elastic-scaling experiments and tests."""
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def describe(mesh) -> str:
    return " x ".join(f"{k}={v}" for k, v in mesh.shape.items())
