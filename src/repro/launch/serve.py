"""Serving launcher: batched continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch <id> [--smoke]
        [--requests N] [--new-tokens K]
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config, get_smoke_config
from ..models import transformer
from ..models.layers import init_params
from ..serve import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    if cfg.encdec is not None or cfg.vlm is not None:
        raise SystemExit("serve.py drives decoder-only LMs")
    params = init_params(transformer.param_defs(cfg), 0, jnp.float32)
    eng = ServingEngine(cfg, params, ServeConfig(
        batch_slots=args.slots, max_len=args.max_len))
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(2, cfg.vocab, size=5))
               for _ in range(args.requests)]
    t0 = time.perf_counter()
    outs = eng.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.perf_counter() - t0
    n = sum(len(o) for o in outs)
    print(f"{args.requests} requests -> {n} tokens in {dt:.1f}s "
          f"({n/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
