import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY other import: jax locks the
#   host device count at first backend initialisation, and the dry-run
#   needs 512 placeholder devices to build the production meshes.
#   (Set here ONLY — tests/benches must see 1 device.)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real step function (train_step for train_4k,
prefill for prefill_32k, serve/decode step for decode_32k & long_500k),
attaches the production shardings, and runs ``jit(...).lower(...).
compile()`` against pure ShapeDtypeStructs — no array is ever allocated
for the full-size configs.

Success == the distribution config is coherent: every sharding divides,
every collective is implementable, and the per-device memory fits.  The
compiled artifact's ``memory_analysis()`` / ``cost_analysis()`` plus the
collective bytes parsed from the optimised HLO are written to
``artifacts/dryrun/<mesh>/<arch>__<shape>.json`` for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import (ARCHS, SHAPES, get_config, input_specs, supports)
from ..dist.sharding import logical_sharding, pspec
from ..models import encdec, transformer, vlm
from ..models.config import ModelConfig
from ..models.layers import abstract_params, param_specs
from ..optim import AdamWConfig
from ..train.step import StepConfig, init_train_state, make_train_step
from .mesh import describe, make_production_mesh

BF16 = jnp.bfloat16

COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\b")
SHAPE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+ = \(?([a-z0-9]+)\[([\d,]*)\]")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_stats(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in the optimised HLO."""
    out: dict[str, dict] = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if m is None or "=" not in line:
            continue
        kind = m.group(1)
        sm = SHAPE_RE.match(line)
        if sm is None:
            continue
        dt, dims = sm.group(1), sm.group(2)
        nbytes = DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


# ---------------------------------------------------------------------------
# per-cell step builders (params/caches as ShapeDtypeStructs + shardings)
# ---------------------------------------------------------------------------


def _model_defs(cfg: ModelConfig):
    if cfg.encdec is not None:
        return encdec.param_defs(cfg)
    if cfg.vlm is not None:
        return vlm.param_defs(cfg)
    return transformer.param_defs(cfg)


def _sharded_abstract(tree_abs, tree_spec, mesh):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(
            a.shape, a.dtype,
            sharding=logical_sharding(tuple(s), a.shape, mesh)),
        tree_abs, tree_spec,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _abstract_inputs(cfg, shape_name, mesh):
    """Batch inputs with batch/seq shardings attached."""
    specs = input_specs(cfg, shape_name)
    out = {}
    for name, s in specs.items():
        dims = (("batch",) + (None,) * (len(s.shape) - 1))
        out[name] = jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=logical_sharding(dims, s.shape, mesh))
    return out


def build_cell(cfg: ModelConfig, shape_name: str, mesh):
    """Returns (fn, abstract_args: tuple, donate_argnums)."""
    import dataclasses
    cfg = dataclasses.replace(
        cfg, dtype="bfloat16",
        remat=cfg.remat if cfg.remat != "none" else "full")
    cell = SHAPES[shape_name]
    defs = _model_defs(cfg)
    p_abs = abstract_params(defs, BF16)
    p_spec = param_specs(defs)
    params_in = _sharded_abstract(p_abs, p_spec, mesh)
    batch = _abstract_inputs(cfg, shape_name, mesh)

    if cell.step == "train":
        sc = StepConfig(opt=AdamWConfig(use_master=True))
        step_fn = make_train_step(cfg, sc)
        state_abs = jax.eval_shape(
            lambda p: init_train_state(cfg, p, sc), p_abs)
        from ..train.step import train_state_specs
        st_spec = train_state_specs(cfg, p_spec, sc)
        # rng key: replicated
        state_in = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(
                a.shape, a.dtype,
                sharding=logical_sharding(
                    tuple(s) if isinstance(s, tuple) else
                    (None,) * len(a.shape), a.shape, mesh)),
            state_abs, st_spec,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        return step_fn, (state_in, batch), (0,)

    if cell.step == "prefill":
        if cfg.encdec is not None:
            fn = lambda p, b: encdec.prefill(
                p, cfg, b["frames"], b["tokens"], cell.seq_len)
        elif cfg.vlm is not None:
            fn = lambda p, b: vlm.prefill(
                p, cfg, b["patches"], b["tokens"], cell.seq_len)
        else:
            fn = lambda p, b: transformer.prefill(
                p, cfg, b["tokens"], cell.seq_len)
        return fn, (params_in, batch), ()

    # decode: caches as sharded abstract inputs, donated
    b = cell.global_batch
    if cfg.encdec is not None:
        caches_abs = jax.eval_shape(
            lambda: encdec.init_dec_caches(cfg, b, cell.seq_len, BF16))
        caches_in = _sharded_abstract(caches_abs,
                                      encdec.dec_cache_specs(cfg), mesh)
        enc_out = jax.ShapeDtypeStruct(
            (b, cfg.encdec.enc_seq, cfg.d_model), BF16,
            sharding=logical_sharding(("batch", None, None),
                                      (b, cfg.encdec.enc_seq, cfg.d_model),
                                      mesh))
        fn = lambda p, tok, enc, c: encdec.decode_step(
            p, cfg, tok["token"], enc, c,
            jnp.asarray(cell.seq_len - 1, jnp.int32))
        return fn, (params_in, batch, enc_out, caches_in), (3,)

    caches_abs = jax.eval_shape(
        lambda: transformer.init_caches(cfg, b, cell.seq_len, BF16))
    caches_spec = transformer.cache_specs(cfg, b, cell.seq_len)
    caches_in = _sharded_abstract(caches_abs, caches_spec, mesh)
    fn = lambda p, tok, c: transformer.decode_step(
        p, cfg, tok["token"], c, jnp.asarray(cell.seq_len - 1, jnp.int32))
    return fn, (params_in, batch, caches_in), (2,)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def _with_groups(cfg: ModelConfig, k: int) -> ModelConfig:
    """UNROLLED probe config with exactly k pattern groups.

    XLA's cost_analysis counts a while-loop (lax.scan) body once, so the
    full-size lowering under-reports flops/collectives.  Probes unroll a
    shallow stack; the (4-group - 2-group)/2 delta is the true per-group
    cost, scaled back to the full depth."""
    import dataclasses
    from ..models.transformer import layer_plan
    head, pat, n_groups, tail = layer_plan(cfg)
    if cfg.encdec is not None:   # enc-dec scans n_layers directly
        return dataclasses.replace(cfg, n_layers=k, scan_layers=False,
                                   encdec=dataclasses.replace(
                                       cfg.encdec, n_enc_layers=k))
    new_layers = len(head) + k * len(pat) + len(tail)
    return dataclasses.replace(cfg, n_layers=new_layers,
                               scan_layers=False)


def _compile_cell(cfg, shape_name, mesh, want_hlo=True):
    fn, args, donate = build_cell(cfg, shape_name, mesh)
    lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text() if want_hlo else ""
    return {
        "memory": {
            k: int(getattr(mem, k))
            for k in ("generated_code_size_in_bytes",
                      "argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes")
            if hasattr(mem, k)},
        "flops": float(cost.get("flops", -1)) if cost else -1.0,
        "bytes_accessed": float(cost.get("bytes accessed", -1))
        if cost else -1.0,
        "collectives": collective_stats(hlo),
        "hlo_bytes": len(hlo),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "artifacts/dryrun") -> dict:
    cfg = get_config(arch)
    ok, why = supports(cfg, shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "skipped": not ok, "skip_reason": why}
    if not ok:
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with jax.set_mesh(mesh):
        full = _compile_cell(cfg, shape_name, mesh)
        t_full = time.time() - t0

        # XLA's cost_analysis counts a lax.scan (while-loop) body ONCE —
        # extrapolate per-group cost from 2-group vs 4-group lowerings.
        from ..models.transformer import layer_plan
        n_groups = (cfg.n_layers if cfg.encdec is not None
                    else layer_plan(cfg)[2])
        extra = {}
        if n_groups > 4:
            c2 = _compile_cell(_with_groups(cfg, 2), shape_name, mesh)
            c4 = _compile_cell(_with_groups(cfg, 4), shape_name, mesh)

            def scale(f2, f4):
                per = max(0.0, (f4 - f2) / 2.0)
                outside = max(0.0, f2 - 2 * per)
                return outside + per * n_groups

            extra = {
                "flops_total": scale(c2["flops"], c4["flops"]),
                "bytes_accessed_total": scale(c2["bytes_accessed"],
                                              c4["bytes_accessed"]),
                "collective_bytes_total": scale(
                    c2["collectives"]["total_bytes"],
                    c4["collectives"]["total_bytes"]),
                "scan_groups": n_groups,
            }
        else:   # unrolled or shallow: raw numbers already complete
            extra = {
                "flops_total": full["flops"],
                "bytes_accessed_total": full["bytes_accessed"],
                "collective_bytes_total":
                    full["collectives"]["total_bytes"],
                "scan_groups": n_groups,
            }

    rec.update(full)
    rec.update(extra)
    rec["mesh_desc"] = describe(mesh)
    rec["compile_s"] = round(time.time() - t0, 2)
    rec["compile_full_s"] = round(t_full, 2)
    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    path = os.path.join(out_dir, mesh_name, f"{arch}__{shape_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    rec["path"] = path
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCHS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                path = os.path.join(args.out, mesh_name,
                                    f"{arch}__{shape}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip-existing] {mesh_name} {arch} {shape}")
                    continue
                tag = f"{mesh_name} {arch:18s} {shape:12s}"
                try:
                    rec = run_cell(arch, shape, multi, args.out)
                except Exception as e:   # noqa: BLE001 — report & continue
                    traceback.print_exc()
                    failures.append((tag, str(e)))
                    print(f"[FAIL] {tag}: {e}")
                    continue
                if rec.get("skipped"):
                    print(f"[skip] {tag}: {rec['skip_reason']}")
                else:
                    mem = rec["memory"]
                    print(f"[ok]   {tag} compile={rec['compile_s']:.0f}s "
                          f"flops={rec['flops_total']:.3g} "
                          f"coll={rec['collective_bytes_total']:.3g}B "
                          f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e[:200])
        raise SystemExit(1)
    print("\nDRY-RUN COMPLETE: all attempted cells compiled.")


if __name__ == "__main__":
    main()
