"""Declarative Experiment/Sweep API: one-jit batched CC evaluation.

The paper's claims are sweep-shaped — scheme x scenario x parameter
grids — but a python loop of ``run()`` calls re-jits and re-launches per
point.  This module makes the sweep itself the unit of execution:

  * ``ScenarioSpec``   — declarative description of a workload (topology
    + traffic pattern + timing/volume).  ``spec.build(cfg)`` compiles it
    to the padded ``Scenario`` tensors of the fluid model.  The legacy
    builder functions in ``scenarios.py`` are thin wrappers over specs.
  * ``pad_scenario`` / stacking — N scenarios are padded to a common
    [F_max, H_max] (and link/switch counts) so they stack into one
    batched ``ScenarioDev`` pytree.  PAD flows/links are inert by
    construction (zero demand, infinite start time).
  * ``Sweep``          — N (config, scenario) points executed under ONE
    jitted vmap-of-scan: scheme ablations, Kmin/ERP-gain grids and
    incast-degree scans are single device launches.  Traces are
    decimated on device (``trace_every``), and the delay line is sized
    from the batch's worst-case RTT instead of a fixed cap.

Quickstart::

    from repro.core import CCScheme, PAPER_CONFIG
    from repro.core.experiments import ScenarioSpec, Sweep

    sweep = Sweep.grid(
        configs={s.name: PAPER_CONFIG.replace(scheme=s) for s in CCScheme},
        scenarios={"hol": ScenarioSpec.paper_incast(roll=0),
                   "disjoint": ScenarioSpec.paper_incast(roll=1)})
    res = sweep.run()                       # ONE compile, ONE launch
    res["DCQCN_REV/hol"].mean_throughput_while_active()
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import cc
from .exec_cache import ExecutableCache, structural_signature
from .fluid import (FluidState, Scenario, check_routing_paths,
                    clamp_dense_rows, delay_depth, dense_reduce_rows,
                    fluid_step, init_state, kernel_tier, scenario_device,
                    step_body_fn, step_params)
from .params import CCConfig, CCSpec
from .routing import PAD, route_hops
from .simulator import (SimResult, _acc_update, _resolve_steps,
                        _window_sample, _zero_accum, decimating_scan)
from .topology import Topology

if TYPE_CHECKING:           # real import is lazy: repro.net imports core
    from repro.net import FabricSpec


# ---------------------------------------------------------------------------
# ScenarioSpec — declarative workload description
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Fabric + traffic pattern + timing/volume, as plain data.

    ``kind`` selects the traffic pattern:
      * ``"incast"``      — ``n_senders``-to-1 into ``dst`` (+ optional
        victim flow), the paper's §II scene when n_senders=4 on arity 4.
      * ``"permutation"`` — seeded uniform random permutation traffic.
      * ``"pairs"``       — explicit (src, dst) pairs.
      * ``"flowspec"``    — fully explicit per-flow tuples (src, dst,
        timing, volume, rate, buffer) — what the collective-workload
        generators in ``repro.core.workloads`` emit.

    ``fabric`` names the network (any ``repro.net.FabricSpec``: CLOS,
    XGFT/tapered fat-tree, dragonfly); ``None`` keeps the legacy
    3-stage CLOS of ``arity``/``roll``.  Routing is table-driven for
    every fabric — the CLOS closed form is just one table builder.

    Timing: generators open at ``t_start`` and close at ``t_stop``
    (window mode) — or carry ``volume`` bytes each and stay open until
    done (equal-work mode, ``t_stop = inf``), the variant behind the
    paper's completion-time ordering.

    ``build(cfg)`` compiles the spec to ``Scenario`` tensors; rates and
    feedback delays derive from ``cfg.link`` / ``cfg.sim``.
    """

    kind: str = "incast"
    fabric: "FabricSpec | None" = None
    arity: int = 4
    roll: int = 0                 # D-mod-K digit roll (paper wirings)
    n_senders: int = 4
    dst: int = 16
    victim: tuple[int, int] | None = (3, 12)
    pairs: tuple[tuple[int, int], ...] = ()
    n_flows: int = 16             # permutation
    seed: int = 0
    t_start: float = 1e-3
    t_stop: float = 3e-3          # inf => volume (equal-work) mode
    volume: float = float("inf")  # bytes per flow; inf = window-limited
    nic_buffer: float = 4e6
    gen_rate: float | None = None  # B/s; None = line rate
    label: str = ""
    # adaptive routing: K candidate paths per flow (slot 0 minimal,
    # 1..K-1 Valiant detours from the fabric's RouteSet).  Which
    # candidate a flow actually uses is the *config's* choice
    # (``cfg.routing`` in {min, valiant, ugal}), so one multi-path
    # scenario serves a whole routing-mode sweep axis.
    n_paths: int = 1
    route_seed: int = 0           # VLB intermediate sampling seed
    # virtual channels: how flows map onto the config's
    # ``LinkParams.n_vcs`` queues ("slot" = detours on VC 1, "hop" =
    # dateline escalation — see ``repro.core.routing.assign_vc``).
    # Ignored (all VC 0) when the config runs a single VC.
    vc_mode: str = "slot"
    # per-flow tuples (kind == "flowspec"); empty = broadcast the scalar
    flow_src: tuple[int, ...] = ()
    flow_dst: tuple[int, ...] = ()
    flow_t_start: tuple[float, ...] = ()
    flow_t_stop: tuple[float, ...] = ()
    flow_volume: tuple[float, ...] = ()
    flow_rate: tuple[float, ...] = ()          # B/s; empty = gen_rate
    flow_nic_buffer: tuple[float, ...] = ()    # B; empty = nic_buffer
    # per-flow VC pin (overrides vc_mode on every hop; clipped to the
    # config's n_vcs) and victim-flow designation for the PFC-pathology
    # metrics (``SimResult.victim_slowdown``); empty = none
    flow_vc: tuple[int, ...] = ()
    flow_victim: tuple[bool, ...] = ()

    # -- canned specs -------------------------------------------------------

    @classmethod
    def paper_incast(cls, roll: int = 0, **kw) -> "ScenarioSpec":
        """The paper's §II.A scene: F0,F1,F4,F8 -> N16 plus the victim
        F3 -> N12.  roll=0 shares the victim's wire (Fig. 3 HoL); roll=1
        is wire-disjoint (Fig. 2's 25 GB/s aggregate)."""
        return cls(kind="pairs",
                   pairs=((0, 16), (1, 16), (4, 16), (8, 16), (3, 12)),
                   roll=roll, label=kw.pop("label", f"paper-roll{roll}"),
                   flow_victim=kw.pop("flow_victim",
                                      (False,) * 4 + (True,)),
                   **kw)

    @classmethod
    def paper_incast_volume(cls, roll: int = 0,
                            volume_bytes: float = 9.375e6,
                            **kw) -> "ScenarioSpec":
        """Equal-work variant for completion-time runs (each flow carries
        the 9.375 MB a fair-shared incast source admits in 1->3 ms)."""
        return cls(kind="pairs",
                   pairs=((0, 16), (1, 16), (4, 16), (8, 16), (3, 12)),
                   roll=roll, t_stop=float("inf"), volume=volume_bytes,
                   nic_buffer=kw.pop("nic_buffer", 2 * volume_bytes),
                   label=kw.pop("label", f"paper-vol-roll{roll}"),
                   flow_victim=kw.pop("flow_victim",
                                      (False,) * 4 + (True,)),
                   **kw)

    @classmethod
    def incast(cls, n_senders: int, dst: int = 16, *, victim: bool = True,
               **kw) -> "ScenarioSpec":
        return cls(kind="incast", n_senders=n_senders, dst=dst,
                   victim=(3, 12) if victim else None,
                   label=kw.pop("label", f"incast{n_senders}"), **kw)

    @classmethod
    def permutation(cls, n_flows: int, seed: int = 0, **kw) -> "ScenarioSpec":
        kw.setdefault("t_start", 0.1e-3)
        kw.setdefault("t_stop", 2e-3)
        return cls(kind="permutation", n_flows=n_flows, seed=seed,
                   label=kw.pop("label", f"perm{n_flows}"), **kw)

    @classmethod
    def flows(cls, pairs: Sequence[tuple[int, int]], **kw) -> "ScenarioSpec":
        return cls(kind="pairs", pairs=tuple(tuple(p) for p in pairs),
                   label=kw.pop("label", f"pairs{len(pairs)}"), **kw)

    @classmethod
    def from_workload(cls, wl, fabric: "FabricSpec | None" = None,
                      **kw) -> "ScenarioSpec":
        """Compile a ``repro.core.workloads.Workload`` onto a fabric.

        The workload's per-flow (src, dst, timing, volume, rate) tuples
        become a ``"flowspec"`` spec; NIC buffers default to twice each
        flow's volume (volume mode) or the scalar ``nic_buffer``.
        """
        nic = kw.pop("flow_nic_buffer", None)
        if nic is None and any(np.isfinite(v) for v in wl.volume):
            nic = tuple(2 * v if np.isfinite(v) else kw.get(
                "nic_buffer", 4e6) for v in wl.volume)
        return cls(kind="flowspec", fabric=fabric,
                   flow_src=wl.src, flow_dst=wl.dst,
                   flow_t_start=wl.t_start, flow_t_stop=wl.t_stop,
                   flow_volume=wl.volume,
                   flow_rate=wl.rate or (),
                   flow_nic_buffer=nic or (),
                   flow_victim=kw.pop(
                       "flow_victim", getattr(wl, "victim", ()) or ()),
                   flow_vc=kw.pop(
                       "flow_vc", getattr(wl, "vc", ()) or ()),
                   label=kw.pop("label", wl.label), **kw)

    # -- compilation to tensors --------------------------------------------

    @property
    def name(self) -> str:
        return self.label or self.kind

    def _fabric(self) -> "FabricSpec":
        if self.fabric is not None:
            return self.fabric
        from repro.net import FabricSpec
        return FabricSpec.clos3(arity=self.arity, roll=self.roll)

    def _pairs(self, topo: Topology) -> list[tuple[int, int]]:
        if self.kind == "flowspec":
            if len(self.flow_src) != len(self.flow_dst):
                raise ValueError("flow_src / flow_dst length mismatch")
            return list(zip(self.flow_src, self.flow_dst))
        if self.kind == "pairs":
            return [tuple(p) for p in self.pairs]
        if self.kind == "incast":
            senders = [n for n in range(topo.n_nodes) if n != self.dst]
            out = [(s, self.dst) for s in senders[: self.n_senders]]
            if self.victim is not None:
                out.append(tuple(self.victim))
            return out
        if self.kind == "permutation":
            rng = np.random.RandomState(self.seed)
            n = topo.n_nodes
            perm = rng.permutation(n)
            srcs = rng.choice(n, size=self.n_flows,
                              replace=self.n_flows > n)
            out = []
            for s in srcs:
                d = int(perm[s % n])
                if d == s:
                    d = (d + 1) % n
                out.append((int(s), d))
            return out
        raise ValueError(f"unknown ScenarioSpec kind: {self.kind!r}")

    def _per_flow(self, field: tuple, scalar, F: int,
                  dtype=np.float32) -> np.ndarray:
        if field:
            if len(field) != F:
                raise ValueError(
                    f"per-flow tuple has {len(field)} entries for {F} flows")
            return np.asarray(field, dtype)
        return np.full((F,), scalar, dtype)

    def build(self, cfg: CCConfig) -> Scenario:
        fab = self._fabric()
        topo = fab.build(line_rate=cfg.link.line_rate)
        pairs = self._pairs(topo)
        # the general routing path: every fabric family precomputes a
        # validated per-(src,dst) table; scenarios route by lookup.
        # n_paths > 1 pulls the fabric's multi-path RouteSet instead:
        # slot 0 (minimal) fills the legacy single-path tensors, the
        # full candidate stack rides along for run-time selection.
        # flow_routes / flow_route_set are cached per (spec hash, pairs):
        # every grid point sharing a fabric reuses one extraction, and
        # the identical arrays downstream hit the device-upload and
        # incidence caches of ``scenario_device``.
        alt_routes = alt_hops = None
        if self.n_paths > 1:
            alt_routes, alt_hops = fab.flow_route_set(
                pairs, self.n_paths, seed=self.route_seed)
            routes = alt_routes[:, 0].copy()
        else:
            routes = fab.flow_routes(pairs)
        F = len(pairs)
        hops = route_hops(routes)
        # CNP feedback delay ~ 2 * hops * (prop + serialisation) + NIC
        # turnaround; quantised to dt steps, >= 2 so the loop is never
        # same-step.
        per_hop = cfg.link.propagation_delay + cfg.link.mtu / cfg.link.line_rate
        rtt = 2 * hops * per_hop + 1e-6
        rtt_steps = np.maximum(2, np.round(rtt / cfg.sim.dt)).astype(np.int32)
        rate = cfg.link.line_rate if self.gen_rate is None else self.gen_rate
        # per-flow rates: workloads are built before the config's line
        # rate is known, so inf means "line rate" and a negative entry
        # -f means "fraction f of line rate".
        rates = self._per_flow(self.flow_rate, rate, F).astype(np.float64)
        rates = np.where(np.isfinite(rates), rates, cfg.link.line_rate)
        rates = np.where(rates < 0, -rates * cfg.link.line_rate,
                         rates).astype(np.float32)
        # scalar stays scalar (host-side API compat); per-flow goes [F]
        nic = (self._per_flow(self.flow_nic_buffer, 0.0, F)
               if self.flow_nic_buffer else self.nic_buffer)
        # virtual channels: only materialised when the config runs more
        # than one, so single-VC scenarios stay byte-identical to the
        # pre-VC builds (vc=None, victim still carried for metrics)
        vc = None
        n_vcs = int(getattr(cfg.link, "n_vcs", 1))
        if n_vcs > 1:
            from .routing import assign_vc
            alt = alt_routes if alt_routes is not None \
                else routes[:, None, :]
            fv = np.asarray(self.flow_vc, np.int32) \
                if self.flow_vc else None
            vc = assign_vc(alt, n_vcs, mode=self.vc_mode, flow_vc=fv)
        victim = None
        if self.flow_victim:
            victim = self._per_flow(
                tuple(bool(v) for v in self.flow_victim), False, F,
                dtype=bool)
        elif self.kind == "incast" and self.victim is not None:
            victim = np.zeros((F,), bool)
            victim[-1] = True          # the appended victim pair
        return Scenario(
            routes=routes,
            hops=hops,
            gen_rate=rates,
            t_start=self._per_flow(self.flow_t_start, self.t_start, F),
            t_stop=self._per_flow(self.flow_t_stop, self.t_stop, F),
            volume=self._per_flow(self.flow_volume, self.volume, F),
            capacity=topo.link_capacity.astype(np.float32),
            sink_switch=topo.sink_switch(),
            n_switches=topo.n_switches,
            # feedback delay is pinned to the minimal path's RTT even for
            # multi-path scenarios: the delay line is per-flow static, and
            # a mode-dependent RTT would make routing="min" on a K-path
            # scenario diverge from the K=1 build of the same workload.
            rtt_steps=rtt_steps,
            nic_buffer=nic,
            alt_routes=alt_routes,
            alt_hops=alt_hops,
            vc=vc,
            victim=victim,
        )


# ---------------------------------------------------------------------------
# padding + stacking
# ---------------------------------------------------------------------------


def pad_scenario(scn: Scenario, n_flows: int, n_hops: int,
                 n_links: int, n_paths: int | None = None) -> Scenario:
    """Grow a scenario to [n_flows, n_hops] flows and n_links links.

    PAD flows never generate (t_start = inf, zero rate/volume) and cross
    no links; PAD links carry no flow and a nominal capacity — both are
    inert in every scatter/reduce of the step, so padding cannot change
    delivered bytes (property-tested in test_experiments).

    ``n_paths`` pads the candidate axis of multi-path scenarios; padded
    candidate slots are all-PAD with hop count 0, which the selection
    logic reads as "no such detour" (``n_alt`` counts real slots only).
    ``None`` keeps the scenario's own K (single-path stays single-path).
    """
    F, H = scn.routes.shape
    L = scn.capacity.shape[0]
    K = 1 if scn.alt_routes is None else scn.alt_routes.shape[1]
    n_paths = K if n_paths is None else n_paths
    if n_flows < F or n_hops < H or n_links < L or n_paths < K:
        raise ValueError(f"pad target ({n_flows},{n_hops},{n_links},"
                         f"{n_paths}) smaller than scenario "
                         f"({F},{H},{L},{K})")

    def pad_f(x, fill):
        return np.concatenate(
            [x, np.full((n_flows - F,) + x.shape[1:], fill, x.dtype)])

    routes = np.full((n_flows, n_hops), PAD, np.int32)
    routes[:F, :H] = scn.routes
    alt_routes = alt_hops = None
    if not (n_paths == 1 and scn.alt_routes is None):
        alt_routes = np.full((n_flows, n_paths, n_hops), PAD, np.int32)
        alt_hops = np.zeros((n_flows, n_paths), np.int32)
        if scn.alt_routes is None:
            alt_routes[:F, 0, :H] = scn.routes
            alt_hops[:F, 0] = scn.hops
        else:
            alt_routes[:F, :K, :H] = scn.alt_routes
            alt_hops[:F, :K] = scn.alt_hops
    # VC padding: PAD flows/slots ride VC 0 (forced, so the incidence
    # scratch mapping stays exact); victim padding is non-victim.
    vc = None
    if scn.vc is not None:
        Kv = scn.vc.shape[1]
        Kp = n_paths if alt_routes is not None else Kv
        vc = np.zeros((n_flows, Kp, n_hops), np.int32)
        vc[:F, :Kv, :H] = scn.vc
    victim = None if scn.victim is None \
        else pad_f(np.asarray(scn.victim, bool), False)
    return Scenario(
        routes=routes,
        hops=pad_f(scn.hops, 0),
        gen_rate=pad_f(scn.gen_rate, 0.0),
        t_start=pad_f(scn.t_start, np.inf),
        t_stop=pad_f(scn.t_stop, np.inf),
        volume=pad_f(scn.volume, 0.0),
        capacity=np.concatenate(
            [scn.capacity, np.full((n_links - L,), 1.0, np.float32)]),
        sink_switch=np.concatenate(
            [scn.sink_switch, np.full((n_links - L,), -1, np.int32)]),
        n_switches=scn.n_switches,
        rtt_steps=pad_f(scn.rtt_steps, 2),
        # per-flow buffers pad with inf (PAD flows never generate);
        # scalar buffers broadcast on device, so they pass through
        nic_buffer=pad_f(np.asarray(scn.nic_buffer, np.float32), np.inf)
        if np.ndim(scn.nic_buffer) else scn.nic_buffer,
        alt_routes=alt_routes,
        alt_hops=alt_hops,
        vc=vc,
        victim=victim,
    )


def stack_scenarios(scns: Sequence[Scenario], n_vcs: int = 1):
    """Pad to common shape and stack into one batched ScenarioDev.

    Returns (batched ScenarioDev with leading run axis, padded host
    scenarios, n_switches_max).  ``n_vcs`` must match the sweep's
    shared ``LinkParams.n_vcs`` (the batch shares one incidence
    layout, so one static VC count).
    """
    F = max(s.routes.shape[0] for s in scns)
    H = max(s.routes.shape[1] for s in scns)
    L = max(s.capacity.shape[0] for s in scns)
    K = max(1 if s.alt_routes is None else s.alt_routes.shape[1]
            for s in scns)
    n_sw = max(s.n_switches for s in scns)
    padded = [pad_scenario(s, F, H, L, n_paths=K) for s in scns]
    devs = [scenario_device(s, n_vcs=n_vcs) for s in padded]
    batched = jax.tree.map(lambda *xs: jnp.stack(xs), *devs)
    return batched, padded, n_sw


def batch_dense_rows(padded: Sequence[Scenario], n_vcs: int,
                     reduce: str = "fused",
                     dense_rows: int | None = None) -> int:
    """The dense-CSR row count one batch of padded scenarios runs with.

    The static row count must cover every run in the batch; any
    over-skew scenario disables the dense engine for the batch (0 = the
    segment-sum path, bit-identical), and the batch-wide max is
    re-clamped so one skewed run can't force the rest onto an oversized
    table.  An explicit ``dense_rows`` that cannot cover the batch also
    falls back to 0.  Shared by ``Sweep.run`` and the fleet planner so
    a shard pinned to the plan's value runs the exact program the full
    batch would.
    """
    if reduce != "fused":
        return 0
    if dense_rows is None:
        mls = [dense_reduce_rows(s, n_vcs) for s in padded]
        if 0 in mls:
            return 0
        s0 = padded[0]
        K = 1 if s0.alt_routes is None else s0.alt_routes.shape[1]
        return clamp_dense_rows(
            max(mls), s0.capacity.shape[0] * n_vcs,
            s0.routes.shape[0] * K * s0.routes.shape[1])
    if dense_rows > 0 and any(
            not 0 < dense_reduce_rows(s, n_vcs) <= dense_rows
            for s in padded):
        return 0                     # can't cover the batch: safe path
    return int(dense_rows)


# ---------------------------------------------------------------------------
# Sweep — N points, one jitted vmap-of-scan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    name: str
    cfg: CCConfig
    scenario: Scenario            # built tensors (specs compile on add)


def _replace_path(cfg: CCConfig, path: str, value) -> CCConfig:
    """dataclasses.replace through dotted paths, e.g. "dcqcn.kmin"."""
    head, _, rest = path.partition(".")
    if not rest:
        return dataclasses.replace(cfg, **{head: value})
    sub = getattr(cfg, head)
    return dataclasses.replace(
        cfg, **{head: _replace_path(sub, rest, value)})


def config_grid(cfg: CCConfig, **axes) -> dict[str, CCConfig]:
    """{"kmin=8192": cfg', ...} over the product of dotted-path axes.

    ``config_grid(cfg, **{"dcqcn.kmin": [8e3, 15e3], "rev.erp_rai": [...]})``
    """
    out = {"": cfg}
    for path, values in axes.items():
        leaf = path.rsplit(".", 1)[-1]
        nxt = {}
        for name, c in out.items():
            for v in values:
                key = f"{leaf}={v:g}" if isinstance(v, (int, float)) else \
                    f"{leaf}={v}"
                nxt[f"{name}/{key}" if name else key] = \
                    _replace_path(c, path, v)
        out = nxt
    return out


#: The sweep-executable cache: every ``Sweep.run`` resolves its compiled
#: program here, keyed by the full structural signature (static scan
#: configuration + input pytree treedef + leaf shapes/dtypes).  It is a
#: module-level singleton on purpose — the what-if serving engine
#: (``repro.serve.whatif``) snapshots its :class:`CacheStats` to report
#: hit rates and to *assert* "this query replay compiled exactly once".
SWEEP_EXEC_CACHE = ExecutableCache(capacity=32, name="sweep")


def _sweep_scan_fn(n_samples: int, trace_every: int, dt: float,
                   n_switches: int, reduce: str, dense_rows: int,
                   use_kernels: "bool | str", interpret: bool,
                   n_vcs: int, substep_block: int, mesh):
    """Build the (unjitted) sweep scan for one static configuration.

    The whole sweep is one vmap-of-(decimating)-scan.  With ``mesh`` the
    run axis is sharded over every mesh axis via ``shard_map`` — each
    device advances (and decimates the traces of) its own slice of the
    run batch, with zero cross-device communication, so a sharded sweep
    is bitwise the single-device sweep cut into ``mesh.size`` pieces.

    ``substep_block`` is the megakernel's in-kernel scan depth (0 on the
    non-mega tiers): with ``use_kernels="mega"`` the inner per-step scan
    is replaced by one vmapped whole-window ``megastep_block`` launch
    per trace sample, ``substep_block`` (= ``trace_every``) substeps
    deep, the fluid state staying kernel-resident throughout.
    """
    tier = kernel_tier(use_kernels)
    if tier == "mega":
        body = step_body_fn(dt=dt, n_switches=n_switches, reduce=reduce,
                            dense_rows=dense_rows, n_vcs=n_vcs)
        from repro.kernels.fluid_step import megastep_block

        def scan_fn(st_b, sd_b, par_b):
            def block(st):
                return jax.vmap(
                    lambda s, sd, par: megastep_block(
                        s, sd, par, body=body,
                        n_substeps=substep_block,
                        acc_init=_zero_accum, acc_update=_acc_update,
                        make_sample=_window_sample, n_vcs=n_vcs, dt=dt,
                        interpret=interpret)
                )(st, sd_b, par_b)

            return decimating_scan(None, st_b, n_samples, trace_every,
                                   dt, n_vcs, block_fn=block)
    else:
        def scan_fn(st_b, sd_b, par_b):
            # flow tier: hoist the reaction kernels' SMEM param rows out
            # of the scan — packed once per trace, reused every substep
            # (None on the other tiers: an empty pytree vmaps freely).
            packed_b = jax.vmap(
                lambda par: cc.pack_react_rows(
                    par.react, par.line_rate, jnp.float32(dt))
            )(par_b) if tier == "flow" else None

            def step(st):
                return jax.vmap(
                    lambda s, sd, par, pk: fluid_step(
                        s, sd, par, dt=dt, n_switches=n_switches,
                        reduce=reduce, dense_rows=dense_rows,
                        use_kernels=use_kernels, interpret=interpret,
                        n_vcs=n_vcs, packed_react=pk)
                )(st, sd_b, par_b, packed_b)

            return decimating_scan(step, st_b, n_samples, trace_every,
                                   dt, n_vcs)

    if mesh is None:
        return scan_fn
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    run_spec = P(tuple(mesh.axis_names))     # leading run axis sharded
    return shard_map(
        scan_fn, mesh=mesh,
        in_specs=(run_spec, run_spec, run_spec),
        # decimating_scan returns (final [R, ...], traces [T, R, ...])
        out_specs=(run_spec, P(None, *run_spec)),
        check_rep=False)


def _sweep_executable(static: tuple, args: tuple):
    """Resolve one sweep launch to a cached compiled executable.

    The cache key is the *structural signature*: the static scan
    configuration plus the input pytree's treedef and every leaf's
    shape/dtype — exactly what determines the compiled program, so a
    cache hit swaps traced data into an existing executable and a miss
    is a real compile (counted once, in ``SWEEP_EXEC_CACHE`` stats).
    Single-device launches are AOT-lowered (``jit(...).lower(args)
    .compile()``) so compile time lands in the cache's ``build_s``
    instead of smearing into the first run; the mesh-sharded path keeps
    the jitted callable (shard_map AOT is not worth the API risk here —
    serving never passes a mesh).
    """
    mesh = static[-1]

    def build():
        fn = jax.jit(_sweep_scan_fn(*static))
        if mesh is not None:
            return fn
        return fn.lower(*args).compile()

    return SWEEP_EXEC_CACHE.get_or_build(
        structural_signature(static, args), build)


class Sweep:
    """A batch of (config, scenario) points run as one device launch.

    Points come in as ``(name, cfg, scenario-or-spec)`` triples; specs
    are compiled against their point's config.  All points must agree on
    ``sim.dt`` and ``sim.trace_every`` (they share the scan); shapes are
    padded to the batch maximum.
    """

    def __init__(self, points: Sequence[tuple[str, "CCConfig | CCSpec",
                                              "ScenarioSpec | Scenario"]]):
        if not points:
            raise ValueError("empty sweep")
        self.points: list[SweepPoint] = []
        names = set()
        for name, cfg, scn in points:
            if name in names:
                raise ValueError(f"duplicate sweep point name: {name!r}")
            names.add(name)
            if isinstance(scn, ScenarioSpec):
                scn = scn.build(cfg)
            check_routing_paths(cfg, scn)
            self.points.append(SweepPoint(name, cfg, scn))
        dts = {p.cfg.sim.dt for p in self.points}
        kps = {p.cfg.sim.trace_every for p in self.points}
        if len(dts) > 1 or len(kps) > 1:
            raise ValueError(
                f"sweep points disagree on sim.dt ({dts}) or "
                f"trace_every ({kps}); they share one scan")
        vcs = {int(getattr(p.cfg.link, "n_vcs", 1)) for p in self.points}
        if len(vcs) > 1:
            raise ValueError(
                f"sweep points disagree on link.n_vcs ({sorted(vcs)}); "
                f"the VC count is a static shape parameter shared by "
                f"the whole batch — run them as separate sweeps")
        self.n_vcs = vcs.pop()

    @classmethod
    def grid(cls, configs, scenarios) -> "Sweep":
        """Cross named configs with named scenarios/specs.

        ``configs``: dict[str, CCConfig | CCSpec] (or one config);
        ``scenarios``: dict[str, ScenarioSpec | Scenario] (or one).
        Point names are "cfg/scenario" (or the sole non-dict's name).
        """
        if isinstance(configs, (CCConfig, CCSpec)):
            configs = {"": configs}
        if isinstance(scenarios, (ScenarioSpec, Scenario)):
            scenarios = {getattr(scenarios, "name", "scenario"): scenarios}
        points = []
        for cn, cfg in configs.items():
            for sn, scn in scenarios.items():
                name = f"{cn}/{sn}" if cn and sn else (cn or sn)
                points.append((name, cfg, scn))
        return cls(points)

    @property
    def names(self) -> list[str]:
        return [p.name for p in self.points]

    def subset(self, keys: Sequence["str | int"]) -> "Sweep":
        """A new Sweep over the named (or indexed) points — the grid
        slicing primitive behind shard-addressable fleet execution.
        Scenarios pass through as built tensors; order follows ``keys``.
        """
        names = self.names
        pts = []
        for key in keys:
            r = key if isinstance(key, int) else names.index(key)
            p = self.points[r]
            pts.append((p.name, p.cfg, p.scenario))
        return Sweep(pts)

    def _prepare(self, n_steps: int | None = None,
                 trace_every: int | None = None, *, mesh=None,
                 reduce: str = "fused", use_kernels: bool = False,
                 interpret: bool = False, pad_runs_to: int | None = None,
                 min_delay_slots: int | None = None,
                 dense_rows: int | None = None,
                 temperature: float = 0.0,
                 min_switches: int | None = None):
        """Stack, pad and stage the batch; returns
        ``(static, (st_b, sd_b, par_b), n_samples, k)`` — everything a
        launch needs short of resolving the executable.  Shared by
        :meth:`run` and the fleet's streaming runner
        (``repro.fleet.stream``), which swaps the scan depth in
        ``static`` for per-window execution but must otherwise stage
        the bit-identical program.
        """
        if temperature and use_kernels:
            raise ValueError(
                "temperature > 0 needs use_kernels=False: the Pallas "
                "kernel tiers implement the hard dynamics only")
        cfg0 = self.points[0].cfg
        n_samples, k = _resolve_steps(cfg0, n_steps, trace_every)
        scns = [p.scenario for p in self.points]
        sd_b, padded, n_sw = stack_scenarios(scns, n_vcs=self.n_vcs)
        if min_switches is not None:
            n_sw = max(n_sw, int(min_switches))
        D = max(delay_depth(s) for s in padded)
        if min_delay_slots is not None:
            D = max(D, int(min_delay_slots))
        st_b = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[init_state(s, p.cfg, delay_slots=D)
              for s, p in zip(padded, self.points)])
        par_b = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[step_params(p.cfg, temperature=temperature)
              for p in self.points])
        R = len(self.points)
        R_target = R if pad_runs_to is None else max(R, int(pad_runs_to))
        if mesh is not None and R_target % mesh.size:
            R_target += mesh.size - R_target % mesh.size
        if R_target > R:
            pad_r = R_target - R                 # replicate the last run
            rep = lambda x: jnp.concatenate(
                [x] + [x[-1:]] * pad_r, axis=0)
            st_b, sd_b, par_b = (jax.tree.map(rep, t)
                                 for t in (st_b, sd_b, par_b))
        dense_rows = batch_dense_rows(padded, self.n_vcs, reduce,
                                      dense_rows)
        # the substep-block depth (the megakernel's in-kernel scan
        # length) is part of the executable signature: a mega sweep
        # re-blocked at a different trace_every is a different program
        substep_block = k if kernel_tier(use_kernels) == "mega" else 0
        static = (n_samples, k, float(cfg0.sim.dt), n_sw, reduce,
                  int(dense_rows), use_kernels, interpret, self.n_vcs,
                  substep_block, mesh)
        return static, (st_b, sd_b, par_b), n_samples, k

    def run(self, n_steps: int | None = None,
            trace_every: int | None = None, *, mesh=None,
            reduce: str = "fused", use_kernels: bool = False,
            interpret: bool = False, pad_runs_to: int | None = None,
            min_delay_slots: int | None = None,
            dense_rows: int | None = None,
            temperature: float = 0.0,
            min_switches: int | None = None) -> "SweepResult":
        """Execute all points as one device launch.

        ``mesh``: a ``jax.sharding.Mesh`` (e.g. ``repro.dist.sweep_mesh()``)
        shards the run axis across its devices with ``shard_map``; the
        batch is padded to a multiple of ``mesh.size`` by replicating
        the last point (padding runs are discarded on return) and each
        shard decimates its own traces.  Results are bitwise identical
        to the single-device launch, run for run.

        ``reduce`` / ``use_kernels`` / ``interpret`` select the per-step
        reduction engine and the Pallas tier (see ``fluid_step``);
        ``use_kernels="mega"`` runs each trace window as one whole-step
        megakernel launch per run, ``trace_every`` substeps deep.

        The remaining knobs exist for serving (``repro.serve.whatif``),
        which must keep the executable-cache key stable across batches
        of varying composition; results are bitwise unaffected:
          * ``pad_runs_to`` grows the run axis to a fixed width by
            replicating the last point (discarded on return) — the
            micro-batcher's pad-to-bucket on the vmap axis;
          * ``min_delay_slots`` floors the delay-line depth (normally
            sized from the batch's worst RTT, which varies with batch
            mix; extra slots are inert by construction);
          * ``dense_rows`` overrides the dense-CSR row count (``None``
            = derive from the batch; an explicit value that cannot
            cover the batch's skew falls back to 0, the segment-sum
            path, which is bit-identical).

        ``temperature`` > 0 runs the soft-relaxed dynamics
        (``repro.tune.soft``) — smoothed marking/PFC/notification
        gates for differentiable tuning.  The default 0 is the exact
        hard model (bitwise; temperature is traced data, so both share
        one compiled executable).  Soft runs require
        ``use_kernels=False`` (the Pallas per-flow kernels implement
        the hard path only).

        ``min_switches`` floors the static switch count the scan is
        built for (normally the batch max) — the fleet planner pins it
        so every shard of a grid compiles and runs the exact program
        the full batch would; extra switch rows are inert.
        """
        static, args, n_samples, k = self._prepare(
            n_steps, trace_every, mesh=mesh, reduce=reduce,
            use_kernels=use_kernels, interpret=interpret,
            pad_runs_to=pad_runs_to, min_delay_slots=min_delay_slots,
            dense_rows=dense_rows, temperature=temperature,
            min_switches=min_switches)
        st_b, sd_b, par_b = args
        R = len(self.points)
        exec_fn = _sweep_executable(static, args)
        final, tr = exec_fn(st_b, sd_b, par_b)
        times = (np.arange(n_samples) + 1) * k * self.points[0].cfg.sim.dt
        # scan stacks samples on axis 0 -> [T, R, ...]; runs lead on host
        return SweepResult(
            points=self.points, times=times,
            traces=jax.tree.map(
                lambda x: np.moveaxis(np.asarray(x), 0, 1)[:R], tr),
            final=jax.tree.map(lambda x: np.asarray(x)[:R],
                               jax.device_get(final)),
            trace_every=k)


def trim_final(fin: FluidState, F: int) -> FluidState:
    """An (unbatched) final state trimmed back to its true flow count —
    the inverse of ``pad_scenario`` for result views (PAD flows are
    inert, so trimming loses nothing).  Used by the sweep's per-point
    views and by the what-if engine's bucket-padded query slicing."""
    flow = lambda x: x[:F]
    return FluidState(
        qh=flow(fin.qh), nicq=flow(fin.nicq), delivered=flow(fin.delivered),
        offered=flow(fin.offered), dropped=flow(fin.dropped),
        est=flow(fin.est), paused=fin.paused, rate=flow(fin.rate),
        rp_target=flow(fin.rp_target), alpha=flow(fin.alpha),
        byte_cnt=flow(fin.byte_cnt), tmr=flow(fin.tmr),
        alpha_tmr=flow(fin.alpha_tmr), bc_stage=flow(fin.bc_stage),
        t_stage=flow(fin.t_stage), hold=flow(fin.hold),
        np_tmr=flow(fin.np_tmr), trig_buf=fin.trig_buf[:, :F],
        tgt_buf=fin.tgt_buf[:, :F], path_idx=flow(fin.path_idx),
        cc={k: flow(v) for k, v in fin.cc.items()},
        t=fin.t)


def _slice_final(fin: FluidState, r: int, F: int) -> FluidState:
    """Run r's final state, trimmed back to its true flow count."""
    return trim_final(jax.tree.map(lambda x: x[r], fin), F)


@dataclasses.dataclass
class SweepResult:
    """All runs' decimated traces, indexable by point name (or index)
    into per-point ``SimResult`` views trimmed to their true flows."""

    points: list[SweepPoint]
    times: np.ndarray              # [T] window-end seconds
    traces: object                 # TraceSample of [R, T, ...] numpy
    final: object                  # FluidState with leading [R]
    trace_every: int

    @property
    def names(self) -> list[str]:
        return [p.name for p in self.points]

    def __len__(self) -> int:
        return len(self.points)

    def __contains__(self, name: str) -> bool:
        return name in self.names

    def __getitem__(self, key: "str | int") -> SimResult:
        if isinstance(key, int):
            r = key
        elif key in self.names:
            r = self.names.index(key)
        else:
            raise KeyError(f"{key!r} not in sweep; points: {self.names}")
        p = self.points[r]
        F = p.scenario.routes.shape[0]
        tr = self.traces
        return SimResult(
            cfg=p.cfg, scn=p.scenario, times=self.times,
            delivered=tr.delivered[r][:, :F],
            rate=tr.rate[r][:, :F],
            inst_thr=tr.inst_thr[r][:, :F],
            max_q=tr.max_q[r], n_paused=tr.n_paused[r],
            marked=tr.marked[r][:, :F], cnp=tr.cnp[r][:, :F],
            n_nonmin=tr.n_nonmin[r],
            final=_slice_final(self.final, r, F),
            ctrl=tr.ctrl[r][:, :F],
            trace_every=self.trace_every,
            pause_time=None if tr.pause_time is None
            else tr.pause_time[r],
            vc_stall=None if tr.vc_stall is None else tr.vc_stall[r])

    def items(self):
        for i, p in enumerate(self.points):
            yield p.name, self[i]

    def to_dict(self, *, traces: bool = True) -> dict:
        """JSON-ready dict (numpy-free scalars, tagged arrays); the
        full form round-trips bit-exactly via :meth:`from_dict` — per
        point views of the reconstruction match the original's (see
        ``repro.core.serialize``)."""
        from .serialize import sweepresult_to_dict
        return sweepresult_to_dict(self, traces=traces)

    @classmethod
    def from_dict(cls, d: dict) -> "SweepResult":
        from .serialize import sweepresult_from_dict
        return sweepresult_from_dict(d)

    def summary(self) -> dict[str, dict]:
        """Headline numbers per point (the Fig. 2/3 table in one dict)."""
        return {name: res.summary() for name, res in self.items()}
