"""Scenario builders: the paper's §II incast + synthetic DC workloads."""

from __future__ import annotations

import numpy as np

from .fluid import Scenario
from .params import CCConfig
from .routing import build_flow_routes, route_hops, validate_routes
from .topology import Topology, make_paper_clos


def _mk_scenario(topo: Topology, pairs, cfg: CCConfig, *,
                 t_start, t_stop, roll: int = 0,
                 nic_buffer: float = 4e6, arity: int = 4,
                 volume=None) -> Scenario:
    routes = build_flow_routes(topo, pairs, arity=arity, roll=roll)
    validate_routes(topo, routes)
    F = len(pairs)
    if volume is None:
        volume = np.full((F,), np.inf, np.float32)
    hops = route_hops(routes)
    # CNP feedback delay ~ 2 * hops * (prop + serialisation) + NIC turnaround;
    # quantised to dt steps, >= 2 steps so the loop is never same-step.
    per_hop = cfg.link.propagation_delay + cfg.link.mtu / cfg.link.line_rate
    rtt = 2 * hops * per_hop + 1e-6
    rtt_steps = np.maximum(2, np.round(rtt / cfg.sim.dt)).astype(np.int32)
    return Scenario(
        routes=routes,
        hops=hops,
        gen_rate=np.full((F,), cfg.link.line_rate, np.float32),
        t_start=np.asarray(t_start, np.float32),
        t_stop=np.asarray(t_stop, np.float32),
        volume=np.asarray(volume, np.float32),
        capacity=topo.link_capacity.astype(np.float32),
        sink_switch=topo.sink_switch(),
        n_switches=topo.n_switches,
        rtt_steps=rtt_steps,
        nic_buffer=nic_buffer,
    )


def paper_incast(cfg: CCConfig, roll: int = 0,
                 nic_buffer: float = 4e6) -> Scenario:
    """The paper's §II.A scenario on the 64-node CLOS.

    Flows (order matters for figures):
      0: F0 N0->N16   (congesting, shares leaf-0 uplink-0 wire)
      1: F1 N1->N16   (congesting, same wire)
      2: F4 N4->N16   (congesting)
      3: F8 N8->N16   (congesting)
      4: F3 N3->N12   (victim)

    All generators open at 1 ms and close at 3 ms at line rate.
    roll=0 reproduces the Fig. 3 narrative (victim shares the wire into
    switch 16); roll=1 the Fig. 2 aggregate (victim wire-disjoint).
    """
    topo = make_paper_clos(cfg.link.line_rate)
    pairs = [(0, 16), (1, 16), (4, 16), (8, 16), (3, 12)]
    F = len(pairs)
    return _mk_scenario(
        topo, pairs, cfg,
        t_start=np.full((F,), 1e-3), t_stop=np.full((F,), 3e-3),
        roll=roll, nic_buffer=nic_buffer)


PAPER_FLOW_NAMES = ["F0", "F1", "F4", "F8", "F3(victim)"]


def paper_incast_volume(cfg: CCConfig, roll: int = 0,
                        volume_bytes: float = 9.375e6) -> Scenario:
    """Equal-work variant of the paper scenario for completion-time runs.

    Every flow carries a fixed volume (default: the 9.375 MB a fair-shared
    incast source admits during the paper's 1->3 ms window) and sources
    stay open until done, so completion times are comparable across CC
    schemes — this is the variant behind the 4 / 6.5 / 12.5 ms ordering.
    """
    topo = make_paper_clos(cfg.link.line_rate)
    pairs = [(0, 16), (1, 16), (4, 16), (8, 16), (3, 12)]
    F = len(pairs)
    return _mk_scenario(
        topo, pairs, cfg,
        t_start=np.full((F,), 1e-3), t_stop=np.full((F,), np.inf),
        roll=roll, nic_buffer=2 * volume_bytes,
        volume=np.full((F,), volume_bytes))


def incast(cfg: CCConfig, n_senders: int, dst: int = 16, *,
           victim: bool = True, arity: int = 4, roll: int = 0,
           t_start: float = 1e-3, t_stop: float = 3e-3) -> Scenario:
    """Parametric n-to-1 incast with an optional victim flow."""
    topo = make_paper_clos(cfg.link.line_rate) if arity == 4 else None
    if topo is None:
        from .topology import make_clos3
        topo = make_clos3(arity=arity, line_rate=cfg.link.line_rate)
    n_nodes = topo.n_nodes
    senders = [n for n in range(n_nodes) if n != dst][:n_senders]
    pairs = [(s, dst) for s in senders]
    if victim:
        pairs.append((3, 12))
    F = len(pairs)
    return _mk_scenario(
        topo, pairs, cfg,
        t_start=np.full((F,), t_start), t_stop=np.full((F,), t_stop),
        roll=roll, arity=arity)


def random_permutation(cfg: CCConfig, n_flows: int, seed: int = 0, *,
                       arity: int = 4, t_start: float = 0.1e-3,
                       t_stop: float = 2e-3) -> Scenario:
    """Uniform random permutation traffic (DC-scale stress)."""
    from .topology import make_clos3
    topo = make_clos3(arity=arity, line_rate=cfg.link.line_rate)
    rng = np.random.RandomState(seed)
    n = topo.n_nodes
    perm = rng.permutation(n)
    srcs = rng.choice(n, size=n_flows, replace=n_flows > n)
    pairs = []
    for s in srcs:
        d = int(perm[s % n])
        if d == s:
            d = (d + 1) % n
        pairs.append((int(s), d))
    F = len(pairs)
    return _mk_scenario(
        topo, pairs, cfg,
        t_start=np.full((F,), t_start), t_stop=np.full((F,), t_stop),
        arity=arity)


def collective_flows(cfg: CCConfig, pairs: list[tuple[int, int]],
                     bytes_per_flow: float, *, arity: int = 4,
                     t_start: float = 0.0) -> Scenario:
    """Flows carrying a fixed volume (for co-simulating training traffic).

    The generator window is sized so a line-rate source would emit exactly
    ``bytes_per_flow``; completion under each CC scheme is then the
    collective's finish time on the modelled fabric.
    """
    from .topology import make_clos3
    topo = make_clos3(arity=arity, line_rate=cfg.link.line_rate)
    F = len(pairs)
    return _mk_scenario(
        topo, pairs, cfg,
        t_start=np.full((F,), t_start),
        t_stop=np.full((F,), np.inf),
        arity=arity, nic_buffer=2 * bytes_per_flow,
        volume=np.full((F,), bytes_per_flow))
