"""Legacy scenario builders — thin wrappers over ``ScenarioSpec``.

The declarative ``repro.core.experiments.ScenarioSpec`` is the public
entrypoint (it composes with ``Sweep`` for one-jit batched evaluation);
these functions survive as conveniences for single-run callers and keep
the seed API stable.  Each is ``ScenarioSpec.<ctor>(...).build(cfg)``.
"""

from __future__ import annotations

import numpy as np

from .experiments import ScenarioSpec
from .fluid import Scenario
from .params import CCConfig

PAPER_FLOW_NAMES = ["F0", "F1", "F4", "F8", "F3(victim)"]


def paper_incast(cfg: CCConfig, roll: int = 0,
                 nic_buffer: float = 4e6) -> Scenario:
    """The paper's §II.A scenario on the 64-node CLOS.

    Flows (order matters for figures):
      0: F0 N0->N16   (congesting, shares leaf-0 uplink-0 wire)
      1: F1 N1->N16   (congesting, same wire)
      2: F4 N4->N16   (congesting)
      3: F8 N8->N16   (congesting)
      4: F3 N3->N12   (victim)

    All generators open at 1 ms and close at 3 ms at line rate.
    roll=0 reproduces the Fig. 3 narrative (victim shares the wire into
    switch 16); roll=1 the Fig. 2 aggregate (victim wire-disjoint).
    """
    return ScenarioSpec.paper_incast(
        roll=roll, nic_buffer=nic_buffer).build(cfg)


def paper_incast_volume(cfg: CCConfig, roll: int = 0,
                        volume_bytes: float = 9.375e6) -> Scenario:
    """Equal-work variant of the paper scenario for completion-time runs.

    Every flow carries a fixed volume (default: the 9.375 MB a fair-shared
    incast source admits during the paper's 1->3 ms window) and sources
    stay open until done, so completion times are comparable across CC
    schemes — this is the variant behind the 4 / 6.5 / 12.5 ms ordering.
    """
    return ScenarioSpec.paper_incast_volume(
        roll=roll, volume_bytes=volume_bytes).build(cfg)


def incast(cfg: CCConfig, n_senders: int, dst: int = 16, *,
           victim: bool = True, arity: int = 4, roll: int = 0,
           t_start: float = 1e-3, t_stop: float = 3e-3) -> Scenario:
    """Parametric n-to-1 incast with an optional victim flow."""
    return ScenarioSpec.incast(
        n_senders, dst, victim=victim, arity=arity, roll=roll,
        t_start=t_start, t_stop=t_stop).build(cfg)


def random_permutation(cfg: CCConfig, n_flows: int, seed: int = 0, *,
                       arity: int = 4, t_start: float = 0.1e-3,
                       t_stop: float = 2e-3) -> Scenario:
    """Uniform random permutation traffic (DC-scale stress)."""
    return ScenarioSpec.permutation(
        n_flows, seed, arity=arity, t_start=t_start,
        t_stop=t_stop).build(cfg)


def collective_flows(cfg: CCConfig, pairs: list[tuple[int, int]],
                     bytes_per_flow: float, *, arity: int = 4,
                     t_start: float = 0.0) -> Scenario:
    """Flows carrying a fixed volume (for co-simulating training traffic).

    Completion under each CC scheme is then the collective's finish time
    on the modelled fabric.
    """
    return ScenarioSpec.flows(
        pairs, arity=arity, t_start=t_start, t_stop=float("inf"),
        volume=float(bytes_per_flow),
        nic_buffer=2 * bytes_per_flow).build(cfg)
