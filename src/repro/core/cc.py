"""Composable CC-stage registry: pluggable detection / notification /
reaction riding one jit.

The paper's thesis is that DCQCN's closed loop decomposes into three
independently improvable mechanisms — congestion detection (CP -> ECP),
notification (NP -> ENP) and injection throttling (RP -> ERP).  This
module makes each stage a first-class, sweepable axis: **marking**,
**notification** and **reaction** are registries of small components,
and a ``CCSpec(marking=..., notification=..., reaction=...)`` names one
entry per family.

The protocol (one entry per ``StageRegistry``):

  * ``params``      — ``{field_name: (spec) -> python scalar}``: the
    constants this stage reads, flattened into the family's traced
    param pytree (``StepParams.mark`` / ``.notif`` / ``.react``).
    Field names are namespaced by convention (``cp_kmin``,
    ``erp_settle``); a name shared across stages (``drain_gain``) must
    extract the same value — ``device_params`` raises otherwise.
  * ``init_state``  — optional ``(Scenario) -> {key: [F] array}``:
    per-flow state this stage carries across steps, stacked into
    ``FluidState.cc`` (every registered stage contributes, so the
    pytree is shape-stable across a whole sweep batch).
  * ``step``        — the pure per-``dt`` update
    ``(params, ctx, state) -> (outputs, state_updates)``.  ``ctx`` is
    the family's context NamedTuple below; outputs are selected across
    stages with ``jnp.where`` on the family's traced code, which is
    what lets any (marking x notification x reaction x param grid)
    product compile to ONE ``Sweep`` launch — exactly like
    ``route_code`` for adaptive routing.
  * ``kernel_step`` — optional Pallas form of ``step`` (same signature
    + ``interpret=`` and an optional ``packed=`` prepacked SMEM param
    row, see ``pack_react_rows``), used when
    ``fluid_step(use_kernels=True)``.
  * ``kernel_body`` — optional *in-kernel* form of ``step``: the body
    the whole-step megakernel (``use_kernels="mega"``) traces inside
    its single ``pallas_call``.  It must stay plain jnp — no nested
    ``pallas_call`` — and defaults to ``step`` itself (the built-in
    stages' updates are already elementwise/small-reduction jnp, which
    is exactly the in-kernel contract).  Register a dedicated body only
    when a stage's ``step`` does something a kernel trace cannot.

Dispatch (``dispatch``) evaluates every registered stage and selects by
the traced integer code — stage selection is *data*, so a grid mixing
stages never recompiles.  Codes are assigned in registration order and
the built-in order is frozen (cp/ecp/slope, np/enp/fncc,
pfc/rp/erp/swift): appending new stages never renumbers existing ones.

Adding a variant (three lines + the step function)::

    from repro.core import cc

    def _mark_mine(p, ctx, state):
        base = ((ctx.B1_w > p["mine_thresh"]) & ctx.present
                & ctx.holds_queue).astype(jnp.float32)
        return (base, ctx.grant_next), {}

(mark intensities are floats — exact 0/1 for a hard stage; a stage may
also smooth its gates behind ``ctx.tau``, see ``repro.tune.soft`` and
the built-ins below, so ``jax.grad`` flows through the dt-scan at
``temperature > 0``)

    cc.MARKING.register("mine",
        params={"mine_thresh": lambda s: s.dcqcn.kmin}, step=_mark_mine)

then ``CCSpec(marking="mine")`` sweeps it against every other axis.

Built-in stages
---------------
marking:
  * ``cp``    — step marking on occupancy only (DCQCN's CP).
  * ``ecp``   — occupancy AND the flow's arrival rate above its
    waterfilled fair grant (the paper's ECP; victims never marked).
  * ``slope`` — RED-style ramp: marking probability rises from 0 at
    ``kmin`` to ``pmax`` at ``kmax`` (finally exercising
    ``DCQCNParams.pmax``); the probability is realised *deterministically*
    by per-flow error diffusion (an accumulator fires when it crosses 1),
    keeping the fluid model reproducible.
notification:
  * ``np``    — DCQCN NP: one CNP per ``cnp_window``, delivered after
    the full end-to-end RTT.
  * ``enp``   — the paper's ENP: fast coalescing + severity payload,
    still end-to-end.
  * ``fncc``  — FNCC-style in-path notification: the congested hop
    writes the severity payload directly into the return path, so the
    feedback delay shrinks to the upstream trip from the marking hop
    (``rtt/2 * (h_mark+1)/hops``, scaled by ``fncc.rtt_scale``).
reaction:
  * ``pfc``   — fixed-rate source (no end-to-end CC; PFC only).
  * ``rp``    — DCQCN RP (alpha EWMA + staged byte/timer recovery).
  * ``erp``   — the paper's ERP (settle to signalled fair share, hold,
    desynchronised additive recovery).
  * ``swift`` — delay-target reaction (Swift-like): throttles on the
    queuing-delay *estimate* (bytes queued along the path / line rate)
    instead of mark arrival — multiplicative decrease proportional to
    the excess over ``swift.target_delay`` at most once per guard
    period, additive recovery below target.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.tune import soft


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Stage:
    """One registered component of a family (see module docstring)."""

    family: str
    name: str
    code: int
    params: dict                      # {field: (spec) -> python scalar}
    step: Callable                    # (params, ctx, state) -> (out, upd)
    int_params: frozenset = frozenset()   # fields traced as int32
    init_state: Callable | None = None
    kernel_step: Callable | None = None
    # in-kernel (megakernel) form of ``step``; None falls back to
    # ``step`` itself, which is valid whenever the update is plain jnp
    kernel_body: Callable | None = None
    # reaction stages only: does this stage read the mark/CNP feedback?
    # Mark-free reactions (swift's delay signal) make the marking axis
    # dead — ablation grids cross it only for consumers.
    consumes_marks: bool = True


class StageRegistry:
    """Ordered name -> Stage mapping; codes follow registration order."""

    def __init__(self, family: str):
        self.family = family
        self._stages: dict[str, Stage] = {}

    def register(self, name: str, *, step: Callable,
                 params: dict | None = None,
                 int_params: tuple = (),
                 init_state: Callable | None = None,
                 kernel_step: Callable | None = None,
                 kernel_body: Callable | None = None,
                 consumes_marks: bool = True) -> Stage:
        if name in self._stages:
            raise ValueError(
                f"{self.family} stage {name!r} already registered")
        stage = Stage(family=self.family, name=name,
                      code=len(self._stages), params=dict(params or {}),
                      int_params=frozenset(int_params),
                      step=step, init_state=init_state,
                      kernel_step=kernel_step,
                      kernel_body=kernel_body,
                      consumes_marks=consumes_marks)
        self._stages[name] = stage
        return stage

    def __contains__(self, name: str) -> bool:
        return name in self._stages

    def names(self) -> tuple[str, ...]:
        return tuple(self._stages)

    def get(self, name: str) -> Stage:
        if name not in self._stages:
            raise KeyError(
                f"unknown {self.family} stage {name!r}; registered: "
                f"{self.names()}")
        return self._stages[name]

    def code(self, name: str) -> int:
        return self.get(name).code

    def stages(self) -> tuple[Stage, ...]:
        return tuple(self._stages.values())

    def device_params(self, spec) -> dict:
        """Union of every registered stage's traced param scalars.

        Every field traces as float32 unless its stage listed it in
        ``int_params`` (int32) — dtype is a declaration, not inferred
        from the python value, so ``SwiftParams(ai=10**12)`` and
        ``ai=1e12`` build the identical pytree.  A field name declared
        by several stages must extract the same value (shared constants
        like ``drain_gain``); a mismatch raises.
        """
        out: dict = {}
        for stage in self.stages():
            for field, fn in stage.params.items():
                v = fn(spec)
                is_int = field in stage.int_params
                if field in out:
                    if out[field] != (v, is_int):
                        raise ValueError(
                            f"{self.family} param {field!r} extracted "
                            f"conflicting values {out[field][0]!r} vs "
                            f"{v!r}; shared field names must share "
                            f"semantics — namespace stage-specific "
                            f"params by the stage name")
                    continue
                out[field] = (v, is_int)
        return {field: jnp.asarray(v, jnp.int32 if is_int
                                   else jnp.float32)
                for field, (v, is_int) in out.items()}

    def init_cc_state(self, scn) -> dict:
        """Every registered stage's per-flow state for one scenario."""
        out: dict = {}
        for stage in self.stages():
            if stage.init_state is None:
                continue
            for k, v in stage.init_state(scn).items():
                if k in out:
                    raise ValueError(
                        f"{self.family} state key {k!r} declared twice; "
                        f"namespace state keys by the stage name")
                out[k] = jnp.asarray(v)
        return out


MARKING = StageRegistry("marking")
NOTIFICATION = StageRegistry("notification")
REACTION = StageRegistry("reaction")

FAMILIES = (MARKING, NOTIFICATION, REACTION)


def init_cc_state(scn) -> dict:
    """Union of all families' per-flow stage state for one scenario."""
    out: dict = {}
    for reg in FAMILIES:
        for k, v in reg.init_cc_state(scn).items():
            if k in out:
                raise ValueError(f"cc state key {k!r} declared by two "
                                 f"families")
            out[k] = v
    return out


def _select(code, outs):
    """where-chain over same-structure pytrees, stage 0 as the base."""
    sel = outs[0]
    for i, o in enumerate(outs[1:], start=1):
        sel = jax.tree.map(lambda a, b, i=i: jnp.where(code == i, b, a),
                           sel, o)
    return sel


def dispatch(registry: StageRegistry, code, params: dict, ctx,
             state: dict, *, use_kernels: bool = False,
             interpret: bool = False, in_kernel: bool = False,
             packed: dict | None = None):
    """Evaluate every stage of ``registry`` and select by traced code.

    Returns ``(outputs, family_state)`` where ``family_state`` maps
    every state key any stage of this family owns to its post-step
    value (non-selected stages pass their keys through unchanged, so
    merging families back into ``FluidState.cc`` is a dict union).

    ``in_kernel`` marks a trace already inside the megakernel launch:
    stages run their ``kernel_body`` (default: ``step``) and must not
    open a nested ``pallas_call``, so ``use_kernels`` is ignored.
    ``packed`` optionally maps stage names to prepacked kernel param
    rows (``pack_react_rows``); it is forwarded to ``kernel_step`` only
    when present, keeping third-party kernel stages (which may not
    accept the kwarg) working unchanged.
    """
    outs = []
    owned: set[str] = set()
    for stage in registry.stages():
        if in_kernel:
            main, upd = (stage.kernel_body or stage.step)(params, ctx,
                                                          state)
        elif use_kernels and stage.kernel_step is not None:
            kw = {}
            if packed is not None and stage.name in packed:
                kw["packed"] = packed[stage.name]
            main, upd = stage.kernel_step(params, ctx, state,
                                          interpret=interpret, **kw)
        else:
            main, upd = stage.step(params, ctx, state)
        owned.update(upd)
        outs.append((main, upd))
    full = []
    for main, upd in outs:
        merged = {k: state[k] for k in owned}
        merged.update(upd)
        full.append((main, merged))
    return _select(code, full)


def pack_react_rows(react: dict, line_rate, dt) -> dict:
    """Prepacked ``(1, NP)`` SMEM param rows per built-in reaction stage.

    The per-flow reaction kernels (``repro.kernels.cc_step``) take
    their scalars as one packed row; rebuilding it inside a scanned
    step re-traces the stack every substep.  The rows are pure
    functions of a run's constants, so callers holding the traced
    params (``make_step_fn``, the sweep engine) pack them ONCE per
    launch and thread the result through ``dispatch(packed=...)``.
    Row layouts live with the kernels (``cc_step.pack_rp_params`` and
    friends) so the order has a single definition.
    """
    from repro.kernels import cc_step
    from repro.kernels.ref import ERPParams, RPParams, SwiftKParams
    rp = RPParams(g=react["rp_g"], rate_decrease=react["rp_rdf"],
                  timer_T=react["rp_timer"], byte_B=react["rp_byte"],
                  rai=react["rp_rai"], rhai=react["rp_rhai"],
                  fr_stages=react["rp_fr_stages"].astype(jnp.float32),
                  min_rate=react["rp_min_rate"], line_rate=line_rate,
                  dt=dt)
    erp = ERPParams(settle=react["erp_settle"], hold=react["erp_hold"],
                    min_rate=react["erp_min_rate"], line_rate=line_rate,
                    dt=dt)
    swift = SwiftKParams(target=react["swift_target"],
                         beta=react["swift_beta"], ai=react["swift_ai"],
                         guard=react["swift_guard"],
                         min_rate=react["swift_min_rate"],
                         line_rate=line_rate, dt=dt)
    return {"rp": cc_step.pack_rp_params(rp),
            "erp": cc_step.pack_erp_params(erp),
            "swift": cc_step.pack_swift_params(swift)}


# ---------------------------------------------------------------------------
# family contexts
# ---------------------------------------------------------------------------


class MarkCtx(NamedTuple):
    """Phase-4 context: per-(flow, hop) congestion signals.

    ``B1_w``: occupancy of each hop's sink queue — under multiple
    virtual channels (``LinkParams.n_vcs > 1``) this is the flow's own
    (wire, VC) lane, so marking never charges a flow for a sibling
    VC's backlog; ``present``: the flow has bytes there;
    ``holds_queue``: hop owns a queue (not the delivery hop);
    ``dem_next``/``grant_next``/``over_next``: the flow's demand,
    waterfilled fair grant and oversubscription flag at its *requested
    output* wire (per-wire notions: grants share the wire's capacity
    across all its VCs).
    """

    B1_w: jnp.ndarray         # [F, H] f32
    present: jnp.ndarray      # [F, H] bool
    holds_queue: jnp.ndarray  # [F, H] bool
    dem_next: jnp.ndarray     # [F, H] f32
    grant_next: jnp.ndarray   # [F, H] f32
    over_next: jnp.ndarray    # [F, H] f32 (exact 0/1 hard, graded soft)
    port_buffer: jnp.ndarray  # [] f32
    line_rate: jnp.ndarray    # [] f32
    tau: jnp.ndarray          # [] f32 soft-relaxation temperature


class NotifCtx(NamedTuple):
    """Phase-5 context: who marked, and the delay-line geometry."""

    marked: jnp.ndarray       # [F] f32 mark level (exact 0/1 hard)
    mark_fh: jnp.ndarray      # [F, H] f32 — which hop(s), graded soft
    np_tmr_t: jnp.ndarray     # [F] f32 — suppression timer (post-tick)
    hops: jnp.ndarray         # [F] int32 — current path's hop count
    rtt: jnp.ndarray          # [F] int32 — end-to-end delay in dt steps
    t: jnp.ndarray            # [] int32 — step counter
    D: int                    # static delay-line depth
    tau: jnp.ndarray          # [] f32 soft-relaxation temperature


class ReactCtx(NamedTuple):
    """Phase-6 context: reaction-point state + feedback signals."""

    rate: jnp.ndarray         # [F] f32
    rp_target: jnp.ndarray    # [F]
    alpha: jnp.ndarray        # [F]
    byte_cnt: jnp.ndarray     # [F]
    tmr: jnp.ndarray          # [F]
    alpha_tmr: jnp.ndarray    # [F]
    bc_stage: jnp.ndarray     # [F] int32
    t_stage: jnp.ndarray      # [F] int32
    hold: jnp.ndarray         # [F]
    cnp: jnp.ndarray          # [F] f32 — notification level (0/1 hard)
    tgt_rx: jnp.ndarray       # [F] f32 — received severity payload
    qdelay: jnp.ndarray       # [F] f32 — queuing-delay estimate (s)
    jitter: jnp.ndarray       # [F] f32 — deterministic per-flow jitter
    gen_rate: jnp.ndarray     # [F] f32 — offered rate (pfc source)
    line_rate: jnp.ndarray    # [] f32
    dt: jnp.ndarray           # [] f32
    tau: jnp.ndarray          # [] f32 soft-relaxation temperature


class ReactOut(NamedTuple):
    """Reaction-point state after one dt (fields a stage does not own
    pass through from the context)."""

    rate: jnp.ndarray
    rp_target: jnp.ndarray
    alpha: jnp.ndarray
    byte_cnt: jnp.ndarray
    tmr: jnp.ndarray
    alpha_tmr: jnp.ndarray
    bc_stage: jnp.ndarray
    t_stage: jnp.ndarray
    hold: jnp.ndarray


def _passthrough(ctx: ReactCtx) -> ReactOut:
    return ReactOut(rate=ctx.rate, rp_target=ctx.rp_target,
                    alpha=ctx.alpha, byte_cnt=ctx.byte_cnt, tmr=ctx.tmr,
                    alpha_tmr=ctx.alpha_tmr, bc_stage=ctx.bc_stage,
                    t_stage=ctx.t_stage, hold=ctx.hold)


# ---------------------------------------------------------------------------
# marking stages
# ---------------------------------------------------------------------------


def _mark_common(thresh, ctx: MarkCtx):
    """(base mark intensity, queue excess over thresh) shared by variants.

    The intensity is an exact 0/1 float in hard mode (``tau == 0``
    selects the original boolean, cast); under the soft model the
    threshold crossing becomes a sigmoid in the occupancy — this is the
    site that gives kmin/detect-threshold a gradient.  The presence
    gates stay hard multipliers (state-dependent, not tuned; keeping
    them exact prevents ghost marks at empty queues).
    """
    gate_h = ((ctx.B1_w > thresh) & ctx.present
              & ctx.holds_queue).astype(jnp.float32)
    gate_s = (soft.unit_gate(ctx.B1_w - thresh, ctx.tau, ctx.port_buffer)
              * ctx.present * ctx.holds_queue)
    base = soft.select(ctx.tau, gate_s, gate_h)
    qexc = jnp.clip((ctx.B1_w - thresh) / ctx.port_buffer, 0.0, 1.0)
    return base, qexc


def _severity(ctx: MarkCtx, drain_gain, qexc):
    """``grant_next * (1 - drain_gain * qexc)``, inf-sentinel safe.

    Hops without a finite fair grant keep the exact ``inf`` payload the
    hard min-severity aggregation expects, but never on a product: a
    literal ``inf * (1 - g*qexc)`` would hand ``jax.grad`` an infinite
    partial, and even a zero cotangent times inf is nan.  Finite
    entries are bitwise the plain product.
    """
    finite = jnp.isfinite(ctx.grant_next)
    g_fin = jnp.where(finite, ctx.grant_next, 0.0)
    return jnp.where(finite, g_fin * (1.0 - drain_gain * qexc), jnp.inf)


def _mark_cp(p, ctx: MarkCtx, state):
    base, qexc = _mark_common(p["cp_kmin"], ctx)
    sev = _severity(ctx, p["drain_gain"], qexc)
    return (base, sev), {}


def _mark_ecp(p, ctx: MarkCtx, state):
    base, qexc = _mark_common(p["ecp_thresh"], ctx)
    # hard: oversubscribed output AND demand above the slack-scaled
    # fair grant; soft: product of the graded oversubscription level
    # and a sigmoid in the demand excess (grant_next's inf sentinels
    # drive the sigmoid argument to -inf -> exactly 0, never nan).
    cong_h = ((ctx.over_next > 0)
              & (ctx.dem_next > p["ecp_slack"] * ctx.grant_next)
              ).astype(jnp.float32)
    cong_s = ctx.over_next * soft.unit_gate(
        ctx.dem_next - p["ecp_slack"] * ctx.grant_next, ctx.tau,
        ctx.line_rate)
    congesting = soft.select(ctx.tau, cong_s, cong_h)
    sev = _severity(ctx, p["drain_gain"], qexc)
    return (base * congesting, sev), {}


def _mark_slope(p, ctx: MarkCtx, state):
    """RED-style kmin..kmax ramp, realised by per-flow error diffusion.

    The marking probability ``p(B)`` (0 below kmin, ``pmax`` ramp to
    kmax, 1 above) accumulates per flow; a mark fires when the
    accumulator crosses 1 and spends it — a deterministic thinning with
    exactly the right long-run marking rate, which keeps the fluid
    model reproducible (no RNG in the hot loop).  The soft model fires
    fractionally (sigmoid in the accumulator excess) and spends what it
    fired, so the long-run rate is preserved while kmin/kmax/pmax all
    get gradients through the ramp.
    """
    kmin, kmax = p["slope_kmin"], p["slope_kmax"]
    base, qexc = _mark_common(kmin, ctx)
    ramp = jnp.clip((ctx.B1_w - kmin) / jnp.maximum(kmax - kmin, 1.0),
                    0.0, 1.0)
    prob_fh = jnp.where(ctx.B1_w >= kmax, 1.0, p["slope_pmax"] * ramp)
    prob_fh = prob_fh * base
    prob = jnp.max(prob_fh, axis=1)                    # [F]
    acc = state["slope_acc"] + prob
    fire_h = acc >= 1.0
    fire = soft.select(ctx.tau,
                       soft.unit_gate(acc - 1.0, ctx.tau, 1.0),
                       fire_h.astype(jnp.float32))
    acc = soft.select(ctx.tau, acc - fire,
                      jnp.where(fire_h, acc - 1.0, acc))
    sev = _severity(ctx, p["drain_gain"], qexc)
    return (base * fire[:, None], sev), {"slope_acc": acc}


# ---------------------------------------------------------------------------
# notification stages
# ---------------------------------------------------------------------------


def _notify_window(window, ctx: NotifCtx):
    """Suppression window shared by NP/ENP/FNCC.

    Returns the [F] emission intensity (exact 0/1 hard; soft = mark
    level x a sigmoid timer gate) and the partially-reset suppression
    timer (a full emission resets it to 0, a fractional one
    proportionally — annealing recovers the hard reset).
    """
    emit_h = ((ctx.marked > 0)
              & (ctx.np_tmr_t >= window)).astype(jnp.float32)
    np_h = jnp.where(emit_h > 0, 0.0, ctx.np_tmr_t)
    emit_s = ctx.marked * soft.unit_gate(ctx.np_tmr_t - window, ctx.tau,
                                         window)
    emit = soft.select(ctx.tau, emit_s, emit_h)
    np_tmr = soft.select(ctx.tau, (1.0 - emit_s) * ctx.np_tmr_t, np_h)
    return emit, np_tmr


def _notif_np(p, ctx: NotifCtx, state):
    emit, np_tmr = _notify_window(p["np_window"], ctx)
    wslot = (ctx.t + ctx.rtt) % ctx.D
    return (emit, np_tmr, wslot), {}


def _notif_enp(p, ctx: NotifCtx, state):
    emit, np_tmr = _notify_window(p["enp_window"], ctx)
    wslot = (ctx.t + ctx.rtt) % ctx.D
    return (emit, np_tmr, wslot), {}


def _notif_fncc(p, ctx: NotifCtx, state):
    """In-path notification: the marking hop writes the return path.

    The payload skips the remaining forward trip and the destination
    turnaround — it only rides upstream from the first marking hop, so
    the delay is the hop-proportional share of the one-way latency,
    ``rtt/2 * (h_mark+1)/hops`` (clipped to [2, rtt]: never same-step,
    never slower than the end-to-end CNP).
    """
    emit, np_tmr = _notify_window(p["fncc_window"], ctx)
    h_mark = jnp.argmax(ctx.mark_fh, axis=1).astype(jnp.float32)
    frac = (h_mark + 1.0) / jnp.maximum(ctx.hops.astype(jnp.float32), 1.0)
    rtt_f = ctx.rtt.astype(jnp.float32)
    rtt_eff = jnp.round(rtt_f * 0.5 * frac * p["fncc_scale"])
    rtt_eff = jnp.clip(rtt_eff.astype(jnp.int32), 2, ctx.rtt)
    wslot = (ctx.t + rtt_eff) % ctx.D
    return (emit, np_tmr, wslot), {}


# ---------------------------------------------------------------------------
# reaction stages
# ---------------------------------------------------------------------------


def _react_pfc(p, ctx: ReactCtx, state):
    out = _passthrough(ctx)._replace(
        rate=jnp.minimum(ctx.gen_rate, ctx.line_rate))
    return out, {}


def _react_rp(p, ctx: ReactCtx, state):
    """DCQCN RP: alpha EWMA + staged byte/timer recovery machine.

    Soft path: every CNP-gated update blends by the fractional
    notification level (``soft.pick``), so the rate cut, alpha EWMA and
    counter resets carry gradients to rdf/g and — through the marking
    intensity upstream — to the detection thresholds; the integer
    stage machine and its byte/timer events stay hard (discrete
    counters have no useful relaxation), but rai/rhai still get exact
    gradients because they enter the fired updates linearly.
    """
    g = p["rp_g"]
    dt, tau = ctx.dt, ctx.tau
    c = ctx.cnp                      # [F] level: exact 0/1 in hard mode
    cnp = ctx.cnp > 0
    pk = lambda a, b: soft.pick(tau, c, cnp, a, b)   # noqa: E731
    alpha_tmr = ctx.alpha_tmr + dt
    a_tick = alpha_tmr >= p["rp_timer"]
    alpha = jnp.where(a_tick, (1 - g) * ctx.alpha, ctx.alpha)
    alpha_tmr = jnp.where(a_tick, 0.0, alpha_tmr)
    rp_target = pk(ctx.rate, ctx.rp_target)
    rate = pk(ctx.rate * (1 - alpha * p["rp_rdf"]), ctx.rate)
    alpha = pk((1 - g) * alpha + g, alpha)
    byte_cnt = pk(0.0, ctx.byte_cnt + ctx.rate * dt)
    tmr = pk(0.0, ctx.tmr + dt)
    alpha_tmr = pk(0.0, alpha_tmr)
    bc_stage = jnp.where(cnp, 0, ctx.bc_stage)
    t_stage = jnp.where(cnp, 0, ctx.t_stage)
    b_ev = byte_cnt >= p["rp_byte"]
    t_ev = tmr >= p["rp_timer"]
    byte_cnt = jnp.where(b_ev, 0.0, byte_cnt)
    tmr = jnp.where(t_ev, 0.0, tmr)
    bc_stage = bc_stage + b_ev.astype(jnp.int32)
    t_stage = t_stage + t_ev.astype(jnp.int32)
    ev = b_ev | t_ev
    imax = jnp.maximum(bc_stage, t_stage)
    imin = jnp.minimum(bc_stage, t_stage)
    in_fr = imax <= p["rp_fr_stages"]
    in_hyper = imin > p["rp_fr_stages"]
    rp_target = jnp.where(ev & ~in_fr & ~in_hyper, rp_target + p["rp_rai"],
                          rp_target)
    rp_target = jnp.where(
        ev & in_hyper,
        rp_target + p["rp_rhai"]
        * (imin - p["rp_fr_stages"]).astype(jnp.float32),
        rp_target)
    rate = jnp.where(ev, 0.5 * (rate + rp_target), rate)
    rate = soft.clip(rate, p["rp_min_rate"], ctx.line_rate, tau,
                     ctx.line_rate)
    rp_target = soft.clip(rp_target, p["rp_min_rate"], ctx.line_rate,
                          tau, ctx.line_rate)
    out = _passthrough(ctx)._replace(
        rate=rate, rp_target=rp_target, alpha=alpha, byte_cnt=byte_cnt,
        tmr=tmr, alpha_tmr=alpha_tmr, bc_stage=bc_stage, t_stage=t_stage)
    return out, {}


def _react_rp_kernel(p, ctx: ReactCtx, state, *, interpret, packed=None):
    from repro.kernels.cc_step import rp_step
    from repro.kernels.ref import RPParams, RPState
    out = rp_step(
        RPState(ctx.rate, ctx.rp_target, ctx.alpha, ctx.byte_cnt,
                ctx.tmr, ctx.alpha_tmr,
                ctx.bc_stage.astype(jnp.float32),
                ctx.t_stage.astype(jnp.float32)),
        ctx.cnp,
        RPParams(g=p["rp_g"], rate_decrease=p["rp_rdf"],
                 timer_T=p["rp_timer"], byte_B=p["rp_byte"],
                 rai=p["rp_rai"], rhai=p["rp_rhai"],
                 fr_stages=p["rp_fr_stages"].astype(jnp.float32),
                 min_rate=p["rp_min_rate"], line_rate=ctx.line_rate,
                 dt=ctx.dt),
        interpret=interpret, packed=packed)
    res = _passthrough(ctx)._replace(
        rate=out.rate, rp_target=out.target, alpha=out.alpha,
        byte_cnt=out.byte_cnt, tmr=out.tmr, alpha_tmr=out.alpha_tmr,
        bc_stage=out.bc_stage.astype(jnp.int32),
        t_stage=out.t_stage.astype(jnp.int32))
    return res, {}


def _erp_slope(p, ctx: ReactCtx):
    """Per-flow desynchronised recovery slope (deterministic jitter)."""
    return p["erp_rai"] * (1.0 + p["erp_jitter"] * ctx.jitter)


def _react_erp(p, ctx: ReactCtx, state):
    """ERP: settle to signalled fair share, hold, additive recovery.

    Soft path: settle/hold blend by the notification level, and the
    hold-down expiry becomes a sigmoid recovery gate — erp_settle,
    erp_hold and erp_rai all differentiable through the scan.
    """
    dt, tau = ctx.dt, ctx.tau
    c = ctx.cnp
    cnp = ctx.cnp > 0
    pk = lambda a, b: soft.pick(tau, c, cnp, a, b)   # noqa: E731
    settle = jnp.maximum(p["erp_settle"] * ctx.tgt_rx, p["erp_min_rate"])
    rate = pk(settle, ctx.rate)
    hold = pk(p["erp_hold"], jnp.maximum(ctx.hold - dt, 0.0))
    slope = _erp_slope(p, ctx) * dt
    rec_s = (1.0 - c) * soft.unit_gate(-hold, tau, p["erp_hold"] + 1e-9)
    rate = soft.select(tau, rate + rec_s * slope,
                       jnp.where(~cnp & (hold <= 0), rate + slope, rate))
    rate = soft.clip(rate, p["erp_min_rate"], ctx.line_rate, tau,
                     ctx.line_rate)
    return _passthrough(ctx)._replace(rate=rate, hold=hold), {}


def _react_erp_kernel(p, ctx: ReactCtx, state, *, interpret, packed=None):
    from repro.kernels.cc_step import erp_step
    from repro.kernels.ref import ERPParams
    rate, hold = erp_step(
        ctx.rate, ctx.hold, ctx.cnp, ctx.tgt_rx, _erp_slope(p, ctx),
        ERPParams(settle=p["erp_settle"], hold=p["erp_hold"],
                  min_rate=p["erp_min_rate"], line_rate=ctx.line_rate,
                  dt=ctx.dt),
        interpret=interpret, packed=packed)
    return _passthrough(ctx)._replace(rate=rate, hold=hold), {}


def _react_swift(p, ctx: ReactCtx, state):
    """Delay-target throttling on the path queuing-delay estimate.

    Hard path = ``swift_update_ref`` verbatim (the single definition
    the Pallas kernel reproduces).  Soft path: the over-target and
    cool-down gates become sigmoids, blending the multiplicative
    decrease against the additive recovery — target_delay/beta/ai get
    gradients (the qdelay signal itself is already differentiable).
    """
    from repro.kernels.ref import swift_update_ref
    rate_h, cool_h = swift_update_ref(
        ctx.rate, state["swift_cool"], ctx.qdelay,
        target=p["swift_target"], beta=p["swift_beta"], ai=p["swift_ai"],
        guard=p["swift_guard"], min_rate=p["swift_min_rate"],
        line_rate=ctx.line_rate, dt=ctx.dt)
    tau = ctx.tau
    target, beta = p["swift_target"], p["swift_beta"]
    cool = jnp.maximum(state["swift_cool"] - ctx.dt, 0.0)
    g_over = soft.unit_gate(ctx.qdelay - target, tau, target + 1e-12)
    g_can = soft.unit_gate(-cool, tau, p["swift_guard"] + 1e-12)
    factor = 1.0 - beta * (ctx.qdelay - target) \
        / jnp.maximum(ctx.qdelay, 1e-12)
    dec = jnp.maximum(ctx.rate * jnp.maximum(factor, 1.0 - beta),
                      p["swift_min_rate"])
    cut = g_over * g_can
    rate_s = cut * dec + (1.0 - cut) * \
        (ctx.rate + (1.0 - g_over) * p["swift_ai"] * ctx.dt)
    rate_s = soft.clip(rate_s, p["swift_min_rate"], ctx.line_rate, tau,
                       ctx.line_rate)
    cool_s = cut * p["swift_guard"] + (1.0 - cut) * cool
    rate = soft.select(tau, rate_s, rate_h)
    cool = soft.select(tau, cool_s, cool_h)
    return _passthrough(ctx)._replace(rate=rate), {"swift_cool": cool}


def _react_swift_kernel(p, ctx: ReactCtx, state, *, interpret,
                        packed=None):
    from repro.kernels.cc_step import swift_step
    from repro.kernels.ref import SwiftKParams
    rate, cool = swift_step(
        ctx.rate, state["swift_cool"], ctx.qdelay,
        SwiftKParams(target=p["swift_target"], beta=p["swift_beta"],
                     ai=p["swift_ai"], guard=p["swift_guard"],
                     min_rate=p["swift_min_rate"], line_rate=ctx.line_rate,
                     dt=ctx.dt),
        interpret=interpret, packed=packed)
    return _passthrough(ctx)._replace(rate=rate), {"swift_cool": cool}


# ---------------------------------------------------------------------------
# built-in registration (codes frozen in this order)
# ---------------------------------------------------------------------------


def _zeros_f(scn) -> np.ndarray:
    return np.zeros((scn.routes.shape[0],), np.float32)


# Every built-in registers an explicit ``kernel_body`` — the in-kernel
# form the megakernel dispatches on.  For these stages the jnp ``step``
# IS a valid kernel body (elementwise + [F, H]-axis reductions, no
# nested pallas_call), so the entries alias it; the point of spelling
# them out is that the whole marking x notification x reaction matrix
# is declared megakernel-clean, and a future TPU-hostile stage opts out
# by registering a dedicated body instead.
MARKING.register(
    "cp", step=_mark_cp, kernel_body=_mark_cp,
    params={"cp_kmin": lambda s: s.dcqcn.kmin,
            "drain_gain": lambda s: s.rev.erp_drain_gain})
MARKING.register(
    "ecp", step=_mark_ecp, kernel_body=_mark_ecp,
    params={"ecp_thresh": lambda s: s.rev.detect_threshold,
            "ecp_slack": lambda s: s.rev.ecp_fairness_slack,
            "drain_gain": lambda s: s.rev.erp_drain_gain})
MARKING.register(
    "slope", step=_mark_slope, kernel_body=_mark_slope,
    params={"slope_kmin": lambda s: s.dcqcn.kmin,
            "slope_kmax": lambda s: s.dcqcn.kmax,
            "slope_pmax": lambda s: s.dcqcn.pmax,
            "drain_gain": lambda s: s.rev.erp_drain_gain},
    init_state=lambda scn: {"slope_acc": _zeros_f(scn)})

NOTIFICATION.register(
    "np", step=_notif_np, kernel_body=_notif_np,
    params={"np_window": lambda s: s.dcqcn.cnp_window})
NOTIFICATION.register(
    "enp", step=_notif_enp, kernel_body=_notif_enp,
    params={"enp_window": lambda s: s.rev.enp_coalesce})
NOTIFICATION.register(
    "fncc", step=_notif_fncc, kernel_body=_notif_fncc,
    params={"fncc_window": lambda s: s.fncc.coalesce,
            "fncc_scale": lambda s: s.fncc.rtt_scale})

REACTION.register("pfc", step=_react_pfc, kernel_body=_react_pfc,
                  consumes_marks=False)
REACTION.register(
    "rp", step=_react_rp, kernel_step=_react_rp_kernel,
    kernel_body=_react_rp,
    params={"rp_g": lambda s: s.dcqcn.g,
            "rp_rdf": lambda s: s.dcqcn.rate_decrease_factor,
            "rp_timer": lambda s: s.dcqcn.timer_T,
            "rp_byte": lambda s: s.dcqcn.byte_counter_B,
            "rp_rai": lambda s: s.dcqcn.rai,
            "rp_rhai": lambda s: s.dcqcn.rhai,
            "rp_fr_stages": lambda s: s.dcqcn.fr_stages,
            "rp_min_rate": lambda s: s.dcqcn.min_rate},
    int_params=("rp_fr_stages",))
REACTION.register(
    "erp", step=_react_erp, kernel_step=_react_erp_kernel,
    kernel_body=_react_erp,
    params={"erp_settle": lambda s: s.rev.erp_settle,
            "erp_rai": lambda s: s.rev.erp_rai,
            "erp_jitter": lambda s: s.rev.erp_jitter,
            "erp_hold": lambda s: s.rev.erp_hold,
            "erp_min_rate": lambda s: s.rev.min_rate})
REACTION.register(
    "swift", step=_react_swift, kernel_step=_react_swift_kernel,
    kernel_body=_react_swift, consumes_marks=False,
    params={"swift_target": lambda s: s.swift.target_delay,
            "swift_beta": lambda s: s.swift.beta,
            "swift_ai": lambda s: s.swift.ai,
            "swift_guard": lambda s: s.swift.guard,
            "swift_min_rate": lambda s: s.swift.min_rate},
    init_state=lambda scn: {"swift_cool": _zeros_f(scn)})
