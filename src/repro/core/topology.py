"""CLOS / XGFT topology builder for the congestion-control fluid model.

The paper evaluates a 64-node, 3-stage CLOS built from 48 radix-8 switches
(Fig. 1).  That is exactly XGFT(3; 4,4,4; 1,4,4):

* 16 leaf switches  (ids 0..15),  4 down-ports to nodes, 4 up-ports,
* 16 middle (agg) switches (ids 16..31) — the paper's "switch 16" is
  agg(group=0, pos=0), which is where the incast HoL forms,
* 16 spine switches (ids 32..47), 4 down-ports used.

Nodes are *blocked* onto leaves (node n -> leaf n // 4), which places
N0,N1,N3 on leaf 0 as the paper's narrative requires.

Queueing model: every **directed link** carries one queue at its *sink*
end — i.e. the input buffer of the downstream switch (InfiniBand-style
input-buffered switches; the paper explicitly describes HoL at "the input
buffer of switch 16").  A link is *paused* (PFC) when its own sink-side
queue crosses XOFF, which stops all flows crossing that wire — the HoL
mechanism.

Link id layout for the 64-node CLOS (L = 384 directed links):
    [0,   64)   nic-up:    node n        -> leaf n//4        (queue at leaf)
    [64, 128)   leaf-up:   leaf l, up u  -> agg(l//4, u)     (queue at agg)
    [128,192)   agg-up:    agg(g,p), u   -> spine p*4+u      (queue at spine)
    [192,256)   spine-dn:  spine s -> agg(g, s//4) for g     (queue at agg)
    [256,320)   agg-dn:    agg(g,p) -> leaf g*4+j            (queue at leaf)
    [320,384)   leaf-dn:   leaf l -> node (delivery)         (queue at node)

Everything is returned as plain numpy arrays inside a frozen ``Topology``;
the fluid model converts them to device arrays once per scenario.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    """A directed-link network description (generic, not CLOS-specific)."""

    n_nodes: int
    n_switches: int
    n_links: int
    # per directed link: source entity and sink entity. Switches are ids in
    # [0, n_switches); nodes are encoded as -(node_id + 1); so src/dst < 0
    # means a host NIC endpoint.
    link_src: np.ndarray          # [L] int32
    link_dst: np.ndarray          # [L] int32
    link_capacity: np.ndarray     # [L] float64, bytes/s
    name: str = "generic"

    # -- convenience masks -------------------------------------------------
    def sink_switch(self) -> np.ndarray:
        """Switch id owning each link's sink-side queue (-1 for host sinks)."""
        d = self.link_dst
        return np.where(d >= 0, d, -1).astype(np.int32)

    def is_delivery_link(self) -> np.ndarray:
        return (self.link_dst < 0)


# --------------------------------------------------------------------------
# 64-node 3-stage CLOS (the paper's Fig. 1) and its k-ary generalisation.
# --------------------------------------------------------------------------


def _node_enc(n: int) -> int:
    return -(n + 1)


def make_clos3(arity: int = 4, line_rate: float = 12.5e9,
               name: str = "clos64") -> Topology:
    """3-stage folded CLOS, XGFT(3; a,a,a; 1,a,a) with ``a = arity``.

    arity=4 gives the paper's 64-node / 48-switch / radix-8 network.
    Total: nodes = a^3, leaves = a^2, aggs = a^2, spines = a^2,
    directed links = 6 * a^3.
    """
    a = arity
    n_nodes = a ** 3
    n_leaf = a * a
    n_agg = a * a
    n_spine = a * a
    n_switches = n_leaf + n_agg + n_spine

    def leaf_id(l: int) -> int:
        return l

    def agg_id(g: int, p: int) -> int:
        return n_leaf + g * a + p

    def spine_id(s: int) -> int:
        return n_leaf + n_agg + s

    src, dst = [], []

    # [0, a^3): nic-up, node n -> leaf n//a
    for n in range(n_nodes):
        src.append(_node_enc(n))
        dst.append(leaf_id(n // a))
    # [a^3, 2a^3): leaf-up, leaf l uplink u -> agg(l//a, u)
    for l in range(n_leaf):
        for u in range(a):
            src.append(leaf_id(l))
            dst.append(agg_id(l // a, u))
    # [2a^3, 3a^3): agg-up, agg(g,p) uplink u -> spine p*a + u
    for g in range(a):
        for p in range(a):
            for u in range(a):
                src.append(agg_id(g, p))
                dst.append(spine_id(p * a + u))
    # [3a^3, 4a^3): spine-dn, spine s -> agg(g, s//a) for each group g
    for s in range(n_spine):
        for g in range(a):
            src.append(spine_id(s))
            dst.append(agg_id(g, s // a))
    # [4a^3, 5a^3): agg-dn, agg(g,p) -> leaf g*a + j
    for g in range(a):
        for p in range(a):
            for j in range(a):
                src.append(agg_id(g, p))
                dst.append(leaf_id(g * a + j))
    # [5a^3, 6a^3): leaf-dn, leaf l -> node (delivery)
    for n in range(n_nodes):
        src.append(leaf_id(n // a))
        dst.append(_node_enc(n))

    src_a = np.asarray(src, dtype=np.int32)
    dst_a = np.asarray(dst, dtype=np.int32)
    cap = np.full(src_a.shape, float(line_rate), dtype=np.float64)
    return Topology(
        n_nodes=n_nodes,
        n_switches=n_switches,
        n_links=len(src),
        link_src=src_a,
        link_dst=dst_a,
        link_capacity=cap,
        name=name,
    )


def make_paper_clos(line_rate: float = 12.5e9) -> Topology:
    """The exact network of the paper's §II.A: 64 nodes, 48 switches."""
    return make_clos3(arity=4, line_rate=line_rate, name="paper-clos64")


# Link-id helpers for the 3-stage CLOS (used by routing + tests) -----------


@dataclasses.dataclass(frozen=True)
class ClosIndex:
    arity: int

    @property
    def a3(self) -> int:
        return self.arity ** 3

    def nic_up(self, node: int) -> int:
        return node

    def leaf_up(self, leaf: int, u: int) -> int:
        return self.a3 + leaf * self.arity + u

    def agg_up(self, g: int, p: int, u: int) -> int:
        a = self.arity
        return 2 * self.a3 + (g * a + p) * a + u

    def spine_dn(self, s: int, g: int) -> int:
        return 3 * self.a3 + s * self.arity + g

    def agg_dn(self, g: int, p: int, j: int) -> int:
        a = self.arity
        return 4 * self.a3 + (g * a + p) * a + j

    def leaf_dn(self, node: int) -> int:
        return 5 * self.a3 + node

    def switch_of_agg(self, g: int, p: int) -> int:
        """Global switch id of agg(g,p); paper's 'switch 16' is (0,0)."""
        a = self.arity
        return a * a + g * a + p
