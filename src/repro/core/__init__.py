"""repro.core — the paper's contribution: DCQCN-Rev congestion control.

Public surface:
  * params:      CCConfig / CCScheme / CCSpec / PAPER_CONFIG
  * cc:          the composable stage registries (MARKING /
                 NOTIFICATION / REACTION) — pluggable detection,
                 notification and reaction components selected by
                 traced codes, all combinations riding one jit
  * topology:    make_paper_clos / make_clos3 / Topology
  * routing:     build_flow_routes / clos_route
  * fluid:       Scenario / FluidState / fluid_step / make_step_fn
  * simulator:   run / run_all_schemes / SimResult
  * experiments: ScenarioSpec / Sweep / SweepResult / config_grid —
                 the declarative one-jit sweep API (preferred entrypoint)
  * scenarios:   paper_incast / incast / ... (legacy wrappers over specs)
  * workloads:   collective-workload generator (all-to-all, ring /
                 recursive-doubling allreduce, incast storms, hotspots,
                 bursts) — combine with ``repro.net`` fabrics
"""

from .params import (CCConfig, CCScheme, CCSpec, DCQCNParams, FNCCParams,
                     LinkParams, PAPER_CONFIG, ROUTING_MODES, RevParams,
                     SimParams, SwiftParams)
from . import cc
from .topology import ClosIndex, Topology, make_clos3, make_paper_clos
from .routing import (build_flow_routes, clos_route, link_incidence,
                      route_hops)
from .fluid import (FluidState, Scenario, ScenarioDev, StepParams,
                    delay_depth, dense_reduce_rows, fluid_step,
                    init_state, make_step_fn, scenario_device,
                    step_params)
from .simulator import SimResult, run, run_all_schemes
from .exec_cache import CacheStats, ExecutableCache
from .experiments import (SWEEP_EXEC_CACHE, ScenarioSpec, Sweep,
                          SweepResult, config_grid, pad_scenario,
                          stack_scenarios, trim_final)
from .scenarios import (PAPER_FLOW_NAMES, collective_flows, incast,
                        paper_incast, paper_incast_volume,
                        random_permutation)
from .workloads import Workload
from . import workloads

__all__ = [
    "CCConfig", "CCScheme", "CCSpec", "DCQCNParams", "FNCCParams",
    "LinkParams", "PAPER_CONFIG", "ROUTING_MODES", "RevParams",
    "SimParams", "SwiftParams", "cc",
    "ClosIndex", "Topology", "make_clos3",
    "make_paper_clos", "build_flow_routes", "clos_route",
    "link_incidence", "route_hops",
    "FluidState", "Scenario", "ScenarioDev", "StepParams", "delay_depth",
    "dense_reduce_rows", "fluid_step", "init_state", "make_step_fn",
    "scenario_device", "step_params", "SimResult", "run",
    "run_all_schemes", "CacheStats", "ExecutableCache",
    "SWEEP_EXEC_CACHE",
    "ScenarioSpec", "Sweep", "SweepResult", "config_grid",
    "pad_scenario", "stack_scenarios", "trim_final", "PAPER_FLOW_NAMES",
    "collective_flows", "incast", "paper_incast", "paper_incast_volume",
    "random_permutation", "Workload", "workloads",
]
