"""repro.core — the paper's contribution: DCQCN-Rev congestion control.

Public surface:
  * params:    CCConfig / CCScheme / PAPER_CONFIG
  * topology:  make_paper_clos / make_clos3 / Topology
  * routing:   build_flow_routes / clos_route
  * fluid:     Scenario / FluidState / make_step_fn
  * simulator: run / run_all_schemes / SimResult
  * scenarios: paper_incast / incast / random_permutation / collective_flows
"""

from .params import (CCConfig, CCScheme, DCQCNParams, LinkParams,
                     PAPER_CONFIG, RevParams, SimParams)
from .topology import ClosIndex, Topology, make_clos3, make_paper_clos
from .routing import build_flow_routes, clos_route, route_hops
from .fluid import FluidState, Scenario, init_state, make_step_fn
from .simulator import SimResult, run, run_all_schemes
from .scenarios import (PAPER_FLOW_NAMES, collective_flows, incast,
                        paper_incast, paper_incast_volume,
                        random_permutation)

__all__ = [
    "CCConfig", "CCScheme", "DCQCNParams", "LinkParams", "PAPER_CONFIG",
    "RevParams", "SimParams", "ClosIndex", "Topology", "make_clos3",
    "make_paper_clos", "build_flow_routes", "clos_route", "route_hops",
    "FluidState", "Scenario", "init_state", "make_step_fn", "SimResult",
    "run", "run_all_schemes", "PAPER_FLOW_NAMES", "collective_flows",
    "incast", "paper_incast", "paper_incast_volume", "random_permutation",
]
