"""Collective-workload generator: communication patterns of real apps.

The paper's verdicts hinge on congestion *dynamics*, which are set by
what applications actually do on the wire — collectives, incast storms,
hotspots, bursts — not just the §II 5-flow scene.  Each generator here
emits a ``Workload``: plain per-flow tuples (src, dst, start, stop,
volume, rate) that compile through ``ScenarioSpec.from_workload`` to
the padded/stackable ``Scenario`` tensors, so any (fabric x workload)
point drops straight into one-jit ``Sweep`` evaluation:

    from repro.core import PAPER_CONFIG, CCScheme, Sweep
    from repro.core.workloads import ring_allreduce, incast_storm
    from repro.net import FabricSpec

    fab = FabricSpec.fat_tree(4, taper=2)
    Sweep.grid(
        configs={s.name: PAPER_CONFIG.replace(scheme=s) for s in CCScheme},
        scenarios={
            "ring": ring_allreduce(16, 8e6).spec(fabric=fab),
            "storm": incast_storm(24, 4, 64, volume=2e6).spec(fabric=fab),
        }).run()

Phases are modelled by staggered start times (the fluid model has no
inter-flow dependencies): phase p opens at ``t0 + p * phase_gap``,
with ``phase_gap`` defaulting to the slack-scaled serialisation time
of one phase's bytes at line rate.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .experiments import ScenarioSpec

LINE_RATE = 12.5e9            # B/s default for phase-gap sizing only
INF = float("inf")


@dataclasses.dataclass(frozen=True)
class Workload:
    """Per-flow traffic description as plain (hashable) tuples."""

    src: tuple[int, ...]
    dst: tuple[int, ...]
    t_start: tuple[float, ...]
    t_stop: tuple[float, ...]          # inf with finite volume = work mode
    volume: tuple[float, ...]          # bytes; inf = window-limited
    # B/s per flow; None = all at line rate.  Workloads are built before
    # the config's line rate is known, so two sentinels resolve at
    # ``build(cfg)`` time: an entry of inf means "line rate", and a
    # negative entry -f means "fraction f of line rate".
    rate: tuple[float, ...] | None = None
    label: str = "workload"

    @property
    def n_flows(self) -> int:
        return len(self.src)

    def spec(self, fabric=None, **kw) -> ScenarioSpec:
        """Compile onto a fabric (see ScenarioSpec.from_workload)."""
        return ScenarioSpec.from_workload(self, fabric=fabric, **kw)

    def __post_init__(self):
        n = len(self.src)
        for f in ("dst", "t_start", "t_stop", "volume"):
            if len(getattr(self, f)) != n:
                raise ValueError(f"{f} has {len(getattr(self, f))} entries "
                                 f"for {n} flows")
        if self.rate is not None and len(self.rate) != n:
            raise ValueError("rate length mismatch")


def concat(*workloads: Workload, label: str | None = None) -> Workload:
    """Mix workloads into one (e.g. a collective + background traffic)."""
    if not workloads:
        raise ValueError("nothing to concat")
    rates = [w.rate or (INF,) * w.n_flows for w in workloads]
    return Workload(
        src=sum((w.src for w in workloads), ()),
        dst=sum((w.dst for w in workloads), ()),
        t_start=sum((w.t_start for w in workloads), ()),
        t_stop=sum((w.t_stop for w in workloads), ()),
        volume=sum((w.volume for w in workloads), ()),
        rate=sum((tuple(r) for r in rates), ()),
        label=label or "+".join(w.label for w in workloads))


def _mk(src, dst, t0, t1, vol, rate=None, label="workload") -> Workload:
    return Workload(
        src=tuple(int(s) for s in src), dst=tuple(int(d) for d in dst),
        t_start=tuple(float(t) for t in t0),
        t_stop=tuple(float(t) for t in t1),
        volume=tuple(float(v) for v in vol),
        rate=None if rate is None else tuple(float(r) for r in rate),
        label=label)


def _gap(bytes_per_flow: float, line_rate: float, slack: float) -> float:
    return slack * bytes_per_flow / line_rate


# ---------------------------------------------------------------------------
# collectives
# ---------------------------------------------------------------------------


def all_to_all(n_nodes: int, volume: float, *, phases: int | None = None,
               phase_gap: float | None = None, t_start: float = 0.0,
               line_rate: float = LINE_RATE, slack: float = 2.0,
               nodes=None) -> Workload:
    """Phased all-to-all: shift k sends node i -> (i+k) % n.

    The n-1 shifts are spread over ``phases`` groups (default: one
    phase per shift, the classic ring-ordered exchange); each phase
    opens ``phase_gap`` after the previous.  ``volume`` is bytes per
    (src, dst) pair; ``nodes`` restricts the participant set.
    """
    nodes = list(range(n_nodes)) if nodes is None else list(nodes)
    n = len(nodes)
    if n < 2:
        raise ValueError("all_to_all needs >= 2 participants")
    phases = n - 1 if phases is None else int(phases)
    if not 1 <= phases <= n - 1:
        raise ValueError(f"phases must be in [1, {n - 1}]")
    shifts_per_phase = -(-(n - 1) // phases)
    if phase_gap is None:
        phase_gap = _gap(volume * shifts_per_phase, line_rate, slack)
    src, dst, t0 = [], [], []
    for k in range(1, n):
        p = (k - 1) % phases
        for i in range(n):
            src.append(nodes[i])
            dst.append(nodes[(i + k) % n])
            t0.append(t_start + p * phase_gap)
    return _mk(src, dst, t0, [INF] * len(src), [volume] * len(src),
               label=f"a2a{n}p{phases}")


def ring_allreduce(n_nodes: int, bytes_total: float, *,
                   phased: bool = False, phase_gap: float | None = None,
                   t_start: float = 0.0, line_rate: float = LINE_RATE,
                   slack: float = 2.0, nodes=None) -> Workload:
    """Ring allreduce: reduce-scatter + allgather over neighbour links.

    Unphased (default): each node's 2(n-1) chunk sends to its ring
    successor coalesce into one volume-mode flow of 2(n-1)/n * S bytes
    — the collective's true per-link traffic.  ``phased=True`` emits
    all 2(n-1) steps as separate staggered flows (n flows per step).
    """
    nodes = list(range(n_nodes)) if nodes is None else list(nodes)
    n = len(nodes)
    if n < 2:
        raise ValueError("ring needs >= 2 participants")
    chunk = bytes_total / n
    succ = [nodes[(i + 1) % n] for i in range(n)]
    if not phased:
        vol = 2 * (n - 1) * chunk
        return _mk(nodes, succ, [t_start] * n, [INF] * n, [vol] * n,
                   label=f"ring{n}")
    if phase_gap is None:
        phase_gap = _gap(chunk, line_rate, slack)
    src, dst, t0 = [], [], []
    for step in range(2 * (n - 1)):
        for i in range(n):
            src.append(nodes[i])
            dst.append(succ[i])
            t0.append(t_start + step * phase_gap)
    return _mk(src, dst, t0, [INF] * len(src), [chunk] * len(src),
               label=f"ring{n}phased")


def recursive_doubling_allreduce(n_nodes: int, bytes_total: float, *,
                                 phase_gap: float | None = None,
                                 t_start: float = 0.0,
                                 line_rate: float = LINE_RATE,
                                 slack: float = 2.0,
                                 nodes=None) -> Workload:
    """Recursive-doubling allreduce: log2(n) rounds of pairwise
    exchanges at distance 2^r, each carrying the full vector.

    The distance doubles every round, so successive rounds climb the
    fabric — late rounds are the bisection-stressing ones.
    """
    nodes = list(range(n_nodes)) if nodes is None else list(nodes)
    n = len(nodes)
    if n < 2 or n & (n - 1):
        raise ValueError(f"recursive doubling needs a power-of-two "
                         f"participant count, got {n}")
    if phase_gap is None:
        phase_gap = _gap(bytes_total, line_rate, slack)
    src, dst, t0 = [], [], []
    rounds = n.bit_length() - 1
    for r in range(rounds):
        for i in range(n):
            src.append(nodes[i])
            dst.append(nodes[i ^ (1 << r)])
            t0.append(t_start + r * phase_gap)
    return _mk(src, dst, t0, [INF] * len(src), [bytes_total] * len(src),
               label=f"rdbl{n}")


# ---------------------------------------------------------------------------
# storms, hotspots, bursts
# ---------------------------------------------------------------------------


def incast_storm(n_senders: int, n_receivers: int, n_nodes: int, *,
                 volume: float = INF, t_start: float = 1e-3,
                 t_stop: float = 3e-3, seed: int = 0) -> Workload:
    """n-to-m incast: ``n_senders`` sources fan into ``n_receivers``
    sinks round-robin (each sink absorbs ~n/m flows).  With a finite
    ``volume`` the storm is equal-work; otherwise window-mode."""
    if n_senders + n_receivers > n_nodes:
        raise ValueError(f"{n_senders}+{n_receivers} endpoints exceed "
                         f"{n_nodes} hosts")
    rng = np.random.RandomState(seed)
    picks = rng.permutation(n_nodes)[: n_senders + n_receivers]
    recv, send = picks[:n_receivers], picks[n_receivers:]
    dst = [int(recv[i % n_receivers]) for i in range(n_senders)]
    stop = INF if np.isfinite(volume) else t_stop
    return _mk(send, dst, [t_start] * n_senders, [stop] * n_senders,
               [volume] * n_senders,
               label=f"storm{n_senders}to{n_receivers}")


def group_shift(n_groups: int, hosts_per_group: int, *, shift: int = 1,
                volume: float = INF, t_start: float = 0.0,
                t_stop: float = 3e-3) -> Workload:
    """Adversarial group-shifted permutation: host j of group g sends
    to host j of group (g + shift) % n_groups.

    On a dragonfly (``hosts_per_group = a * p``) this is the classic
    worst case for minimal routing: every flow leaving group g wants
    the *single* global channel g -> g+shift, so that one link carries
    ``hosts_per_group`` line-rate flows while every other global
    channel idles.  Valiant/UGAL detours spread the same traffic over
    two hops through random intermediate groups — the scenario where
    non-minimal routing must win.  (The pattern is fabric-agnostic:
    hosts are numbered group-major, matching the dragonfly layout.)
    """
    if n_groups < 2 or shift % n_groups == 0:
        raise ValueError(f"need >= 2 groups and a non-identity shift, "
                         f"got {n_groups} groups, shift {shift}")
    n = n_groups * hosts_per_group
    src = list(range(n))
    dst = [((g + shift) % n_groups) * hosts_per_group + j
           for g in range(n_groups) for j in range(hosts_per_group)]
    stop = INF if np.isfinite(volume) else t_stop
    return _mk(src, dst, [t_start] * n, [stop] * n, [volume] * n,
               label=f"gshift{n_groups}x{hosts_per_group}s{shift}")


def hotspot(n_flows: int, n_nodes: int, *, hot_frac: float = 0.5,
            hot_node: int = 0, bg_rate_frac: float = 0.5,
            t_start: float = 0.5e-3, t_stop: float = 3e-3,
            seed: int = 0) -> Workload:
    """Hotspot mix: ``hot_frac`` of the flows converge on ``hot_node``
    at line rate; the rest are random-pair background at
    ``bg_rate_frac`` of line rate (the tenants a throttler must not
    collaterally damage).  Rates use the config-agnostic sentinels
    (inf = line rate, -f = fraction f of it), so the workload tracks
    whatever line rate the scenario builds against."""
    rng = np.random.RandomState(seed)
    n_hot = int(round(n_flows * hot_frac))
    src, dst, rate = [], [], []
    others = [v for v in range(n_nodes) if v != hot_node]
    for i in range(n_hot):
        src.append(others[int(rng.randint(len(others)))])
        dst.append(hot_node)
        rate.append(INF)
    for i in range(n_flows - n_hot):
        s = int(rng.randint(n_nodes))
        d = int(rng.randint(n_nodes - 1))
        d = d + 1 if d >= s else d
        src.append(s)
        dst.append(d)
        rate.append(-bg_rate_frac)
    n = len(src)
    return _mk(src, dst, [t_start] * n, [t_stop] * n, [INF] * n, rate,
               label=f"hot{n_flows}f{hot_frac:g}")


def bursty(n_flows: int, n_nodes: int, *, on: float = 0.3e-3,
           off: float = 0.7e-3, n_bursts: int = 3, t_start: float = 0.0,
           jitter: float = 0.5, seed: int = 0) -> Workload:
    """Bursty on/off arrivals: each of ``n_flows`` random pairs fires
    ``n_bursts`` line-rate bursts of ``on`` seconds separated by ``off``
    seconds, with per-flow phase jitter — every burst is its own
    window-mode flow entry sharing the pair's route."""
    rng = np.random.RandomState(seed)
    src, dst, t0, t1 = [], [], [], []
    period = on + off
    for f in range(n_flows):
        s = int(rng.randint(n_nodes))
        d = int(rng.randint(n_nodes - 1))
        d = d + 1 if d >= s else d
        phase = float(rng.rand()) * jitter * period
        for b in range(n_bursts):
            t0.append(t_start + phase + b * period)
            t1.append(t0[-1] + on)
            src.append(s)
            dst.append(d)
    n = len(src)
    return _mk(src, dst, t0, t1, [INF] * n,
               label=f"burst{n_flows}x{n_bursts}")
