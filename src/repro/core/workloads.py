"""Collective-workload generator: communication patterns of real apps.

The paper's verdicts hinge on congestion *dynamics*, which are set by
what applications actually do on the wire — collectives, incast storms,
hotspots, bursts — not just the §II 5-flow scene.  Each generator here
emits a ``Workload``: plain per-flow tuples (src, dst, start, stop,
volume, rate) that compile through ``ScenarioSpec.from_workload`` to
the padded/stackable ``Scenario`` tensors, so any (fabric x workload)
point drops straight into one-jit ``Sweep`` evaluation:

    from repro.core import PAPER_CONFIG, CCScheme, Sweep
    from repro.core.workloads import ring_allreduce, incast_storm
    from repro.net import FabricSpec

    fab = FabricSpec.fat_tree(4, taper=2)
    Sweep.grid(
        configs={s.name: PAPER_CONFIG.replace(scheme=s) for s in CCScheme},
        scenarios={
            "ring": ring_allreduce(16, 8e6).spec(fabric=fab),
            "storm": incast_storm(24, 4, 64, volume=2e6).spec(fabric=fab),
        }).run()

Phases are modelled by staggered start times (the fluid model has no
inter-flow dependencies): phase p opens at ``t0 + p * phase_gap``,
with ``phase_gap`` defaulting to the slack-scaled serialisation time
of one phase's bytes at line rate.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .experiments import ScenarioSpec

LINE_RATE = 12.5e9            # B/s default for phase-gap sizing only
INF = float("inf")


@dataclasses.dataclass(frozen=True)
class Workload:
    """Per-flow traffic description as plain (hashable) tuples."""

    src: tuple[int, ...]
    dst: tuple[int, ...]
    t_start: tuple[float, ...]
    t_stop: tuple[float, ...]          # inf with finite volume = work mode
    volume: tuple[float, ...]          # bytes; inf = window-limited
    # B/s per flow; None = all at line rate.  Workloads are built before
    # the config's line rate is known, so two sentinels resolve at
    # ``build(cfg)`` time: an entry of inf means "line rate", and a
    # negative entry -f means "fraction f of line rate".
    rate: tuple[float, ...] | None = None
    label: str = "workload"
    # victim designation for the PFC-pathology metrics: flows that do
    # NOT cause the congestion under test but share fabric with it
    # (compiled to ``Scenario.victim``, aggregated by
    # ``SimResult.victim_slowdown``).  Empty = no designated victims.
    victim: tuple[bool, ...] = ()
    # per-flow virtual-channel pin (compiled to ``ScenarioSpec.flow_vc``;
    # clipped to the config's ``LinkParams.n_vcs``).  Empty = the
    # spec's ``vc_mode`` rule decides.
    vc: tuple[int, ...] = ()

    @property
    def n_flows(self) -> int:
        return len(self.src)

    def spec(self, fabric=None, **kw) -> ScenarioSpec:
        """Compile onto a fabric (see ScenarioSpec.from_workload)."""
        return ScenarioSpec.from_workload(self, fabric=fabric, **kw)

    def __post_init__(self):
        n = len(self.src)
        for f in ("dst", "t_start", "t_stop", "volume"):
            if len(getattr(self, f)) != n:
                raise ValueError(f"{f} has {len(getattr(self, f))} entries "
                                 f"for {n} flows")
        if self.rate is not None and len(self.rate) != n:
            raise ValueError("rate length mismatch")
        for f in ("victim", "vc"):
            if getattr(self, f) and len(getattr(self, f)) != n:
                raise ValueError(f"{f} length mismatch")


def concat(*workloads: Workload, label: str | None = None) -> Workload:
    """Mix workloads into one (e.g. a collective + background traffic)."""
    if not workloads:
        raise ValueError("nothing to concat")
    rates = [w.rate or (INF,) * w.n_flows for w in workloads]
    vics = [w.victim or (False,) * w.n_flows for w in workloads]
    vcs = [w.vc or (0,) * w.n_flows for w in workloads]
    any_vic = any(any(v) for v in vics)
    any_vc = any(any(v) for v in vcs)
    return Workload(
        src=sum((w.src for w in workloads), ()),
        dst=sum((w.dst for w in workloads), ()),
        t_start=sum((w.t_start for w in workloads), ()),
        t_stop=sum((w.t_stop for w in workloads), ()),
        volume=sum((w.volume for w in workloads), ()),
        rate=sum((tuple(r) for r in rates), ()),
        victim=sum((tuple(v) for v in vics), ()) if any_vic else (),
        vc=sum((tuple(v) for v in vcs), ()) if any_vc else (),
        label=label or "+".join(w.label for w in workloads))


def _mk(src, dst, t0, t1, vol, rate=None, label="workload",
        victim=None, vc=None) -> Workload:
    return Workload(
        src=tuple(int(s) for s in src), dst=tuple(int(d) for d in dst),
        t_start=tuple(float(t) for t in t0),
        t_stop=tuple(float(t) for t in t1),
        volume=tuple(float(v) for v in vol),
        rate=None if rate is None else tuple(float(r) for r in rate),
        victim=() if victim is None else tuple(bool(v) for v in victim),
        vc=() if vc is None else tuple(int(v) for v in vc),
        label=label)


def _gap(bytes_per_flow: float, line_rate: float, slack: float) -> float:
    return slack * bytes_per_flow / line_rate


# ---------------------------------------------------------------------------
# collectives
# ---------------------------------------------------------------------------


def all_to_all(n_nodes: int, volume: float, *, phases: int | None = None,
               phase_gap: float | None = None, t_start: float = 0.0,
               line_rate: float = LINE_RATE, slack: float = 2.0,
               nodes=None) -> Workload:
    """Phased all-to-all: shift k sends node i -> (i+k) % n.

    The n-1 shifts are spread over ``phases`` groups (default: one
    phase per shift, the classic ring-ordered exchange); each phase
    opens ``phase_gap`` after the previous.  ``volume`` is bytes per
    (src, dst) pair; ``nodes`` restricts the participant set.
    """
    nodes = list(range(n_nodes)) if nodes is None else list(nodes)
    n = len(nodes)
    if n < 2:
        raise ValueError("all_to_all needs >= 2 participants")
    phases = n - 1 if phases is None else int(phases)
    if not 1 <= phases <= n - 1:
        raise ValueError(f"phases must be in [1, {n - 1}]")
    shifts_per_phase = -(-(n - 1) // phases)
    if phase_gap is None:
        phase_gap = _gap(volume * shifts_per_phase, line_rate, slack)
    src, dst, t0 = [], [], []
    for k in range(1, n):
        p = (k - 1) % phases
        for i in range(n):
            src.append(nodes[i])
            dst.append(nodes[(i + k) % n])
            t0.append(t_start + p * phase_gap)
    return _mk(src, dst, t0, [INF] * len(src), [volume] * len(src),
               label=f"a2a{n}p{phases}")


def ring_allreduce(n_nodes: int, bytes_total: float, *,
                   phased: bool = False, phase_gap: float | None = None,
                   t_start: float = 0.0, line_rate: float = LINE_RATE,
                   slack: float = 2.0, nodes=None) -> Workload:
    """Ring allreduce: reduce-scatter + allgather over neighbour links.

    Unphased (default): each node's 2(n-1) chunk sends to its ring
    successor coalesce into one volume-mode flow of 2(n-1)/n * S bytes
    — the collective's true per-link traffic.  ``phased=True`` emits
    all 2(n-1) steps as separate staggered flows (n flows per step).
    """
    nodes = list(range(n_nodes)) if nodes is None else list(nodes)
    n = len(nodes)
    if n < 2:
        raise ValueError("ring needs >= 2 participants")
    chunk = bytes_total / n
    succ = [nodes[(i + 1) % n] for i in range(n)]
    if not phased:
        vol = 2 * (n - 1) * chunk
        return _mk(nodes, succ, [t_start] * n, [INF] * n, [vol] * n,
                   label=f"ring{n}")
    if phase_gap is None:
        phase_gap = _gap(chunk, line_rate, slack)
    src, dst, t0 = [], [], []
    for step in range(2 * (n - 1)):
        for i in range(n):
            src.append(nodes[i])
            dst.append(succ[i])
            t0.append(t_start + step * phase_gap)
    return _mk(src, dst, t0, [INF] * len(src), [chunk] * len(src),
               label=f"ring{n}phased")


def recursive_doubling_allreduce(n_nodes: int, bytes_total: float, *,
                                 phase_gap: float | None = None,
                                 t_start: float = 0.0,
                                 line_rate: float = LINE_RATE,
                                 slack: float = 2.0,
                                 nodes=None) -> Workload:
    """Recursive-doubling allreduce: log2(n) rounds of pairwise
    exchanges at distance 2^r, each carrying the full vector.

    The distance doubles every round, so successive rounds climb the
    fabric — late rounds are the bisection-stressing ones.
    """
    nodes = list(range(n_nodes)) if nodes is None else list(nodes)
    n = len(nodes)
    if n < 2 or n & (n - 1):
        raise ValueError(f"recursive doubling needs a power-of-two "
                         f"participant count, got {n}")
    if phase_gap is None:
        phase_gap = _gap(bytes_total, line_rate, slack)
    src, dst, t0 = [], [], []
    rounds = n.bit_length() - 1
    for r in range(rounds):
        for i in range(n):
            src.append(nodes[i])
            dst.append(nodes[i ^ (1 << r)])
            t0.append(t_start + r * phase_gap)
    return _mk(src, dst, t0, [INF] * len(src), [bytes_total] * len(src),
               label=f"rdbl{n}")


# ---------------------------------------------------------------------------
# storms, hotspots, bursts
# ---------------------------------------------------------------------------


def incast_storm(n_senders: int, n_receivers: int, n_nodes: int, *,
                 volume: float = INF, t_start: float = 1e-3,
                 t_stop: float = 3e-3, seed: int = 0) -> Workload:
    """n-to-m incast: ``n_senders`` sources fan into ``n_receivers``
    sinks round-robin (each sink absorbs ~n/m flows).  With a finite
    ``volume`` the storm is equal-work; otherwise window-mode."""
    if n_senders + n_receivers > n_nodes:
        raise ValueError(f"{n_senders}+{n_receivers} endpoints exceed "
                         f"{n_nodes} hosts")
    rng = np.random.RandomState(seed)
    picks = rng.permutation(n_nodes)[: n_senders + n_receivers]
    recv, send = picks[:n_receivers], picks[n_receivers:]
    dst = [int(recv[i % n_receivers]) for i in range(n_senders)]
    stop = INF if np.isfinite(volume) else t_stop
    return _mk(send, dst, [t_start] * n_senders, [stop] * n_senders,
               [volume] * n_senders,
               label=f"storm{n_senders}to{n_receivers}")


def group_shift(n_groups: int, hosts_per_group: int, *, shift: int = 1,
                volume: float = INF, t_start: float = 0.0,
                t_stop: float = 3e-3) -> Workload:
    """Adversarial group-shifted permutation: host j of group g sends
    to host j of group (g + shift) % n_groups.

    On a dragonfly (``hosts_per_group = a * p``) this is the classic
    worst case for minimal routing: every flow leaving group g wants
    the *single* global channel g -> g+shift, so that one link carries
    ``hosts_per_group`` line-rate flows while every other global
    channel idles.  Valiant/UGAL detours spread the same traffic over
    two hops through random intermediate groups — the scenario where
    non-minimal routing must win.  (The pattern is fabric-agnostic:
    hosts are numbered group-major, matching the dragonfly layout.)
    """
    if n_groups < 2 or shift % n_groups == 0:
        raise ValueError(f"need >= 2 groups and a non-identity shift, "
                         f"got {n_groups} groups, shift {shift}")
    n = n_groups * hosts_per_group
    src = list(range(n))
    dst = [((g + shift) % n_groups) * hosts_per_group + j
           for g in range(n_groups) for j in range(hosts_per_group)]
    stop = INF if np.isfinite(volume) else t_stop
    return _mk(src, dst, [t_start] * n, [stop] * n, [volume] * n,
               label=f"gshift{n_groups}x{hosts_per_group}s{shift}")


def hotspot(n_flows: int, n_nodes: int, *, hot_frac: float = 0.5,
            hot_node: int = 0, bg_rate_frac: float = 0.5,
            t_start: float = 0.5e-3, t_stop: float = 3e-3,
            seed: int = 0) -> Workload:
    """Hotspot mix: ``hot_frac`` of the flows converge on ``hot_node``
    at line rate; the rest are random-pair background at
    ``bg_rate_frac`` of line rate (the tenants a throttler must not
    collaterally damage).  Rates use the config-agnostic sentinels
    (inf = line rate, -f = fraction f of it), so the workload tracks
    whatever line rate the scenario builds against."""
    rng = np.random.RandomState(seed)
    n_hot = int(round(n_flows * hot_frac))
    src, dst, rate = [], [], []
    others = [v for v in range(n_nodes) if v != hot_node]
    for i in range(n_hot):
        src.append(others[int(rng.randint(len(others)))])
        dst.append(hot_node)
        rate.append(INF)
    for i in range(n_flows - n_hot):
        s = int(rng.randint(n_nodes))
        d = int(rng.randint(n_nodes - 1))
        d = d + 1 if d >= s else d
        src.append(s)
        dst.append(d)
        rate.append(-bg_rate_frac)
    n = len(src)
    return _mk(src, dst, [t_start] * n, [t_stop] * n, [INF] * n, rate,
               label=f"hot{n_flows}f{hot_frac:g}")


def bursty(n_flows: int, n_nodes: int, *, on: float = 0.3e-3,
           off: float = 0.7e-3, n_bursts: int = 3, t_start: float = 0.0,
           jitter: float = 0.5, seed: int = 0) -> Workload:
    """Bursty on/off arrivals: each of ``n_flows`` random pairs fires
    ``n_bursts`` line-rate bursts of ``on`` seconds separated by ``off``
    seconds, with per-flow phase jitter — every burst is its own
    window-mode flow entry sharing the pair's route."""
    rng = np.random.RandomState(seed)
    src, dst, t0, t1 = [], [], [], []
    period = on + off
    for f in range(n_flows):
        s = int(rng.randint(n_nodes))
        d = int(rng.randint(n_nodes - 1))
        d = d + 1 if d >= s else d
        phase = float(rng.rand()) * jitter * period
        for b in range(n_bursts):
            t0.append(t_start + phase + b * period)
            t1.append(t0[-1] + on)
            src.append(s)
            dst.append(d)
    n = len(src)
    return _mk(src, dst, t0, t1, [INF] * n,
               label=f"burst{n_flows}x{n_bursts}")


# ---------------------------------------------------------------------------
# PFC pathologies (victim-flagged scenarios for the injection-throttling
# comparisons: HOL blocking, pause cascades, credit loops)
# ---------------------------------------------------------------------------


def hol_victim_incast(n_senders: int, n_nodes: int, *,
                      leaf_arity: int = 4, hot: int | None = None,
                      victim_rate: float = -0.3,
                      victim_delay: float = 1e-3,
                      burst_delay: float = 1.5e-3,
                      t_start: float = 1e-3,
                      t_stop: float = 5e-3) -> Workload:
    """Head-of-line-blocking incast with one designated victim flow.

    Two-wave geometry, built so the three throttling philosophies land
    in their characteristic order on the victim:

      * wave A — ``n_senders - 1`` line-rate sources, one per leaf
        (skipping leaf 0 and the hot leaf), open at ``t_start`` and
        converge onto host ``hot``;
      * the victim — last slot of leaf 0, at a *modest*
        ``victim_rate`` — joins at ``t_start + victim_delay``, once a
        working throttler has the incast under control;
      * wave B — one more line-rate sender on leaf 0 — lands at
        ``t_start + burst_delay``, slamming the victim's own uplink
        wire through the marking threshold.

    The CLOS route tables hash a flow's uplink slot by ``dst %
    leaf_arity``, so the victim's sink (on a third, uninvolved leaf)
    is chosen congruent to ``hot``: the victim rides exactly the wire
    wave B saturates while its own NIC stays idle — the paper's F3 =
    N3 -> N12 against N16, generalised.  Under PFC-only the shared
    wire is simply xoff-paused, stalling the victim outright; DCQCN's
    occupancy marking (cp) cannot tell the victim from the burst and
    cuts both, then recovers it at the glacial additive-increase rate;
    the refined grant-aware marking (ecp) sees the victim below its
    fair share and spares it.  Hence the scenario's defining metric
    ordering ``victim_slowdown: REV < DCQCN < PFC_ONLY``.  The victim
    is flagged in ``Workload.victim`` so ``SimResult.victim_slowdown``
    reports it directly (hosts are numbered leaf-major, as on the CLOS
    fabrics)."""
    if n_senders < 2:
        raise ValueError("need >= 2 senders (wave A + the wave-B burst)")
    hot = n_nodes - 1 if hot is None else int(hot)
    A = leaf_arity
    hot_leaf, n_leaves = hot // A, n_nodes // A
    if n_leaves < 3 or hot_leaf == 0:
        raise ValueError("need >= 3 leaves with the hot host off leaf 0")
    v_src = A - 1                                  # last slot of leaf 0
    v_leaf = next(g for g in range(1, n_leaves) if g != hot_leaf)
    v_dst = v_leaf * A + hot % A                   # collides by dst-hash
    wave_a = [g * A + s for s in range(A - 1)
              for g in range(1, n_leaves)
              if g != hot_leaf and g * A + s != v_dst][:n_senders - 1]
    if len(wave_a) < n_senders - 1:
        raise ValueError(f"{n_nodes} hosts / arity {A} fit only "
                         f"{len(wave_a)} wave-A senders, need "
                         f"{n_senders - 1}")
    wave_b = [0]                                   # leaf-0 slot 0
    src = wave_a + wave_b + [v_src]
    dst = [hot] * n_senders + [v_dst]
    t0 = ([t_start] * len(wave_a) + [t_start + burst_delay]
          + [t_start + victim_delay])
    n = n_senders + 1
    return _mk(src, dst, t0, [t_stop] * n, [INF] * n,
               [INF] * n_senders + [victim_rate],
               victim=[False] * n_senders + [True],
               label=f"holvictim{n_senders}")


def pause_storm(n_stages: int, fan: int, n_nodes: int, *,
                leaf_arity: int = 4, stage_gap: float = 0.3e-3,
                victim_rate: float = INF, t_start: float = 1e-3,
                t_stop: float = 4e-3) -> Workload:
    """Pause-storm cascade: staggered incast waves widening the paused
    region stage by stage.

    Stage s (at ``t_start + s * stage_gap``) aims ``fan`` line-rate
    senders at the s-th host of the hot leaf, so each wave adds another
    saturated downlink behind the same last-hop switch: xoff trips
    wire by wire and the pause front climbs into the spine instead of
    staying put.  ``n_stages`` through-flows from the
    sender leaves to an *uninvolved* sink leaf are flagged victims —
    their sinks stay idle the whole run, but every wave widens the
    paused region their traffic must cross.  ``SimResult.pause_duration``
    on this workload measures the cascade directly."""
    n_leaves = (n_nodes + leaf_arity - 1) // leaf_arity
    if n_leaves < 3:
        raise ValueError("pause_storm needs >= 3 leaves (hot leaf, "
                         "sender leaves, victim-sink leaf)")
    hot_hosts = list(range((n_leaves - 1) * leaf_arity, n_nodes))
    sink_hosts = list(range((n_leaves - 2) * leaf_arity,
                            (n_leaves - 1) * leaf_arity))
    pool = list(range((n_leaves - 2) * leaf_arity))  # sender/victim srcs
    src, dst, t0, t1, rate, victim = [], [], [], [], [], []
    k = 0
    for s in range(n_stages):
        start = t_start + s * stage_gap
        for _ in range(fan):
            src.append(pool[k % len(pool)])
            dst.append(hot_hosts[s % len(hot_hosts)])
            t0.append(start)
            t1.append(t_stop)
            rate.append(INF)
            victim.append(False)
            k += 1
    for s in range(n_stages):                     # through-flow victims
        src.append(pool[(k + s) % len(pool)])
        dst.append(sink_hosts[s % len(sink_hosts)])
        t0.append(t_start * 0.5)                  # up before the storm
        t1.append(t_stop)
        rate.append(victim_rate)
        victim.append(True)
    n = len(src)
    return _mk(src, dst, t0, t1, [INF] * n, rate, victim=victim,
               label=f"pausestorm{n_stages}x{fan}")


def credit_loop(n_groups: int, hosts_per_group: int, *, shift: int = 1,
                probe_rate: float = -0.25, volume: float = INF,
                t_start: float = 0.0, t_stop: float = 3e-3) -> Workload:
    """Dragonfly credit-loop: cyclic backpressure around the global
    channels, with probe flows as victims.

    Hosts ``j < hosts_per_group - 1`` of group g send to the same slot
    of group ``g + shift``, saturating the cyclic chain of global
    channels g -> g+shift -> g+2*shift -> ... -> g.  Under PFC the xoff
    backpressure circulates that same cycle — the fluid-model analogue
    of a credit-loop deadlock: pauses feed themselves and throughput
    collapses even though every queue would drain if any one link were
    released.  The last host of each group sends a ``probe_rate`` probe
    ``shift + 1`` groups ahead (riding the paused global channels but
    sinking elsewhere) and is flagged victim.  Compiling the spec with
    ``vc_mode="hop"`` and ``n_vcs >= 2`` breaks the cycle: later hops
    escalate to a higher VC (dateline rule), so the pause loop cannot
    close — the per-VC story this scenario exists to exercise.
    Hosts are numbered group-major, matching the dragonfly layout."""
    if n_groups < 3:
        raise ValueError("credit loop needs >= 3 groups to form a cycle")
    if hosts_per_group < 2:
        raise ValueError("need >= 2 hosts/group (loop + probe slots)")
    if shift % n_groups == 0:
        raise ValueError("identity shift closes no cycle")
    src, dst, rate, victim, vol = [], [], [], [], []
    for g in range(n_groups):
        for j in range(hosts_per_group - 1):
            src.append(g * hosts_per_group + j)
            dst.append(((g + shift) % n_groups) * hosts_per_group + j)
            rate.append(INF)
            victim.append(False)
            vol.append(volume)
    j = hosts_per_group - 1
    for g in range(n_groups):
        src.append(g * hosts_per_group + j)
        dst.append(((g + shift + 1) % n_groups) * hosts_per_group + j)
        rate.append(probe_rate)
        victim.append(True)
        vol.append(INF)                           # probes stay window-mode
    n = len(src)
    stop = INF if np.isfinite(volume) else t_stop
    t1 = [stop] * (n - n_groups) + [t_stop] * n_groups
    return _mk(src, dst, [t_start] * n, t1, vol, rate, victim=victim,
               label=f"creditloop{n_groups}x{hosts_per_group}")
