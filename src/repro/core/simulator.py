"""Scan driver + result analysis for the CC fluid model.

``run`` advances one (scenario, config) point; the scan body decimates
traces on device (one ``TraceSample`` per ``trace_every`` steps), so the
trace memory pulled to host shrinks by that factor.  Batched sweeps live
in ``experiments.py`` and share the same scan body.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .fluid import (FluidState, Scenario, StepTrace, init_state,
                    make_step_fn)
from .params import CCConfig, CCScheme


class TraceSample(StepTrace):
    """One decimated trace sample covering ``trace_every`` sim steps.

    Cumulative fields (``delivered``, ``rate``) are the window's last
    step, i.e. a strided sample of the full trace; ``inst_thr`` is the
    window-mean delivery rate; ``max_q`` / ``n_paused`` / ``n_nonmin``
    are window maxima; ``marked`` / ``cnp`` are window event *counts*
    (so sums over the decimated trace equal sums over the full one);
    ``ctrl`` is the window *sum* of notification emissions — a float,
    because the soft model (``StepParams.temperature > 0``) emits
    fractional control traffic.  ``pause_time`` / ``vc_stall`` are
    window *sums* of pause wire-seconds (total / per VC), so run totals
    are decimation-invariant too.
    """


def _zero_accum(st: FluidState, n_vcs: int = 1):
    # shapes follow the state so the same scan body serves single runs
    # ([] / [F]) and batched sweeps ([R] / [R, F]).  ``n_vcs`` is passed
    # explicitly: the [V] per-VC stall accumulator cannot be told apart
    # from the flat [L * V] pause vector by shape alone.
    return (jnp.zeros_like(st.t, jnp.float32),    # max_q
            jnp.zeros_like(st.t, jnp.int32),      # n_paused
            jnp.zeros_like(st.nicq, jnp.int32),   # marked
            jnp.zeros_like(st.nicq, jnp.int32),   # cnp
            jnp.zeros_like(st.t, jnp.int32),      # n_nonmin
            jnp.zeros_like(st.nicq, jnp.float32),  # ctrl
            jnp.zeros_like(st.t, jnp.float32),    # pause_time
            jnp.zeros(st.t.shape + (n_vcs,), jnp.float32))  # vc_stall


def _acc_update(acc, tr: StepTrace):
    """Fold one step's trace into the window accumulators.

    Shared by the host-side decimating scan AND the megakernel's
    in-kernel dt-scan (``repro.kernels.fluid_step.megastep_block``) —
    the single definition is what keeps the two trace paths bitwise
    identical."""
    mq, npz, mk, cn, nm, ct, pt, vs = acc
    return (jnp.maximum(mq, tr.max_q),
            jnp.maximum(npz, tr.n_paused),
            mk + tr.marked.astype(jnp.int32),
            cn + tr.cnp.astype(jnp.int32),
            jnp.maximum(nm, tr.n_nonmin),
            ct + tr.ctrl,
            pt + tr.pause_time,
            vs + tr.vc_stall)


def _window_sample(st: FluidState, d0, acc, trace_every: int,
                   dt: float) -> TraceSample:
    """One TraceSample from the window-end state + accumulators."""
    mq, npz, mk, cn, nm, ct, pt, vs = acc
    return TraceSample(
        delivered=st.delivered, rate=st.rate,
        inst_thr=(st.delivered - d0) / jnp.float32(trace_every * dt),
        max_q=mq, n_paused=npz, marked=mk, cnp=cn, n_nonmin=nm,
        ctrl=ct, pause_time=pt, vc_stall=vs)


def decimating_scan(step, st: FluidState, n_samples: int,
                    trace_every: int, dt: float, n_vcs: int = 1, *,
                    block_fn=None):
    """Run ``n_samples * trace_every`` steps, emitting one TraceSample
    per ``trace_every`` steps.  Accumulation happens inside the scan, so
    the full-resolution trace never materialises.

    ``block_fn`` replaces the inner per-step scan with one call per
    trace window (``block_fn(state) -> (state, TraceSample)``) — the
    megakernel's whole-window launch; the outer scan then just chains
    windows.  ``step``/``trace_every``/``dt``/``n_vcs`` are unused in
    that form (the block closes over them)."""
    if block_fn is not None:
        return jax.lax.scan(lambda s, _: block_fn(s), st, None,
                            length=n_samples)

    def outer(st, _):
        d0 = st.delivered

        def inner(carry, _):
            stt = carry[0]
            st2, tr = step(stt)
            return (st2,) + _acc_update(carry[1:], tr), None

        (st, *acc), _ = jax.lax.scan(
            inner, (st,) + _zero_accum(st, n_vcs), None,
            length=trace_every)
        return st, _window_sample(st, d0, tuple(acc), trace_every, dt)

    return jax.lax.scan(outer, st, None, length=n_samples)


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4, 5))
def _run_scan(state: FluidState, step_fn, n_samples: int,
              trace_every: int, dt: float, n_vcs: int = 1):
    return decimating_scan(step_fn, state, n_samples, trace_every, dt,
                           n_vcs)


@functools.partial(jax.jit, static_argnums=(1, 2))
def _run_block_scan(state: FluidState, block_fn, n_samples: int):
    return decimating_scan(None, state, n_samples, 0, 0.0,
                           block_fn=block_fn)


def make_block_fn(scn: Scenario, cfg: CCConfig, trace_every: int, *,
                  reduce: str = "fused", dense_rows: int | None = None,
                  interpret: bool = False):
    """Megakernel analogue of ``make_step_fn``: one whole trace window
    per launch.

    Returns ``block(state) -> (state, TraceSample)`` running
    ``trace_every`` substeps inside a single ``pallas_call`` with the
    fluid state VMEM-resident throughout (see
    ``repro.kernels.fluid_step.megastep_block``); only the decimated
    sample row leaves the kernel.  The accumulation functions are the
    exact ones ``decimating_scan`` uses, so traces are bit-identical to
    the per-step path.
    """
    from .fluid import (check_routing_paths, dense_reduce_rows,
                        scenario_device, step_body_fn, step_params)
    from repro.kernels.fluid_step import megastep_block
    check_routing_paths(cfg, scn)
    n_vcs = int(getattr(cfg.link, "n_vcs", 1))
    sd = scenario_device(scn, n_vcs=n_vcs)
    par = step_params(cfg)
    dt = float(cfg.sim.dt)
    if dense_rows is None:
        dense_rows = dense_reduce_rows(scn, n_vcs) \
            if reduce == "fused" else 0
    body = step_body_fn(dt=dt, n_switches=int(scn.n_switches),
                        reduce=reduce, dense_rows=dense_rows,
                        n_vcs=n_vcs)

    def block(st: FluidState):
        return megastep_block(
            st, sd, par, body=body, n_substeps=trace_every,
            acc_init=_zero_accum, acc_update=_acc_update,
            make_sample=_window_sample, n_vcs=n_vcs, dt=dt,
            interpret=interpret)

    return block


def _resolve_steps(cfg: CCConfig, n_steps: int | None,
                   trace_every: int | None) -> tuple[int, int]:
    if n_steps is None:
        n_steps = int(round(cfg.sim.t_end / cfg.sim.dt))
    k = cfg.sim.trace_every if trace_every is None else trace_every
    k = max(1, int(k))
    n_samples = -(-n_steps // k)          # ceil: round the run up to a
    return n_samples, k                   # whole number of samples


@dataclasses.dataclass
class SimResult:
    """Host-side view of a finished run.

    Trace arrays are decimated by ``trace_every`` (see TraceSample for
    the per-field semantics); ``times`` marks each sample's window end.
    """

    cfg: CCConfig
    scn: Scenario
    times: np.ndarray          # [T] seconds (window-end times)
    delivered: np.ndarray      # [T, F] cumulative bytes
    rate: np.ndarray           # [T, F] RP rate (B/s)
    inst_thr: np.ndarray       # [T, F] window-mean delivery rate (B/s)
    max_q: np.ndarray          # [T] window-max hottest queue (bytes)
    n_paused: np.ndarray       # [T] window-max paused wires
    marked: np.ndarray         # [T, F] marking events in window
    cnp: np.ndarray            # [T, F] CNPs received in window
    n_nonmin: np.ndarray       # [T] window-max flows on non-minimal paths
    final: Any                 # FluidState (host)
    ctrl: np.ndarray = None    # [T, F] notification emissions in window
    trace_every: int = 1
    # PFC-pathology instrumentation (None on traces that predate it):
    pause_time: np.ndarray = None  # [T] pause wire-seconds in window
    vc_stall: np.ndarray = None    # [T, V] per-VC pause wire-seconds

    # -- wire format --------------------------------------------------------
    def to_dict(self, *, traces: bool = True, decimate: int = 1) -> dict:
        """JSON-ready dict (numpy-free scalars, tagged arrays).

        ``traces=False`` drops the trace arrays; ``decimate=k`` thins
        them by a further factor k.  The full form round-trips through
        ``json.dumps``/``loads`` + :meth:`from_dict` bit-exactly (see
        ``repro.core.serialize``)."""
        from .serialize import simresult_to_dict
        return simresult_to_dict(self, traces=traces, decimate=decimate)

    @classmethod
    def from_dict(cls, d: dict) -> "SimResult":
        from .serialize import simresult_from_dict
        return simresult_from_dict(d)

    # -- derived metrics ----------------------------------------------------
    def window_samples(self, seconds: float) -> int:
        """Trace samples spanning ``seconds`` (smoothing windows should
        be specified in time, not samples — sample spacing depends on
        ``trace_every``)."""
        dt_sample = self.trace_every * self.cfg.sim.dt
        return max(1, int(round(seconds / dt_sample)))

    def flow_throughput(self, window: int = 50) -> np.ndarray:
        """[T, F] delivery rate smoothed over `window` samples (B/s).

        Box filter over the sample axis via cumulative sums (equivalent
        to per-flow ``np.convolve(..., mode="same")`` but one vectorised
        pass over [T, F] instead of an O(F) python loop).
        """
        x = self.inst_thr.astype(np.float64)   # f32 cumsum would drift
        T = x.shape[0]
        w = max(1, min(window, T))
        c = np.concatenate([np.zeros((1,) + x.shape[1:]), np.cumsum(x, 0)])
        # same-mode box filter: sample t averages [t - w//2, t + (w-1)//2]
        lo = np.clip(np.arange(T) - w // 2, 0, T)
        hi = np.clip(np.arange(T) + (w - 1) // 2 + 1, 0, T)
        return (c[hi] - c[lo]) / w

    def aggregate_throughput(self, window: int = 50) -> np.ndarray:
        return self.flow_throughput(window).sum(axis=1)

    def completion_times(self, frac: float = 0.999) -> np.ndarray:
        """[F] time when `frac` of the flow's work was delivered.

        Volume-mode flows are measured against their declared volume
        (NaN if the run ended early); window-mode flows against the
        admitted bytes.  ``delivered`` is monotone per flow, so the
        first crossing is a vectorised argmax over the sample axis."""
        offered = np.asarray(self.final.offered)
        vol = np.asarray(self.scn.volume, dtype=np.float64)
        total = np.where(np.isfinite(vol), vol, offered)
        done = self.delivered >= frac * np.maximum(total, 1e-300)[None, :]
        first = done.argmax(axis=0)                   # 0 if never done too
        hit = done.any(axis=0) & (total > 0)
        return np.where(hit, self.times[first], np.nan)

    def completion_time(self, frac: float = 0.999) -> float:
        ct = self.completion_times(frac)
        return float(np.nanmax(ct)) if np.isfinite(ct).any() else float("nan")

    def mean_throughput_while_active(self) -> np.ndarray:
        """[F] mean delivery rate while the flow is live.

        Window mode: averaged over [t_start, t_stop).  Volume mode
        (t_stop = inf): volume / (completion - t_start).
        """
        t0 = np.asarray(self.scn.t_start, np.float64)
        t1 = np.asarray(self.scn.t_stop, np.float64)
        ct = self.completion_times()
        windowed = np.isfinite(t1)
        live = ((self.times[:, None] >= t0[None, :])
                & (self.times[:, None] < t1[None, :]))          # [T, F]
        n_live = live.sum(axis=0)
        mean_w = np.where(n_live > 0,
                          (self.inst_thr * live).sum(axis=0)
                          / np.maximum(n_live, 1), 0.0)
        span = ct - t0
        mean_v = np.where(np.isfinite(ct) & (span > 0),
                          self.delivered[-1] / np.maximum(span, 1e-300), 0.0)
        return np.where(windowed, mean_w, mean_v)

    def _real_flows(self) -> np.ndarray:
        """[F] bool — flows with actual offered work (padding rows in
        stacked sweeps carry zero rate and are excluded from
        fairness/tail statistics)."""
        return np.asarray(self.scn.gen_rate) > 0

    def jain_index(self) -> float:
        """Jain fairness over per-flow goodput while active, in [0, 1].

        1 = all real flows saw the same rate; 1/n = one flow took
        everything.  A first-class tuner objective (repro.tune).
        """
        thr = self.mean_throughput_while_active()[self._real_flows()]
        n = thr.size
        if n == 0:
            return float("nan")
        denom = n * float((thr ** 2).sum())
        return float(thr.sum()) ** 2 / denom if denom > 0 else 1.0

    def flow_slowdowns(self) -> np.ndarray:
        """[F_real] demand-normalised slowdown per real flow (>= ~1).

        Ideal rate = min(offered rate, line rate); slowdown = ideal /
        achieved mean rate while active — the fluid-model analogue of
        FCT slowdown (a flow throttled to half its unconstrained rate
        scores 2).
        """
        real = self._real_flows()
        thr = self.mean_throughput_while_active()[real]
        ideal = np.minimum(np.asarray(self.scn.gen_rate),
                           self.cfg.link.line_rate)[real]
        return ideal / np.maximum(thr, 1e-6 * self.cfg.link.line_rate)

    def p99_slowdown(self) -> float:
        """p99 of ``flow_slowdowns`` — the tail-latency tuner objective."""
        s = self.flow_slowdowns()
        return float(np.percentile(s, 99)) if s.size else float("nan")

    def victim_slowdown(self) -> float:
        """Mean slowdown over the scenario's designated victim flows.

        Victims (``Scenario.victim``) are flows that do not contribute
        to the congestion under test but share fabric with it — the
        HoL/pause-storm collateral the PFC-pathology scenarios measure.
        NaN when the scenario designates none (or none are real flows).
        """
        if self.scn.victim is None:
            return float("nan")
        vic = np.asarray(self.scn.victim, bool)[self._real_flows()]
        if not vic.any():
            return float("nan")
        return float(self.flow_slowdowns()[vic].mean())

    def pause_duration(self) -> float:
        """Total PFC pause wire-seconds over the run (sum over queues
        of pause level x dt).  NaN on traces predating the counter."""
        if self.pause_time is None:
            return float("nan")
        return float(np.asarray(self.pause_time).sum())

    def vc_stall_time(self) -> np.ndarray:
        """[V] pause wire-seconds per virtual channel ([1] when the
        config runs a single VC).  None on traces predating it."""
        if self.vc_stall is None:
            return None
        return np.asarray(self.vc_stall).sum(axis=0)

    def ctrl_per_mb(self) -> float:
        """Notification messages per delivered MB (control overhead).

        NaN when the trace predates the ``ctrl`` counter (old blobs).
        """
        if self.ctrl is None:
            return float("nan")
        mb = float(np.asarray(self.final.delivered).sum()) / 1e6
        return float(self.ctrl.sum()) / max(mb, 1e-9)

    def summary(self) -> dict:
        """Headline numbers for this run (one row of the Fig. 2/3
        table; ``SweepResult.summary`` is this, per point)."""
        thr = self.mean_throughput_while_active()
        return {
            "aggregate_gbps": float(thr.sum() / 1e9),
            "min_flow_gbps": float(thr.min() / 1e9),
            "completion_ms": float(self.completion_time() * 1e3),
            "peak_queue_kb": float(self.max_q.max() / 1e3),
            "delivered_mb": float(
                np.asarray(self.final.delivered).sum() / 1e6),
            "marks": int(self.marked.sum()),
            "cnps": int(self.cnp.sum()),
            "peak_nonmin_flows": int(self.n_nonmin.max()),
            "jain_index": self.jain_index(),
            "p99_slowdown": self.p99_slowdown(),
            "ctrl_per_mb": self.ctrl_per_mb(),
            "victim_slowdown": self.victim_slowdown(),
            "pause_s": self.pause_duration(),
            "vc_stall_s": None if self.vc_stall is None else
                [float(x) for x in self.vc_stall_time()],
        }


def run(scn: Scenario, cfg: CCConfig, n_steps: int | None = None,
        trace_every: int | None = None, *, reduce: str = "fused",
        use_kernels: "bool | str" = False,
        interpret: bool = False) -> SimResult:
    """Simulate one point and pull (decimated) traces to host.

    ``trace_every`` defaults to ``cfg.sim.trace_every``; pass 1 for a
    full-resolution trace.  ``n_steps`` is rounded up to a whole number
    of trace windows.  ``reduce`` / ``use_kernels`` / ``interpret``
    select the reduction engine and Pallas tier (see
    ``repro.core.fluid.fluid_step``); ``use_kernels="mega"`` runs each
    trace window as one whole-step megakernel launch with the fluid
    state VMEM-resident across all ``trace_every`` substeps.
    """
    n_samples, k = _resolve_steps(cfg, n_steps, trace_every)
    st0 = init_state(scn, cfg)
    n_vcs = int(getattr(cfg.link, "n_vcs", 1))
    from .fluid import kernel_tier
    if kernel_tier(use_kernels) == "mega":
        block = make_block_fn(scn, cfg, k, reduce=reduce,
                              interpret=interpret)
        final, tr = _run_block_scan(st0, block, n_samples)
    else:
        step = make_step_fn(scn, cfg, reduce=reduce,
                            use_kernels=use_kernels, interpret=interpret)
        final, tr = _run_scan(st0, step, n_samples, k,
                              float(cfg.sim.dt), n_vcs)
    # (i+1)*k first (exact int), then *dt — so decimated times are the
    # same floats as the strided full-resolution times
    times = (np.arange(n_samples) + 1) * k * cfg.sim.dt
    return SimResult(
        cfg=cfg, scn=scn, times=times,
        delivered=np.asarray(tr.delivered),
        rate=np.asarray(tr.rate),
        inst_thr=np.asarray(tr.inst_thr),
        max_q=np.asarray(tr.max_q),
        n_paused=np.asarray(tr.n_paused),
        marked=np.asarray(tr.marked),
        cnp=np.asarray(tr.cnp),
        n_nonmin=np.asarray(tr.n_nonmin),
        final=jax.device_get(final),
        ctrl=np.asarray(tr.ctrl),
        trace_every=k,
        pause_time=np.asarray(tr.pause_time),
        vc_stall=np.asarray(tr.vc_stall),
    )


def run_all_schemes(scn: Scenario, cfg: CCConfig,
                    n_steps: int | None = None) -> dict[str, SimResult]:
    """Scheme ablation as ONE batched device launch (see experiments).

    Kept for API compatibility; now a thin wrapper over a 3-point Sweep
    instead of three serial jit compilations.
    """
    from .experiments import Sweep
    schemes = (CCScheme.PFC_ONLY, CCScheme.DCQCN, CCScheme.DCQCN_REV)
    sweep = Sweep([(s.name, cfg.replace(scheme=s), scn) for s in schemes])
    res = sweep.run(n_steps=n_steps)
    return {s.name: res[s.name] for s in schemes}
