"""Scan driver + result analysis for the CC fluid model."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .fluid import FluidState, Scenario, init_state, make_step_fn
from .params import CCConfig, CCScheme


@dataclasses.dataclass
class SimResult:
    """Host-side view of a finished run."""

    cfg: CCConfig
    scn: Scenario
    times: np.ndarray          # [T] seconds
    delivered: np.ndarray      # [T, F] cumulative bytes
    rate: np.ndarray           # [T, F] RP rate (B/s)
    inst_thr: np.ndarray       # [T, F] instantaneous delivery rate (B/s)
    max_q: np.ndarray          # [T]
    n_paused: np.ndarray       # [T]
    marked: np.ndarray         # [T, F]
    cnp: np.ndarray            # [T, F]
    final: Any                 # FluidState (host)

    # -- derived metrics ----------------------------------------------------
    def flow_throughput(self, window: int = 50) -> np.ndarray:
        """[T, F] delivery rate smoothed over `window` samples (B/s)."""
        k = np.ones(window) / window
        return np.stack(
            [np.convolve(self.inst_thr[:, f], k, mode="same")
             for f in range(self.inst_thr.shape[1])], axis=1)

    def aggregate_throughput(self, window: int = 50) -> np.ndarray:
        return self.flow_throughput(window).sum(axis=1)

    def completion_times(self, frac: float = 0.999) -> np.ndarray:
        """[F] time when `frac` of the flow's work was delivered.

        Volume-mode flows are measured against their declared volume
        (NaN if the run ended early); window-mode flows against the
        admitted bytes."""
        offered = np.asarray(self.final.offered)
        vol = np.asarray(self.scn.volume, dtype=np.float64)
        total = np.where(np.isfinite(vol), vol, offered)
        out = np.full((total.shape[0],), np.nan)
        for f in range(total.shape[0]):
            if total[f] <= 0:
                continue
            hit = np.nonzero(self.delivered[:, f] >= frac * total[f])[0]
            if hit.size:
                out[f] = self.times[hit[0]]
        return out

    def completion_time(self, frac: float = 0.999) -> float:
        ct = self.completion_times(frac)
        return float(np.nanmax(ct)) if np.isfinite(ct).any() else float("nan")

    def mean_throughput_while_active(self) -> np.ndarray:
        """[F] mean delivery rate while the flow is live.

        Window mode: averaged over [t_start, t_stop).  Volume mode
        (t_stop = inf): volume / (completion - t_start).
        """
        t0 = np.asarray(self.scn.t_start)
        t1 = np.asarray(self.scn.t_stop)
        ct = self.completion_times()
        out = np.zeros(t0.shape)
        for f in range(t0.shape[0]):
            if np.isfinite(t1[f]):
                m = (self.times >= t0[f]) & (self.times < t1[f])
                out[f] = self.inst_thr[m, f].mean() if m.any() else 0.0
            elif np.isfinite(ct[f]) and ct[f] > t0[f]:
                out[f] = self.delivered[-1, f] / (ct[f] - t0[f])
        return out


@functools.partial(jax.jit, static_argnums=(2, 3))
def _run_scan(state: FluidState, dummy, step_fn, n_steps: int):
    def body(st, _):
        return step_fn(st)
    return jax.lax.scan(body, state, None, length=n_steps)


def run(scn: Scenario, cfg: CCConfig, n_steps: int | None = None) -> SimResult:
    """Simulate and pull traces to host."""
    if n_steps is None:
        n_steps = int(round(cfg.sim.t_end / cfg.sim.dt))
    step = make_step_fn(scn, cfg)
    st0 = init_state(scn, cfg)
    final, tr = _run_scan(st0, None, step, n_steps)
    times = (np.arange(n_steps) + 1) * cfg.sim.dt
    return SimResult(
        cfg=cfg, scn=scn, times=times,
        delivered=np.asarray(tr.delivered),
        rate=np.asarray(tr.rate),
        inst_thr=np.asarray(tr.inst_thr),
        max_q=np.asarray(tr.max_q),
        n_paused=np.asarray(tr.n_paused),
        marked=np.asarray(tr.marked),
        cnp=np.asarray(tr.cnp),
        final=jax.device_get(final),
    )


def run_all_schemes(scn: Scenario, cfg: CCConfig,
                    n_steps: int | None = None) -> dict[str, SimResult]:
    out = {}
    for scheme in (CCScheme.PFC_ONLY, CCScheme.DCQCN, CCScheme.DCQCN_REV):
        out[scheme.name] = run(scn, cfg.replace(scheme=scheme), n_steps)
    return out
