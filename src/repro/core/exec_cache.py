"""Instrumented LRU cache for compiled executables.

The sweep engine (``repro.core.experiments``) used to hide its jitted
executables behind a private ``functools.lru_cache`` — invisible to the
serving layer, which needs to *assert* "this 100-query replay compiled
exactly once" and to report hit rates and compile-time split as
first-class metrics.  ``ExecutableCache`` is that cache made explicit:

  * bounded LRU keyed by the caller's structural signature (static
    scan configuration — including the kernel tier and the megakernel's
    substep-block depth, since a mega sweep re-blocked at a different
    ``trace_every`` is a different program — plus the input pytree
    treedef and leaf shapes/dtypes, so a hit really means "this
    executable can run these arrays as-is");
  * hit / miss / eviction counters plus cumulative build (compile)
    seconds, snapshotable as :class:`CacheStats` — deltas subtract, so
    a serving engine can report per-window stats off a shared cache;
  * configurable capacity (``resize``), safe under concurrent readers
    (one lock; builders run under it so a key is only ever built once).

The module is dependency-free on purpose: the cache stores whatever the
builder returns (AOT-compiled ``jax.stages.Compiled`` executables for
the sweep engine, plain jitted callables for the mesh-sharded path).
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Hashable


@dataclasses.dataclass(frozen=True)
class CacheStats:
    """Monotone counter snapshot; subtract two snapshots for a window."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    build_s: float = 0.0          # cumulative seconds spent in builders

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        """Hits per lookup (1.0 for the empty window: nothing missed)."""
        n = self.lookups
        return self.hits / n if n else 1.0

    def __sub__(self, other: "CacheStats") -> "CacheStats":
        return CacheStats(hits=self.hits - other.hits,
                          misses=self.misses - other.misses,
                          evictions=self.evictions - other.evictions,
                          build_s=self.build_s - other.build_s)

    def to_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": round(self.hit_rate, 4),
                "build_s": round(self.build_s, 3)}


def structural_signature(static: tuple, args) -> tuple:
    """The full structural cache key for a compiled program.

    ``static`` is the caller's static configuration tuple; ``args`` is
    the input pytree the executable will be called with.  The returned
    key appends the pytree's treedef and every leaf's
    (shape, dtype, weak_type) — exactly what determines the compiled
    program, so two calls with equal signatures can share one
    executable and run each other's arrays as-is.

    This is the sweep engine's key, exported so other layers (the fleet
    planner's structural buckets, the serving engine's compile-once
    assertion) can group work by "compiles to the same program" without
    re-deriving the rule.
    """
    import jax                     # lazy: the module itself stays free

    leaves, treedef = jax.tree.flatten(args)
    shapes = tuple((tuple(x.shape), x.dtype.name,
                    bool(getattr(x, "weak_type", False))) for x in leaves)
    return static + (treedef, shapes)


class ExecutableCache:
    """Bounded, instrumented LRU: key -> built executable.

    ``get_or_build(key, builder)`` returns the cached value for ``key``
    or runs ``builder()`` (counting its wall time as compile time) and
    inserts the result, evicting least-recently-used entries past
    ``capacity``.  Keys must be hashable; use a full structural
    signature — anything that changes the compiled program (static
    arguments, input shapes/dtypes/treedef) belongs in the key.
    """

    def __init__(self, capacity: int = 32, name: str = "exec"):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.name = name
        self._capacity = int(capacity)
        self._entries: "collections.OrderedDict[Hashable, Any]" = \
            collections.OrderedDict()
        self._lock = threading.RLock()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._build_s = 0.0

    # -- core ---------------------------------------------------------------

    def get_or_build(self, key: Hashable,
                     builder: Callable[[], Any]) -> Any:
        with self._lock:
            if key in self._entries:
                self._hits += 1
                self._entries.move_to_end(key)
                return self._entries[key]
            # build under the lock: concurrent callers of one key must
            # not compile twice (compilation is the expensive part)
            self._misses += 1
            t0 = time.perf_counter()
            value = builder()
            self._build_s += time.perf_counter() - t0
            self._entries[key] = value
            while len(self._entries) > self._capacity:
                self._entries.popitem(last=False)
                self._evictions += 1
            return value

    # -- introspection ------------------------------------------------------

    @property
    def capacity(self) -> int:
        return self._capacity

    def resize(self, capacity: int) -> None:
        """Change capacity; shrinking evicts LRU entries immediately."""
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        with self._lock:
            self._capacity = int(capacity)
            while len(self._entries) > self._capacity:
                self._entries.popitem(last=False)
                self._evictions += 1

    def stats(self) -> CacheStats:
        with self._lock:
            return CacheStats(hits=self._hits, misses=self._misses,
                              evictions=self._evictions,
                              build_s=self._build_s)

    def reset_stats(self) -> None:
        """Zero the counters (entries stay — hit rates restart clean)."""
        with self._lock:
            self._hits = self._misses = self._evictions = 0
            self._build_s = 0.0

    def clear(self) -> None:
        """Drop every entry (not counted as evictions; stats persist)."""
        with self._lock:
            self._entries.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._entries

    def keys(self):
        with self._lock:
            return list(self._entries.keys())

    def __repr__(self) -> str:
        s = self.stats()
        return (f"ExecutableCache({self.name!r}, {len(self)}/"
                f"{self._capacity} entries, hits={s.hits} "
                f"misses={s.misses} evictions={s.evictions})")
