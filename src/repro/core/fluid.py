"""Dense fluid model of the CC closed loop (PFC / DCQCN / DCQCN-Rev).

TPU-native adaptation of the paper's event-driven evaluation (DESIGN.md §2):
the whole network is a fixed-shape state advanced by one fused, branch-free
update per ``dt``.  No event queue exists; flows x hops are vectorised.

Representation (compact, scales to DC-size):
  * ``routes[F, H]`` — link id crossed at each hop (PAD = -1).  H is
                       whatever the fabric's route table needs (6 for
                       the 3-stage CLOS, 2h for an h-level XGFT, 5 for
                       dragonfly — see ``repro.net``); every update
                       below is shape-polymorphic in it, and mixed
                       fabrics pad to a common H when stacked.
  * ``qh[F, H]``     — bytes of flow f queued at the *sink* of wire h
                       (the input buffer of the downstream switch), waiting
                       to cross wire h+1.  The last wire delivers to the
                       host, so qh[:, hops-1] is always 0.
  * ``nicq[F]``      — host backlog (generated, not yet injected).

Adaptive routing: scenarios may carry K candidate paths per flow
(``alt_routes[F, K, H]``, slot 0 minimal, slots 1..K-1 Valiant detours
— see ``repro.net.routing.RouteSet``); ``FluidState.path_idx`` names
each flow's live candidate and ``StepParams.route_code`` the policy
(0 = min, 1 = valiant, 2 = ugal).  Selection happens at the top of the
step, at flow start and (UGAL) on CNP-arrival epochs: UGAL-L compares
queue-occupancy-weighted hops of the minimal path against one sampled
detour, built from the per-link backlog the model already tracks, with
ties keeping the minimal route.  Switching a flow mid-flight
reinterprets its queued bytes onto the new path's hop positions — the
usual fluid-model abstraction (bytes are a continuum, not packets).

Per step (Jacobi, from pre-step state):
  0. path selection (min / valiant / ugal) at epoch flows;
  1. generation into nicq (rate-limited window generator, finite NIC buf);
  2. transfers: every wire w serves the queues feeding it proportionally
     to their backlog, capped by C_w*dt, gated by PFC pause, and scaled by
     a strict-FIFO HoL factor (a queue whose head bytes belong to a paused
     flow stalls everyone — the paper's victim pathology);
  3. PFC: a wire pauses when its sink queue crosses XOFF (hysteresis XON),
     plus a shared-pool pause per switch;
  4. marking: one registered ``repro.core.cc.MARKING`` stage — CP
     (occupancy only), ECP (occupancy AND flow rate above its
     waterfilled fair grant on its next wire — victims never marked),
     slope (RED-style kmin..kmax ramp, error-diffused), ...;
  5. notification: one ``cc.NOTIFICATION`` stage — NP (50us
     suppression), ENP (fast coalescing + severity payload = fair
     grant at the marking queue), FNCC (in-path: the marking hop
     writes the return path, shrinking the feedback delay);
  6. reaction: one ``cc.REACTION`` stage — fixed-rate PFC source, RP
     (DCQCN alpha/stage machine), ERP (set to signalled fair share,
     hold, desynchronised additive recovery), swift (delay-target).

All arrays are float32; the update is pure jnp and runs inside lax.scan.

Layering (the Sweep engine in ``experiments.py`` builds on this):
  * ``Scenario``        — host-side numpy tensors describing one workload.
  * ``ScenarioDev``     — the same tensors as device arrays, the exact
                          pytree ``fluid_step`` consumes.  Batched sweeps
                          stack R of these and ``vmap`` over the leading
                          axis.
  * ``StepParams``      — every config scalar the update reads, as
                          traced values (NOT python statics), so one
                          compiled step serves all stage combinations /
                          param grids.
  * ``fluid_step``      — the pure per-``dt`` update.  Stage selection
                          (``mark_code`` / ``notif_code`` /
                          ``react_code``, see ``repro.core.cc``) happens
                          with ``jnp.where`` on traced selectors, which
                          is what lets a stage ablation ride one jit.
"""

from __future__ import annotations

import collections
import functools
import hashlib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import cc
from .params import CCConfig, CCSpec, ROUTING_MODES
from .routing import PAD, link_incidence
from repro.tune import soft


class Scenario(NamedTuple):
    """Static per-run tensors (host numpy; moved to device once)."""

    routes: np.ndarray        # [F, H] int32 link ids, PAD = -1
    hops: np.ndarray          # [F] int32
    gen_rate: np.ndarray      # [F] f32 B/s offered by the generator
    t_start: np.ndarray       # [F] f32 s
    t_stop: np.ndarray        # [F] f32 s (generator closes)
    volume: np.ndarray        # [F] f32 B total work (inf = window-limited)
    capacity: np.ndarray      # [L] f32 B/s per directed link
    sink_switch: np.ndarray   # [L] int32 (-1 for host sinks)
    n_switches: int
    rtt_steps: np.ndarray     # [F] int32 CNP feedback delay in dt steps
    # B of host NIC queue: a scalar (shared) or a per-flow [F] array —
    # mixed workloads give deep buffers to volume-mode collective flows
    # and shallow ones to window-mode background traffic.
    nic_buffer: "float | np.ndarray" = 4e6
    # multi-path candidates (adaptive routing): K per-flow paths, slot 0
    # the minimal route (== ``routes``), slots 1..K-1 Valiant detours.
    # None = single-path scenario (selection is a no-op).
    alt_routes: "np.ndarray | None" = None    # [F, K, H] int32, PAD-padded
    alt_hops: "np.ndarray | None" = None      # [F, K] int32 (0 = no path)
    # static virtual-channel assignment per candidate hop (values in
    # [0, n_vcs); see ``repro.core.routing.assign_vc``).  None = all
    # VC 0.  Only read when the config's ``LinkParams.n_vcs > 1``;
    # under n_vcs = 1 every VC collapses onto the single wire queue.
    vc: "np.ndarray | None" = None            # [F, K, H] int32
    # victim-flow mask for the PFC-pathology metrics: flows that do NOT
    # contribute to the congestion under test but share fabric with it
    # (``SimResult.victim_slowdown`` aggregates over these).  None = no
    # designated victims.
    victim: "np.ndarray | None" = None        # [F] bool


class ScenarioDev(NamedTuple):
    """Device-side scenario: the pytree ``fluid_step`` consumes.

    A batched sweep stacks R of these along a new leading axis and vmaps;
    every field is data, so runs with different routes / rates / RTTs
    share one compiled step.  Routes live only in the candidate stack
    ``alt_routes`` (single-path scenarios mirror into K = 1) — the
    host-side ``Scenario.routes``/``hops`` stay the minimal slot 0.
    """

    gen_rate: jnp.ndarray     # [F] f32
    t_start: jnp.ndarray      # [F] f32
    t_stop: jnp.ndarray       # [F] f32
    volume: jnp.ndarray       # [F] f32
    cap_ext: jnp.ndarray      # [L+1] f32 (scratch slot L for PAD scatters)
    sink_ext: jnp.ndarray     # [L+1] int32
    rtt: jnp.ndarray          # [F] int32
    nic_buffer: jnp.ndarray   # [F] f32 (host scalars broadcast per flow)
    alt_routes: jnp.ndarray   # [F, K, H] int32 (K = 1 mirrors ``routes``)
    alt_hops: jnp.ndarray     # [F, K] int32
    # static VC per candidate hop (all-zero when the scenario has none);
    # only consulted by ``fluid_step(..., n_vcs > 1)`` — under one VC
    # the queue index is the wire index and this tensor is dead data.
    vc: jnp.ndarray           # [F, K, H] int32 in [0, n_vcs)
    # per-flow ERP recovery jitter (Weyl sequence), hoisted here so the
    # step never rebuilds host constants inside a trace
    jitter: jnp.ndarray       # [F] f32
    # fused-reduction incidence (see core.routing.link_incidence): the
    # flattened [F*K*H] candidate entries stably sorted by link id.
    # Every per-link scatter-add of the step becomes one gather by
    # ``red_perm`` + sorted multi-channel segment sum over ``red_seg``;
    # ``red_off`` are the CSR offsets the Pallas kernel tiles by.
    red_perm: jnp.ndarray     # [F*K*H] int32
    red_seg: jnp.ndarray      # [F*K*H] int32
    red_off: jnp.ndarray      # [L+2] int32
    # same trick for the per-switch shared-pool reduction: link ids
    # stably sorted by sink switch (host sinks -> scratch segment)
    pool_perm: jnp.ndarray    # [L] int32
    pool_seg: jnp.ndarray     # [L] int32


class StepParams(NamedTuple):
    """Per-run CC constants as traced scalars (stack + vmap for sweeps).

    Stage selection is data, not structure: ``mark_code`` /
    ``notif_code`` / ``react_code`` name one registered component per
    family in ``repro.core.cc`` (selected inside the step with
    ``jnp.where``, like ``route_code``), and ``mark`` / ``notif`` /
    ``react`` carry each family's param union as a flat dict pytree —
    so any (marking x notification x reaction x param grid) product
    shares ONE compiled step.
    """

    mark_code: jnp.ndarray    # [] int32 — cc.MARKING entry
    notif_code: jnp.ndarray   # [] int32 — cc.NOTIFICATION entry
    react_code: jnp.ndarray   # [] int32 — cc.REACTION entry
    route_code: jnp.ndarray   # [] int32  — 0 min / 1 valiant / 2 ugal
    line_rate: jnp.ndarray    # [] f32
    xoff: jnp.ndarray         # [] f32
    xon: jnp.ndarray          # [] f32
    pool_xoff: jnp.ndarray    # [] f32
    port_buffer: jnp.ndarray  # [] f32
    ecp_beta: jnp.ndarray     # [] f32 — crossing-rate EWMA gain (the
    #   demand estimate is shared step infrastructure, not a stage)
    mark: dict                # marking-family param union ([] scalars)
    notif: dict               # notification-family param union
    react: dict               # reaction-family param union
    # Soft-relaxation temperature (``repro.tune.soft``): 0 runs the
    # exact hard dynamics (bitwise — every softened site selects its
    # original expression); > 0 smooths the hard gates (PFC
    # hysteresis, marking thresholds, CNP windows, rate clamps) so
    # ``jax.grad`` flows through the dt-scan.  Traced data like every
    # other constant: hard sweeps and soft tuner rollouts share ONE
    # compiled step.
    temperature: jnp.ndarray  # [] f32


class FluidState(NamedTuple):
    qh: jnp.ndarray           # [F, H] bytes at hop queues
    nicq: jnp.ndarray         # [F]
    delivered: jnp.ndarray    # [F]
    offered: jnp.ndarray      # [F] bytes the generator admitted into nicq
    dropped: jnp.ndarray      # [F] generator overflow (app backpressure)
    est: jnp.ndarray          # [F, H] EWMA crossing rate per wire (B/s)
    # Pause level per (wire, VC) queue: exact 0/1 in hard mode
    # (temperature == 0), fractional under the soft PFC hysteresis —
    # float32 so the pause gate is a differentiable multiplier instead
    # of a boolean select.  Flat [L * n_vcs] layout (queue q of wire w
    # at w * n_vcs + q), so the single-VC model keeps its legacy [L]
    # shape bit-for-bit.
    paused: jnp.ndarray       # [L * n_vcs] f32
    # reaction-point state (DCQCN RP and ERP share slots where sensible)
    rate: jnp.ndarray         # [F] current injection rate
    rp_target: jnp.ndarray    # [F]
    alpha: jnp.ndarray        # [F]
    byte_cnt: jnp.ndarray     # [F]
    tmr: jnp.ndarray          # [F]
    alpha_tmr: jnp.ndarray    # [F]
    bc_stage: jnp.ndarray     # [F] int32
    t_stage: jnp.ndarray      # [F] int32
    hold: jnp.ndarray         # [F] ERP hold-down timer
    np_tmr: jnp.ndarray       # [F] time since last CNP emission
    trig_buf: jnp.ndarray     # [D, F] CNP in flight (delay line)
    tgt_buf: jnp.ndarray      # [D, F] severity payload in flight
    path_idx: jnp.ndarray     # [F] int32 selected candidate (0 = minimal)
    # per-stage state pytree: every registered cc stage contributes its
    # [F]-shaped keys (e.g. slope marking's error-diffusion accumulator,
    # swift's decrease-guard timer), so the structure is stable across a
    # whole sweep batch and unselected stages pass theirs through.
    cc: dict
    t: jnp.ndarray            # [] int32 step counter


class StepTrace(NamedTuple):
    delivered: jnp.ndarray    # [F] cumulative bytes
    rate: jnp.ndarray         # [F] RP rate
    inst_thr: jnp.ndarray     # [F] delivery rate this step (B/s)
    max_q: jnp.ndarray        # [] hottest queue (bytes)
    n_paused: jnp.ndarray     # [] paused wires
    marked: jnp.ndarray       # [F] marked this step?
    cnp: jnp.ndarray          # [F] CNP received this step?
    n_nonmin: jnp.ndarray     # [] flows currently on a non-minimal path
    # control-traffic counter: notification messages (CNP/ENP/FNCC)
    # emitted this step — exact 0/1 per flow in hard mode, fractional
    # emission intensity under the soft model.  Accumulated (not
    # sampled) by the decimating scan, it feeds the control-overhead
    # objective in repro.tune and SimResult.summary().
    ctrl: jnp.ndarray         # [F] f32 notifications emitted this step
    # PFC pathology instrumentation (accumulated, like ``ctrl``):
    # ``pause_time`` is wire-seconds of pause asserted this step
    # (sum over queues of pause level x dt); ``vc_stall`` splits the
    # same quantity per VC ([n_vcs], so [1] in the single-VC model) —
    # the per-lane stall budget a pause storm burns.
    pause_time: jnp.ndarray   # [] f32 wire-seconds paused this step
    vc_stall: jnp.ndarray     # [V] f32 per-VC wire-seconds paused


DELAY_SLOTS = 32              # legacy fixed delay-line depth (see below)


def delay_depth(scn: Scenario) -> int:
    """Delay-line depth covering every flow's CNP feedback delay.

    The legacy code used a hard ``DELAY_SLOTS = 32`` ring and silently
    wrapped ``rtt_steps % 32``, corrupting the control loop of any path
    with >= 32 steps of feedback delay.  The depth is now derived from
    the scenario; ``DELAY_SLOTS`` survives only as an explicit opt-in
    (and raises instead of wrapping).
    """
    return max(2, int(np.max(scn.rtt_steps)) + 1)


def _check_delay(scn: Scenario, delay_slots: int) -> int:
    max_rtt = int(np.max(scn.rtt_steps))
    if max_rtt >= delay_slots:
        raise ValueError(
            f"rtt_steps up to {max_rtt} overflow the {delay_slots}-slot "
            f"delay line; pass delay_slots >= {max_rtt + 1} (or None to "
            f"size it from the scenario)")
    return delay_slots


def _flow_jitter(n: int) -> np.ndarray:
    """Deterministic per-flow jitter in [-1, 1] (Weyl sequence)."""
    x = (np.arange(n, dtype=np.uint64) * np.uint64(2654435761)) % np.uint64(2**32)
    return (x.astype(np.float64) / 2**31 - 1.0).astype(np.float32)


@functools.lru_cache(maxsize=128)
def _index_consts(F: int, H: int) -> tuple[np.ndarray, np.ndarray]:
    """(arange_h [1, H], fidx [F]) — shared across traces of one shape."""
    return (np.arange(H, dtype=np.int32)[None, :],
            np.arange(F, dtype=np.int32))


def _digest(x: np.ndarray) -> tuple:
    x = np.ascontiguousarray(x)
    return (x.shape, x.dtype.str, hashlib.sha1(x.tobytes()).hexdigest())


def _memo_lru(cache: collections.OrderedDict, maxsize: int, key, fn):
    """Bounded content-keyed LRU shared by the host-side caches below."""
    hit = cache.get(key)
    if hit is not None:
        cache.move_to_end(key)
        return hit
    out = cache[key] = fn()
    while len(cache) > maxsize:
        cache.popitem(last=False)
    return out


# Content-keyed device-placement cache.  A sweep's grid points mostly
# share a FabricSpec, so the route/capacity/incidence tensors of every
# point are byte-identical; hashing is cheaper than re-uploading (and
# than re-sorting the incidence).  Keys carry shape + dtype + digest, so
# two different tensors never alias.  Bounded LRU: a long-lived process
# sweeping many fabrics cannot leak device memory.
_PUT_CACHE: "collections.OrderedDict[tuple, jnp.ndarray]" = \
    collections.OrderedDict()
_PUT_CACHE_SIZE = 256

_INC_CACHE: "collections.OrderedDict[tuple, tuple]" = collections.OrderedDict()
_INC_CACHE_SIZE = 128


def _cached_put(x: np.ndarray, dtype) -> jnp.ndarray:
    x = np.ascontiguousarray(np.asarray(x, dtype))
    return _memo_lru(_PUT_CACHE, _PUT_CACHE_SIZE, _digest(x),
                     lambda: jnp.asarray(x))


def _incidence(alt_routes: np.ndarray, n_links: int,
               vc: np.ndarray | None = None, n_vcs: int = 1):
    """``link_incidence`` memoised on route-stack content (the sort is
    O(FKH log FKH) on host; grid points sharing a fabric pay it once).
    The key carries the VC layout too: the same routes under a
    different VC assignment sort into different (wire, VC) queues."""
    key = _digest(alt_routes) + (n_links, n_vcs)
    if n_vcs > 1 and vc is not None:
        key = key + _digest(vc)
    return _memo_lru(_INC_CACHE, _INC_CACHE_SIZE, key,
                     lambda: link_incidence(alt_routes, n_links,
                                            vc=vc, n_vcs=n_vcs))


def _pool_incidence(sink_switch: np.ndarray, n_switches: int):
    """Link ids stably sorted by sink switch (-1 hosts -> scratch)."""
    seg = np.where(sink_switch >= 0, sink_switch, n_switches)
    perm = np.argsort(seg, kind="stable").astype(np.int32)
    return perm, seg[perm].astype(np.int32)


#: Longest per-link contributor list the dense reduction will tile; more
#: skewed scenarios (massive incast onto one link) fall back to the
#: sorted segment-sum engine.
DENSE_ROWS_CAP = 1024


def clamp_dense_rows(ml: int, n_links: int, n_entries: int) -> int:
    """Apply the dense-CSR size guard to a row count (0 = disable).

    One guard for single scenarios AND batches: a batch must re-clamp
    its *maximum* per-run row count here, otherwise one high-skew run
    would drag every run onto an oversized [L, rows] table the
    per-scenario check was meant to refuse.
    """
    if ml == 0 or ml > DENSE_ROWS_CAP:
        return 0
    if n_links * ml > max(16 * n_entries, 1 << 20):
        return 0
    return ml


def _scenario_vc(scn: Scenario, alt_routes: np.ndarray,
                 n_vcs: int) -> np.ndarray:
    """Validated [F, K, H] VC tensor for a scenario (all-zero default).

    ``n_vcs = 1`` always collapses to VC 0 — running a VC-annotated
    scenario under a single-VC config degenerates to the shared-queue
    model, by design.  With more VCs the assignment must fit, and PAD
    hops are forced to VC 0 so they land on the incidence scratch
    segment exactly.
    """
    if n_vcs == 1 or scn.vc is None:
        return np.zeros(alt_routes.shape, np.int32)
    vc = np.asarray(scn.vc, np.int32)
    if vc.shape != alt_routes.shape:
        raise ValueError(
            f"Scenario.vc shape {vc.shape} != candidate stack shape "
            f"{alt_routes.shape}")
    if vc.min(initial=0) < 0 or vc.max(initial=0) >= n_vcs:
        raise ValueError(
            f"Scenario.vc entries must lie in [0, {n_vcs}) "
            f"(got [{vc.min()}, {vc.max()}]); rebuild the assignment "
            f"for this n_vcs (routing.assign_vc clips for you)")
    return np.where(alt_routes == PAD, 0, vc).astype(np.int32)


def dense_reduce_rows(scn: Scenario, n_vcs: int = 1) -> int:
    """Static row count for the dense-CSR fused reduction (0 = disable).

    The fused reduction can run scatter-free: lay each (wire, VC)
    queue's (sorted) contributors out as a dense [L * n_vcs, rows]
    table derived from the CSR offsets and accumulate positions
    left-to-right — bit-identical to the sequential scatter, but pure
    gathers + vector adds.  The table blows up with load skew (rows =
    max contributors on one queue), so scenarios past
    ``DENSE_ROWS_CAP`` — or whose table would dwarf the incidence
    itself — report 0 and use the segment-sum engine.
    """
    alt = scn.routes[:, None, :] if scn.alt_routes is None \
        else scn.alt_routes
    alt = np.asarray(alt, np.int32)
    L = scn.capacity.shape[0]
    if L == 0:
        return 0
    vc = _scenario_vc(scn, alt, n_vcs)
    S = L * n_vcs
    _, _, off = _incidence(alt, L, vc, n_vcs)
    ml = int(np.max(off[1:S + 1] - off[:S]))
    return clamp_dense_rows(ml, S, alt.size)


def scenario_device(scn: Scenario, n_vcs: int = 1) -> ScenarioDev:
    """Move one scenario's tensors to device-ready arrays.

    Fabric-shaped tensors (routes, capacities, incidence) go through a
    content-keyed placement cache: grid points sharing a ``FabricSpec``
    upload them once instead of once per point.  ``n_vcs`` (static,
    from ``LinkParams.n_vcs``) keys the incidence by (wire, VC) queue;
    the default 1 is byte-identical to the legacy single-queue layout.
    """
    if scn.alt_routes is None:          # single-path: K = 1 mirror
        alt_routes = scn.routes[:, None, :]
        alt_hops = scn.hops[:, None]
    else:
        alt_routes, alt_hops = scn.alt_routes, scn.alt_hops
    alt_routes = np.asarray(alt_routes, np.int32)
    F = scn.routes.shape[0]
    L = scn.capacity.shape[0]
    vc = _scenario_vc(scn, alt_routes, n_vcs)
    perm, seg, off = _incidence(alt_routes, L, vc, n_vcs)
    pool_perm, pool_seg = _pool_incidence(
        np.asarray(scn.sink_switch, np.int32), int(scn.n_switches))
    return ScenarioDev(
        alt_routes=_cached_put(alt_routes, np.int32),
        alt_hops=_cached_put(alt_hops, np.int32),
        vc=_cached_put(vc, np.int32),
        gen_rate=jnp.asarray(scn.gen_rate, jnp.float32),
        t_start=jnp.asarray(scn.t_start, jnp.float32),
        t_stop=jnp.asarray(scn.t_stop, jnp.float32),
        volume=jnp.asarray(scn.volume, jnp.float32),
        cap_ext=_cached_put(
            np.concatenate([scn.capacity, [np.inf]]), np.float32),
        sink_ext=_cached_put(
            np.concatenate([scn.sink_switch, [-1]]), np.int32),
        rtt=jnp.asarray(scn.rtt_steps, jnp.int32),
        # broadcast to [F] so scalar- and per-flow-buffer scenarios share
        # one device shape (batched sweeps stack them along a run axis)
        nic_buffer=jnp.broadcast_to(
            jnp.asarray(scn.nic_buffer, jnp.float32),
            scn.routes.shape[:1]),
        jitter=_cached_put(_flow_jitter(F), np.float32),
        red_perm=_cached_put(perm, np.int32),
        red_seg=_cached_put(seg, np.int32),
        red_off=_cached_put(off, np.int32),
        pool_perm=_cached_put(pool_perm, np.int32),
        pool_seg=_cached_put(pool_seg, np.int32),
    )


def step_params(cfg: "CCConfig | CCSpec", *,
                temperature: float = 0.0) -> StepParams:
    """Flatten a config into the traced scalars ``fluid_step`` reads.

    Accepts the legacy ``CCConfig`` (mapped through ``to_spec()``, the
    bit-exact shim) or a ``CCSpec`` directly.  Stage names resolve to
    registry codes; each family's param union comes from the registered
    stages' extractors.  ``temperature`` selects the soft-relaxed
    dynamics (``repro.tune``); the default 0 is the exact hard model.
    """
    spec: CCSpec = cfg.to_spec()
    lk = spec.link
    route_code = ROUTING_MODES.index(spec.routing)
    f32 = lambda v: jnp.asarray(v, jnp.float32)
    return StepParams(
        mark_code=jnp.asarray(cc.MARKING.code(spec.marking), jnp.int32),
        notif_code=jnp.asarray(cc.NOTIFICATION.code(spec.notification),
                               jnp.int32),
        react_code=jnp.asarray(cc.REACTION.code(spec.reaction), jnp.int32),
        route_code=jnp.asarray(route_code, jnp.int32),
        line_rate=f32(lk.line_rate),
        xoff=f32(lk.port_buffer * lk.pfc_xoff_frac),
        xon=f32(lk.port_buffer * lk.pfc_xon_frac),
        pool_xoff=f32(lk.shared_buffer * lk.pfc_xoff_frac),
        port_buffer=f32(lk.port_buffer),
        ecp_beta=f32(spec.rev.ecp_rate_ewma),
        mark=cc.MARKING.device_params(spec),
        notif=cc.NOTIFICATION.device_params(spec),
        react=cc.REACTION.device_params(spec),
        temperature=f32(temperature),
    )


def check_routing_paths(cfg: "CCConfig | CCSpec", scn: Scenario) -> None:
    """Adaptive routing needs detour candidates to select from.

    ``routing != "min"`` on a single-path scenario would silently
    degenerate to minimal routing (there is nothing to pick); raise at
    the point where config meets scenario instead.
    """
    K = 1 if scn.alt_routes is None else scn.alt_routes.shape[1]
    if cfg.routing != "min" and K == 1:
        raise ValueError(
            f"routing={cfg.routing!r} needs a multi-path scenario with "
            f"detour candidates (build it with ScenarioSpec(n_paths > 1) "
            f"or Scenario.alt_routes); this scenario is single-path")


def init_state(scn: Scenario, cfg: "CCConfig | CCSpec",
               delay_slots: int | None = None) -> FluidState:
    F, H = scn.routes.shape
    L = scn.capacity.shape[0]
    V = int(getattr(cfg.link, "n_vcs", 1))
    D = delay_depth(scn) if delay_slots is None \
        else _check_delay(scn, delay_slots)
    line = jnp.asarray(np.minimum(scn.gen_rate, cfg.link.line_rate),
                       jnp.float32)
    z_f = jnp.zeros((F,), jnp.float32)
    return FluidState(
        qh=jnp.zeros((F, H), jnp.float32),
        nicq=z_f, delivered=z_f, offered=z_f, dropped=z_f,
        est=jnp.zeros((F, H), jnp.float32),
        paused=jnp.zeros((L * V,), jnp.float32),
        rate=line,
        rp_target=line,
        alpha=jnp.full((F,), cfg.dcqcn.alpha_init, jnp.float32),
        byte_cnt=z_f, tmr=z_f, alpha_tmr=z_f,
        bc_stage=jnp.zeros((F,), jnp.int32),
        t_stage=jnp.zeros((F,), jnp.int32),
        hold=z_f, np_tmr=jnp.full((F,), 1.0, jnp.float32),
        trig_buf=jnp.zeros((D, F), jnp.float32),
        tgt_buf=jnp.zeros((D, F), jnp.float32),
        path_idx=jnp.zeros((F,), jnp.int32),
        cc=cc.init_cc_state(scn),
        t=jnp.zeros((), jnp.int32),
    )


def kernel_tier(use_kernels) -> str:
    """Normalise the ``use_kernels`` tiers.

    ``False`` -> ``"off"`` (pure jnp step), ``True`` -> ``"flow"`` (the
    per-flow ``repro.kernels.cc_step`` kernels of PR 4), ``"mega"`` ->
    the whole-step megakernel (``repro.kernels.fluid_step``).  The
    string forms are accepted directly so configs can spell the tier.
    """
    if use_kernels is False or use_kernels is None:
        return "off"
    if use_kernels is True:
        return "flow"
    if use_kernels in ("off", "flow", "mega"):
        return use_kernels
    raise ValueError(
        f"use_kernels must be False, True or 'mega' "
        f"(or the tier names 'off'/'flow'), got {use_kernels!r}")


def _refuse_soft_kernels(tier: str, temperature) -> None:
    """Every Pallas tier implements the *hard* dynamics only.

    A positive soft-relaxation temperature (``repro.tune``) under
    ``use_kernels`` used to be silently ignored — PR 7 guarded only
    ``Sweep.run``.  Raise wherever the temperature is statically known
    to be positive; a traced temperature (batched sweeps) cannot be
    inspected here and stays guarded at the ``Sweep.run`` entry point.
    """
    if tier == "off":
        return
    if isinstance(temperature, jax.core.Tracer):
        return
    try:
        tv = float(temperature)
    except (TypeError, jax.errors.ConcretizationTypeError):
        return
    if tv > 0.0:
        raise ValueError(
            "temperature > 0 needs use_kernels=False: the Pallas "
            "kernel tiers implement the hard dynamics only, so the "
            "soft gates (PFC hysteresis, marking thresholds, CNP "
            "windows) would be silently ignored")


def fluid_step(st: FluidState, sd: ScenarioDev, par: StepParams, *,
               dt: float, n_switches: int, reduce: str = "fused",
               dense_rows: int = 0, use_kernels: "bool | str" = False,
               interpret: bool = False, n_vcs: int = 1,
               packed_react: dict | None = None):
    """One ``dt`` update: (state, scenario, params) -> (state, trace).

    Pure in all array arguments; ``dt`` / ``n_switches`` and the
    pipeline switches are static.  ``sd`` and ``par`` are data, so a
    sweep vmaps this over a leading run axis with a single compilation.

    ``reduce`` picks the per-link reduction engine:
      * ``"fused"`` (default) — every per-link sum rides one of three
        multi-channel sorted segment reductions over the precomputed
        incidence (``sd.red_perm``/``red_seg``), bit-identical to the
        scatter path (stable sort preserves each link's contributor
        order; interleaved +0.0 terms from unselected candidates are
        exact no-ops).
      * ``"pallas"`` — same fused layout, summed by the
        ``repro.kernels.fluid_reduce`` Pallas TPU kernel (all channels
        resident in VMEM, ordered accumulation, so still bit-exact).
      * ``"scat"`` — the legacy one-scatter-per-quantity path, kept as
        the parity/benchmark baseline.

    ``dense_rows`` (static, from ``dense_reduce_rows``) upgrades the
    ``"fused"`` engine to the scatter-free dense-CSR form: each pass
    gathers contributors into a [L, dense_rows] table and accumulates
    positions left-to-right — the fastest path when link load is not
    pathologically skewed, still bit-identical.  Must cover the longest
    per-link contributor list; 0 keeps the segment-sum engine.

    ``use_kernels`` selects the Pallas tier (see ``kernel_tier``):
      * ``False`` — pure jnp step (the parity reference).
      * ``True`` — the per-flow block (generation, notification timer,
        RP/ERP/swift reaction) rides the ``repro.kernels.cc_step``
        kernels — one HBM round trip per state vector instead of one
        per intermediate.  ``packed_react`` optionally carries the
        prepacked per-stage param rows (``cc.pack_react_rows``) so a
        scanned step doesn't rebuild them every substep.
      * ``"mega"`` — the ENTIRE step (all phases, link reductions
        included) runs as ONE ``repro.kernels.fluid_step`` launch with
        the state VMEM-resident; stage dispatch happens *inside* the
        kernel on the traced codes, so the whole CCSpec matrix still
        shares one build.  Requires ``reduce != "pallas"`` (the
        reduction kernel cannot nest inside the launch).

    Every kernel tier implements the hard dynamics only; combining one
    with ``temperature > 0`` raises (see ``_refuse_soft_kernels``).
    ``interpret=True`` runs every Pallas kernel in interpreter mode
    (CPU tests).

    ``n_vcs`` (static, ``LinkParams.n_vcs``) splits every wire's input
    buffer into that many virtual-channel queues with independent
    backlog, FIFO order and PFC pause state (per-VC thresholds =
    port thresholds / n_vcs); wire *capacity* stays shared, served
    across VCs in proportion to drainable backlog.  Per-wire
    quantities (fair grants, oversubscription, the shared pool, UGAL
    path cost) are per-VC sums folded back per wire.  ``n_vcs = 1``
    takes statically identical code paths to the legacy single-queue
    model — bitwise, not just numerically.
    """
    if reduce not in ("fused", "pallas", "scat"):
        raise ValueError(
            f"reduce must be 'fused', 'pallas' or 'scat', got {reduce!r}")
    tier = kernel_tier(use_kernels)
    _refuse_soft_kernels(tier, par.temperature)
    if tier == "mega":
        from repro.kernels.fluid_step import megastep
        body = step_body_fn(dt=dt, n_switches=n_switches, reduce=reduce,
                            dense_rows=dense_rows, n_vcs=n_vcs)
        return megastep(st, sd, par, body=body, interpret=interpret)
    return _step_body(st, sd, par, dt=dt, n_switches=n_switches,
                      reduce=reduce, dense_rows=dense_rows,
                      use_kernels=(tier == "flow"), interpret=interpret,
                      n_vcs=n_vcs, packed_react=packed_react)


def step_body_fn(*, dt: float, n_switches: int, reduce: str = "fused",
                 dense_rows: int = 0, n_vcs: int = 1):
    """The in-kernel step closure: ``(st, sd, par) -> (state, trace)``.

    This is the single definition of the update the megakernel executes
    — statics baked, stage dispatch through each stage's
    ``kernel_body`` (falling back to its jnp ``step``), and the dense
    engine in its tiled on-chip form.  It is the *same* jnp math as the
    plain path (same primitives, same order), which is what holds the
    mega tier bit-exact to the reference.
    """
    if reduce == "pallas":
        raise ValueError(
            "use_kernels='mega' runs the link reductions inside the "
            "launch; reduce must be 'fused' or 'scat' (the "
            "fluid_reduce Pallas kernel cannot nest in the megakernel)")

    def body(st, sd, par):
        return _step_body(st, sd, par, dt=dt, n_switches=n_switches,
                          reduce=reduce, dense_rows=dense_rows,
                          use_kernels=False, interpret=False,
                          n_vcs=n_vcs, dense_tiled=True, in_kernel=True)

    return body


def _step_body(st: FluidState, sd: ScenarioDev, par: StepParams, *,
               dt: float, n_switches: int, reduce: str,
               dense_rows: int, use_kernels: bool, interpret: bool,
               n_vcs: int, dense_tiled: bool = False,
               in_kernel: bool = False,
               packed_react: dict | None = None):
    """The step update itself (see ``fluid_step`` for semantics).

    ``dense_tiled`` swaps the dense-CSR accumulation for its
    ``[S, block]``-tiled on-chip form (bit-identical, see
    ``repro.kernels.fluid_step.dense_reduce_tiled``); ``in_kernel``
    marks that this trace runs inside the megakernel launch, which
    routes every cc dispatch through the stages' ``kernel_body``
    entries and must not nest further ``pallas_call``s.
    """
    fused = reduce != "scat"
    F, K, H = sd.alt_routes.shape
    L = sd.cap_ext.shape[0] - 1
    V = int(n_vcs)
    S = L * V                 # (wire, VC) queue count; S == L when V == 1
    D = st.trig_buf.shape[0]
    dt = jnp.float32(dt)

    def to_wire(x_ext):
        """Fold a per-queue [S + 1] sum to per-wire [L + 1] (keep
        scratch).  Static identity at V == 1 — zero graph change."""
        if V == 1:
            return x_ext
        return jnp.concatenate(
            [x_ext[:S].reshape(L, V).sum(axis=1), x_ext[S:]])
    # soft-relaxation temperature: every hard gate below is written
    # ``soft.select(tau, soft_expr, hard_expr)`` with the hard branch
    # verbatim, so tau == 0 is bitwise the hard model (repro.tune).
    tau = par.temperature

    if in_kernel:
        # inside the megakernel trace, numpy-backed constants would be
        # captured by the kernel jaxpr (pallas_call refuses); iota
        # generates the same int32 indices on-chip — value-identical.
        arange_h = jax.lax.iota(jnp.int32, H)[None, :]
        fidx = jax.lax.iota(jnp.int32, F)
    else:
        _ah, _fi = _index_consts(F, H)
        arange_h = jnp.asarray(_ah)
        fidx = jnp.asarray(_fi)
    t_sec = st.t.astype(jnp.float32) * dt

    def pick_paths(k_idx):
        """([F, H] routes, [F] hops) of candidate ``k_idx`` per flow."""
        r = jnp.take_along_axis(sd.alt_routes, k_idx[:, None, None],
                                axis=1)[:, 0]
        h = jnp.take_along_axis(sd.alt_hops, k_idx[:, None], axis=1)[:, 0]
        return r, h

    if fused and dense_rows:
        # dense-CSR row table, shared by every reduction pass this
        # step: position p of queue q reads sorted row off[q] + p (the
        # sentinel F*K*H reads an all-zero row).
        _lens = sd.red_off[1:S + 1] - sd.red_off[:S]        # [S]
        _pos = jnp.arange(dense_rows, dtype=jnp.int32)[None, :]
        dense_idx = jnp.where(_pos < _lens[:, None],
                              sd.red_off[:S, None] + _pos,
                              F * K * H).reshape(-1)

    def link_sums(channels, k_sel):
        """All per-queue sums of the [F, H] ``channels`` in ONE sweep.

        Channels are laid out on candidate slot ``k_sel`` per flow
        (zeros elsewhere) and gathered into the queue-sorted incidence
        order; one [F*K*H, C] pass produces every [S+1] per-(wire, VC)
        vector at once instead of C scatters (S == L when V == 1, in
        which case "queue" is just "wire").  The pass is summed by
        the dense-CSR tiles, the Pallas kernel, or a sorted segment
        sum — all three accumulate each queue's contributors in the
        same order, so the result is bit-identical across engines.
        """
        data = jnp.stack(channels, axis=-1)                 # [F, H, C]
        C = data.shape[-1]
        if K > 1:
            onehot = (jnp.arange(K, dtype=jnp.int32)[None, :]
                      == k_sel[:, None])                    # [F, K]
            data = data[:, None] * \
                onehot[:, :, None, None].astype(jnp.float32)
        data = jnp.take(data.reshape(F * K * H, C), sd.red_perm, axis=0)
        if reduce == "pallas":
            from repro.kernels.fluid_reduce import segment_reduce
            sums = segment_reduce(data, sd.red_seg, S + 1,
                                  interpret=interpret)
        elif dense_rows:
            data_ext = jnp.concatenate(
                [data, jnp.zeros((1, C), jnp.float32)])
            if dense_tiled:
                from repro.kernels.fluid_step import dense_reduce_tiled
                sums = dense_reduce_tiled(data_ext, dense_idx, S,
                                          dense_rows)
            else:
                dense = jnp.take(data_ext, dense_idx,
                                 axis=0).reshape(S, dense_rows, C)

                def body(p, acc):
                    return acc + jax.lax.dynamic_slice_in_dim(
                        dense, p, 1, 1)[:, 0]

                acc = jax.lax.fori_loop(0, dense_rows, body,
                                        jnp.zeros((S, C), jnp.float32))
                sums = jnp.concatenate(
                    [acc, jnp.zeros((1, C), jnp.float32)])
        else:
            sums = jax.ops.segment_sum(data, sd.red_seg,
                                       num_segments=S + 1,
                                       indices_are_sorted=True)
        return [sums[:, c] for c in range(C)]

    # ---- 0. path selection (min / valiant / ugal) -------------------------
    if K == 1:
        # single-path scenario: selection is statically a no-op, and the
        # update below is the exact single-table computation.
        path_idx = st.path_idx
        routes, hops = sd.alt_routes[:, 0, :], sd.alt_hops[:, 0]
    else:
        # Per-link backlog of the *pre-step* queues, laid out along each
        # flow's currently selected path (its queued bytes live there).
        routes_old, hops_old = pick_paths(st.path_idx)
        v_old = routes_old != PAD
        hq_old = v_old & (arange_h < (hops_old[:, None] - 1))
        if fused:
            (B_prev,) = link_sums([jnp.where(hq_old, st.qh, 0.0)],
                                  st.path_idx)
            B_prev = to_wire(B_prev)
        elif V == 1:
            B_prev = jnp.zeros((L + 1,), jnp.float32).at[
                jnp.where(v_old, routes_old, L)].add(
                    jnp.where(hq_old, st.qh, 0.0))
        else:
            vc_old = jnp.take_along_axis(
                sd.vc, st.path_idx[:, None, None], axis=1)[:, 0]
            B_prev = to_wire(jnp.zeros((S + 1,), jnp.float32).at[
                jnp.where(v_old, routes_old * V + vc_old, S)].add(
                    jnp.where(hq_old, st.qh, 0.0)))

        def path_cost(k_idx):
            """UGAL cost: hop count x backlog along the candidate."""
            r, h = pick_paths(k_idx)
            v = r != PAD
            q = jnp.sum(jnp.where(v, B_prev[jnp.where(v, r, L)], 0.0),
                        axis=1)
            return h.astype(jnp.float32) * q

        # one sampled detour per flow, rotating over its valid slots
        # (slots 1..n_alt; flows without candidates stay minimal)
        n_alt = jnp.sum((sd.alt_hops[:, 1:] > 0).astype(jnp.int32), axis=1)
        samp = jnp.where(n_alt > 0,
                         1 + (fidx + st.t) % jnp.maximum(n_alt, 1), 0)
        # UGAL-L: switch only if the detour's queue-weighted hops beat
        # the minimal path's STRICTLY — ties (e.g. zero backlog
        # everywhere) keep the minimal route.
        ugal_pick = jnp.where(path_cost(samp) < path_cost(
            jnp.zeros((F,), jnp.int32)), samp, 0)
        # selection epochs: flow start (both modes) + CNP arrival (ugal
        # re-evaluates under congestion feedback).  Reading the delay
        # line here matches phase 5's cnp exactly: this step's emissions
        # land at (t + rtt) % D != t % D since 0 < rtt < D.
        starting = (t_sec >= sd.t_start) & (t_sec - dt < sd.t_start)
        cnp_now = st.trig_buf[st.t % D] > 0
        epoch = starting | ((par.route_code == 2) & cnp_now)
        pick = jnp.where(par.route_code == 1, samp, ugal_pick)
        path_idx = jnp.where(par.route_code == 0, 0,
                             jnp.where(epoch, pick, st.path_idx))
        routes, hops = pick_paths(path_idx)

    valid = routes != PAD
    widx = jnp.where(valid, routes, L)         # PAD -> scratch slot L
    if V == 1:
        qidx = widx                            # queue == wire, verbatim
    else:
        # VC of the selected candidate per hop; PAD hops carry VC 0
        # (enforced host-side), so qidx == S exactly at the scratch.
        vc_sel = sd.vc[:, 0, :] if K == 1 else jnp.take_along_axis(
            sd.vc, path_idx[:, None, None], axis=1)[:, 0]
        qidx = jnp.where(valid, widx * V + vc_sel, S)
    is_last = valid & (arange_h == (hops[:, None] - 1))
    holds_queue = valid & (arange_h < (hops[:, None] - 1))
    eps_rate = jnp.float32(1e6)                # B/s: "active" demand

    def scat(values_fh, init=0.0):
        """Scatter-add a [F,H] quantity onto per-queue slots [S+1]."""
        out = jnp.full((S + 1,), init, jnp.float32)
        return out.at[qidx].add(values_fh)

    # ---- 1. generation ----------------------------------------------------
    if use_kernels:
        from repro.kernels.cc_step import gen_np_step
        nicq, offered, dropped, np_tmr_t = gen_np_step(
            st.nicq, st.offered, st.dropped, st.np_tmr,
            sd.gen_rate, sd.t_start, sd.t_stop, sd.volume, sd.nic_buffer,
            t_sec=t_sec, dt=dt, interpret=interpret)
    else:
        active = (t_sec >= sd.t_start) & (t_sec < sd.t_stop)
        gen = jnp.where(active, sd.gen_rate, 0.0) * dt
        gen = jnp.minimum(gen, jnp.maximum(sd.volume - st.offered, 0.0))
        nicq = st.nicq + gen
        over = jnp.maximum(nicq - sd.nic_buffer, 0.0)
        nicq = nicq - over
        offered = st.offered + gen - over
        dropped = st.dropped + over
        np_tmr_t = st.np_tmr + dt              # notification-window tick

    # ---- 2. transfers -----------------------------------------------------
    src_inj = jnp.minimum(nicq, jnp.minimum(st.rate, par.line_rate) * dt)
    src_q = jnp.concatenate([src_inj[:, None], st.qh[:, :-1]], axis=1)
    src_q = jnp.where(valid, src_q, 0.0)

    pause_q = jnp.concatenate([st.paused, jnp.zeros((1,), jnp.float32)])
    wire_open = 1.0 - pause_q[qidx]                    # [F,H] 1 = drainable

    # strict-FIFO HoL factor per link queue: share of the queue whose
    # *next* wire is currently drainable.  ``wire_open`` is an exact
    # 0/1 float in hard mode; a fractional pause level scales service
    # proportionally (the fluid relaxation of the on/off gate).
    next_open = jnp.concatenate(
        [wire_open[:, 1:], jnp.ones((F, 1), jnp.float32)], axis=1)
    q_here = jnp.where(holds_queue, st.qh, 0.0)        # queue at sink(h)
    weight = src_q * wire_open
    caps_w = sd.cap_ext[widx]                          # [F,H]
    if fused:
        num, den, sum_w = link_sums(
            [q_here * next_open, q_here, weight], path_idx)
    else:
        num = scat(q_here * next_open)
        den = scat(q_here)
        sum_w = scat(weight)
    # FIFO factor is per (wire, VC) queue — a paused-head VC no longer
    # stalls its siblings, only its own lane (the HoL fix VCs buy).
    fifo_ok = jnp.where(den > 0, num / jnp.maximum(den, 1e-9), 1.0)
    # ... but the byte budget is per *wire*: capacity is shared across
    # VCs in proportion to drainable backlog.  fifo_ok <= 1, so the
    # summed per-VC grants never exceed the wire's C*dt.
    sum_w_w = to_wire(sum_w)

    budget = caps_w * dt * fifo_ok[qidx]
    share = jnp.where(sum_w_w[widx] > 0,
                      budget * weight / jnp.maximum(sum_w_w[widx], 1e-9),
                      0.0)
    T = jnp.minimum(weight, share)                     # bytes crossing h

    nicq = nicq - T[:, 0]
    qh = st.qh - jnp.pad(T[:, 1:], ((0, 0), (0, 1)))   # drain from h-1
    qh = qh + jnp.where(holds_queue, T, 0.0)           # land at sink(h)
    qh = jnp.maximum(qh, 0.0)
    deliv_step = jnp.sum(jnp.where(is_last, T, 0.0), axis=1)
    delivered = st.delivered + deliv_step

    # crossing-rate EWMA (doubles as arrival-into-queue estimate)
    est = (1 - par.ecp_beta) * st.est + par.ecp_beta * (T / dt)

    # Demand to cross wire h = arrival rate into the queue feeding it
    # (pre-stall, so FIFO-blocked victims keep their true demand).
    # Computed here so the post-transfer reduction pass covers the PFC
    # sink queues AND the marking activity sums in one sweep.
    dem = jnp.concatenate([est[:, :1], est[:, :-1]], axis=1)
    dem = jnp.where(valid, dem, 0.0)
    act = (dem > eps_rate) & valid

    # ---- 3. PFC -----------------------------------------------------------
    if fused:
        B_ext, n_act, sum_dem = link_sums(
            [jnp.where(holds_queue, qh, 0.0),
             act.astype(jnp.float32),
             jnp.where(act, dem, 0.0)], path_idx)
        B = B_ext[:S]                           # [S] per-(wire, VC) queues
    else:
        B = scat(jnp.where(holds_queue, qh, 0.0))[:S]
        n_act = scat(act.astype(jnp.float32), init=0.0)
        sum_dem = scat(jnp.where(act, dem, 0.0))
    # fair grants / oversubscription below are per-wire notions
    n_act_w = to_wire(n_act)
    sum_dem_w = to_wire(sum_dem)
    # xoff/xon hysteresis per queue: hard = set above xoff, clear below
    # xon, hold in between; soft = the pause level relaxes toward 1 (0)
    # through a sigmoid band O(tau * port_buffer) wide around each
    # threshold.  With V > 1 the port thresholds split evenly across
    # the VC queues (static branch — V == 1 keeps the exact scalars).
    if V == 1:
        xoff_q, xon_q = par.xoff, par.xon
    else:
        xoff_q, xon_q = par.xoff / V, par.xon / V
    paused_h = jnp.where(B > xoff_q, 1.0,
                         jnp.where(B < xon_q, 0.0, st.paused))
    g_on = soft.unit_gate(B - xoff_q, tau, par.port_buffer)
    g_off = soft.unit_gate(xon_q - B, tau, par.port_buffer)
    paused_s = st.paused + (1.0 - st.paused) * g_on - st.paused * g_off
    paused = soft.select(tau, paused_s, paused_h)
    sink_l = sd.sink_ext[:L]
    # shared pool counts the wire's whole input buffer across its VCs
    B_wire = B if V == 1 else B.reshape(L, V).sum(axis=1)
    if fused:
        pool = jax.ops.segment_sum(
            jnp.take(jnp.where(sink_l >= 0, B_wire, 0.0), sd.pool_perm),
            sd.pool_seg, num_segments=n_switches + 1,
            indices_are_sorted=True)[:n_switches]
    else:
        pool = jnp.zeros((n_switches,), jnp.float32).at[
            jnp.maximum(sink_l, 0)].add(
                jnp.where(sink_l >= 0, B_wire, 0.0))
    pool_hot = soft.select(
        tau,
        soft.unit_gate(pool - par.pool_xoff, tau, par.port_buffer),
        (pool > par.pool_xoff).astype(jnp.float32))
    # max of pause levels == boolean OR on the exact 0/1 hard values;
    # a hot pool pauses every VC of the wire (pause is per-queue state)
    pool_pause = jnp.where(sink_l >= 0,
                           pool_hot[jnp.maximum(sink_l, 0)], 0.0)
    if V > 1:
        pool_pause = jnp.repeat(pool_pause, V)
    paused = jnp.maximum(paused, pool_pause)

    # ---- 4. marking (cc.MARKING dispatch) ---------------------------------
    # B1_w: occupancy of the flow's own (wire, VC) queue — marking sees
    # the lane the flow actually sits in, not its siblings' backlog
    B1 = jnp.concatenate([B, jnp.zeros((1,), jnp.float32)])
    B1_w = B1[qidx]
    present = (qh > 0) | (T > 0)

    share0 = caps_w / jnp.maximum(n_act_w[widx], 1.0)
    under = dem < share0
    if fused:
        surplus, n_heavy = link_sums(
            [jnp.where(act & under, share0 - dem, 0.0),
             (act & ~under).astype(jnp.float32)], path_idx)
    else:
        surplus = scat(jnp.where(act & under, share0 - dem, 0.0))
        n_heavy = scat((act & ~under).astype(jnp.float32))
    surplus_w = to_wire(surplus)
    n_heavy_w = to_wire(n_heavy)
    grant = jnp.where(
        under, dem,
        share0 + surplus_w[widx] / jnp.maximum(n_heavy_w[widx], 1.0))
    grant = jnp.where(act, grant, caps_w)
    # wire h oversubscribed?  (soft: sigmoid in the demand excess; the
    # PAD slot's cap is inf, so the soft gate is exactly 0 there too)
    oversub = soft.select(
        tau,
        soft.unit_gate(sum_dem_w[widx] - caps_w, tau, par.line_rate),
        (sum_dem_w[widx] > caps_w).astype(jnp.float32))
    # ... all shifted to the *next* wire (the flow's requested output)
    inf_col = jnp.full((F, 1), jnp.inf, jnp.float32)
    grant_next = jnp.concatenate([grant[:, 1:], inf_col], axis=1)
    grant_next = jnp.where(holds_queue, grant_next, jnp.inf)
    dem_next = jnp.concatenate(
        [dem[:, 1:], jnp.zeros((F, 1), jnp.float32)], axis=1)
    over_next = jnp.concatenate(
        [oversub[:, 1:], jnp.zeros((F, 1), jnp.float32)], axis=1)

    # Every registered marking stage (CP occupancy / ECP fair-grant /
    # slope ramp / ...) computes its mark set + severity from this
    # shared context; the traced ``mark_code`` selects one — so marking
    # joins scheme constants and routing as a one-launch sweep axis.
    (mark_fh, sev), cc_mark = cc.dispatch(
        cc.MARKING, par.mark_code, par.mark,
        cc.MarkCtx(B1_w=B1_w, present=present, holds_queue=holds_queue,
                   dem_next=dem_next, grant_next=grant_next,
                   over_next=over_next, port_buffer=par.port_buffer,
                   line_rate=par.line_rate, tau=tau),
        st.cc, in_kernel=in_kernel)
    # mark_fh is a [F, H] float mark intensity: exact 0/1 in hard mode,
    # sigmoid-graded under the soft model.
    mark_pos = mark_fh > 0.0
    marked = jnp.any(mark_pos, axis=1)
    # severity payload: fair grant at the marking queue, scaled down by
    # the queue's excess over V so standing backlog drains (ENP carries
    # "timely congestion severity", ERP converges to fair as B -> V).
    # Hard: min over marking hops.  Soft: intensity-weighted mean —
    # inf sentinels (non-queue hops) carry zero intensity and are
    # where-masked out, never multiplied (0 * inf = nan).
    tgt_h = jnp.min(jnp.where(mark_pos, sev, jnp.inf), axis=1)
    tgt_h = jnp.where(jnp.isfinite(tgt_h), tgt_h, par.line_rate)
    # inf severities (a marking hop whose next wire has no finite
    # grant) take the same line-rate fallback as the hard min above —
    # inside the mask, so the weighted mean never touches inf
    sev_fin = jnp.where(jnp.isfinite(sev), sev, par.line_rate)
    m_sev = jnp.sum(jnp.where(mark_pos, mark_fh * sev_fin, 0.0), axis=1)
    m_sum = jnp.sum(mark_fh, axis=1)
    tgt = soft.select(
        tau, (m_sev + 1e-6 * par.line_rate) / (m_sum + 1e-6), tgt_h)
    # notification sees a [F] mark level: any-hop in hard mode, the
    # peak intensity (capped at one message) under the soft model
    mark_lvl = jnp.minimum(jnp.max(mark_fh, axis=1), 1.0)

    # ---- 5. notification (cc.NOTIFICATION dispatch) -----------------------
    # Each stage decides who emits (suppression/coalescing window) and
    # *when* the payload lands: NP/ENP after the end-to-end RTT, FNCC
    # from the marking hop's position on the return path.  The delay
    # line is sized >= max(rtt)+1 (see delay_depth), so the modulo is a
    # ring-buffer index, never an aliased (shortened) feedback delay.
    # ``emit`` is a [F] float emission intensity (exact 0/1 hard,
    # fractional soft) — it is also the per-step control-traffic
    # counter surfaced in the trace below.
    (emit, np_tmr, wslot), cc_notif = cc.dispatch(
        cc.NOTIFICATION, par.notif_code, par.notif,
        cc.NotifCtx(marked=mark_lvl, mark_fh=mark_fh, np_tmr_t=np_tmr_t,
                    hops=hops, rtt=sd.rtt, t=st.t, D=D, tau=tau),
        st.cc, in_kernel=in_kernel)
    rslot = st.t % D
    if fused:
        # branch-free ring ops: one-hot compare instead of scatters.
        # Exact: each (wslot[f], f) cell gets the same single add/set,
        # every other cell an exact +0.0 / keep; the read row rslot is
        # disjoint from all write slots (0 < rtt < D).
        d_iota = jnp.arange(D, dtype=jnp.int32)[:, None]       # [D, 1]
        w_hot = d_iota == wslot[None, :]                       # [D, F]
        trig_buf = st.trig_buf + jnp.where(w_hot, emit[None, :], 0.0)
        tgt_buf = soft.select(
            tau,
            jnp.where(w_hot,
                      emit[None, :] * tgt[None, :]
                      + (1.0 - emit[None, :]) * st.tgt_buf,
                      st.tgt_buf),
            jnp.where(w_hot & (emit[None, :] > 0), tgt[None, :],
                      st.tgt_buf))
        cnp = soft.select(tau, jnp.minimum(trig_buf[rslot], 1.0),
                          (trig_buf[rslot] > 0).astype(jnp.float32))
        tgt_rx = tgt_buf[rslot]
        trig_buf = jnp.where(d_iota == rslot, 0.0, trig_buf)
    else:
        trig_buf = st.trig_buf.at[wslot, fidx].add(emit)
        prev_tgt = st.tgt_buf[wslot, fidx]
        tgt_buf = st.tgt_buf.at[wslot, fidx].set(
            soft.select(tau,
                        emit * tgt + (1.0 - emit) * prev_tgt,
                        jnp.where(emit > 0, tgt, prev_tgt)))
        cnp = soft.select(tau, jnp.minimum(trig_buf[rslot], 1.0),
                          (trig_buf[rslot] > 0).astype(jnp.float32))
        tgt_rx = tgt_buf[rslot]
        trig_buf = trig_buf.at[rslot].set(0.0)

    # ---- 6. reaction (cc.REACTION dispatch), branchless -------------------
    # Every registered reaction (fixed-rate PFC source / DCQCN RP / the
    # paper's ERP / delay-target swift / ...) advances from the same
    # context; the traced ``react_code`` selects one, and stages with a
    # Pallas form route through it behind ``use_kernels``.  The queuing-
    # delay estimate (bytes queued along the path / line rate) feeds the
    # mark-free delay-based stages.
    qdelay = jnp.sum(jnp.where(holds_queue, qh, 0.0),
                     axis=1) / par.line_rate
    react_out, cc_react = cc.dispatch(
        cc.REACTION, par.react_code, par.react,
        cc.ReactCtx(rate=st.rate, rp_target=st.rp_target, alpha=st.alpha,
                    byte_cnt=st.byte_cnt, tmr=st.tmr,
                    alpha_tmr=st.alpha_tmr, bc_stage=st.bc_stage,
                    t_stage=st.t_stage, hold=st.hold, cnp=cnp,
                    tgt_rx=tgt_rx, qdelay=qdelay, jitter=sd.jitter,
                    gen_rate=sd.gen_rate, line_rate=par.line_rate, dt=dt,
                    tau=tau),
        st.cc, use_kernels=use_kernels, interpret=interpret,
        in_kernel=in_kernel, packed=packed_react)

    new = FluidState(
        qh=qh, nicq=nicq, delivered=delivered, offered=offered,
        dropped=dropped, est=est, paused=paused, rate=react_out.rate,
        rp_target=react_out.rp_target, alpha=react_out.alpha,
        byte_cnt=react_out.byte_cnt, tmr=react_out.tmr,
        alpha_tmr=react_out.alpha_tmr, bc_stage=react_out.bc_stage,
        t_stage=react_out.t_stage, hold=react_out.hold, np_tmr=np_tmr,
        trig_buf=trig_buf, tgt_buf=tgt_buf, path_idx=path_idx,
        cc={**st.cc, **cc_mark, **cc_notif, **cc_react}, t=st.t + 1)
    rate = react_out.rate
    trace = StepTrace(
        delivered=delivered, rate=rate, inst_thr=deliv_step / dt,
        max_q=jnp.max(B),
        n_paused=jnp.sum((paused > 0.5).astype(jnp.int32)),
        marked=marked, cnp=cnp > 0,
        n_nonmin=jnp.sum((path_idx > 0).astype(jnp.int32)),
        ctrl=emit,
        pause_time=jnp.sum(paused) * dt,
        vc_stall=paused.reshape(L, V).sum(axis=0) * dt)
    return new, trace


def make_step_fn(scn: Scenario, cfg: "CCConfig | CCSpec",
                 delay_slots: int | None = None, *,
                 reduce: str = "fused", dense_rows: int | None = None,
                 use_kernels: "bool | str" = False,
                 interpret: bool = False, temperature: float = 0.0):
    """Returns step(state) -> (state, StepTrace). Pure; closes over statics.

    ``delay_slots`` pins a fixed delay-line depth (legacy callers passing
    ``DELAY_SLOTS``); it raises if any flow's RTT would overflow it.  By
    default the depth is sized from the scenario (``delay_depth``).
    ``reduce`` / ``use_kernels`` / ``interpret`` select the reduction
    engine and the Pallas tier (see ``fluid_step``);
    ``dense_rows=None`` auto-sizes the dense-CSR engine from the
    scenario (``dense_reduce_rows``), 0 forces the segment-sum engine.
    ``temperature`` selects the soft-relaxed dynamics (``repro.tune``)
    — only valid on the pure-jnp tier, since the kernels implement the
    hard model only (a positive value under any kernel tier raises).
    """
    if delay_slots is not None:
        _check_delay(scn, delay_slots)
    check_routing_paths(cfg, scn)
    tier = kernel_tier(use_kernels)
    _refuse_soft_kernels(tier, temperature)
    if tier == "mega" and reduce == "pallas":
        raise ValueError(
            "use_kernels='mega' runs the link reductions inside the "
            "launch; reduce must be 'fused' or 'scat' (the "
            "fluid_reduce Pallas kernel cannot nest in the megakernel)")
    n_vcs = int(getattr(cfg.link, "n_vcs", 1))
    sd = scenario_device(scn, n_vcs=n_vcs)
    par = step_params(cfg, temperature=temperature)
    n_sw = int(scn.n_switches)
    dt = float(cfg.sim.dt)
    if dense_rows is None:
        dense_rows = dense_reduce_rows(scn, n_vcs) \
            if reduce == "fused" else 0
    # flow tier: prepack the reaction kernels' SMEM param rows once per
    # step *function*, so a scanned step stops rebuilding them every
    # substep (they are pure functions of the run's constants).
    packed = cc.pack_react_rows(par.react, par.line_rate,
                                jnp.float32(dt)) if tier == "flow" else None

    def step(st: FluidState):
        return fluid_step(st, sd, par, dt=dt, n_switches=n_sw,
                          reduce=reduce, dense_rows=dense_rows,
                          use_kernels=use_kernels, interpret=interpret,
                          n_vcs=n_vcs, packed_react=packed)

    return step
