"""Dense fluid model of the CC closed loop (PFC / DCQCN / DCQCN-Rev).

TPU-native adaptation of the paper's event-driven evaluation (DESIGN.md §2):
the whole network is a fixed-shape state advanced by one fused, branch-free
update per ``dt``.  No event queue exists; flows x hops are vectorised.

Representation (compact, scales to DC-size):
  * ``routes[F, H]`` — link id crossed at each hop (PAD = -1).
  * ``qh[F, H]``     — bytes of flow f queued at the *sink* of wire h
                       (the input buffer of the downstream switch), waiting
                       to cross wire h+1.  The last wire delivers to the
                       host, so qh[:, hops-1] is always 0.
  * ``nicq[F]``      — host backlog (generated, not yet injected).

Per step (Jacobi, from pre-step state):
  1. generation into nicq (rate-limited window generator, finite NIC buf);
  2. transfers: every wire w serves the queues feeding it proportionally
     to their backlog, capped by C_w*dt, gated by PFC pause, and scaled by
     a strict-FIFO HoL factor (a queue whose head bytes belong to a paused
     flow stalls everyone — the paper's victim pathology);
  3. PFC: a wire pauses when its sink queue crosses XOFF (hysteresis XON),
     plus a shared-pool pause per switch;
  4. marking: CP (occupancy only) vs ECP (occupancy AND flow rate above
     its waterfilled fair grant on its next wire — victims never marked);
  5. notification: NP (50us suppression) vs ENP (fast coalescing +
     severity payload = fair grant at the marking queue);
  6. reaction: RP (DCQCN alpha/stage machine) vs ERP (set to signalled
     fair share, hold, desynchronised additive recovery).

All arrays are float32; the update is pure jnp and runs inside lax.scan.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .params import CCConfig, CCScheme
from .routing import PAD


class Scenario(NamedTuple):
    """Static per-run tensors (host numpy; moved to device once)."""

    routes: np.ndarray        # [F, H] int32 link ids, PAD = -1
    hops: np.ndarray          # [F] int32
    gen_rate: np.ndarray      # [F] f32 B/s offered by the generator
    t_start: np.ndarray       # [F] f32 s
    t_stop: np.ndarray        # [F] f32 s (generator closes)
    volume: np.ndarray        # [F] f32 B total work (inf = window-limited)
    capacity: np.ndarray      # [L] f32 B/s per directed link
    sink_switch: np.ndarray   # [L] int32 (-1 for host sinks)
    n_switches: int
    rtt_steps: np.ndarray     # [F] int32 CNP feedback delay in dt steps
    nic_buffer: float = 4e6   # B of host NIC queue


class FluidState(NamedTuple):
    qh: jnp.ndarray           # [F, H] bytes at hop queues
    nicq: jnp.ndarray         # [F]
    delivered: jnp.ndarray    # [F]
    offered: jnp.ndarray      # [F] bytes the generator admitted into nicq
    dropped: jnp.ndarray      # [F] generator overflow (app backpressure)
    est: jnp.ndarray          # [F, H] EWMA crossing rate per wire (B/s)
    paused: jnp.ndarray       # [L] bool
    # reaction-point state (DCQCN RP and ERP share slots where sensible)
    rate: jnp.ndarray         # [F] current injection rate
    rp_target: jnp.ndarray    # [F]
    alpha: jnp.ndarray        # [F]
    byte_cnt: jnp.ndarray     # [F]
    tmr: jnp.ndarray          # [F]
    alpha_tmr: jnp.ndarray    # [F]
    bc_stage: jnp.ndarray     # [F] int32
    t_stage: jnp.ndarray      # [F] int32
    hold: jnp.ndarray         # [F] ERP hold-down timer
    np_tmr: jnp.ndarray       # [F] time since last CNP emission
    trig_buf: jnp.ndarray     # [D, F] CNP in flight (delay line)
    tgt_buf: jnp.ndarray      # [D, F] severity payload in flight
    t: jnp.ndarray            # [] int32 step counter


class StepTrace(NamedTuple):
    delivered: jnp.ndarray    # [F] cumulative bytes
    rate: jnp.ndarray         # [F] RP rate
    inst_thr: jnp.ndarray     # [F] delivery rate this step (B/s)
    max_q: jnp.ndarray        # [] hottest queue (bytes)
    n_paused: jnp.ndarray     # [] paused wires
    marked: jnp.ndarray       # [F] marked this step?
    cnp: jnp.ndarray          # [F] CNP received this step?


DELAY_SLOTS = 32              # max CNP feedback delay in steps


def _flow_jitter(n: int) -> np.ndarray:
    """Deterministic per-flow jitter in [-1, 1] (Weyl sequence)."""
    x = (np.arange(n, dtype=np.uint64) * np.uint64(2654435761)) % np.uint64(2**32)
    return (x.astype(np.float64) / 2**31 - 1.0).astype(np.float32)


def init_state(scn: Scenario, cfg: CCConfig) -> FluidState:
    F, H = scn.routes.shape
    L = scn.capacity.shape[0]
    line = jnp.asarray(np.minimum(scn.gen_rate, cfg.link.line_rate),
                       jnp.float32)
    z_f = jnp.zeros((F,), jnp.float32)
    return FluidState(
        qh=jnp.zeros((F, H), jnp.float32),
        nicq=z_f, delivered=z_f, offered=z_f, dropped=z_f,
        est=jnp.zeros((F, H), jnp.float32),
        paused=jnp.zeros((L,), bool),
        rate=line,
        rp_target=line,
        alpha=jnp.full((F,), cfg.dcqcn.alpha_init, jnp.float32),
        byte_cnt=z_f, tmr=z_f, alpha_tmr=z_f,
        bc_stage=jnp.zeros((F,), jnp.int32),
        t_stage=jnp.zeros((F,), jnp.int32),
        hold=z_f, np_tmr=jnp.full((F,), 1.0, jnp.float32),
        trig_buf=jnp.zeros((DELAY_SLOTS, F), jnp.float32),
        tgt_buf=jnp.zeros((DELAY_SLOTS, F), jnp.float32),
        t=jnp.zeros((), jnp.int32),
    )


def make_step_fn(scn: Scenario, cfg: CCConfig):
    """Returns step(state) -> (state, StepTrace). Pure; closes over statics."""
    scheme = cfg.scheme
    dt = jnp.float32(cfg.sim.dt)
    F, H = scn.routes.shape
    L = int(scn.capacity.shape[0])

    routes = jnp.asarray(scn.routes, jnp.int32)
    valid = routes != PAD
    # safe indices: PAD -> L (extra scratch slot in scatter targets)
    widx = jnp.where(valid, routes, L)
    hops = jnp.asarray(scn.hops, jnp.int32)
    arange_h = jnp.arange(H, dtype=jnp.int32)[None, :]
    is_last = valid & (arange_h == (hops[:, None] - 1))
    holds_queue = valid & (arange_h < (hops[:, None] - 1))   # qh slots in use

    cap = jnp.asarray(np.concatenate([scn.capacity, [np.inf]]), jnp.float32)
    sink_sw = jnp.asarray(np.concatenate([scn.sink_switch, [-1]]), jnp.int32)
    n_sw = int(scn.n_switches)

    gen_rate = jnp.asarray(scn.gen_rate, jnp.float32)
    t_start = jnp.asarray(scn.t_start, jnp.float32)
    t_stop = jnp.asarray(scn.t_stop, jnp.float32)
    volume = jnp.asarray(scn.volume, jnp.float32)
    line_rate = jnp.float32(cfg.link.line_rate)
    nic_buf = jnp.float32(scn.nic_buffer)
    rtt = jnp.asarray(scn.rtt_steps % DELAY_SLOTS, jnp.int32)
    fidx = jnp.arange(F, dtype=jnp.int32)

    xoff = jnp.float32(cfg.link.port_buffer * cfg.link.pfc_xoff_frac)
    xon = jnp.float32(cfg.link.port_buffer * cfg.link.pfc_xon_frac)
    pool_xoff = jnp.float32(cfg.link.shared_buffer * cfg.link.pfc_xoff_frac)
    marking_kind = cfg.marking_kind
    reaction_kind = cfg.reaction_kind
    v_thresh = jnp.float32(cfg.dcqcn.kmin if marking_kind == "cp"
                           else cfg.rev.detect_threshold)

    p = cfg.dcqcn
    r = cfg.rev
    jitter = jnp.asarray(1.0 + r.erp_jitter * _flow_jitter(F), jnp.float32)
    erp_slope = jnp.float32(r.erp_rai) * jitter
    eps_rate = jnp.float32(1e6)      # B/s: "active" demand threshold

    def scat(values_fh, init=0.0):
        """Scatter-add a [F,H] quantity onto per-link slots [L+1]."""
        out = jnp.full((L + 1,), init, jnp.float32)
        return out.at[widx].add(values_fh)

    def step(st: FluidState):
        t_sec = st.t.astype(jnp.float32) * dt

        # ---- 1. generation ------------------------------------------------
        active = (t_sec >= t_start) & (t_sec < t_stop)
        gen = jnp.where(active, gen_rate, 0.0) * dt
        gen = jnp.minimum(gen, jnp.maximum(volume - st.offered, 0.0))
        nicq = st.nicq + gen
        over = jnp.maximum(nicq - nic_buf, 0.0)
        nicq = nicq - over
        offered = st.offered + gen - over
        dropped = st.dropped + over

        # ---- 2. transfers -------------------------------------------------
        # source quantity eligible to cross wire h this step
        src_inj = jnp.minimum(nicq, jnp.minimum(st.rate, line_rate) * dt)
        src_q = jnp.concatenate([src_inj[:, None], st.qh[:, :-1]], axis=1)
        src_q = jnp.where(valid, src_q, 0.0)

        pause_l = jnp.concatenate([st.paused, jnp.zeros((1,), bool)])
        wire_open = ~pause_l[widx]                         # [F,H]

        # strict-FIFO HoL factor per link queue: share of the queue whose
        # *next* wire is currently drainable.
        next_open = jnp.concatenate(
            [wire_open[:, 1:], jnp.ones((F, 1), bool)], axis=1)
        q_here = jnp.where(holds_queue, st.qh, 0.0)        # queue at sink(h)
        num = scat(q_here * next_open)
        den = scat(q_here)
        fifo_ok = jnp.where(den > 0, num / jnp.maximum(den, 1e-9), 1.0)

        weight = jnp.where(wire_open, src_q, 0.0)
        sum_w = scat(weight)
        budget = cap[widx] * dt * fifo_ok[widx]
        share = jnp.where(sum_w[widx] > 0,
                          budget * weight / jnp.maximum(sum_w[widx], 1e-9),
                          0.0)
        T = jnp.minimum(weight, share)                     # bytes crossing h

        nicq = nicq - T[:, 0]
        qh = st.qh - jnp.pad(T[:, 1:], ((0, 0), (0, 1)))   # drain from h-1
        qh = qh + jnp.where(holds_queue, T, 0.0)           # land at sink(h)
        qh = jnp.maximum(qh, 0.0)
        deliv_step = jnp.sum(jnp.where(is_last, T, 0.0), axis=1)
        delivered = st.delivered + deliv_step

        # crossing-rate EWMA (doubles as arrival-into-queue estimate)
        beta = jnp.float32(r.ecp_rate_ewma)
        est = (1 - beta) * st.est + beta * (T / dt)

        # ---- 3. PFC -------------------------------------------------------
        B = scat(jnp.where(holds_queue, qh, 0.0))[:L]      # [L] sink queues
        paused = jnp.where(B > xoff, True,
                           jnp.where(B < xon, False, st.paused))
        pool = jnp.zeros((n_sw,), jnp.float32).at[
            jnp.maximum(sink_sw[:L], 0)].add(jnp.where(sink_sw[:L] >= 0, B, 0.0))
        pool_hot = pool > pool_xoff
        paused = paused | jnp.where(sink_sw[:L] >= 0, pool_hot[
            jnp.maximum(sink_sw[:L], 0)], False)

        # ---- 4. marking ---------------------------------------------------
        B1 = jnp.concatenate([B, jnp.zeros((1,), jnp.float32)])
        q_over = B1[widx] > v_thresh                       # [F,H] queue hot?
        present = (qh > 0) | (T > 0)

        # Demand to cross wire h = arrival rate into the queue feeding it
        # (pre-stall, so FIFO-blocked victims keep their true demand).
        dem = jnp.concatenate([est[:, :1], est[:, :-1]], axis=1)
        dem = jnp.where(valid, dem, 0.0)
        act = (dem > eps_rate) & valid
        n_act = scat(act.astype(jnp.float32), init=0.0)
        caps_w = cap[widx]
        sum_dem = scat(jnp.where(act, dem, 0.0))
        share0 = caps_w / jnp.maximum(n_act[widx], 1.0)
        under = dem < share0
        surplus = scat(jnp.where(act & under, share0 - dem, 0.0))
        n_heavy = scat((act & ~under).astype(jnp.float32))
        grant = jnp.where(
            under, dem,
            share0 + surplus[widx] / jnp.maximum(n_heavy[widx], 1.0))
        grant = jnp.where(act, grant, caps_w)
        oversub = sum_dem[widx] > caps_w          # wire h oversubscribed?
        # ... all shifted to the *next* wire (the flow's requested output)
        inf_col = jnp.full((F, 1), jnp.inf, jnp.float32)
        grant_next = jnp.concatenate([grant[:, 1:], inf_col], axis=1)
        grant_next = jnp.where(holds_queue, grant_next, jnp.inf)
        dem_next = jnp.concatenate([dem[:, 1:], inf_col * 0], axis=1)
        over_next = jnp.concatenate(
            [oversub[:, 1:], jnp.zeros((F, 1), bool)], axis=1)

        if marking_kind == "cp":
            mark_fh = q_over & present & holds_queue
        else:
            # ECP: queue over threshold AND the flow's requested output is
            # oversubscribed AND its own demand exceeds its fair grant there.
            congesting = over_next & (
                dem_next > jnp.float32(r.ecp_fairness_slack) * grant_next)
            mark_fh = q_over & present & congesting & holds_queue
        marked = jnp.any(mark_fh, axis=1)
        # severity payload: fair grant at the marking queue, scaled down by
        # the queue's excess over V so standing backlog drains (ENP carries
        # "timely congestion severity", ERP converges to fair as B -> V).
        qexc = jnp.clip((B1[widx] - v_thresh)
                        / jnp.float32(cfg.link.port_buffer), 0.0, 1.0)
        sev = grant_next * (1.0 - jnp.float32(r.erp_drain_gain) * qexc)
        tgt = jnp.min(jnp.where(mark_fh, sev, jnp.inf), axis=1)
        tgt = jnp.where(jnp.isfinite(tgt), tgt, line_rate)

        # ---- 5. notification (NP / ENP) ----------------------------------
        window = jnp.float32(p.cnp_window if reaction_kind == "rp"
                             else r.enp_coalesce)
        np_tmr = st.np_tmr + dt
        emit = marked & (np_tmr >= window)
        np_tmr = jnp.where(emit, 0.0, np_tmr)
        wslot = (st.t + rtt) % DELAY_SLOTS
        trig_buf = st.trig_buf.at[wslot, fidx].add(emit.astype(jnp.float32))
        tgt_buf = st.tgt_buf.at[wslot, fidx].set(
            jnp.where(emit, tgt, st.tgt_buf[wslot, fidx]))
        rslot = st.t % DELAY_SLOTS
        cnp = trig_buf[rslot] > 0
        tgt_rx = tgt_buf[rslot]
        trig_buf = trig_buf.at[rslot].set(0.0)

        # ---- 6. reaction (RP / ERP) ---------------------------------------
        if scheme == CCScheme.PFC_ONLY:
            rate = jnp.full((F,), 1.0, jnp.float32) * jnp.minimum(
                gen_rate, line_rate)
            rp_target, alpha = st.rp_target, st.alpha
            byte_cnt, tmr, alpha_tmr = st.byte_cnt, st.tmr, st.alpha_tmr
            bc_stage, t_stage, hold = st.bc_stage, st.t_stage, st.hold
        elif reaction_kind == "rp":
            g = jnp.float32(p.g)
            # alpha update timer (runs when no CNP)
            alpha_tmr = st.alpha_tmr + dt
            a_tick = alpha_tmr >= jnp.float32(p.timer_T)
            alpha = jnp.where(a_tick, (1 - g) * st.alpha, st.alpha)
            alpha_tmr = jnp.where(a_tick, 0.0, alpha_tmr)
            # on CNP: cut
            rp_target = jnp.where(cnp, st.rate, st.rp_target)
            rate = jnp.where(
                cnp,
                st.rate * (1 - alpha * jnp.float32(p.rate_decrease_factor)),
                st.rate)
            alpha = jnp.where(cnp, (1 - g) * alpha + g, alpha)
            byte_cnt = jnp.where(cnp, 0.0, st.byte_cnt + st.rate * dt)
            tmr = jnp.where(cnp, 0.0, st.tmr + dt)
            alpha_tmr = jnp.where(cnp, 0.0, alpha_tmr)
            bc_stage = jnp.where(cnp, 0, st.bc_stage)
            t_stage = jnp.where(cnp, 0, st.t_stage)
            # increase events
            b_ev = byte_cnt >= jnp.float32(p.byte_counter_B)
            t_ev = tmr >= jnp.float32(p.timer_T)
            byte_cnt = jnp.where(b_ev, 0.0, byte_cnt)
            tmr = jnp.where(t_ev, 0.0, tmr)
            bc_stage = bc_stage + b_ev.astype(jnp.int32)
            t_stage = t_stage + t_ev.astype(jnp.int32)
            ev = b_ev | t_ev
            imax = jnp.maximum(bc_stage, t_stage)
            imin = jnp.minimum(bc_stage, t_stage)
            frs = jnp.int32(p.fr_stages)
            in_fr = imax <= frs
            in_hyper = imin > frs
            rp_target = jnp.where(
                ev & ~in_fr & ~in_hyper, rp_target + jnp.float32(p.rai),
                rp_target)
            rp_target = jnp.where(
                ev & in_hyper,
                rp_target + jnp.float32(p.rhai)
                * (imin - frs).astype(jnp.float32),
                rp_target)
            rate = jnp.where(ev, 0.5 * (rate + rp_target), rate)
            rate = jnp.clip(rate, jnp.float32(p.min_rate), line_rate)
            rp_target = jnp.clip(rp_target, jnp.float32(p.min_rate), line_rate)
            hold = st.hold
        else:  # DCQCN_REV / ERP
            rate = jnp.where(
                cnp,
                jnp.maximum(jnp.float32(r.erp_settle) * tgt_rx,
                            jnp.float32(r.min_rate)),
                st.rate)
            hold = jnp.where(cnp, jnp.float32(r.erp_hold),
                             jnp.maximum(st.hold - dt, 0.0))
            rate = jnp.where(~cnp & (hold <= 0), rate + erp_slope * dt, rate)
            rate = jnp.clip(rate, jnp.float32(r.min_rate), line_rate)
            rp_target, alpha = st.rp_target, st.alpha
            byte_cnt, tmr, alpha_tmr = st.byte_cnt, st.tmr, st.alpha_tmr
            bc_stage, t_stage = st.bc_stage, st.t_stage

        new = FluidState(
            qh=qh, nicq=nicq, delivered=delivered, offered=offered,
            dropped=dropped, est=est, paused=paused, rate=rate,
            rp_target=rp_target, alpha=alpha, byte_cnt=byte_cnt, tmr=tmr,
            alpha_tmr=alpha_tmr, bc_stage=bc_stage, t_stage=t_stage,
            hold=hold, np_tmr=np_tmr, trig_buf=trig_buf, tgt_buf=tgt_buf,
            t=st.t + 1)
        trace = StepTrace(
            delivered=delivered, rate=rate, inst_thr=deliv_step / dt,
            max_q=jnp.max(B), n_paused=jnp.sum(paused.astype(jnp.int32)),
            marked=marked, cnp=cnp)
        return new, trace

    return step
