"""Wire-format (JSON) round-trips for configs, scenarios and results.

The what-if query service returns simulation results over the wire, so
``SimResult`` / ``SweepResult`` need a stable, numpy-free dict form:

  * every scalar is a plain python ``int`` / ``float`` / ``bool`` /
    ``str`` — ``json.dumps`` works without custom encoders (python's
    ``json`` emits ``Infinity`` for the window-mode ``t_stop = inf``
    sentinels and parses it back; the round-trip is exact);
  * arrays are tagged dicts ``{"__ndarray__": dtype, "shape": [...],
    "data": [flat scalars]}`` — float32 values pass through python
    floats (float64) losslessly, so ``from_dict(to_dict(x))`` is
    *bit-exact*, not approximate;
  * configs carry a ``__class__`` tag (``CCConfig`` vs ``CCSpec``) and
    spell enums by name, so a round-tripped config reconstructs the
    identical frozen dataclass (hash-equal, jit-cache-equal).

Traces dominate the payload; ``simresult_to_dict(..., traces=False)``
drops them (final state + metadata only) and ``decimate=k`` thins them
by a further factor k for dashboard-weight responses — both are lossy
by construction and refuse to ``from_dict`` back into a full result.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .fluid import FluidState, Scenario
from .params import (CCConfig, CCScheme, CCSpec, DCQCNParams, FNCCParams,
                     LinkParams, RevParams, SimParams, SwiftParams)

# ---------------------------------------------------------------------------
# arrays and scalars
# ---------------------------------------------------------------------------


def encode_array(a: np.ndarray) -> dict:
    a = np.asarray(a)
    return {"__ndarray__": a.dtype.name, "shape": list(a.shape),
            "data": a.ravel().tolist()}


def decode_array(d: dict) -> np.ndarray:
    return np.asarray(d["data"], dtype=d["__ndarray__"]).reshape(
        d["shape"])


def _enc(x):
    """Array -> tagged dict; numpy scalar -> python scalar; rest as-is."""
    if isinstance(x, np.ndarray) or hasattr(x, "__array__"):
        a = np.asarray(x)
        return a.item() if a.ndim == 0 else encode_array(a)
    if isinstance(x, (np.generic,)):
        return x.item()
    return x


def _dec(x):
    if isinstance(x, dict) and "__ndarray__" in x:
        return decode_array(x)
    return x


# ---------------------------------------------------------------------------
# configs (CCConfig / CCSpec and their frozen param dataclasses)
# ---------------------------------------------------------------------------

_PARAM_FIELDS = {"link": LinkParams, "dcqcn": DCQCNParams,
                 "rev": RevParams, "fncc": FNCCParams,
                 "swift": SwiftParams, "sim": SimParams}


def config_to_dict(cfg: "CCConfig | CCSpec") -> dict:
    out = {"__class__": type(cfg).__name__}
    for f in dataclasses.fields(cfg):
        v = getattr(cfg, f.name)
        if f.name in _PARAM_FIELDS:
            out[f.name] = dataclasses.asdict(v)
        elif isinstance(v, CCScheme):
            out[f.name] = v.name
        else:
            out[f.name] = v
    return out


def config_from_dict(d: dict) -> "CCConfig | CCSpec":
    cls = {"CCConfig": CCConfig, "CCSpec": CCSpec}[d["__class__"]]
    kw = {}
    for f in dataclasses.fields(cls):
        if f.name not in d:
            continue
        v = d[f.name]
        if f.name in _PARAM_FIELDS:
            v = _PARAM_FIELDS[f.name](**v)
        elif f.name == "scheme":
            v = CCScheme[v]
        kw[f.name] = v
    return cls(**kw)


# ---------------------------------------------------------------------------
# scenario + final state
# ---------------------------------------------------------------------------


def scenario_to_dict(scn: Scenario) -> dict:
    out = {"__class__": "Scenario"}
    for name, v in scn._asdict().items():
        out[name] = None if v is None else _enc(v)
    return out


def scenario_from_dict(d: dict) -> Scenario:
    kw = {}
    for name in Scenario._fields:
        v = d.get(name)
        kw[name] = None if v is None else _dec(v)
    kw["n_switches"] = int(kw["n_switches"])
    # host-side scalar buffers stay scalars (shape [] arrays decode to
    # python floats via _enc's .item() on the way out)
    return Scenario(**kw)


def state_to_dict(st: FluidState) -> dict:
    # always the tagged-array form (even for the 0-d ``t`` counter), so
    # dtypes survive the round trip exactly
    out = {"__class__": "FluidState"}
    for name, v in st._asdict().items():
        if name == "cc":
            out[name] = {k: encode_array(np.asarray(a))
                         for k, a in v.items()}
        else:
            out[name] = encode_array(np.asarray(v))
    return out


def state_from_dict(d: dict) -> FluidState:
    kw = {}
    for name in FluidState._fields:
        v = d[name]
        if name == "cc":
            kw[name] = {k: _dec(a) for k, a in v.items()}
        else:
            kw[name] = np.asarray(_dec(v))
    return FluidState(**kw)


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------

_SIM_TRACE_FIELDS = ("delivered", "rate", "inst_thr", "max_q",
                     "n_paused", "marked", "cnp", "n_nonmin", "ctrl",
                     "pause_time", "vc_stall")

#: Trace fields added after the wire format shipped: absent from old
#: blobs, decoded as None (SimResult treats None as "predates the
#: counter") instead of failing the whole reconstruction.
_OPTIONAL_TRACE_FIELDS = ("pause_time", "vc_stall")


def simresult_to_dict(res, *, traces: bool = True,
                      decimate: int = 1) -> dict:
    """``SimResult`` -> JSON-ready dict (see module docstring).

    ``traces=False`` omits the trace arrays (and ``times``);
    ``decimate=k`` keeps every k-th sample.  Either makes the dict
    lossy: ``simresult_from_dict`` only accepts the full form.
    """
    out = {"__class__": "SimResult",
           "cfg": config_to_dict(res.cfg),
           "scn": scenario_to_dict(res.scn),
           "trace_every": int(res.trace_every),
           "traces": bool(traces) and decimate == 1,
           "final": state_to_dict(res.final)}
    if traces:
        k = max(1, int(decimate))
        out["times"] = encode_array(np.asarray(res.times)[k - 1::k])
        for f in _SIM_TRACE_FIELDS:
            v = getattr(res, f)
            if v is None:                 # result predates the counter
                continue
            out[f] = encode_array(np.asarray(v)[k - 1::k])
        if k > 1:
            out["trace_every"] = int(res.trace_every) * k
    return out


def simresult_from_dict(d: dict):
    from .simulator import SimResult
    if not d.get("traces"):
        raise ValueError(
            "cannot reconstruct a SimResult from a trace-less (or "
            "re-decimated) dict; serialise with traces=True, decimate=1")
    return SimResult(
        cfg=config_from_dict(d["cfg"]),
        scn=scenario_from_dict(d["scn"]),
        times=decode_array(d["times"]),
        final=state_from_dict(d["final"]),
        trace_every=int(d["trace_every"]),
        **{f: decode_array(d[f]) if f in d else None
           for f in _SIM_TRACE_FIELDS})


def sweepresult_to_dict(res, *, traces: bool = True) -> dict:
    """``SweepResult`` -> JSON-ready dict.

    Point order is the wire contract (names key the per-point views);
    the batched trace pytree serialises field-wise with its [R, T, ...]
    layout intact, so ``sweepresult_from_dict`` rebuilds a result whose
    per-point ``SimResult`` views are bit-identical to the original's.
    """
    from .experiments import SweepPoint  # noqa: F401  (doc pointer)
    out = {"__class__": "SweepResult",
           "trace_every": int(res.trace_every),
           "traces": bool(traces),
           "times": encode_array(np.asarray(res.times)),
           "points": [{"name": p.name, "cfg": config_to_dict(p.cfg),
                       "scenario": scenario_to_dict(p.scenario)}
                      for p in res.points],
           "final": state_to_dict(res.final)}
    if traces:
        out["trace_fields"] = {
            f: encode_array(np.asarray(getattr(res.traces, f)))
            for f in _SIM_TRACE_FIELDS
            if getattr(res.traces, f, None) is not None}
    return out


def sweepresult_from_dict(d: dict):
    from .experiments import SweepPoint, SweepResult
    from .simulator import TraceSample
    if not d.get("traces"):
        raise ValueError(
            "cannot reconstruct a SweepResult from a trace-less dict; "
            "serialise with traces=True")
    points = [SweepPoint(name=p["name"], cfg=config_from_dict(p["cfg"]),
                         scenario=scenario_from_dict(p["scenario"]))
              for p in d["points"]]
    tf = {f: decode_array(d["trace_fields"][f])
          if f in d["trace_fields"] else None
          for f in _SIM_TRACE_FIELDS}
    missing = [f for f, v in tf.items() if v is None
               and f not in _OPTIONAL_TRACE_FIELDS]
    if missing:
        raise KeyError(f"trace_fields missing {missing}")
    return SweepResult(points=points,
                       times=decode_array(d["times"]),
                       traces=TraceSample(**tf),
                       final=state_from_dict(d["final"]),
                       trace_every=int(d["trace_every"]))


# ---------------------------------------------------------------------------
# shard-level merge (the fleet coordinator's result assembly)
# ---------------------------------------------------------------------------


def merge_sweepresults(parts, points=None):
    """Concatenate shard-level ``SweepResult``s back into one grid result.

    ``parts`` are per-shard results over disjoint point subsets of one
    grid (all sharing the shape envelope, times and ``trace_every`` —
    the fleet planner pins those, so the arrays concatenate along the
    run axis without reshaping).  ``points`` optionally supplies the
    authoritative ``SweepPoint`` list: the merged run axis follows its
    order, and its (typically unpadded) scenarios replace the shards'
    padded copies so per-point views trim exactly like the one-launch
    reference.  Every name in ``points`` must be covered by exactly one
    shard; with ``points=None`` the merge keeps concatenation order.

    Purely a gather — every run's row is copied bit-for-bit from the
    shard that computed it, so a merge of bitwise-correct shards is
    bitwise the uninterrupted ``Sweep.run``.
    """
    import jax

    from .experiments import SweepResult
    from .simulator import TraceSample

    parts = list(parts)
    if not parts:
        raise ValueError("merge_sweepresults: no shard results")
    base = parts[0]
    for p in parts[1:]:
        if int(p.trace_every) != int(base.trace_every) or \
                not np.array_equal(np.asarray(p.times),
                                   np.asarray(base.times)):
            raise ValueError(
                "shard results disagree on times/trace_every; they are "
                "not shards of one plan")
    where: dict[str, tuple] = {}
    for part in parts:
        for r, pt in enumerate(part.points):
            if pt.name in where:
                raise ValueError(f"point {pt.name!r} in two shards")
            where[pt.name] = (part, r)
    if points is None:
        order = [(pt.name, part, r) for part in parts
                 for r, pt in enumerate(part.points)]
        out_points = [part.points[r] for _, part, r in order]
    else:
        missing = [p.name for p in points if p.name not in where]
        if missing:
            raise ValueError(f"no shard produced points {missing}")
        order = [(p.name, *where[p.name]) for p in points]
        out_points = list(points)
    tf = {}
    for f in _SIM_TRACE_FIELDS:
        vals = [getattr(part.traces, f, None) for part in parts]
        if any(v is None for v in vals):
            if not all(v is None for v in vals):
                raise ValueError(f"trace field {f!r} present in some "
                                 f"shards but not others")
            tf[f] = None
            continue
        tf[f] = np.stack([np.asarray(getattr(part.traces, f))[r]
                          for _, part, r in order])
    finals = [jax.tree.map(lambda x, r=r: np.asarray(x)[r], part.final)
              for _, part, r in order]
    final = jax.tree.map(lambda *xs: np.stack(xs), *finals)
    return SweepResult(points=out_points,
                       times=np.asarray(base.times),
                       traces=TraceSample(**tf),
                       final=final,
                       trace_every=int(base.trace_every))
