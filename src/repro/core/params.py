"""Congestion-control parameters for the DCQCN / DCQCN-Rev closed loop.

All constants follow the paper (§II.A) and, where the paper defers, the
original DCQCN fluid model (Zhu et al., SIGCOMM'15, [6]):

* 100 Gbps serial full-duplex pipelined links, 25 ns propagation delay.
* Tomahawk-3-like switches: 64 MB shared buffer, >= 512 KB per port.
* MTU 1 KB;  Kmin = Kmax = V = 15 KB  (step marking).
* DCQCN RP constants from [6]: g = 1/256, timer T = 55 us, byte counter
  B = 10 MB, RAI = 40 Mbps, RHAI = 200 Mbps, rate-decrease factor 1/2,
  NP CNP window 50 us.

Everything is a frozen dataclass of plain floats so that configs hash and
jit caches key cleanly; arrays live in the simulator state, not here.
"""

from __future__ import annotations

import dataclasses
import enum


class CCScheme(enum.IntEnum):
    """Which closed loop is active (static python-level switch)."""

    PFC_ONLY = 0      # no end-to-end CC; only hop-by-hop PFC backpressure
    DCQCN = 1         # CP/NP/RP per [6]
    DCQCN_REV = 2     # ECP/ENP/ERP per the paper


@dataclasses.dataclass(frozen=True)
class LinkParams:
    """Physical link + switch buffer constants (paper §II.A)."""

    line_rate: float = 12.5e9          # B/s  (100 Gbps)
    propagation_delay: float = 25e-9   # s, per hop
    mtu: float = 1024.0                # B
    port_buffer: float = 512 * 1024.0  # B, per-port guaranteed share
    shared_buffer: float = 64 * 1024 * 1024.0  # B, switch total (Tomahawk 3)
    # PFC thresholds (fractions of the per-port buffer). XOFF below XON is a
    # config error; hysteresis keeps pause from chattering at the boundary.
    pfc_xoff_frac: float = 0.75
    pfc_xon_frac: float = 0.50
    # Virtual channels per wire.  Each wire's input buffer splits into
    # ``n_vcs`` independent queues with their own PFC pause state and
    # FIFO order (per-VC thresholds are the port thresholds / n_vcs);
    # capacity stays shared per wire.  VC assignment is scenario data
    # (``Scenario.vc``, default: Valiant detours ride VC 1 so they stop
    # HoL-blocking minimal traffic).  ``n_vcs = 1`` is bit-identical to
    # the single-queue model (golden-grid held).
    n_vcs: int = 1

    def __post_init__(self):
        if self.pfc_xoff_frac <= self.pfc_xon_frac:
            raise ValueError(
                f"PFC XOFF threshold must sit above XON for the pause "
                f"hysteresis to work: pfc_xoff_frac={self.pfc_xoff_frac} "
                f"<= pfc_xon_frac={self.pfc_xon_frac} would pause and "
                f"unpause in the same region (or never unpause)")
        if not (isinstance(self.n_vcs, int) and self.n_vcs >= 1):
            raise ValueError(
                f"n_vcs={self.n_vcs!r} must be a positive int: it is a "
                f"static shape parameter (per-VC queue/pause state is "
                f"[n_links * n_vcs])")


@dataclasses.dataclass(frozen=True)
class DCQCNParams:
    """CP/NP/RP constants per [6]; Kmin=Kmax=V per the paper's §II.A."""

    # --- CP (switch marking) ---
    kmin: float = 15 * 1024.0          # B
    kmax: float = 15 * 1024.0          # B
    pmax: float = 1.0                  # marking prob at kmax (step since kmin==kmax)
    # --- NP (destination NIC) ---
    cnp_window: float = 50e-6          # s, min gap between CNPs of one flow
    # --- RP (source NIC) ---
    g: float = 1.0 / 256.0             # alpha EWMA gain
    alpha_init: float = 1.0
    rate_decrease_factor: float = 0.5  # R <- R * (1 - alpha * f)
    timer_T: float = 55e-6             # s, rate-increase timer period
    byte_counter_B: float = 10e6       # B, rate-increase byte period
    rai: float = 5e6                   # B/s additive increase (40 Mbps)
    rhai: float = 25e6                 # B/s hyper increase   (200 Mbps)
    fr_stages: int = 5                 # fast-recovery stages before AI
    min_rate: float = 1e6              # B/s floor so flows never starve

    def __post_init__(self):
        if self.kmin > self.kmax:
            raise ValueError(
                f"kmin={self.kmin} > kmax={self.kmax}: the marking ramp "
                f"must be non-decreasing (kmin == kmax gives step "
                f"marking; kmin < kmax the slope ramp up to pmax)")
        # The tuner explores these boxes programmatically (bounded
        # reparameterisations in repro.tune); construction-time checks
        # keep a mis-specified box from silently simulating nonsense.
        if not 0.0 < self.pmax <= 1.0:
            raise ValueError(
                f"pmax={self.pmax} must lie in (0, 1]: it is the marking "
                f"probability at kmax (0 would never mark, >1 is not a "
                f"probability)")
        if not 0.0 < self.g <= 1.0:
            raise ValueError(
                f"g={self.g} must lie in (0, 1]: it is the alpha EWMA "
                f"gain of the RP state machine")
        for name in ("rai", "rhai", "timer_T", "byte_counter_B",
                     "min_rate", "cnp_window"):
            v = getattr(self, name)
            if v < 0:
                raise ValueError(
                    f"{name}={v} must be non-negative (rate-increase "
                    f"gains, periods and floors have no meaningful "
                    f"negative form)")
        if not 0.0 <= self.rate_decrease_factor <= 1.0:
            raise ValueError(
                f"rate_decrease_factor={self.rate_decrease_factor} must "
                f"lie in [0, 1]: R <- R * (1 - alpha * f) would raise "
                f"the rate on congestion otherwise")


@dataclasses.dataclass(frozen=True)
class RevParams:
    """ECP/ENP/ERP constants (the paper's contribution).

    ECP: a flow is marked only if its measured arrival rate at the congested
    egress exceeds ``ecp_fairness_slack`` x fair-share of the drain rate.
    ENP: CNPs are immediate (coalesced at ``enp_coalesce``) and carry
    (drain bandwidth, n_contributors) severity.
    ERP: on CNP the rate is set to the signalled fair share scaled by
    ``erp_settle``; recovery is additive with a deterministic per-flow
    jitter in [1-j, 1+j] to desynchronise flows.
    """

    detect_threshold: float = 15 * 1024.0  # B, same V as DCQCN for parity
    ecp_fairness_slack: float = 1.10       # >1: tolerate small overshoot
    ecp_rate_ewma: float = 0.2             # per-dt EWMA for arrival estimate
    enp_coalesce: float = 5e-6             # s, CNP coalescing interval
    erp_settle: float = 0.98               # target = settle * fair_share
    erp_rai: float = 5e12                  # B/s^2 additive recovery slope
    #   (full 12.5 GB/s ramp in ~2.5 ms — same timescale DCQCN's staged
    #    recovery needs, but desynchronised and starting from fair share)
    erp_jitter: float = 0.5                # +-50% per-flow slope jitter
    erp_hold: float = 50e-6                # s, hold at target before recovery
    erp_drain_gain: float = 0.5            # severity: scale target below
    #   fair share in proportion to queue excess over V, so standing
    #   queues drain and the rate converges to fair as occupancy -> V
    min_rate: float = 1e6                  # B/s floor


@dataclasses.dataclass(frozen=True)
class FNCCParams:
    """FNCC-style fast in-path notification constants.

    Instead of the destination NIC echoing a CNP after the full forward
    trip, the congested switch writes the severity payload directly into
    the *return* path: the feedback delay shrinks from one RTT to the
    upstream trip from the marking hop back to the source,
    ``rtt/2 * (h_mark+1)/hops`` (scaled by ``rtt_scale``).
    """

    coalesce: float = 5e-6             # s, per-flow notification coalescing
    rtt_scale: float = 1.0             # scale on the hop-proportional delay


@dataclasses.dataclass(frozen=True)
class SwiftParams:
    """Delay-target reaction constants (Swift-like, mark-free).

    The source throttles on its *queuing-delay estimate* (bytes queued
    along the path / line rate) instead of mark arrival: multiplicative
    decrease proportional to the excess over ``target_delay`` (at most
    once per ``guard`` seconds), additive recovery below target.
    """

    target_delay: float = 3e-6         # s of path queuing delay
    beta: float = 0.8                  # max multiplicative decrease
    ai: float = 1e12                   # B/s^2 additive recovery slope
    guard: float = 25e-6               # s between decreases (~RTT pacing)
    min_rate: float = 1e6              # B/s floor


@dataclasses.dataclass(frozen=True)
class SimParams:
    """Integrator constants."""

    dt: float = 1e-6                   # s, fluid step
    t_end: float = 14e-3               # s, simulate past DCQCN's 12.5 ms tail
    trace_every: int = 10              # record a trace sample every N steps


#: Routing-mode selectors (traced into ``StepParams.route_code``):
#: ``min`` pins every flow to its minimal path; ``valiant`` pins a
#: sampled VLB detour at flow start; ``ugal`` compares queue-weighted
#: hop costs (UGAL-L) at flow start and on CNP epochs.  Modes beyond
#: ``min`` need a multi-path scenario (``ScenarioSpec(n_paths > 1)``)
#: to have any candidates to pick from.
ROUTING_MODES = ("min", "valiant", "ugal")


@dataclasses.dataclass(frozen=True)
class CCSpec:
    """Composable CC description: one pluggable component per stage.

    The closed loop decomposes into three independently improvable
    mechanisms — congestion detection (``marking``), notification
    (``notification``) and injection throttling (``reaction``) — each
    named by a registry entry in ``repro.core.cc``.  Every name traces
    to an integer code in ``StepParams``, so any (marking x
    notification x reaction x param-grid) product still compiles to ONE
    ``Sweep`` launch.

    Built-in stages (see ``repro.core.cc`` to add more):
      * marking:      ``cp`` (step occupancy), ``ecp`` (occupancy AND
                      rate over fair grant), ``slope`` (RED-style
                      kmin<kmax ramp up to ``pmax``, error-diffused)
      * notification: ``np`` (CNP window), ``enp`` (fast coalescing +
                      severity), ``fncc`` (in-path: congested hop
                      writes the return path, shrinking the delay)
      * reaction:     ``pfc`` (fixed-rate source), ``rp`` (DCQCN),
                      ``erp`` (the paper), ``swift`` (delay-target)

    The legacy ``CCConfig`` maps onto this via ``CCConfig.to_spec()``
    bit-exactly (golden-grid verified).
    """

    marking: str = "ecp"
    notification: str = "enp"
    reaction: str = "erp"
    # adaptive-routing mode (see ROUTING_MODES); a traced selector, so
    # routing joins the stage names as a one-launch sweep axis
    routing: str = "min"
    link: LinkParams = dataclasses.field(default_factory=LinkParams)
    dcqcn: DCQCNParams = dataclasses.field(default_factory=DCQCNParams)
    rev: RevParams = dataclasses.field(default_factory=RevParams)
    fncc: FNCCParams = dataclasses.field(default_factory=FNCCParams)
    swift: SwiftParams = dataclasses.field(default_factory=SwiftParams)
    sim: SimParams = dataclasses.field(default_factory=SimParams)

    def __post_init__(self):
        from . import cc                     # deferred: cc imports params
        for family, name in ((cc.MARKING, self.marking),
                             (cc.NOTIFICATION, self.notification),
                             (cc.REACTION, self.reaction)):
            if name not in family:
                raise ValueError(
                    f"unknown {family.family} stage {name!r}; registered: "
                    f"{family.names()} (register new stages via "
                    f"repro.core.cc.{family.family.upper()}.register)")
        if self.routing not in ROUTING_MODES:
            raise ValueError(f"unknown routing mode {self.routing!r}; "
                             f"expected one of {ROUTING_MODES}")

    def replace(self, **kw) -> "CCSpec":
        return dataclasses.replace(self, **kw)

    @property
    def name(self) -> str:
        return f"{self.marking}+{self.notification}+{self.reaction}"

    def to_spec(self) -> "CCSpec":
        return self


@dataclasses.dataclass(frozen=True)
class CCConfig:
    """Legacy scheme-enum config — a thin shim over ``CCSpec``.

    ``scheme`` (+ the ``marking``/``reaction`` ablation overrides) maps
    onto stage-registry entries via ``to_spec()``; the mapping is
    bit-exact on the golden grid, so existing configs and sweeps keep
    their numerics.  New code should construct ``CCSpec`` directly —
    it exposes notification as its own axis and accepts any registered
    stage (the override fields here also accept new registry names,
    e.g. ``marking="slope"`` or ``reaction="swift"``).
    """

    scheme: CCScheme = CCScheme.DCQCN_REV
    link: LinkParams = dataclasses.field(default_factory=LinkParams)
    dcqcn: DCQCNParams = dataclasses.field(default_factory=DCQCNParams)
    rev: RevParams = dataclasses.field(default_factory=RevParams)
    fncc: FNCCParams = dataclasses.field(default_factory=FNCCParams)
    swift: SwiftParams = dataclasses.field(default_factory=SwiftParams)
    sim: SimParams = dataclasses.field(default_factory=SimParams)
    # ablation overrides (None -> derived from scheme): isolate the
    # paper's mechanisms — marking in {cp, ecp, ...}, reaction in
    # {rp, erp, ...} (any registered stage name)
    marking: str | None = None
    reaction: str | None = None
    # adaptive-routing mode (see ROUTING_MODES); a traced selector, so
    # routing joins scheme/Kmin/gain as a one-launch sweep axis
    routing: str = "min"

    def replace(self, **kw) -> "CCConfig":
        return dataclasses.replace(self, **kw)

    @property
    def marking_kind(self) -> str:
        if self.marking:
            return self.marking
        return "ecp" if self.scheme == CCScheme.DCQCN_REV else "cp"

    @property
    def reaction_kind(self) -> str:
        if self.reaction:
            return self.reaction
        return "erp" if self.scheme == CCScheme.DCQCN_REV else "rp"

    def to_spec(self) -> CCSpec:
        """The registry view of this config (bit-exact shim).

        PFC_ONLY pins the fixed-rate ``pfc`` reaction (reaction
        overrides are ignored, as before); notification follows the
        reaction like the legacy window selection did — ``np`` with RP,
        ``enp`` otherwise.
        """
        reaction = ("pfc" if self.scheme == CCScheme.PFC_ONLY
                    else self.reaction_kind)
        notification = "np" if self.reaction_kind == "rp" else "enp"
        return CCSpec(
            marking=self.marking_kind, notification=notification,
            reaction=reaction, routing=self.routing, link=self.link,
            dcqcn=self.dcqcn, rev=self.rev, fncc=self.fncc,
            swift=self.swift, sim=self.sim)


PAPER_CONFIG = CCConfig()
