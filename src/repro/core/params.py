"""Congestion-control parameters for the DCQCN / DCQCN-Rev closed loop.

All constants follow the paper (§II.A) and, where the paper defers, the
original DCQCN fluid model (Zhu et al., SIGCOMM'15, [6]):

* 100 Gbps serial full-duplex pipelined links, 25 ns propagation delay.
* Tomahawk-3-like switches: 64 MB shared buffer, >= 512 KB per port.
* MTU 1 KB;  Kmin = Kmax = V = 15 KB  (step marking).
* DCQCN RP constants from [6]: g = 1/256, timer T = 55 us, byte counter
  B = 10 MB, RAI = 40 Mbps, RHAI = 200 Mbps, rate-decrease factor 1/2,
  NP CNP window 50 us.

Everything is a frozen dataclass of plain floats so that configs hash and
jit caches key cleanly; arrays live in the simulator state, not here.
"""

from __future__ import annotations

import dataclasses
import enum


class CCScheme(enum.IntEnum):
    """Which closed loop is active (static python-level switch)."""

    PFC_ONLY = 0      # no end-to-end CC; only hop-by-hop PFC backpressure
    DCQCN = 1         # CP/NP/RP per [6]
    DCQCN_REV = 2     # ECP/ENP/ERP per the paper


@dataclasses.dataclass(frozen=True)
class LinkParams:
    """Physical link + switch buffer constants (paper §II.A)."""

    line_rate: float = 12.5e9          # B/s  (100 Gbps)
    propagation_delay: float = 25e-9   # s, per hop
    mtu: float = 1024.0                # B
    port_buffer: float = 512 * 1024.0  # B, per-port guaranteed share
    shared_buffer: float = 64 * 1024 * 1024.0  # B, switch total (Tomahawk 3)
    # PFC thresholds (fractions of the per-port buffer). XOFF below XON is a
    # config error; hysteresis keeps pause from chattering at the boundary.
    pfc_xoff_frac: float = 0.75
    pfc_xon_frac: float = 0.50


@dataclasses.dataclass(frozen=True)
class DCQCNParams:
    """CP/NP/RP constants per [6]; Kmin=Kmax=V per the paper's §II.A."""

    # --- CP (switch marking) ---
    kmin: float = 15 * 1024.0          # B
    kmax: float = 15 * 1024.0          # B
    pmax: float = 1.0                  # marking prob at kmax (step since kmin==kmax)
    # --- NP (destination NIC) ---
    cnp_window: float = 50e-6          # s, min gap between CNPs of one flow
    # --- RP (source NIC) ---
    g: float = 1.0 / 256.0             # alpha EWMA gain
    alpha_init: float = 1.0
    rate_decrease_factor: float = 0.5  # R <- R * (1 - alpha * f)
    timer_T: float = 55e-6             # s, rate-increase timer period
    byte_counter_B: float = 10e6       # B, rate-increase byte period
    rai: float = 5e6                   # B/s additive increase (40 Mbps)
    rhai: float = 25e6                 # B/s hyper increase   (200 Mbps)
    fr_stages: int = 5                 # fast-recovery stages before AI
    min_rate: float = 1e6              # B/s floor so flows never starve


@dataclasses.dataclass(frozen=True)
class RevParams:
    """ECP/ENP/ERP constants (the paper's contribution).

    ECP: a flow is marked only if its measured arrival rate at the congested
    egress exceeds ``ecp_fairness_slack`` x fair-share of the drain rate.
    ENP: CNPs are immediate (coalesced at ``enp_coalesce``) and carry
    (drain bandwidth, n_contributors) severity.
    ERP: on CNP the rate is set to the signalled fair share scaled by
    ``erp_settle``; recovery is additive with a deterministic per-flow
    jitter in [1-j, 1+j] to desynchronise flows.
    """

    detect_threshold: float = 15 * 1024.0  # B, same V as DCQCN for parity
    ecp_fairness_slack: float = 1.10       # >1: tolerate small overshoot
    ecp_rate_ewma: float = 0.2             # per-dt EWMA for arrival estimate
    enp_coalesce: float = 5e-6             # s, CNP coalescing interval
    erp_settle: float = 0.98               # target = settle * fair_share
    erp_rai: float = 5e12                  # B/s^2 additive recovery slope
    #   (full 12.5 GB/s ramp in ~2.5 ms — same timescale DCQCN's staged
    #    recovery needs, but desynchronised and starting from fair share)
    erp_jitter: float = 0.5                # +-50% per-flow slope jitter
    erp_hold: float = 50e-6                # s, hold at target before recovery
    erp_drain_gain: float = 0.5            # severity: scale target below
    #   fair share in proportion to queue excess over V, so standing
    #   queues drain and the rate converges to fair as occupancy -> V
    min_rate: float = 1e6                  # B/s floor


@dataclasses.dataclass(frozen=True)
class SimParams:
    """Integrator constants."""

    dt: float = 1e-6                   # s, fluid step
    t_end: float = 14e-3               # s, simulate past DCQCN's 12.5 ms tail
    trace_every: int = 10              # record a trace sample every N steps


#: Routing-mode selectors (traced into ``StepParams.route_code``):
#: ``min`` pins every flow to its minimal path; ``valiant`` pins a
#: sampled VLB detour at flow start; ``ugal`` compares queue-weighted
#: hop costs (UGAL-L) at flow start and on CNP epochs.  Modes beyond
#: ``min`` need a multi-path scenario (``ScenarioSpec(n_paths > 1)``)
#: to have any candidates to pick from.
ROUTING_MODES = ("min", "valiant", "ugal")


@dataclasses.dataclass(frozen=True)
class CCConfig:
    scheme: CCScheme = CCScheme.DCQCN_REV
    link: LinkParams = dataclasses.field(default_factory=LinkParams)
    dcqcn: DCQCNParams = dataclasses.field(default_factory=DCQCNParams)
    rev: RevParams = dataclasses.field(default_factory=RevParams)
    sim: SimParams = dataclasses.field(default_factory=SimParams)
    # ablation overrides (None -> derived from scheme): isolate the
    # paper's mechanisms — marking in {cp, ecp}, reaction in {rp, erp}
    marking: str | None = None
    reaction: str | None = None
    # adaptive-routing mode (see ROUTING_MODES); a traced selector, so
    # routing joins scheme/Kmin/gain as a one-launch sweep axis
    routing: str = "min"

    def replace(self, **kw) -> "CCConfig":
        return dataclasses.replace(self, **kw)

    @property
    def marking_kind(self) -> str:
        if self.marking:
            return self.marking
        return "ecp" if self.scheme == CCScheme.DCQCN_REV else "cp"

    @property
    def reaction_kind(self) -> str:
        if self.reaction:
            return self.reaction
        return "erp" if self.scheme == CCScheme.DCQCN_REV else "rp"


PAPER_CONFIG = CCConfig()
