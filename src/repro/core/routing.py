"""Deterministic D-mod-K routing over the 3-stage CLOS.

The paper: "we have also modeled a deterministic routing similar to
D-mod-K, which balances the routes of the flows so that the links at a
given stage are crossed by a similar number of flow routes."

For the 3-stage CLOS with arity ``a`` and destination node ``d``:

* at the leaf, the uplink is chosen as ``d mod a``;
* at the agg, the uplink (spine digit) is ``(d // a) mod a``.

With the paper's flows this puts F0,F1 (→N16) and F3 (→N12) on the *same*
leaf-0 uplink (16 mod 4 == 12 mod 4 == 0), i.e. they share the wire into
the input buffer of switch 16 — exactly the HoL scene of §II.  The
alternative selector (``roll=1``) uses digit ``(d // a) mod a`` at the
leaf, which makes the victim's path wire-disjoint from the congesting
flows (needed to reach Fig. 2's 25 GB/s aggregate — see DESIGN.md §4 for
why both wirings are provided).

Routes are returned as padded link-id sequences ``[H_MAX]`` with -1
padding; H_MAX = 6 covers the worst case nic→leaf→agg→spine→agg→leaf→node.
"""

from __future__ import annotations

import numpy as np

from .topology import ClosIndex, Topology

H_MAX = 6
PAD = -1


def clos_route(idx: ClosIndex, src: int, dst: int, roll: int = 0) -> list[int]:
    """Directed-link id sequence for src node -> dst node (D-mod-K)."""
    a = idx.arity
    if roll not in (0, 1):
        raise ValueError(f"roll must be 0 or 1, got {roll}")
    if src == dst:
        return []
    s_leaf, d_leaf = src // a, dst // a
    s_grp, d_grp = s_leaf // a, d_leaf // a
    # digit selectors for up-path balancing: roll rotates which base-a
    # digit of dst picks each stage's uplink.
    # roll=0: leaf uses dst%a,     agg uses (dst//a)%a.
    # roll=1: leaf uses (dst//a)%a, agg uses dst%a  (swapped).
    digit0 = (dst // (a ** roll)) % a            # leaf uplink choice
    digit1 = (dst // (a ** (1 - roll))) % a      # agg uplink (spine digit)

    path = [idx.nic_up(src)]
    if d_leaf == s_leaf:
        path.append(idx.leaf_dn(dst))
        return path
    u0 = digit0
    path.append(idx.leaf_up(s_leaf, u0))         # -> agg(s_grp, u0)
    if d_grp == s_grp:
        path.append(idx.agg_dn(s_grp, u0, d_leaf % a))
        path.append(idx.leaf_dn(dst))
        return path
    u1 = digit1
    spine = u0 * a + u1
    path.append(idx.agg_up(s_grp, u0, u1))       # -> spine u0*a+u1
    path.append(idx.spine_dn(spine, d_grp))      # -> agg(d_grp, u0)
    path.append(idx.agg_dn(d_grp, u0, d_leaf % a))
    path.append(idx.leaf_dn(dst))
    return path


def build_flow_routes(topo: Topology, pairs: list[tuple[int, int]],
                      arity: int = 4, roll: int = 0) -> np.ndarray:
    """[F, H_MAX] int32 link-id matrix (PAD-filled) for (src,dst) pairs."""
    idx = ClosIndex(arity)
    routes = np.full((len(pairs), H_MAX), PAD, dtype=np.int32)
    for f, (s, d) in enumerate(pairs):
        p = clos_route(idx, s, d, roll=roll)
        if len(p) > H_MAX:
            raise ValueError(f"path longer than H_MAX for flow {f}: {p}")
        routes[f, : len(p)] = p
    return routes


def route_hops(routes: np.ndarray) -> np.ndarray:
    """Number of real hops per flow."""
    return (routes != PAD).sum(axis=1).astype(np.int32)


def validate_routes(topo: Topology, routes: np.ndarray) -> None:
    """Each consecutive link pair must share an entity (sink == src)."""
    for f in range(routes.shape[0]):
        hops = [h for h in routes[f] if h != PAD]
        for i in range(len(hops) - 1):
            if topo.link_dst[hops[i]] != topo.link_src[hops[i + 1]]:
                raise AssertionError(
                    f"flow {f}: link {hops[i]} sink "
                    f"{topo.link_dst[hops[i]]} != link {hops[i+1]} src "
                    f"{topo.link_src[hops[i+1]]}")


def link_incidence(alt_routes: np.ndarray, n_links: int,
                   vc: np.ndarray | None = None, n_vcs: int = 1
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sorted (flow, path, hop) -> link incidence for fused reductions.

    ``alt_routes`` is the [F, K, H] candidate stack (PAD = -1).  The
    flattened (f, k, h) entries are stably sorted by link id (PAD maps
    to the scratch segment ``n_links``), which turns every per-link
    scatter-add of the fluid step into ONE gather + sorted segment sum:
    the stable sort keeps each link's contributors in flattened (f, k,
    h) order, so sequential segment accumulation is bit-identical to
    the legacy ``.at[widx].add`` path.

    With ``n_vcs > 1`` the segment key becomes the *(link, VC) queue*
    ``link * n_vcs + vc[f, k, h]`` (``vc`` same shape as
    ``alt_routes``, values in [0, n_vcs)), so every per-queue sum of
    the per-VC fluid model rides the same single pass; PAD entries map
    to the scratch segment ``n_links * n_vcs`` regardless of their VC.
    At ``n_vcs = 1`` the key degenerates to the link id — the identical
    stable sort, hence the identical permutation and accumulation
    order, which is what keeps the single-VC model bitwise unchanged.

    Returns ``(perm, seg, offsets)`` with ``S = n_links * n_vcs``:
      * ``perm``    [F*K*H] int32 — gather order into the sorted layout
      * ``seg``     [F*K*H] int32 — sorted segment (queue) id per entry
      * ``offsets`` [S + 2] int32 — CSR row pointers: entries of queue
        q live at ``perm[offsets[q]:offsets[q + 1]]`` (segment ``S`` is
        the PAD scratch)
    """
    flat = alt_routes.reshape(-1).astype(np.int64)
    n_seg = n_links * n_vcs
    if n_vcs == 1 or vc is None:
        seg = np.where(flat == PAD, n_seg, flat * n_vcs)
    else:
        vflat = vc.reshape(-1).astype(np.int64)
        if vc.shape != alt_routes.shape:
            raise ValueError(f"vc shape {vc.shape} != routes shape "
                             f"{alt_routes.shape}")
        if ((vflat < 0) | (vflat >= n_vcs)).any():
            raise ValueError(f"vc entries must lie in [0, {n_vcs})")
        seg = np.where(flat == PAD, n_seg, flat * n_vcs + vflat)
    perm = np.argsort(seg, kind="stable").astype(np.int32)
    seg_sorted = seg[perm].astype(np.int32)
    offsets = np.zeros((n_seg + 2,), np.int64)
    np.add.at(offsets, seg_sorted + 1, 1)
    return perm, seg_sorted, np.cumsum(offsets).astype(np.int32)


def assign_vc(alt_routes: np.ndarray, n_vcs: int,
              mode: str = "slot",
              flow_vc: np.ndarray | None = None) -> np.ndarray:
    """Static VC assignment for a [F, K, H] candidate stack.

    ``mode`` picks the rule (both clip to the available ``n_vcs``):
      * ``"slot"`` — candidate slot 0 (the minimal path) rides VC 0,
        detour slots ride VC 1: Valiant/UGAL traffic stops sharing hop
        queues (and PFC pause state) with minimal traffic — the
        twice-deferred per-VC separation from the ROADMAP.
      * ``"hop"``  — VC escalates with hop index (``min(h, n_vcs-1)``),
        the classic dateline/credit-loop deadlock-avoidance discipline
        for torus/dragonfly cycles: a flow re-entering a previously
        used wire does so on a higher VC, breaking the cyclic buffer
        dependency that a pause storm needs to wedge.

    ``flow_vc`` ([F] ints, optional) overrides the rule per flow on
    every hop/slot — how a scenario pins e.g. a victim flow to its own
    lane.  PAD hops are forced to VC 0 so the incidence scratch mapping
    stays exact.  ``n_vcs = 1`` returns all-zeros (the single-queue
    model).
    """
    if mode not in ("slot", "hop"):
        raise ValueError(f"vc mode must be 'slot' or 'hop', got {mode!r}")
    F, K, H = alt_routes.shape
    if mode == "slot":
        vc = np.where(np.arange(K, dtype=np.int32)[None, :, None] > 0,
                      min(1, n_vcs - 1), 0)
        vc = np.broadcast_to(vc, (F, K, H))
    else:
        vc = np.broadcast_to(
            np.minimum(np.arange(H, dtype=np.int32), n_vcs - 1)
            [None, None, :], (F, K, H))
    if flow_vc is not None:
        fv = np.minimum(np.asarray(flow_vc, np.int32), n_vcs - 1)
        if fv.shape != (F,):
            raise ValueError(f"flow_vc must be [{F}], got {fv.shape}")
        if (fv < 0).any():
            raise ValueError("flow_vc entries must be >= 0")
        vc = np.broadcast_to(fv[:, None, None], (F, K, H))
    return np.where(alt_routes == PAD, 0, vc).astype(np.int32)


def stage_load(routes: np.ndarray, n_links: int) -> np.ndarray:
    """How many flow routes cross each link (balance diagnostic)."""
    load = np.zeros((n_links,), dtype=np.int64)
    for f in range(routes.shape[0]):
        for h in routes[f]:
            if h != PAD:
                load[h] += 1
    return load
