"""Fault-tolerant host training loop.

Large-scale runnability features (tests/test_train_loop.py exercises each
on CPU):
  * checkpoint/restart: async atomic saves every N steps; on start the
    loop resumes from the latest committed checkpoint including the data
    step (bit-exact),
  * preemption: SIGTERM-style `stop_flag` triggers a final save,
  * straggler detection: per-step wall-time EWMA; steps slower than
    ``straggler_factor``x the EWMA are counted and logged (on real fleets
    this feeds the scheduler; here it feeds metrics + tests),
  * elastic restart: restore onto a different mesh via shardings arg.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from ..ckpt import CheckpointManager, latest_step, load_checkpoint
from ..data import DataConfig, make_batches
from ..optim.adamw import OptState
from ..optim.compress import EFState
from .step import TrainState

NT_REGISTRY = {"TrainState": TrainState, "OptState": OptState,
               "EFState": EFState}


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    keep_ckpts: int = 3
    straggler_factor: float = 3.0


def train_loop(step_fn: Callable, state: TrainState, data_cfg: DataConfig,
               loop_cfg: TrainLoopConfig, *,
               state_shardings: Any = None,
               stop_flag: Optional[Callable[[], bool]] = None,
               on_metrics: Optional[Callable] = None) -> dict:
    """Run training; returns summary metrics."""
    start_step = 0
    if loop_cfg.ckpt_dir and latest_step(loop_cfg.ckpt_dir) is not None:
        state, extra = load_checkpoint(
            loop_cfg.ckpt_dir, shardings=state_shardings,
            nt_registry=NT_REGISTRY)
        start_step = int(extra["data_step"])

    mgr = (CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.keep_ckpts)
           if loop_cfg.ckpt_dir else None)

    losses, step_times = [], []
    ewma = None
    stragglers = 0
    it = make_batches(data_cfg, start_step)
    final_step = start_step

    for step, batch in it:
        if step >= loop_cfg.total_steps:
            break
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        step_times.append(dt)
        losses.append(loss)
        final_step = step + 1

        # straggler detection (EWMA of steady-state step time)
        if step - start_step >= 2:      # skip compile steps
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > loop_cfg.straggler_factor * ewma:
                stragglers += 1

        if on_metrics and step % loop_cfg.log_every == 0:
            on_metrics(step, dict(metrics, step_time=dt))

        if mgr and (step + 1) % loop_cfg.ckpt_every == 0:
            mgr.save_async(step + 1, state, extra={"data_step": step + 1})

        if stop_flag and stop_flag():
            if mgr:
                mgr.save_async(step + 1, state,
                               extra={"data_step": step + 1})
            break

    if mgr:
        mgr.wait()
    return {
        "final_step": final_step,
        "losses": np.asarray(losses),
        "mean_step_time": float(np.mean(step_times)) if step_times else 0.0,
        "stragglers": stragglers,
        "state": state,
    }
