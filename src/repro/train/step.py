"""train_step builder: loss -> grads -> (optional EF-int8) -> AdamW.

One function covers all ten architectures: the model family dispatch
(decoder / enc-dec / vlm) picks the loss; everything below it is shared.
Microbatch gradient accumulation happens inside the step (scan) so the
global batch is a config knob independent of memory.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..models import encdec, transformer, vlm
from ..models.config import ModelConfig
from ..optim import (AdamWConfig, EFState, OptState, adamw_init,
                     adamw_update, ef_compress_update, ef_init,
                     cosine_schedule, opt_state_specs)


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    ef: Optional[EFState]         # error-feedback residual (compression on)
    rng: jax.Array


@dataclasses.dataclass(frozen=True)
class StepConfig:
    opt: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    compress_grads: bool = False
    warmup_steps: int = 100
    total_steps: int = 10000


def model_loss(cfg: ModelConfig):
    if cfg.encdec is not None:
        return lambda p, batch: encdec.loss_fn(
            p, cfg, batch["frames"], batch["tokens"], batch["labels"])
    if cfg.vlm is not None:
        return lambda p, batch: vlm.loss_fn(
            p, cfg, batch["patches"], batch["tokens"], batch["labels"])
    return lambda p, batch: transformer.loss_fn(
        p, cfg, batch["tokens"], batch["labels"])


def init_train_state(cfg: ModelConfig, params, sc: StepConfig,
                     seed: int = 0) -> TrainState:
    return TrainState(
        params=params,
        opt=adamw_init(params, sc.opt),
        ef=ef_init(params) if sc.compress_grads else None,
        rng=jax.random.PRNGKey(seed))


def train_state_specs(cfg: ModelConfig, param_spec_tree, sc: StepConfig):
    leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    copy = lambda: jax.tree.map(lambda d: tuple(d), param_spec_tree,
                                is_leaf=leaf)
    return TrainState(
        params=copy(),
        opt=opt_state_specs(param_spec_tree, sc.opt),
        ef=EFState(residual=copy()) if sc.compress_grads else None,
        rng=(None,))


def make_train_step(cfg: ModelConfig, sc: StepConfig):
    loss_fn = model_loss(cfg)

    def train_step(state: TrainState, batch):
        mb = sc.microbatches

        def grads_of(p, b):
            (l, (ce, aux)), g = jax.value_and_grad(
                lambda pp: loss_fn(pp, b), has_aux=True)(p)
            return g, l, ce

        if mb == 1:
            grads, loss, ce = grads_of(state.params, batch)
        else:
            split = jax.tree.map(
                lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]),
                batch)

            def acc_fn(carry, b):
                g, l, c = grads_of(state.params, b)
                gacc, lacc, cacc = carry
                return (jax.tree.map(jnp.add, gacc, g), lacc + l,
                        cacc + c), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss, ce), _ = jax.lax.scan(
                acc_fn, (zero_g, jnp.zeros(()), jnp.zeros(())), split)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss, ce = loss / mb, ce / mb

        ef = state.ef
        if sc.compress_grads:
            grads, ef = ef_compress_update(grads, ef)

        lr = cosine_schedule(state.opt.step + 1, peak_lr=sc.opt.lr,
                             warmup_steps=sc.warmup_steps,
                             total_steps=sc.total_steps)
        params, opt, metrics = adamw_update(grads, state.opt, state.params,
                                            sc.opt, lr)
        rng, _ = jax.random.split(state.rng)
        new_state = TrainState(params=params, opt=opt, ef=ef, rng=rng)
        metrics = dict(metrics, loss=loss, ce=ce)
        return new_state, metrics

    return train_step
