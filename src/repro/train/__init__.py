"""repro.train — step builders + fault-tolerant training loop."""

from .step import TrainState, make_train_step, train_state_specs
from .loop import TrainLoopConfig, train_loop

__all__ = ["TrainState", "make_train_step", "train_state_specs",
           "TrainLoopConfig", "train_loop"]
