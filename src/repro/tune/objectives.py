"""Tuner objectives — device-side scalar figures of merit.

Every objective is a pure-JAX function ``fn(final, trace, ctx) ->
scalar`` over one finished rollout: ``final`` is the ``FluidState`` a
``decimating_scan`` returns, ``trace`` the stacked ``TraceSample``
pytree ([T, ...] leaves) and ``ctx`` an :class:`ObjCtx` of scenario
constants.  Nothing here touches the host, so a population tuner vmaps
(rollout + objective) over its parameter batch and the whole evaluation
stays one device launch — the same one-jit discipline as
``repro.core.experiments.Sweep``.

The four primitive metrics mirror the host-side ``SimResult`` methods
(``jain_index`` / ``p99_slowdown`` / ``ctrl_per_mb``) on the decimated
trace, with one deliberate simplification: per-flow mean rate is
``delivered / active-span`` instead of the host's completion-time
bookkeeping — identical for window-mode flows, and a monotone proxy for
volume-mode ones.  Gradient-based tuners differentiate these through
the soft rollout (``repro.tune.soft``); the *decisions* (which
parameter point wins) are always re-taken on the hard model via
``Sweep.run`` + host metrics, so the proxy never gets the final word.

Scales: tail and overhead metrics enter combinations in log space so a
weighted scalarisation mixes O(1) terms —

  ==============  ======================================  =========
  name            objective value                         sense
  ==============  ======================================  =========
  goodput         delivered / offered capacity  [0, 1]    higher
  jain            Jain fairness index           [0, 1]    higher
  p99_slowdown    log(p99 flow slowdown)        [0, ~9]   lower
  ctrl_overhead   log1p(notifications per MB)   [0, ~7]   lower
  ==============  ======================================  =========

``resolve`` turns a name, a ``{name: weight}`` dict or a callable into
one higher-is-better scalar function (senses applied internally).
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax.numpy as jnp

_TINY = 1e-12


class ObjCtx(NamedTuple):
    """Scenario constants an objective needs beside the rollout."""

    gen_rate: jnp.ndarray     # [F] f32 B/s offered
    t_start: jnp.ndarray      # [F] f32 s
    t_stop: jnp.ndarray       # [F] f32 s (inf = volume mode)
    line_rate: jnp.ndarray    # [] f32 B/s
    horizon: jnp.ndarray      # [] f32 s simulated
    dt: jnp.ndarray           # [] f32 s


def make_ctx(scn, line_rate: float, horizon: float, dt: float) -> ObjCtx:
    """Build an :class:`ObjCtx` from a (host or device) scenario."""
    f32 = lambda x: jnp.asarray(x, jnp.float32)
    return ObjCtx(gen_rate=f32(scn.gen_rate), t_start=f32(scn.t_start),
                  t_stop=f32(scn.t_stop), line_rate=f32(line_rate),
                  horizon=f32(horizon), dt=f32(dt))


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------


def _real(ctx: ObjCtx) -> jnp.ndarray:
    """[F] f32 mask of flows with actual offered work (sweep padding
    rows carry zero rate)."""
    return (ctx.gen_rate > 0).astype(jnp.float32)


def _flow_rate(final, ctx: ObjCtx) -> jnp.ndarray:
    """[F] mean delivery rate over each flow's active span (B/s)."""
    t1 = jnp.minimum(ctx.t_stop, ctx.horizon)
    span = jnp.maximum(t1 - ctx.t_start, ctx.dt)
    return final.delivered / span


# ---------------------------------------------------------------------------
# primitive metrics (natural sense; see SENSE below)
# ---------------------------------------------------------------------------


def goodput(final, trace, ctx: ObjCtx) -> jnp.ndarray:
    """Delivered fraction of the offered (line-rate-capped) capacity."""
    m = _real(ctx)
    thr = _flow_rate(final, ctx)
    cap = jnp.sum(m * jnp.minimum(ctx.gen_rate, ctx.line_rate))
    return jnp.sum(m * thr) / jnp.maximum(cap, _TINY)


def jain(final, trace, ctx: ObjCtx) -> jnp.ndarray:
    """Jain fairness over per-flow mean rates, in [1/n, 1]."""
    m = _real(ctx)
    x = m * _flow_rate(final, ctx)
    n = jnp.sum(m)
    return jnp.sum(x) ** 2 / jnp.maximum(n * jnp.sum(x * x), _TINY)


def p99_slowdown(final, trace, ctx: ObjCtx) -> jnp.ndarray:
    """log of the ~p99 demand-normalised flow slowdown (lower better).

    Slowdown = min(offered, line) / achieved.  The p99 is the order
    statistic at rank ``ceil(0.01 * n_real)`` from the top of the real
    flows (non-real rows sort to the bottom at slowdown 1); the sort
    permutation is differentiable almost everywhere, and the log keeps
    the value O(1) next to goodput/jain in scalarisations.
    """
    m = _real(ctx)
    thr = _flow_rate(final, ctx)
    ideal = jnp.minimum(ctx.gen_rate, ctx.line_rate)
    s = ideal / jnp.maximum(thr, 1e-6 * ctx.line_rate)
    s = jnp.where(m > 0, s, 1.0)
    top = jnp.sort(s)[::-1]                       # descending
    n = jnp.sum(m)
    k = jnp.clip(jnp.ceil(0.01 * n).astype(jnp.int32) - 1, 0,
                 s.shape[0] - 1)
    return jnp.log(jnp.maximum(top[k], 1.0))


def ctrl_overhead(final, trace, ctx: ObjCtx) -> jnp.ndarray:
    """log1p of notification messages per delivered MB (lower better).

    ``trace.ctrl`` accumulates (possibly fractional, under the soft
    model) notification emissions per decimation window; the sum over
    the trace is the run total.
    """
    msgs = jnp.sum(trace.ctrl)
    mb = jnp.sum(final.delivered) / 1e6
    return jnp.log1p(msgs / jnp.maximum(mb, 1e-3))


OBJECTIVES: dict[str, Callable] = {
    "goodput": goodput,
    "jain": jain,
    "p99_slowdown": p99_slowdown,
    "ctrl_overhead": ctrl_overhead,
}

#: +1 = the metric is already higher-is-better; -1 = it is a cost.
SENSE = {"goodput": 1.0, "jain": 1.0,
         "p99_slowdown": -1.0, "ctrl_overhead": -1.0}

#: The default scalarisation ``autotune`` optimises: mostly goodput,
#: with fairness, tail and control-traffic regularisers.
DEFAULT_WEIGHTS = {"goodput": 1.0, "jain": 0.25,
                   "p99_slowdown": 0.15, "ctrl_overhead": 0.02}


def weighted(weights: dict[str, float]) -> Callable:
    """Higher-is-better scalarisation ``sum_k w_k * sense_k * metric_k``.

    Weights are positive importances; senses are applied here, so
    ``{"goodput": 1, "p99_slowdown": 0.1}`` rewards goodput and
    penalises tail slowdown without sign gymnastics at the call site.
    """
    unknown = set(weights) - set(OBJECTIVES)
    if unknown:
        raise KeyError(f"unknown objective(s) {sorted(unknown)}; "
                       f"have {sorted(OBJECTIVES)}")

    def fn(final, trace, ctx):
        tot = jnp.asarray(0.0, jnp.float32)
        for name, w in sorted(weights.items()):
            tot = tot + jnp.float32(w * SENSE[name]) \
                * OBJECTIVES[name](final, trace, ctx)
        return tot

    return fn


def resolve(objective) -> tuple[Callable, str]:
    """(higher-is-better scalar fn, cache signature) from a name, a
    ``{name: weight}`` dict, ``"default"`` or a raw callable."""
    if callable(objective):
        sig = getattr(objective, "__name__", None) or repr(objective)
        return objective, f"callable:{sig}"
    if objective == "default":
        objective = DEFAULT_WEIGHTS
    if isinstance(objective, str):
        if objective not in OBJECTIVES:
            raise KeyError(f"unknown objective {objective!r}; "
                           f"have {sorted(OBJECTIVES)} or a weight dict")
        name = objective
        fn = lambda final, trace, ctx: \
            jnp.float32(SENSE[name]) * OBJECTIVES[name](final, trace, ctx)
        return fn, f"name:{name}"
    if isinstance(objective, dict):
        sig = ",".join(f"{k}={float(v):g}"
                       for k, v in sorted(objective.items()))
        return weighted(objective), f"weighted:{sig}"
    raise TypeError(f"objective must be a name, weight dict or callable; "
                    f"got {type(objective).__name__}")
