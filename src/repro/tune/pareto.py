"""``autotune`` — the tuning front door — and Pareto-front utilities.

``autotune(cfg, scenario)`` replaces a hand-rolled ``config_grid``
sweep: pick a tuner (gradient on the soft model, ES or BO on the hard
one), run it, then **re-score every candidate on the exact hard model**
in one ``Sweep`` launch and return the winner.  The decision never
trusts the smoothed objective: a tuned config is reported as an
improvement only if its unsmoothed rollout beats the baseline's.

``pareto_autotune`` runs a scalarisation sweep (a weight grid over two
or more objectives), pools every hard-scored candidate and keeps the
non-dominated set — the goodput / tail-latency / overhead trade-off
curve the paper's single-number tables flatten.  Records serialise
through ``repro.core.serialize`` (``TuneResult.to_record``) for the
``BENCH_tune.json`` benchmark trail.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.serialize import config_to_dict

from . import objectives
from .optimizers import (TUNERS, Evaluator, ParamBox, TuneProblem,
                         TuneTrace, _TraceShim)

# ---------------------------------------------------------------------------
# Pareto fronts
# ---------------------------------------------------------------------------


def pareto_front(values: np.ndarray, senses=None) -> np.ndarray:
    """Indices of the non-dominated rows of ``values`` [N, M].

    ``senses`` ([M] of +/-1, default all +1) orients each column so
    that larger-after-scaling is better.  A point is kept iff no other
    point is >= in every objective and > in at least one.  Duplicate
    rows all survive (none strictly dominates its twin).
    """
    v = np.asarray(values, np.float64)
    if v.ndim != 2:
        raise ValueError(f"values must be [N, M], got shape {v.shape}")
    if senses is not None:
        v = v * np.asarray(senses, np.float64)[None, :]
    keep = []
    for i in range(v.shape[0]):
        ge = (v >= v[i]).all(axis=1)
        gt = (v > v[i]).any(axis=1)
        if not (ge & gt).any():
            keep.append(i)
    return np.asarray(keep, np.int64)


# ---------------------------------------------------------------------------
# hard re-scoring (the decision pass)
# ---------------------------------------------------------------------------


def _hard_eval(ev: Evaluator, thetas: np.ndarray):
    """One hard sweep over a theta batch -> (objective [P], metric
    dicts).  Metrics are the primitive objectives in natural units
    (p99 and ctrl unlogged) plus the host summary's aggregate Gbps."""
    from repro.core.experiments import Sweep
    thetas = np.atleast_2d(np.asarray(thetas, np.float64))
    points = [(f"t{i}", ev.box.to_spec(ev.spec, th), ev.scn)
              for i, th in enumerate(thetas)]
    res = Sweep(points).run(n_steps=ev.problem.n_steps,
                            trace_every=ev.k)
    vals, metrics = [], []
    for i in range(len(thetas)):
        r = res[i]
        vals.append(ev.hard_objective(r))
        shim = _TraceShim(r.ctrl)
        raw = {name: float(np.asarray(fn(r.final, shim, ev.ctx)))
               for name, fn in objectives.OBJECTIVES.items()}
        raw["p99_slowdown"] = float(np.exp(raw["p99_slowdown"]))
        raw["ctrl_overhead"] = float(np.expm1(raw["ctrl_overhead"]))
        raw["aggregate_gbps"] = float(
            r.mean_throughput_while_active().sum() / 1e9)
        metrics.append(raw)
    return np.asarray(vals), metrics


def _select_candidates(trace: TuneTrace, limit: int) -> np.ndarray:
    """Up to ``limit`` distinct thetas worth hard-scoring: the final
    iterate plus the tuner's top-valued visits."""
    order = np.argsort(trace.value)[::-1]
    picked = [len(trace.theta) - 1]            # always the final iterate
    for i in order:
        if len(picked) >= limit:
            break
        if not any(np.array_equal(trace.theta[i], trace.theta[j])
                   for j in picked):
            picked.append(int(i))
    return trace.theta[picked]


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TuneResult:
    """Outcome of one ``autotune`` call.

    ``improvement`` compares hard-model objectives (tuned minus
    baseline, higher-is-better scale); ``best_cfg`` is the winning
    frozen ``CCSpec`` (the *original* config when nothing beat it).
    """

    method: str
    objective: str                 # resolved signature string
    knobs: tuple                   # box knob names
    baseline_value: float
    best_value: float
    best_params: dict              # {knob: physical value}
    best_cfg: object               # CCSpec (or the input cfg if best)
    baseline_metrics: dict
    best_metrics: dict
    candidates: np.ndarray         # [P, d] hard-scored thetas
    candidate_values: np.ndarray   # [P]
    candidate_metrics: list
    trace: TuneTrace

    @property
    def improvement(self) -> float:
        return self.best_value - self.baseline_value

    @property
    def improved(self) -> bool:
        return self.best_value > self.baseline_value

    def to_record(self) -> dict:
        """JSON-ready benchmark record (``BENCH_tune.json`` row)."""
        return {
            "method": self.method,
            "objective": self.objective,
            "knobs": list(self.knobs),
            "baseline_value": float(self.baseline_value),
            "best_value": float(self.best_value),
            "improvement": float(self.improvement),
            "improved": bool(self.improved),
            "best_params": {k: float(v)
                            for k, v in self.best_params.items()},
            "best_cfg": config_to_dict(self.best_cfg),
            "baseline_metrics": self.baseline_metrics,
            "best_metrics": self.best_metrics,
            "n_evaluations": int(len(self.trace.value)),
        }


# ---------------------------------------------------------------------------
# front doors
# ---------------------------------------------------------------------------


def autotune(cfg, scenario, *, objective="default", method: str = "grad",
             box: ParamBox = None, n_steps: int = 2000,
             trace_every: int = 50, seed: int = 0,
             ckpt_dir: str = None, ckpt_every: int = 0,
             max_candidates: int = 16, **tuner_kw) -> TuneResult:
    """Tune ``cfg``'s CC constants for ``scenario`` and verify on the
    hard model.

    ``method`` picks the tuner (``"grad"`` / ``"es"`` / ``"bo"``);
    ``tuner_kw`` forwards to its constructor (e.g. ``iters=20``,
    ``temperature=0.05``).  ``ckpt_dir`` makes the tuner resumable
    through ``repro.ckpt`` (bit-exact).  The returned
    :class:`TuneResult` carries the hard-verified winner — compare
    ``best_value`` against ``baseline_value`` (same objective, same
    unsmoothed model, scored in one batched sweep with the candidates).
    """
    if method not in TUNERS:
        raise KeyError(f"unknown method {method!r}; have {sorted(TUNERS)}")
    problem = TuneProblem(cfg, scenario, objective=objective, box=box,
                          n_steps=n_steps, trace_every=trace_every)
    ev = Evaluator(problem)
    tuner = TUNERS[method](**tuner_kw)
    trace = tuner.run(ev, seed=seed, ckpt_dir=ckpt_dir,
                      ckpt_every=ckpt_every)

    theta0 = ev.box.encode(ev.spec)
    cand = np.vstack([theta0[None],
                      _select_candidates(trace, max_candidates)])
    values, metrics = _hard_eval(ev, cand)
    best = int(np.argmax(values))
    names = ev.box.names
    best_vals = ev.box.values(np.asarray(cand[best], np.float32), xp=np)
    return TuneResult(
        method=method, objective=ev.obj_sig, knobs=names,
        baseline_value=float(values[0]), best_value=float(values[best]),
        best_params=dict(zip(names, map(float, best_vals))),
        best_cfg=ev.spec if best == 0
        else ev.box.to_spec(ev.spec, cand[best]),
        baseline_metrics=metrics[0], best_metrics=metrics[best],
        candidates=cand, candidate_values=values,
        candidate_metrics=metrics, trace=trace)


def pareto_autotune(cfg, scenario, *, axes=("goodput", "p99_slowdown"),
                    n_weights: int = 5, method: str = "grad",
                    box: ParamBox = None, n_steps: int = 2000,
                    trace_every: int = 50, seed: int = 0,
                    **tuner_kw) -> dict:
    """Trade-off curve between two (or more) objectives.

    Runs ``autotune`` once per scalarisation weight (a geometric ramp
    of relative importances over ``axes``), pools every hard-scored
    candidate and returns the non-dominated set::

        {"axes": [...], "front": [{"weights": ..., "params": ...,
                                   "metrics": ...}, ...],
         "results": [TuneResult, ...]}

    The front is computed on the *hard* metric vectors, senses applied
    from ``objectives.SENSE`` — every point on it is a real,
    unsmoothed operating point of the model.
    """
    if len(axes) < 2:
        raise ValueError("pareto_autotune needs >= 2 objective axes")
    for a in axes:
        if a not in objectives.OBJECTIVES:
            raise KeyError(f"unknown objective axis {a!r}")
    ramps = np.linspace(0.0, 1.0, n_weights)
    results = []
    for w in ramps:
        # two-axis ramp; extra axes keep a small constant weight
        weights = {axes[0]: float(1.0 - w) + 1e-3,
                   axes[1]: float(w) + 1e-3}
        for a in axes[2:]:
            weights[a] = 0.05
        results.append(autotune(
            cfg, scenario, objective=weights, method=method, box=box,
            n_steps=n_steps, trace_every=trace_every, seed=seed,
            **tuner_kw))
    from .optimizers import box_for
    the_box = box if box is not None else box_for(cfg)
    pool_params, pool_metrics, pool_weights = [], [], []
    for res in results:
        for th, mets in zip(res.candidates, res.candidate_metrics):
            vals = the_box.values(np.asarray(th, np.float32), xp=np)
            pool_params.append(dict(zip(res.knobs, map(float, vals))))
            pool_metrics.append(mets)
            pool_weights.append(res.objective)
    mat = np.asarray([[m[a] for a in axes] for m in pool_metrics])
    # metrics are natural units here; log-senses still order the same
    senses = [objectives.SENSE[a] for a in axes]
    keep = pareto_front(mat, senses)
    front = [{"weights": pool_weights[i], "params": pool_params[i],
              "metrics": pool_metrics[i],
              "axis_values": [float(x) for x in mat[i]]}
             for i in keep]
    return {"axes": list(axes), "front": front, "results": results}
